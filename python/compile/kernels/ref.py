"""Pure-jnp correctness oracles for the Pallas kernels (L1 ground truth).

Every kernel in `hadamard.py` is checked against these references by
`python/tests/test_kernel.py` (pytest + hypothesis-style sweeps). The
references favour clarity over speed: `fwht_ref` is the O(N^2) dense
multiply by the explicit Sylvester Hadamard matrix.
"""

import numpy as np
import jax.numpy as jnp


def hadamard_matrix(n: int) -> np.ndarray:
    """Explicit +-1 Sylvester Hadamard matrix (n a power of two)."""
    assert n & (n - 1) == 0 and n > 0, f"n={n} not a power of two"
    h = np.array([[1.0]], dtype=np.float64)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h


def fwht_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Orthonormal Walsh-Hadamard transform of the last axis, O(N^2)."""
    n = x.shape[-1]
    h = jnp.asarray(hadamard_matrix(n), dtype=jnp.float32) / jnp.sqrt(
        jnp.asarray(n, dtype=jnp.float32)
    )
    return (x.astype(jnp.float32) @ h).astype(x.dtype)


def ndsc_embed_ref(y: jnp.ndarray, signs: jnp.ndarray) -> jnp.ndarray:
    """Near-democratic embedding x = H D y (Parseval Hadamard frame with
    P = I, i.e. n == N): sign-flip then orthonormal FWHT."""
    return fwht_ref(y * signs)


def ndsc_decode_ref(x: jnp.ndarray, signs: jnp.ndarray) -> jnp.ndarray:
    """Inverse transform y = D H x (H symmetric, D = D^-1)."""
    return fwht_ref(x) * signs


def uniform_quantize_ref(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Deterministic nearest-neighbour uniform quantizer on [-scale, scale]
    with 2^bits cells (eq. 11 of the paper), matching
    rust/src/quant/uniform.rs exactly."""
    m = 2 ** bits
    delta = 2.0 / m
    t = jnp.clip(x / jnp.maximum(scale, 1e-30), -1.0, 1.0)
    idx = jnp.clip(jnp.floor((t + 1.0) / delta), 0, m - 1)
    return scale * (-1.0 + (2.0 * idx + 1.0) * delta / 2.0)
