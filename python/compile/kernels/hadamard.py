"""L1 Pallas kernels: batched fast Walsh-Hadamard transform and the fused
NDSC embed (sign-flip -> FWHT -> l_inf scale), the compute hot-spot of
Near-Democratic Source Coding (paper §2.1).

Hardware adaptation (DESIGN.md §2): the paper's transform ran on
CPU/MATLAB; a CUDA port would use warp butterflies + shared memory. On TPU
the right shape is a *batch-tiled, VMEM-resident* kernel: each grid step
pulls a (block_rows x N) tile from HBM into VMEM (BlockSpec), runs all
log2(N) butterfly stages in-register on the VPU (+-1 butterflies do not
benefit from the MXU), and writes the tile back once. Sign-flip and scale
extraction fuse into the same kernel so the embedding never round-trips to
HBM between stages.

Pallas is invoked with interpret=True throughout: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret mode lowers to plain HLO
that the Rust runtime can run (see /opt/xla-example/README.md). Real-TPU
performance is estimated from the VMEM footprint in DESIGN.md §8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwht_stages(x: jnp.ndarray) -> jnp.ndarray:
    """All log2(N) butterfly stages over the last axis (unnormalized).

    The loop is a Python (trace-time) loop: N is static, so this unrolls
    into log2(N) fused adds/subs — exactly the structure a Mosaic build
    would keep in VMEM.
    """
    shape = x.shape
    n = shape[-1]
    h = 1
    while h < n:
        x = x.reshape(shape[:-1] + (n // (2 * h), 2, h))
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.stack([a + b, a - b], axis=-2).reshape(shape)
        h *= 2
    return x


def _fwht_kernel(x_ref, o_ref, *, n: int):
    """Pallas kernel: orthonormal FWHT of a (rows, n) VMEM tile."""
    x = x_ref[...]
    y = _fwht_stages(x)
    o_ref[...] = y * (1.0 / jnp.sqrt(jnp.asarray(n, dtype=x.dtype)))


def fwht_pallas(x: jnp.ndarray, block_rows: int = 8) -> jnp.ndarray:
    """Batched orthonormal FWHT over the last axis via pallas_call.

    `x`: (batch, n) with n a power of two; batch need not divide
    block_rows — the grid covers ceil(batch / block_rows) tiles and Pallas
    masks the tail tile.
    """
    b, n = x.shape
    assert n & (n - 1) == 0, f"n={n} must be a power of two"
    rows = min(block_rows, b)
    grid = ((b + rows - 1) // rows,)
    return pl.pallas_call(
        functools.partial(_fwht_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((rows, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, n), lambda i: (i, 0)),
        interpret=True,
    )(x)


def _ndsc_embed_kernel(y_ref, signs_ref, o_ref, *, n: int):
    """Fused NDSC embed tile: x = H . (D y), all in VMEM."""
    y = y_ref[...]
    d = signs_ref[...]
    x = _fwht_stages(y * d[None, :])
    o_ref[...] = x * (1.0 / jnp.sqrt(jnp.asarray(n, dtype=y.dtype)))


def ndsc_embed_pallas(
    y: jnp.ndarray, signs: jnp.ndarray, block_rows: int = 8
) -> jnp.ndarray:
    """Near-democratic embedding x = H D y for a batch of vectors.

    `y`: (batch, n); `signs`: (n,) of +-1. Equivalent to
    `ref.ndsc_embed_ref` but single-pass through VMEM.
    """
    b, n = y.shape
    assert signs.shape == (n,)
    rows = min(block_rows, b)
    grid = ((b + rows - 1) // rows,)
    return pl.pallas_call(
        functools.partial(_ndsc_embed_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((b, n), y.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, n), lambda i: (i, 0)),
        interpret=True,
    )(y, signs)


def ndsc_decode_pallas(
    x: jnp.ndarray, signs: jnp.ndarray, block_rows: int = 8
) -> jnp.ndarray:
    """Inverse transform y = D H x (H symmetric, D its own inverse)."""
    b, n = x.shape
    hx = fwht_pallas(x, block_rows=block_rows)
    return hx * signs[None, :]


def vmem_footprint_bytes(block_rows: int, n: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency of one grid step of the embed kernel:
    input tile + signs + output tile (double-buffered input).

    Used by DESIGN.md §8 to size block_rows: with N = 2^17 and
    block_rows = 8 the footprint is ~12.6 MiB < 16 MiB VMEM.
    """
    tile = block_rows * n * dtype_bytes
    return 2 * tile + n * dtype_bytes + tile
