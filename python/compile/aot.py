"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the Rust runtime.

Run once via `make artifacts` (a no-op when outputs are newer than inputs);
Python never appears on the request path. The interchange format is HLO
text, NOT `.serialize()`: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that the image's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts (all under --out-dir, default ../artifacts):
  model_grad.hlo.txt        (flat_params[n], tokens[b,s]u32, targets) -> (loss, grad[n])
  model_loss.hlo.txt        same inputs -> (loss,)
  model_grad_embed.hlo.txt  + signs[N] -> (loss, x_nd[N], linf) — L2 calling the L1 Pallas kernel
  ndsc_embed_{N}.hlo.txt    (y[1,N], signs[N]) -> (x_nd[1,N],) — standalone L1 kernel
  ndsc_decode_{N}.hlo.txt   (x[1,N], signs[N]) -> (y[1,N],)
  model_meta.txt            key=value metadata (n_params, config, padded N)

Model size is configurable through KF_* env vars (defaults give a ~0.9M
parameter transformer that trains in minutes on CPU; KF_DMODEL=256
KF_LAYERS=4 gives ~13M for bigger runs).
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.hadamard import ndsc_decode_pallas, ndsc_embed_pallas


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def env_int(name: str, default: int) -> int:
    return int(os.environ.get(name, default))


def config_from_env() -> M.ModelConfig:
    return M.ModelConfig(
        vocab=env_int("KF_VOCAB", 64),
        d_model=env_int("KF_DMODEL", 128),
        n_heads=env_int("KF_HEADS", 4),
        n_layers=env_int("KF_LAYERS", 2),
        seq=env_int("KF_SEQ", 64),
        batch=env_int("KF_BATCH", 8),
    )


def write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")


def lower_model(cfg: M.ModelConfig, out_dir: str) -> None:
    n = cfg.n_params
    big_n = M.padded_dim(n)
    flat = jax.ShapeDtypeStruct((n,), jnp.float32)
    toks = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.uint32)
    signs = jax.ShapeDtypeStruct((big_n,), jnp.float32)

    def grad_fn(flat, tokens, targets):
        loss, g = M.loss_and_grad(cfg, flat, tokens, targets)
        return (loss, g)

    def loss_fn(flat, tokens, targets):
        return (M.loss_fn(cfg, flat, tokens, targets),)

    def grad_embed_fn(flat, tokens, targets, signs):
        return M.loss_and_grad_embed(cfg, flat, tokens, targets, signs)

    print(f"model: {n} params (padded N = {big_n}), cfg = {cfg}")
    write(
        os.path.join(out_dir, "model_grad.hlo.txt"),
        to_hlo_text(jax.jit(grad_fn).lower(flat, toks, toks)),
    )
    write(
        os.path.join(out_dir, "model_loss.hlo.txt"),
        to_hlo_text(jax.jit(loss_fn).lower(flat, toks, toks)),
    )
    write(
        os.path.join(out_dir, "model_grad_embed.hlo.txt"),
        to_hlo_text(jax.jit(grad_embed_fn).lower(flat, toks, toks, signs)),
    )
    # Initial parameters for the Rust server (flat f32 little-endian).
    import numpy as np

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    flat = np.asarray(M.flatten(cfg, params), dtype="<f4")
    flat.tofile(os.path.join(out_dir, "model_init.bin"))
    print(f"  wrote {out_dir}/model_init.bin ({flat.nbytes / 1e6:.2f} MB)")
    meta = "\n".join(
        [
            f"n_params={n}",
            f"padded_n={big_n}",
            f"vocab={cfg.vocab}",
            f"d_model={cfg.d_model}",
            f"n_heads={cfg.n_heads}",
            f"n_layers={cfg.n_layers}",
            f"seq={cfg.seq}",
            f"batch={cfg.batch}",
        ]
    )
    write(os.path.join(out_dir, "model_meta.txt"), meta + "\n")


def lower_kernels(out_dir: str, sizes) -> None:
    for big_n in sizes:
        y = jax.ShapeDtypeStruct((1, big_n), jnp.float32)
        s = jax.ShapeDtypeStruct((big_n,), jnp.float32)

        def embed(yv, sv):
            return (ndsc_embed_pallas(yv, sv),)

        def decode(xv, sv):
            return (ndsc_decode_pallas(xv, sv),)

        write(
            os.path.join(out_dir, f"ndsc_embed_{big_n}.hlo.txt"),
            to_hlo_text(jax.jit(embed).lower(y, s)),
        )
        write(
            os.path.join(out_dir, f"ndsc_decode_{big_n}.hlo.txt"),
            to_hlo_text(jax.jit(decode).lower(y, s)),
        )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--kernel-sizes",
        default="1024,4096",
        help="comma-separated padded dims for standalone NDSC kernels",
    )
    ap.add_argument("--skip-model", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    cfg = config_from_env()
    if not args.skip_model:
        lower_model(cfg, args.out_dir)
    sizes = [int(s) for s in args.kernel_sizes.split(",") if s]
    lower_kernels(args.out_dir, sizes)
    print("artifacts complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
