"""L2: a small causal transformer language model in pure JAX.

The paper's Fig. 3b / Fig. 7 non-convex workload (a CNN on CIFAR-10,
infeasible on this CPU-only offline image — DESIGN.md §3) is adapted to a
byte-level transformer LM trained through the full Rust coordinator with
quantized gradients. This module defines:

  * `init_params` / `flatten` / `unflatten` — the parameter vector the
    Rust server owns is the flat f32 vector; the order here is the wire
    contract (opaque to Rust, which only needs its length).
  * `loss_fn` — mean next-token cross-entropy.
  * `loss_and_grad` — value_and_grad, returned flat. Lowered by aot.py to
    `artifacts/model_grad.hlo.txt` and executed from Rust via PJRT.
  * `loss_and_grad_embed` — same, but the flat gradient additionally runs
    through the L1 Pallas NDSC-embed kernel (sign-flip + FWHT), so the
    democratic transform lowers into the *same* HLO as the backward pass
    and never leaves the device.
"""

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from compile.kernels.hadamard import ndsc_embed_pallas


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 64
    d_model: int = 64
    n_heads: int = 2
    n_layers: int = 2
    seq: int = 64
    batch: int = 8

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def shapes(self):
        """Ordered (name, shape) list — the flattening contract."""
        c = self
        out = [
            ("tok_embed", (c.vocab, c.d_model)),
            ("pos_embed", (c.seq, c.d_model)),
        ]
        for layer in range(c.n_layers):
            out += [
                (f"l{layer}.ln1_g", (c.d_model,)),
                (f"l{layer}.ln1_b", (c.d_model,)),
                (f"l{layer}.wqkv", (c.d_model, 3 * c.d_model)),
                (f"l{layer}.wo", (c.d_model, c.d_model)),
                (f"l{layer}.ln2_g", (c.d_model,)),
                (f"l{layer}.ln2_b", (c.d_model,)),
                (f"l{layer}.w1", (c.d_model, 4 * c.d_model)),
                (f"l{layer}.b1", (4 * c.d_model,)),
                (f"l{layer}.w2", (4 * c.d_model, c.d_model)),
                (f"l{layer}.b2", (c.d_model,)),
            ]
        out += [("lnf_g", (c.d_model,)), ("lnf_b", (c.d_model,))]
        # output head tied to tok_embed (no extra params)
        return out

    @property
    def n_params(self) -> int:
        return sum(math.prod(s) for _, s in self.shapes())


def init_params(cfg: ModelConfig, key) -> dict:
    params = {}
    for name, shape in cfg.shapes():
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_b", ".b1", ".b2")):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(float(fan_in))
            )
    return params


def flatten(cfg: ModelConfig, params: dict) -> jnp.ndarray:
    return jnp.concatenate([params[name].reshape(-1) for name, _ in cfg.shapes()])


def unflatten(cfg: ModelConfig, flat: jnp.ndarray) -> dict:
    params = {}
    off = 0
    for name, shape in cfg.shapes():
        size = math.prod(shape)
        params[name] = flat[off : off + size].reshape(shape)
        off += size
    return params


def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(cfg: ModelConfig, x, wqkv, wo):
    b, s, d = x.shape
    qkv = x @ wqkv  # (b, s, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    scores = q @ k.transpose(0, 1, 3, 2) / math.sqrt(cfg.d_head)  # (b,h,s,s)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = (probs @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ wo


def forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    """Logits (batch, seq, vocab) for u32 tokens (batch, seq)."""
    x = params["tok_embed"][tokens] + params["pos_embed"][None, :, :]
    for layer in range(cfg.n_layers):
        p = lambda k: params[f"l{layer}.{k}"]
        h = _layer_norm(x, p("ln1_g"), p("ln1_b"))
        x = x + _attention(cfg, h, p("wqkv"), p("wo"))
        h = _layer_norm(x, p("ln2_g"), p("ln2_b"))
        h = jax.nn.gelu(h @ p("w1") + p("b1")) @ p("w2") + p("b2")
        x = x + h
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["tok_embed"].T  # tied head


def loss_fn(cfg: ModelConfig, flat: jnp.ndarray, tokens, targets) -> jnp.ndarray:
    """Mean next-token cross-entropy (nats)."""
    params = unflatten(cfg, flat)
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None].astype(jnp.int32), axis=-1)
    return nll.mean()


def loss_and_grad(cfg: ModelConfig, flat, tokens, targets):
    """(loss, flat_grad) — the worker's oracle call."""
    loss, grad = jax.value_and_grad(loss_fn, argnums=1)(cfg, flat, tokens, targets)
    return loss, grad


def padded_dim(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def loss_and_grad_embed(cfg: ModelConfig, flat, tokens, targets, signs):
    """(loss, x_nd, linf) — the gradient already pushed through the L1
    Pallas NDSC-embed kernel (zero-pad to N = 2^ceil(log2 n), sign-flip,
    FWHT). `signs`: (N,) of +-1. The Rust worker then only normalizes by
    `linf` and bit-packs — the O(n log n) hot-spot stays in the artifact.
    """
    loss, grad = loss_and_grad(cfg, flat, tokens, targets)
    big_n = padded_dim(grad.shape[0])
    padded = jnp.zeros((1, big_n), jnp.float32).at[0, : grad.shape[0]].set(grad)
    x = ndsc_embed_pallas(padded, signs)[0]
    return loss, x, jnp.max(jnp.abs(x))
