"""L2 correctness: transformer shapes, gradient integrity, trainability,
and the L2-calls-L1 composition (loss_and_grad_embed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.ModelConfig(vocab=32, d_model=32, n_heads=2, n_layers=2, seq=16, batch=4)


def make_batch(key, cfg=CFG):
    k1, k2 = jax.random.split(key)
    toks = jax.random.randint(k1, (cfg.batch, cfg.seq), 0, cfg.vocab).astype(jnp.uint32)
    tgts = jax.random.randint(k2, (cfg.batch, cfg.seq), 0, cfg.vocab).astype(jnp.uint32)
    return toks, tgts


def test_param_count_and_flatten_roundtrip():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    flat = M.flatten(CFG, params)
    assert flat.shape == (CFG.n_params,)
    back = M.unflatten(CFG, flat)
    for name, _ in CFG.shapes():
        np.testing.assert_array_equal(back[name], params[name])


def test_forward_shapes_and_finiteness():
    params = M.init_params(CFG, jax.random.PRNGKey(1))
    toks, _ = make_batch(jax.random.PRNGKey(2))
    logits = M.forward(CFG, params, toks)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform():
    params = M.init_params(CFG, jax.random.PRNGKey(3))
    flat = M.flatten(CFG, params)
    toks, tgts = make_batch(jax.random.PRNGKey(4))
    loss = M.loss_fn(CFG, flat, toks, tgts)
    # near log(vocab) at init
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.7


def test_causality():
    """Changing a future token must not affect earlier logits."""
    params = M.init_params(CFG, jax.random.PRNGKey(5))
    toks, _ = make_batch(jax.random.PRNGKey(6))
    logits1 = M.forward(CFG, params, toks)
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % CFG.vocab)
    logits2 = M.forward(CFG, params, toks2)
    np.testing.assert_allclose(
        logits1[:, :-1], logits2[:, :-1], rtol=1e-5, atol=1e-5
    )
    assert float(jnp.max(jnp.abs(logits1[:, -1] - logits2[:, -1]))) > 1e-4


def test_grad_matches_finite_difference():
    params = M.init_params(CFG, jax.random.PRNGKey(7))
    flat = M.flatten(CFG, params)
    toks, tgts = make_batch(jax.random.PRNGKey(8))
    loss, grad = M.loss_and_grad(CFG, flat, toks, tgts)
    assert grad.shape == flat.shape
    rng = np.random.default_rng(0)
    idx = rng.choice(CFG.n_params, size=5, replace=False)
    eps = 1e-3
    for j in idx:
        e = jnp.zeros_like(flat).at[j].set(eps)
        fp = M.loss_fn(CFG, flat + e, toks, tgts)
        fm = M.loss_fn(CFG, flat - e, toks, tgts)
        fd = (float(fp) - float(fm)) / (2 * eps)
        assert abs(fd - float(grad[j])) < 5e-3 + 0.05 * abs(fd), (j, fd, float(grad[j]))


def test_few_gd_steps_reduce_loss():
    params = M.init_params(CFG, jax.random.PRNGKey(9))
    flat = M.flatten(CFG, params)
    # a fixed, learnable batch (memorization)
    toks, tgts = make_batch(jax.random.PRNGKey(10))
    grad_fn = jax.jit(lambda f: M.loss_and_grad(CFG, f, toks, tgts))
    loss0, _ = grad_fn(flat)
    for _ in range(30):
        _, g = grad_fn(flat)
        flat = flat - 0.5 * g
    loss1, _ = grad_fn(flat)
    assert float(loss1) < 0.7 * float(loss0)


def test_loss_and_grad_embed_composes_l1():
    """The embedded gradient must equal ref-embedding of the plain grad:
    the L2 graph genuinely routed the gradient through the Pallas kernel."""
    params = M.init_params(CFG, jax.random.PRNGKey(11))
    flat = M.flatten(CFG, params)
    toks, tgts = make_batch(jax.random.PRNGKey(12))
    n = CFG.n_params
    big_n = M.padded_dim(n)
    key = jax.random.PRNGKey(13)
    signs = jnp.where(jax.random.bernoulli(key, 0.5, (big_n,)), 1.0, -1.0)
    loss_e, x_nd, linf = M.loss_and_grad_embed(CFG, flat, toks, tgts, signs)
    loss_p, grad = M.loss_and_grad(CFG, flat, toks, tgts)
    assert abs(float(loss_e) - float(loss_p)) < 1e-6
    padded = jnp.zeros((1, big_n)).at[0, :n].set(grad)
    want = ref.ndsc_embed_ref(padded, signs)[0]
    np.testing.assert_allclose(x_nd, want, rtol=2e-3, atol=2e-4)
    assert abs(float(linf) - float(jnp.max(jnp.abs(want)))) < 1e-5
    # Parseval: embedding preserves the gradient's l2 norm
    np.testing.assert_allclose(
        jnp.linalg.norm(x_nd), jnp.linalg.norm(grad), rtol=1e-3
    )


@pytest.mark.parametrize("d_model,n_layers", [(32, 1), (64, 2)])
def test_param_count_formula(d_model, n_layers):
    cfg = M.ModelConfig(
        vocab=32, d_model=d_model, n_heads=2, n_layers=n_layers, seq=16, batch=2
    )
    want = 32 * d_model + 16 * d_model  # embeddings
    per_layer = (
        2 * d_model  # ln1
        + d_model * 3 * d_model
        + d_model * d_model
        + 2 * d_model  # ln2
        + d_model * 4 * d_model
        + 4 * d_model
        + 4 * d_model * d_model
        + d_model
    )
    want += n_layers * per_layer + 2 * d_model  # final ln
    assert cfg.n_params == want
