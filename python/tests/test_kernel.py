"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes/block sizes; this is the CORE correctness
signal for the kernels the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.hadamard import (
    fwht_pallas,
    ndsc_decode_pallas,
    ndsc_embed_pallas,
    vmem_footprint_bytes,
)

POW2 = [8, 16, 64, 128, 512, 1024]


def rand(key, shape, dtype=jnp.float32, heavy=False):
    x = jax.random.normal(key, shape, jnp.float32)
    if heavy:
        x = x ** 3
    return x.astype(dtype)


@pytest.mark.parametrize("n", POW2)
def test_fwht_matches_ref(n):
    x = rand(jax.random.PRNGKey(n), (4, n), heavy=True)
    got = fwht_pallas(x)
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    n_pow=st.integers(min_value=1, max_value=10),
    batch=st.integers(min_value=1, max_value=17),
    block_rows=st.sampled_from([1, 2, 4, 8, 16]),
    heavy=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fwht_hypothesis_sweep(n_pow, batch, block_rows, heavy, seed):
    n = 2 ** n_pow
    x = rand(jax.random.PRNGKey(seed), (batch, n), heavy=heavy)
    got = fwht_pallas(x, block_rows=block_rows)
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_dtypes(dtype):
    n = 128
    x = rand(jax.random.PRNGKey(0), (4, n), dtype=dtype)
    got = fwht_pallas(x).astype(jnp.float32)
    want = ref.fwht_ref(x).astype(jnp.float32)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_fwht_is_involution():
    n = 256
    x = rand(jax.random.PRNGKey(1), (3, n))
    y = fwht_pallas(fwht_pallas(x))
    np.testing.assert_allclose(y, x, rtol=1e-4, atol=1e-4)


def test_fwht_preserves_l2():
    n = 512
    x = rand(jax.random.PRNGKey(2), (2, n), heavy=True)
    y = fwht_pallas(x)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-3
    )


@settings(max_examples=15, deadline=None)
@given(
    n_pow=st.integers(min_value=2, max_value=10),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ndsc_embed_matches_ref(n_pow, seed):
    n = 2 ** n_pow
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    y = rand(k1, (5, n), heavy=True)
    signs = jnp.sign(jax.random.normal(k2, (n,))) + (
        jax.random.normal(k2, (n,)) == 0
    )  # +-1, no zeros
    got = ndsc_embed_pallas(y, signs)
    want = ref.ndsc_embed_ref(y, signs)
    np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)


def test_embed_decode_roundtrip():
    n = 1024
    key = jax.random.PRNGKey(3)
    y = rand(key, (2, n), heavy=True)
    signs = jnp.where(jax.random.bernoulli(key, 0.5, (n,)), 1.0, -1.0)
    x = ndsc_embed_pallas(y, signs)
    back = ndsc_decode_pallas(x, signs)
    np.testing.assert_allclose(back, y, rtol=1e-3, atol=1e-3)


def test_embedding_flattens_heavy_tails():
    """Lemma 3's point: l_inf of the embedding ~ sqrt(log N / N) * l2."""
    n = 1024
    key = jax.random.PRNGKey(4)
    y = jnp.zeros((1, n)).at[0, 13].set(100.0)  # one-hot, worst case
    signs = jnp.where(jax.random.bernoulli(key, 0.5, (n,)), 1.0, -1.0)
    x = ndsc_embed_pallas(y, signs)
    bound = 2.0 * np.sqrt(np.log(2 * n) / n) * float(jnp.linalg.norm(y))
    assert float(jnp.max(jnp.abs(x))) <= bound


def test_uniform_quantize_ref_error_bound():
    x = jnp.linspace(-0.999, 0.999, 101)
    for bits in [1, 2, 4, 8]:
        q = ref.uniform_quantize_ref(x, jnp.asarray(1.0), bits)
        assert float(jnp.max(jnp.abs(q - x))) <= 2.0 ** (-bits) + 1e-6


def test_vmem_footprint_within_budget():
    # DESIGN.md §8: default tiling must fit a 16 MiB VMEM.
    assert vmem_footprint_bytes(8, 2**17) < 16 * 2**20
    assert vmem_footprint_bytes(8, 2**20) > 16 * 2**20  # and the bound binds
