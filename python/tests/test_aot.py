"""AOT path: lowering produces loadable HLO text with the expected
interfaces (the contract the Rust runtime depends on)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.kernels.hadamard import ndsc_embed_pallas

SMALL = M.ModelConfig(vocab=16, d_model=16, n_heads=2, n_layers=1, seq=8, batch=2)


def test_to_hlo_text_emits_hlo_module():
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_pallas_kernel_lowers_to_plain_hlo():
    """interpret=True must lower to plain HLO ops (no custom-call), or the
    Rust CPU client cannot execute the artifact."""

    def fn(y, s):
        return (ndsc_embed_pallas(y, s),)

    y = jax.ShapeDtypeStruct((1, 64), jnp.float32)
    s = jax.ShapeDtypeStruct((64,), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(y, s))
    assert "HloModule" in text
    assert "custom-call" not in text.lower(), "Mosaic custom-call leaked into HLO"


def test_model_grad_lowering_interface():
    """The (flat, tokens, targets) -> (loss, grad) signature is the wire
    contract with rust/src/exp/transformer.rs."""
    cfg = SMALL
    n = cfg.n_params
    flat = jax.ShapeDtypeStruct((n,), jnp.float32)
    toks = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.uint32)

    def grad_fn(flat, tokens, targets):
        loss, g = M.loss_and_grad(cfg, flat, tokens, targets)
        return (loss, g)

    text = aot.to_hlo_text(jax.jit(grad_fn).lower(flat, toks, toks))
    assert "HloModule" in text
    # output tuple carries a scalar and an n-vector
    assert f"f32[{n}]" in text


def test_artifacts_dir_contents(tmp_path):
    """Full aot main() on a tiny config end-to-end."""
    os.environ.update(
        KF_VOCAB="16", KF_DMODEL="16", KF_HEADS="2", KF_LAYERS="1", KF_SEQ="8", KF_BATCH="2"
    )
    try:
        cfg = aot.config_from_env()
        out = str(tmp_path)
        aot.lower_model(cfg, out)
        aot.lower_kernels(out, [64])
        names = sorted(os.listdir(out))
        for want in [
            "model_grad.hlo.txt",
            "model_loss.hlo.txt",
            "model_grad_embed.hlo.txt",
            "model_init.bin",
            "model_meta.txt",
            "ndsc_embed_64.hlo.txt",
            "ndsc_decode_64.hlo.txt",
        ]:
            assert want in names, f"missing {want} in {names}"
        meta = dict(
            line.split("=", 1)
            for line in open(os.path.join(out, "model_meta.txt"))
            if "=" in line
        )
        n = int(meta["n_params"])
        init = np.fromfile(os.path.join(out, "model_init.bin"), dtype="<f4")
        assert init.shape == (n,)
        assert np.isfinite(init).all()
    finally:
        for k in ["KF_VOCAB", "KF_DMODEL", "KF_HEADS", "KF_LAYERS", "KF_SEQ", "KF_BATCH"]:
            os.environ.pop(k, None)
