//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links libxla/PJRT and executes AOT-compiled HLO. This
//! image cannot fetch or link it, so this path crate provides the exact
//! API surface `kashinflow::runtime` compiles against; every entry point
//! that would touch PJRT returns an [`Error`] at runtime. The runtime
//! integration tests already skip when `artifacts/` has not been built,
//! so a stubbed runtime keeps `cargo test` green while leaving the Rust
//! call sites byte-for-byte compatible with the real bindings.

use std::fmt;

/// Error type mirroring `xla::Error`: displayable, `std::error::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT runtime unavailable (offline stub build — \
         swap rust/vendor/xla for the real bindings to enable artifacts)"
    ))
}

/// Parsed HLO module (stub: carries nothing).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing {path}")))
    }
}

/// An XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("creating PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling computation"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("fetching buffer"))
    }
}

/// Element types the stub accepts (mirrors the real crate's sealed trait).
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for u32 {}
impl NativeType for i64 {}

/// Host literal (stub: carries nothing).
pub struct Literal;

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable("decomposing tuple"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("reading literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_errors_not_panics() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(PjRtClient::cpu().is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_ok());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
