//! Minimal offline shim of the `anyhow` crate.
//!
//! The build image has no crates.io access, so this in-tree path crate
//! provides exactly the surface `kashinflow` uses: [`Error`], [`Result`],
//! the [`Context`] extension trait on `Result`/`Option`, and the
//! [`ensure!`]/[`anyhow!`]/[`bail!`] macros. Error messages are flat
//! strings with the context chain prepended (`context: cause`), matching
//! how the callers format errors with `{e:#}`.

use std::fmt;

/// A type-erased error: a display message plus the chained causes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{}` and the alternate `{:#}` both print the full chain.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: like the real `anyhow::Error`, this deliberately does NOT
// implement `std::error::Error` — that is what makes the blanket
// `From<E: std::error::Error>` impl below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`, defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with an error if the condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($($arg)+)));
        }
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::Error::msg(format!($($arg)+)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let r = std::fs::read_to_string("/definitely/not/a/file");
        r.with_context(|| "reading config".to_string())
    }

    #[test]
    fn context_chains_messages() {
        let e = io_fail().unwrap_err();
        let s = format!("{e:#}");
        assert!(s.starts_with("reading config: "), "{s}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        let some: Option<u32> = Some(3);
        assert_eq!(some.context("x").unwrap(), 3);
    }

    #[test]
    fn ensure_and_question_mark() {
        fn check(n: usize) -> Result<usize> {
            ensure!(n > 2, "need n > 2, got {n}");
            Ok(n)
        }
        assert!(check(1).is_err());
        assert_eq!(check(5).unwrap(), 5);
    }
}
