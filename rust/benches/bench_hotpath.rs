//! The compression hot path, kernel by kernel — writes `BENCH_hotpath.json`.
//! Run with `cargo bench --bench bench_hotpath` (`BENCH_SMOKE=1` for the CI
//! smoke settings).
//!
//! Two families of rows, every one carrying the bytes/s column:
//!
//! * `fwht/<kernel>/<n>` — the transform alone at n up to 2^20, for the
//!   scalar reference, the blocked/SIMD kernel, the scoped-thread kernel
//!   and the `fwht_inplace_auto` dispatcher the codecs actually call.
//! * `ndsc/<op>/<path>/<n>` — the full codec round: `reference` is the
//!   unfused scalar pipeline (`compress_reference_into`, three sweeps:
//!   embed → normalize → quantize), `fused` is the production fast path
//!   (`compress_into`, one sweep with the 1/√N scale deferred into the
//!   quantizer). Both run in the SAME process invocation, so the
//!   fused-vs-reference ratio in one `BENCH_hotpath.json` is the
//!   apples-to-apples speedup of this PR's fusion — the acceptance
//!   criterion is fused ≥ 2× reference on `ndsc/compress/*/65536`.
//!
//! Byte accounting: transforms touch `n * 4` bytes in place; codec rows
//! charge the uncompressed input (`n * 4`), i.e. the rate at which raw
//! gradient bytes are consumed (compress) or reproduced (decompress).

use kashinflow::linalg::fwht::{
    fwht_inplace, fwht_inplace_auto, fwht_inplace_mt, fwht_reference_inplace,
};
use kashinflow::linalg::rng::Rng;
use kashinflow::quant::ndsc::Ndsc;
use kashinflow::quant::{Compressed, Compressor, Workspace};
use kashinflow::testkit::bench::{black_box, Bencher};

fn bench_fwht(b: &mut Bencher) {
    let mut rng = Rng::seed_from(1);
    for &n in &[1usize << 12, 1 << 16, 1 << 18, 1 << 20] {
        let base: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut buf = base.clone();
        let mut case = |name: &str, f: &mut dyn FnMut(&mut [f32])| {
            b.run_bytes(&format!("fwht/{name}/{n}"), n * 4, || {
                buf.copy_from_slice(&base);
                f(&mut buf);
                black_box(buf[0]);
            });
        };
        case("reference", &mut fwht_reference_inplace);
        case("blocked", &mut fwht_inplace);
        case("mt8", &mut |x| fwht_inplace_mt(x, 8));
        case("auto", &mut fwht_inplace_auto);
    }
}

fn bench_ndsc(b: &mut Bencher, dithered: bool) {
    let tag = if dithered { "ndsc-dith" } else { "ndsc" };
    for &n in &[1usize << 16, 1 << 20] {
        let mut rng = Rng::seed_from(2);
        let codec = if dithered {
            Ndsc::hadamard_dithered(n, 2.0, &mut rng)
        } else {
            Ndsc::hadamard(n, 2.0, &mut rng)
        };
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
        let mut ws = Workspace::for_compressor(&codec);
        let mut msg = Compressed::empty(n);
        let mut dec = vec![0.0f32; n];
        // Same-run baseline first, fused second: the ratio between the two
        // rows is the PR's measured speedup.
        let mut enc_rng = Rng::seed_from(3);
        b.run_bytes(&format!("{tag}/compress/reference/{n}"), n * 4, || {
            codec.compress_reference_into(&y, &mut enc_rng, &mut ws, &mut msg);
            black_box(msg.payload_bits);
        });
        b.run_bytes(&format!("{tag}/compress/fused/{n}"), n * 4, || {
            codec.compress_into(&y, &mut enc_rng, &mut ws, &mut msg);
            black_box(msg.payload_bits);
        });
        b.run_bytes(&format!("{tag}/decompress/reference/{n}"), n * 4, || {
            codec.decompress_reference_into(&msg, &mut ws, &mut dec);
            black_box(dec[0]);
        });
        b.run_bytes(&format!("{tag}/decompress/fused/{n}"), n * 4, || {
            codec.decompress_into(&msg, &mut ws, &mut dec);
            black_box(dec[0]);
        });
    }
}

fn main() {
    let mut b = Bencher::from_env();
    bench_fwht(&mut b);
    bench_ndsc(&mut b, false);
    bench_ndsc(&mut b, true);
    b.save_json("BENCH_hotpath.json");
}
