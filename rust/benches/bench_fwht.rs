//! FWHT micro-benchmarks — the L3 hot-path kernel (and the §Perf target).
//! Run with `cargo bench --bench bench_fwht`.
//!
//! Covers the three kernels side by side: the textbook scalar reference
//! (`fwht_reference_inplace`), the blocked/SIMD single-threaded kernel
//! (`fwht_inplace`), and the scoped-thread kernel (`fwht_inplace_mt`).
//! All three are bit-identical (see `linalg::fwht` tests); the only
//! difference measured here is speed. The bytes/s column counts the
//! in-place buffer once (`n * 4`).

use kashinflow::linalg::fwht::{fwht_inplace, fwht_inplace_mt, fwht_reference_inplace};
use kashinflow::linalg::rng::Rng;
use kashinflow::testkit::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::from_env();
    let mut rng = Rng::seed_from(1);
    for &n in &[1024usize, 4096, 16384, 65536, 262144, 1048576] {
        let base: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut buf = base.clone();
        b.run_bytes(&format!("fwht/reference/{n}"), n * 4, || {
            buf.copy_from_slice(&base);
            fwht_reference_inplace(&mut buf);
            black_box(buf[0]);
        });
        b.run_bytes(&format!("fwht/blocked/{n}"), n * 4, || {
            buf.copy_from_slice(&base);
            fwht_inplace(&mut buf);
            black_box(buf[0]);
        });
        // MT only pays off above MT_FWHT_MIN_DIM; benching it across the
        // whole range shows where the crossover sits.
        b.run_bytes(&format!("fwht/mt8/{n}"), n * 4, || {
            buf.copy_from_slice(&base);
            fwht_inplace_mt(&mut buf, 8);
            black_box(buf[0]);
        });
    }
    b.save_json("BENCH_fwht.json");
}
