//! FWHT micro-benchmarks — the L3 hot-path kernel (and the §Perf target).
//! Run with `cargo bench --bench bench_fwht`.

use kashinflow::linalg::fwht::fwht_inplace;
use kashinflow::linalg::rng::Rng;
use kashinflow::testkit::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::seed_from(1);
    for &n in &[1024usize, 4096, 16384, 65536, 262144, 1048576] {
        let base: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut buf = base.clone();
        b.run_throughput(&format!("fwht/{n}"), n, || {
            buf.copy_from_slice(&base);
            fwht_inplace(&mut buf);
            black_box(buf[0]);
        });
    }
}
