//! Mesh-engine benchmarks: per-round gossip cost (oracle + per-edge
//! innovation encode/decode + Metropolis mix) for a compressed ring, a
//! 3×3 torus and the uncompressed fp32 ring twin, a threads=4 variant
//! of the compressed ring (the scoped-thread phases are pure overhead
//! at this size — the row documents the crossover, not a win), and one
//! end-to-end accounting run whose exact per-link byte tallies land in
//! the JSON. Saves `BENCH_mesh.json` so gossip-throughput and wire-
//! accounting regressions diff mechanically across PRs.

use std::time::Instant;

use kashinflow::coordinator::transport::Topology;
use kashinflow::data::synthetic::planted_regression_shards;
use kashinflow::linalg::rng::Rng;
use kashinflow::mesh::{run_sharded, MeshConfig, MeshDriver};
use kashinflow::opt::engine::oracle::ExactGrad;
use kashinflow::opt::engine::schedule::Schedule;
use kashinflow::opt::multi::ShardedProblem;
use kashinflow::opt::objectives::Loss;
use kashinflow::quant::registry::CompressorSpec;
use kashinflow::testkit::bench::{black_box, Bencher};

const SEED: u64 = 7;

/// Small planted shards (8 rows each) so the codec path, not the
/// oracle, dominates the per-round cost under measurement.
fn problem(m: usize, n: usize) -> ShardedProblem {
    let mut rng = Rng::seed_from(SEED ^ 0xBE9C);
    let (shards, _) = planted_regression_shards(m, 8, n, Loss::Square, &mut rng, false);
    ShardedProblem::new(shards)
}

/// A config on `prob`'s own stable step, so the timed rounds stay on a
/// convergent (bounded-iterate) trajectory however long the window is.
fn mesh_cfg(prob: &ShardedProblem, topology: Topology, scheme: &str, r: f32) -> MeshConfig {
    let spec = CompressorSpec::parse(scheme).expect("registry scheme");
    let mut cfg = MeshConfig::new(prob.m(), prob.n, topology, spec, r, SEED);
    cfg.schedule = Schedule::Constant(prob.stable_step());
    cfg.rounds = 4096;
    cfg
}

struct MeshRow {
    case: String,
    topology: String,
    scheme: String,
    nodes: usize,
    n: usize,
    rounds_per_sec: f64,
    median_ns: u128,
    /// Pre-rendered extra JSON fields (`, "k": v` fragments) for rows
    /// with a wider schema (the accounting run); empty otherwise.
    extra: String,
}

// `BENCH_mesh.json` has two producers by design — this bench (CI's
// smoke artifact, written in `rust/`) and the `repro mesh` sweep
// (written in the invocation cwd). Rows carry a `source` discriminator
// so a mixed diff is always attributable to its writer.
fn rows_to_json(rows: &[MeshRow]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"source\": \"bench\", \"case\": \"{}\", \"topology\": \"{}\", \
             \"scheme\": \"{}\", \"nodes\": {}, \"n\": {}, \"rounds_per_sec\": {}, \
             \"median_ns\": {}{}}}{}\n",
            r.case,
            r.topology,
            r.scheme,
            r.nodes,
            r.n,
            r.rounds_per_sec,
            r.median_ns,
            r.extra,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("]\n");
    s
}

fn main() {
    let mut b = Bencher::from_env();
    let mut rows = Vec::new();

    let n = 256usize;
    let cases: [(&str, usize, Topology, &str, f32, usize); 4] = [
        ("mesh/ring8-ndsc-dith-r1", 8, Topology::Ring, "ndsc-dith", 1.0, 1),
        ("mesh/torus3x3-sd-r1", 9, Topology::Torus { rows: 3, cols: 3 }, "sd", 1.0, 1),
        ("mesh/ring8-fp32", 8, Topology::Ring, "fp32", 32.0, 1),
        ("mesh/ring8-ndsc-dith-r1-threads4", 8, Topology::Ring, "ndsc-dith", 1.0, 4),
    ];
    for (case, m, topology, scheme, r, threads) in cases {
        let prob = problem(m, n);
        let mut cfg = mesh_cfg(&prob, topology, scheme, r);
        cfg.threads = threads;
        let topo_name = cfg.topology.to_string();
        let oracles: Vec<ExactGrad<'_>> =
            prob.shards.iter().map(|s| ExactGrad { obj: s }).collect();
        let x0 = vec![0.0f32; n];
        let mut drv = MeshDriver::new(cfg, oracles, &x0).expect("bench config is valid");
        // The trace value closure is free on purpose: the number under
        // test is the gossip round itself, not objective evaluation.
        let stats = b.run(case, || {
            drv.step(&|_| 0.0);
            black_box(drv.round());
        });
        rows.push(MeshRow {
            case: case.to_string(),
            topology: topo_name,
            scheme: scheme.to_string(),
            nodes: m,
            n,
            rounds_per_sec: 1e9 / (stats.median.as_nanos().max(1) as f64),
            median_ns: stats.median.as_nanos(),
            extra: String::new(),
        });
    }

    // End-to-end accounting run: a lossy ring under 10% link drops,
    // with the exact per-link byte/delivered/dropped tallies in the
    // row — the mechanical diff surface for the wire-accounting
    // contract (`protocol::upload_wire_bytes`, both directions of
    // every link charged separately).
    {
        let (m, acc_n) = (6usize, 64usize);
        let rounds = if std::env::var_os("BENCH_SMOKE").is_some() { 40 } else { 200 };
        let prob = problem(m, acc_n);
        let mut cfg = mesh_cfg(&prob, Topology::Ring, "ndsc-dith", 1.0);
        cfg.rounds = rounds;
        cfg.link.drop_prob = 0.1;
        let t0 = Instant::now();
        let metrics = run_sharded(cfg, &prob).expect("accounting config is valid");
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let mut links = String::from("[");
        for (k, l) in metrics.per_link.iter().enumerate() {
            links.push_str(&format!(
                "{{\"a\": {}, \"b\": {}, \"bytes\": {}, \"delivered\": {}, \"dropped\": {}}}{}",
                l.a,
                l.b,
                l.bytes,
                l.delivered,
                l.dropped,
                if k + 1 == metrics.per_link.len() { "" } else { ", " }
            ));
        }
        links.push(']');
        let case = format!("mesh/accounting-ring{m}-ndsc-dith-r1-drop0.1");
        let rps = rounds as f64 / secs;
        println!(
            "{case:<48} {rps:>12.0} rounds/s ({} wire bytes over {} links)",
            metrics.total_wire_bytes(),
            metrics.per_link.len()
        );
        rows.push(MeshRow {
            case,
            topology: "ring".into(),
            scheme: "ndsc-dith".into(),
            nodes: m,
            n: acc_n,
            rounds_per_sec: rps,
            median_ns: 0,
            extra: format!(
                ", \"rounds\": {rounds}, \"drop\": 0.1, \"wire_bytes\": {}, \
                 \"final_consensus\": {}, \"per_link\": {links}",
                metrics.total_wire_bytes(),
                metrics.final_consensus
            ),
        });
    }

    match std::fs::write("BENCH_mesh.json", rows_to_json(&rows)) {
        Ok(()) => println!("wrote BENCH_mesh.json ({} cases)", rows.len()),
        Err(e) => eprintln!("could not write BENCH_mesh.json: {e}"),
    }
}
