//! Optimizer-loop benches: per-iteration cost of DGD-DEF and DQ-PSGD at
//! the paper's problem sizes (Fig. 1b / Fig. 2 regimes) — L3 must not be
//! the bottleneck relative to the oracle call. Every case executes on
//! the unified `opt::engine` round driver (the legacy entry points are
//! spec-builders over it), so a regression in the engine hot path
//! surfaces here; results land in `BENCH_optimizers.json` (the CI
//! bench-smoke job uploads it alongside `BENCH_hotpath.json`).

use kashinflow::coordinator::transport::Participation;
use kashinflow::data::synthetic::{
    planted_regression, planted_regression_shards, two_gaussian_svm, Tail,
};
use kashinflow::linalg::rng::Rng;
use kashinflow::opt::dgd_def::{self, DgdDefOptions};
use kashinflow::opt::dq_psgd::{self, DqPsgdOptions};
use kashinflow::opt::multi::{self, MultiOptions, ShardedProblem};
use kashinflow::opt::objectives::Loss;
use kashinflow::opt::oracle::MinibatchOracle;
use kashinflow::opt::projection::Domain;
use kashinflow::quant::ndsc::Ndsc;
use kashinflow::quant::Compressor;
use kashinflow::testkit::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::from_env();
    let mut rng = Rng::seed_from(4);

    // DGD-DEF per-iteration (10 iters per measurement), n = 116.
    let (obj, _) = planted_regression(200, 116, Tail::GaussianCubed, Tail::Gaussian, 0.1, &mut rng);
    let (l, mu) = obj.smoothness_strong_convexity();
    let c = Ndsc::hadamard(116, 4.0, &mut rng);
    b.run("dgd_def/n116/10iter", || {
        let tr = dgd_def::run(
            &obj,
            &c,
            &vec![0.0; 116],
            None,
            DgdDefOptions { step: 2.0 / (l + mu), iters: 10 },
            &mut rng,
        );
        black_box(tr.final_x[0]);
    });

    // DQ-PSGD per-iteration, n = 784 (MNIST regime), R = 0.1.
    let svm = two_gaussian_svm(300, 784, 0.5, &mut rng);
    let cd = Ndsc::hadamard_dithered(784, 0.1, &mut rng);
    b.run("dq_psgd/n784_r0.1/10iter", || {
        let mut oracle = MinibatchOracle::new(&svm, 30, Rng::seed_from(5));
        let tr = dq_psgd::run(
            &svm,
            &mut oracle,
            &cd,
            &vec![0.0; 784],
            None,
            DqPsgdOptions {
                step: 0.05,
                iters: 10,
                domain: Domain::L2Ball { radius: 10.0 },
                drop_prob: 0.0,
            },
            &mut rng,
        );
        black_box(tr.final_x[0]);
    });

    // The engine's multi-worker consensus round: m = 8 ShardOracles +
    // per-worker codecs + k-of-m participation, inline driver — the
    // unified hot path the coordinator mirrors (10 rounds/measurement).
    let mut data_rng = Rng::seed_from(6);
    let (shards, _) =
        planted_regression_shards(8, 10, 256, Loss::Square, &mut data_rng, false);
    let problem = ShardedProblem::new(shards);
    let comps: Vec<Box<dyn Compressor>> = (0..8)
        .map(|_| Box::new(Ndsc::hadamard_dithered(256, 1.0, &mut data_rng)) as Box<dyn Compressor>)
        .collect();
    let step = problem.stable_step();
    b.run("engine_multi/n256_m8_k6/10round", || {
        let tr = multi::run(
            &problem,
            &comps,
            &vec![0.0; 256],
            None,
            MultiOptions {
                step,
                iters: 10,
                domain: Domain::Unconstrained,
                batch: Some(5),
                participation: Participation::KofM { k: 6 },
            },
            &mut rng,
        );
        black_box(tr.final_x[0]);
    });

    // Raw compress/decompress at transformer scale (n = 2^17).
    let n = 1 << 17;
    let big = Ndsc::hadamard(n, 4.0, &mut rng);
    let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
    b.run_throughput("ndsc_compress/n131072", n, || {
        black_box(kashinflow::quant::Compressor::compress(&big, &y, &mut rng));
    });

    b.save_json("BENCH_optimizers.json");
}
