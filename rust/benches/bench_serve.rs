//! Serving-layer benchmarks: fleet-round throughput with 8 concurrent
//! heterogeneous jobs under both scheduler policies, plus the
//! checkpoint save/restore round-trip. Saves `BENCH_serve.json` with the
//! per-case stats **and** the measured aggregate job-rounds/sec (the
//! serving layer's headline throughput number), so regressions diff
//! mechanically across PRs.

use std::time::Instant;

use kashinflow::exp::serve::job_mix;
use kashinflow::serve::{checkpoint, Job, JobServer, Policy};
use kashinflow::testkit::bench::{black_box, Bencher};

const JOBS: usize = 8;
const N: usize = 256;
/// Long horizon so jobs never finish inside a measurement window (the
/// trace reserve is `rounds + 1` records, so keep this moderate).
const JOB_ROUNDS: usize = 200_000;

fn fresh_server(policy: Policy) -> JobServer {
    // Ample budget: throughput of the serve path itself, not of idling.
    let mut srv = JobServer::new(1 << 30, policy);
    for spec in job_mix(JOBS, N, JOB_ROUNDS, 7) {
        srv.submit(spec).expect("ample budget admits the whole mix");
    }
    srv
}

struct ThroughputRow {
    case: String,
    policy: Policy,
    jobs: usize,
    rounds_per_sec: f64,
    median_ns: u128,
}

// `BENCH_serve.json` has two producers by design — this bench (CI's
// smoke artifact, written in `rust/`) and the `repro serve` sweep
// (written in the invocation cwd). Rows carry a `source` discriminator
// so a mixed diff is always attributable to its writer.
fn rows_to_json(rows: &[ThroughputRow]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"source\": \"bench\", \"case\": \"{}\", \"policy\": \"{}\", \"jobs\": {}, \
             \"rounds_per_sec\": {}, \"median_ns\": {}}}{}\n",
            r.case,
            r.policy,
            r.jobs,
            r.rounds_per_sec,
            r.median_ns,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("]\n");
    s
}

fn main() {
    let mut b = Bencher::from_env();
    let mut rows = Vec::new();

    for policy in [Policy::Drr, Policy::DrrAdaptive] {
        let mut srv = fresh_server(policy);
        let case = format!("serve/{policy}-{JOBS}jobs-n{N}");
        let stats = b.run(&case, || {
            if srv.live_jobs() == 0 {
                srv = fresh_server(policy);
            }
            black_box(srv.run_round());
        });
        // Aggregate throughput over a dedicated timed window (the
        // Bencher measures per-fleet-round latency; the serving headline
        // is engine rounds served per second across all tenants).
        let mut srv = fresh_server(policy);
        let window = if std::env::var_os("BENCH_SMOKE").is_some() { 0.2 } else { 1.0 };
        let t0 = Instant::now();
        let mut served = 0u64;
        while t0.elapsed().as_secs_f64() < window {
            if srv.live_jobs() == 0 {
                srv = fresh_server(policy);
            }
            served += srv.run_round() as u64;
        }
        let rps = served as f64 / t0.elapsed().as_secs_f64();
        println!("{case:<48} aggregate {rps:>12.0} job-rounds/s ({JOBS} concurrent jobs)");
        rows.push(ThroughputRow {
            case,
            policy,
            jobs: JOBS,
            rounds_per_sec: rps,
            median_ns: stats.median.as_nanos(),
        });
    }

    // Checkpoint round-trip: save + restore of a warm DEF-feedback job.
    let mut job = Job::build(
        job_mix(5, 1024, 1000, 7)
            .into_iter()
            .nth(4)
            .expect("mix slot 4 is the DEF tenant"),
    )
    .expect("mix specs build");
    for _ in 0..50 {
        job.step_round(0);
    }
    let stats = b.run("serve/checkpoint-roundtrip-n1024", || {
        let bytes = checkpoint::save(&job).expect("resumable jobs snapshot cleanly");
        let restored = checkpoint::restore(&bytes).expect("clean snapshot restores");
        black_box(restored.rounds_done());
    });
    rows.push(ThroughputRow {
        case: "serve/checkpoint-roundtrip-n1024".into(),
        policy: Policy::Drr,
        jobs: 1,
        rounds_per_sec: 0.0,
        median_ns: stats.median.as_nanos(),
    });

    match std::fs::write("BENCH_serve.json", rows_to_json(&rows)) {
        Ok(()) => println!("wrote BENCH_serve.json ({} cases)", rows.len()),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}
