//! Serving-layer benchmarks: fleet-round throughput with 8 concurrent
//! heterogeneous jobs under both scheduler policies, the checkpoint
//! save/restore round-trip, a multi-fleet cluster drill (1024
//! tenants sharded over 4 fleets, with mid-run migrations and the
//! served/queued/rejected/migrated breakdown), and the skewed-mix
//! straggler case: the same 1-big + 1023-small tenant population timed
//! under the lockstep per-round barrier executor and the work-stealing
//! epoch executor, with the same-run speedup ratio in the JSON. Two
//! plan-cache rows complete the set: an admission storm (1024 same-spec
//! submits of a heavy orthonormal-frame plan, cached vs uncached) and a
//! migration churn (512 checkpoint→restore moves across a 4-fleet
//! cluster, cache on vs off), each carrying its same-run
//! `ratio_vs_uncached`. Saves `BENCH_serve.json` with the per-case
//! stats **and** the measured aggregate job-rounds/sec (the serving
//! layer's headline throughput number), so regressions diff
//! mechanically across PRs.

use std::sync::Arc;
use std::time::Instant;

use kashinflow::exp::serve::job_mix;
use kashinflow::quant::budget_bits;
use kashinflow::quant::registry::CompressorSpec;
use kashinflow::serve::{
    checkpoint, FleetCluster, Job, JobServer, JobSpec, PlanCache, Policy, QosClass,
};
use kashinflow::testkit::bench::{black_box, Bencher};

const JOBS: usize = 8;
const N: usize = 256;
/// Long horizon so jobs never finish inside a measurement window (the
/// trace reserve is `rounds + 1` records, so keep this moderate).
const JOB_ROUNDS: usize = 200_000;

/// Multi-fleet drill shape: ≥1000 tenants over ≥4 fleets is the
/// contract `BENCH_serve.json` keeps for the jobs axis.
const FLEETS: usize = 4;
const TENANTS: usize = 1024;

fn fresh_server(policy: Policy) -> JobServer {
    // Ample budget: throughput of the serve path itself, not of idling.
    let mut srv = JobServer::new(1 << 30, policy);
    for spec in job_mix(JOBS, N, JOB_ROUNDS, 7) {
        srv.submit(spec).expect("ample budget admits the whole mix");
    }
    srv
}

struct ThroughputRow {
    case: String,
    policy: Policy,
    jobs: usize,
    rounds_per_sec: f64,
    median_ns: u128,
    /// Pre-rendered extra JSON fields (`, "k": v` fragments) for cases
    /// with a wider schema (the cluster breakdown); empty otherwise.
    extra: String,
}

// `BENCH_serve.json` has two producers by design — this bench (CI's
// smoke artifact, written in `rust/`) and the `repro serve` sweep
// (written in the invocation cwd). Rows carry a `source` discriminator
// so a mixed diff is always attributable to its writer.
fn rows_to_json(rows: &[ThroughputRow]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"source\": \"bench\", \"case\": \"{}\", \"policy\": \"{}\", \"jobs\": {}, \
             \"rounds_per_sec\": {}, \"median_ns\": {}{}}}{}\n",
            r.case,
            r.policy,
            r.jobs,
            r.rounds_per_sec,
            r.median_ns,
            r.extra,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("]\n");
    s
}

fn main() {
    let mut b = Bencher::from_env();
    let mut rows = Vec::new();

    for policy in [Policy::Drr, Policy::DrrAdaptive] {
        let mut srv = fresh_server(policy);
        let case = format!("serve/{policy}-{JOBS}jobs-n{N}");
        let stats = b.run(&case, || {
            if srv.live_jobs() == 0 {
                srv = fresh_server(policy);
            }
            black_box(srv.run_round());
        });
        // Aggregate throughput over a dedicated timed window (the
        // Bencher measures per-fleet-round latency; the serving headline
        // is engine rounds served per second across all tenants).
        let mut srv = fresh_server(policy);
        let window = if std::env::var_os("BENCH_SMOKE").is_some() { 0.2 } else { 1.0 };
        let t0 = Instant::now();
        let mut served = 0u64;
        while t0.elapsed().as_secs_f64() < window {
            if srv.live_jobs() == 0 {
                srv = fresh_server(policy);
            }
            served += srv.run_round() as u64;
        }
        let rps = served as f64 / t0.elapsed().as_secs_f64();
        println!("{case:<48} aggregate {rps:>12.0} job-rounds/s ({JOBS} concurrent jobs)");
        rows.push(ThroughputRow {
            case,
            policy,
            jobs: JOBS,
            rounds_per_sec: rps,
            median_ns: stats.median.as_nanos(),
            extra: String::new(),
        });
    }

    // Checkpoint round-trip: save + restore of a warm DEF-feedback job.
    let mut job = Job::build(
        job_mix(5, 1024, 1000, 7)
            .into_iter()
            .nth(4)
            .expect("mix slot 4 is the DEF tenant"),
    )
    .expect("mix specs build");
    for _ in 0..50 {
        job.step_round(0);
    }
    let stats = b.run("serve/checkpoint-roundtrip-n1024", || {
        let bytes = checkpoint::save(&job).expect("resumable jobs snapshot cleanly");
        let restored = checkpoint::restore(&bytes).expect("clean snapshot restores");
        black_box(restored.rounds_done());
    });
    rows.push(ThroughputRow {
        case: "serve/checkpoint-roundtrip-n1024".into(),
        policy: Policy::Drr,
        jobs: 1,
        rounds_per_sec: 0.0,
        median_ns: stats.median.as_nanos(),
        extra: String::new(),
    });

    // Multi-fleet cluster drill: shard TENANTS short-horizon jobs over
    // FLEETS threaded fleets under a scarce (half-demand) budget, reject
    // a handful of oversized tenants, live-migrate a slice mid-run, and
    // report the full served/queued/rejected/migrated breakdown. One
    // timed end-to-end pass (not a Bencher window): the number that
    // matters is cluster-wide job-rounds/sec at four-digit tenancy.
    {
        let specs = job_mix(TENANTS, 16, 2, 7);
        let demand: usize = specs.iter().map(|s| s.workers * budget_bits(s.n, s.r)).sum();
        let budget = (demand / 2 / FLEETS).max(1);
        let mut cluster = FleetCluster::new(FLEETS, budget, Policy::Drr);
        let t0 = Instant::now();
        let mut gids = Vec::with_capacity(TENANTS);
        for spec in specs {
            if let Ok(gid) = cluster.submit(spec) {
                gids.push(gid);
            }
        }
        for i in 0..4u64 {
            let wide = JobSpec::new(
                format!("wide{i}-qsgd"),
                CompressorSpec::parse("qsgd").expect("canonical"),
                4.0,
                16,
                2,
                7 ^ (0xB16 + i),
            )
            .with_workers(1024);
            let _ = cluster.submit(wide); // counted in the rejected breakdown
        }
        cluster.run_round();
        let queued_mid = cluster.metrics().queued_jobs;
        for &gid in gids.iter().step_by(127) {
            let from = cluster.fleet_of(gid).unwrap_or(0);
            let _ = cluster.migrate(gid, (from + 1) % FLEETS);
        }
        cluster.run(2 * TENANTS * 8);
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let m = cluster.metrics();
        let case = format!("serve/cluster-{FLEETS}fleets-{TENANTS}tenants-n16");
        let rps = m.served_job_rounds as f64 / secs;
        println!(
            "{case:<48} aggregate {rps:>12.0} job-rounds/s \
             (served {} queued@mid {queued_mid} rejected {} migrated {})",
            m.served_jobs, m.rejected_jobs, m.migrated_jobs
        );
        rows.push(ThroughputRow {
            case,
            policy: Policy::Drr,
            jobs: TENANTS,
            rounds_per_sec: rps,
            median_ns: 0,
            extra: format!(
                ", \"fleets\": {FLEETS}, \"served\": {}, \"queued_mid\": {queued_mid}, \
                 \"rejected\": {}, \"migrated\": {}",
                m.served_jobs, m.rejected_jobs, m.migrated_jobs
            ),
        });
    }

    // Skewed-mix straggler case (the work-stealing acceptance number):
    // one n = 2^20 heavyweight tenant — a single engine round costs the
    // whole per-fleet bit budget and milliseconds of FWHT — plus 1023
    // n = 16 lightweights, a quarter of them active and the rest parked
    // as paused backlog, over 4 fleets. The lockstep executor pays a
    // scoped spawn-and-join barrier on EVERY cluster round and stalls
    // every fleet whenever the straggler transmits; the epoch executor
    // arbitrates EPOCH_LEN rounds per barrier and lets the persistent
    // pool absorb the straggler by stealing the other grants. Grants are
    // bit-identical between the two executors (test_serve.rs proves it),
    // so the same-run ratio isolates pure executor overhead. Rows report
    // *cluster* rounds/sec — the per-round barrier is the quantity under
    // test. Protocol details: EXPERIMENTS.md § Serving.
    {
        const EPOCH_LEN: usize = 64;
        let big_n = 1usize << 20;
        // Bronze weight against gold/silver lightweights: the straggler
        // banks deficit for hundreds of rounds between transmissions, so
        // its (identical-in-both-executors) compute cost stays a small
        // additive term and the barrier overhead dominates the contrast.
        let budget = budget_bits(big_n, 1.0) + 64;
        let build = || {
            let mut cluster = FleetCluster::new(FLEETS, budget, Policy::Drr);
            let big = JobSpec::new(
                "straggler-ndsc-dith",
                CompressorSpec::parse("ndsc-dith").expect("canonical"),
                1.0,
                big_n,
                JOB_ROUNDS,
                7,
            )
            .with_qos(QosClass::Bronze);
            cluster.submit(big).expect("the straggler fits its own cost budget");
            let gids: Vec<_> = job_mix(TENANTS - 1, 16, JOB_ROUNDS, 7)
                .into_iter()
                .map(|s| cluster.submit(s).expect("lightweights fit under the big budget"))
                .collect();
            // Park 3 of every 4 lightweights: live queue pressure plus a
            // paused backlog, without the active slice's step work
            // drowning out the per-round executor overhead.
            for (i, &gid) in gids.iter().enumerate() {
                if i % 4 != 0 {
                    cluster.pause(gid).expect("freshly admitted jobs pause");
                }
            }
            cluster
        };
        let window = if std::env::var_os("BENCH_SMOKE").is_some() { 0.2 } else { 1.0 };

        let mut lockstep = build();
        let t0 = Instant::now();
        let mut lock_rounds = 0u64;
        while t0.elapsed().as_secs_f64() < window {
            lockstep.run_round();
            lock_rounds += 1;
        }
        let lock_rps = lock_rounds as f64 / t0.elapsed().as_secs_f64();
        drop(lockstep); // the straggler's 40 MB problem shard, promptly

        let mut steal = build();
        let t0 = Instant::now();
        let mut steal_rounds = 0u64;
        while t0.elapsed().as_secs_f64() < window {
            steal.run_epoch(EPOCH_LEN);
            steal_rounds += EPOCH_LEN as u64;
        }
        let steal_rps = steal_rounds as f64 / t0.elapsed().as_secs_f64();

        let ratio = steal_rps / lock_rps.max(1e-9);
        let stolen = steal.metrics().stolen_grants;
        println!(
            "serve/skewed-{FLEETS}fleets-{TENANTS}tenants         lockstep {lock_rps:>9.0} \
             vs steal {steal_rps:>9.0} cluster-rounds/s (ratio {ratio:.2}x, {stolen} stolen grants)"
        );
        let shape = format!(
            ", \"fleets\": {FLEETS}, \"big_n\": {big_n}, \"active_tenants\": {}",
            1 + (TENANTS - 1).div_ceil(4)
        );
        rows.push(ThroughputRow {
            case: format!("serve/skewed-{FLEETS}fleets-{TENANTS}tenants-lockstep"),
            policy: Policy::Drr,
            jobs: TENANTS,
            rounds_per_sec: lock_rps,
            median_ns: 0,
            extra: format!("{shape}, \"executor\": \"lockstep\""),
        });
        rows.push(ThroughputRow {
            case: format!("serve/skewed-{FLEETS}fleets-{TENANTS}tenants-steal"),
            policy: Policy::Drr,
            jobs: TENANTS,
            rounds_per_sec: steal_rps,
            median_ns: 0,
            extra: format!(
                "{shape}, \"executor\": \"steal\", \"epoch_len\": {EPOCH_LEN}, \
                 \"stolen_grants\": {stolen}, \"ratio_vs_lockstep\": {ratio}"
            ),
        });
    }

    // Admission storm (the plan-cache acceptance number): 1024 submits
    // of the same heavy-plan spec. ndsc-ortho grows a dense Haar
    // orthonormal frame per worker per ladder level, so ladder build
    // dominates admission; with the cache installed every submit after
    // the first reuses one shared plan. Same process, same spec stream
    // — the ratio isolates pure ladder-rebuild work. The horizon is
    // short so the (identical on both sides) trace reserve stays small.
    {
        const STORM: usize = 1024;
        let storm_spec = |i: usize| {
            JobSpec::new(
                format!("storm{i}"),
                CompressorSpec::parse("ndsc-ortho").expect("canonical"),
                1.0,
                64,
                8,
                7,
            )
            .with_workers(2)
        };
        let mut uncached = JobServer::new(1 << 30, Policy::Drr);
        let t0 = Instant::now();
        for i in 0..STORM {
            black_box(uncached.submit(storm_spec(i)).expect("ample budget admits the storm"));
        }
        let cold = t0.elapsed();
        drop(uncached);

        let cache = Arc::new(PlanCache::with_default_cap());
        let mut cached = JobServer::new(1 << 30, Policy::Drr);
        cached.set_plan_cache(Some(cache.clone()));
        let t0 = Instant::now();
        for i in 0..STORM {
            black_box(cached.submit(storm_spec(i)).expect("ample budget admits the storm"));
        }
        let warm = t0.elapsed();
        let hits = cache.hits();
        let ratio = cold.as_secs_f64() / warm.as_secs_f64().max(1e-12);
        println!(
            "serve/admit-storm-{STORM}-ndsc-ortho-n64          uncached {:>9.0} vs cached \
             {:>9.0} admissions/s (ratio {ratio:.2}x, {hits} cache hits)",
            STORM as f64 / cold.as_secs_f64().max(1e-12),
            STORM as f64 / warm.as_secs_f64().max(1e-12),
        );
        rows.push(ThroughputRow {
            case: format!("serve/admit-storm-{STORM}-uncached"),
            policy: Policy::Drr,
            jobs: STORM,
            rounds_per_sec: STORM as f64 / cold.as_secs_f64().max(1e-12),
            median_ns: cold.as_nanos() / STORM as u128,
            extra: ", \"cache_hits\": 0".to_string(),
        });
        rows.push(ThroughputRow {
            case: format!("serve/admit-storm-{STORM}-cached"),
            policy: Policy::Drr,
            jobs: STORM,
            rounds_per_sec: STORM as f64 / warm.as_secs_f64().max(1e-12),
            median_ns: warm.as_nanos() / STORM as u128,
            extra: format!(", \"cache_hits\": {hits}, \"ratio_vs_uncached\": {ratio}"),
        });
    }

    // Migration churn (the autoscaler's hot path): 256 same-generative-
    // spec tenants on a 4-fleet cluster, then 512 checkpoint→restore
    // moves, each rebuilding the ladder when the cache is off and
    // hitting the admission-time plan when it is on. Cache-off runs
    // first so the shared-process comparison is cold→warm.
    {
        const CHURN_TENANTS: usize = 256;
        const CHURN_MIGRATIONS: usize = 512;
        let churn_spec = |i: usize| {
            JobSpec::new(
                format!("churn{i}"),
                CompressorSpec::parse("ndsc-ortho").expect("canonical"),
                1.0,
                64,
                64,
                7,
            )
            .with_workers(2)
        };
        let run_churn = |cache_on: bool| -> (f64, u64, u64) {
            let mut cluster = FleetCluster::new(FLEETS, 1 << 30, Policy::Drr);
            cluster.set_plan_cache_enabled(cache_on);
            let gids: Vec<_> = (0..CHURN_TENANTS)
                .map(|i| cluster.submit(churn_spec(i)).expect("ample budget admits the churn"))
                .collect();
            cluster.run_round();
            let t0 = Instant::now();
            let mut done = 0usize;
            'churn: loop {
                for &gid in &gids {
                    if done == CHURN_MIGRATIONS {
                        break 'churn;
                    }
                    let from = cluster.fleet_of(gid).unwrap_or(0);
                    cluster.migrate(gid, (from + 1) % FLEETS).expect("live jobs migrate");
                    done += 1;
                }
            }
            let secs = t0.elapsed().as_secs_f64().max(1e-9);
            let m = cluster.metrics();
            (done as f64 / secs, m.plan_cache_hits, m.migrated_jobs)
        };
        let (cold_rps, _, _) = run_churn(false);
        let (warm_rps, hits, migrated) = run_churn(true);
        let ratio = warm_rps / cold_rps.max(1e-9);
        println!(
            "serve/migrate-churn-{FLEETS}fleets-{CHURN_TENANTS}tenants     uncached \
             {cold_rps:>9.0} vs cached {warm_rps:>9.0} migrations/s \
             (ratio {ratio:.2}x, {hits} cache hits, {migrated} migrated)"
        );
        rows.push(ThroughputRow {
            case: format!("serve/migrate-churn-{FLEETS}fleets-{CHURN_TENANTS}tenants-uncached"),
            policy: Policy::Drr,
            jobs: CHURN_TENANTS,
            rounds_per_sec: cold_rps,
            median_ns: 0,
            extra: format!(
                ", \"fleets\": {FLEETS}, \"migrations\": {CHURN_MIGRATIONS}, \"cache_hits\": 0"
            ),
        });
        rows.push(ThroughputRow {
            case: format!("serve/migrate-churn-{FLEETS}fleets-{CHURN_TENANTS}tenants-cached"),
            policy: Policy::Drr,
            jobs: CHURN_TENANTS,
            rounds_per_sec: warm_rps,
            median_ns: 0,
            extra: format!(
                ", \"fleets\": {FLEETS}, \"migrations\": {CHURN_MIGRATIONS}, \
                 \"cache_hits\": {hits}, \"ratio_vs_uncached\": {ratio}"
            ),
        });
    }

    match std::fs::write("BENCH_serve.json", rows_to_json(&rows)) {
        Ok(()) => println!("wrote BENCH_serve.json ({} cases)", rows.len()),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}
