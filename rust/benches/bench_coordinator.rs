//! End-to-end coordinator bench: full rounds/second of the threaded
//! parameter server (Fig. 3a regime) — the headline L3 throughput number
//! for EXPERIMENTS.md §Perf.

use kashinflow::coordinator::config::{RunConfig, SchemeKind};
use kashinflow::coordinator::worker::DatasetGradSource;
use kashinflow::coordinator::run_distributed;
use kashinflow::data::synthetic::planted_regression_shards;
use kashinflow::linalg::rng::Rng;
use kashinflow::opt::objectives::Loss;
use kashinflow::testkit::bench::{black_box, Bencher};

fn bench_rounds(b: &mut Bencher, scheme: SchemeKind, n: usize, workers: usize, rounds: usize) {
    let name = format!("coordinator/{scheme:?}/n{n}/m{workers}/{rounds}rounds");
    b.run(&name, || {
        let mut rng = Rng::seed_from(6);
        let (shards, _) = planted_regression_shards(workers, 10, n, Loss::Square, &mut rng, false);
        let cfg = RunConfig {
            n,
            workers,
            r: 2.0,
            scheme,
            rounds,
            step: 0.02,
            batch: 5,
            ..Default::default()
        };
        let comps = cfg.build_compressors(&mut rng);
        let sources: Vec<Box<dyn kashinflow::coordinator::worker::GradSource>> = shards
            .into_iter()
            .enumerate()
            .map(|(i, obj)| {
                Box::new(DatasetGradSource {
                    obj,
                    batch: 5,
                    rng: Rng::seed_from(i as u64),
                    idx: Vec::new(),
                }) as Box<dyn kashinflow::coordinator::worker::GradSource>
            })
            .collect();
        let metrics = run_distributed(&cfg, vec![0.0; n], sources, comps, |_| 0.0);
        black_box(metrics.total_payload_bits);
    });
}

fn main() {
    // BENCH_SMOKE=1 → quick CI smoke settings.
    let mut b = Bencher::from_env();
    bench_rounds(&mut b, SchemeKind::Ndsc, 30, 4, 50);
    // m = 8: the acceptance case for the scoped-thread fan-out — below
    // server::PARALLEL_DECODE_MIN_DIM the decode path is byte-identical to
    // the sequential loop, so small-n rounds cannot regress; the 16384-dim
    // rows below exercise the parallel decode itself.
    bench_rounds(&mut b, SchemeKind::Ndsc, 30, 8, 50);
    bench_rounds(&mut b, SchemeKind::Ndsc, 30, 10, 50);
    bench_rounds(&mut b, SchemeKind::NdscDithered, 1024, 4, 20);
    bench_rounds(&mut b, SchemeKind::Naive, 1024, 4, 20);
    // The allocation-free hot-path acceptance rows: per-round time at
    // n = 4096 (sequential decode) and n = 16384 (scoped-thread decode),
    // both running entirely on recycled buffers after round 0.
    bench_rounds(&mut b, SchemeKind::Ndsc, 4096, 4, 10);
    bench_rounds(&mut b, SchemeKind::NdscDithered, 16384, 8, 5);
    bench_rounds(&mut b, SchemeKind::Naive, 16384, 8, 5);
    // Historical note: before the fused-kernel PR this bench owned
    // `BENCH_hotpath.json`; the kernel-level hot path now lives in
    // `bench_hotpath.rs` and this end-to-end target keeps its own file.
    b.save_json("BENCH_coordinator.json");
}
