//! Fig. 1c as a bench: wall-clock of democratic (LP, LV) vs
//! near-democratic embeddings across dimensions.

use kashinflow::embed::democratic::KashinSolver;
use kashinflow::embed::lp::{min_linf, LinfOptions};
use kashinflow::embed::near_democratic::nde;
use kashinflow::linalg::frames::HadamardFrame;
use kashinflow::linalg::fwht::next_pow2;
use kashinflow::linalg::rng::Rng;
use kashinflow::testkit::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::seed_from(3);
    for &n in &[64usize, 256, 1024, 4096] {
        let big_n = next_pow2(n * 2);
        let frame = HadamardFrame::with_big_n(n, big_n, &mut rng);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
        b.run(&format!("nde/{n}"), || {
            black_box(nde(&frame, &y));
        });
        let mut solver = KashinSolver::for_frame(&frame);
        b.run(&format!("lv/{n}"), || {
            black_box(solver.embed(&frame, &y));
        });
        if n <= 256 {
            b.run(&format!("lp/{n}"), || {
                black_box(min_linf(&frame, &y, &LinfOptions::default()));
            });
        }
    }
}
