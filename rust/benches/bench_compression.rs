//! Table 1 timing column: encode+decode wall-clock for every compression
//! scheme at n = 1024 and n = 65536 (the regimes of the paper's
//! evaluation vs. the transformer workload) — through both the allocating
//! API and the allocation-free workspace (`*_into`) API, so the hot-path
//! win is measured per scheme.

use kashinflow::exp::table1::schemes;
use kashinflow::linalg::rng::Rng;
use kashinflow::quant::{Compressed, Compressor, Workspace};
use kashinflow::testkit::bench::{black_box, Bencher};

fn main() {
    // BENCH_SMOKE=1 → quick CI smoke settings.
    let mut b = Bencher::from_env();
    let mut rng = Rng::seed_from(2);
    for &n in &[1024usize, 65536] {
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
        let mut build_rng = Rng::seed_from(3);
        for c in schemes(n, 3.0, &mut build_rng) {
            // DE at n=65536 is O(n^2)-ish via dense frames — skip the
            // dense-frame schemes at large n to keep the bench tractable.
            if n > 4096 && (c.name().contains("DSC[") && !c.name().contains("NDSC")
                || c.name().contains("orthonormal"))
            {
                continue;
            }
            let dim = c.n();
            let input = &y[..dim];
            b.run(&format!("encode/{}/{}", c.name(), dim), || {
                black_box(c.compress(input, &mut rng));
            });
            let msg = c.compress(input, &mut rng);
            b.run(&format!("decode/{}/{}", c.name(), dim), || {
                black_box(c.decompress(&msg));
            });
            // Workspace variants: warm buffers, zero steady-state allocs.
            let mut ws = Workspace::for_compressor(c.as_ref());
            let mut out = Compressed::empty(dim);
            let mut dec = vec![0.0f32; dim];
            c.compress_into(input, &mut rng, &mut ws, &mut out);
            b.run(&format!("encode-into/{}/{}", c.name(), dim), || {
                c.compress_into(input, &mut rng, &mut ws, &mut out);
                black_box(out.payload_bits);
            });
            c.decompress_into(&out, &mut ws, &mut dec);
            b.run(&format!("decode-into/{}/{}", c.name(), dim), || {
                c.decompress_into(&out, &mut ws, &mut dec);
                black_box(dec[0]);
            });
        }
    }
    b.save_json("BENCH_compression.json");
}
