//! Cross-scheme conformance suite over the compressor registry.
//!
//! For every spec in `registry::all_specs()` at `R ∈ {0.5, 1.0, 3.0}` and
//! `n ∈ {64, 100, 1024}` this asserts the wire contract of §3 / App. F:
//!
//! * `payload_bits ≤ budget_bits(n, R)` — the strict `⌊nR⌋` budget —
//!   whenever the spec is feasible at `(n, R)`, and `is_feasible` is
//!   *honest*: a fixed-rate scheme flagged infeasible really cannot fit
//!   (its fixed payload exceeds the budget);
//! * `bytes.len()` is exactly consistent with `total_bits()` (the bit
//!   writer emits no slack bytes);
//! * `decompress(compress(y))` returns a finite vector of length `n` for
//!   adversarial input shapes (heavy-tailed, one-hot, constant, zero);
//! * every `is_unbiased()` claim is verified empirically via
//!   `testkit::prop::forall`.

use kashinflow::linalg::rng::Rng;
use kashinflow::linalg::vecops::{dist2, norm2};
use kashinflow::quant::registry::{self, CompressorSpec};
use kashinflow::quant::{budget_bits, Compressed, Compressor, Workspace};
use kashinflow::testkit::prop::{forall, Cases};

const RS: [f32; 3] = [0.5, 1.0, 3.0];
const NS: [usize; 3] = [64, 100, 1024];

/// Adversarial input shapes for a dimension-`n` compressor.
fn test_vectors(n: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    let heavy: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
    let gauss: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
    let mut one_hot = vec![0.0f32; n];
    one_hot[rng.below(n)] = 42.0;
    let constant = vec![0.7f32; n];
    let zero = vec![0.0f32; n];
    vec![heavy, gauss, one_hot, constant, zero]
}

#[test]
fn registry_enumerates_at_least_12_schemes() {
    let specs = registry::all_specs();
    assert!(specs.len() >= 12, "zoo has only {} schemes", specs.len());
    let mut names: Vec<String> = specs.iter().map(|s| s.name()).collect();
    names.sort();
    names.dedup();
    assert_eq!(names.len(), specs.len(), "duplicate scheme names in the zoo");
}

#[test]
fn wire_contract_holds_for_every_spec_budget_dimension() {
    let specs = registry::all_specs();
    let mut rng = Rng::seed_from(0xC0DE);
    let mut feasible_somewhere = vec![false; specs.len()];
    for (si, spec) in specs.iter().enumerate() {
        for &n in &NS {
            for &r in &RS {
                if !spec.is_feasible(n, r) {
                    continue;
                }
                feasible_somewhere[si] = true;
                let c = spec.build(n, r, &mut rng);
                assert_eq!(c.n(), n, "{}: wrong dimension", spec.name());
                let budget = budget_bits(n, r);
                for y in test_vectors(n, &mut rng) {
                    let msg = c.compress(&y, &mut rng);
                    assert_eq!(msg.n, n, "{}: message dimension", spec.name());
                    assert!(
                        msg.payload_bits <= budget,
                        "{} at (n={n}, R={r}): payload {} > budget {budget}",
                        spec.name(),
                        msg.payload_bits
                    );
                    assert_eq!(
                        msg.bytes.len(),
                        msg.total_bits().div_ceil(8),
                        "{} at (n={n}, R={r}): {} wire bytes vs {} total bits",
                        spec.name(),
                        msg.bytes.len(),
                        msg.total_bits()
                    );
                    let yhat = c.decompress(&msg);
                    assert_eq!(yhat.len(), n, "{}: decode length", spec.name());
                    assert!(
                        yhat.iter().all(|v| v.is_finite()),
                        "{} at (n={n}, R={r}): non-finite decode",
                        spec.name()
                    );
                }
            }
        }
    }
    for (si, spec) in specs.iter().enumerate() {
        assert!(
            feasible_somewhere[si],
            "{} is never feasible on the conformance grid — dead zoo entry",
            spec.name()
        );
    }
}

/// `is_feasible` must be honest for fixed-rate schemes: when it says no,
/// the scheme's wire format genuinely cannot fit `⌊nR⌋` (its payload at
/// the *smallest* configuration exceeds the budget). We verify by
/// building the scheme anyway at a feasible larger budget and checking
/// its fixed payload exceeds the refused budget.
#[test]
fn infeasibility_is_honest_for_fixed_rate_schemes() {
    let mut rng = Rng::seed_from(0xFEA5);
    for spec in [CompressorSpec::Sign, CompressorSpec::Ternary, CompressorSpec::Qsgd] {
        for &n in &NS {
            for &r in &RS {
                if spec.is_feasible(n, r) {
                    continue;
                }
                // Build at a budget where the scheme does fit; its wire
                // rate is fixed, so the same payload must overflow ⌊nR⌋.
                let c = spec.build(n, 8.0, &mut rng);
                let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
                let payload = c.compress(&y, &mut rng).payload_bits;
                assert!(
                    payload > budget_bits(n, r),
                    "{} flagged infeasible at (n={n}, R={r}) but its payload {payload} fits",
                    spec.name()
                );
            }
        }
    }
}

/// Every `is_unbiased() == true` claim in the zoo, verified empirically:
/// the mean of many independent dithered encodings must converge to the
/// input. One `forall` case per unbiased spec, each with its own seeded
/// RNG stream so failures replay in isolation.
#[test]
fn unbiasedness_flags_verified_empirically() {
    let n = 64;
    let r = 3.0;
    let specs: Vec<CompressorSpec> = registry::all_specs()
        .into_iter()
        .filter(|s| s.is_feasible(n, r))
        .collect();
    forall(Cases::new("is_unbiased flags", specs.len()), |rng, idx| {
        let spec = &specs[idx];
        let c = spec.build(n, r, rng);
        if !c.is_unbiased() {
            // Deterministic schemes: nothing to average. (Their bias IS
            // their quantization error, which the error bounds cover.)
            return;
        }
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let trials = 2500;
        let mut mean = vec![0.0f64; n];
        for _ in 0..trials {
            let yhat = c.decompress(&c.compress(&y, rng));
            for (m, &v) in mean.iter_mut().zip(&yhat) {
                *m += v as f64 / trials as f64;
            }
        }
        let mean_f: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
        let bias = dist2(&mean_f, &y) / norm2(&y);
        assert!(bias < 0.2, "{} claims unbiased but bias is {bias}", spec.name());
    });
}

/// The workspace hot path is **bit-identical** to the allocating path:
/// for every spec × R × n on the conformance grid, twin codecs built from
/// identical RNG states — one driven through `compress`/`decompress`
/// (fresh buffers every call), one through `compress_into`/
/// `decompress_into` with a single `Workspace` and message shell reused
/// across the *entire* matrix (dirty-buffer stress) — must produce the
/// same wire bytes, the same bit accounting and the same decoded floats
/// for every input shape.
#[test]
fn into_path_bit_identical_to_allocating_path() {
    let specs = registry::all_specs();
    // One workspace + shell reused across all specs, budgets, dimensions
    // and inputs: any state leaking between calls shows up as a byte or
    // float mismatch somewhere on the grid.
    let mut ws = Workspace::new();
    let mut msg_b = Compressed::empty(1);
    let mut dec_b: Vec<f32> = Vec::new();
    for spec in &specs {
        for &n in &NS {
            for &r in &RS {
                if !spec.is_feasible(n, r) {
                    continue;
                }
                // Twin builds: same seed ⇒ same frame/shared randomness.
                let mut rng_a = Rng::seed_from(0xA11C ^ n as u64);
                let mut rng_b = Rng::seed_from(0xA11C ^ n as u64);
                let ca = spec.build(n, r, &mut rng_a);
                let cb = spec.build(n, r, &mut rng_b);
                let mut gen = Rng::seed_from(0x5EED ^ (n as u64) << 8);
                dec_b.resize(n, 0.0);
                for y in test_vectors(n, &mut gen) {
                    let msg_a = ca.compress(&y, &mut rng_a);
                    cb.compress_into(&y, &mut rng_b, &mut ws, &mut msg_b);
                    assert_eq!(
                        msg_a.bytes,
                        msg_b.bytes,
                        "{} at (n={n}, R={r}): wire bytes diverge between paths",
                        spec.name()
                    );
                    assert_eq!(msg_a.n, msg_b.n, "{}: message n", spec.name());
                    assert_eq!(
                        msg_a.payload_bits,
                        msg_b.payload_bits,
                        "{}: payload accounting",
                        spec.name()
                    );
                    assert_eq!(
                        msg_a.side_bits,
                        msg_b.side_bits,
                        "{}: side accounting",
                        spec.name()
                    );
                    let dec_a = ca.decompress(&msg_a);
                    cb.decompress_into(&msg_b, &mut ws, &mut dec_b);
                    assert!(
                        dec_a.iter().zip(&dec_b).all(|(a, b)| a.to_bits() == b.to_bits()),
                        "{} at (n={n}, R={r}): decoded floats diverge between paths",
                        spec.name()
                    );
                }
            }
        }
    }
}

/// The registry must be referentially sane: the same spec built twice
/// from the same RNG state is the same codec (deterministic schemes
/// produce identical wire bytes).
#[test]
fn deterministic_schemes_reproduce_bitstreams() {
    let n = 100;
    let r = 3.0;
    for spec in registry::all_specs() {
        if !spec.is_feasible(n, r) {
            continue;
        }
        let mut rng_a = Rng::seed_from(7);
        let mut rng_b = Rng::seed_from(7);
        let ca = spec.build(n, r, &mut rng_a);
        let cb = spec.build(n, r, &mut rng_b);
        let y: Vec<f32> = {
            let mut g = Rng::seed_from(9);
            (0..n).map(|_| g.gaussian_cubed()).collect()
        };
        let ma = ca.compress(&y, &mut rng_a);
        let mb = cb.compress(&y, &mut rng_b);
        assert_eq!(
            ma.bytes,
            mb.bytes,
            "{}: same seeds must give identical wire bytes",
            spec.name()
        );
    }
}

/// Satellite: `is_feasible` at the contract's edges — `n = 1`, budgets
/// driven toward `R → 0⁺` (sub-linear, down to a 1-bit wire), and `R`
/// large enough that the wire budget exceeds fp32. Each accept/reject is
/// asserted against the scheme's documented contract (fixed-rate schemes
/// need their wire rate; budget-adaptive schemes need one atom; fp32
/// needs all 32 bits/dim).
#[test]
fn feasibility_edge_cases_match_documented_contract() {
    use kashinflow::quant::dsc::{CodecMode, EmbedKind};
    use kashinflow::quant::registry::{FrameSpec, InnerSpec, SparsifyKind};
    let subspace = CompressorSpec::Subspace {
        embed: EmbedKind::NearDemocratic,
        mode: CodecMode::Dithered,
        frame: FrameSpec::Hadamard,
    };

    // --- n = 1, R = 1 ⇒ budget is a single bit. -------------------------
    let (n, r) = (1usize, 1.0f32);
    assert_eq!(budget_bits(n, r), 1);
    assert!(subspace.is_feasible(n, r), "subspace codecs adapt to any positive budget");
    assert!(CompressorSpec::Naive.is_feasible(n, r));
    assert!(CompressorSpec::StandardDither.is_feasible(n, r));
    assert!(CompressorSpec::Sign.is_feasible(n, r), "sign needs exactly n bits");
    assert!(!CompressorSpec::Qsgd.is_feasible(n, r), "QSGD needs >= 2 bits/dim");
    assert!(!CompressorSpec::Ternary.is_feasible(n, r), "ternary packs 5 dims per 8 bits");
    assert!(
        CompressorSpec::TopK { value_bits: 1, count_index_bits: false }.is_feasible(n, r),
        "one 1-bit entry fits (index_bits(1) = 0)"
    );
    assert!(
        !CompressorSpec::TopK { value_bits: 4, count_index_bits: true }.is_feasible(n, r),
        "a 4-bit entry cannot fit in 1 bit"
    );
    assert!(CompressorSpec::RandK { value_bits: 1, kind: SparsifyKind::Unbiased }
        .is_feasible(n, r));
    assert!(
        CompressorSpec::VqSgd.is_feasible(n, r),
        "vqSGD at n = 1 needs ceil(log2(2)) = 1 bit per vertex index"
    );
    assert!(
        !CompressorSpec::Ratq.is_feasible(n, r),
        "RATQ's per-group ladder overhead (3 bits) exceeds the 1-bit budget"
    );
    assert!(!CompressorSpec::Fp32.is_feasible(n, r));

    // --- R → 0⁺: a sub-linear budget with exactly one wire bit. ---------
    let (n, r) = (1024usize, 0.001f32);
    assert_eq!(budget_bits(n, r), 1);
    assert!(subspace.is_feasible(n, r), "the paper's regime: R < 1 is first-class");
    assert!(CompressorSpec::StandardDither.is_feasible(n, r));
    assert!(CompressorSpec::RandK { value_bits: 1, kind: SparsifyKind::Unbiased }
        .is_feasible(n, r));
    assert!(CompressorSpec::TopK { value_bits: 1, count_index_bits: false }.is_feasible(n, r));
    assert!(
        !CompressorSpec::TopK { value_bits: 1, count_index_bits: true }.is_feasible(n, r),
        "charging index bits needs 1 + log2(1024) = 11 bits"
    );
    assert!(!CompressorSpec::Sign.is_feasible(n, r));
    assert!(!CompressorSpec::Qsgd.is_feasible(n, r));
    assert!(!CompressorSpec::Ternary.is_feasible(n, r));
    assert!(
        !CompressorSpec::VqSgd.is_feasible(n, r),
        "one vertex index is ceil(log2(2048)) = 11 bits"
    );
    assert!(!CompressorSpec::Ratq.is_feasible(n, r));
    assert!(!CompressorSpec::Fp32.is_feasible(n, r));
    assert!(
        CompressorSpec::Embedded { inner: InnerSpec::StandardDither, frame: FrameSpec::Hadamard }
            .is_feasible(n, r)
    );
    // And R small enough that even the 1-bit atom no longer fits:
    // ⌊64 · 0.001⌋ = 0 wire bits.
    let (n, r) = (64usize, 0.001f32);
    assert_eq!(budget_bits(n, r), 0);
    assert!(!CompressorSpec::RandK { value_bits: 1, kind: SparsifyKind::Unbiased }
        .is_feasible(n, r));
    assert!(!CompressorSpec::TopK { value_bits: 1, count_index_bits: false }.is_feasible(n, r));
    assert!(!CompressorSpec::VqSgd.is_feasible(n, r));

    // --- R beyond fp32: every fixed-rate baseline fits, fp32 included. --
    let (n, r) = (64usize, 40.0f32);
    assert!(budget_bits(n, r) > 32 * n, "the wire budget exceeds an fp32 vector");
    for spec in registry::all_specs() {
        assert!(
            spec.is_feasible(n, r),
            "{} claims infeasible at the super-fp32 budget R = {r}",
            spec.name()
        );
    }
    assert!(CompressorSpec::Fp32.is_feasible(n, r));
    assert!(
        !CompressorSpec::Fp32.is_feasible(n, 31.99),
        "fp32 needs the full 32 bits per dimension"
    );
    // Feasible edge specs really honor the contract when built: the
    // 1-bit-budget sparsifier spends exactly its single bit.
    let mut rng = Rng::seed_from(0xED6E);
    let c = CompressorSpec::RandK { value_bits: 1, kind: SparsifyKind::Unbiased }
        .build(1024, 0.001, &mut rng);
    let y: Vec<f32> = (0..1024).map(|_| rng.gaussian_f32()).collect();
    let msg = c.compress(&y, &mut rng);
    assert_eq!(msg.payload_bits, 1);
}

/// Satellite: the sparsifiers at budgets so large their derived `k`
/// overshoots `n` — `build` must clamp `k` to `n` (a top-`n` / rand-`n`
/// selection is the whole vector) and the built codec's exact wire
/// accounting must match the clamp, never the unclamped `⌊nR⌋/per`.
#[test]
fn sparsifier_k_clamps_to_n_at_huge_budgets() {
    use kashinflow::quant::registry::SparsifyKind;
    let (n, r) = (64usize, 40.0f32);
    let budget = budget_bits(n, r);
    assert_eq!(budget, 2560, "⌊64·40⌋");
    let mut rng = Rng::seed_from(0xB16);
    let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();

    // RandK, 1-bit values: unclamped k would be 2560 > n = 64.
    for kind in [SparsifyKind::Plain, SparsifyKind::Unbiased, SparsifyKind::Deterministic] {
        let spec = CompressorSpec::RandK { value_bits: 1, kind };
        assert!(spec.is_feasible(n, r));
        let c = spec.build(n, r, &mut rng);
        let msg = c.compress(&y, &mut rng);
        assert_eq!(msg.payload_bits, n, "{}: k must clamp to n=64 at 1 bit each", spec.name());
        assert_eq!(msg.bytes.len(), msg.total_bits().div_ceil(8), "{}", spec.name());
        let yhat = c.decompress(&msg);
        assert!(yhat.iter().all(|v| v.is_finite()), "{}", spec.name());
    }

    // TopK, free indices: per-entry cost 4 bits ⇒ unclamped k = 640.
    let spec = CompressorSpec::TopK { value_bits: 4, count_index_bits: false };
    let c = spec.build(n, r, &mut rng);
    let msg = c.compress(&y, &mut rng);
    assert_eq!(msg.payload_bits, n * 4, "top-n keeps all 64 entries at 4 bits");
    // Free indices still ride along as side information: 32-bit norm
    // header + log2(64) bits per kept index.
    assert_eq!(msg.side_bits, 32 + n * 6);
    assert!(msg.payload_bits <= budget);

    // TopK, charged indices: per-entry cost 4 + 6 ⇒ unclamped k = 256.
    let spec = CompressorSpec::TopK { value_bits: 4, count_index_bits: true };
    let c = spec.build(n, r, &mut rng);
    let msg = c.compress(&y, &mut rng);
    assert_eq!(msg.payload_bits, n * (4 + 6));
    assert_eq!(msg.side_bits, 32);
    assert!(msg.payload_bits <= budget);
}

/// Satellite: the wire contract at super-fp32 budgets (`R > 32`), where
/// the conformance grid above never reaches. Every zoo spec must be
/// feasible, build, respect `⌊nR⌋`, keep the byte length exact and
/// decode finite — in particular the schemes whose per-coordinate widths
/// are *derived* from `R` (QSGD levels, RATQ ladders, subspace bit
/// allocation) must not overflow their bit-packing at 40–64 bits/dim.
#[test]
fn wire_contract_holds_at_super_fp32_budgets() {
    let mut rng = Rng::seed_from(0xB165);
    for &(n, r) in &[(64usize, 40.0f32), (100, 40.0), (64, 64.0)] {
        let budget = budget_bits(n, r);
        for spec in registry::all_specs() {
            assert!(
                spec.is_feasible(n, r),
                "{} infeasible at the super-fp32 budget (n={n}, R={r})",
                spec.name()
            );
            let c = spec.build(n, r, &mut rng);
            for y in test_vectors(n, &mut rng) {
                let msg = c.compress(&y, &mut rng);
                assert!(
                    msg.payload_bits <= budget,
                    "{} at (n={n}, R={r}): payload {} > budget {budget}",
                    spec.name(),
                    msg.payload_bits
                );
                assert_eq!(
                    msg.bytes.len(),
                    msg.total_bits().div_ceil(8),
                    "{} at (n={n}, R={r}): slack wire bytes",
                    spec.name()
                );
                let yhat = c.decompress(&msg);
                assert_eq!(yhat.len(), n);
                assert!(
                    yhat.iter().all(|v| v.is_finite()),
                    "{} at (n={n}, R={r}): non-finite decode",
                    spec.name()
                );
            }
        }
    }
}
