//! Shared bit-identity oracles for the integration suites. Each suite
//! binary uses the oracle for its own runtime (coordinator `RunMetrics`
//! vs engine `Trace`), so both carry `allow(dead_code)`.

use kashinflow::coordinator::metrics::RunMetrics;
use kashinflow::opt::Trace;

/// Bit-exact run-trace equality: every per-round metric (objective bits,
/// mean local value bits, payload, participants), the final iterate and
/// the traffic totals. One definition on purpose — when `RunMetrics`
/// grows a field (as `participants` did), add it here and every suite
/// that claims bitwise identity starts covering it at once.
#[allow(dead_code)]
pub fn assert_bit_identical(a: &RunMetrics, b: &RunMetrics, label: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{label}: round count");
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(
            ra.value.to_bits(),
            rb.value.to_bits(),
            "{label}: round {} objective diverged ({} vs {})",
            ra.round,
            ra.value,
            rb.value
        );
        assert_eq!(
            ra.mean_local_value.to_bits(),
            rb.mean_local_value.to_bits(),
            "{label}: round {} mean local value diverged",
            ra.round
        );
        assert_eq!(ra.payload_bits, rb.payload_bits, "{label}: round {} bits", ra.round);
        assert_eq!(
            ra.participants, rb.participants,
            "{label}: round {} participants diverged",
            ra.round
        );
    }
    assert_eq!(a.final_iterate.len(), b.final_iterate.len(), "{label}: iterate length");
    for (i, (xa, xb)) in a.final_iterate.iter().zip(&b.final_iterate).enumerate() {
        assert_eq!(
            xa.to_bits(),
            xb.to_bits(),
            "{label}: final iterate coordinate {i} diverged ({xa} vs {xb})"
        );
    }
    assert_eq!(a.total_payload_bits, b.total_payload_bits, "{label}: traffic");
}

/// Bit-exact optimizer-trace equality: every per-record metric (value
/// bits, distance bits, payload, participants), the final iterate, and
/// the traffic totals. Same single-definition policy as
/// [`assert_bit_identical`]: when `IterRecord` grows a field, add it
/// here and every engine golden-trace suite covers it at once.
#[allow(dead_code)]
pub fn assert_trace_bit_identical(a: &Trace, b: &Trace, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record count");
    for (t, (ra, rb)) in a.records.iter().zip(&b.records).enumerate() {
        assert_eq!(
            ra.value.to_bits(),
            rb.value.to_bits(),
            "{label}: record {t} value diverged ({} vs {})",
            ra.value,
            rb.value
        );
        assert_eq!(
            ra.dist_to_opt.to_bits(),
            rb.dist_to_opt.to_bits(),
            "{label}: record {t} dist_to_opt diverged ({} vs {})",
            ra.dist_to_opt,
            rb.dist_to_opt
        );
        assert_eq!(ra.payload_bits, rb.payload_bits, "{label}: record {t} payload bits");
        assert_eq!(ra.participants, rb.participants, "{label}: record {t} participants");
    }
    assert_eq!(a.final_x.len(), b.final_x.len(), "{label}: final_x length");
    for (i, (xa, xb)) in a.final_x.iter().zip(&b.final_x).enumerate() {
        assert_eq!(
            xa.to_bits(),
            xb.to_bits(),
            "{label}: final_x coordinate {i} diverged ({xa} vs {xb})"
        );
    }
    assert_eq!(a.total_payload_bits, b.total_payload_bits, "{label}: payload total");
    assert_eq!(a.total_side_bits, b.total_side_bits, "{label}: side-info total");
}
