//! Shared oracles for the coordinator integration suites.

use kashinflow::coordinator::metrics::RunMetrics;

/// Bit-exact run-trace equality: every per-round metric (objective bits,
/// mean local value bits, payload, participants), the final iterate and
/// the traffic totals. One definition on purpose — when `RunMetrics`
/// grows a field (as `participants` did), add it here and every suite
/// that claims bitwise identity starts covering it at once.
pub fn assert_bit_identical(a: &RunMetrics, b: &RunMetrics, label: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{label}: round count");
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(
            ra.value.to_bits(),
            rb.value.to_bits(),
            "{label}: round {} objective diverged ({} vs {})",
            ra.round,
            ra.value,
            rb.value
        );
        assert_eq!(
            ra.mean_local_value.to_bits(),
            rb.mean_local_value.to_bits(),
            "{label}: round {} mean local value diverged",
            ra.round
        );
        assert_eq!(ra.payload_bits, rb.payload_bits, "{label}: round {} bits", ra.round);
        assert_eq!(
            ra.participants, rb.participants,
            "{label}: round {} participants diverged",
            ra.round
        );
    }
    assert_eq!(a.final_iterate.len(), b.final_iterate.len(), "{label}: iterate length");
    for (i, (xa, xb)) in a.final_iterate.iter().zip(&b.final_iterate).enumerate() {
        assert_eq!(
            xa.to_bits(),
            xb.to_bits(),
            "{label}: final iterate coordinate {i} diverged ({xa} vs {xb})"
        );
    }
    assert_eq!(a.total_payload_bits, b.total_payload_bits, "{label}: traffic");
}
