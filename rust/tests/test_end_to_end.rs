//! Integration: the full threaded coordinator over every scheme, budget
//! enforcement under adversarial configs, and determinism.

use std::sync::Arc;

use kashinflow::coordinator::config::{RunConfig, SchemeKind};
use kashinflow::coordinator::run_distributed;
use kashinflow::coordinator::worker::{DatasetGradSource, GradSource};
use kashinflow::data::synthetic::planted_regression_shards;
use kashinflow::linalg::rng::Rng;
use kashinflow::opt::objectives::Loss;
use kashinflow::quant::Compressor;

fn sources_for(
    shards: Vec<kashinflow::opt::objectives::DatasetObjective>,
    batch: usize,
    seed: u64,
) -> Vec<Box<dyn GradSource>> {
    shards
        .into_iter()
        .enumerate()
        .map(|(i, obj)| {
            Box::new(DatasetGradSource {
                obj,
                batch,
                rng: Rng::seed_from(seed + i as u64),
                idx: Vec::new(),
            }) as Box<dyn GradSource>
        })
        .collect()
}

#[test]
fn every_scheme_completes_a_distributed_run() {
    for scheme in [
        SchemeKind::Ndsc,
        SchemeKind::NdscDithered,
        SchemeKind::Naive,
        SchemeKind::StandardDither,
        SchemeKind::Qsgd,
        SchemeKind::Sign,
        SchemeKind::Ternary,
        SchemeKind::TopK,
        SchemeKind::RandK,
        SchemeKind::None,
    ] {
        let mut rng = Rng::seed_from(1);
        let (shards, _) = planted_regression_shards(3, 8, 16, Loss::Square, &mut rng, false);
        // Schemes with fixed wire rates need a budget that admits them.
        // fp32 runs at a *low* nominal r on purpose: it is the documented
        // unconstrained reference, so the uplink must waive its budget
        // (regression: it used to panic the worker on the first upload).
        let r = match scheme {
            SchemeKind::None => 1.0,
            SchemeKind::Qsgd => 4.0,
            SchemeKind::Ternary | SchemeKind::Sign => 2.0,
            _ => 2.0,
        };
        let cfg = RunConfig { n: 16, workers: 3, r, scheme, rounds: 20, step: 0.02, batch: 0, ..Default::default() };
        let comps = cfg.build_compressors(&mut rng);
        let metrics =
            run_distributed(&cfg, vec![0.0; 16], sources_for(shards, 0, 50), comps, |_| 0.0);
        assert_eq!(metrics.rounds.len(), 20, "{scheme:?}");
        assert_eq!(metrics.rejected_messages, 0, "{scheme:?}");
        assert!(metrics.rounds.iter().all(|r| r.payload_bits > 0 || scheme == SchemeKind::None));
    }
}

#[test]
fn budget_enforcement_rejects_over_budget_compressor() {
    // A compressor that lies about its rate must be caught by the channel.
    struct Liar;
    impl Compressor for Liar {
        fn name(&self) -> String {
            "liar".into()
        }
        fn n(&self) -> usize {
            16
        }
        fn bits_per_dim(&self) -> f32 {
            1.0
        }
        fn compress(&self, _y: &[f32], _rng: &mut Rng) -> kashinflow::quant::Compressed {
            kashinflow::quant::Compressed {
                n: 16,
                bytes: vec![0; 100],
                payload_bits: 800, // way over floor(16*1) = 16
                side_bits: 0,
            }
        }
        fn decompress(&self, _msg: &kashinflow::quant::Compressed) -> Vec<f32> {
            vec![0.0; 16]
        }
    }
    let mut rng = Rng::seed_from(2);
    let (shards, _) = planted_regression_shards(1, 8, 16, Loss::Square, &mut rng, false);
    let cfg =
        RunConfig { n: 16, workers: 1, r: 1.0, rounds: 5, step: 0.01, ..Default::default() };
    let comps: Vec<Arc<dyn Compressor>> = vec![Arc::new(Liar)];
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_distributed(&cfg, vec![0.0; 16], sources_for(shards, 0, 60), comps, |_| 0.0)
    }));
    assert!(result.is_err(), "over-budget messages must abort the run");
}

#[test]
fn runs_are_deterministic_given_seed() {
    let run = || {
        let mut rng = Rng::seed_from(3);
        let (shards, _) = planted_regression_shards(4, 10, 24, Loss::Square, &mut rng, false);
        let cfg = RunConfig {
            n: 24,
            workers: 4,
            r: 2.0,
            scheme: SchemeKind::Ndsc,
            rounds: 30,
            step: 0.02,
            batch: 0,
            seed: 9,
            ..Default::default()
        };
        let comps = cfg.build_compressors(&mut rng);
        let metrics =
            run_distributed(&cfg, vec![0.0; 24], sources_for(shards, 0, 70), comps, |_| 0.0);
        metrics.final_iterate
    };
    // NOTE: worker->server message interleaving is nondeterministic, but
    // consensus averaging is order-independent up to float rounding; with
    // deterministic codecs the result must match to high precision.
    let a = run();
    let b = run();
    let d = kashinflow::linalg::vecops::dist2(&a, &b);
    assert!(d < 1e-5, "nondeterministic result: {d}");
}

#[test]
fn multiworker_variance_reduction() {
    // App. I: quantization variance enters as sigma_q^2 / m — more workers
    // should land closer to x* at a fixed round budget (dithered codec).
    let run_with_workers = |m: usize| -> f32 {
        let mut rng = Rng::seed_from(4);
        let (shards, xs) = planted_regression_shards(m, 10, 16, Loss::Square, &mut rng, false);
        let cfg = RunConfig {
            n: 16,
            workers: m,
            r: 1.0,
            scheme: SchemeKind::NdscDithered,
            rounds: 150,
            step: 0.01,
            batch: 0,
            ..Default::default()
        };
        let comps = cfg.build_compressors(&mut rng);
        let metrics =
            run_distributed(&cfg, vec![0.0; 16], sources_for(shards, 0, 80), comps, |_| 0.0);
        kashinflow::linalg::vecops::dist2(&metrics.final_iterate, &xs)
    };
    let d1 = run_with_workers(2);
    let d2 = run_with_workers(12);
    assert!(
        d2 < d1 * 1.2,
        "more workers should not be much worse: m=2 gives {d1}, m=12 gives {d2}"
    );
}
