//! Serving-layer integration suite: isolation, resumability, fairness.
//!
//! * **Isolation** — a job's trace is bit-identical whether it runs
//!   solo, interleaved with three other tenants under an ample budget,
//!   or starved under a scarce budget (strict DRR): the scheduler may
//!   only decide *when* rounds run, never *what* they compute.
//! * **Engine parity** — the serve path *is* the engine: a served job's
//!   trace equals a hand-built `Engine::run` of the same composition
//!   under the job's derived seeds.
//! * **Resumability** — a job checkpointed mid-run and restored into a
//!   fresh fleet finishes with exactly the uninterrupted trace, for both
//!   DEF-feedback and no-feedback jobs; corrupt/truncated snapshots are
//!   `InvalidData`, never a panic.
//! * **Fairness** — deficit counters stay within their cap and every
//!   live job is served within the starvation bound under an adversarial
//!   tiny-R + greedy high-R mix.
//! * **Multi-fleet** — tenants partitioned across a [`FleetCluster`]'s
//!   concurrent threaded fleets (worker fan-out armed) trace exactly as
//!   solo inline runs, through mid-run fleet-to-fleet migrations
//!   included; the migrated job's banked deficit and adaptive rung
//!   survive the move.
//! * **QoS** — weighted classes bias service toward gold without ever
//!   starving bronze out of its reserved budget slice.
//! * **Epoch executor** — the work-stealing epoch path (`run_epoch` /
//!   `run_async`) is bit-identical to the lockstep cluster: per-job
//!   traces, deficit counters, adaptive rungs and the full DRR/QoS
//!   accounting agree field-for-field at any epoch chunking.
//! * **Plan cache** — the cluster-wide codec-plan cache changes *where*
//!   a ladder comes from, never what it computes: cache-on equals
//!   cache-off bitwise under ample and scarce budgets, migrations
//!   restore through cache hits without perturbing traces, and an
//!   LRU-evicted plan rebuilds bit-identically.
//! * **Batched panels** — coalescing same-shape lightweight grants into
//!   batched execution panels is bit-identical to per-job panels on a
//!   mixed small/heavy tenant population.

mod common;

use std::collections::{HashMap, HashSet};
use std::io;

use common::assert_trace_bit_identical;
use kashinflow::linalg::rng::Rng;
use kashinflow::opt::engine::oracle::ShardOracle;
use kashinflow::opt::engine::{Codecs, Engine, OutputMode, Problem, RngPolicy};
use kashinflow::opt::multi::ShardedProblem;
use kashinflow::opt::objectives::Loss;
use kashinflow::opt::Trace;
use kashinflow::quant::registry::CompressorSpec;
use kashinflow::quant::Compressor;
use kashinflow::serve::checkpoint;
use kashinflow::serve::job::{DATA_SALT, FRAME_SALT, RUN_SALT};
use kashinflow::serve::scheduler::Deficit;
use kashinflow::serve::{FleetCluster, Job, JobServer, JobSpec, JobState, Policy, QosClass};

fn spec(name: &str, scheme: &str, r: f32, n: usize, rounds: usize, seed: u64) -> JobSpec {
    JobSpec::new(name, CompressorSpec::parse(scheme).unwrap(), r, n, rounds, seed)
}

/// Four heterogeneous tenants (schemes, budgets, feedback, worker
/// counts) used by the isolation and checkpoint tests.
fn four_tenants(n: usize, rounds: usize) -> Vec<JobSpec> {
    vec![
        spec("a-ndsc-dith", "ndsc-dith", 1.0, n, rounds, 11),
        spec("b-sd", "sd", 0.5, n, rounds, 22).with_workers(2),
        spec("c-ndsc-def", "ndsc", 2.0, n, rounds, 33).with_def_feedback(),
        spec("d-topk", "topk1b", 2.0, n, rounds, 44),
    ]
}

/// Run one spec to completion in its own single-tenant fleet and return
/// its finalized trace.
fn solo_trace(s: JobSpec) -> Trace {
    let rounds = s.rounds;
    let mut srv = JobServer::new(1 << 24, Policy::Drr);
    let id = srv.submit(s).unwrap();
    srv.run(rounds + 4);
    assert_eq!(srv.state(id), Some(JobState::Finished));
    srv.job(id).unwrap().trace().clone()
}

#[test]
fn served_job_matches_hand_built_engine_run() {
    // The serve path must be the engine, not a reimplementation: rebuild
    // the job's exact composition by hand from its salted seed streams
    // and compare whole traces bitwise.
    let n = 32;
    let rounds = 25;
    let s = spec("parity", "ndsc-dith", 1.0, n, rounds, 77).with_workers(2);
    let seed = s.seed;
    let served = solo_trace(s);

    // Hand-built baseline under the job's derivation discipline.
    let mut data_rng = Rng::seed_from(seed ^ DATA_SALT);
    let (shards, x_star) = kashinflow::data::synthetic::planted_regression_shards(
        2,
        10,
        n,
        Loss::Square,
        &mut data_rng,
        false,
    );
    let problem = ShardedProblem::new(shards);
    let step = problem.stable_step();
    let mut frame_rng = Rng::seed_from(seed ^ FRAME_SALT);
    let mut level0_rng = frame_rng.fork(0);
    let scheme = CompressorSpec::parse("ndsc-dith").unwrap();
    let codecs: Vec<Box<dyn Compressor>> =
        (0..2).map(|_| scheme.build(n, 1.0, &mut level0_rng)).collect();
    let mut run_rng = Rng::seed_from(seed ^ RUN_SALT);
    let mut engine = Engine::new(
        Problem::Sharded(&problem),
        kashinflow::opt::engine::schedule::Schedule::Constant(step),
        rounds,
    )
    .with_codecs(Codecs::PerWorker(&codecs))
    .with_rng_policy(RngPolicy::ForkPerWorker)
    .with_output(OutputMode::PolyakAverage);
    for shard in &problem.shards {
        engine = engine.with_oracle(ShardOracle::new(shard, None));
    }
    let baseline = engine.run(&vec![0.0; n], Some(&x_star), &mut run_rng);
    assert_trace_bit_identical(&served, &baseline, "serve vs hand-built engine");
}

#[test]
fn interleaved_four_job_serve_is_isolated() {
    let n = 24;
    let rounds = 30;
    let solos: Vec<Trace> = four_tenants(n, rounds).into_iter().map(solo_trace).collect();

    // Ample budget: every tenant is served every fleet round.
    let mut ample = JobServer::new(1 << 24, Policy::Drr);
    let ids: Vec<_> =
        four_tenants(n, rounds).into_iter().map(|s| ample.submit(s).unwrap()).collect();
    ample.run(rounds * 8);
    for (i, &id) in ids.iter().enumerate() {
        assert_eq!(ample.state(id), Some(JobState::Finished));
        assert_trace_bit_identical(
            ample.job(id).unwrap().trace(),
            &solos[i],
            &format!("ample-budget job {i}"),
        );
    }

    // Scarce budget (≈40% of aggregate demand): jobs are time-sliced in
    // a completely different interleaving — traces must not notice.
    let demand: u64 = {
        let mut srv = JobServer::new(1 << 24, Policy::Drr);
        four_tenants(n, rounds)
            .into_iter()
            .map(|s| {
                let id = srv.submit(s).unwrap();
                srv.job(id).unwrap().requested_cost_bits()
            })
            .sum()
    };
    let mut scarce = JobServer::new(((demand as f32 * 0.4) as usize).max(1), Policy::Drr);
    let ids: Vec<_> =
        four_tenants(n, rounds).into_iter().map(|s| scarce.submit(s).unwrap()).collect();
    scarce.run(rounds * 64);
    for (i, &id) in ids.iter().enumerate() {
        assert_eq!(scarce.state(id), Some(JobState::Finished), "job {i} must finish");
        assert_trace_bit_identical(
            scarce.job(id).unwrap().trace(),
            &solos[i],
            &format!("scarce-budget job {i}"),
        );
    }
    // The interleavings really did differ: under scarcity not every
    // fleet round served all four tenants.
    assert!(
        scarce.round() > ample.round(),
        "scarce fleet should need more rounds ({} vs {})",
        scarce.round(),
        ample.round()
    );
}

#[test]
fn paused_and_resumed_job_trace_is_uninterrupted() {
    let n = 24;
    let rounds = 30;
    let straight = solo_trace(spec("p", "ndsc-dith", 1.0, n, rounds, 5));
    let mut srv = JobServer::new(1 << 24, Policy::Drr);
    let id = srv.submit(spec("p", "ndsc-dith", 1.0, n, rounds, 5)).unwrap();
    for _ in 0..10 {
        srv.run_round();
    }
    srv.pause(id).unwrap();
    for _ in 0..25 {
        srv.run_round(); // idle: nothing live
    }
    assert_eq!(srv.job(id).unwrap().rounds_done(), 10);
    srv.resume(id).unwrap();
    srv.run(rounds * 4);
    assert_eq!(srv.state(id), Some(JobState::Finished));
    assert_trace_bit_identical(srv.job(id).unwrap().trace(), &straight, "pause/resume");
}

#[test]
fn checkpoint_restore_resumes_bit_for_bit() {
    // Both memory shapes: a DEF-feedback job (per-worker error state must
    // travel in the snapshot) and a no-feedback dithered job (RNG streams
    // must travel). Snapshot at round t, restore into a *fresh* fleet —
    // the process-restart stand-in — and finish.
    let n = 24;
    let rounds = 30;
    let cases = [
        spec("def", "ndsc", 2.0, n, rounds, 61).with_workers(2).with_def_feedback(),
        spec("nofb", "ndsc-dith", 1.0, n, rounds, 62).with_workers(2),
    ];
    for s in cases {
        let label = s.name.clone();
        let uninterrupted = solo_trace(s.clone());
        let mut srv = JobServer::new(1 << 24, Policy::Drr);
        let id = srv.submit(s).unwrap();
        for _ in 0..13 {
            srv.run_round();
        }
        let bytes = srv.checkpoint(id).unwrap();
        srv.cancel(id).unwrap(); // the original is killed mid-run
        let mut fresh = JobServer::new(1 << 24, Policy::Drr);
        let rid = fresh.restore(&bytes).unwrap();
        assert_eq!(fresh.job(rid).unwrap().rounds_done(), 13, "{label}: resumes at round t");
        fresh.run(rounds * 4);
        assert_eq!(fresh.state(rid), Some(JobState::Finished));
        assert_trace_bit_identical(
            fresh.job(rid).unwrap().trace(),
            &uninterrupted,
            &format!("checkpoint round-trip ({label})"),
        );
    }
}

#[test]
fn corrupt_and_truncated_checkpoints_surface_invalid_data() {
    let mut job = Job::build(
        spec("ckpt", "ndsc-dith", 1.0, 16, 8, 9).with_workers(2).with_def_feedback(),
    )
    .unwrap();
    for _ in 0..3 {
        job.step_round(0);
    }
    let good = checkpoint::save(&job).unwrap();
    assert!(checkpoint::restore(&good).is_ok());
    // Every truncation point must be a clean InvalidData error — the
    // short read can land inside any field.
    for cut in 0..good.len() {
        let err = checkpoint::restore(&good[..cut])
            .expect_err(&format!("truncation at {cut}/{} must fail", good.len()));
        assert_eq!(err.kind(), io::ErrorKind::InvalidData, "truncation at byte {cut}");
    }
    // Single-byte corruptions must never panic: either the reader
    // rejects them (InvalidData) or the flip landed in a value field and
    // restores to a (different) well-formed job.
    for pos in 0..good.len() {
        let mut bad = good.clone();
        bad[pos] ^= 0xA5;
        if let Err(e) = checkpoint::restore(&bad) {
            assert_eq!(
                e.kind(),
                io::ErrorKind::InvalidData,
                "corruption at byte {pos} must be InvalidData, got {e:?}"
            );
        }
    }
}

#[test]
fn deficit_counters_stay_bounded_and_no_job_starves() {
    // Adversarial mix: a tiny-R tenant, a greedy high-R multi-worker
    // tenant, and two mid-size tenants, under a budget that fits the
    // greedy job only barely (so it must bank several quanta per grant).
    let n = 64;
    let rounds = 400;
    let specs = vec![
        spec("tiny", "randk1b", 0.25, n, rounds, 1),
        spec("greedy", "qsgd", 4.0, n, rounds, 2).with_workers(2),
        spec("mid-a", "ndsc-dith", 1.0, n, rounds, 3),
        spec("mid-b", "sd", 1.0, n, rounds, 4),
    ];
    let greedy_cost = 2 * 4 * n as u64; // workers · ⌊nR⌋
    let budget = greedy_cost as usize + 64;
    let mut srv = JobServer::new(budget, Policy::Drr);
    let ids: Vec<_> = specs.into_iter().map(|s| srv.submit(s).unwrap()).collect();
    let jobs = ids.len() as u64;
    let quantum = (budget as u64 / jobs).max(1);
    // Starvation bound: once first in rotation with a full-budget round,
    // a job transmits as soon as its deficit covers its cost; accrual is
    // one quantum per round.
    let k_bound = jobs * (greedy_cost.div_ceil(quantum) + 1);

    let mut last_served: HashMap<u64, (u64, u64)> =
        ids.iter().map(|&id| (id, (0u64, 0u64))).collect(); // (rounds_served, fleet round)
    let window = 240u64;
    for fleet_round in 1..=window {
        srv.run_round();
        for (slot, &id) in ids.iter().enumerate() {
            if srv.state(id) != Some(JobState::Running) {
                continue;
            }
            let job = srv.job(id).unwrap();
            // Bounded deficit: never beyond the accrual cap.
            let deficit = srv.deficit_bits(id).unwrap();
            let cap = Deficit::cap(quantum, job.requested_cost_bits());
            assert!(
                deficit <= cap,
                "job {slot} deficit {deficit} exceeds cap {cap} at fleet round {fleet_round}"
            );
            // Starvation-freedom: every live job transmits within K.
            let served_now = srv.metrics().jobs[slot].rounds_served;
            let (served_before, since) = last_served[&id];
            if served_now > served_before {
                last_served.insert(id, (served_now, fleet_round));
            } else {
                assert!(
                    fleet_round - since <= k_bound,
                    "job {slot} not served for {} fleet rounds (bound {k_bound})",
                    fleet_round - since
                );
            }
        }
    }
    // Everyone made real progress, greedy included.
    for (slot, &id) in ids.iter().enumerate() {
        let served = srv.metrics().jobs[slot].rounds_served;
        assert!(served >= window / k_bound, "job {slot} served only {served} rounds");
    }
}

/// The four tenants plus four more — enough population that a 4-fleet
/// cluster puts work on every fleet. Costs stay within a 128-bit
/// per-fleet budget so the scarce variants stay admissible.
fn eight_tenants(n: usize, rounds: usize) -> Vec<JobSpec> {
    let mut v = four_tenants(n, rounds);
    v.push(spec("e-dith3w", "ndsc-dith", 1.0, n, rounds, 55).with_workers(3));
    v.push(spec("f-dith", "ndsc-dith", 0.5, n, rounds, 66));
    v.push(spec("g-def2w", "ndsc", 2.0, n, rounds, 77).with_workers(2).with_def_feedback());
    v.push(spec("h-sd", "sd", 1.0, n, rounds, 88));
    v
}

#[test]
fn multi_fleet_interleaved_serve_is_bit_identical_to_solo() {
    // The tentpole claim: tenants sharded across 4 concurrently-running
    // threaded fleets (worker fan-out armed cluster-wide) trace exactly
    // as solo inline runs — under an ample budget (every tenant served
    // every fleet round) and a scarce one (time-sliced, different
    // interleaving entirely).
    let n = 24;
    let rounds = 30;
    let solos: Vec<Trace> = eight_tenants(n, rounds).into_iter().map(solo_trace).collect();
    for budget in [1usize << 24, 128] {
        let mut cluster = FleetCluster::new(4, budget, Policy::Drr);
        let gids: Vec<_> =
            eight_tenants(n, rounds).into_iter().map(|s| cluster.submit(s).unwrap()).collect();
        let fleets_used: HashSet<usize> =
            gids.iter().map(|&g| cluster.fleet_of(g).unwrap()).collect();
        assert!(
            fleets_used.len() > 1,
            "placement must spread 8 tenants over several fleets, got {fleets_used:?}"
        );
        cluster.run(rounds * 64);
        for (i, &gid) in gids.iter().enumerate() {
            assert_eq!(
                cluster.state(gid),
                Some(JobState::Finished),
                "budget {budget}: job {i} must finish"
            );
            assert_trace_bit_identical(
                cluster.job(gid).unwrap().trace(),
                &solos[i],
                &format!("4-fleet cluster (budget {budget}) job {i}"),
            );
        }
        let m = cluster.metrics();
        assert_eq!(m.served_jobs, 8);
        assert_eq!(m.queued_jobs, 0);
        assert_eq!(m.rejected_jobs, 0);
        assert_eq!(m.served_job_rounds, 8 * rounds as u64);
    }
}

#[test]
fn fanout_fleet_matches_inline_fleet_bit_for_bit() {
    // Same fleet, same job, fan-out armed vs not: the threaded executor
    // behind `enable_fanout` must not perturb a single bit of the trace
    // (DEF feedback included — the memory contract at work).
    let n = 24;
    let rounds = 20;
    let mk = || spec("fan", "ndsc", 2.0, n, rounds, 91).with_workers(4).with_def_feedback();
    let inline_trace = solo_trace(mk()); // default fleet: no fan-out
    let mut srv = JobServer::new(1 << 24, Policy::Drr);
    srv.enable_fanout(1);
    let id = srv.submit(mk()).unwrap();
    srv.run(rounds + 4);
    assert_eq!(srv.state(id), Some(JobState::Finished));
    assert_trace_bit_identical(srv.job(id).unwrap().trace(), &inline_trace, "fan-out vs inline");
}

#[test]
fn mid_run_migration_preserves_traces_deficit_and_rung() {
    // Live migration: drain grant → snapshot → restore in the next fleet
    // over, for every tenant at once, mid-run under a scarce budget (so
    // deficits are mid-flight). Traces must equal uninterrupted solo
    // runs, and the scheduler state must survive the move.
    let n = 24;
    let rounds = 30;
    let tenants = four_tenants(n, rounds);
    let solos: Vec<Trace> = tenants.iter().cloned().map(solo_trace).collect();
    let mut cluster = FleetCluster::new(4, 128, Policy::Drr);
    let gids: Vec<_> = tenants.into_iter().map(|s| cluster.submit(s).unwrap()).collect();
    for _ in 0..7 {
        cluster.run_round();
    }
    for &gid in &gids {
        let from = cluster.fleet_of(gid).unwrap();
        let to = (from + 1) % cluster.fleet_count();
        let deficit = cluster.deficit_bits(gid).unwrap();
        let done = cluster.job(gid).unwrap().rounds_done();
        cluster.migrate(gid, to).unwrap();
        assert_eq!(cluster.fleet_of(gid), Some(to));
        assert_eq!(cluster.deficit_bits(gid), Some(deficit), "banked deficit survives the move");
        assert_eq!(cluster.job(gid).unwrap().rounds_done(), done, "no rounds lost in transit");
    }
    assert_eq!(cluster.metrics().migrated_jobs, gids.len() as u64);
    cluster.run(rounds * 64);
    for (i, &gid) in gids.iter().enumerate() {
        assert_eq!(cluster.state(gid), Some(JobState::Finished), "migrated job {i} must finish");
        assert_trace_bit_identical(
            cluster.job(gid).unwrap().trace(),
            &solos[i],
            &format!("mid-run migration, job {i}"),
        );
    }
}

#[test]
fn qos_classes_bias_service_without_starving_bronze() {
    // Two gold tenants and one bronze, identical 64-bit-cost jobs on a
    // 128-bit budget: weights 4/4/1 give gold ~4x bronze's accrual rate,
    // while bronze's reserved slice + rotation guarantee it still
    // transmits regularly. Property-check both directions over a window.
    let n = 64;
    let rounds = 400;
    let mut srv = JobServer::new(128, Policy::Drr);
    let mk = |name: &str, seed: u64, q: QosClass| {
        spec(name, "ndsc-dith", 1.0, n, rounds, seed).with_qos(q)
    };
    let ids = [
        srv.submit(mk("g1", 1, QosClass::Gold)).unwrap(),
        srv.submit(mk("g2", 2, QosClass::Gold)).unwrap(),
        srv.submit(mk("bz", 3, QosClass::Bronze)).unwrap(),
    ];
    let window = 120u64;
    let mut bronze_gap_max = 0u64;
    let mut bronze_last = (0u64, 0u64); // (rounds_served, fleet round)
    for round in 1..=window {
        srv.run_round();
        // Weighted deficit caps: each job's counter stays within the DRR
        // bound at its own weighted quantum.
        let total_w = 2 * QosClass::Gold.weight() + QosClass::Bronze.weight();
        for (slot, &id) in ids.iter().enumerate() {
            let q = srv.job(id).unwrap().spec().qos;
            let quantum = kashinflow::serve::scheduler::weighted_quantum(128, q.weight(), total_w);
            let cap = Deficit::cap(quantum, srv.job(id).unwrap().requested_cost_bits());
            assert!(
                srv.deficit_bits(id).unwrap() <= cap,
                "slot {slot} deficit beyond weighted cap at round {round}"
            );
        }
        let bz_served = srv.metrics().jobs[2].rounds_served;
        if bz_served > bronze_last.0 {
            bronze_last = (bz_served, round);
        } else {
            bronze_gap_max = bronze_gap_max.max(round - bronze_last.1);
        }
    }
    let gold = srv.metrics().jobs[0].rounds_served + srv.metrics().jobs[1].rounds_served;
    let bronze = srv.metrics().jobs[2].rounds_served;
    // No starvation: bronze keeps transmitting at its reserved rate...
    assert!(bronze >= window / 8, "bronze served only {bronze} of {window} rounds");
    assert!(bronze_gap_max <= 24, "bronze starved for {bronze_gap_max} consecutive rounds");
    // ...while gold's weight genuinely buys it more service.
    assert!(gold >= 3 * bronze, "gold ({gold}) should far outpace bronze ({bronze})");
    // Sanity: the budget can't have served more than 2 cost-64 jobs/round.
    assert!(gold + bronze <= 2 * window);
}

#[test]
fn async_epoch_serve_is_bit_identical_to_lockstep() {
    // The PR-8 tentpole claim: arbitrating E rounds of grants at a
    // barrier and executing them on the work-stealing pool yields
    // *exactly* the lockstep cluster's behaviour — per-job traces,
    // scheduler state and round counts — no matter how the horizon is
    // chunked into epochs or how the pool interleaves the work.
    let n = 24;
    let rounds = 30;
    for budget in [1usize << 24, 128] {
        let mut lockstep = FleetCluster::new(4, budget, Policy::Drr);
        let mut epoch = FleetCluster::new(4, budget, Policy::Drr);
        let gids: Vec<_> =
            eight_tenants(n, rounds).into_iter().map(|s| lockstep.submit(s).unwrap()).collect();
        let egids: Vec<_> =
            eight_tenants(n, rounds).into_iter().map(|s| epoch.submit(s).unwrap()).collect();
        assert_eq!(gids, egids, "identical submissions must place identically");

        // Mid-flight checkpoint: 24 lockstep rounds vs the same 24 as
        // unevenly chunked epochs. The schedulers must agree exactly
        // while deficits and partial progress are still in flight.
        for _ in 0..24 {
            lockstep.run_round();
        }
        for chunk in [1usize, 5, 10, 8] {
            epoch.run_epoch(chunk);
        }
        assert_eq!(lockstep.round(), epoch.round());
        for (i, &gid) in gids.iter().enumerate() {
            assert_eq!(
                lockstep.state(gid),
                epoch.state(gid),
                "budget {budget}: job {i} state diverged mid-flight"
            );
            assert_eq!(
                lockstep.deficit_bits(gid),
                epoch.deficit_bits(gid),
                "budget {budget}: job {i} banked deficit diverged mid-flight"
            );
            assert_eq!(
                lockstep.job(gid).unwrap().rounds_done(),
                epoch.job(gid).unwrap().rounds_done(),
                "budget {budget}: job {i} progress diverged mid-flight"
            );
        }

        // Finish both and compare whole traces bitwise.
        lockstep.run(rounds * 64);
        epoch.run_async(rounds * 64, 7);
        for (i, &gid) in gids.iter().enumerate() {
            assert_eq!(epoch.state(gid), Some(JobState::Finished), "epoch job {i} must finish");
            assert_trace_bit_identical(
                epoch.job(gid).unwrap().trace(),
                lockstep.job(gid).unwrap().trace(),
                &format!("epoch vs lockstep (budget {budget}) job {i}"),
            );
        }
        // Stealing is the epoch executor's prerogative; the lockstep
        // path must never report any.
        assert_eq!(lockstep.metrics().stolen_grants, 0);
    }
}

#[test]
fn work_stealing_epoch_accounting_identity_under_scarce_budget() {
    // The DRR/QoS ledger is part of the bit-identity contract: under a
    // scarce budget with the adaptive policy — banked deficits, rung
    // downgrades and QoS reservations all in play — the epoch
    // executor's accounting must match lockstep field-for-field, both
    // mid-flight and at the end.
    let n = 24;
    let rounds = 60;
    let tenants = || {
        eight_tenants(n, rounds).into_iter().enumerate().map(|(i, s)| match i % 3 {
            0 => s.with_qos(QosClass::Gold),
            1 => s.with_qos(QosClass::Bronze),
            _ => s,
        })
    };
    let mut lockstep = FleetCluster::new(4, 128, Policy::DrrAdaptive);
    let mut epoch = FleetCluster::new(4, 128, Policy::DrrAdaptive);
    let gids: Vec<_> = tenants().map(|s| lockstep.submit(s).unwrap()).collect();
    for s in tenants() {
        epoch.submit(s).unwrap();
    }

    let assert_ledgers_match = |lockstep: &FleetCluster, epoch: &FleetCluster, when: &str| {
        for i in 0..lockstep.fleet_count() {
            let (a, b) = (lockstep.fleet(i).metrics(), epoch.fleet(i).metrics());
            assert_eq!(a.fleet_rounds, b.fleet_rounds, "{when}: fleet {i} rounds");
            assert_eq!(
                a.spent_payload_bits, b.spent_payload_bits,
                "{when}: fleet {i} spent payload"
            );
            // The per-job CSV covers every JobBits row: id, name,
            // rounds_served, payload_bits, side_bits, bits/round.
            assert_eq!(a.to_csv(), b.to_csv(), "{when}: fleet {i} per-job accounting");
            for (x, y) in lockstep.fleet(i).job_ids().zip(epoch.fleet(i).job_ids()) {
                assert_eq!(
                    lockstep.fleet(i).deficit_bits(x),
                    epoch.fleet(i).deficit_bits(y),
                    "{when}: fleet {i} deficit"
                );
                assert_eq!(
                    lockstep.fleet(i).last_rung(x),
                    epoch.fleet(i).last_rung(y),
                    "{when}: fleet {i} adaptive rung"
                );
            }
        }
        let (ma, mb) = (lockstep.metrics(), epoch.metrics());
        assert_eq!(ma.served_job_rounds, mb.served_job_rounds, "{when}: cluster job rounds");
        assert_eq!(ma.spent_payload_bits, mb.spent_payload_bits, "{when}: cluster payload");
        assert_eq!(ma.served_jobs, mb.served_jobs, "{when}: served jobs");
        assert_eq!(ma.queued_jobs, mb.queued_jobs, "{when}: queued jobs");
    };

    // Mid-flight, while the scarce budget keeps deficits banked.
    for _ in 0..36 {
        lockstep.run_round();
    }
    for chunk in [2usize, 3, 13, 1, 17] {
        epoch.run_epoch(chunk);
    }
    assert_ledgers_match(&lockstep, &epoch, "mid-flight");

    // And after both executors drain the whole population.
    lockstep.run(rounds * 64);
    epoch.run_async(rounds * 64, 9);
    for (i, &gid) in gids.iter().enumerate() {
        assert_eq!(lockstep.state(gid), Some(JobState::Finished), "lockstep job {i}");
        assert_eq!(epoch.state(gid), Some(JobState::Finished), "epoch job {i}");
    }
    assert_ledgers_match(&lockstep, &epoch, "drained");
}

/// The eight tenants plus four same-generative-input twins (different
/// names only — names are not cache-key inputs), so a cached cluster
/// sees admission hits while every tenant still has a solo baseline.
fn twinned_tenants(n: usize, rounds: usize) -> Vec<JobSpec> {
    let mut v = eight_tenants(n, rounds);
    let twins: Vec<JobSpec> = four_tenants(n, rounds)
        .into_iter()
        .map(|mut s| {
            s.name = format!("twin-{}", s.name);
            s
        })
        .collect();
    v.extend(twins);
    v
}

#[test]
fn plan_cache_on_equals_cache_off_bit_for_bit() {
    // The cache changes where a ladder comes from, never what it
    // computes: the same population served with and without the plan
    // cache must agree bitwise, under an ample budget and a scarce one,
    // and the cached run must actually have exercised the cache.
    let n = 24;
    let rounds = 30;
    let solos: Vec<Trace> = twinned_tenants(n, rounds).into_iter().map(solo_trace).collect();
    for budget in [1usize << 24, 128] {
        let mut cached = FleetCluster::new(4, budget, Policy::Drr);
        let mut uncached = FleetCluster::new(4, budget, Policy::Drr);
        uncached.set_plan_cache_enabled(false);
        let gids: Vec<_> =
            twinned_tenants(n, rounds).into_iter().map(|s| cached.submit(s).unwrap()).collect();
        let ugids: Vec<_> =
            twinned_tenants(n, rounds).into_iter().map(|s| uncached.submit(s).unwrap()).collect();
        assert_eq!(gids, ugids);
        assert!(
            cached.plan_cache().hits() >= 4,
            "budget {budget}: the four twins must hit the cache at admission, got {}",
            cached.plan_cache().hits()
        );
        assert_eq!(uncached.plan_cache().hits() + uncached.plan_cache().misses(), 0);
        cached.run(rounds * 64);
        uncached.run(rounds * 64);
        for (i, &gid) in gids.iter().enumerate() {
            assert_eq!(cached.state(gid), Some(JobState::Finished), "cached job {i}");
            assert_eq!(uncached.state(gid), Some(JobState::Finished), "uncached job {i}");
            assert_trace_bit_identical(
                cached.job(gid).unwrap().trace(),
                &solos[i],
                &format!("cache-on vs solo (budget {budget}) job {i}"),
            );
            assert_trace_bit_identical(
                cached.job(gid).unwrap().trace(),
                uncached.job(gid).unwrap().trace(),
                &format!("cache-on vs cache-off (budget {budget}) job {i}"),
            );
        }
    }
}

#[test]
fn migration_through_the_plan_cache_preserves_traces() {
    // Autoscaler-churn shape: every tenant is checkpointed and restored
    // into the next fleet over. Admission populated the cache, so each
    // migration's restore must *hit* it — and the reused plan must leave
    // the continued traces exactly on the uninterrupted solo runs.
    let n = 24;
    let rounds = 30;
    let tenants = four_tenants(n, rounds);
    let solos: Vec<Trace> = tenants.iter().cloned().map(solo_trace).collect();
    let mut cluster = FleetCluster::new(4, 128, Policy::Drr);
    let gids: Vec<_> = tenants.into_iter().map(|s| cluster.submit(s).unwrap()).collect();
    assert_eq!(cluster.plan_cache().misses(), gids.len() as u64);
    for _ in 0..7 {
        cluster.run_round();
    }
    let hits_before = cluster.plan_cache().hits();
    for &gid in &gids {
        let to = (cluster.fleet_of(gid).unwrap() + 1) % cluster.fleet_count();
        cluster.migrate(gid, to).unwrap();
    }
    assert_eq!(cluster.metrics().migrated_jobs, gids.len() as u64);
    assert!(
        cluster.plan_cache().hits() >= hits_before + gids.len() as u64,
        "each migration's restore must reuse the admitted plan ({} hits for {} migrations)",
        cluster.plan_cache().hits() - hits_before,
        gids.len()
    );
    cluster.run(rounds * 64);
    for (i, &gid) in gids.iter().enumerate() {
        assert_eq!(cluster.state(gid), Some(JobState::Finished), "migrated job {i}");
        assert_trace_bit_identical(
            cluster.job(gid).unwrap().trace(),
            &solos[i],
            &format!("migration through the plan cache, job {i}"),
        );
    }
}

#[test]
fn batched_panels_are_bit_identical_to_per_job_panels() {
    // A skewed mix — runs of same-(n, workers) lightweight tenants that
    // the batched executor coalesces, broken up by heavy multi-worker
    // and odd-dimension tenants that must stay singleton panels — run
    // through ragged epochs with batching on vs off. Bit-identity of
    // traces and of the full accounting ledger is the claim.
    let rounds = 24;
    let mix = || {
        let mut v: Vec<JobSpec> = (0..6)
            .map(|i| spec(&format!("small{i}"), "ndsc-dith", 1.0, 16, rounds, 200 + i as u64))
            .collect();
        v.push(spec("wide", "ndsc", 2.0, 24, rounds, 300).with_workers(3));
        v.push(spec("odd", "sd", 0.5, 32, rounds, 301));
        v.extend(
            (0..4).map(|i| {
                spec(&format!("tail{i}"), "ndsc-dith", 0.5, 16, rounds, 400 + i as u64)
            }),
        );
        v
    };
    let solos: Vec<Trace> = mix().into_iter().map(solo_trace).collect();
    let mut batched = FleetCluster::new(4, 1 << 24, Policy::Drr);
    let mut perjob = FleetCluster::new(4, 1 << 24, Policy::Drr);
    perjob.set_epoch_batching(false);
    let gids: Vec<_> = mix().into_iter().map(|s| batched.submit(s).unwrap()).collect();
    for s in mix() {
        perjob.submit(s).unwrap();
    }
    for chunk in [3usize, 1, 7, 5, 8] {
        batched.run_epoch(chunk);
        perjob.run_epoch(chunk);
    }
    batched.run_async(rounds * 64, 6);
    perjob.run_async(rounds * 64, 6);
    for (i, &gid) in gids.iter().enumerate() {
        assert_eq!(batched.state(gid), Some(JobState::Finished), "batched job {i}");
        assert_eq!(perjob.state(gid), Some(JobState::Finished), "per-job job {i}");
        assert_trace_bit_identical(
            batched.job(gid).unwrap().trace(),
            &solos[i],
            &format!("batched panels vs solo, job {i}"),
        );
        assert_trace_bit_identical(
            batched.job(gid).unwrap().trace(),
            perjob.job(gid).unwrap().trace(),
            &format!("batched vs per-job panels, job {i}"),
        );
    }
    for i in 0..batched.fleet_count() {
        assert_eq!(
            batched.fleet(i).metrics().to_csv(),
            perjob.fleet(i).metrics().to_csv(),
            "fleet {i} accounting must not notice batching"
        );
    }
}

#[test]
fn evicted_plan_rebuilds_bit_identically() {
    // An LRU cap sized for exactly one plan: every same-shape admission
    // evicts the previous entry, so the "rebuilt after eviction" path
    // runs on every submit — and must produce the same job bit-for-bit
    // as the plan it replaced.
    let n = 24;
    let rounds = 20;
    let mk = |name: &str, seed: u64| spec(name, "ndsc-dith", 1.0, n, rounds, seed);
    // Probe the resident size of one such plan through a roomy cache.
    let probe = std::sync::Arc::new(kashinflow::serve::PlanCache::new(usize::MAX >> 1));
    let mut sizer = JobServer::new(1 << 24, Policy::Drr);
    sizer.set_plan_cache(Some(probe.clone()));
    sizer.submit(mk("probe", 1)).unwrap();
    let one = probe.resident_bytes() as usize;
    assert!(one > 0, "a built plan must report a nonzero resident footprint");

    let cache = std::sync::Arc::new(kashinflow::serve::PlanCache::new(one));
    let mut srv = JobServer::new(1 << 24, Policy::Drr);
    srv.set_plan_cache(Some(cache.clone()));
    let a = srv.submit(mk("a", 1)).unwrap();
    let b = srv.submit(mk("b", 2)).unwrap(); // same shape, new seed: evicts a's plan
    let a2 = srv.submit(mk("a-again", 1)).unwrap(); // evicted: must rebuild, not hit
    assert_eq!(cache.misses(), 3, "the one-plan cap forces a rebuild on every admission");
    assert_eq!(cache.hits(), 0);
    assert_eq!(cache.len(), 1);
    assert!(cache.resident_bytes() as usize <= one);
    srv.run(rounds * 8);
    for id in [a, b, a2] {
        assert_eq!(srv.state(id), Some(JobState::Finished));
    }
    let solo = solo_trace(mk("solo", 1));
    assert_trace_bit_identical(srv.job(a).unwrap().trace(), &solo, "through-cache build");
    assert_trace_bit_identical(
        srv.job(a2).unwrap().trace(),
        &solo,
        "rebuild after LRU eviction",
    );
}

#[test]
fn adaptive_policy_admits_and_downgrades_what_strict_drr_cannot() {
    let n = 64;
    // Greedy tenant at R=4 costs 256 bits/round; offer only 160.
    let s = || spec("greedy", "qsgd", 4.0, n, 60, 8);
    let mut strict = JobServer::new(160, Policy::Drr);
    assert!(strict.submit(s()).is_err(), "strict DRR cannot admit a 256-bit job on 160 bits");
    let mut adaptive = JobServer::new(160, Policy::DrrAdaptive);
    let id = adaptive.submit(s()).unwrap();
    adaptive.run(400);
    assert_eq!(adaptive.state(id), Some(JobState::Finished));
    let job = adaptive.job(id).unwrap();
    // Every served round fits the deeper rung: measured payload per
    // round is bounded by the downgraded level's nominal cost.
    let per_round_max =
        job.trace().records.iter().map(|r| r.payload_bits).max().unwrap_or(0) as u64;
    assert!(per_round_max > 0);
    assert!(
        per_round_max <= job.ladder()[1].cost_bits,
        "served rounds must fit the downgraded budget ({per_round_max} vs {})",
        job.ladder()[1].cost_bits
    );
}
