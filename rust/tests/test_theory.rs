//! Cross-module property tests of the paper's theoretical claims
//! (Theorem 1, Lemma 4, Theorems 2/3 threshold behaviour), using the
//! in-tree property harness.

use kashinflow::linalg::frames::{Frame, HadamardFrame, OrthonormalFrame};
use kashinflow::linalg::rng::Rng;
use kashinflow::linalg::vecops::{dist2, norm2};
use kashinflow::quant::dsc::{CodecMode, EmbedKind, SubspaceCodec};
use kashinflow::quant::Compressor;
use kashinflow::testkit::prop::{forall, gen, Cases};

/// Theorem 1 (NDSC branch): ‖y − Q_nd(y)‖ ≤ 2^{2−R/λ}·√log(2N)·‖y‖ for
/// every input shape the generator produces.
#[test]
fn theorem1_ndsc_bound_holds_for_all_inputs() {
    forall(Cases::new("thm1 ndsc", 60), |rng: &mut Rng, _| {
        let n = gen::dim(rng);
        let r = gen::bit_budget(rng);
        let frame = HadamardFrame::new(n, rng);
        let big_n = frame.big_n();
        let lambda = frame.lambda();
        let codec = SubspaceCodec::new(
            Box::new(frame),
            EmbedKind::NearDemocratic,
            CodecMode::Deterministic,
            r,
        );
        let y = gen::nonzero_vector(rng, n);
        let msg = codec.compress(&y, rng);
        let yhat = codec.decompress(&msg);
        // Thm 1 uses R/λ bits per embedding coordinate; our allocation is
        // floor-based, so compare against the bound with the *actual*
        // minimum per-coordinate width (conservative by <= 1 bit).
        let eff_bits = (kashinflow::quant::budget_bits(n, r) / big_n) as f32;
        let bound =
            (2.0f32).powf(2.0 - eff_bits) * ((2.0 * big_n as f32).ln()).sqrt() * norm2(&y);
        let err = dist2(&yhat, &y);
        assert!(
            err <= bound * 1.05 + 1e-5,
            "n={n} R={r} λ={lambda}: err {err} > bound {bound}"
        );
    });
}

/// Lemma 4: measured covering efficiency of NDSC ≈ 2^{2+R(1−1/λ)}√log(2N),
/// i.e. dimension-*poly-log*; the naive scalar quantizer's is Θ(√n).
#[test]
fn lemma4_covering_efficiency_scaling() {
    let mut rng = Rng::seed_from(5);
    let r = 2.0f32;
    let mut ndsc_eff = Vec::new();
    let mut naive_eff = Vec::new();
    for &n in &[64usize, 256, 1024] {
        // covering efficiency ~ |range|^{1/n} * d(Q)/r: with |range| = 2^{nR},
        // measure worst-case relative error over draws as d(Q)/r proxy.
        let frame = HadamardFrame::new(n, &mut rng);
        let codec = SubspaceCodec::new(
            Box::new(frame),
            EmbedKind::NearDemocratic,
            CodecMode::Deterministic,
            r,
        );
        let naive = kashinflow::quant::gain_shape::NaiveUniform::new(n, r);
        let worst = |c: &dyn Compressor, rng: &mut Rng| -> f32 {
            let mut w = 0.0f32;
            for _ in 0..15 {
                let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
                let msg = c.compress(&y, rng);
                let e = dist2(&c.decompress(&msg), &y) / norm2(&y);
                w = w.max(e);
            }
            w
        };
        ndsc_eff.push((2.0f32).powf(r) * worst(&codec, &mut rng));
        naive_eff.push((2.0f32).powf(r) * worst(&naive, &mut rng));
    }
    // NDSC efficiency grows at most poly-log in n; naive grows ~sqrt(n)
    // (x4 from n=64 to n=1024).
    let ndsc_growth = ndsc_eff[2] / ndsc_eff[0];
    let naive_growth = naive_eff[2] / naive_eff[0];
    assert!(ndsc_growth < 2.0, "NDSC covering efficiency grew {ndsc_growth}x");
    assert!(naive_growth > 2.0, "naive should show sqrt(n) growth, got {naive_growth}x");
}

/// Kashin-constant sanity across frame families (Appendix J): orthonormal
/// λ=2 gives a small constant; the measured constant does not blow up
/// with n.
#[test]
fn appendix_j_kashin_constants() {
    use kashinflow::embed::democratic::{empirical_kashin_constant, KashinSolver};
    let mut rng = Rng::seed_from(6);
    let mut by_n = Vec::new();
    for &n in &[32usize, 128, 512] {
        let frame = HadamardFrame::with_big_n(n, 2 * n.next_power_of_two(), &mut rng);
        let mut solver = KashinSolver::for_frame(&frame);
        by_n.push(empirical_kashin_constant(&frame, &mut solver, 8, &mut rng));
    }
    for (i, &k) in by_n.iter().enumerate() {
        assert!(k < 8.0, "K_u[{i}] = {k} too large");
    }
    assert!(by_n[2] < by_n[0] * 2.5, "K_u should not grow with n: {by_n:?}");
}

/// The dithered codec stays unbiased across dimensions/budgets — the
/// Theorem 3 prerequisite — including the sub-linear regime.
#[test]
fn theorem3_unbiasedness_everywhere() {
    forall(Cases::new("thm3 unbiased", 6), |rng: &mut Rng, _| {
        let n = [16usize, 30, 64][rng.below(3)];
        let r = [0.25f32, 0.5, 1.0, 2.0][rng.below(4)];
        let frame = OrthonormalFrame::with_big_n(n, n, rng);
        let codec =
            SubspaceCodec::new(Box::new(frame), EmbedKind::NearDemocratic, CodecMode::Dithered, r);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let trials = 4000;
        let mut mean = vec![0.0f64; n];
        for _ in 0..trials {
            let yhat = codec.decompress(&codec.compress(&y, rng));
            for (m, &v) in mean.iter_mut().zip(&yhat) {
                *m += v as f64 / trials as f64;
            }
        }
        let mean_f: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
        let bias = dist2(&mean_f, &y) / norm2(&y);
        assert!(bias < 0.15, "n={n} R={r}: bias {bias}");
    });
}

/// Theorem 2 rate regression: on a planted least-squares instance,
/// DGD-DEF's *measured* linear rate must sit at or below the theorem's
/// `max{ν, β}` (ν = σ = (L−μ)/(L+μ) at the optimal step, β the codec's
/// Theorem-1 error factor), up to a small empirical tolerance. Run across
/// several budgets so both regimes (β-dominated and ν-dominated) are
/// exercised.
#[test]
fn theorem2_dgd_def_rate_at_most_max_nu_beta() {
    let mut rng = Rng::seed_from(21);
    let n = 64;
    let (obj, _) = kashinflow::data::synthetic::planted_regression(
        128,
        n,
        kashinflow::data::synthetic::Tail::Gaussian,
        kashinflow::data::synthetic::Tail::Gaussian,
        0.05,
        &mut rng,
    );
    let xs = obj.quadratic_minimizer();
    let (l, mu) = obj.smoothness_strong_convexity();
    let nu = kashinflow::opt::gd::sigma(l, mu);
    let opts = kashinflow::opt::dgd_def::DgdDefOptions::optimal(l, mu, 150);
    for r in [4.0f32, 6.0, 8.0] {
        let frame = HadamardFrame::new(n, &mut rng);
        let codec = SubspaceCodec::new(
            Box::new(frame),
            EmbedKind::NearDemocratic,
            CodecMode::Deterministic,
            r,
        );
        let beta = codec.beta();
        let trace =
            kashinflow::opt::dgd_def::run(&obj, &codec, &vec![0.0; n], Some(&xs), opts, &mut rng);
        let rate = trace.empirical_rate();
        let bound = nu.max(beta);
        assert!(
            rate <= bound + 0.05,
            "R={r}: empirical rate {rate} exceeds max(ν={nu}, β={beta}) + 0.05"
        );
        assert!(rate < 1.0, "R={r}: DGD-DEF failed to converge (rate {rate})");
    }
}

/// Theorem 3 rate regression: with the theorem's `α ∝ √(min{R,1}/T)`
/// step, DQ-PSGD's optimality gap must decay consistently with
/// `O(1/√T)` across T ∈ {200, 800, 3200} — the gap shrinks as T grows,
/// and the √T-normalized constant `gap·√T` stays within a narrow band
/// (a linear-rate or a stalled method would both leave the band).
#[test]
fn theorem3_dq_psgd_gap_decays_like_inv_sqrt_t() {
    use kashinflow::opt::dq_psgd::{self, DqPsgdOptions};
    use kashinflow::opt::oracle::{MinibatchOracle, Oracle};
    use kashinflow::opt::projection::Domain;

    let mut rng = Rng::seed_from(31);
    let n = 30;
    let (obj, _) = kashinflow::data::synthetic::planted_regression(
        120,
        n,
        kashinflow::data::synthetic::Tail::Gaussian,
        kashinflow::data::synthetic::Tail::Gaussian,
        0.05,
        &mut rng,
    );
    let xs = obj.quadratic_minimizer();
    let f_star = obj.value(&xs);
    let radius = 2.0 * norm2(&xs).max(1.0);
    let domain = Domain::L2Ball { radius };
    // Crude empirical subgradient bound B over the ball (Theorem 3 takes
    // it as given; only the constant in C/√T depends on it).
    let b_est = {
        let mut probe_rng = Rng::seed_from(32);
        let mut oracle = MinibatchOracle::new(&obj, 10, Rng::seed_from(33));
        let mut g = vec![0.0f32; n];
        let mut worst = 1e-3f32;
        for _ in 0..50 {
            let x: Vec<f32> =
                (0..n).map(|_| probe_rng.gaussian_f32() * radius / (n as f32).sqrt()).collect();
            oracle.query(&x, &mut g);
            worst = worst.max(norm2(&g));
        }
        worst
    };
    let r = 1.0f32;
    let ts = [200usize, 800, 3200];
    let mut gaps = Vec::new();
    for &t in &ts {
        let mut run_rng = Rng::seed_from(41);
        let codec = kashinflow::quant::ndsc::Ndsc::hadamard_dithered(n, r, &mut run_rng);
        let mut oracle = MinibatchOracle::new(&obj, 10, Rng::seed_from(43));
        let opts = DqPsgdOptions::theory(2.0 * radius, b_est, r, 1.0, t, domain);
        let trace =
            dq_psgd::run(&obj, &mut oracle, &codec, &vec![0.0; n], Some(&xs), opts, &mut run_rng);
        let gap = (trace.final_value() - f_star).max(1e-7);
        gaps.push(gap);
    }
    // Decay: more iterations (with the matched smaller step) never hurts
    // by more than noise, and 16x iterations must show real progress.
    assert!(gaps[1] < gaps[0] * 1.15, "gap(800) {} vs gap(200) {}", gaps[1], gaps[0]);
    assert!(gaps[2] < gaps[1] * 1.15, "gap(3200) {} vs gap(800) {}", gaps[2], gaps[1]);
    assert!(gaps[2] < gaps[0] * 0.75, "no 1/√T-scale progress: {gaps:?}");
    // √T-normalized constants within a factor-8 band.
    let cs: Vec<f32> =
        gaps.iter().zip(&ts).map(|(&g, &t)| g * (t as f32).sqrt()).collect();
    let cmax = cs.iter().fold(0.0f32, |a, &b| a.max(b));
    let cmin = cs.iter().fold(f32::INFINITY, |a, &b| a.min(b));
    assert!(
        cmax / cmin < 8.0,
        "gap·√T drifts by {}x across T — inconsistent with O(1/√T): gaps {gaps:?}",
        cmax / cmin
    );
}

/// DGD-DEF threshold budget (Thm 2 / Fig. 1b): against the paper's actual
/// DQGD baseline (a predefined decaying dynamic-range schedule, [6]),
/// NDSC converges strictly faster at low budgets, and the gap shrinks as
/// R grows (both approach σ).
#[test]
fn theorem2_threshold_budget_gap() {
    let mut rng = Rng::seed_from(7);
    let n = 64;
    let (obj, _) = kashinflow::data::synthetic::planted_regression(
        128,
        n,
        kashinflow::data::synthetic::Tail::GaussianCubed,
        kashinflow::data::synthetic::Tail::Gaussian,
        0.05,
        &mut rng,
    );
    let xs = obj.quadratic_minimizer();
    let (l, mu) = obj.smoothness_strong_convexity();
    let sigma = kashinflow::opt::gd::sigma(l, mu);
    let opts = kashinflow::opt::dgd_def::DgdDefOptions::optimal(l, mu, 100);
    let mut g0 = vec![0.0f32; n];
    obj.gradient(&vec![0.0; n], &mut g0);
    let r0 = 2.0 * kashinflow::linalg::vecops::norm_inf(&g0);
    let rate = |c: &dyn kashinflow::quant::Compressor, rng: &mut Rng| {
        kashinflow::opt::dgd_def::run(obj_ref(&obj), c, &vec![0.0; n], Some(&xs), opts, rng)
            .empirical_rate()
    };
    fn obj_ref(
        o: &kashinflow::opt::objectives::DatasetObjective,
    ) -> &kashinflow::opt::objectives::DatasetObjective {
        o
    }
    let mut gaps = Vec::new();
    for r in [1.0f32, 2.0, 6.0] {
        let ndsc = kashinflow::quant::ndsc::Ndsc::hadamard(n, r, &mut rng);
        let dqgd = kashinflow::quant::dqgd::DqgdRange::new(n, r, r0, sigma);
        let r_ndsc = rate(&ndsc, &mut rng);
        let r_dqgd = rate(&dqgd, &mut rng);
        gaps.push((r, r_dqgd - r_ndsc, r_ndsc));
    }
    // Low budget: a clear gap; NDSC always convergent.
    assert!(gaps[0].1 > 0.003, "no low-R gap: {gaps:?}");
    assert!(gaps.iter().all(|&(_, _, rn)| rn < 1.0), "NDSC diverged: {gaps:?}");
    // High budget: both near sigma, gap collapses.
    assert!(gaps[2].1 < gaps[0].1, "gap should shrink with R: {gaps:?}");
    assert!(gaps[2].2 <= sigma + 0.02);
}
