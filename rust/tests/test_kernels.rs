//! Fused-kernel equivalence tier (ISSUE 6).
//!
//! For every scheme with a fused fast path — the Hadamard-frame
//! `SubspaceCodec` family (`dsc`/`ndsc`, deterministic and dithered) —
//! the fused workspace API must be **bit-for-bit** identical to the
//! unfused scalar reference (`compress_reference_into` /
//! `decompress_reference_into`): wire bytes, bit accounting, RNG
//! consumption and decoded floats. All calls share ONE dirty workspace
//! and message shells that are never cleared between grid points, so any
//! hidden dependence on pre-zeroed scratch shows up as a byte mismatch.
//!
//! The multi-threaded-FWHT ↔ single-threaded bitwise equality at the
//! `MT_FWHT_MIN_DIM` boundaries lives in the `linalg::fwht` module tests;
//! here the threshold crossing is exercised end-to-end through a codec
//! whose embedding dimension sits exactly at the threshold.

use kashinflow::coordinator::config::MT_FWHT_MIN_DIM;
use kashinflow::linalg::frames::HadamardFrame;
use kashinflow::linalg::rng::Rng;
use kashinflow::quant::dsc::{CodecMode, EmbedKind, SubspaceCodec};
use kashinflow::quant::{Compressed, Compressor, Workspace};

fn codec(n: usize, embed: EmbedKind, mode: CodecMode, r: f32, seed: u64) -> SubspaceCodec {
    let mut rng = Rng::seed_from(seed);
    SubspaceCodec::new(Box::new(HadamardFrame::new(n, &mut rng)), embed, mode, r)
}

/// The equivalence test vectors: heavy-tailed, Gaussian, one-hot
/// (worst case for quantizers), constant, and all-zero (the gain-0
/// early-out).
fn vectors(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::seed_from(seed);
    let heavy: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
    let gauss: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
    let mut one_hot = vec![0.0f32; n];
    one_hot[n / 3] = 7.5;
    vec![heavy, gauss, one_hot, vec![1.0; n], vec![0.0; n]]
}

fn assert_bitwise_eq(a: &[f32], b: &[f32], what: &str) {
    let mism = a.iter().zip(b).filter(|(x, y)| x.to_bits() != y.to_bits()).count();
    assert_eq!(mism, 0, "{what}: {mism} coordinates differ bitwise");
}

/// One round-trip through both paths on the shared dirty state; panics on
/// any bit-level divergence.
#[allow(clippy::too_many_arguments)]
fn check_equivalence(
    c: &SubspaceCodec,
    y: &[f32],
    seed: u64,
    ws: &mut Workspace,
    msg_ref: &mut Compressed,
    msg_fused: &mut Compressed,
    dec_ref: &mut Vec<f32>,
    dec_fused: &mut Vec<f32>,
    label: &str,
) {
    // Twin RNGs: the dither draws must consume identically on both paths.
    let mut rng_ref = Rng::seed_from(seed);
    let mut rng_fused = Rng::seed_from(seed);
    c.compress_reference_into(y, &mut rng_ref, ws, msg_ref);
    c.compress_into(y, &mut rng_fused, ws, msg_fused);
    assert_eq!(msg_ref.bytes, msg_fused.bytes, "{label}: wire bytes diverge");
    assert_eq!(msg_ref.payload_bits, msg_fused.payload_bits, "{label}: payload accounting");
    assert_eq!(msg_ref.side_bits, msg_fused.side_bits, "{label}: side accounting");
    assert_eq!(rng_ref.state(), rng_fused.state(), "{label}: RNG consumption diverges");
    let n = y.len();
    dec_ref.resize(n, 0.0);
    dec_fused.resize(n, 0.0);
    c.decompress_reference_into(msg_ref, ws, dec_ref);
    c.decompress_into(msg_fused, ws, dec_fused);
    assert_bitwise_eq(dec_ref, dec_fused, label);
    // Cross-decode: the fused decoder on reference bytes (and vice versa)
    // must also agree — the wire format carries no path fingerprint.
    c.decompress_into(msg_ref, ws, dec_fused);
    assert_bitwise_eq(dec_ref, dec_fused, &format!("{label} (cross-decode)"));
}

#[test]
fn fused_paths_bit_identical_to_reference_on_dirty_shared_workspace() {
    // ONE workspace + shells for the whole grid: never cleared, resized
    // up and down as n changes — deliberately dirty.
    let mut ws = Workspace::default();
    let mut msg_ref = Compressed::empty(1);
    let mut msg_fused = Compressed::empty(1);
    let (mut dec_ref, mut dec_fused) = (Vec::new(), Vec::new());
    let mut case = 0u64;
    for embed in [EmbedKind::NearDemocratic, EmbedKind::Democratic] {
        for mode in [CodecMode::Deterministic, CodecMode::Dithered] {
            for &n in &[64usize, 100, 1024, 4096] {
                if embed == EmbedKind::Democratic && n > 1024 {
                    // The LV iteration is O(rounds·N log N); cap it to keep
                    // tier-1 fast. The frame/quantizer fusion under test is
                    // identical across embeds.
                    continue;
                }
                for &r in &[0.5f32, 2.0] {
                    let c = codec(n, embed, mode, r, 40 + case);
                    for (vi, y) in vectors(n, 90 + case).iter().enumerate() {
                        let label = format!("{embed:?}/{mode:?} n={n} R={r} vec#{vi}");
                        check_equivalence(
                            &c,
                            y,
                            7000 + case * 16 + vi as u64,
                            &mut ws,
                            &mut msg_ref,
                            &mut msg_fused,
                            &mut dec_ref,
                            &mut dec_fused,
                            &label,
                        );
                    }
                    case += 1;
                }
            }
        }
    }
}

/// End-to-end threshold crossing: a codec whose embedding dimension N is
/// exactly `MT_FWHT_MIN_DIM`, so the fused path's transforms dispatch to
/// the multi-threaded kernel while the reference path stays scalar — the
/// wire bytes must still match bit-for-bit.
#[test]
fn fused_mt_codec_bit_identical_to_scalar_reference_at_threshold() {
    let n = MT_FWHT_MIN_DIM; // power of two => N == n == the threshold
    let c = codec(n, EmbedKind::NearDemocratic, CodecMode::Deterministic, 0.5, 3);
    let mut ws = Workspace::for_compressor(&c);
    let mut msg_ref = Compressed::empty(n);
    let mut msg_fused = Compressed::empty(n);
    let (mut dec_ref, mut dec_fused) = (Vec::new(), Vec::new());
    let mut gen = Rng::seed_from(11);
    let y: Vec<f32> = (0..n).map(|_| gen.gaussian_cubed()).collect();
    check_equivalence(
        &c,
        &y,
        77,
        &mut ws,
        &mut msg_ref,
        &mut msg_fused,
        &mut dec_ref,
        &mut dec_fused,
        "ndsc-det at MT threshold",
    );
}
