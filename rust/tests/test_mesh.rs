//! Mesh-engine acceptance: seed-deterministic gossip traces that are
//! bit-identical across runs *and* thread counts, consensus convergence
//! of the fp32 reference and its lossy R = 1 twin on the strongly
//! convex planted problem, the per-edge feedback invariants (exactly
//! zero under a lossless codec; frozen while a link is down), and exact
//! per-link wire accounting against `protocol::upload_wire_bytes`.

use kashinflow::coordinator::protocol::UPLOAD_HEADER_BITS;
use kashinflow::coordinator::transport::Topology;
use kashinflow::linalg::rng::Rng;
use kashinflow::linalg::vecops::matvec;
use kashinflow::mesh::{link_up, run_sharded, MeshConfig, MeshDriver, MeshMetrics};
use kashinflow::opt::engine::oracle::ExactGrad;
use kashinflow::opt::engine::schedule::Schedule;
use kashinflow::opt::multi::ShardedProblem;
use kashinflow::opt::objectives::{DatasetObjective, Loss};
use kashinflow::quant::registry::CompressorSpec;

/// A consistent planted least-squares problem: every shard is generated
/// from the **same** planted `x*` with noiseless labels, so all local
/// minimizers coincide, `f* = 0`, and exact consensus at the optimum is
/// reachable even with a constant step. Plain Gaussian rows keep the
/// conditioning mild (`s = 3n` rows per shard).
fn consistent_problem(m: usize, n: usize, seed: u64) -> ShardedProblem {
    let s = 3 * n;
    let mut rng = Rng::seed_from(seed);
    let x_star: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
    let shards: Vec<DatasetObjective> = (0..m)
        .map(|_| {
            let a: Vec<f32> = (0..s * n).map(|_| rng.gaussian_f32()).collect();
            let mut b = vec![0.0f32; s];
            matvec(&a, s, n, &x_star, &mut b);
            DatasetObjective::new(a, b, s, n, Loss::Square, 0.0)
        })
        .collect();
    ShardedProblem::new(shards)
}

fn cfg_for(
    prob: &ShardedProblem,
    topology: Topology,
    scheme: &str,
    r: f32,
    rounds: usize,
    seed: u64,
) -> MeshConfig {
    let scheme = CompressorSpec::parse(scheme).expect("registry scheme");
    let mut cfg = MeshConfig::new(prob.m(), prob.n, topology, scheme, r, seed);
    cfg.schedule = Schedule::Constant(prob.stable_step());
    cfg.rounds = rounds;
    cfg
}

/// Everything a mesh run reports, flattened to exact bit patterns:
/// per-round consensus/value/bytes, per-link tallies, per-node bits and
/// the final mean iterate.
fn fingerprint(m: &MeshMetrics) -> Vec<u64> {
    let mut f = Vec::new();
    for r in &m.rounds {
        f.push(u64::from(r.consensus.to_bits()));
        f.push(u64::from(r.value.to_bits()));
        f.push(r.wire_bytes);
    }
    for l in &m.per_link {
        f.extend([l.a as u64, l.b as u64, l.bytes, l.delivered, l.dropped]);
    }
    f.extend(m.node_wire_bits.iter().copied());
    f.extend(m.final_mean.iter().map(|v| u64::from(v.to_bits())));
    f
}

#[test]
fn same_seed_traces_are_bit_identical_across_runs_and_thread_counts() {
    let prob = consistent_problem(5, 16, 11);
    let run = |threads: usize, seed: u64| {
        let mut cfg = cfg_for(&prob, Topology::Ring, "ndsc-dith", 1.0, 40, seed);
        cfg.threads = threads;
        cfg.link.drop_prob = 0.2; // exercise the pause path too
        run_sharded(cfg, &prob).unwrap()
    };
    let base = fingerprint(&run(1, 42));
    assert_eq!(base, fingerprint(&run(1, 42)), "same-seed rerun must be bit-identical");
    assert_eq!(base, fingerprint(&run(2, 42)), "threads=2 must not change the trace");
    assert_eq!(base, fingerprint(&run(4, 42)), "threads=4 must not change the trace");
    assert_ne!(base, fingerprint(&run(1, 43)), "the seed must actually steer the run");
}

#[test]
fn fp32_gossip_on_a_ring_converges_to_consensus_at_the_optimum() {
    let prob = consistent_problem(4, 16, 5);
    let cfg = cfg_for(&prob, Topology::Ring, "fp32", 32.0, 1200, 9);
    let m = run_sharded(cfg, &prob).unwrap();
    let first = m.rounds.first().unwrap().value;
    assert!(
        m.final_consensus < 1e-3,
        "fp32 ring consensus distance {} should vanish",
        m.final_consensus
    );
    assert!(
        m.final_value < 1e-4 * first.max(1.0),
        "objective {} barely moved from {first}",
        m.final_value
    );
}

/// The ISSUE acceptance bar: ring topology, a lossy registry scheme at
/// R = 1, consensus distance within 1e-3 of the fp32 twin's final
/// objective gap (`f* = 0` on the consistent problem).
#[test]
fn lossy_ring_gossip_at_r1_matches_its_fp32_twin() {
    let prob = consistent_problem(4, 16, 5);
    let run = |scheme: &str, r: f32| {
        let mut cfg = cfg_for(&prob, Topology::Ring, scheme, r, 2000, 21);
        cfg.gamma = 0.4;
        run_sharded(cfg, &prob).unwrap()
    };
    let lossy = run("ndsc-dith", 1.0);
    let twin = run("fp32", 32.0);
    assert!(
        lossy.final_consensus <= twin.final_value + 1e-3,
        "lossy consensus {} vs fp32 twin gap {}",
        lossy.final_consensus,
        twin.final_value
    );
    assert!(
        lossy.final_value < 1e-2,
        "the lossy run must also optimize: f(x_bar) = {}",
        lossy.final_value
    );
    // And at 32x fewer payload bits per message, the wire story holds.
    assert!(lossy.total_wire_bytes() < twin.total_wire_bytes() / 8);
}

#[test]
fn lossless_codec_keeps_every_edge_memory_exactly_zero() {
    let prob = consistent_problem(4, 8, 3);
    let mut cfg = cfg_for(&prob, Topology::Ring, "fp32", 32.0, 30, 17);
    cfg.link.drop_prob = 0.3; // pausing must not disturb the invariant
    let oracles: Vec<ExactGrad<'_>> = prob.shards.iter().map(|s| ExactGrad { obj: s }).collect();
    let x0 = vec![0.0f32; prob.n];
    let mut drv = MeshDriver::new(cfg, oracles, &x0).unwrap();
    for _ in 0..30 {
        drv.step(&|x| prob.value(x));
    }
    for i in 0..prob.m() {
        for slot in 0..drv.graph().degree(i) {
            let state = drv.edge_feedback_state(i, slot);
            assert_eq!(state.len(), prob.n);
            assert!(
                state.iter().all(|&v| v == 0.0),
                "fp32 per-edge feedback must stay exactly zero (node {i}, slot {slot})"
            );
        }
    }
}

#[test]
fn dropped_link_rounds_leave_the_paused_memory_untouched() {
    let prob = consistent_problem(4, 8, 3);
    let mut cfg = cfg_for(&prob, Topology::Ring, "ndsc-dith", 1.0, 60, 23);
    cfg.link.drop_prob = 0.5;
    let seed = cfg.seed;
    let link = cfg.link;
    let oracles: Vec<ExactGrad<'_>> = prob.shards.iter().map(|s| ExactGrad { obj: s }).collect();
    let x0 = vec![0.0f32; prob.n];
    let mut drv = MeshDriver::new(cfg, oracles, &x0).unwrap();
    let edge = drv.graph().edge_of[0][0];
    let (mut ups, mut downs, mut changed_when_up) = (0u32, 0u32, 0u32);
    for round in 0..60u64 {
        let fb_before = drv.edge_feedback_state(0, 0);
        let est_before = drv.estimate_out(0, 0).to_vec();
        let was_up = link_up(seed, round, edge, &link);
        drv.step(&|x| prob.value(x));
        if was_up {
            ups += 1;
            if drv.edge_feedback_state(0, 0) != fb_before {
                changed_when_up += 1;
            }
        } else {
            downs += 1;
            assert_eq!(
                drv.edge_feedback_state(0, 0),
                fb_before,
                "round {round}: paused edge memory must stay untouched"
            );
            assert_eq!(
                drv.estimate_out(0, 0),
                &est_before[..],
                "round {round}: paused replicas must stay untouched"
            );
        }
    }
    assert!(ups > 0 && downs > 0, "drop 0.5 over 60 rounds must see both verdicts");
    assert!(changed_when_up > 0, "a lossy codec must actually exercise the memory");
}

#[test]
fn per_link_bytes_match_upload_wire_bytes_in_both_directions() {
    let prob = consistent_problem(4, 8, 7);
    let rounds = 80usize;
    let mut cfg = cfg_for(&prob, Topology::Ring, "fp32", 32.0, rounds, 31);
    cfg.link.drop_prob = 0.3;
    let m = run_sharded(cfg, &prob).unwrap();
    // fp32 frames carry no side info: the exact protocol charge per
    // delivered directed message is (32n + header) bits, byte-rounded.
    let per_msg = ((32 * prob.n + UPLOAD_HEADER_BITS).div_ceil(8)) as u64;
    let mut link_bits = 0u64;
    for l in &m.per_link {
        assert_eq!(
            l.delivered + l.dropped,
            2 * rounds as u64,
            "a bidirectional link is tallied once per direction per round"
        );
        assert_eq!(l.bytes, l.delivered * per_msg, "link ({}, {})", l.a, l.b);
        link_bits += 8 * l.bytes;
    }
    assert!(m.per_link.iter().any(|l| l.dropped > 0), "drop 0.3 must pause something");
    assert_eq!(
        m.node_wire_bits.iter().sum::<u64>(),
        link_bits,
        "per-node and per-link tallies must agree"
    );
    assert_eq!(m.total_wire_bytes(), m.per_link.iter().map(|l| l.bytes).sum::<u64>());
    assert_eq!(
        m.rounds.iter().map(|r| r.wire_bytes).sum::<u64>(),
        m.total_wire_bytes(),
        "the per-round trace must carry the same bytes"
    );
}

#[test]
fn torus_and_random_topologies_run_with_full_link_accounting() {
    let prob = consistent_problem(9, 8, 13);
    let torus = cfg_for(&prob, Topology::Torus { rows: 3, cols: 3 }, "sd", 1.0, 20, 3);
    let mt = run_sharded(torus, &prob).unwrap();
    assert_eq!(mt.per_link.len(), 18, "a 3x3 torus has 2m edges");
    assert!(mt.final_value.is_finite());
    let random = cfg_for(&prob, Topology::random(0.4), "sign", 1.0, 20, 3);
    let mr = run_sharded(random, &prob).unwrap();
    assert!(mr.per_link.len() >= 9, "the random overlay keeps its ring backbone");
    assert!(mr.final_value.is_finite());
}
