//! Integration over the PJRT runtime: load AOT artifacts built by
//! `make artifacts` and validate their numerics against the pure-Rust
//! implementations. These tests **skip** (with a notice) when the
//! artifacts directory has not been built, so `cargo test` works on a
//! fresh checkout; CI runs `make artifacts` first.

use kashinflow::linalg::fwht::fwht_normalized_inplace;
use kashinflow::linalg::rng::Rng;
use kashinflow::linalg::vecops::{dist2, norm2};
use kashinflow::runtime::artifact::{artifacts_dir, Artifact, Input};

fn artifact_path(name: &str) -> Option<String> {
    let p = format!("{}/{name}", artifacts_dir());
    if std::path::Path::new(&p).exists() {
        Some(p)
    } else {
        eprintln!("SKIP: {p} not built (run `make artifacts`)");
        None
    }
}

#[test]
fn ndsc_embed_artifact_matches_rust_fwht() {
    let Some(path) = artifact_path("ndsc_embed_1024.hlo.txt") else { return };
    let art = Artifact::load(&path).expect("load/compile");
    let n = 1024;
    let mut rng = Rng::seed_from(1);
    let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
    let signs: Vec<f32> = (0..n).map(|_| rng.sign()).collect();
    let out = art
        .run1_f32(&[Input::F32(&y, vec![1, n]), Input::F32(&signs, vec![n])])
        .expect("execute");
    // Rust reference: x = H (D y) normalized.
    let mut want: Vec<f32> = y.iter().zip(&signs).map(|(&a, &s)| a * s).collect();
    fwht_normalized_inplace(&mut want);
    assert_eq!(out.len(), n);
    assert!(
        dist2(&out, &want) < 1e-3 * (1.0 + norm2(&want)),
        "pallas-in-HLO vs rust FWHT mismatch: {}",
        dist2(&out, &want)
    );
}

#[test]
fn ndsc_embed_decode_roundtrip_through_artifacts() {
    let (Some(pe), Some(pd)) =
        (artifact_path("ndsc_embed_1024.hlo.txt"), artifact_path("ndsc_decode_1024.hlo.txt"))
    else {
        return;
    };
    let embed = Artifact::load(&pe).unwrap();
    let decode = Artifact::load(&pd).unwrap();
    let n = 1024;
    let mut rng = Rng::seed_from(2);
    let y: Vec<f32> = (0..n).map(|_| rng.student_t(1)).collect();
    let signs: Vec<f32> = (0..n).map(|_| rng.sign()).collect();
    let x = embed.run1_f32(&[Input::F32(&y, vec![1, n]), Input::F32(&signs, vec![n])]).unwrap();
    let back = decode.run1_f32(&[Input::F32(&x, vec![1, n]), Input::F32(&signs, vec![n])]).unwrap();
    assert!(dist2(&back, &y) < 1e-3 * (1.0 + norm2(&y)));
}

#[test]
fn model_grad_artifact_losses_are_sane() {
    let Some(path) = artifact_path("model_grad.hlo.txt") else { return };
    let meta = kashinflow::exp::transformer::ModelMeta::load(&artifacts_dir()).unwrap();
    let x0 = kashinflow::exp::transformer::load_init(&artifacts_dir(), meta.n_params).unwrap();
    let art = Artifact::load(&path).unwrap();
    let mut rng = Rng::seed_from(3);
    let corpus = kashinflow::data::corpus::Corpus::synthetic(20_000, &mut rng);
    let (toks, tgts) = corpus.batch(meta.batch, meta.seq, &mut rng);
    let outs = art
        .run_f32(&[
            Input::F32(&x0, vec![meta.n_params]),
            Input::U32(&toks, vec![meta.batch, meta.seq]),
            Input::U32(&tgts, vec![meta.batch, meta.seq]),
        ])
        .unwrap();
    assert_eq!(outs.len(), 2);
    let loss = outs[0][0];
    // At init the LM should sit near uniform: log(vocab).
    let uniform = (meta.vocab as f32).ln();
    assert!(
        (loss - uniform).abs() < 1.0,
        "init loss {loss} far from log(vocab) = {uniform}"
    );
    // Gradient: right length, finite, non-zero.
    let g = &outs[1];
    assert_eq!(g.len(), meta.n_params);
    assert!(g.iter().all(|v| v.is_finite()));
    assert!(norm2(g) > 1e-4);
}

/// Compression quality on a *real* transformer gradient: quantifies the
/// heavy-tailedness of the workload (printed) and checks both codecs stay
/// within their theoretical envelopes. This is the diagnostic behind the
/// Fig. 3b discussion in EXPERIMENTS.md.
#[test]
fn compression_error_on_real_gradient() {
    use kashinflow::quant::{gain_shape::NaiveUniform, ndsc::Ndsc, Compressor};
    let Some(path) = artifact_path("model_grad.hlo.txt") else { return };
    let meta = kashinflow::exp::transformer::ModelMeta::load(&artifacts_dir()).unwrap();
    let x0 = kashinflow::exp::transformer::load_init(&artifacts_dir(), meta.n_params).unwrap();
    let art = Artifact::load(&path).unwrap();
    let mut rng = Rng::seed_from(5);
    let corpus = kashinflow::data::corpus::Corpus::synthetic(20_000, &mut rng);
    let (toks, tgts) = corpus.batch(meta.batch, meta.seq, &mut rng);
    let outs = art
        .run_f32(&[
            Input::F32(&x0, vec![meta.n_params]),
            Input::U32(&toks, vec![meta.batch, meta.seq]),
            Input::U32(&tgts, vec![meta.batch, meta.seq]),
        ])
        .unwrap();
    let g = &outs[1];
    let n = g.len();
    // Heavy-tailedness: l_inf * sqrt(n) / l2 = 1 for flat vectors, sqrt(n)
    // for one-hot.
    let spikiness = kashinflow::linalg::vecops::norm_inf(g) * (n as f32).sqrt() / norm2(g);
    let ndsc = Ndsc::hadamard(n, 4.0, &mut rng);
    let naive = NaiveUniform::new(n, 4.0);
    let e_ndsc = dist2(&ndsc.decompress(&ndsc.compress(g, &mut rng)), g) / norm2(g);
    let e_naive = dist2(&naive.decompress(&naive.compress(g, &mut rng)), g) / norm2(g);
    println!("transformer grad: spikiness {spikiness:.1}, NDSC err {e_ndsc:.4}, naive err {e_naive:.4}");
    // Theorem 1 envelope for NDSC at R=4, lambda = N/n:
    let big_n = kashinflow::linalg::fwht::next_pow2(n) as f32;
    let lambda = big_n / n as f32;
    let bound = (2.0f32).powf(2.0 - 4.0 / lambda) * (2.0 * big_n).ln().sqrt();
    assert!(e_ndsc <= bound, "NDSC err {e_ndsc} above Thm-1 envelope {bound}");
    // The paper's point, measured on a live gradient: NDSC preserves the
    // signal while the naive scalar quantizer's sqrt(n) covering penalty
    // costs ~the whole gradient at this spikiness.
    assert!(e_ndsc < 0.5, "NDSC err {e_ndsc}");
    assert!(e_ndsc < 0.5 * e_naive, "NDSC {e_ndsc} should dominate naive {e_naive}");
}

#[test]
fn model_grad_descends_loss() {
    let Some(path) = artifact_path("model_grad.hlo.txt") else { return };
    let meta = kashinflow::exp::transformer::ModelMeta::load(&artifacts_dir()).unwrap();
    let mut x = kashinflow::exp::transformer::load_init(&artifacts_dir(), meta.n_params).unwrap();
    let art = Artifact::load(&path).unwrap();
    let mut rng = Rng::seed_from(4);
    let corpus = kashinflow::data::corpus::Corpus::synthetic(20_000, &mut rng);
    let (toks, tgts) = corpus.batch(meta.batch, meta.seq, &mut rng);
    let run = |x: &[f32], art: &Artifact| -> (f32, Vec<f32>) {
        let outs = art
            .run_f32(&[
                Input::F32(x, vec![meta.n_params]),
                Input::U32(&toks, vec![meta.batch, meta.seq]),
                Input::U32(&tgts, vec![meta.batch, meta.seq]),
            ])
            .unwrap();
        (outs[0][0], outs[1].clone())
    };
    let (loss0, _) = run(&x, &art);
    for _ in 0..15 {
        let (_, g) = run(&x, &art);
        for (xi, gi) in x.iter_mut().zip(&g) {
            *xi -= 0.1 * gi;
        }
    }
    let (loss1, _) = run(&x, &art);
    assert!(loss1 < loss0 - 0.05, "GD on the artifact failed: {loss0} -> {loss1}");
}
