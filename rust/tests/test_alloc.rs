//! Allocation-counting proof of the allocation-free hot path.
//!
//! A counting `#[global_allocator]` wrapper tallies every `alloc`,
//! `alloc_zeroed` and `realloc` in the process. Three claims are enforced:
//!
//! 1. **Codec level** — after one warm-up call, `compress_into` /
//!    `decompress_into` with a reused [`Workspace`] and message shell
//!    perform exactly zero heap allocations per call (NDSC, n = 4096).
//! 2. **Coordinator level** — in a threaded 4-worker run at n = 4096,
//!    every steady-state round (after a warm-up window for buffer pools,
//!    channel wakers and lazy runtime init) performs exactly zero heap
//!    allocations across *all* threads: gradients, codec scratch,
//!    broadcast iterates and wire bytes are all recycled.
//! 3. **Engine level** — an inline `opt::engine` run (the DGD-DEF spec:
//!    exact oracle + shared codec + error feedback) performs exactly
//!    zero heap allocations per steady-state round, sampled via the
//!    engine's round probe: buffers, workspace, message shell and the
//!    reserved trace all warm up once.
//! 4. **Serve level** — a multi-job `serve::JobServer` round (deficit
//!    accrual + rotation + one engine round per granted job, across a
//!    heterogeneous three-tenant mix incl. error feedback) performs
//!    exactly zero heap allocations per steady-state fleet round: the
//!    scheduler is integer arithmetic over preallocated slots and the
//!    per-job accounting updates rows in place.
//! 5. **Cluster epoch level** — a multi-fleet work-stealing cluster
//!    epoch (`FleetCluster::run_epoch`: barrier grant pass, per-fleet
//!    deque refill, persistent-pool execution with stealing, accounting
//!    fold) performs exactly zero heap allocations once the pool
//!    threads, grant vectors and deque buffers are warm.
//!
//! Everything lives in ONE `#[test]` so the libtest harness cannot run a
//! second counter-touching test concurrently and pollute the tallies.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use kashinflow::coordinator::config::{RunConfig, SchemeKind};
use kashinflow::coordinator::run_distributed;
use kashinflow::coordinator::worker::DatasetGradSource;
use kashinflow::data::synthetic::planted_regression_shards;
use kashinflow::linalg::rng::Rng;
use kashinflow::opt::objectives::Loss;
use kashinflow::quant::ndsc::Ndsc;
use kashinflow::quant::{Compressed, Compressor, Workspace};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> usize {
    ALLOCS.load(Ordering::SeqCst)
}

fn codec_level_zero_allocs() {
    let n = 4096;
    let mut rng = Rng::seed_from(1);
    let codec = Ndsc::hadamard_dithered(n, 2.0, &mut rng);
    let mut ws = Workspace::for_compressor(&codec);
    let mut msg = Compressed::empty(n);
    let mut dec = vec![0.0f32; n];
    let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
    // Warm-up: first call sizes the wire-byte buffer and any workspace
    // slack beyond the preallocation hint.
    for _ in 0..3 {
        codec.compress_into(&y, &mut rng, &mut ws, &mut msg);
        codec.decompress_into(&msg, &mut ws, &mut dec);
    }
    let before = alloc_count();
    for _ in 0..100 {
        codec.compress_into(&y, &mut rng, &mut ws, &mut msg);
        codec.decompress_into(&msg, &mut ws, &mut dec);
    }
    let grew = alloc_count() - before;
    assert_eq!(
        grew, 0,
        "codec hot path allocated {grew} times over 100 warm compress/decompress round-trips"
    );
    assert!(dec.iter().all(|v| v.is_finite()));
}

fn coordinator_level_zero_allocs() {
    // NDSC, n = 4096 (< PARALLEL_DECODE_MIN_DIM ⇒ sequential decode on
    // the server thread), m = 4 workers, full local gradients.
    let n = 4096;
    let m = 4;
    let rounds = 120usize;
    let warmup = 20usize;
    let mut rng = Rng::seed_from(7);
    let (shards, _) = planted_regression_shards(m, 10, n, Loss::Square, &mut rng, false);
    let cfg = RunConfig {
        n,
        workers: m,
        r: 1.0,
        scheme: SchemeKind::Ndsc,
        rounds,
        step: 1e-4,
        batch: 0,
        ..Default::default()
    };
    let comps = cfg.build_compressors(&mut rng);
    let sources: Vec<Box<dyn kashinflow::coordinator::worker::GradSource>> = shards
        .into_iter()
        .enumerate()
        .map(|(i, obj)| {
            Box::new(DatasetGradSource {
                obj,
                batch: 0,
                rng: Rng::seed_from(50 + i as u64),
                idx: Vec::new(),
            }) as Box<dyn kashinflow::coordinator::worker::GradSource>
        })
        .collect();
    // Sample the allocation counter at every round boundary from inside
    // the server's eval hook. When eval(round r) runs, all m workers are
    // parked on their downlinks, so the tally cleanly partitions rounds
    // across every thread. The vector is preallocated: the push itself
    // must not allocate.
    let mut counts: Vec<usize> = Vec::with_capacity(rounds);
    let metrics = run_distributed(&cfg, vec![0.0; n], sources, comps, |_| {
        counts.push(alloc_count());
        0.0
    });
    assert_eq!(metrics.rounds.len(), rounds);
    assert_eq!(metrics.rejected_messages, 0);
    assert_eq!(counts.len(), rounds);
    for i in warmup..rounds {
        let grew = counts[i] - counts[i - 1];
        assert_eq!(
            grew,
            0,
            "steady-state round {i} performed {grew} heap allocations \
             (allocation-free contract violated; warm-up window = {warmup} rounds)"
        );
    }
}

fn engine_level_zero_allocs() {
    use kashinflow::opt::engine::feedback::DefFeedback;
    use kashinflow::opt::engine::oracle::ExactGrad;
    use kashinflow::opt::engine::schedule::Schedule;
    use kashinflow::opt::engine::{Codecs, Engine, Problem};

    let n = 1024;
    let rounds = 60usize;
    let warmup = 10usize;
    let mut rng = Rng::seed_from(21);
    let (shards, _) = planted_regression_shards(1, 10, n, Loss::Square, &mut rng, false);
    let obj = shards.into_iter().next().unwrap();
    let codec = Ndsc::hadamard_dithered(n, 2.0, &mut rng);
    let (l, mu) = obj.smoothness_strong_convexity();
    // Sample the counter from the engine's round probe; the vector is
    // preallocated so the push itself cannot allocate.
    let mut counts: Vec<usize> = Vec::with_capacity(rounds);
    let trace = Engine::new(Problem::Single(&obj), Schedule::Constant(2.0 / (l + mu)), rounds)
        .with_oracle(ExactGrad { obj: &obj })
        .with_codecs(Codecs::Shared(&codec))
        .with_feedback(DefFeedback::new(1, n))
        .with_probe(|_| counts.push(alloc_count()))
        .run(&vec![0.0; n], None, &mut rng);
    assert_eq!(trace.records.len(), rounds + 1);
    assert!(trace.final_x.iter().all(|v| v.is_finite()));
    assert_eq!(counts.len(), rounds);
    for i in warmup..rounds {
        let grew = counts[i] - counts[i - 1];
        assert_eq!(
            grew,
            0,
            "engine round {i} performed {grew} heap allocations \
             (allocation-free contract violated; warm-up window = {warmup} rounds)"
        );
    }
}

fn serve_level_zero_allocs() {
    use kashinflow::quant::registry::CompressorSpec;
    use kashinflow::serve::{JobServer, JobSpec, Policy};

    let n = 1024;
    let job_rounds = 200usize;
    let measured = 60usize;
    let warmup = 20usize;
    // Three heterogeneous tenants: dithered subspace, scalar dither, and
    // a DEF-feedback subspace job — the serve hot path must stay
    // allocation-free across all of them at once.
    let specs = vec![
        JobSpec::new("a-ndsc-dith", CompressorSpec::parse("ndsc-dith").unwrap(), 1.0, n, job_rounds, 1),
        JobSpec::new("b-sd", CompressorSpec::parse("sd").unwrap(), 0.5, n, job_rounds, 2),
        JobSpec::new("c-ndsc-def", CompressorSpec::parse("ndsc").unwrap(), 2.0, n, job_rounds, 3)
            .with_def_feedback(),
    ];
    // Ample budget: every tenant is granted a round every fleet round,
    // so the window measures the full serve path, not idling.
    let mut srv = JobServer::new(1 << 24, Policy::Drr);
    for s in specs {
        srv.submit(s).expect("ample budget admits all tenants");
    }
    for _ in 0..warmup {
        srv.run_round();
    }
    // The vector is preallocated: the push itself must not allocate.
    let mut counts: Vec<usize> = Vec::with_capacity(measured);
    for _ in 0..measured {
        let before = alloc_count();
        let served = srv.run_round();
        assert_eq!(served, 3, "every tenant must be granted a round");
        counts.push(alloc_count() - before);
    }
    for (i, &grew) in counts.iter().enumerate() {
        assert_eq!(
            grew,
            0,
            "steady-state fleet round {i} performed {grew} heap allocations \
             (allocation-free serve contract violated; warm-up window = {warmup} rounds)"
        );
    }
    assert!(warmup + measured < job_rounds, "no job may finalize inside the window");
}

fn serve_cluster_epoch_zero_allocs() {
    use kashinflow::quant::registry::CompressorSpec;
    use kashinflow::serve::{FleetCluster, JobSpec, Policy};

    let n = 1024;
    let job_rounds = 200usize;
    let epoch = 8usize;
    let warmup_epochs = 6usize;
    let measured_epochs = 10usize;
    // Four single-worker tenants over a two-fleet cluster: the epoch
    // path (barrier grant pass → deque refill → persistent pool with
    // stealing → accounting fold) must be allocation-free end to end.
    let specs = vec![
        JobSpec::new("w-ndsc-dith", CompressorSpec::parse("ndsc-dith").unwrap(), 1.0, n, job_rounds, 1),
        JobSpec::new("x-sd", CompressorSpec::parse("sd").unwrap(), 0.5, n, job_rounds, 2),
        JobSpec::new("y-ndsc-def", CompressorSpec::parse("ndsc").unwrap(), 2.0, n, job_rounds, 3)
            .with_def_feedback(),
        JobSpec::new("z-dith", CompressorSpec::parse("ndsc-dith").unwrap(), 0.5, n, job_rounds, 4),
    ];
    let tenants = specs.len();
    let mut cluster = FleetCluster::new(2, 1 << 24, Policy::Drr);
    for s in specs {
        cluster.submit(s).expect("ample budget admits all tenants");
    }
    // Warm-up epochs spawn the persistent pool threads (thread spawn
    // allocates) and size every slot's grant vector and per-fleet deque
    // buffer; the same epoch length afterwards reuses all of it.
    for _ in 0..warmup_epochs {
        cluster.run_epoch(epoch);
    }
    for i in 0..measured_epochs {
        let before = alloc_count();
        let served = cluster.run_epoch(epoch);
        let grew = alloc_count() - before;
        assert_eq!(
            served,
            tenants * epoch,
            "every tenant must be granted every round of the epoch"
        );
        assert_eq!(
            grew,
            0,
            "work-stealing cluster epoch {i} performed {grew} heap allocations \
             (allocation-free epoch contract violated; warm-up = {warmup_epochs} epochs)"
        );
    }
    assert!(
        (warmup_epochs + measured_epochs) * epoch < job_rounds,
        "no job may finalize inside the measured window"
    );
}

/// One test fn on purpose: all phases read the global counter, and the
/// libtest harness runs separate `#[test]`s on concurrent threads.
#[test]
fn zero_steady_state_allocations() {
    codec_level_zero_allocs();
    coordinator_level_zero_allocs();
    engine_level_zero_allocs();
    serve_level_zero_allocs();
    serve_cluster_epoch_zero_allocs();
}
