//! Golden-trace equivalence for the unified optimizer engine.
//!
//! The six legacy `run()` entry points are now thin spec-builders over
//! `opt::engine`. This suite keeps **reference implementations of the
//! pre-refactor loop bodies** (verbatim float-op and RNG ordering,
//! using the allocating codec API — proven bit-identical to the
//! workspace API by the conformance suite) and asserts that every entry
//! point produces a **bitwise-identical** trace: every record's value /
//! distance bits, payload, participants, the final iterate, and the
//! traffic totals (`tests/common::assert_trace_bit_identical`).
//!
//! The engine's distributed driver is additionally checked for seed
//! determinism with the coordinator bit-identity oracle, and a
//! **per-thread** counting allocator proves zero steady-state
//! allocations per engine round without serializing the suite (the
//! process-wide proof across all threads lives in `test_alloc.rs`,
//! phase 3, whose single-test binary keeps its global counter clean).

mod common;

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use common::{assert_bit_identical, assert_trace_bit_identical};
use kashinflow::coordinator::config::{RunConfig, SchemeKind};
use kashinflow::coordinator::transport::Participation;
use kashinflow::data::synthetic::{
    planted_regression, planted_regression_shards, two_gaussian_svm, Tail,
};
use kashinflow::linalg::rng::Rng;
use kashinflow::linalg::vecops::dist2;
use kashinflow::opt::dgd_def::{self, DgdDefOptions};
use kashinflow::opt::dq_psgd::{self, DqPsgdOptions};
use kashinflow::opt::engine::driver::{CoordinatorDriver, Driver};
use kashinflow::opt::engine::schedule::Schedule;
use kashinflow::opt::engine::{Engine, OutputMode, Problem};
use kashinflow::opt::gd::{self, GdOptions};
use kashinflow::opt::multi::{self, MultiOptions, ShardedProblem};
use kashinflow::opt::multi_def::{self, MultiDefOptions};
use kashinflow::opt::objectives::{DatasetObjective, Loss};
use kashinflow::opt::oracle::{MinibatchOracle, Oracle};
use kashinflow::opt::projection::Domain;
use kashinflow::opt::psgd::{self, PsgdOptions};
use kashinflow::opt::{IterRecord, Trace};
use kashinflow::quant::ndsc::Ndsc;
use kashinflow::quant::Compressor;

// ---------------------------------------------------------------------
// Per-thread allocation counter: concurrent tests in this binary tally
// on their own threads, so one thread's steady-state measurement stays
// clean under the parallel libtest harness.
// ---------------------------------------------------------------------

struct ThreadCountingAlloc;

thread_local! {
    static THREAD_ALLOCS: Cell<usize> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for ThreadCountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: ThreadCountingAlloc = ThreadCountingAlloc;

fn thread_allocs() -> usize {
    THREAD_ALLOCS.with(|c| c.get())
}

#[test]
fn engine_round_is_allocation_free_in_steady_state() {
    use kashinflow::opt::engine::feedback::DefFeedback;
    use kashinflow::opt::engine::oracle::ExactGrad;
    use kashinflow::opt::engine::Codecs;
    let n = 512;
    let rounds = 50usize;
    let warmup = 10usize;
    let mut data_rng = Rng::seed_from(30);
    let (obj, _) =
        planted_regression(40, n, Tail::GaussianCubed, Tail::Gaussian, 0.1, &mut data_rng);
    let codec = Ndsc::hadamard_dithered(n, 2.0, &mut Rng::seed_from(31));
    let (l, mu) = obj.smoothness_strong_convexity();
    // Sampled from the engine's round probe into a preallocated vector
    // (the push itself must not allocate).
    let mut counts: Vec<usize> = Vec::with_capacity(rounds);
    let trace = Engine::new(Problem::Single(&obj), Schedule::Constant(2.0 / (l + mu)), rounds)
        .with_oracle(ExactGrad { obj: &obj })
        .with_codecs(Codecs::Shared(&codec))
        .with_feedback(DefFeedback::new(1, n))
        .with_probe(|_| counts.push(thread_allocs()))
        .run(&vec![0.0; n], None, &mut Rng::seed_from(32));
    assert_eq!(trace.records.len(), rounds + 1);
    assert!(trace.final_x.iter().all(|v| v.is_finite()));
    assert_eq!(counts.len(), rounds);
    for i in warmup..rounds {
        let grew = counts[i] - counts[i - 1];
        assert_eq!(
            grew, 0,
            "engine round {i} performed {grew} heap allocations on this thread \
             (warm-up window = {warmup} rounds)"
        );
    }
}

// ---------------------------------------------------------------------
// Reference implementations: the pre-engine loop bodies, preserved here
// as the golden standard. `participants` mirrors the engine's
// delivered-uploads semantics so the whole record is comparable.
// ---------------------------------------------------------------------

fn ref_gd(
    obj: &DatasetObjective,
    x0: &[f32],
    x_star: Option<&[f32]>,
    step: f32,
    iters: usize,
) -> Trace {
    let n = obj.dim();
    let mut x = x0.to_vec();
    let mut g = vec![0.0f32; n];
    let mut trace = Trace::default();
    for _ in 0..=iters {
        trace.records.push(IterRecord {
            value: obj.value(&x),
            dist_to_opt: x_star.map(|xs| dist2(&x, xs)).unwrap_or(f32::NAN),
            payload_bits: 0,
            participants: 1,
        });
        obj.gradient(&x, &mut g);
        for (xi, &gi) in x.iter_mut().zip(&g) {
            *xi -= step * gi;
        }
    }
    trace.final_x = x;
    trace
}

fn ref_psgd(
    obj: &DatasetObjective,
    oracle: &mut dyn Oracle,
    x0: &[f32],
    x_star: Option<&[f32]>,
    step: f32,
    iters: usize,
    domain: Domain,
) -> Trace {
    let n = obj.dim();
    let mut x = x0.to_vec();
    domain.project(&mut x);
    let mut avg = vec![0.0f32; n];
    let mut g = vec![0.0f32; n];
    let mut trace = Trace::default();
    for t in 0..iters {
        oracle.query(&x, &mut g);
        for (xi, &gi) in x.iter_mut().zip(&g) {
            *xi -= step * gi;
        }
        domain.project(&mut x);
        let w = 1.0 / (t + 1) as f32;
        for (ai, &xi) in avg.iter_mut().zip(&x) {
            *ai += w * (xi - *ai);
        }
        trace.records.push(IterRecord {
            value: obj.value(&avg),
            dist_to_opt: x_star.map(|xs| dist2(&avg, xs)).unwrap_or(f32::NAN),
            payload_bits: 0,
            participants: 1,
        });
    }
    trace.final_x = avg;
    trace
}

fn ref_dgd_def(
    obj: &DatasetObjective,
    compressor: &dyn Compressor,
    x0: &[f32],
    x_star: Option<&[f32]>,
    step: f32,
    iters: usize,
    rng: &mut Rng,
) -> Trace {
    let n = obj.dim();
    let mut xhat = x0.to_vec();
    let mut e = vec![0.0f32; n]; // e_{-1} = 0
    let mut z = vec![0.0f32; n];
    let mut u = vec![0.0f32; n];
    let mut trace = Trace::default();
    for _ in 0..iters {
        trace.records.push(IterRecord {
            value: obj.value(&xhat),
            dist_to_opt: x_star.map(|xs| dist2(&xhat, xs)).unwrap_or(f32::NAN),
            payload_bits: 0,
            participants: 0,
        });
        // z_t = x̂_t + α e_{t−1}
        for ((zi, &xi), &ei) in z.iter_mut().zip(&xhat).zip(&e) {
            *zi = xi + step * ei;
        }
        // u_t = ∇f(z_t) − e_{t−1}
        obj.gradient(&z, &mut u);
        for (ui, &ei) in u.iter_mut().zip(&e) {
            *ui -= ei;
        }
        // v_t = E(u_t); q_t = D(v_t)
        let msg = compressor.compress(&u, rng);
        trace.total_payload_bits += msg.payload_bits;
        trace.total_side_bits += msg.side_bits;
        let q = compressor.decompress(&msg);
        // e_t = q_t − u_t
        for ((ei, &qi), &ui) in e.iter_mut().zip(&q).zip(&u) {
            *ei = qi - ui;
        }
        // Server: x̂_{t+1} = x̂_t − α q_t
        for (xi, &qi) in xhat.iter_mut().zip(&q) {
            *xi -= step * qi;
        }
        if let Some(r) = trace.records.last_mut() {
            r.payload_bits = msg.payload_bits;
            r.participants = 1;
        }
    }
    trace.records.push(IterRecord {
        value: obj.value(&xhat),
        dist_to_opt: x_star.map(|xs| dist2(&xhat, xs)).unwrap_or(f32::NAN),
        payload_bits: 0,
        participants: 0,
    });
    trace.final_x = xhat;
    trace
}

#[allow(clippy::too_many_arguments)]
fn ref_dq_psgd(
    obj: &DatasetObjective,
    oracle: &mut dyn Oracle,
    compressor: &dyn Compressor,
    x0: &[f32],
    x_star: Option<&[f32]>,
    step: f32,
    iters: usize,
    domain: Domain,
    drop_prob: f32,
    rng: &mut Rng,
) -> Trace {
    let n = obj.dim();
    let mut x = x0.to_vec();
    domain.project(&mut x);
    let mut avg = vec![0.0f32; n];
    let mut g = vec![0.0f32; n];
    let mut trace = Trace::default();
    for t in 0..iters {
        oracle.query(&x, &mut g);
        let msg = compressor.compress(&g, rng);
        trace.total_payload_bits += msg.payload_bits;
        trace.total_side_bits += msg.side_bits;
        let delivered = drop_prob <= 0.0 || rng.uniform_f32() >= drop_prob;
        if delivered {
            let q = compressor.decompress(&msg);
            for (xi, &qi) in x.iter_mut().zip(&q) {
                *xi -= step * qi;
            }
            domain.project(&mut x);
        }
        let w = 1.0 / (t + 1) as f32;
        for (ai, &xi) in avg.iter_mut().zip(&x) {
            *ai += w * (xi - *ai);
        }
        trace.records.push(IterRecord {
            value: obj.value(&avg),
            dist_to_opt: x_star.map(|xs| dist2(&avg, xs)).unwrap_or(f32::NAN),
            payload_bits: msg.payload_bits,
            participants: usize::from(delivered),
        });
    }
    trace.final_x = avg;
    trace
}

#[allow(clippy::too_many_arguments)]
fn ref_multi(
    problem: &ShardedProblem,
    compressors: &[Box<dyn Compressor>],
    x0: &[f32],
    x_star: Option<&[f32]>,
    opts: MultiOptions,
    rng: &mut Rng,
) -> Trace {
    let n = problem.n;
    let m = problem.m();
    let mut x = x0.to_vec();
    opts.domain.project(&mut x);
    let mut avg = vec![0.0f32; n];
    let mut consensus = vec![0.0f32; n];
    let mut g = vec![0.0f32; n];
    let mut worker_rngs: Vec<Rng> = (0..m).map(|i| rng.fork(i as u64)).collect();
    let mut batch_idx: Vec<usize> = Vec::new();
    let mut participants: Vec<usize> = Vec::with_capacity(m);
    let mut trace = Trace::default();
    for t in 0..opts.iters {
        consensus.fill(0.0);
        let mut round_bits = 0usize;
        match opts.participation {
            Participation::KofM { k } => {
                rng.sample_indices_into(m, k.min(m), &mut participants);
                participants.sort_unstable();
            }
            Participation::Full | Participation::Deadline { .. } => {
                participants.clear();
                participants.extend(0..m);
            }
        }
        let p = participants.len().max(1);
        for &i in &participants {
            let shard = &problem.shards[i];
            match opts.batch {
                Some(bsz) => {
                    worker_rngs[i].sample_indices_into(shard.m, bsz.min(shard.m), &mut batch_idx);
                    shard.minibatch_gradient(&x, Some(&batch_idx), &mut g);
                }
                None => shard.gradient(&x, &mut g),
            }
            let msg = compressors[i].compress(&g, &mut worker_rngs[i]);
            round_bits += msg.payload_bits;
            trace.total_payload_bits += msg.payload_bits;
            trace.total_side_bits += msg.side_bits;
            let q = compressors[i].decompress(&msg);
            for (ci, &qi) in consensus.iter_mut().zip(&q) {
                *ci += qi / p as f32;
            }
        }
        for (xi, &ci) in x.iter_mut().zip(&consensus) {
            *xi -= opts.step * ci;
        }
        opts.domain.project(&mut x);
        let w = 1.0 / (t + 1) as f32;
        for (ai, &xi) in avg.iter_mut().zip(&x) {
            *ai += w * (xi - *ai);
        }
        trace.records.push(IterRecord {
            value: problem.value(&avg),
            dist_to_opt: x_star.map(|xs| dist2(&avg, xs)).unwrap_or(f32::NAN),
            payload_bits: round_bits,
            participants: participants.len(),
        });
    }
    trace.final_x = avg;
    trace
}

fn ref_multi_def(
    problem: &ShardedProblem,
    compressors: &[Box<dyn Compressor>],
    x0: &[f32],
    x_star: Option<&[f32]>,
    opts: MultiDefOptions,
    rng: &mut Rng,
) -> Trace {
    let n = problem.n;
    let m = problem.m();
    let mut xhat = x0.to_vec();
    let mut errs = vec![vec![0.0f32; n]; m];
    let mut z = vec![0.0f32; n];
    let mut g = vec![0.0f32; n];
    let mut consensus = vec![0.0f32; n];
    let mut participants: Vec<usize> = Vec::with_capacity(m);
    let mut trace = Trace::default();
    for _ in 0..opts.iters {
        trace.records.push(IterRecord {
            value: problem.value(&xhat),
            dist_to_opt: x_star.map(|xs| dist2(&xhat, xs)).unwrap_or(f32::NAN),
            payload_bits: 0,
            participants: 0,
        });
        consensus.fill(0.0);
        let mut round_bits = 0;
        match opts.participation {
            Participation::KofM { k } => {
                rng.sample_indices_into(m, k.min(m), &mut participants);
                participants.sort_unstable();
            }
            Participation::Full | Participation::Deadline { .. } => {
                participants.clear();
                participants.extend(0..m);
            }
        }
        let p = participants.len().max(1);
        for &i in &participants {
            let shard = &problem.shards[i];
            let e = &mut errs[i];
            for ((zi, &xi), &ei) in z.iter_mut().zip(&xhat).zip(e.iter()) {
                *zi = xi + opts.step * ei;
            }
            shard.gradient(&z, &mut g);
            for (gi, &ei) in g.iter_mut().zip(e.iter()) {
                *gi -= ei; // u_i
            }
            let msg = compressors[i].compress(&g, rng);
            round_bits += msg.payload_bits;
            trace.total_payload_bits += msg.payload_bits;
            trace.total_side_bits += msg.side_bits;
            let q = compressors[i].decompress(&msg);
            for ((ei, &qi), &ui) in e.iter_mut().zip(&q).zip(&g) {
                *ei = qi - ui;
            }
            for (ci, &qi) in consensus.iter_mut().zip(&q) {
                *ci += qi / p as f32;
            }
        }
        for (xi, &ci) in xhat.iter_mut().zip(&consensus) {
            *xi -= opts.step * ci;
        }
        if let Some(r) = trace.records.last_mut() {
            r.payload_bits = round_bits;
            r.participants = participants.len();
        }
    }
    trace.records.push(IterRecord {
        value: problem.value(&xhat),
        dist_to_opt: x_star.map(|xs| dist2(&xhat, xs)).unwrap_or(f32::NAN),
        payload_bits: 0,
        participants: 0,
    });
    trace.final_x = xhat;
    trace
}

// ---------------------------------------------------------------------
// Golden-trace equivalence: one test per legacy entry point.
// ---------------------------------------------------------------------

#[test]
fn gd_is_bit_identical_to_legacy() {
    let mut data_rng = Rng::seed_from(1);
    let (obj, _) =
        planted_regression(100, 20, Tail::Gaussian, Tail::Gaussian, 0.1, &mut data_rng);
    let xs = obj.quadratic_minimizer();
    let (l, mu) = obj.smoothness_strong_convexity();
    let opts = GdOptions::optimal(l, mu, 80);
    let x0 = vec![0.0f32; 20];
    let want = ref_gd(&obj, &x0, Some(&xs), opts.step, opts.iters);
    let got = gd::run(&obj, &x0, Some(&xs), opts);
    assert_trace_bit_identical(&want, &got, "gd");
}

#[test]
fn psgd_is_bit_identical_to_legacy() {
    let mut data_rng = Rng::seed_from(2);
    let obj = two_gaussian_svm(80, 24, 0.8, &mut data_rng);
    let domain = Domain::L2Ball { radius: 5.0 };
    let x0 = vec![0.0f32; 24];
    let mut oracle_a = MinibatchOracle::new(&obj, 8, Rng::seed_from(3));
    let want = ref_psgd(&obj, &mut oracle_a, &x0, None, 0.05, 120, domain);
    let mut oracle_b = MinibatchOracle::new(&obj, 8, Rng::seed_from(3));
    let got = psgd::run(
        &obj,
        &mut oracle_b,
        &x0,
        None,
        PsgdOptions { step: 0.05, iters: 120, domain },
        &mut Rng::seed_from(4),
    );
    assert_trace_bit_identical(&want, &got, "psgd");
}

#[test]
fn dgd_def_is_bit_identical_to_legacy() {
    let mut data_rng = Rng::seed_from(5);
    let (obj, _) =
        planted_regression(80, 24, Tail::GaussianCubed, Tail::Gaussian, 0.1, &mut data_rng);
    let xs = obj.quadratic_minimizer();
    let (l, mu) = obj.smoothness_strong_convexity();
    let step = GdOptions::optimal(l, mu, 0).step;
    let codec = Ndsc::hadamard(24, 3.0, &mut Rng::seed_from(6));
    let x0 = vec![0.0f32; 24];
    let want = ref_dgd_def(&obj, &codec, &x0, Some(&xs), step, 60, &mut Rng::seed_from(7));
    let got = dgd_def::run(
        &obj,
        &codec,
        &x0,
        Some(&xs),
        DgdDefOptions { step, iters: 60 },
        &mut Rng::seed_from(7),
    );
    assert_trace_bit_identical(&want, &got, "dgd_def");
}

#[test]
fn dq_psgd_is_bit_identical_to_legacy_including_drops() {
    let mut data_rng = Rng::seed_from(8);
    let obj = two_gaussian_svm(80, 30, 0.8, &mut data_rng);
    let domain = Domain::L2Ball { radius: 8.0 };
    let codec = Ndsc::hadamard_dithered(30, 0.5, &mut Rng::seed_from(9));
    let x0 = vec![0.0f32; 30];
    for drop_prob in [0.0f32, 0.3] {
        let mut oracle_a = MinibatchOracle::new(&obj, 10, Rng::seed_from(10));
        let want = ref_dq_psgd(
            &obj,
            &mut oracle_a,
            &codec,
            &x0,
            None,
            0.05,
            150,
            domain,
            drop_prob,
            &mut Rng::seed_from(11),
        );
        let mut oracle_b = MinibatchOracle::new(&obj, 10, Rng::seed_from(10));
        let got = dq_psgd::run(
            &obj,
            &mut oracle_b,
            &codec,
            &x0,
            None,
            DqPsgdOptions { step: 0.05, iters: 150, domain, drop_prob },
            &mut Rng::seed_from(11),
        );
        assert_trace_bit_identical(&want, &got, &format!("dq_psgd drop={drop_prob}"));
        if drop_prob > 0.0 {
            // Lossy rounds are visible: some records report 0 delivered
            // uploads while still charging the payload bits.
            assert!(got.records.iter().any(|r| r.participants == 0 && r.payload_bits > 0));
        }
    }
}

fn dithered_fleet(m: usize, n: usize, seed: u64) -> Vec<Box<dyn Compressor>> {
    let budgets = [0.5f32, 1.0, 2.0, 4.0];
    let mut rng = Rng::seed_from(seed);
    (0..m)
        .map(|i| {
            Box::new(Ndsc::hadamard_dithered(n, budgets[i % budgets.len()], &mut rng))
                as Box<dyn Compressor>
        })
        .collect()
}

#[test]
fn multi_is_bit_identical_to_legacy() {
    let mut data_rng = Rng::seed_from(12);
    let (shards, xs) = planted_regression_shards(6, 10, 20, Loss::Square, &mut data_rng, false);
    let problem = ShardedProblem::new(shards);
    let opts = MultiOptions {
        step: problem.stable_step(),
        iters: 80,
        domain: Domain::L2Ball { radius: 50.0 },
        batch: Some(5),
        participation: Participation::KofM { k: 4 },
    };
    let x0 = vec![0.0f32; 20];
    let comps_a = dithered_fleet(6, 20, 13);
    let want = ref_multi(&problem, &comps_a, &x0, Some(&xs), opts, &mut Rng::seed_from(14));
    let comps_b = dithered_fleet(6, 20, 13);
    let got = multi::run(&problem, &comps_b, &x0, Some(&xs), opts, &mut Rng::seed_from(14));
    assert_trace_bit_identical(&want, &got, "multi k-of-m");
    // Full participation, full local gradients.
    let opts_full = MultiOptions {
        batch: None,
        participation: Participation::Full,
        ..opts
    };
    let comps_a = dithered_fleet(6, 20, 15);
    let want = ref_multi(&problem, &comps_a, &x0, Some(&xs), opts_full, &mut Rng::seed_from(16));
    let comps_b = dithered_fleet(6, 20, 15);
    let got = multi::run(&problem, &comps_b, &x0, Some(&xs), opts_full, &mut Rng::seed_from(16));
    assert_trace_bit_identical(&want, &got, "multi full");
}

#[test]
fn multi_def_is_bit_identical_to_legacy() {
    let mut data_rng = Rng::seed_from(17);
    let (shards, xs) = planted_regression_shards(5, 12, 16, Loss::Square, &mut data_rng, false);
    let problem = ShardedProblem::new(shards);
    let step = problem.stable_step();
    let x0 = vec![0.0f32; 16];
    for participation in [Participation::Full, Participation::KofM { k: 3 }] {
        let opts = MultiDefOptions { step, iters: 60, participation };
        let mut rng = Rng::seed_from(18);
        let comps_a: Vec<Box<dyn Compressor>> =
            (0..5).map(|_| Box::new(Ndsc::hadamard(16, 4.0, &mut rng)) as _).collect();
        let want = ref_multi_def(&problem, &comps_a, &x0, Some(&xs), opts, &mut Rng::seed_from(19));
        let mut rng = Rng::seed_from(18);
        let comps_b: Vec<Box<dyn Compressor>> =
            (0..5).map(|_| Box::new(Ndsc::hadamard(16, 4.0, &mut rng)) as _).collect();
        let got = multi_def::run(&problem, &comps_b, &x0, Some(&xs), opts, &mut Rng::seed_from(19));
        assert_trace_bit_identical(&want, &got, &format!("multi_def {participation}"));
    }
}

// ---------------------------------------------------------------------
// Driver-level checks.
// ---------------------------------------------------------------------

#[test]
fn coordinator_driver_is_seed_deterministic() {
    let n = 24;
    let m = 4;
    let cfg = RunConfig {
        n,
        workers: m,
        r: 2.0,
        scheme: SchemeKind::NdscDithered,
        participation: Participation::KofM { k: 3 },
        rounds: 25,
        step: 1e-3,
        batch: 0,
        seed: 77,
        ..Default::default()
    };
    let run_once = || {
        let mut rng = Rng::seed_from(20);
        let (shards, _) = planted_regression_shards(m, 8, n, Loss::Square, &mut rng, false);
        let problem = ShardedProblem::new(shards);
        let spec = Engine::new(Problem::Sharded(&problem), Schedule::Constant(cfg.step), cfg.rounds)
            .with_output(OutputMode::PolyakAverage);
        let mut driver = CoordinatorDriver::new(&cfg);
        let trace = driver.drive(spec, &vec![0.0; n], None, &mut rng);
        (trace, driver.last_metrics.expect("metrics recorded"))
    };
    let (trace_a, metrics_a) = run_once();
    let (trace_b, metrics_b) = run_once();
    assert_bit_identical(&metrics_a, &metrics_b, "coordinator driver x2");
    assert_trace_bit_identical(&trace_a, &trace_b, "coordinator driver traces x2");
    // The trace view carries the metrics content: payloads, participants
    // (k = 3 every round), final iterate.
    assert!(trace_a.records.iter().all(|r| r.participants == 3));
    assert_eq!(trace_a.final_x, metrics_a.final_iterate);
}

#[test]
fn engine_spec_equals_wrapper_composition() {
    // The worked README example: DGD-DEF as an explicit engine
    // composition must equal the dgd_def spec-builder bit-for-bit.
    use kashinflow::opt::engine::feedback::DefFeedback;
    use kashinflow::opt::engine::oracle::ExactGrad;
    use kashinflow::opt::engine::Codecs;
    let mut data_rng = Rng::seed_from(21);
    let (obj, _) =
        planted_regression(60, 16, Tail::GaussianCubed, Tail::Gaussian, 0.1, &mut data_rng);
    let xs = obj.quadratic_minimizer();
    let (l, mu) = obj.smoothness_strong_convexity();
    let step = 2.0 / (l + mu);
    let codec = Ndsc::hadamard(16, 4.0, &mut Rng::seed_from(22));
    let x0 = vec![0.0f32; 16];
    let via_engine = Engine::new(Problem::Single(&obj), Schedule::Constant(step), 50)
        .with_oracle(ExactGrad { obj: &obj })
        .with_codecs(Codecs::Shared(&codec))
        .with_feedback(DefFeedback::new(1, 16))
        .run(&x0, Some(&xs), &mut Rng::seed_from(23));
    let via_wrapper = dgd_def::run(
        &obj,
        &codec,
        &x0,
        Some(&xs),
        DgdDefOptions { step, iters: 50 },
        &mut Rng::seed_from(23),
    );
    assert_trace_bit_identical(&via_engine, &via_wrapper, "engine vs wrapper");
}
