//! Transport-layer acceptance: heterogeneous per-worker budgets with
//! k-of-m partial participation, seed-deterministic SimNet schedules
//! (stragglers + lossy links), and Recorded-trace replay fidelity.

mod common;

use common::assert_bit_identical;
use kashinflow::coordinator::config::{RunConfig, SchemeKind};
use kashinflow::coordinator::metrics::RunMetrics;
use kashinflow::coordinator::transport::{
    LinkModel, Participation, SimNetConfig, Topology, TransportKind,
};
use kashinflow::coordinator::worker::{DatasetGradSource, GradSource};
use kashinflow::coordinator::{replay_distributed, run_distributed};
use kashinflow::data::synthetic::planted_regression_shards;
use kashinflow::linalg::rng::Rng;
use kashinflow::opt::objectives::Loss;

/// Build the standard planted-regression job: shards, eval closure data,
/// compressors — all deterministic in `cfg.seed` and `data_seed`.
fn job(
    cfg: &RunConfig,
    data_seed: u64,
) -> (
    Vec<Box<dyn GradSource>>,
    Vec<std::sync::Arc<dyn kashinflow::quant::Compressor>>,
    Vec<kashinflow::opt::objectives::DatasetObjective>,
) {
    let mut rng = Rng::seed_from(data_seed);
    let (shards, _) =
        planted_regression_shards(cfg.workers, 10, cfg.n, Loss::Square, &mut rng, false);
    let global = shards.clone();
    let comps = cfg.build_compressors(&mut rng);
    let sources: Vec<Box<dyn GradSource>> = shards
        .into_iter()
        .enumerate()
        .map(|(i, obj)| {
            Box::new(DatasetGradSource {
                obj,
                batch: 0,
                rng: Rng::seed_from(300 + i as u64),
                idx: Vec::new(),
            }) as Box<dyn GradSource>
        })
        .collect();
    (sources, comps, global)
}

fn run_job(cfg: &RunConfig, data_seed: u64) -> RunMetrics {
    let (sources, comps, global) = job(cfg, data_seed);
    let m = cfg.workers;
    run_distributed(cfg, vec![0.0; cfg.n], sources, comps, move |x| {
        global.iter().map(|s| s.value(x)).sum::<f32>() / m as f32
    })
}

/// (a) k-of-m partial participation with heterogeneous `R_i` still
/// converges on the quadratic objective, every worker held to its own
/// exact budget.
#[test]
fn kofm_with_heterogeneous_budgets_converges() {
    let n = 32;
    let cfg = RunConfig {
        n,
        workers: 4,
        r: 1.875,
        budgets: Some(vec![0.5, 1.0, 2.0, 4.0]),
        scheme: SchemeKind::NdscDithered,
        participation: Participation::KofM { k: 3 },
        rounds: 300,
        step: 0.02,
        batch: 0,
        seed: 11,
        ..Default::default()
    };
    let metrics = run_job(&cfg, 1);
    assert_eq!(metrics.rejected_messages, 0, "no worker may trip its budget");
    assert!(metrics.rounds.iter().all(|r| r.participants == 3), "k-of-m must hold every round");
    let first = metrics.rounds[0].value;
    let last = metrics.final_value();
    assert!(last < 0.3 * first, "no convergence under 3-of-4: {first} -> {last}");
    // Lockstep: all four workers still *send* every round, each spending
    // exactly its own ⌊n·R_i⌋ on a nonzero gradient. Round 0 is far from
    // the optimum, so the exact per-round spend is 16+32+64+128 bits.
    let per_round: usize = [0.5f32, 1.0, 2.0, 4.0]
        .iter()
        .map(|&r| kashinflow::quant::budget_bits(n, r))
        .sum();
    assert_eq!(per_round, 240);
    assert_eq!(metrics.rounds[0].payload_bits, per_round);
    assert!(metrics.rounds.iter().all(|r| r.payload_bits <= per_round));
}

/// (b) SimNet drop/latency schedules are seed-deterministic: same seed ⇒
/// bit-identical traces (values, participants, traffic); different net
/// seed ⇒ a different straggler/loss schedule.
#[test]
fn simnet_schedules_are_seed_deterministic() {
    let lossy = |net_seed: u64| SimNetConfig {
        seed: net_seed,
        topology: Topology::Chain,
        links: vec![LinkModel {
            base_latency_us: 100,
            jitter_us: 50,
            drop_prob: 0.15,
            bandwidth_bits_per_us: 8.0,
        }],
    };
    let run_with = |net_seed: u64| {
        let cfg = RunConfig {
            n: 24,
            workers: 4,
            r: 2.0,
            scheme: SchemeKind::NdscDithered,
            participation: Participation::KofM { k: 3 },
            transport: TransportKind::SimNet(lossy(net_seed)),
            rounds: 60,
            step: 0.01,
            batch: 0,
            seed: 5,
            ..Default::default()
        };
        run_job(&cfg, 2)
    };
    let a = run_with(77);
    let b = run_with(77);
    assert_bit_identical(&a, &b, "same net seed");
    // With 15% per-hop loss on a chain some rounds must degrade below k.
    assert!(
        a.rounds.iter().any(|r| r.participants < 3),
        "lossy chain never lost a frame — drop model inert?"
    );
    let c = run_with(78);
    let schedule = |m: &RunMetrics| -> Vec<(u32, usize)> {
        m.rounds.iter().map(|r| (r.value.to_bits(), r.participants)).collect()
    };
    assert_ne!(schedule(&a), schedule(&c), "different net seeds must differ");
}

/// Deadline-triggered aggregation over a zero-jitter chain is exactly
/// predictable: worker `i` arrives at `(i+1) * base_latency`, so a 250µs
/// deadline admits precisely workers 0 and 1.
#[test]
fn deadline_cuts_off_chain_stragglers_exactly() {
    let cfg = RunConfig {
        n: 16,
        workers: 4,
        r: 2.0,
        scheme: SchemeKind::Ndsc,
        participation: Participation::Deadline { us: 250 },
        transport: TransportKind::SimNet(SimNetConfig {
            seed: 1,
            topology: Topology::Chain,
            links: vec![LinkModel {
                base_latency_us: 100,
                jitter_us: 0,
                drop_prob: 0.0,
                bandwidth_bits_per_us: 0.0,
            }],
        }),
        rounds: 10,
        step: 0.01,
        batch: 0,
        seed: 3,
        ..Default::default()
    };
    let metrics = run_job(&cfg, 3);
    assert!(
        metrics.rounds.iter().all(|r| r.participants == 2),
        "exactly workers 0 and 1 beat a 250µs deadline on a 100µs/hop chain"
    );
}

/// (c) Recorded traces replay to identical server iterates — including a
/// lossy SimNet schedule and partial participation: the trace alone
/// carries enough (wire bytes + arrival tags) to re-derive every iterate.
#[test]
fn recorded_trace_replays_to_identical_iterates() {
    let path = std::env::temp_dir()
        .join(format!("kf_replay_{}.kftrace", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let net = SimNetConfig {
        seed: 13,
        topology: Topology::Tree { fanout: 2 },
        links: vec![LinkModel {
            base_latency_us: 50,
            jitter_us: 20,
            drop_prob: 0.1,
            bandwidth_bits_per_us: 16.0,
        }],
    };
    let cfg = RunConfig {
        n: 20,
        workers: 5,
        r: 2.0,
        scheme: SchemeKind::NdscDithered,
        participation: Participation::KofM { k: 4 },
        transport: TransportKind::Recorded { path: path.clone(), net: Some(net) },
        rounds: 40,
        step: 0.015,
        batch: 0,
        seed: 17,
        ..Default::default()
    };
    let live = run_job(&cfg, 4);

    // Replay: same config, same setup seed ⇒ same codecs (common
    // randomness), but no workers — the trace is the only input.
    let (_, comps, global) = job(&cfg, 4);
    let m = cfg.workers;
    let replayed = replay_distributed(&cfg, vec![0.0; cfg.n], &comps, &path, move |x| {
        global.iter().map(|s| s.value(x)).sum::<f32>() / m as f32
    });
    assert_bit_identical(&live, &replayed, "live vs replay");
    let _ = std::fs::remove_file(&path);
}
