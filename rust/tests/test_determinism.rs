//! Seed-determinism of the threaded coordinator.
//!
//! Upload arrival order at the server is scheduler-dependent, and the
//! per-round decode may run sequentially or fan out across scoped threads
//! (dimension-gated). Neither may leak into the result: the server sorts
//! uploads by worker id and accumulates the consensus in that fixed order,
//! so the same seed must yield **bit-identical** traces and final iterates
//! across repeated runs *and* across both decode paths. The parallel path
//! is forced at small `n` through the test-only threshold override
//! `RunConfig::parallel_decode_min_dim`.

mod common;

use common::assert_bit_identical;
use kashinflow::coordinator::config::{RunConfig, SchemeKind};
use kashinflow::coordinator::metrics::RunMetrics;
use kashinflow::coordinator::transport::{LinkModel, SimNetConfig, Topology, TransportKind};
use kashinflow::coordinator::run_distributed;
use kashinflow::coordinator::worker::{DatasetGradSource, GradSource};
use kashinflow::data::synthetic::planted_regression_shards;
use kashinflow::linalg::rng::Rng;
use kashinflow::opt::objectives::Loss;

fn run_once(scheme: SchemeKind, parallel_decode_min_dim: usize) -> RunMetrics {
    run_once_over(scheme, parallel_decode_min_dim, TransportKind::InProc)
}

fn run_once_over(
    scheme: SchemeKind,
    parallel_decode_min_dim: usize,
    transport: TransportKind,
) -> RunMetrics {
    let n = 32;
    let m = 4;
    let mut rng = Rng::seed_from(11);
    let (shards, _) = planted_regression_shards(m, 10, n, Loss::Square, &mut rng, false);
    let global = shards.clone();
    let cfg = RunConfig {
        n,
        workers: m,
        r: 2.0,
        scheme,
        rounds: 40,
        step: 0.01,
        batch: 0,
        seed: 123,
        parallel_decode_min_dim,
        transport,
        ..Default::default()
    };
    let comps = cfg.build_compressors(&mut rng);
    let sources: Vec<Box<dyn GradSource>> = shards
        .into_iter()
        .enumerate()
        .map(|(i, obj)| {
            Box::new(DatasetGradSource {
                obj,
                batch: 0,
                rng: Rng::seed_from(200 + i as u64),
                idx: Vec::new(),
            }) as Box<dyn GradSource>
        })
        .collect();
    run_distributed(&cfg, vec![0.0; n], sources, comps, move |x| {
        global.iter().map(|s| s.value(x)).sum::<f32>() / m as f32
    })
}

/// Same seed ⇒ identical trace, run-over-run, with the default
/// (sequential at n = 32) decode path.
#[test]
fn same_seed_same_trace_sequential_decode() {
    let a = run_once(SchemeKind::Ndsc, usize::MAX);
    let b = run_once(SchemeKind::Ndsc, usize::MAX);
    assert_bit_identical(&a, &b, "sequential x2");
}

/// Forcing the scoped-thread decode (threshold 1) must not change a
/// single bit relative to the sequential path — accumulation order is
/// worker-id order in both.
#[test]
fn scoped_thread_decode_matches_sequential_bitwise() {
    let seq = run_once(SchemeKind::Ndsc, usize::MAX);
    let par = run_once(SchemeKind::Ndsc, 1);
    assert_bit_identical(&seq, &par, "sequential vs scoped-threads");
    // and the threaded path is itself reproducible
    let par2 = run_once(SchemeKind::Ndsc, 1);
    assert_bit_identical(&par, &par2, "scoped-threads x2");
}

/// The guarantee holds for a stochastic (dithered) codec too: worker RNGs
/// are forked per worker id, so scheduling cannot reorder their draws.
#[test]
fn dithered_codec_is_seed_deterministic_across_decode_paths() {
    let seq = run_once(SchemeKind::NdscDithered, usize::MAX);
    let par = run_once(SchemeKind::NdscDithered, 1);
    assert_bit_identical(&seq, &par, "dithered sequential vs scoped-threads");
}

/// An ideal SimNet (zero latency, zero jitter, zero drops, infinite
/// bandwidth) must be **bitwise identical** to InProc: the network model
/// consumes no randomness and stamps every frame `at = 0`, so selection,
/// decode order and accumulation cannot differ — over any topology.
#[test]
fn inproc_and_zero_simnet_runs_are_bitwise_identical() {
    for scheme in [SchemeKind::Ndsc, SchemeKind::NdscDithered] {
        let inproc = run_once_over(scheme, usize::MAX, TransportKind::InProc);
        let ideal = run_once_over(
            scheme,
            usize::MAX,
            TransportKind::SimNet(SimNetConfig::ideal()),
        );
        assert_bit_identical(&inproc, &ideal, "inproc vs ideal simnet (star)");
        // Hops multiply latency — and any multiple of zero is zero.
        let chain = run_once_over(
            scheme,
            usize::MAX,
            TransportKind::SimNet(SimNetConfig {
                seed: 987,
                topology: Topology::Chain,
                links: vec![LinkModel::IDEAL],
            }),
        );
        assert_bit_identical(&inproc, &chain, "inproc vs ideal simnet (chain)");
    }
}
