//! Seed-determinism of the threaded coordinator.
//!
//! Upload arrival order at the server is scheduler-dependent, and the
//! per-round decode may run sequentially or fan out across scoped threads
//! (dimension-gated). Neither may leak into the result: the server sorts
//! uploads by worker id and accumulates the consensus in that fixed order,
//! so the same seed must yield **bit-identical** traces and final iterates
//! across repeated runs *and* across both decode paths. The parallel path
//! is forced at small `n` through the test-only threshold override
//! `RunConfig::parallel_decode_min_dim`.

use kashinflow::coordinator::config::{RunConfig, SchemeKind};
use kashinflow::coordinator::metrics::RunMetrics;
use kashinflow::coordinator::run_distributed;
use kashinflow::coordinator::worker::{DatasetGradSource, GradSource};
use kashinflow::data::synthetic::planted_regression_shards;
use kashinflow::linalg::rng::Rng;
use kashinflow::opt::objectives::Loss;

fn run_once(scheme: SchemeKind, parallel_decode_min_dim: usize) -> RunMetrics {
    let n = 32;
    let m = 4;
    let mut rng = Rng::seed_from(11);
    let (shards, _) = planted_regression_shards(m, 10, n, Loss::Square, &mut rng, false);
    let global = shards.clone();
    let cfg = RunConfig {
        n,
        workers: m,
        r: 2.0,
        scheme,
        rounds: 40,
        step: 0.01,
        batch: 0,
        seed: 123,
        parallel_decode_min_dim,
        ..Default::default()
    };
    let comps = cfg.build_compressors(&mut rng);
    let sources: Vec<Box<dyn GradSource>> = shards
        .into_iter()
        .enumerate()
        .map(|(i, obj)| {
            Box::new(DatasetGradSource {
                obj,
                batch: 0,
                rng: Rng::seed_from(200 + i as u64),
                idx: Vec::new(),
            }) as Box<dyn GradSource>
        })
        .collect();
    run_distributed(&cfg, vec![0.0; n], sources, comps, move |x| {
        global.iter().map(|s| s.value(x)).sum::<f32>() / m as f32
    })
}

fn assert_bit_identical(a: &RunMetrics, b: &RunMetrics, label: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{label}: round count");
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(
            ra.value.to_bits(),
            rb.value.to_bits(),
            "{label}: round {} objective diverged ({} vs {})",
            ra.round,
            ra.value,
            rb.value
        );
        assert_eq!(
            ra.mean_local_value.to_bits(),
            rb.mean_local_value.to_bits(),
            "{label}: round {} mean local value diverged",
            ra.round
        );
        assert_eq!(ra.payload_bits, rb.payload_bits, "{label}: round {} bits", ra.round);
    }
    assert_eq!(a.final_iterate.len(), b.final_iterate.len(), "{label}: iterate length");
    for (i, (xa, xb)) in a.final_iterate.iter().zip(&b.final_iterate).enumerate() {
        assert_eq!(
            xa.to_bits(),
            xb.to_bits(),
            "{label}: final iterate coordinate {i} diverged ({xa} vs {xb})"
        );
    }
    assert_eq!(a.total_payload_bits, b.total_payload_bits, "{label}: traffic");
}

/// Same seed ⇒ identical trace, run-over-run, with the default
/// (sequential at n = 32) decode path.
#[test]
fn same_seed_same_trace_sequential_decode() {
    let a = run_once(SchemeKind::Ndsc, usize::MAX);
    let b = run_once(SchemeKind::Ndsc, usize::MAX);
    assert_bit_identical(&a, &b, "sequential x2");
}

/// Forcing the scoped-thread decode (threshold 1) must not change a
/// single bit relative to the sequential path — accumulation order is
/// worker-id order in both.
#[test]
fn scoped_thread_decode_matches_sequential_bitwise() {
    let seq = run_once(SchemeKind::Ndsc, usize::MAX);
    let par = run_once(SchemeKind::Ndsc, 1);
    assert_bit_identical(&seq, &par, "sequential vs scoped-threads");
    // and the threaded path is itself reproducible
    let par2 = run_once(SchemeKind::Ndsc, 1);
    assert_bit_identical(&par, &par2, "scoped-threads x2");
}

/// The guarantee holds for a stochastic (dithered) codec too: worker RNGs
/// are forked per worker id, so scheduling cannot reorder their draws.
#[test]
fn dithered_codec_is_seed_deterministic_across_decode_paths() {
    let seq = run_once(SchemeKind::NdscDithered, usize::MAX);
    let par = run_once(SchemeKind::NdscDithered, 1);
    assert_bit_identical(&seq, &par, "dithered sequential vs scoped-threads");
}
