//! Shape assertions for the paper's headline figure claims (DESIGN.md §5),
//! run on the quick settings of the experiment harness.

use kashinflow::exp;

/// Fig. 1a: NDE-composed schemes beat their plain counterparts on
/// heavy-tailed inputs; NDSC beats naive.
#[test]
fn fig1a_nde_improves_compression() {
    let series = exp::fig1::fig1a(true);
    let get = |name: &str| {
        series
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("missing series {name}"))
    };
    let sd = get("SD");
    let sd_ndh = get("SD+NDH");
    let naive = get("naive");
    let ndh = get("NDH");
    // compare at the last (largest) R
    assert!(sd_ndh.y_at_end() < sd.y_at_end(), "SD+NDH {} !< SD {}", sd_ndh.y_at_end(), sd.y_at_end());
    assert!(ndh.y_at_end() < naive.y_at_end(), "NDH {} !< naive {}", ndh.y_at_end(), naive.y_at_end());
}

/// Fig. 1b: at the largest budget all democratic schemes approach σ and
/// beat the naive quantizer at the smallest budget.
#[test]
fn fig1b_rate_ordering() {
    let series = exp::fig1::fig1b(true);
    let get = |name: &str| series.iter().find(|s| s.name == name).unwrap();
    let sigma = get("unquantized(σ)").y_at_end();
    let naive = get("DQGD(naive)");
    let ndh = get("NDE-Hadamard");
    // At the smallest swept R, NDSC converges strictly faster than naive.
    let naive_first = naive.points.first().unwrap().1;
    let ndh_first = ndh.points.first().unwrap().1;
    assert!(ndh_first <= naive_first + 1e-6, "NDH {ndh_first} vs naive {naive_first} at low R");
    // At the largest R, NDSC is within a whisker of sigma.
    assert!(ndh.y_at_end() <= sigma + 0.06, "NDH {} vs sigma {sigma}", ndh.y_at_end());
}

/// Fig. 1c: NDE is orders of magnitude faster than the LP; LV sits
/// between; all grow with n.
#[test]
fn fig1c_wallclock_ordering() {
    let series = exp::fig1::fig1c(true);
    let get = |name: &str| series.iter().find(|s| s.name == name).unwrap();
    let nde = get("NDE(Sᵀy)");
    let lv = get("DE(LV-iter)");
    let lp = get("DE(LP/CVX-like)");
    // compare at the largest n both have
    let last_common = nde.points.len().min(lv.points.len()) - 1;
    assert!(nde.points[last_common].1 < lv.points[last_common].1);
    assert!(lp.y_at_end() > nde.points[lp.points.len() - 1].1 * 5.0, "LP should dwarf NDE");
}

/// Fig. 3a: on the Student-t planted model (Gaussian *data* rows, so the
/// gradients are not heavy-tailed) NDSC must stay competitive with naive
/// dithering at equal budget (the paper's curves nearly overlap early on).
#[test]
fn fig3a_ndsc_competitive_on_student_t() {
    let series = exp::fig3::fig3a(true);
    let naive = series.iter().find(|s| s.name.starts_with("naive")).unwrap();
    let ndsc = series.iter().find(|s| s.name.starts_with("ndsc")).unwrap();
    assert!(
        ndsc.y_at_end() <= naive.y_at_end() * 1.5,
        "ndsc {} vs naive {}",
        ndsc.y_at_end(),
        naive.y_at_end()
    );
}

/// Fig. 5: on heavy-tailed (Gaussian³) data — where the embedding's
/// flattening matters — NDSC strictly beats naive at the sub-linear
/// budget R = 0.5 and at R = 1.
#[test]
fn fig5_ndsc_beats_naive_on_heavy_tails() {
    let series = exp::fig3::fig5(true);
    for r in ["R0.5", "R1"] {
        let naive = series.iter().find(|s| s.name == format!("naive-{r}")).unwrap();
        let ndsc = series.iter().find(|s| s.name == format!("ndsc-{r}")).unwrap();
        assert!(
            ndsc.y_at_end() < naive.y_at_end(),
            "{r}: ndsc {} !< naive {}",
            ndsc.y_at_end(),
            naive.y_at_end()
        );
    }
}

/// Figs. 8/9: ‖x_nd‖∞ decreases in N while ‖x_nd‖∞·√N stays ≈ flat.
#[test]
fn fig8_9_linf_scaling() {
    let series = exp::appendix::fig8_9(true);
    let inf = series.iter().find(|s| s.name == "linf-gauss3").unwrap();
    let scaled = series.iter().find(|s| s.name == "linf*sqrtN-gauss3").unwrap();
    assert!(inf.points.last().unwrap().1 < inf.points.first().unwrap().1 * 0.5);
    let (min, max) = scaled
        .points
        .iter()
        .fold((f32::MAX, 0.0f32), |(lo, hi), &(_, y)| (lo.min(y), hi.max(y)));
    assert!(max / min < 3.0, "linf*sqrtN should be ~flat: [{min}, {max}]");
}

/// Figs. 11/12: DSC quantization error *increases* with N (the App. N
/// conclusion: pick λ close to 1).
#[test]
fn fig12_error_increases_with_big_n() {
    let series = exp::appendix::fig11_12(true);
    let err = series.iter().find(|s| s.name.starts_with("DSC-quant-err")).unwrap();
    let first = err.points.first().unwrap().1;
    let last = err.points.last().unwrap().1;
    assert!(last > first, "error should grow with N: {first} -> {last}");
}
