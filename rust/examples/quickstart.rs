//! Quickstart: compress a heavy-tailed gradient under a 2-bit budget with
//! NDSC vs naive quantization, then run bit-budgeted gradient descent
//! (DGD-DEF) on a small least-squares problem.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use kashinflow::data::synthetic::{planted_regression, Tail};
use kashinflow::linalg::rng::Rng;
use kashinflow::linalg::vecops::{dist2, norm2};
use kashinflow::opt::dgd_def::{self, DgdDefOptions};
use kashinflow::opt::gd;
use kashinflow::quant::gain_shape::NaiveUniform;
use kashinflow::quant::ndsc::Ndsc;
use kashinflow::quant::Compressor;

fn main() {
    let mut rng = Rng::seed_from(7);

    // --- 1. Vector compression under a strict bit budget -----------------
    let n = 1000;
    let r = 2.0; // bits per dimension
    let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();

    let ndsc = Ndsc::hadamard(n, r, &mut rng);
    let naive = NaiveUniform::new(n, r);
    println!("compressing a heavy-tailed y in R^{n} at R = {r} bits/dim:");
    for c in [&ndsc as &dyn Compressor, &naive] {
        let msg = c.compress(&y, &mut rng);
        let yhat = c.decompress(&msg);
        println!(
            "  {:<22} {:>5} payload bits ({:.2} b/dim)   rel l2 error {:.4}",
            c.name(),
            msg.payload_bits,
            msg.rate(),
            dist2(&yhat, &y) / norm2(&y)
        );
    }

    // --- 2. Bit-budgeted optimization: DGD-DEF (Alg. 1) ------------------
    let (obj, _) = planted_regression(200, 116, Tail::GaussianCubed, Tail::Gaussian, 0.1, &mut rng);
    let xs = obj.quadratic_minimizer();
    let (l, mu) = obj.smoothness_strong_convexity();
    println!("\nleast squares n=116: L={l:.1} mu={mu:.3} sigma={:.4}", gd::sigma(l, mu));
    let opts = DgdDefOptions::optimal(l, mu, 150);
    let mut last_trace = None;
    for r in [1.0f32, 3.0, 6.0] {
        let c = Ndsc::hadamard(116, r, &mut rng);
        let tr = dgd_def::run(&obj, &c, &vec![0.0; 116], Some(&xs), opts, &mut rng);
        println!(
            "  DGD-DEF + NDSC R={r}: empirical rate {:.4}  final ||x-x*|| {:.2e}  ({} bits/iter)",
            tr.empirical_rate(),
            tr.records.last().unwrap().dist_to_opt,
            kashinflow::quant::budget_bits(116, r),
        );
        last_trace = Some(tr);
    }

    // Engine traces speak the same per-round CSV schema as the
    // distributed coordinator (round,value,...,participants,wall_us) —
    // one writer for both runtimes.
    let csv = last_trace.expect("loop ran").to_csv();
    println!("\nper-round CSV (first 3 rows of the R=6 run):");
    for line in csv.lines().take(3) {
        println!("  {line}");
    }
    println!("\n(see `repro figures` for the full paper reproduction)");
}
