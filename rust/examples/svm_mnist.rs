//! SVM training on MNIST(-like) digits with DQ-PSGD at a sub-linear bit
//! budget — the Fig. 2c/2d workload as a standalone application.
//!
//! ```sh
//! cargo run --release --example svm_mnist -- r=0.1 rounds=400
//! ```
//!
//! Set `MNIST_DIR=/path/to/idx` to use real MNIST; otherwise the built-in
//! deterministic digit generator is used (DESIGN.md §3).

use kashinflow::coordinator::config::RunConfig;
use kashinflow::data::mnist_like;
use kashinflow::linalg::rng::Rng;
use kashinflow::opt::dq_psgd::{self, DqPsgdOptions};
use kashinflow::opt::oracle::MinibatchOracle;
use kashinflow::opt::projection::Domain;
use kashinflow::quant::compose::EmbeddedCompressor;
use kashinflow::quant::randk::RandK;
use kashinflow::quant::Compressor;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cfg = RunConfig::parse_args(&args).unwrap_or_else(|e| {
        eprintln!("config error: {e}");
        std::process::exit(2);
    });
    let r = if cfg.r == RunConfig::default().r { 0.1 } else { cfg.r };
    let rounds = if cfg.rounds == RunConfig::default().rounds { 400 } else { cfg.rounds };

    let mut rng = Rng::seed_from(cfg.seed + 1);
    let data = mnist_like::binary_digits(400, &mut rng);
    let (train, test) = data.split(300);
    let obj = train.svm_objective();
    let test_obj = test.svm_objective();
    let n = mnist_like::DIM;
    let k = kashinflow::quant::budget_bits(n, r).max(1); // k coords at 1 bit

    println!("SVM 0-vs-1, n={n}, train={}, test={}, R={r} ({k} bits/round)", train.m, test.m);
    for with_nde in [false, true] {
        let compressor: Box<dyn Compressor> = if with_nde {
            let frame = kashinflow::linalg::frames::HadamardFrame::new(n, &mut rng);
            let big_n = kashinflow::linalg::fwht::next_pow2(n);
            Box::new(EmbeddedCompressor::nde(
                Box::new(frame),
                Box::new(RandK::new(big_n, k, 1).unbiased()),
            ))
        } else {
            Box::new(RandK::new(n, k, 1).unbiased())
        };
        let mut oracle = MinibatchOracle::new(&obj, 30, Rng::seed_from(cfg.seed + 2));
        let opts = DqPsgdOptions {
            step: 1.0, // the paper's nominal α = 1 for this experiment
            iters: rounds,
            domain: Domain::L2Ball { radius: 50.0 },
            drop_prob: 0.0,
        };
        let trace =
            dq_psgd::run(&obj, &mut oracle, compressor.as_ref(), &vec![0.0; n], None, opts, &mut rng);
        println!(
            "  {:<22} objective {:.4} -> {:.4}   test error {:.2}%   ({} payload bits/iter)",
            compressor.name(),
            trace.records.first().unwrap().value,
            trace.final_value(),
            100.0 * test_obj.classification_error(&trace.final_x),
            trace.records.last().unwrap().payload_bits,
        );
    }
}
