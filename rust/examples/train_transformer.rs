//! End-to-end driver: federated training of the AOT-compiled transformer
//! LM through the full three-layer stack.
//!
//!   L1  Pallas FWHT kernel (inside the model_grad_embed artifact)
//!   L2  JAX transformer fwd/bwd, lowered once to artifacts/*.hlo.txt
//!   L3  this Rust coordinator: m workers, NDSC-quantized gradients over
//!       byte-accounted channels, consensus parameter server
//!
//! Prerequisite: `make artifacts`. Typical run (a few minutes on CPU):
//!
//! ```sh
//! cargo run --release --example train_transformer -- rounds=300 workers=4 r=4 scheme=ndsc
//! ```
//!
//! Compare against `scheme=naive r=4` (stalls) and `scheme=naive r=6`
//! (recovers) to reproduce the Fig. 3b shape; the loss curve is printed
//! as CSV for EXPERIMENTS.md.

use kashinflow::coordinator::config::RunConfig;
use kashinflow::exp::transformer::train_federated;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = RunConfig {
        workers: 4,
        r: 4.0,
        rounds: 300,
        step: 0.1,
        seed: 7,
        ..Default::default()
    };
    if !args.is_empty() {
        // n is fixed by the artifact; parse the rest over our defaults.
        match RunConfig::parse_args(&args) {
            Ok(c) => {
                cfg.workers = c.workers;
                cfg.r = c.r;
                cfg.scheme = c.scheme;
                cfg.spec_override = c.spec_override;
                cfg.rounds = c.rounds;
                cfg.step = c.step;
                cfg.seed = c.seed;
            }
            Err(e) => {
                eprintln!("config error: {e}");
                std::process::exit(2);
            }
        }
    }
    eprintln!(
        "federated transformer: scheme={} R={} workers={} rounds={} step={}",
        cfg.scheme_name(),
        cfg.r,
        cfg.workers,
        cfg.rounds,
        cfg.step
    );
    match train_federated(cfg.compressor_spec(), cfg.r, cfg.workers, cfg.rounds, cfg.step, cfg.seed)
    {
        Ok(metrics) => {
            print!("{}", metrics.to_csv());
            let first = metrics.rounds.first().map(|r| r.value).unwrap_or(f32::NAN);
            eprintln!(
                "loss {first:.4} -> {:.4} over {} rounds; {:.3} bits/dim/worker/round; \
                 uplink payload {:.2} MB total; {} rejected messages",
                metrics.final_value(),
                metrics.rounds.len(),
                metrics.mean_rate(metrics.final_iterate.len(), cfg.workers),
                metrics.total_payload_bits as f64 / 8e6,
                metrics.rejected_messages
            );
        }
        Err(e) => {
            eprintln!("run `make artifacts` first — {e:#}");
            std::process::exit(1);
        }
    }
}
