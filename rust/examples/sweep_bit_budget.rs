//! Sweep the bit budget R and report DGD-DEF's empirical convergence rate
//! per scheme — the Fig. 1b experiment as a standalone tool with
//! configurable problem size.
//!
//! ```sh
//! cargo run --release --example sweep_bit_budget -- n=116 rounds=150
//! ```

use kashinflow::coordinator::config::RunConfig;
use kashinflow::data::synthetic::{planted_regression, Tail};
use kashinflow::linalg::rng::Rng;
use kashinflow::opt::dgd_def::{self, DgdDefOptions};
use kashinflow::opt::gd;
use kashinflow::quant::gain_shape::NaiveUniform;
use kashinflow::quant::ndsc::Ndsc;
use kashinflow::quant::Compressor;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = RunConfig { n: 116, rounds: 150, ..Default::default() };
    if !args.is_empty() {
        cfg = RunConfig::parse_args(&args).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        });
    }
    let n = cfg.n;
    let mut rng = Rng::seed_from(cfg.seed + 5);
    let (obj, _) =
        planted_regression(2 * n, n, Tail::GaussianCubed, Tail::Gaussian, 0.1, &mut rng);
    let xs = obj.quadratic_minimizer();
    let (l, mu) = obj.smoothness_strong_convexity();
    let sigma = gd::sigma(l, mu);
    let opts = DgdDefOptions::optimal(l, mu, cfg.rounds);
    println!("n={n}  L={l:.2}  mu={mu:.4}  sigma={sigma:.4}  (rate 1.0 = diverged)");
    println!("{:>6} {:>14} {:>14} {:>14}", "R", "naive", "NDSC-H", "NDSC-O");
    for r in [0.5f32, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 10.0, 12.0] {
        let mut rates = Vec::new();
        let schemes: Vec<Box<dyn Compressor>> = vec![
            Box::new(NaiveUniform::new(n, r)),
            Box::new(Ndsc::hadamard(n, r, &mut rng)),
            Box::new(Ndsc::orthonormal(n, r, &mut rng)),
        ];
        for c in &schemes {
            let tr = dgd_def::run(&obj, c.as_ref(), &vec![0.0; n], Some(&xs), opts, &mut rng);
            rates.push(tr.empirical_rate());
        }
        println!("{r:>6.1} {:>14.4} {:>14.4} {:>14.4}", rates[0], rates[1], rates[2]);
    }
    println!("\nNDSC should reach sigma ({sigma:.4}) at R ≈ log2(beta/sigma), naive needs ~log2(sqrt(n)/sigma).");
}
