//! Multi-worker regression through the threaded coordinator — the Fig. 3a
//! / Appendix I workload as a standalone application, with full traffic
//! accounting.
//!
//! ```sh
//! cargo run --release --example multiworker_regression -- \
//!     n=30 workers=10 r=1 scheme=ndsc-dith rounds=300 step=0.03 batch=5
//! ```

use kashinflow::coordinator::config::RunConfig;
use kashinflow::coordinator::worker::DatasetGradSource;
use kashinflow::data::synthetic::planted_regression_shards;
use kashinflow::linalg::rng::Rng;
use kashinflow::opt::objectives::Loss;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = RunConfig { step: 0.03, ..Default::default() };
    if !args.is_empty() {
        cfg = RunConfig::parse_args(&args).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        });
    }
    let mut rng = Rng::seed_from(cfg.seed);
    let (shards, x_star) =
        planted_regression_shards(cfg.workers, 10, cfg.n, Loss::Square, &mut rng, true);
    let global = shards.clone();
    let comps = cfg.build_compressors(&mut rng);
    let sources: Vec<Box<dyn kashinflow::coordinator::worker::GradSource>> = shards
        .into_iter()
        .enumerate()
        .map(|(i, obj)| {
            Box::new(DatasetGradSource {
                obj,
                batch: cfg.batch,
                rng: Rng::seed_from(cfg.seed ^ (11 + i as u64)),
                idx: Vec::new(),
            }) as Box<dyn kashinflow::coordinator::worker::GradSource>
        })
        .collect();
    let m = cfg.workers;
    let metrics = kashinflow::coordinator::run_distributed(
        &cfg,
        vec![0.0; cfg.n],
        sources,
        comps,
        move |x| global.iter().map(|s| s.value(x)).sum::<f32>() / m as f32,
    );
    // Print a thinned loss curve + summary.
    for (i, r) in metrics.rounds.iter().enumerate() {
        if i % (metrics.rounds.len() / 15).max(1) == 0 || i + 1 == metrics.rounds.len() {
            println!("round {:>5}  f(x) {:>12.6}  bits {:>8}", r.round, r.value, r.payload_bits);
        }
    }
    println!(
        "scheme={} R={}: ||x_T - x*|| = {:.4}, uplink rate {:.3} bits/dim/worker/round, \
         total payload {:.1} KB, overhead {:.1} KB, rejected {}",
        cfg.scheme_name(),
        cfg.r,
        kashinflow::linalg::vecops::dist2(&metrics.final_iterate, &x_star),
        metrics.mean_rate(cfg.n, cfg.workers),
        metrics.total_payload_bits as f64 / 8e3,
        metrics.total_overhead_bits as f64 / 8e3,
        metrics.rejected_messages
    );
}
