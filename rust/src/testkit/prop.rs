//! Miniature property-testing harness (stand-in for `proptest`, which is
//! unavailable offline).
//!
//! ```no_run
//! // (no_run: rustdoc test binaries miss this image's libstdc++ rpath)
//! use kashinflow::testkit::prop::{forall, Cases};
//! forall(Cases::new("abs is non-negative", 100), |rng, case| {
//!     let x = rng.gaussian_f32();
//!     assert!(x.abs() >= 0.0, "case {case}: {x}");
//! });
//! ```
//!
//! On failure the panic message includes the master seed and the case index
//! so the exact input is replayable with
//! `Cases::new(..).seed(s).only(case_idx)`.

use crate::linalg::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Cases {
    pub name: &'static str,
    pub n_cases: usize,
    pub master_seed: u64,
    pub only: Option<usize>,
}

impl Cases {
    pub fn new(name: &'static str, n_cases: usize) -> Self {
        Cases { name, n_cases, master_seed: 0xC0FFEE, only: None }
    }

    /// Override the master seed (for replay).
    pub fn seed(mut self, s: u64) -> Self {
        self.master_seed = s;
        self
    }

    /// Run only one case index (for replay / shrinking by hand).
    pub fn only(mut self, idx: usize) -> Self {
        self.only = Some(idx);
        self
    }
}

/// Run `body` over `cases.n_cases` independent RNG streams. Each case gets
/// an RNG deterministically derived from `(master_seed, case_idx)`, so a
/// failing case reproduces in isolation.
pub fn forall<F: FnMut(&mut Rng, usize)>(cases: Cases, mut body: F) {
    let run_one = |idx: usize, body: &mut F| {
        let mut rng = Rng::seed_from(cases.master_seed ^ (idx as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng, idx);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{}' failed at case {idx} (replay: Cases::new(..).seed({:#x}).only({idx})): {msg}",
                cases.name, cases.master_seed
            );
        }
    };
    if let Some(idx) = cases.only {
        run_one(idx, &mut body);
        return;
    }
    for idx in 0..cases.n_cases {
        run_one(idx, &mut body);
    }
}

/// Common generators for property tests.
pub mod gen {
    use crate::linalg::rng::Rng;

    /// A random vector with one of several "shapes" the paper's inputs take:
    /// Gaussian, heavy-tailed Gaussian³, Student-t(1), sparse, constant and
    /// one-hot — the adversarial cases for quantizers.
    pub fn vector(rng: &mut Rng, n: usize) -> Vec<f32> {
        match rng.below(6) {
            0 => (0..n).map(|_| rng.gaussian_f32()).collect(),
            1 => (0..n).map(|_| rng.gaussian_cubed()).collect(),
            2 => (0..n).map(|_| rng.student_t(1)).collect(),
            3 => {
                // sparse: ~10% support
                (0..n)
                    .map(|_| if rng.bernoulli(0.1) { rng.gaussian_cubed() } else { 0.0 })
                    .collect()
            }
            4 => vec![rng.gaussian_f32(); n],
            _ => {
                let mut v = vec![0.0; n];
                v[rng.below(n)] = rng.gaussian_cubed() + 1.0;
                v
            }
        }
    }

    /// A non-zero vector (quantizers normalize by the norm).
    pub fn nonzero_vector(rng: &mut Rng, n: usize) -> Vec<f32> {
        loop {
            let v = vector(rng, n);
            if v.iter().any(|&x| x != 0.0 && x.is_finite()) {
                return v;
            }
        }
    }

    /// A dimension in the ranges the paper sweeps.
    pub fn dim(rng: &mut Rng) -> usize {
        [3, 8, 16, 30, 31, 100, 116, 128, 257, 784, 1000][rng.below(11)]
    }

    /// A bit budget R covering sub-linear, unit and high-budget regimes.
    pub fn bit_budget(rng: &mut Rng) -> f32 {
        [0.1, 0.25, 0.5, 1.0, 2.0, 3.0, 4.0, 6.0, 8.0][rng.below(9)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        forall(Cases::new("trivial", 50), |rng, _| {
            let x = rng.gaussian_f32();
            assert!(x.is_finite());
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed at case 0")]
    fn reports_failing_case() {
        forall(Cases::new("always-false", 10), |_, _| {
            panic!("boom");
        });
    }

    #[test]
    fn only_replays_single_case() {
        let mut ran = 0;
        forall(Cases::new("only", 100).only(7), |_, idx| {
            assert_eq!(idx, 7);
        });
        ran += 1;
        assert_eq!(ran, 1);
    }

    #[test]
    fn nonzero_vector_is_nonzero() {
        forall(Cases::new("nonzero", 100), |rng, _| {
            let n = gen::dim(rng);
            let v = gen::nonzero_vector(rng, n);
            assert!(v.iter().any(|&x| x != 0.0));
        });
    }
}
