//! Micro-benchmark harness (stand-in for `criterion`, unavailable offline).
//!
//! Each `rust/benches/*.rs` target is built with `harness = false` and calls
//! [`Bencher::run`] per case. The harness warms up, collects wall-clock
//! samples, and prints `name  median  mean  p95  [throughput]` rows plus a
//! machine-readable `BENCH\t...` line consumed by `EXPERIMENTS.md` tooling.
//!
//! [`Bencher::from_env`] selects smoke settings when `BENCH_SMOKE` is set
//! (what the CI bench job uses), and [`Bencher::save_json`] dumps the
//! collected stats as a JSON array (e.g. `BENCH_hotpath.json`) so
//! regressions diff mechanically across PRs.

use std::time::{Duration, Instant};

/// Result statistics of one benchmark case.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub samples: usize,
    /// Sustained throughput (bytes processed per second, from the median
    /// sample); `None` unless the case was run via [`Bencher::run_bytes`].
    pub bytes_per_sec: Option<f64>,
}

/// Micro-benchmark runner.
pub struct Bencher {
    /// Target time to spend measuring each case.
    pub measure_time: Duration,
    /// Warmup time before measuring.
    pub warmup_time: Duration,
    /// Cap on recorded samples.
    pub max_samples: usize,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            measure_time: Duration::from_millis(600),
            warmup_time: Duration::from_millis(150),
            max_samples: 512,
            results: Vec::new(),
        }
    }
}

/// Prevent the optimizer from eliding a computed value (stable `black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fast settings for CI smoke runs.
    pub fn quick() -> Self {
        Bencher {
            measure_time: Duration::from_millis(120),
            warmup_time: Duration::from_millis(30),
            max_samples: 64,
            results: Vec::new(),
        }
    }

    /// [`Bencher::quick`] when the `BENCH_SMOKE` env var is set (CI),
    /// full settings otherwise.
    pub fn from_env() -> Self {
        if std::env::var_os("BENCH_SMOKE").is_some() {
            Self::quick()
        } else {
            Self::new()
        }
    }

    /// Time `f` repeatedly; `f` should perform one logical operation.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        // Warmup + estimate per-iter cost.
        let warm_start = Instant::now();
        let mut iters_per_sample = 1usize;
        let mut one = Duration::ZERO;
        while warm_start.elapsed() < self.warmup_time {
            let t = Instant::now();
            f();
            one = t.elapsed();
        }
        if one < Duration::from_micros(50) && !one.is_zero() {
            iters_per_sample =
                (Duration::from_micros(50).as_nanos() / one.as_nanos().max(1)) as usize + 1;
        } else if one.is_zero() {
            iters_per_sample = 1000;
        }

        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure_time && samples.len() < self.max_samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            samples.push(t.elapsed() / iters_per_sample as u32);
        }
        samples.sort_unstable();
        let median = samples[samples.len() / 2];
        let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let stats = Stats {
            name: name.to_string(),
            median,
            mean,
            p95,
            samples: samples.len(),
            bytes_per_sec: None,
        };
        println!(
            "{:<48} median {:>12?}  mean {:>12?}  p95 {:>12?}  ({} samples)",
            stats.name, stats.median, stats.mean, stats.p95, stats.samples
        );
        println!(
            "BENCH\t{}\t{}\t{}\t{}",
            stats.name,
            stats.median.as_nanos(),
            stats.mean.as_nanos(),
            stats.p95.as_nanos()
        );
        self.results.push(stats.clone());
        stats
    }

    /// Like [`run`] but also reports elements/second throughput.
    pub fn run_throughput<F: FnMut()>(&mut self, name: &str, elems: usize, f: F) -> Stats {
        let stats = self.run(name, f);
        let eps = elems as f64 / stats.median.as_secs_f64();
        println!("{:<48} throughput {:>12.3e} elems/s", name, eps);
        stats
    }

    /// Like [`run`] but records a bytes/second throughput column for the
    /// case, where `bytes` is the data volume one call of `f` touches
    /// (e.g. `n * 4` for one in-place f32 transform). The figure is stored
    /// on the [`Stats`] and emitted by [`Bencher::to_json`], so
    /// `BENCH_hotpath.json` carries an absolute bandwidth column that is
    /// comparable across vector sizes.
    pub fn run_bytes<F: FnMut()>(&mut self, name: &str, bytes: usize, f: F) -> Stats {
        let mut stats = self.run(name, f);
        let bps = bytes as f64 / stats.median.as_secs_f64().max(f64::MIN_POSITIVE);
        stats.bytes_per_sec = Some(bps);
        if let Some(last) = self.results.last_mut() {
            last.bytes_per_sec = Some(bps);
        }
        println!("{:<48} throughput {:>12.3e} bytes/s", name, bps);
        stats
    }

    /// All collected results.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Serialize every collected case as a JSON array (no external crates:
    /// names are escaped manually, durations reported in nanoseconds).
    pub fn to_json(&self) -> String {
        let mut s = String::from("[\n");
        for (i, r) in self.results.iter().enumerate() {
            let name: String = r
                .name
                .chars()
                .flat_map(|c| match c {
                    '"' | '\\' => vec!['\\', c],
                    _ => vec![c],
                })
                .collect();
            let bps = match r.bytes_per_sec {
                Some(b) => format!("{b:.1}"),
                None => "null".to_string(),
            };
            s.push_str(&format!(
                "  {{\"name\": \"{}\", \"median_ns\": {}, \"mean_ns\": {}, \"p95_ns\": {}, \"samples\": {}, \"bytes_per_sec\": {}}}{}\n",
                name,
                r.median.as_nanos(),
                r.mean.as_nanos(),
                r.p95.as_nanos(),
                r.samples,
                bps,
                if i + 1 == self.results.len() { "" } else { "," }
            ));
        }
        s.push_str("]\n");
        s
    }

    /// Write [`Bencher::to_json`] to `path` (best-effort: benches must not
    /// fail on a read-only checkout; the error is printed, not raised).
    pub fn save_json(&self, path: &str) {
        match std::fs::write(path, self.to_json()) {
            Ok(()) => println!("wrote {path} ({} cases)", self.results.len()),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_plausible() {
        let mut b = Bencher::quick();
        let mut acc = 0u64;
        let s = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.median < Duration::from_millis(1));
        assert!(s.samples > 0);
    }

    #[test]
    fn json_dump_is_well_formed() {
        let mut b = Bencher::quick();
        b.run("case \"a\"", || {
            black_box(1 + 1);
        });
        let j = b.to_json();
        assert!(j.trim_start().starts_with('['));
        assert!(j.trim_end().ends_with(']'));
        assert!(j.contains("\"median_ns\""));
        assert!(j.contains("case \\\"a\\\""));
    }

    #[test]
    fn bytes_column_recorded_and_serialized() {
        let mut b = Bencher::quick();
        let s = b.run_bytes("copy-4k", 4096, || {
            black_box(1 + 1);
        });
        assert!(s.bytes_per_sec.unwrap() > 0.0);
        assert_eq!(s.bytes_per_sec, b.results()[0].bytes_per_sec);
        b.run("no-bytes", || {
            black_box(2 + 2);
        });
        let j = b.to_json();
        assert!(j.contains("\"bytes_per_sec\""));
        assert!(j.contains("\"bytes_per_sec\": null")); // the run() case
    }

    #[test]
    fn ordering_of_costs() {
        let mut b = Bencher::quick();
        let small = b.run("sum-1k", || {
            let v: f64 = (0..1_000).map(|i| i as f64).sum();
            black_box(v);
        });
        let big = b.run("sum-100k", || {
            let v: f64 = (0..100_000).map(|i| i as f64).sum();
            black_box(v);
        });
        assert!(big.median > small.median);
    }
}
