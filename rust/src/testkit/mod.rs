//! In-tree testing & benchmarking substrate.
//!
//! The build image is fully offline and ships neither `proptest` nor
//! `criterion`, so this module provides the two pieces the test/bench suite
//! needs:
//!
//! * [`prop`] — a miniature property-testing harness: run a closure over
//!   many seeded random cases, report the failing seed for replay.
//! * [`bench`] — a micro-benchmark timer with warmup, repeated samples and
//!   criterion-style median/p95 reporting, used by every `rust/benches/*`
//!   target (built with `harness = false`).

pub mod bench;
pub mod prop;
