//! `repro serve` — the serving-layer load driver: **jobs × budget ×
//! scheduler-policy** sweeps over a heterogeneous tenant mix, plus a
//! lifecycle drill that pauses, resumes and cancels jobs mid-run.
//!
//! Each cell builds a mixed fleet (subspace / dithered / sparsified /
//! fixed-rate tenants at budgets from 0.25 to 4 bits/dim, single- and
//! multi-worker), arbitrates it under a global bits-per-round budget set
//! as a fraction of the aggregate demand, runs it to completion and
//! reports per-job convergence plus aggregate throughput. The grid is
//! printed as a table and saved to `BENCH_serve.json` (same convention
//! as `BENCH_transport.json`) so serving regressions diff mechanically
//! across PRs.
//!
//! ```text
//! repro serve [--quick] [jobs=8] [n=64] [rounds=150] [seed=7] [policy=drr|adaptive|both]
//! ```

use std::time::Instant;

use crate::quant::budget_bits;
use crate::quant::registry::CompressorSpec;
use crate::serve::{JobServer, JobSpec, Policy};

/// One row of the tenant-mix template the sweep cycles through:
/// `(scheme, R, workers, error-feedback)`.
const MIX: [(&str, f32, usize, bool); 8] = [
    ("ndsc-dith", 1.0, 1, false),
    ("sd", 0.5, 1, false),
    ("topk1b", 2.0, 1, false),
    ("qsgd", 4.0, 2, false),
    ("ndsc", 1.0, 1, true),
    ("randk1b", 0.25, 1, false),
    ("dsc-dith", 1.0, 2, false),
    ("vqsgd", 0.5, 1, false),
];

/// The heterogeneous job mix the sweep (and `bench_serve`) submits:
/// `count` specs cycled from the eight-row tenant template above
/// (subspace / dithered / sparsified / fixed-rate schemes, budgets from
/// 0.25 to 4 bits/dim, single- and multi-worker, with one DEF-feedback
/// tenant), seeded `base_seed + index`.
pub fn job_mix(count: usize, n: usize, rounds: usize, base_seed: u64) -> Vec<JobSpec> {
    (0..count)
        .map(|i| {
            let (scheme, r, workers, def) = MIX[i % MIX.len()];
            let mut s = JobSpec::new(
                format!("job{i}-{scheme}"),
                CompressorSpec::parse(scheme).expect("mix schemes are canonical"),
                r,
                n,
                rounds,
                base_seed + i as u64,
            )
            .with_workers(workers);
            if def {
                s = s.with_def_feedback();
            }
            s
        })
        .collect()
}

/// Aggregate per-round demand of a spec list at their requested budgets.
fn demand_bits(specs: &[JobSpec]) -> usize {
    specs.iter().map(|s| s.workers * budget_bits(s.n, s.r)).sum()
}

struct ServeCell {
    jobs: usize,
    policy: Policy,
    budget_frac: f32,
    budget_bits: usize,
    admitted: usize,
    rejected: usize,
    fleet_rounds: usize,
    served_job_rounds: u64,
    rounds_per_sec: f64,
    utilization: f32,
    mean_final_value: f32,
}

fn run_cell(jobs: usize, n: usize, rounds: usize, seed: u64, policy: Policy, frac: f32) -> ServeCell {
    let specs = job_mix(jobs, n, rounds, seed);
    let budget = ((demand_bits(&specs) as f32 * frac) as usize).max(1);
    let mut srv = JobServer::new(budget, policy);
    let mut admitted = 0usize;
    let mut rejected = 0usize;
    for spec in specs {
        match srv.submit(spec) {
            Ok(_) => admitted += 1,
            Err(_) => rejected += 1,
        }
    }
    // Under a tight budget a job is served every few fleet rounds, so
    // completion needs a comfortable multiple of the per-job horizon.
    let cap = rounds * (jobs.max(1)) * 8;
    let t0 = Instant::now();
    let fleet_rounds = srv.run(cap);
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    let m = srv.metrics();
    let finals: Vec<f32> = srv
        .job_ids()
        .filter_map(|id| srv.job(id))
        .filter(|j| j.is_complete())
        .map(|j| j.trace().final_value())
        .collect();
    let mean_final_value = if finals.is_empty() {
        f32::NAN
    } else {
        finals.iter().sum::<f32>() / finals.len() as f32
    };
    ServeCell {
        jobs,
        policy,
        budget_frac: frac,
        budget_bits: budget,
        admitted,
        rejected,
        fleet_rounds,
        served_job_rounds: m.served_job_rounds(),
        rounds_per_sec: m.served_job_rounds() as f64 / secs,
        utilization: m.utilization(),
        mean_final_value,
    }
}

fn cells_to_json(cells: &[ServeCell]) -> String {
    let mut s = String::from("[\n");
    for (i, c) in cells.iter().enumerate() {
        // JSON has no NaN literal: a cell with no finished job (e.g. all
        // tenants rejected under a starvation budget) reports `null`.
        let mean_final = if c.mean_final_value.is_finite() {
            c.mean_final_value.to_string()
        } else {
            "null".to_string()
        };
        s.push_str(&format!(
            "  {{\"source\": \"repro-serve\", \"jobs\": {}, \"policy\": \"{}\", \
             \"budget_frac\": {}, \"budget_bits\": {}, \
             \"admitted\": {}, \"rejected\": {}, \"fleet_rounds\": {}, \
             \"served_job_rounds\": {}, \"rounds_per_sec\": {}, \"utilization\": {}, \
             \"mean_final_value\": {mean_final}}}{}\n",
            c.jobs,
            c.policy,
            c.budget_frac,
            c.budget_bits,
            c.admitted,
            c.rejected,
            c.fleet_rounds,
            c.served_job_rounds,
            c.rounds_per_sec,
            c.utilization,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    s.push_str("]\n");
    s
}

fn usage_and_exit() -> ! {
    eprintln!(
        "usage: repro serve [--quick] [jobs=8] [n=64] [rounds=150] [seed=7] \
         [policy=drr|adaptive|both]"
    );
    std::process::exit(2);
}

/// The lifecycle drill: pause, resume and cancel tenants mid-run on a
/// live fleet, proving the serving API under churn. Prints one summary
/// line per job.
fn lifecycle_drill(n: usize, rounds: usize, seed: u64) {
    let specs = job_mix(4, n, rounds, seed ^ 0xD411);
    let budget = demand_bits(&specs);
    let mut srv = JobServer::new(budget, Policy::Drr);
    let ids: Vec<_> = specs.into_iter().map(|s| srv.submit(s).expect("ample budget")).collect();
    let third = rounds / 3;
    for _ in 0..third {
        srv.run_round();
    }
    srv.pause(ids[0]).expect("pause running job");
    let paused_at = srv.job(ids[0]).map(|j| j.rounds_done()).unwrap_or(0);
    for _ in 0..third {
        srv.run_round();
    }
    srv.resume(ids[0]).expect("resume paused job");
    srv.cancel(ids[3]).expect("cancel running job");
    srv.run(rounds * 16);
    println!("--- lifecycle drill (4 jobs, pause/resume/cancel mid-run) ---");
    for &id in &ids {
        let job = srv.job(id).expect("job stays registered");
        println!(
            "  job {id} [{}] {:>10}: {:>4} rounds, final value {:.6}",
            job.spec().name,
            srv.state(id).expect("state known").to_string(),
            job.rounds_done(),
            job.trace().final_value(),
        );
    }
    println!(
        "  (job {} held at round {paused_at} while paused; cancelled job {} kept its partial trace)",
        ids[0], ids[3]
    );
}

/// Run the sweep. `args` accepts `jobs=`, `n=`, `rounds=`, `seed=` and
/// `policy=` overrides; anything else prints usage and exits 2.
pub fn run(quick: bool, args: &[String]) {
    let mut jobs = 8usize;
    let mut n = 64usize;
    let mut rounds = if quick { 40 } else { 150 };
    let mut seed = 7u64;
    let mut policies: Vec<Policy> = vec![Policy::Drr, Policy::DrrAdaptive];
    // Malformed values abort just like unknown keys do: silently keeping
    // a default would run the whole sweep on the wrong parameters.
    fn bail(key: &str, v: &str) -> ! {
        eprintln!("serve: bad value '{v}' for {key}=");
        usage_and_exit()
    }
    for a in args {
        match a.split_once('=') {
            Some(("jobs", v)) => jobs = v.parse().unwrap_or_else(|_| bail("jobs", v)),
            Some(("n", v)) => n = v.parse().unwrap_or_else(|_| bail("n", v)),
            Some(("rounds", v)) => rounds = v.parse().unwrap_or_else(|_| bail("rounds", v)),
            Some(("seed", v)) => seed = v.parse().unwrap_or_else(|_| bail("seed", v)),
            Some(("policy", v)) => {
                policies = match v {
                    "both" => vec![Policy::Drr, Policy::DrrAdaptive],
                    p => vec![Policy::parse(p).unwrap_or_else(|| bail("policy", v))],
                }
            }
            _ => {
                eprintln!("serve: expected jobs=|n=|rounds=|seed=|policy=, got '{a}'");
                usage_and_exit()
            }
        }
    }
    if jobs == 0 || n == 0 || rounds == 0 {
        eprintln!("serve: jobs, n and rounds must be positive");
        usage_and_exit()
    }
    let job_counts: Vec<usize> = if jobs <= 2 { vec![jobs] } else { vec![2, jobs / 2, jobs] };
    let fracs = [0.25f32, 0.5, 1.0];
    println!("=== repro serve: jobs x budget x policy sweep (n={n}, rounds={rounds}) ===");
    println!(
        "{:<10} {:>5} {:>8} {:>12} {:>9} {:>8} {:>14} {:>12} {:>8} {:>12}",
        "policy", "jobs", "budget%", "budget-bits", "admitted", "fleet-T", "job-rounds", "rounds/s", "util", "mean-f(x_T)"
    );
    let mut cells = Vec::new();
    for &policy in &policies {
        for &jc in &job_counts {
            for &frac in &fracs {
                let cell = run_cell(jc, n, rounds, seed, policy, frac);
                println!(
                    "{:<10} {:>5} {:>8} {:>12} {:>9} {:>8} {:>14} {:>12.0} {:>8.3} {:>12.5}",
                    cell.policy.to_string(),
                    cell.jobs,
                    format!("{:.0}%", cell.budget_frac * 100.0),
                    cell.budget_bits,
                    format!("{}/{}", cell.admitted, cell.admitted + cell.rejected),
                    cell.fleet_rounds,
                    cell.served_job_rounds,
                    cell.rounds_per_sec,
                    cell.utilization,
                    cell.mean_final_value
                );
                cells.push(cell);
            }
        }
    }
    lifecycle_drill(n, rounds, seed);
    let json = cells_to_json(&cells);
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!("wrote BENCH_serve.json ({} cells)", cells.len()),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_is_heterogeneous_and_buildable() {
        let specs = job_mix(8, 32, 10, 3);
        assert_eq!(specs.len(), 8);
        let schemes: std::collections::BTreeSet<String> =
            specs.iter().map(|s| s.scheme.name()).collect();
        assert!(schemes.len() >= 6, "mix must span many schemes, got {schemes:?}");
        assert!(specs.iter().any(|s| s.workers > 1), "mix must include multi-worker jobs");
        assert!(demand_bits(&specs) > 0);
    }

    #[test]
    fn one_cell_runs_and_serializes() {
        let cell = run_cell(4, 16, 8, 3, Policy::DrrAdaptive, 0.5);
        assert!(cell.admitted >= 1);
        assert!(cell.served_job_rounds > 0);
        assert!(cell.rounds_per_sec > 0.0);
        let json = cells_to_json(&[cell]);
        assert!(json.contains("\"rounds_per_sec\""));
        assert!(json.contains("\"policy\": \"adaptive\""));
        assert!(json.trim_end().ends_with(']'));
    }

    #[test]
    fn empty_cells_serialize_null_not_nan() {
        // A starvation budget rejects every tenant: the JSON must stay
        // parseable (`null`), never emit a bare `NaN` token.
        let cell = run_cell(2, 64, 8, 3, Policy::Drr, 0.05);
        assert_eq!(cell.admitted, 0);
        let json = cells_to_json(&[cell]);
        assert!(json.contains("\"mean_final_value\": null"), "got: {json}");
        assert!(!json.contains("NaN"));
    }
}
