//! `repro serve` — the serving-layer load driver: **jobs × budget ×
//! scheduler-policy** sweeps over a heterogeneous tenant mix, plus a
//! lifecycle drill that pauses, resumes and cancels jobs mid-run.
//!
//! Each cell builds a mixed fleet (subspace / dithered / sparsified /
//! fixed-rate tenants at budgets from 0.25 to 4 bits/dim, single- and
//! multi-worker), arbitrates it under a global bits-per-round budget set
//! as a fraction of the aggregate demand, runs it to completion and
//! reports per-job convergence plus aggregate throughput. The grid is
//! printed as a table and saved to `BENCH_serve.json` (same convention
//! as `BENCH_transport.json`) so serving regressions diff mechanically
//! across PRs.
//!
//! A multi-fleet cluster pass then shards a ≥1000-tenant population over
//! `fleets=` concurrent fleets (hash placement + load-aware rebalance,
//! threaded worker fan-out, a burst of mid-run migrations and a few
//! deliberately oversized tenants), drains it on the work-stealing
//! epoch executor with the autoscaler in the loop, and reports the
//! served/queued/rejected/migrated breakdown plus the stolen-grant and
//! autoscale counters.
//!
//! ```text
//! repro serve [--quick] [jobs=8] [n=64] [rounds=150] [seed=7] [fleets=4]
//!             [policy=drr|adaptive|both]
//! ```

use std::time::Instant;

use crate::quant::budget_bits;
use crate::quant::registry::CompressorSpec;
use crate::serve::{FleetCluster, JobServer, JobSpec, Policy, QosClass};

/// One row of the tenant-mix template the sweep cycles through:
/// `(scheme, R, workers, error-feedback, qos)`. QoS names follow the
/// CLI grammar: `gold` | `silver` | `bronze`.
const MIX: [(&str, f32, usize, bool, &str); 8] = [
    ("ndsc-dith", 1.0, 1, false, "gold"),
    ("sd", 0.5, 1, false, "silver"),
    ("topk1b", 2.0, 1, false, "bronze"),
    ("qsgd", 4.0, 2, false, "gold"),
    ("ndsc", 1.0, 1, true, "silver"),
    ("randk1b", 0.25, 1, false, "bronze"),
    ("dsc-dith", 1.0, 2, false, "silver"),
    ("vqsgd", 0.5, 1, false, "silver"),
];

/// The heterogeneous job mix the sweep (and `bench_serve`) submits:
/// `count` specs cycled from the eight-row tenant template above
/// (subspace / dithered / sparsified / fixed-rate schemes, budgets from
/// 0.25 to 4 bits/dim, single- and multi-worker, one DEF-feedback
/// tenant, and all three QoS classes), seeded `base_seed + index`.
pub fn job_mix(count: usize, n: usize, rounds: usize, base_seed: u64) -> Vec<JobSpec> {
    (0..count)
        .map(|i| {
            let (scheme, r, workers, def, qos) = MIX[i % MIX.len()];
            let mut s = JobSpec::new(
                format!("job{i}-{scheme}"),
                CompressorSpec::parse(scheme).expect("mix schemes are canonical"),
                r,
                n,
                rounds,
                base_seed + i as u64,
            )
            .with_workers(workers)
            .with_qos(QosClass::parse(qos).expect("mix classes are canonical"));
            if def {
                s = s.with_def_feedback();
            }
            s
        })
        .collect()
}

/// Aggregate per-round demand of a spec list at their requested budgets.
fn demand_bits(specs: &[JobSpec]) -> usize {
    specs.iter().map(|s| s.workers * budget_bits(s.n, s.r)).sum()
}

struct ServeCell {
    jobs: usize,
    policy: Policy,
    budget_frac: f32,
    budget_bits: usize,
    admitted: usize,
    rejected: usize,
    fleet_rounds: usize,
    served_job_rounds: u64,
    rounds_per_sec: f64,
    utilization: f32,
    mean_final_value: f32,
}

fn run_cell(jobs: usize, n: usize, rounds: usize, seed: u64, policy: Policy, frac: f32) -> ServeCell {
    let specs = job_mix(jobs, n, rounds, seed);
    let budget = ((demand_bits(&specs) as f32 * frac) as usize).max(1);
    let mut srv = JobServer::new(budget, policy);
    let mut admitted = 0usize;
    let mut rejected = 0usize;
    for spec in specs {
        match srv.submit(spec) {
            Ok(_) => admitted += 1,
            Err(_) => rejected += 1,
        }
    }
    // Under a tight budget a job is served every few fleet rounds, so
    // completion needs a comfortable multiple of the per-job horizon.
    let cap = rounds * (jobs.max(1)) * 8;
    let t0 = Instant::now();
    let fleet_rounds = srv.run(cap);
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    let m = srv.metrics();
    let finals: Vec<f32> = srv
        .job_ids()
        .filter_map(|id| srv.job(id))
        .filter(|j| j.is_complete())
        .map(|j| j.trace().final_value())
        .collect();
    let mean_final_value = if finals.is_empty() {
        f32::NAN
    } else {
        finals.iter().sum::<f32>() / finals.len() as f32
    };
    ServeCell {
        jobs,
        policy,
        budget_frac: frac,
        budget_bits: budget,
        admitted,
        rejected,
        fleet_rounds,
        served_job_rounds: m.served_job_rounds(),
        rounds_per_sec: m.served_job_rounds() as f64 / secs,
        utilization: m.utilization(),
        mean_final_value,
    }
}

/// One multi-fleet cluster pass: `tenants` jobs sharded over `fleets`
/// concurrent fleets, with the mid-horizon queue depth and the
/// admission / migration breakdowns the single-fleet sweep cannot show.
struct ClusterCell {
    policy: Policy,
    fleets: usize,
    tenants: usize,
    budget_bits_per_fleet: usize,
    served: u64,
    queued_mid: u64,
    rejected: u64,
    migrated: u64,
    stolen_grants: u64,
    active_fleets: u64,
    autoscale_events: u64,
    cluster_rounds: u64,
    served_job_rounds: u64,
    rounds_per_sec: f64,
    utilization: f32,
}

fn run_cluster_cell(
    fleets: usize,
    tenants: usize,
    n: usize,
    rounds: usize,
    seed: u64,
    policy: Policy,
    frac: f32,
) -> ClusterCell {
    let specs = job_mix(tenants, n, rounds, seed);
    let budget = ((demand_bits(&specs) as f32 * frac / fleets as f32) as usize).max(1);
    let mut cluster = FleetCluster::new(fleets, budget, policy);
    let mut gids = Vec::with_capacity(tenants);
    for spec in specs {
        if let Ok(gid) = cluster.submit(spec) {
            gids.push(gid);
        }
    }
    // A few deliberately oversized tenants exercise admission control:
    // 1024 workers at 4 bits/dim dwarfs any per-fleet fraction of the
    // mix's demand, so each one lands in the rejected breakdown.
    for i in 0..4u64 {
        let wide = JobSpec::new(
            format!("wide{i}-qsgd"),
            CompressorSpec::parse("qsgd").expect("canonical"),
            4.0,
            n,
            rounds,
            seed ^ (0xB16 + i),
        )
        .with_workers(1024);
        let _ = cluster.submit(wide);
    }
    let t0 = Instant::now();
    cluster.run_round();
    // Mid-horizon snapshot: after one cluster round no multi-round job
    // can have finished, so the queue depth here is the live backlog —
    // and migration below moves real in-flight scheduler state.
    let queued_mid = cluster.metrics().queued_jobs;
    for &gid in gids.iter().step_by(101) {
        let from = cluster.fleet_of(gid).unwrap_or(0);
        cluster
            .migrate(gid, (from + 1) % fleets)
            .expect("mid-run migration of a live job");
    }
    // Drain on the work-stealing epoch executor with the autoscaler
    // between epochs (grants stay bit-identical to the lockstep round
    // above — test_serve.rs proves the executor equivalence).
    cluster
        .run_autoscaled(rounds * tenants.max(1) * 8, 4)
        .expect("autoscaled drain rebalances over the migration path");
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    let m = cluster.metrics();
    let offered: u64 = m.fleets.iter().map(|f| budget as u64 * f.fleet_rounds).sum();
    ClusterCell {
        policy,
        fleets,
        tenants: gids.len() + m.rejected_jobs as usize,
        budget_bits_per_fleet: budget,
        served: m.served_jobs,
        queued_mid,
        rejected: m.rejected_jobs,
        migrated: m.migrated_jobs,
        stolen_grants: m.stolen_grants,
        active_fleets: m.active_fleets,
        autoscale_events: m.autoscale_events,
        cluster_rounds: m.cluster_rounds,
        served_job_rounds: m.served_job_rounds,
        rounds_per_sec: m.served_job_rounds as f64 / secs,
        utilization: if offered == 0 {
            0.0
        } else {
            m.spent_payload_bits as f32 / offered as f32
        },
    }
}

fn sweep_row(c: &ServeCell) -> String {
    // JSON has no NaN literal: a cell with no finished job (e.g. all
    // tenants rejected under a starvation budget) reports `null`.
    let mean_final = if c.mean_final_value.is_finite() {
        c.mean_final_value.to_string()
    } else {
        "null".to_string()
    };
    format!(
        "  {{\"source\": \"repro-serve\", \"kind\": \"sweep\", \"jobs\": {}, \
         \"policy\": \"{}\", \"budget_frac\": {}, \"budget_bits\": {}, \
         \"admitted\": {}, \"rejected\": {}, \"fleet_rounds\": {}, \
         \"served_job_rounds\": {}, \"rounds_per_sec\": {}, \"utilization\": {}, \
         \"mean_final_value\": {mean_final}}}",
        c.jobs,
        c.policy,
        c.budget_frac,
        c.budget_bits,
        c.admitted,
        c.rejected,
        c.fleet_rounds,
        c.served_job_rounds,
        c.rounds_per_sec,
        c.utilization,
    )
}

fn cluster_row(c: &ClusterCell) -> String {
    format!(
        "  {{\"source\": \"repro-serve\", \"kind\": \"cluster\", \"policy\": \"{}\", \
         \"fleets\": {}, \"tenants\": {}, \"budget_bits_per_fleet\": {}, \
         \"served\": {}, \"queued_mid\": {}, \"rejected\": {}, \"migrated\": {}, \
         \"stolen_grants\": {}, \"active_fleets\": {}, \"autoscale_events\": {}, \
         \"cluster_rounds\": {}, \"served_job_rounds\": {}, \
         \"rounds_per_sec\": {}, \"utilization\": {}}}",
        c.policy,
        c.fleets,
        c.tenants,
        c.budget_bits_per_fleet,
        c.served,
        c.queued_mid,
        c.rejected,
        c.migrated,
        c.stolen_grants,
        c.active_fleets,
        c.autoscale_events,
        c.cluster_rounds,
        c.served_job_rounds,
        c.rounds_per_sec,
        c.utilization,
    )
}

/// One JSON array holding both the single-fleet sweep rows and the
/// multi-fleet cluster rows (`"kind"` discriminates).
fn cells_to_json(cells: &[ServeCell], clusters: &[ClusterCell]) -> String {
    let rows: Vec<String> = cells
        .iter()
        .map(sweep_row)
        .chain(clusters.iter().map(cluster_row))
        .collect();
    let mut s = String::from("[\n");
    s.push_str(&rows.join(",\n"));
    s.push_str("\n]\n");
    s
}

fn usage_and_exit() -> ! {
    eprintln!(
        "usage: repro serve [--quick] [jobs=8] [n=64] [rounds=150] [seed=7] \
         [fleets=4] [policy=drr|adaptive|both]"
    );
    std::process::exit(2);
}

/// The lifecycle drill: pause, resume and cancel tenants mid-run on a
/// live fleet, proving the serving API under churn. Prints one summary
/// line per job.
fn lifecycle_drill(n: usize, rounds: usize, seed: u64) {
    let specs = job_mix(4, n, rounds, seed ^ 0xD411);
    let budget = demand_bits(&specs);
    let mut srv = JobServer::new(budget, Policy::Drr);
    let ids: Vec<_> = specs.into_iter().map(|s| srv.submit(s).expect("ample budget")).collect();
    let third = rounds / 3;
    for _ in 0..third {
        srv.run_round();
    }
    srv.pause(ids[0]).expect("pause running job");
    let paused_at = srv.job(ids[0]).map(|j| j.rounds_done()).unwrap_or(0);
    for _ in 0..third {
        srv.run_round();
    }
    srv.resume(ids[0]).expect("resume paused job");
    srv.cancel(ids[3]).expect("cancel running job");
    srv.run(rounds * 16);
    println!("--- lifecycle drill (4 jobs, pause/resume/cancel mid-run) ---");
    for &id in &ids {
        let job = srv.job(id).expect("job stays registered");
        println!(
            "  job {id} [{}] {:>10}: {:>4} rounds, final value {:.6}",
            job.spec().name,
            srv.state(id).expect("state known").to_string(),
            job.rounds_done(),
            job.trace().final_value(),
        );
    }
    println!(
        "  (job {} held at round {paused_at} while paused; cancelled job {} kept its partial trace)",
        ids[0], ids[3]
    );
}

/// Run the sweep. `args` accepts `jobs=`, `n=`, `rounds=`, `seed=`,
/// `fleets=` and `policy=` overrides; anything else prints usage and
/// exits 2.
pub fn run(quick: bool, args: &[String]) {
    let mut jobs = 8usize;
    let mut n = 64usize;
    let mut rounds = if quick { 40 } else { 150 };
    let mut seed = 7u64;
    let mut fleets = 4usize;
    let mut policies: Vec<Policy> = vec![Policy::Drr, Policy::DrrAdaptive];
    // Malformed values abort just like unknown keys do: silently keeping
    // a default would run the whole sweep on the wrong parameters.
    fn bail(key: &str, v: &str) -> ! {
        eprintln!("serve: bad value '{v}' for {key}=");
        usage_and_exit()
    }
    for a in args {
        match a.split_once('=') {
            Some(("jobs", v)) => jobs = v.parse().unwrap_or_else(|_| bail("jobs", v)),
            Some(("n", v)) => n = v.parse().unwrap_or_else(|_| bail("n", v)),
            Some(("rounds", v)) => rounds = v.parse().unwrap_or_else(|_| bail("rounds", v)),
            Some(("seed", v)) => seed = v.parse().unwrap_or_else(|_| bail("seed", v)),
            Some(("fleets", v)) => fleets = v.parse().unwrap_or_else(|_| bail("fleets", v)),
            Some(("policy", v)) => {
                policies = match v {
                    "both" => vec![Policy::Drr, Policy::DrrAdaptive],
                    p => vec![Policy::parse(p).unwrap_or_else(|| bail("policy", v))],
                }
            }
            _ => {
                eprintln!("serve: expected jobs=|n=|rounds=|seed=|fleets=|policy=, got '{a}'");
                usage_and_exit()
            }
        }
    }
    if jobs == 0 || n == 0 || rounds == 0 || fleets == 0 {
        eprintln!("serve: jobs, n, rounds and fleets must be positive");
        usage_and_exit()
    }
    let job_counts: Vec<usize> = if jobs <= 2 { vec![jobs] } else { vec![2, jobs / 2, jobs] };
    let fracs = [0.25f32, 0.5, 1.0];
    println!("=== repro serve: jobs x budget x policy sweep (n={n}, rounds={rounds}) ===");
    println!(
        "{:<10} {:>5} {:>8} {:>12} {:>9} {:>8} {:>14} {:>12} {:>8} {:>12}",
        "policy", "jobs", "budget%", "budget-bits", "admitted", "fleet-T", "job-rounds", "rounds/s", "util", "mean-f(x_T)"
    );
    let mut cells = Vec::new();
    for &policy in &policies {
        for &jc in &job_counts {
            for &frac in &fracs {
                let cell = run_cell(jc, n, rounds, seed, policy, frac);
                println!(
                    "{:<10} {:>5} {:>8} {:>12} {:>9} {:>8} {:>14} {:>12.0} {:>8.3} {:>12.5}",
                    cell.policy.to_string(),
                    cell.jobs,
                    format!("{:.0}%", cell.budget_frac * 100.0),
                    cell.budget_bits,
                    format!("{}/{}", cell.admitted, cell.admitted + cell.rejected),
                    cell.fleet_rounds,
                    cell.served_job_rounds,
                    cell.rounds_per_sec,
                    cell.utilization,
                    cell.mean_final_value
                );
                cells.push(cell);
            }
        }
    }
    lifecycle_drill(n, rounds, seed);

    // The multi-fleet cluster pass: ≥1000 tenants sharded over the fleet
    // count, short per-job horizons (the point is placement, migration
    // and the queue/reject breakdowns, not per-job convergence).
    let tenants = if quick { 1000 } else { 1024 };
    let cluster_rounds_per_job = if quick { 2 } else { 3 };
    println!("--- multi-fleet cluster ({tenants} tenants over {fleets} fleets, n=16) ---");
    println!(
        "{:<10} {:>7} {:>12} {:>8} {:>10} {:>9} {:>9} {:>7} {:>7} {:>14} {:>12} {:>8}",
        "policy", "tenants", "budget/fleet", "served", "queued@mid", "rejected", "migrated", "stolen", "scales", "job-rounds", "rounds/s", "util"
    );
    let mut clusters = Vec::new();
    for &policy in &policies {
        let cell = run_cluster_cell(fleets, tenants, 16, cluster_rounds_per_job, seed, policy, 0.5);
        println!(
            "{:<10} {:>7} {:>12} {:>8} {:>10} {:>9} {:>9} {:>7} {:>7} {:>14} {:>12.0} {:>8.3}",
            cell.policy.to_string(),
            cell.tenants,
            cell.budget_bits_per_fleet,
            cell.served,
            cell.queued_mid,
            cell.rejected,
            cell.migrated,
            cell.stolen_grants,
            cell.autoscale_events,
            cell.served_job_rounds,
            cell.rounds_per_sec,
            cell.utilization,
        );
        clusters.push(cell);
    }

    let json = cells_to_json(&cells, &clusters);
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!(
            "wrote BENCH_serve.json ({} sweep cells + {} cluster cells)",
            cells.len(),
            clusters.len()
        ),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_is_heterogeneous_and_buildable() {
        let specs = job_mix(8, 32, 10, 3);
        assert_eq!(specs.len(), 8);
        let schemes: std::collections::BTreeSet<String> =
            specs.iter().map(|s| s.scheme.name()).collect();
        assert!(schemes.len() >= 6, "mix must span many schemes, got {schemes:?}");
        assert!(specs.iter().any(|s| s.workers > 1), "mix must include multi-worker jobs");
        assert!(demand_bits(&specs) > 0);
    }

    #[test]
    fn one_cell_runs_and_serializes() {
        let cell = run_cell(4, 16, 8, 3, Policy::DrrAdaptive, 0.5);
        assert!(cell.admitted >= 1);
        assert!(cell.served_job_rounds > 0);
        assert!(cell.rounds_per_sec > 0.0);
        let json = cells_to_json(&[cell], &[]);
        assert!(json.contains("\"rounds_per_sec\""));
        assert!(json.contains("\"policy\": \"adaptive\""));
        assert!(json.trim_end().ends_with(']'));
    }

    #[test]
    fn empty_cells_serialize_null_not_nan() {
        // A starvation budget rejects every tenant: the JSON must stay
        // parseable (`null`), never emit a bare `NaN` token.
        let cell = run_cell(2, 64, 8, 3, Policy::Drr, 0.05);
        assert_eq!(cell.admitted, 0);
        let json = cells_to_json(&[cell], &[]);
        assert!(json.contains("\"mean_final_value\": null"), "got: {json}");
        assert!(!json.contains("NaN"));
    }

    #[test]
    fn cluster_cell_reports_every_breakdown() {
        // A scaled-down cluster pass (40 tenants over 4 fleets) must
        // still exercise every breakdown: backlog at mid-horizon,
        // oversized-tenant rejections, and at least one live migration —
        // now drained on the work-stealing epoch executor with the
        // autoscaler in the loop, which must not change any outcome.
        let cell = run_cluster_cell(4, 40, 16, 2, 3, Policy::Drr, 0.5);
        assert_eq!(cell.fleets, 4);
        assert_eq!(cell.served, 40, "every feasible tenant must finish");
        assert_eq!(cell.queued_mid, 40, "no 2-round job can finish in one cluster round");
        assert_eq!(cell.rejected, 4, "the oversized tenants must all be rejected");
        assert!(cell.migrated >= 1, "the mid-run migration slice must move jobs");
        assert!(cell.served_job_rounds == 80);
        assert!(
            (1..=4).contains(&cell.active_fleets),
            "active fleet count stays within the cluster, got {}",
            cell.active_fleets
        );
        let json = cells_to_json(&[], &[cell]);
        assert!(json.contains("\"kind\": \"cluster\""), "got: {json}");
        assert!(json.contains("\"queued_mid\": 40"), "got: {json}");
        assert!(json.contains("\"stolen_grants\""), "got: {json}");
        assert!(json.contains("\"autoscale_events\""), "got: {json}");
        assert!(json.trim_end().ends_with(']'));
    }
}
