//! `repro net` — transport-layer sweeps over the deterministic SimNet
//! model: **topology × budget-mix × drop-rate** grids on the planted
//! multi-worker regression, with k-of-m partial participation.
//!
//! Each cell runs the full threaded coordinator over a
//! [`SimNetConfig`] (per-link latency/jitter/bandwidth, per-hop loss
//! compounded by the topology's hop counts) with heterogeneous
//! per-worker budgets `R_i`, and reports the final objective value, the
//! achieved uplink rate and the effective participation. The grid is
//! printed as a table and saved to `BENCH_transport.json` so transport
//! regressions diff mechanically across PRs (same convention as
//! `BENCH_hotpath.json`).
//!
//! ```text
//! repro net [--quick] [n=64] [workers=8] [rounds=200] [seed=7] [part=k:6]
//! ```

use crate::coordinator::config::{RunConfig, SchemeKind};
use crate::coordinator::transport::{
    LinkModel, Participation, SimNetConfig, Topology, TransportKind,
};
use crate::data::synthetic::planted_regression_shards;
use crate::linalg::rng::Rng;
use crate::opt::engine::driver::run_config;
use crate::opt::multi::ShardedProblem;
use crate::opt::objectives::Loss;

/// Per-worker gradient-noise salt for this harness (kept distinct from
/// the CLI's so `repro net` traces stay byte-stable across PRs).
const WORKER_SEED_SALT: u64 = 31;

/// One grid cell's summary.
struct NetCell {
    topology: Topology,
    mix_name: &'static str,
    drop: f32,
    participation: Participation,
    first_value: f32,
    final_value: f32,
    mean_rate: f32,
    mean_participants: f32,
}

#[allow(clippy::too_many_arguments)]
fn run_cell(
    n: usize,
    m: usize,
    rounds: usize,
    seed: u64,
    topology: Topology,
    mix_name: &'static str,
    mix: &[f32],
    drop: f32,
    participation: Participation,
) -> NetCell {
    let budgets: Vec<f32> = (0..m).map(|i| mix[i % mix.len()]).collect();
    let r_mean = budgets.iter().sum::<f32>() / m as f32;
    let mut rng = Rng::seed_from(seed);
    let (shards, _xs) = planted_regression_shards(m, 10, n, Loss::Square, &mut rng, false);
    let problem = ShardedProblem::new(shards.clone());
    let step = problem.stable_step();
    let cfg = RunConfig {
        n,
        workers: m,
        r: r_mean,
        budgets: Some(budgets),
        scheme: SchemeKind::NdscDithered,
        participation,
        transport: TransportKind::SimNet(SimNetConfig {
            seed: seed ^ 0x5E7,
            topology,
            links: vec![LinkModel {
                base_latency_us: 200,
                jitter_us: 100,
                drop_prob: drop,
                bandwidth_bits_per_us: 8.0,
            }],
        }),
        rounds,
        step,
        batch: 0,
        seed,
        ..Default::default()
    };
    // One source of truth for invariants (k range, per-R_i feasibility,
    // drop-probability range): the same validation the CLI path runs.
    cfg.validate().unwrap_or_else(|e| {
        eprintln!("net: invalid configuration: {e}");
        std::process::exit(2);
    });
    // The engine's distributed driver owns the fleet plumbing: one
    // budget-R_i codec and one gradient source per shard, over the
    // configured transport.
    let metrics = run_config(&cfg, vec![0.0; n], shards, WORKER_SEED_SALT, &mut rng, move |x| {
        problem.value(x)
    });
    NetCell {
        topology,
        mix_name,
        drop,
        participation,
        first_value: metrics.rounds.first().map(|r| r.value).unwrap_or(f32::NAN),
        final_value: metrics.final_value(),
        mean_rate: metrics.mean_rate(n, m),
        mean_participants: metrics.mean_participants(),
    }
}

fn cells_to_json(cells: &[NetCell]) -> String {
    let mut s = String::from("[\n");
    for (i, c) in cells.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"topology\": \"{}\", \"budget_mix\": \"{}\", \"drop\": {}, \
             \"participation\": \"{}\", \"first_value\": {}, \"final_value\": {}, \
             \"mean_rate\": {}, \"mean_participants\": {}}}{}\n",
            c.topology,
            c.mix_name,
            c.drop,
            c.participation,
            c.first_value,
            c.final_value,
            c.mean_rate,
            c.mean_participants,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    s.push_str("]\n");
    s
}

/// Run the sweep. `args` accepts `n=`, `workers=`/`m=`, `rounds=`,
/// `seed=` and `part=` overrides.
pub fn run(quick: bool, args: &[String]) {
    let mut n = 64usize;
    let mut m = 8usize;
    let mut rounds = if quick { 60 } else { 200 };
    let mut seed = 7u64;
    let mut part_arg: Option<Participation> = None;
    // Malformed values abort just like unknown keys do: silently keeping
    // a default would run the whole sweep on the wrong parameters.
    fn bail(key: &str, v: &str) -> ! {
        eprintln!("net: bad value '{v}' for {key}=");
        std::process::exit(2);
    }
    for a in args {
        match a.split_once('=') {
            Some(("n", v)) => n = v.parse().unwrap_or_else(|_| bail("n", v)),
            Some(("workers", v)) | Some(("m", v)) => {
                m = v.parse().unwrap_or_else(|_| bail("workers", v))
            }
            Some(("rounds", v)) => rounds = v.parse().unwrap_or_else(|_| bail("rounds", v)),
            Some(("seed", v)) => seed = v.parse().unwrap_or_else(|_| bail("seed", v)),
            Some(("part", v)) | Some(("participation", v)) => {
                part_arg = Some(Participation::parse(v).unwrap_or_else(|| bail("part", v)))
            }
            _ => {
                eprintln!("net: expected n=|workers=|rounds=|seed=|part=, got '{a}'");
                std::process::exit(2);
            }
        }
    }
    // Default: aggregate the earliest three quarters of the fleet.
    // Range checking is RunConfig::validate's job (run_cell calls it on
    // the assembled config), not re-implemented here.
    let participation =
        part_arg.unwrap_or(Participation::KofM { k: ((3 * m).div_ceil(4)).clamp(1, m) });

    let topologies = [Topology::Star, Topology::Chain, Topology::Tree { fanout: 2 }];
    let mixes: [(&'static str, &[f32]); 3] = [
        ("uniform-1", &[1.0]),
        ("lo-hi", &[0.5, 4.0]),
        ("spread", &[0.5, 1.0, 2.0, 4.0]),
    ];
    let drops = [0.0f32, 0.05, 0.2];

    println!(
        "=== repro net: SimNet sweep (n={n}, m={m}, rounds={rounds}, part={participation}) ==="
    );
    println!(
        "{:<10} {:<10} {:>6} {:>12} {:>12} {:>10} {:>8}",
        "topology", "budgets", "drop", "f(x_0)", "f(x_T)", "bits/dim", "mean-k"
    );
    let mut cells = Vec::new();
    for topology in topologies {
        for (mix_name, mix) in mixes {
            for drop in drops {
                let cell =
                    run_cell(n, m, rounds, seed, topology, mix_name, mix, drop, participation);
                println!(
                    "{:<10} {:<10} {:>6} {:>12.5} {:>12.5} {:>10.3} {:>8.2}",
                    cell.topology.to_string(),
                    cell.mix_name,
                    cell.drop,
                    cell.first_value,
                    cell.final_value,
                    cell.mean_rate,
                    cell.mean_participants
                );
                cells.push(cell);
            }
        }
    }
    let json = cells_to_json(&cells);
    match std::fs::write("BENCH_transport.json", &json) {
        Ok(()) => println!("wrote BENCH_transport.json ({} cells)", cells.len()),
        Err(e) => eprintln!("could not write BENCH_transport.json: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_cell_runs_and_serializes() {
        let cell = run_cell(
            16,
            4,
            15,
            3,
            Topology::Chain,
            "lo-hi",
            &[0.5, 4.0],
            0.1,
            Participation::KofM { k: 3 },
        );
        assert!(cell.final_value.is_finite());
        assert!(cell.mean_participants <= 3.0 + 1e-6);
        let json = cells_to_json(&[cell]);
        assert!(json.contains("\"topology\": \"chain\""));
        assert!(json.trim_end().ends_with(']'));
    }
}
