//! Shared plumbing for the experiment harness: series printing, trial
//! averaging, and quick-mode scaling.
//!
//! Per-round metrics come out of the optimizer engine as one stream
//! ([`crate::opt::Trace`], convertible to coordinator
//! [`crate::coordinator::metrics::RunMetrics`]); this module is the glue
//! from that stream to figure curves ([`value_series`]) — CSV export
//! goes through the single writer in [`crate::coordinator::metrics`].

use crate::opt::Trace;

/// A named series of (x, y) points — one curve of a figure.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f32, f32)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Series { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f32, y: f32) {
        self.points.push((x, y));
    }

    pub fn y_at_end(&self) -> f32 {
        self.points.last().map(|p| p.1).unwrap_or(f32::NAN)
    }
}

/// Print a figure: header, one aligned row per x with all series values,
/// plus machine-readable `SERIES` lines.
pub fn print_figure(title: &str, xlabel: &str, series: &[Series]) {
    println!("\n=== {title} ===");
    print!("{:>12}", xlabel);
    for s in series {
        print!("  {:>18}", truncate(&s.name, 18));
    }
    println!();
    let xs: Vec<f32> = series
        .first()
        .map(|s| s.points.iter().map(|p| p.0).collect())
        .unwrap_or_default();
    for (i, &x) in xs.iter().enumerate() {
        print!("{x:>12.4}");
        for s in series {
            match s.points.get(i) {
                Some(&(_, y)) => print!("  {y:>18.6}"),
                None => print!("  {:>18}", "-"),
            }
        }
        println!();
    }
    for s in series {
        let pts: Vec<String> = s.points.iter().map(|(x, y)| format!("{x}:{y}")).collect();
        println!("SERIES\t{title}\t{}\t{}", s.name, pts.join(","));
    }
}

fn truncate(s: &str, w: usize) -> &str {
    if s.len() <= w {
        s
    } else {
        &s[..w]
    }
}

/// Mean of `trials` runs of `f`.
pub fn mean_of(trials: usize, mut f: impl FnMut(usize) -> f32) -> f32 {
    (0..trials).map(&mut f).sum::<f32>() / trials as f32
}

/// Scale trial/iteration counts down in quick mode (CI smoke).
pub fn scaled(full: usize, quick: bool) -> usize {
    if quick {
        (full / 5).max(2)
    } else {
        full
    }
}

/// One value-vs-iteration curve from an optimizer trace, thinned to ~`k`
/// points — the standard engine-trace → figure glue.
pub fn value_series(name: impl Into<String>, trace: &Trace, k: usize) -> Series {
    let mut s = Series::new(name);
    let pts: Vec<(f32, f32)> =
        trace.records.iter().enumerate().map(|(i, rec)| (i as f32, rec.value)).collect();
    for (x, y) in thin(&pts, k) {
        s.push(x, y);
    }
    s
}

/// Thin down a trace to ~`k` evenly spaced points for printing.
pub fn thin(points: &[(f32, f32)], k: usize) -> Vec<(f32, f32)> {
    if points.len() <= k {
        return points.to_vec();
    }
    let step = points.len() as f32 / k as f32;
    (0..k).map(|i| points[((i as f32 * step) as usize).min(points.len() - 1)]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_basics() {
        let mut s = Series::new("a");
        s.push(1.0, 2.0);
        s.push(2.0, 3.0);
        assert_eq!(s.y_at_end(), 3.0);
    }

    #[test]
    fn thin_preserves_ends_roughly() {
        let pts: Vec<(f32, f32)> = (0..100).map(|i| (i as f32, i as f32)).collect();
        let t = thin(&pts, 10);
        assert_eq!(t.len(), 10);
        assert_eq!(t[0].0, 0.0);
    }

    #[test]
    fn scaled_quick() {
        assert_eq!(scaled(50, true), 10);
        assert_eq!(scaled(50, false), 50);
        assert_eq!(scaled(4, true), 2);
    }

    #[test]
    fn value_series_thins_trace_records() {
        use crate::opt::IterRecord;
        let trace = Trace {
            records: (0..100)
                .map(|i| IterRecord { value: i as f32, ..Default::default() })
                .collect(),
            ..Default::default()
        };
        let s = value_series("v", &trace, 10);
        assert_eq!(s.points.len(), 10);
        assert_eq!(s.points[0], (0.0, 0.0));
    }
}
