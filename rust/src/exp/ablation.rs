//! Ablations of the design choices DESIGN.md calls out:
//!
//! * `ablation_ef` — error feedback on/off in DGD-DEF at a low budget
//!   (feedback converts the quantization-noise ball into linear decay).
//! * `ablation_lambda` — DGD-DEF convergence vs the frame aspect ratio λ
//!   (App. N: λ → 1 wins once the fixed budget is split over N coords).
//! * `ablation_dqgd` — our adaptive-scale naive baseline vs the paper's
//!   original decaying-range DQGD [6] (which collapses at low R).

use crate::data::synthetic::{planted_regression, Tail};
use crate::exp::common::{print_figure, scaled, Series};
use crate::linalg::rng::Rng;
use crate::opt::dgd_def::{self, DgdDefOptions};
use crate::opt::engine::oracle::ExactGrad;
use crate::opt::engine::schedule::Schedule;
use crate::opt::engine::{Codecs, Engine, Problem};
use crate::quant::dsc::{CodecMode, EmbedKind};
use crate::quant::registry::{CompressorSpec, FrameSpec};
use crate::quant::Compressor;

fn ndh_spec() -> CompressorSpec {
    CompressorSpec::Subspace {
        embed: EmbedKind::NearDemocratic,
        mode: CodecMode::Deterministic,
        frame: FrameSpec::Hadamard,
    }
}

/// Error feedback on/off: DGD-DEF vs plain quantized GD (e ≡ 0).
pub fn ablation_ef(quick: bool) -> Vec<Series> {
    let n = 64;
    let iters = scaled(120, quick);
    let mut rng = Rng::seed_from(31);
    let (obj, _) = planted_regression(128, n, Tail::GaussianCubed, Tail::Gaussian, 0.05, &mut rng);
    let xs = obj.quadratic_minimizer();
    let (l, mu) = obj.smoothness_strong_convexity();
    let opts = DgdDefOptions::optimal(l, mu, iters);
    let mut series = Vec::new();
    for &r in &[2.0f32, 4.0] {
        // With feedback: Algorithm 1.
        let c = ndh_spec().build(n, r, &mut rng);
        let tr = dgd_def::run(&obj, c.as_ref(), &vec![0.0; n], Some(&xs), opts, &mut rng);
        let mut s = Series::new(format!("EF-R{r}"));
        s.push(iters as f32, tr.records.last().unwrap().dist_to_opt);
        series.push(s);
        // Without feedback: x <- x - α·Q(∇f(x)), same codec — the same
        // engine spec minus the `DefFeedback` component (what used to be
        // a hand-written seventh loop is a one-line composition change).
        let c2 = ndh_spec().build(n, r, &mut rng);
        let tr_plain = Engine::new(Problem::Single(&obj), Schedule::Constant(opts.step), iters)
            .with_oracle(ExactGrad { obj: &obj })
            .with_codecs(Codecs::Shared(c2.as_ref()))
            .run(&vec![0.0; n], Some(&xs), &mut rng);
        let mut s = Series::new(format!("noEF-R{r}"));
        s.push(iters as f32, tr_plain.records.last().unwrap().dist_to_opt);
        series.push(s);
    }
    print_figure("Ablation: error feedback on/off, final ||x−x*||", "iters", &series);
    series
}

/// λ sweep: DGD-DEF final error vs frame aspect ratio at fixed budget.
pub fn ablation_lambda(quick: bool) -> Vec<Series> {
    let n = 64; // N = 64·λ must be a power of two: λ ∈ {1, 2, 4, 8}
    let iters = scaled(120, quick);
    let r = 3.0;
    let mut rng = Rng::seed_from(32);
    let (obj, _) = planted_regression(128, n, Tail::GaussianCubed, Tail::Gaussian, 0.05, &mut rng);
    let xs = obj.quadratic_minimizer();
    let (l, mu) = obj.smoothness_strong_convexity();
    let opts = DgdDefOptions::optimal(l, mu, iters);
    let mut s = Series::new("final-dist");
    for &lambda in &[1u8, 2, 4, 8] {
        let spec = CompressorSpec::Subspace {
            embed: EmbedKind::NearDemocratic,
            mode: CodecMode::Deterministic,
            frame: FrameSpec::HadamardLambda(lambda),
        };
        let c = spec.build(n, r, &mut rng);
        let tr = dgd_def::run(&obj, c.as_ref(), &vec![0.0; n], Some(&xs), opts, &mut rng);
        s.push(lambda as f32, tr.records.last().unwrap().dist_to_opt);
    }
    let series = vec![s];
    print_figure("Ablation: DGD-DEF final ||x−x*|| vs frame λ (R=3)", "λ", &series);
    series
}

/// Adaptive-scale naive vs the paper's decaying-range DQGD baseline.
pub fn ablation_dqgd(quick: bool) -> Vec<Series> {
    let n = 64;
    let iters = scaled(120, quick);
    let mut rng = Rng::seed_from(33);
    let (obj, _) = planted_regression(128, n, Tail::GaussianCubed, Tail::Gaussian, 0.05, &mut rng);
    let xs = obj.quadratic_minimizer();
    let (l, mu) = obj.smoothness_strong_convexity();
    let sigma = crate::opt::gd::sigma(l, mu);
    let opts = DgdDefOptions::optimal(l, mu, iters);
    let mut g0 = vec![0.0f32; n];
    obj.gradient(&vec![0.0; n], &mut g0);
    let r0 = 2.0 * crate::linalg::vecops::norm_inf(&g0);
    let mut s_adapt = Series::new("naive-adaptive");
    let mut s_sched = Series::new("dqgd-range-schedule");
    let mut s_ndsc = Series::new("ndsc");
    for &r in &[1.0f32, 2.0, 3.0, 4.0, 6.0] {
        let curves: [(&mut Series, CompressorSpec); 3] = [
            (&mut s_adapt, CompressorSpec::Naive),
            (&mut s_sched, CompressorSpec::Dqgd { r0, gamma: sigma }),
            (&mut s_ndsc, ndh_spec()),
        ];
        for (series, spec) in curves {
            let c = spec.build(n, r, &mut rng);
            let rate =
                dgd_def::run(&obj, c.as_ref(), &vec![0.0; n], Some(&xs), opts, &mut rng)
                    .empirical_rate();
            series.push(r, rate);
        }
    }
    let series = vec![s_adapt, s_sched, s_ndsc];
    print_figure(
        "Ablation: DGD-DEF empirical rate vs R — adaptive naive vs range-schedule DQGD vs NDSC",
        "R",
        &series,
    );
    series
}
