//! Fig. 3b / Fig. 7 workload: federated training of the AOT-compiled
//! transformer LM through the full coordinator, with quantized gradients.
//!
//! The CNN-on-CIFAR setup of the paper is substituted per DESIGN.md §3 by
//! a byte-level transformer on a synthetic corpus, sharded non-iid across
//! workers. The model's forward/backward is the `model_grad.hlo.txt`
//! artifact built by `make artifacts` (L2 JAX, lowered once); each worker
//! thread owns a PJRT executable and never touches Python.

use anyhow::{Context, Result};

use crate::coordinator::config::RunConfig;
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::worker::GradSource;
use crate::data::corpus::Corpus;
use crate::linalg::rng::Rng;
use crate::quant::registry::CompressorSpec;
use crate::runtime::artifact::{artifacts_dir, Artifact, Input};

/// Metadata emitted by aot.py alongside the model artifacts.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub n_params: usize,
    pub padded_n: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
}

impl ModelMeta {
    pub fn load(dir: &str) -> Result<ModelMeta> {
        let text = std::fs::read_to_string(format!("{dir}/model_meta.txt"))
            .with_context(|| format!("{dir}/model_meta.txt missing — run `make artifacts`"))?;
        let mut map = std::collections::HashMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                map.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        let get = |k: &str| -> Result<usize> {
            map.get(k)
                .with_context(|| format!("model_meta.txt missing key {k}"))?
                .parse::<usize>()
                .with_context(|| format!("model_meta.txt bad value for {k}"))
        };
        Ok(ModelMeta {
            n_params: get("n_params")?,
            padded_n: get("padded_n")?,
            vocab: get("vocab")?,
            seq: get("seq")?,
            batch: get("batch")?,
        })
    }
}

/// A worker gradient source backed by the PJRT `model_grad` artifact.
///
/// The artifact is loaded lazily on the **worker thread** (PJRT handles
/// are `Rc`-based and must not cross threads); `Send` is sound because the
/// handle is created, used and dropped on that one thread — asserted at
/// every call.
pub struct PjrtGradSource {
    artifact_path: String,
    meta: ModelMeta,
    corpus: Corpus,
    rng: Rng,
    loaded: Option<(Artifact, std::thread::ThreadId)>,
}

// SAFETY: `loaded` is always None when the struct crosses threads (it is
// populated on first use, on the worker thread, and the thread id is
// asserted on every subsequent call).
unsafe impl Send for PjrtGradSource {}

impl PjrtGradSource {
    pub fn new(artifact_path: String, meta: ModelMeta, corpus: Corpus, rng: Rng) -> Self {
        PjrtGradSource { artifact_path, meta, corpus, rng, loaded: None }
    }

    fn artifact(&mut self) -> &Artifact {
        let tid = std::thread::current().id();
        if self.loaded.is_none() {
            let art = Artifact::load(&self.artifact_path)
                .unwrap_or_else(|e| panic!("loading {}: {e:#}", self.artifact_path));
            self.loaded = Some((art, tid));
        }
        let (art, owner) = self.loaded.as_ref().unwrap();
        assert_eq!(*owner, tid, "PjrtGradSource used from a different thread");
        art
    }
}

impl GradSource for PjrtGradSource {
    fn dim(&self) -> usize {
        self.meta.n_params
    }

    fn grad(&mut self, x: &[f32], out: &mut [f32]) -> f32 {
        let (batch, seq) = (self.meta.batch, self.meta.seq);
        let (toks, tgts) = self.corpus.batch(batch, seq, &mut self.rng);
        let meta = self.meta.clone();
        let art = self.artifact();
        let outs = art
            .run_f32(&[
                Input::F32(x, vec![meta.n_params]),
                Input::U32(&toks, vec![batch, seq]),
                Input::U32(&tgts, vec![batch, seq]),
            ])
            .expect("model_grad execution failed");
        assert_eq!(outs.len(), 2, "model_grad must return (loss, grad)");
        let loss = outs[0][0];
        out.copy_from_slice(&outs[1]);
        loss
    }
}

/// Server-side evaluation on a held-out batch via `model_loss.hlo.txt`.
pub struct PjrtEvaluator {
    art: Artifact,
    toks: Vec<u32>,
    tgts: Vec<u32>,
    meta: ModelMeta,
}

impl PjrtEvaluator {
    pub fn new(dir: &str, meta: ModelMeta, corpus: &Corpus, rng: &mut Rng) -> Result<Self> {
        let art = Artifact::load(&format!("{dir}/model_loss.hlo.txt"))?;
        let (toks, tgts) = corpus.batch(meta.batch, meta.seq, rng);
        Ok(PjrtEvaluator { art, toks, tgts, meta })
    }

    pub fn loss(&self, x: &[f32]) -> f32 {
        let outs = self
            .art
            .run_f32(&[
                Input::F32(x, vec![self.meta.n_params]),
                Input::U32(&self.toks, vec![self.meta.batch, self.meta.seq]),
                Input::U32(&self.tgts, vec![self.meta.batch, self.meta.seq]),
            ])
            .expect("model_loss execution failed");
        outs[0][0]
    }
}

/// One federated training run; returns the metrics log. `spec` is any
/// registry compressor spec (see [`crate::quant::registry`]).
pub fn train_federated(
    spec: CompressorSpec,
    r: f32,
    workers: usize,
    rounds: usize,
    step: f32,
    seed: u64,
) -> Result<RunMetrics> {
    let dir = artifacts_dir();
    let meta = ModelMeta::load(&dir)?;
    let mut rng = Rng::seed_from(seed);
    let corpus = Corpus::synthetic(200_000, &mut rng);
    let shards = corpus.shard(workers);
    let eval = PjrtEvaluator::new(&dir, meta.clone(), &corpus, &mut rng)?;

    let cfg = RunConfig {
        n: meta.n_params,
        workers,
        r,
        spec_override: Some(spec),
        rounds,
        step,
        batch: 0,
        seed,
        ..Default::default()
    };
    let comps = cfg.build_compressors(&mut rng);
    let path = format!("{dir}/model_grad.hlo.txt");
    let sources: Vec<Box<dyn GradSource>> = shards
        .into_iter()
        .enumerate()
        .map(|(i, shard)| {
            Box::new(PjrtGradSource::new(
                path.clone(),
                meta.clone(),
                shard,
                Rng::seed_from(seed ^ (i as u64 + 1) * 0x9E37),
            )) as Box<dyn GradSource>
        })
        .collect();

    // Initial parameters: the exact init tensor produced by
    // model.init_params at AOT time (artifacts/model_init.bin, f32 LE).
    let x0 = load_init(&dir, meta.n_params)?;
    Ok(crate::coordinator::run_distributed(&cfg, x0, sources, comps, move |x| eval.loss(x)))
}

/// Load the flat f32 (little-endian) initial parameter vector.
pub fn load_init(dir: &str, n: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(format!("{dir}/model_init.bin"))
        .with_context(|| format!("{dir}/model_init.bin missing — run `make artifacts`"))?;
    anyhow::ensure!(bytes.len() == 4 * n, "model_init.bin has {} bytes, want {}", bytes.len(), 4 * n);
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// Fig. 3b: NDSC vs naive quantization on the federated non-convex
/// workload, at matched budgets.
///
/// The paper ran a CNN on CIFAR and found naive quantization *diverges* at
/// R = 4 while NDSC trains. On our substitute workload (transformer LM,
/// whose gradients are better conditioned than momentum-SGD CNN gradients)
/// the separation appears at the **1-bit** budget within the dithered
/// family the multi-worker algorithm (Alg. 3) actually prescribes:
/// NDSC-dith at R = 1 beats standard dithering at R = 1, and SD needs
/// roughly twice the budget to catch up — the same crossover *shape* at a
/// shifted threshold (see EXPERIMENTS.md §Fig 3b for the measurement and
/// the per-message diagnostic behind it).
pub fn fig3b(quick: bool) -> Result<Vec<crate::exp::common::Series>> {
    use crate::exp::common::{print_figure, scaled, thin, Series};
    use crate::quant::dsc::{CodecMode, EmbedKind};
    use crate::quant::registry::FrameSpec;
    let ndsc_dith = CompressorSpec::Subspace {
        embed: EmbedKind::NearDemocratic,
        mode: CodecMode::Dithered,
        frame: FrameSpec::Hadamard,
    };
    let workers = if quick { 2 } else { 4 };
    let rounds = scaled(100, quick);
    let mut series = Vec::new();
    for (name, spec, r) in [
        ("NDSC-dith-R1", ndsc_dith, 1.0),
        ("SD-R1", CompressorSpec::StandardDither, 1.0),
        ("SD-R2", CompressorSpec::StandardDither, 2.0),
    ] {
        let metrics = train_federated(spec, r, workers, rounds, 0.1, 7)?;
        let pts: Vec<(f32, f32)> = metrics
            .rounds
            .iter()
            .map(|rm| (rm.round as f32, rm.mean_local_value))
            .collect();
        let mut s = Series::new(name);
        for (x, y) in thin(&pts, 15) {
            s.push(x, y);
        }
        println!(
            "{name}: final held-out loss {:.4}, mean rate {:.3} bits/dim, {} rejected msgs",
            metrics.final_value(),
            metrics.mean_rate(metrics.final_iterate.len(), workers),
            metrics.rejected_messages
        );
        series.push(s);
    }
    print_figure("Fig 3b: federated transformer, loss vs round", "round", &series);
    Ok(series)
}
