//! Figure 2 — general convex & non-smooth: SVM training with DQ-PSGD.
//!
//! * 2a/2b: synthetic two-Gaussian data, `n = 30`, `m = 100`, `R = 0.5` —
//!   suboptimality gap and training classification error vs iterations.
//! * 2c/2d: MNIST(-like) 0-vs-1, `n = 784`, `R = 0.1` — objective value
//!   and held-out test error vs iterations.
//!
//! Each curve is a [`CompressorSpec`] (or `None` for the unquantized
//! reference) built through the registry at the figure's budget — the
//! sparsifier sizes (`k = ⌊nR⌋`, the paper's "78 coordinates × 1 bit"
//! accounting) fall out of the spec instead of being hand-wired. The
//! runs themselves execute on the unified [`crate::opt::engine`] round
//! driver via the `dq_psgd` / `psgd` spec builders.

use crate::data::mnist_like;
use crate::data::synthetic::two_gaussian_svm;
use crate::exp::common::{print_figure, scaled, thin, Series};
use crate::linalg::rng::Rng;
use crate::opt::dq_psgd::{self, DqPsgdOptions};
use crate::opt::objectives::DatasetObjective;
use crate::opt::oracle::MinibatchOracle;
use crate::opt::projection::Domain;
use crate::opt::psgd::{self, PsgdOptions};
use crate::quant::registry::{CompressorSpec, FrameSpec, InnerSpec, SparsifyKind};

/// Estimate `f*` with a long unquantized PSGD run (the paper used CVX).
fn estimate_fstar(obj: &DatasetObjective, iters: usize, seed: u64) -> f32 {
    let mut rng = Rng::seed_from(seed);
    let mut oracle = MinibatchOracle::new(obj, (obj.m / 4).max(1), Rng::seed_from(seed + 1));
    let opts = PsgdOptions { step: 0.02, iters, domain: Domain::L2Ball { radius: 20.0 } };
    let tr = psgd::run(obj, &mut oracle, &vec![0.0; obj.dim()], None, opts, &mut rng);
    tr.final_value()
}

struct SchemeSpec {
    name: &'static str,
    /// `None` = unquantized PSGD reference.
    spec: Option<CompressorSpec>,
}

#[allow(clippy::too_many_arguments)]
fn run_svm_schemes(
    obj: &DatasetObjective,
    test: Option<&DatasetObjective>,
    specs: Vec<SchemeSpec>,
    r: f32,
    iters: usize,
    step: f32,
    trials: usize,
    fstar: f32,
    title_gap: &str,
    title_err: &str,
) -> (Vec<Series>, Vec<Series>) {
    let n = obj.dim();
    let mut gap_series = Vec::new();
    let mut err_series = Vec::new();
    for scheme in &specs {
        // average the value trace over trials
        let mut acc: Vec<f64> = vec![0.0; iters];
        let mut errs: Vec<f64> = vec![0.0; iters];
        for t in 0..trials {
            let mut rng = Rng::seed_from(1000 + t as u64);
            let mut oracle =
                MinibatchOracle::new(obj, (obj.m / 10).max(1), Rng::seed_from(2000 + t as u64));
            let opts = DqPsgdOptions {
                step,
                iters,
                domain: Domain::L2Ball { radius: 20.0 },
                drop_prob: 0.0,
            };
            let trace = match scheme.spec {
                Some(spec) => {
                    let c = spec.build(n, r, &mut rng);
                    dq_psgd::run(obj, &mut oracle, c.as_ref(), &vec![0.0; n], None, opts, &mut rng)
                }
                None => psgd::run(
                    obj,
                    &mut oracle,
                    &vec![0.0; n],
                    None,
                    PsgdOptions { step, iters, domain: Domain::L2Ball { radius: 20.0 } },
                    &mut rng,
                ),
            };
            // reconstruct the averaged-iterate trajectory values
            for (i, rec) in trace.records.iter().enumerate() {
                acc[i] += rec.value as f64 / trials as f64;
            }
            // classification error of the final average at checkpoints:
            // cheap proxy — recompute from value trace is impossible, so
            // track err on the eval set at thinned points via re-run of
            // the final iterate only.
            let eval_obj = test.unwrap_or(obj);
            let e = eval_obj.classification_error(&trace.final_x) as f64;
            for v in errs.iter_mut() {
                *v = e; // final error replicated; thinned below to last point
            }
        }
        let mut s = Series::new(scheme.name);
        let pts: Vec<(f32, f32)> =
            acc.iter().enumerate().map(|(i, &v)| (i as f32, (v as f32 - fstar).max(1e-6))).collect();
        for (x, y) in thin(&pts, 16) {
            s.push(x, y);
        }
        gap_series.push(s);
        let mut se = Series::new(scheme.name);
        se.push(iters as f32, errs[0] as f32);
        err_series.push(se);
    }
    print_figure(title_gap, "iter", &gap_series);
    print_figure(title_err, "iter", &err_series);
    (gap_series, err_series)
}

/// Fig. 2a/2b: synthetic SVM at R = 0.5.
pub fn fig2ab(quick: bool) -> (Vec<Series>, Vec<Series>) {
    let (m, n) = (100, 30);
    let mut rng = Rng::seed_from(10);
    let obj = two_gaussian_svm(m, n, 0.8, &mut rng);
    let iters = scaled(600, quick);
    let trials = scaled(10, quick);
    let fstar = estimate_fstar(&obj, scaled(3000, quick), 77);
    let r = 0.5; // ⌊nR⌋ = 15 bits: rand-k keeps 15 coords, top-k 3 × 5 bits
    let specs: Vec<SchemeSpec> = vec![
        SchemeSpec { name: "unquantized", spec: None },
        SchemeSpec { name: "SD(R=0.5)", spec: Some(CompressorSpec::StandardDither) },
        SchemeSpec {
            name: "rand50%+1b",
            spec: Some(CompressorSpec::RandK { value_bits: 1, kind: SparsifyKind::Unbiased }),
        },
        SchemeSpec {
            name: "rand50%+1b+NDE",
            spec: Some(CompressorSpec::Embedded {
                inner: InnerSpec::RandK { value_bits: 1, kind: SparsifyKind::Unbiased },
                frame: FrameSpec::Orthonormal,
            }),
        },
        SchemeSpec {
            name: "top3x5b",
            spec: Some(CompressorSpec::TopK { value_bits: 5, count_index_bits: false }),
        },
        SchemeSpec {
            name: "top3x5b+NDE",
            spec: Some(CompressorSpec::Embedded {
                inner: InnerSpec::TopK { value_bits: 5 },
                frame: FrameSpec::Orthonormal,
            }),
        },
    ];
    run_svm_schemes(
        &obj,
        None,
        specs,
        r,
        iters,
        0.05,
        trials,
        fstar,
        "Fig 2a: SVM suboptimality gap (synthetic, R=0.5)",
        "Fig 2b: SVM training classification error (final)",
    )
}

/// Fig. 2c/2d: MNIST(-like) 0-vs-1 SVM at R = 0.1.
pub fn fig2cd(quick: bool) -> (Vec<Series>, Vec<Series>) {
    let mut rng = Rng::seed_from(20);
    let m = scaled(400, quick);
    let data = mnist_like::binary_digits(m, &mut rng);
    let (train, test) = data.split(m * 3 / 4);
    let obj = train.svm_objective();
    let test_obj = test.svm_objective();
    let iters = scaled(400, quick);
    let r = 0.1; // ⌊784·0.1⌋ = 78 coords at 1 bit
    let specs: Vec<SchemeSpec> = vec![
        SchemeSpec { name: "unquantized", spec: None },
        SchemeSpec {
            name: "rand78x1b",
            spec: Some(CompressorSpec::RandK { value_bits: 1, kind: SparsifyKind::Unbiased }),
        },
        SchemeSpec {
            name: "rand78x1b+NDE",
            spec: Some(CompressorSpec::Embedded {
                inner: InnerSpec::RandK { value_bits: 1, kind: SparsifyKind::Unbiased },
                frame: FrameSpec::Hadamard,
            }),
        },
        SchemeSpec {
            name: "top78x1b",
            spec: Some(CompressorSpec::TopK { value_bits: 1, count_index_bits: false }),
        },
        SchemeSpec {
            name: "top78x1b+NDE",
            spec: Some(CompressorSpec::Embedded {
                inner: InnerSpec::TopK { value_bits: 1 },
                frame: FrameSpec::Hadamard,
            }),
        },
    ];
    let fstar = 0.0; // paper plots raw objective for 2c
    run_svm_schemes(
        &obj,
        Some(&test_obj),
        specs,
        r,
        iters,
        1.0, // the paper's nominal α = 1
        1,   // single realization, as in the paper
        fstar,
        "Fig 2c: SVM objective on MNIST-like 0v1 (R=0.1)",
        "Fig 2d: SVM test classification error (final)",
    )
}
