//! Figure 2 — general convex & non-smooth: SVM training with DQ-PSGD.
//!
//! * 2a/2b: synthetic two-Gaussian data, `n = 30`, `m = 100`, `R = 0.5` —
//!   suboptimality gap and training classification error vs iterations.
//! * 2c/2d: MNIST(-like) 0-vs-1, `n = 784`, `R = 0.1` — objective value
//!   and held-out test error vs iterations.

use crate::data::mnist_like;
use crate::data::synthetic::two_gaussian_svm;
use crate::exp::common::{print_figure, scaled, thin, Series};
use crate::linalg::frames::OrthonormalFrame;
use crate::linalg::fwht::next_pow2;
use crate::linalg::rng::Rng;
use crate::opt::dq_psgd::{self, DqPsgdOptions};
use crate::opt::objectives::DatasetObjective;
use crate::opt::oracle::MinibatchOracle;
use crate::opt::projection::Domain;
use crate::opt::psgd::{self, PsgdOptions};
use crate::quant::compose::EmbeddedCompressor;
use crate::quant::gain_shape::StandardDither;
use crate::quant::randk::RandK;
use crate::quant::topk::TopK;
use crate::quant::Compressor;

/// Estimate `f*` with a long unquantized PSGD run (the paper used CVX).
fn estimate_fstar(obj: &DatasetObjective, iters: usize, seed: u64) -> f32 {
    let mut rng = Rng::seed_from(seed);
    let mut oracle = MinibatchOracle::new(obj, (obj.m / 4).max(1), Rng::seed_from(seed + 1));
    let opts =
        PsgdOptions { step: 0.02, iters, domain: Domain::L2Ball { radius: 20.0 } };
    let tr = psgd::run(obj, &mut oracle, &vec![0.0; obj.dim()], None, opts, &mut rng);
    tr.final_value()
}

struct SchemeSpec {
    name: &'static str,
    make: Box<dyn FnMut(&mut Rng) -> Option<Box<dyn Compressor>>>,
}

fn run_svm_schemes(
    obj: &DatasetObjective,
    test: Option<&DatasetObjective>,
    mut specs: Vec<SchemeSpec>,
    iters: usize,
    step: f32,
    trials: usize,
    fstar: f32,
    title_gap: &str,
    title_err: &str,
) -> (Vec<Series>, Vec<Series>) {
    let n = obj.dim();
    let mut gap_series = Vec::new();
    let mut err_series = Vec::new();
    for spec in specs.iter_mut() {
        // average the value trace over trials
        let mut acc: Vec<f64> = vec![0.0; iters];
        let mut errs: Vec<f64> = vec![0.0; iters];
        for t in 0..trials {
            let mut rng = Rng::seed_from(1000 + t as u64);
            let mut oracle =
                MinibatchOracle::new(obj, (obj.m / 10).max(1), Rng::seed_from(2000 + t as u64));
            let opts = DqPsgdOptions {
                step,
                iters,
                domain: Domain::L2Ball { radius: 20.0 },
            };
            let trace = match (spec.make)(&mut rng) {
                Some(c) => dq_psgd::run(obj, &mut oracle, c.as_ref(), &vec![0.0; n], None, opts, &mut rng),
                None => psgd::run(
                    obj,
                    &mut oracle,
                    &vec![0.0; n],
                    None,
                    PsgdOptions { step, iters, domain: Domain::L2Ball { radius: 20.0 } },
                    &mut rng,
                ),
            };
            // reconstruct the averaged-iterate trajectory values
            for (i, r) in trace.records.iter().enumerate() {
                acc[i] += r.value as f64 / trials as f64;
            }
            // classification error of the final average at checkpoints:
            // cheap proxy — recompute from value trace is impossible, so
            // track err on the eval set at thinned points via re-run of
            // the final iterate only.
            let eval_obj = test.unwrap_or(obj);
            let e = eval_obj.classification_error(&trace.final_x) as f64;
            for v in errs.iter_mut() {
                *v = e; // final error replicated; thinned below to last point
            }
        }
        let mut s = Series::new(spec.name);
        let pts: Vec<(f32, f32)> =
            acc.iter().enumerate().map(|(i, &v)| (i as f32, (v as f32 - fstar).max(1e-6))).collect();
        for (x, y) in thin(&pts, 16) {
            s.push(x, y);
        }
        gap_series.push(s);
        let mut se = Series::new(spec.name);
        se.push(iters as f32, errs[0] as f32);
        err_series.push(se);
    }
    print_figure(title_gap, "iter", &gap_series);
    print_figure(title_err, "iter", &err_series);
    (gap_series, err_series)
}

/// Fig. 2a/2b: synthetic SVM at R = 0.5.
pub fn fig2ab(quick: bool) -> (Vec<Series>, Vec<Series>) {
    let (m, n) = (100, 30);
    let mut rng = Rng::seed_from(10);
    let obj = two_gaussian_svm(m, n, 0.8, &mut rng);
    let iters = scaled(600, quick);
    let trials = scaled(10, quick);
    let fstar = estimate_fstar(&obj, scaled(3000, quick), 77);
    let k_rand = 15; // nR = 15 bits -> 15 coords at 1 bit
    let specs: Vec<SchemeSpec> = vec![
        SchemeSpec { name: "unquantized", make: Box::new(|_| None) },
        SchemeSpec {
            name: "SD(R=0.5)",
            make: Box::new(move |_| Some(Box::new(StandardDither::new(n, 0.5)) as Box<dyn Compressor>)),
        },
        SchemeSpec {
            name: "rand50%+1b",
            make: Box::new(move |_| Some(Box::new(RandK::new(n, k_rand, 1).unbiased()))),
        },
        SchemeSpec {
            name: "rand50%+1b+NDE",
            make: Box::new(move |rng| {
                let f = OrthonormalFrame::with_big_n(n, n, rng);
                Some(Box::new(EmbeddedCompressor::nde(
                    Box::new(f),
                    Box::new(RandK::new(n, k_rand, 1).unbiased()),
                )))
            }),
        },
        SchemeSpec {
            name: "top3x5b",
            make: Box::new(move |_| Some(Box::new(TopK::new(n, 3, 5)))),
        },
        SchemeSpec {
            name: "top3x5b+NDE",
            make: Box::new(move |rng| {
                let f = OrthonormalFrame::with_big_n(n, n, rng);
                Some(Box::new(EmbeddedCompressor::nde(Box::new(f), Box::new(TopK::new(n, 3, 5)))))
            }),
        },
    ];
    run_svm_schemes(
        &obj,
        None,
        specs,
        iters,
        0.05,
        trials,
        fstar,
        "Fig 2a: SVM suboptimality gap (synthetic, R=0.5)",
        "Fig 2b: SVM training classification error (final)",
    )
}

/// Fig. 2c/2d: MNIST(-like) 0-vs-1 SVM at R = 0.1.
pub fn fig2cd(quick: bool) -> (Vec<Series>, Vec<Series>) {
    let mut rng = Rng::seed_from(20);
    let m = scaled(400, quick);
    let data = mnist_like::binary_digits(m, &mut rng);
    let (train, test) = data.split(m * 3 / 4);
    let obj = train.svm_objective();
    let test_obj = test.svm_objective();
    let n = mnist_like::DIM;
    let iters = scaled(400, quick);
    let k = (n as f32 * 0.1) as usize; // 78 coords at 1 bit = nR bits
    let big_n = next_pow2(n);
    let specs: Vec<SchemeSpec> = vec![
        SchemeSpec { name: "unquantized", make: Box::new(|_| None) },
        SchemeSpec {
            name: "rand78x1b",
            make: Box::new(move |_| Some(Box::new(RandK::new(n, k, 1).unbiased()) as Box<dyn Compressor>)),
        },
        SchemeSpec {
            name: "rand78x1b+NDE",
            make: Box::new(move |rng| {
                let f = crate::linalg::frames::HadamardFrame::new(n, rng);
                Some(Box::new(EmbeddedCompressor::nde(
                    Box::new(f),
                    Box::new(RandK::new(big_n, k, 1).unbiased()),
                )))
            }),
        },
        SchemeSpec {
            name: "top78x1b",
            make: Box::new(move |_| Some(Box::new(TopK::new(n, k, 1)))),
        },
        SchemeSpec {
            name: "top78x1b+NDE",
            make: Box::new(move |rng| {
                let f = crate::linalg::frames::HadamardFrame::new(n, rng);
                Some(Box::new(EmbeddedCompressor::nde(
                    Box::new(f),
                    Box::new(TopK::new(big_n, k, 1)),
                )))
            }),
        },
    ];
    let fstar = 0.0; // paper plots raw objective for 2c
    run_svm_schemes(
        &obj,
        Some(&test_obj),
        specs,
        iters,
        1.0, // the paper's nominal α = 1
        1,   // single realization, as in the paper
        fstar,
        "Fig 2c: SVM objective on MNIST-like 0v1 (R=0.1)",
        "Fig 2d: SVM test classification error (final)",
    )
}
