//! Figure 1 — smooth & strongly-convex experiments.
//!
//! * 1a: normalized compression error, schemes ± near-democratic embedding
//!   (`y ∈ R^1000` Gaussian³, 50 realizations).
//! * 1b: empirical convergence rate of DGD-DEF vs bit budget `R`
//!   (least squares, `n = 116`, Gaussian³ data).
//! * 1c: wall-clock of democratic (LP / LV) vs near-democratic embeddings
//!   vs dimension.
//! * 1d: `l₂`-regularized least squares on (synthetic) MNIST with
//!   sparsified GD at `R = 0.5` — rand-k + 1-bit, with vs without NDE.
//!
//! Every compressor is constructed through the registry
//! ([`crate::quant::registry`]): each curve is a `CompressorSpec`
//! evaluated across the budget sweep, so adding a scheme to a figure is a
//! one-line spec change. Every optimizer run executes on the unified
//! [`crate::opt::engine`] round driver via the `dgd_def` / `gd` spec
//! builders.

use std::time::Instant;

use crate::data::mnist_like;
use crate::embed::democratic::KashinSolver;
use crate::embed::lp::{min_linf, LinfOptions};
use crate::embed::near_democratic::nde;
use crate::exp::common::{print_figure, scaled, value_series, Series};
use crate::linalg::frames::HadamardFrame;
use crate::linalg::fwht::next_pow2;
use crate::linalg::rng::Rng;
use crate::opt::dgd_def::{self, DgdDefOptions};
use crate::opt::gd;
use crate::quant::dsc::{CodecMode, EmbedKind};
use crate::quant::registry::{CompressorSpec, FrameSpec, InnerSpec, SparsifyKind};
use crate::quant::normalized_error;

fn ndsc_spec(frame: FrameSpec) -> CompressorSpec {
    CompressorSpec::Subspace { embed: EmbedKind::NearDemocratic, mode: CodecMode::Deterministic, frame }
}

fn dsc_spec(frame: FrameSpec) -> CompressorSpec {
    CompressorSpec::Subspace { embed: EmbedKind::Democratic, mode: CodecMode::Deterministic, frame }
}

/// Fig. 1a: compression error vs bit budget, with and without NDE.
pub fn fig1a(quick: bool) -> Vec<Series> {
    let n = 1000;
    let trials = scaled(50, quick);
    let rs: &[f32] = if quick { &[1.0, 3.0, 5.0] } else { &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0] };
    let mut rng = Rng::seed_from(1);
    let gen = move |rng: &mut Rng| -> Vec<f32> { (0..n).map(|_| rng.gaussian_cubed()).collect() };

    // (name, R ↦ spec): TopK's value bits scale with the budget so the
    // retained fraction stays 10%, exactly as the seed harness wired it.
    let topk = |r: f32| CompressorSpec::TopK {
        value_bits: (r.max(1.0) as u8) * 10,
        count_index_bits: false,
    };
    let curves: Vec<(&str, Box<dyn Fn(f32) -> CompressorSpec>)> = vec![
        ("SD", Box::new(|_| CompressorSpec::StandardDither)),
        (
            "SD+NDH",
            Box::new(|_| CompressorSpec::Embedded {
                inner: InnerSpec::StandardDither,
                frame: FrameSpec::Hadamard,
            }),
        ),
        (
            "SD+NDO",
            Box::new(|_| CompressorSpec::Embedded {
                inner: InnerSpec::StandardDither,
                frame: FrameSpec::Orthonormal,
            }),
        ),
        ("TopK(10%)", Box::new(move |r| topk(r))),
        (
            "TopK+NDH",
            Box::new(move |r| CompressorSpec::Embedded {
                inner: InnerSpec::TopK { value_bits: (r.max(1.0) as u8) * 10 },
                frame: FrameSpec::Hadamard,
            }),
        ),
        ("Kashin-1.5", Box::new(|_| dsc_spec(FrameSpec::OrthonormalLambda(1.5)))),
        ("naive", Box::new(|_| CompressorSpec::Naive)),
        ("NDH", Box::new(|_| ndsc_spec(FrameSpec::Hadamard))),
    ];

    let mut series: Vec<Series> = Vec::new();
    for (name, spec_at) in curves {
        let mut s = Series::new(name);
        for &r in rs {
            let c = spec_at(r).build(n, r, &mut rng);
            s.push(r, normalized_error(c.as_ref(), trials, &mut rng, gen));
        }
        series.push(s);
    }

    print_figure("Fig 1a: normalized compression error vs R (n=1000, Gaussian³)", "R", &series);
    series
}

/// Fig. 1b: empirical linear rate of DGD-DEF vs R (n = 116 least squares).
pub fn fig1b(quick: bool) -> Vec<Series> {
    let n = 116;
    let m = 200;
    let iters = scaled(150, quick);
    let rs: &[f32] =
        if quick { &[2.0, 5.0, 8.0] } else { &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 10.0, 12.0] };
    let mut rng = Rng::seed_from(2);
    let (obj, _) = crate::data::synthetic::planted_regression(
        m,
        n,
        crate::data::synthetic::Tail::GaussianCubed,
        crate::data::synthetic::Tail::Gaussian,
        0.1,
        &mut rng,
    );
    let xs = obj.quadratic_minimizer();
    let (l, mu) = obj.smoothness_strong_convexity();
    let sigma = gd::sigma(l, mu);
    let x0 = vec![0.0f32; n];
    let opts = DgdDefOptions::optimal(l, mu, iters);

    let mut series = Vec::new();
    // Unquantized GD: flat sigma line.
    let mut s = Series::new("unquantized(σ)");
    for &r in rs {
        s.push(r, sigma);
    }
    series.push(s);

    let curves: Vec<(&str, CompressorSpec)> = vec![
        ("DQGD(naive)", CompressorSpec::Naive),
        ("NDE-Hadamard", ndsc_spec(FrameSpec::Hadamard)),
        ("NDE-Orthonormal", ndsc_spec(FrameSpec::Orthonormal)),
        ("DE(Kashin λ=1.5)", dsc_spec(FrameSpec::OrthonormalLambda(1.5))),
    ];
    for (name, spec) in curves {
        let mut s = Series::new(name);
        for &r in rs {
            let c = spec.build(n, r, &mut rng);
            let tr = dgd_def::run(&obj, c.as_ref(), &x0, Some(&xs), opts, &mut rng);
            s.push(r, tr.empirical_rate());
        }
        series.push(s);
    }

    print_figure(
        &format!("Fig 1b: DGD-DEF empirical rate vs R (n={n}, σ={sigma:.3})"),
        "R",
        &series,
    );
    series
}

/// Fig. 1c: wall-clock to compute DE (LP and LV) vs NDE vs dimension.
pub fn fig1c(quick: bool) -> Vec<Series> {
    let dims: &[usize] =
        if quick { &[16, 64, 256] } else { &[16, 32, 64, 128, 256, 512, 1024, 2048] };
    let reps = if quick { 2 } else { 5 };
    let mut rng = Rng::seed_from(3);
    let mut s_lp = Series::new("DE(LP/CVX-like)");
    let mut s_lv = Series::new("DE(LV-iter)");
    let mut s_nde = Series::new("NDE(Sᵀy)");
    for &n in dims {
        let big_n = next_pow2(n.max(2) * 2); // λ≈2 as the paper's DE runs
        let frame = HadamardFrame::with_big_n(n, big_n, &mut rng);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
        // NDE
        let t0 = Instant::now();
        for _ in 0..reps * 20 {
            std::hint::black_box(nde(&frame, &y));
        }
        s_nde.push(n as f32, t0.elapsed().as_secs_f32() * 1e3 / (reps * 20) as f32);
        // LV
        let mut solver = KashinSolver::for_frame(&frame);
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(solver.embed(&frame, &y));
        }
        s_lv.push(n as f32, t0.elapsed().as_secs_f32() * 1e3 / reps as f32);
        // LP (expensive — skip huge dims in quick mode)
        if !quick || n <= 64 {
            let t0 = Instant::now();
            std::hint::black_box(min_linf(&frame, &y, &LinfOptions::default()));
            s_lp.push(n as f32, t0.elapsed().as_secs_f32() * 1e3);
        }
    }
    let series = vec![s_lp, s_lv, s_nde];
    print_figure("Fig 1c: embedding wall-clock (ms) vs dimension", "n", &series);
    series
}

/// Fig. 1d: ridge regression on MNIST(-like), sparsified GD at R = 0.5.
pub fn fig1d(quick: bool) -> Vec<Series> {
    let mut rng = Rng::seed_from(4);
    let m = scaled(200, quick);
    let data = mnist_like::generate_binary(m, 0.3, &mut rng);
    let obj = data.ridge_objective(1.0);
    let n = mnist_like::DIM;
    let (l, mu) = obj.smoothness_strong_convexity();
    let iters = scaled(150, quick);
    let opts = DgdDefOptions { step: 2.0 / (l + mu), iters };
    let x0 = vec![0.0f32; n];
    let xs = obj.quadratic_minimizer();
    let r = 0.5; // ⌊nR⌋ = n/2 coords at 1 bit — the registry derives k

    let curves: Vec<(&str, CompressorSpec)> = vec![
        (
            "rand-k+1bit",
            CompressorSpec::RandK { value_bits: 1, kind: SparsifyKind::Deterministic },
        ),
        (
            "rand-k+1bit+NDE",
            CompressorSpec::Embedded {
                inner: InnerSpec::RandK { value_bits: 1, kind: SparsifyKind::Deterministic },
                frame: FrameSpec::Orthonormal,
            },
        ),
        ("unquantized", CompressorSpec::Fp32),
    ];
    let mut series = Vec::new();
    for (name, spec) in curves {
        let eff_r = if spec == CompressorSpec::Fp32 { 32.0 } else { r };
        let c = spec.build(n, eff_r, &mut rng);
        let tr = dgd_def::run(&obj, c.as_ref(), &x0, Some(&xs), opts, &mut rng);
        series.push(value_series(name, &tr, 20));
    }

    print_figure(
        "Fig 1d: ridge on MNIST-like, sparsified GD at R=0.5 (objective vs iter)",
        "iter",
        &series,
    );
    series
}
