//! Table 1 — measured comparison of compression schemes: wire bits per
//! dimension, normalized `l₂` error on heavy-tailed inputs, and encode
//! wall-clock. The paper's table lists asymptotic orders; this harness
//! prints the corresponding *measured* values at `n = 1024` so the
//! ordering claims can be checked directly.

use std::time::Instant;

use crate::linalg::frames::HadamardFrame;
use crate::linalg::rng::Rng;
use crate::quant::compose::EmbeddedCompressor;
use crate::quant::dsc::{CodecMode, EmbedKind, SubspaceCodec};
use crate::quant::gain_shape::{NaiveUniform, StandardDither};
use crate::quant::ndsc::Ndsc;
use crate::quant::qsgd::Qsgd;
use crate::quant::randk::RandK;
use crate::quant::ratq::Ratq;
use crate::quant::sign::SignQuantizer;
use crate::quant::ternary::Ternary;
use crate::quant::topk::TopK;
use crate::quant::vqsgd::VqSgd;
use crate::quant::{normalized_error, Compressor};

pub fn schemes(n: usize, r: f32, rng: &mut Rng) -> Vec<Box<dyn Compressor>> {
    let big_n = crate::linalg::fwht::next_pow2(n);
    vec![
        Box::new(SignQuantizer::new(n)),
        Box::new(Qsgd::new(n, (r as usize).max(1))),
        Box::new(Ternary::new(n)),
        Box::new(VqSgd::new(n, 1)),
        Box::new(VqSgd::new(n, 16)),
        Box::new(TopK::new(n, n / 10, 8).counting_index_bits()),
        Box::new(RandK::new(n, n / 10, 8).unbiased()),
        Box::new(NaiveUniform::new(n, r)),
        Box::new(StandardDither::new(n, r)),
        Box::new(Ratq::new(n, r as usize, rng)),
        Box::new(SubspaceCodec::new(
            Box::new(HadamardFrame::with_big_n(n / 2, big_n / 2, rng)),
            EmbedKind::Democratic,
            CodecMode::Deterministic,
            r,
        )),
        Box::new(Ndsc::hadamard(n, r, rng)),
        Box::new(Ndsc::orthonormal(n.min(512), r, rng)),
        Box::new(EmbeddedCompressor::nde(
            Box::new(HadamardFrame::new(n, rng)),
            Box::new(StandardDither::new(big_n, r)),
        )),
    ]
}

/// Run Table 1. `quick` shrinks trial counts for CI.
pub fn run(quick: bool) {
    let n = 1024;
    let r = 3.0;
    let trials = if quick { 5 } else { 30 };
    let mut rng = Rng::seed_from(42);
    println!("\n=== Table 1: compression schemes at n={n}, R≈{r} (Gaussian³ inputs) ===");
    println!(
        "{:<24} {:>12} {:>14} {:>14} {:>12}",
        "scheme", "bits/dim", "norm-error", "encode-us", "unbiased"
    );
    let schemes = schemes(n, r, &mut rng);
    for c in &schemes {
        let dim = c.n();
        let err = normalized_error(c.as_ref(), trials, &mut rng, |rng| {
            (0..dim).map(|_| rng.gaussian_cubed()).collect()
        });
        // encode timing
        let y: Vec<f32> = (0..dim).map(|_| rng.gaussian_cubed()).collect();
        let reps = if quick { 3 } else { 10 };
        let t0 = Instant::now();
        let mut bits = 0usize;
        for _ in 0..reps {
            bits = c.compress(&y, &mut rng).payload_bits;
        }
        let us = t0.elapsed().as_micros() as f64 / reps as f64;
        println!(
            "{:<24} {:>12.3} {:>14.4} {:>14.1} {:>12}",
            c.name(),
            bits as f32 / dim as f32,
            err,
            us,
            c.is_unbiased()
        );
        println!(
            "TABLE1\t{}\t{}\t{}\t{}",
            c.name(),
            bits as f32 / dim as f32,
            err,
            us
        );
    }
}
