//! Table 1 — measured comparison of compression schemes: wire bits per
//! dimension, normalized `l₂` error on heavy-tailed inputs, and encode
//! wall-clock. The paper's table lists asymptotic orders; this harness
//! prints the corresponding *measured* values at `n = 1024` so the
//! ordering claims can be checked directly. (Pure codec measurements —
//! the only experiment with no optimizer run, hence nothing routed
//! through [`crate::opt::engine`]; every scheme still comes from the
//! registry.)

use std::time::Instant;

use crate::linalg::rng::Rng;
use crate::quant::dsc::{CodecMode, EmbedKind};
use crate::quant::registry::{CompressorSpec, FrameSpec};
use crate::quant::{normalized_error, Compressor};

/// The Table-1 scheme zoo, constructed entirely through the registry.
/// Dense-frame schemes are capped in dimension (a Haar rotation at
/// `n = 65536` would be an `O(n²)` matrix) exactly as the seed harness
/// did.
pub fn schemes(n: usize, r: f32, rng: &mut Rng) -> Vec<Box<dyn Compressor>> {
    let mut out: Vec<Box<dyn Compressor>> = Vec::new();
    for spec in crate::quant::registry::all_specs() {
        // Dimension caps for dense frames; skip infeasible fixed-rate
        // schemes rather than emit budget-violating rows.
        let dim = crate::quant::registry::dense_frame_dim_cap(&spec, n);
        if !spec.is_feasible(dim, r) {
            continue;
        }
        out.push(spec.build(dim, r, rng));
    }
    // Extra row beyond the canonical zoo: a genuinely wide (λ = 2)
    // democratic code on the half dimension — the Kashin wide-frame
    // regime that the zoo's λ → 1 Hadamard rows cannot show (App. N).
    // NOTE: this is a deliberate change of operating point from the seed
    // harness, whose "half-dimension DSC" row worked out to λ = 1 for
    // power-of-two n.
    let half = (n / 2).max(2);
    out.push(
        CompressorSpec::Subspace {
            embed: EmbedKind::Democratic,
            mode: CodecMode::Deterministic,
            frame: FrameSpec::HadamardLambda(2),
        }
        .build(half, r, rng),
    );
    out
}

/// Run Table 1. `quick` shrinks trial counts for CI.
pub fn run(quick: bool) {
    let n = 1024;
    let r = 3.0;
    let trials = if quick { 5 } else { 30 };
    let mut rng = Rng::seed_from(42);
    println!("\n=== Table 1: compression schemes at n={n}, R≈{r} (Gaussian³ inputs) ===");
    println!(
        "{:<24} {:>12} {:>14} {:>14} {:>12}",
        "scheme", "bits/dim", "norm-error", "encode-us", "unbiased"
    );
    let schemes = schemes(n, r, &mut rng);
    for c in &schemes {
        let dim = c.n();
        let err = normalized_error(c.as_ref(), trials, &mut rng, |rng| {
            (0..dim).map(|_| rng.gaussian_cubed()).collect()
        });
        // encode timing
        let y: Vec<f32> = (0..dim).map(|_| rng.gaussian_cubed()).collect();
        let reps = if quick { 3 } else { 10 };
        let t0 = Instant::now();
        let mut bits = 0usize;
        for _ in 0..reps {
            bits = c.compress(&y, &mut rng).payload_bits;
        }
        let us = t0.elapsed().as_micros() as f64 / reps as f64;
        println!(
            "{:<24} {:>12.3} {:>14.4} {:>14.1} {:>12}",
            c.name(),
            bits as f32 / dim as f32,
            err,
            us,
            c.is_unbiased()
        );
        println!(
            "TABLE1\t{}\t{}\t{}\t{}",
            c.name(),
            bits as f32 / dim as f32,
            err,
            us
        );
    }
}
