//! `repro mesh` — decentralized gossip sweeps: **topology × scheme ×
//! R × drop-rate** grids on the planted multi-shard regression, run on
//! the serverless mesh engine ([`crate::mesh`]).
//!
//! Each cell gossips compressed innovations over the peer graph with
//! Metropolis mixing and per-edge DEF feedback, and reports the final
//! consensus distance `max_i ‖x_i − x̄‖`, the global objective at the
//! node average, and the **exact** wire accounting: every delivered
//! directed message is charged
//! [`upload_wire_bytes`](crate::coordinator::protocol::upload_wire_bytes),
//! so a bidirectional link counts twice per round. The grid is printed
//! as a table and saved to `BENCH_mesh.json` — per-link byte tallies
//! included — so mesh regressions diff mechanically across PRs. An
//! uncompressed `fp32` twin (R = 32) anchors every topology × drop
//! pair.
//!
//! ```text
//! repro mesh [--quick] [n=32] [m=9] [rounds=400] [seed=7] [gamma=0.5]
//! ```

use crate::coordinator::transport::{LinkModel, Topology};
use crate::data::synthetic::planted_regression_shards;
use crate::linalg::rng::Rng;
use crate::mesh::{run_sharded, LinkStats, MeshConfig};
use crate::opt::engine::schedule::Schedule;
use crate::opt::multi::ShardedProblem;
use crate::opt::objectives::Loss;
use crate::quant::registry::CompressorSpec;

/// Shard-data salt (kept distinct from the CLI's so `repro mesh`
/// traces stay byte-stable across PRs).
const MESH_DATA_SALT: u64 = 0xDA7A_3E5B;

/// One grid cell's summary.
struct MeshCell {
    topology: String,
    scheme: String,
    r: f32,
    drop: f32,
    rounds: usize,
    final_consensus: f32,
    final_value: f32,
    wire_bytes: u64,
    mean_node_bits: f64,
    per_link: Vec<LinkStats>,
}

#[allow(clippy::too_many_arguments)]
fn run_cell(
    n: usize,
    m: usize,
    rounds: usize,
    seed: u64,
    gamma: f32,
    topology: Topology,
    scheme: CompressorSpec,
    r: f32,
    drop: f32,
) -> MeshCell {
    let mut rng = Rng::seed_from(seed ^ MESH_DATA_SALT);
    let (shards, _xs) = planted_regression_shards(m, 2 * n, n, Loss::Square, &mut rng, false);
    let problem = ShardedProblem::new(shards);
    let step = problem.stable_step();
    let mut cfg = MeshConfig::new(m, n, topology, scheme, r, seed);
    cfg.gamma = gamma;
    cfg.schedule = Schedule::Constant(step);
    cfg.rounds = rounds;
    cfg.link = LinkModel {
        base_latency_us: 200,
        jitter_us: 100,
        drop_prob: drop,
        bandwidth_bits_per_us: 8.0,
    };
    // One source of truth for invariants (topology node counts, budget
    // feasibility, gamma range): the same validation the library runs.
    let metrics = run_sharded(cfg, &problem).unwrap_or_else(|e| {
        eprintln!("mesh: invalid configuration: {e}");
        std::process::exit(2);
    });
    let mean_node_bits = metrics.node_wire_bits.iter().sum::<u64>() as f64 / m as f64;
    MeshCell {
        topology: topology.to_string(),
        scheme: scheme.name(),
        r,
        drop,
        rounds,
        final_consensus: metrics.final_consensus,
        final_value: metrics.final_value,
        wire_bytes: metrics.total_wire_bytes(),
        mean_node_bits,
        per_link: metrics.per_link,
    }
}

fn cells_to_json(cells: &[MeshCell]) -> String {
    let mut s = String::from("[\n");
    for (i, c) in cells.iter().enumerate() {
        let mut links = String::from("[");
        for (k, l) in c.per_link.iter().enumerate() {
            links.push_str(&format!(
                "{{\"a\": {}, \"b\": {}, \"bytes\": {}, \"delivered\": {}, \"dropped\": {}}}{}",
                l.a,
                l.b,
                l.bytes,
                l.delivered,
                l.dropped,
                if k + 1 == c.per_link.len() { "" } else { ", " }
            ));
        }
        links.push(']');
        s.push_str(&format!(
            "  {{\"topology\": \"{}\", \"scheme\": \"{}\", \"r\": {}, \"drop\": {}, \
             \"rounds\": {}, \"final_consensus\": {}, \"final_value\": {}, \
             \"wire_bytes\": {}, \"mean_node_bits\": {}, \"per_link\": {}}}{}\n",
            c.topology,
            c.scheme,
            c.r,
            c.drop,
            c.rounds,
            c.final_consensus,
            c.final_value,
            c.wire_bytes,
            c.mean_node_bits,
            links,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    s.push_str("]\n");
    s
}

/// The most-square torus that tiles `m` nodes with both axes ≥ 3, if
/// one exists.
fn torus_for(m: usize) -> Option<Topology> {
    let mut best = None;
    let mut rows = 3usize;
    while rows * rows <= m {
        if m % rows == 0 && m / rows >= 3 {
            best = Some(Topology::Torus { rows, cols: m / rows });
        }
        rows += 1;
    }
    best
}

/// Run the sweep. `args` accepts `n=`, `m=`/`nodes=`, `rounds=`,
/// `seed=` and `gamma=` overrides.
pub fn run(quick: bool, args: &[String]) {
    let mut n = 32usize;
    let mut m = 9usize;
    let mut rounds = if quick { 60 } else { 400 };
    let mut seed = 7u64;
    let mut gamma = 0.5f32;
    // Malformed values abort just like unknown keys do: silently keeping
    // a default would run the whole sweep on the wrong parameters.
    fn bail(key: &str, v: &str) -> ! {
        eprintln!("mesh: bad value '{v}' for {key}=");
        std::process::exit(2);
    }
    for a in args {
        match a.split_once('=') {
            Some(("n", v)) => n = v.parse().unwrap_or_else(|_| bail("n", v)),
            Some(("m", v)) | Some(("nodes", v)) => {
                m = v.parse().unwrap_or_else(|_| bail("m", v))
            }
            Some(("rounds", v)) => rounds = v.parse().unwrap_or_else(|_| bail("rounds", v)),
            Some(("seed", v)) => seed = v.parse().unwrap_or_else(|_| bail("seed", v)),
            Some(("gamma", v)) => gamma = v.parse().unwrap_or_else(|_| bail("gamma", v)),
            _ => {
                eprintln!("mesh: expected n=|m=|rounds=|seed=|gamma=, got '{a}'");
                std::process::exit(2);
            }
        }
    }

    let mut topologies = vec![Topology::Ring, Topology::random(0.3)];
    match torus_for(m) {
        Some(t) => topologies.insert(1, t),
        None => println!("(no torus fits m={m} with both axes >= 3; skipping the torus column)"),
    }
    let schemes: Vec<CompressorSpec> = ["ndsc-dith", "sd", "sign"]
        .iter()
        .map(|s| CompressorSpec::parse(s).expect("registry scheme"))
        .collect();
    let rates = [0.5f32, 1.0, 4.0];
    let drops = [0.0f32, 0.1];

    println!("=== repro mesh: gossip sweep (n={n}, m={m}, rounds={rounds}, gamma={gamma}) ===");
    println!(
        "{:<12} {:<10} {:>5} {:>6} {:>12} {:>12} {:>12}",
        "topology", "scheme", "R", "drop", "consensus", "f(x_bar)", "KiB/node"
    );
    let mut cells = Vec::new();
    for &topology in &topologies {
        for drop in drops {
            // The uncompressed twin anchors each topology × drop pair.
            for (scheme, r) in schemes
                .iter()
                .flat_map(|s| rates.iter().map(move |&r| (*s, r)))
                .chain(std::iter::once((CompressorSpec::Fp32, 32.0)))
            {
                if !scheme.is_feasible(n, r) {
                    continue; // e.g. sign below 1 bit/dim
                }
                let cell = run_cell(n, m, rounds, seed, gamma, topology, scheme, r, drop);
                println!(
                    "{:<12} {:<10} {:>5} {:>6} {:>12.5} {:>12.5} {:>12.2}",
                    cell.topology,
                    cell.scheme,
                    cell.r,
                    cell.drop,
                    cell.final_consensus,
                    cell.final_value,
                    cell.mean_node_bits / 8192.0
                );
                cells.push(cell);
            }
        }
    }
    let json = cells_to_json(&cells);
    match std::fs::write("BENCH_mesh.json", &json) {
        Ok(()) => println!("wrote BENCH_mesh.json ({} cells)", cells.len()),
        Err(e) => eprintln!("could not write BENCH_mesh.json: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_cell_runs_and_serializes() {
        let cell = run_cell(
            16,
            4,
            10,
            3,
            0.5,
            Topology::Ring,
            CompressorSpec::parse("ndsc-dith").unwrap(),
            1.0,
            0.1,
        );
        assert!(cell.final_value.is_finite());
        assert_eq!(cell.per_link.len(), 4, "a 4-ring has 4 links");
        let json = cells_to_json(&[cell]);
        assert!(json.contains("\"topology\": \"ring\""));
        assert!(json.contains("\"per_link\": [{\"a\": 0"));
        assert!(json.trim_end().ends_with(']'));
    }

    #[test]
    fn torus_fitting_prefers_square_tilings() {
        assert_eq!(torus_for(9), Some(Topology::Torus { rows: 3, cols: 3 }));
        assert_eq!(torus_for(12), Some(Topology::Torus { rows: 3, cols: 4 }));
        assert_eq!(torus_for(16), Some(Topology::Torus { rows: 4, cols: 4 }));
        assert_eq!(torus_for(7), None);
        assert_eq!(torus_for(6), None, "2x3 axes are too short");
    }
}
