//! Figure 3 + Appendix I — parameter-server with multiple workers.
//!
//! * 3a: multi-worker regression (`n = 30`, `m = 10`, `s = 10`,
//!   `x* ~ Student-t`, `A ~ N(0,1)`) — suboptimality vs rounds.
//! * 5/6: the Appendix-I sweeps (Gaussian³ / Student-t, R ∈ {0.5, 1}).
//! * 3b: the non-convex federated run (transformer; see
//!   [`crate::exp::transformer`] and `examples/train_transformer.rs`).
//!
//! Every sweep cell executes the `multi` spec (per-worker `ShardOracle`
//! + codec, Polyak average) on the unified [`crate::opt::engine`] round
//! driver.

use crate::coordinator::transport::Participation;
use crate::data::synthetic::planted_regression_shards;
use crate::exp::common::{print_figure, scaled, thin, Series};
use crate::linalg::rng::Rng;
use crate::opt::multi::{self, MultiOptions, ShardedProblem};
use crate::opt::objectives::Loss;
use crate::opt::projection::Domain;
use crate::quant::gain_shape::StandardDither;
use crate::quant::ndsc::Ndsc;
use crate::quant::Compressor;

fn make_worker_compressors(
    m: usize,
    n: usize,
    r: f32,
    scheme: &str,
    rng: &mut Rng,
) -> Vec<Box<dyn Compressor>> {
    (0..m)
        .map(|_| -> Box<dyn Compressor> {
            match scheme {
                "ndsc" => Box::new(Ndsc::hadamard_dithered(n, r, rng)),
                "ndsc-ortho" => Box::new(Ndsc::orthonormal_dithered(n, r, rng)),
                "naive" => Box::new(StandardDither::new(n, r)),
                _ => panic!("unknown scheme {scheme}"),
            }
        })
        .collect()
}

/// One multi-worker regression sweep; returns value-vs-round series per
/// scheme, averaged over `trials` independent data draws.
pub fn multiworker_sweep(
    student_t: bool,
    rs: &[f32],
    trials: usize,
    rounds: usize,
    seed: u64,
) -> Vec<Series> {
    let (m_workers, s, n) = (10, 10, 30);
    let mut series = Vec::new();
    for &r in rs {
        for scheme in ["naive", "ndsc"] {
            let mut acc = vec![0.0f64; rounds];
            for t in 0..trials {
                let mut rng = Rng::seed_from(seed + 31 * t as u64);
                let (shards, xs) =
                    planted_regression_shards(m_workers, s, n, Loss::Square, &mut rng, student_t);
                let problem = ShardedProblem::new(shards);
                let comps = make_worker_compressors(m_workers, n, r, scheme, &mut rng);
                let opts = MultiOptions {
                    step: problem.stable_step(),
                    iters: rounds,
                    domain: Domain::Unconstrained,
                    batch: Some(5),
                    participation: Participation::Full,
                };
                let tr = multi::run(&problem, &comps, &vec![0.0; n], Some(&xs), opts, &mut rng);
                for (i, rec) in tr.records.iter().enumerate() {
                    acc[i] += (rec.value as f64).min(1e9) / trials as f64;
                }
            }
            let mut ser = Series::new(format!("{scheme}-R{r}"));
            let pts: Vec<(f32, f32)> =
                acc.iter().enumerate().map(|(i, &v)| (i as f32, v as f32)).collect();
            for (x, y) in thin(&pts, 16) {
                ser.push(x, y);
            }
            series.push(ser);
        }
    }
    series
}

/// Fig. 3a: Student-t planted model, R = 1.
pub fn fig3a(quick: bool) -> Vec<Series> {
    let rounds = scaled(300, quick);
    let trials = scaled(5, quick);
    let series = multiworker_sweep(true, &[1.0], trials, rounds, 42);
    print_figure(
        "Fig 3a: multi-worker regression (Student-t, m=10, R=1) — f(x_t) vs round",
        "round",
        &series,
    );
    series
}

/// Fig. 5: Gaussian³ data, R ∈ {0.5, 1} (Appendix I).
pub fn fig5(quick: bool) -> Vec<Series> {
    let rounds = scaled(300, quick);
    let trials = scaled(5, quick);
    let series = multiworker_sweep(false, &[0.5, 1.0], trials, rounds, 43);
    print_figure("Fig 5: multi-worker regression (Gaussian³), R∈{0.5,1}", "round", &series);
    series
}

/// Fig. 6: Student-t data, R ∈ {0.5, 1} (Appendix I).
pub fn fig6(quick: bool) -> Vec<Series> {
    let rounds = scaled(300, quick);
    let trials = scaled(5, quick);
    let series = multiworker_sweep(true, &[0.5, 1.0], trials, rounds, 44);
    print_figure("Fig 6: multi-worker regression (Student-t), R∈{0.5,1}", "round", &series);
    series
}
