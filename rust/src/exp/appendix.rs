//! Appendix N figures — the λ = N/n tradeoff study.
//!
//! * Fig. 8a/8b: `‖x_nd‖∞` vs embedding dimension `N` (decreasing).
//! * Fig. 9a/9b: `‖x_nd‖∞·√N` vs `N` (≈ constant — the two effects of
//!   growing `N` cancel).
//! * Fig. 11a/11b: same two quantities for *democratic* embeddings over
//!   random orthonormal frames with λ ∈ [1, 50].
//! * Fig. 12a/12b: `l₂` quantization error of DSC vs `N` (increasing ⇒
//!   choose λ → 1, the paper's App. N conclusion).

use crate::embed::democratic::KashinSolver;
use crate::embed::near_democratic::nde;
use crate::exp::common::{print_figure, scaled, Series};
use crate::linalg::frames::{HadamardFrame, OrthonormalFrame};
use crate::linalg::rng::Rng;
use crate::linalg::vecops::{norm2, norm_inf};
use crate::quant::dsc::{CodecMode, EmbedKind, SubspaceCodec};
use crate::quant::normalized_error;

fn heavy_vec(n: usize, student_t: bool, rng: &mut Rng) -> Vec<f32> {
    (0..n)
        .map(|_| if student_t { rng.student_t(1) } else { rng.gaussian_cubed() })
        .collect()
}

/// Figs. 8 & 9: NDE l∞ norm (and ·√N) vs N, Hadamard frames, n = 30.
pub fn fig8_9(quick: bool) -> Vec<Series> {
    let n = 30;
    let trials = scaled(50, quick);
    let pows: &[u32] = if quick { &[5, 8, 11] } else { &[5, 6, 7, 8, 9, 10, 11, 12, 13] };
    let mut rng = Rng::seed_from(8);
    let mut series = Vec::new();
    for (tail_name, student_t) in [("gauss3", false), ("student-t", true)] {
        let mut s_inf = Series::new(format!("linf-{tail_name}"));
        let mut s_scaled = Series::new(format!("linf*sqrtN-{tail_name}"));
        for &p in pows {
            let big_n = 1usize << p;
            let mut acc = 0.0f64;
            for _ in 0..trials {
                let frame = HadamardFrame::with_big_n(n, big_n, &mut rng);
                let y = heavy_vec(n, student_t, &mut rng);
                let x = nde(&frame, &y);
                acc += norm_inf(&x) as f64 / trials as f64;
            }
            s_inf.push(big_n as f32, acc as f32);
            s_scaled.push(big_n as f32, (acc * (big_n as f64).sqrt()) as f32);
        }
        series.push(s_inf);
        series.push(s_scaled);
    }
    print_figure("Figs 8/9: ‖x_nd‖∞ and ‖x_nd‖∞·√N vs N (n=30, Hadamard)", "N", &series);
    series
}

/// Figs. 11 & 12: democratic embeddings over orthonormal frames,
/// λ ∈ [1, 50]: l∞ norms and the DSC quantization error vs N.
pub fn fig11_12(quick: bool) -> Vec<Series> {
    let n = 30;
    let r = 2.0; // bits/dim for the Fig. 12 error
    let trials = scaled(20, quick);
    let lambdas: &[f32] =
        if quick { &[1.0, 1.5, 3.0, 10.0] } else { &[1.0, 1.1, 1.3, 1.5, 1.8, 2.0, 2.5, 3.0, 4.0, 5.0, 10.0, 20.0, 50.0] };
    let mut rng = Rng::seed_from(11);
    let mut s_inf = Series::new("linf(DE)");
    let mut s_scaled = Series::new("linf*sqrtN(DE)");
    let mut s_err = Series::new("DSC-quant-err(R=2)");
    for &lambda in lambdas {
        let big_n = ((n as f32 * lambda).ceil() as usize).max(n);
        let mut acc_inf = 0.0f64;
        for _ in 0..trials {
            let frame = OrthonormalFrame::with_big_n(n, big_n, &mut rng);
            let mut solver = KashinSolver::for_frame(&frame);
            let y = heavy_vec(n, false, &mut rng);
            let emb = solver.embed(&frame, &y);
            acc_inf += (norm_inf(&emb.x) / norm2(&y).max(1e-30)) as f64 / trials as f64;
        }
        s_inf.push(big_n as f32, acc_inf as f32);
        s_scaled.push(big_n as f32, (acc_inf * (big_n as f64).sqrt()) as f32);
        // Fig 12: end-to-end DSC error at this λ.
        let frame = OrthonormalFrame::with_big_n(n, big_n, &mut rng);
        let codec = SubspaceCodec::new(
            Box::new(frame),
            EmbedKind::Democratic,
            CodecMode::Deterministic,
            r,
        );
        let err = normalized_error(&codec, trials, &mut rng, |rng| heavy_vec(n, false, rng));
        s_err.push(big_n as f32, err);
    }
    let series = vec![s_inf, s_scaled, s_err];
    print_figure(
        "Figs 11/12: DE ‖x_d‖∞ (normalized), ·√N, and DSC error vs N (n=30, orthonormal)",
        "N",
        &series,
    );
    series
}
