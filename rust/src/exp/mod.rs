//! Experiment harness — regenerates every table and figure of the paper's
//! evaluation (index in DESIGN.md §5). Each module prints the same
//! rows/series the paper plots, in plain text + machine-readable
//! `SERIES\t...` lines; `repro <exp-id>` is the CLI entry point.
//!
//! Absolute numbers differ from the paper (different hardware, synthetic
//! data substitutes); the *shape* claims — who wins, by what factor, where
//! crossovers fall — are asserted in `rust/tests/test_figures.rs`.

pub mod ablation;
pub mod appendix;
pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod mesh;
pub mod net;
pub mod serve;
pub mod table1;
pub mod transformer;
