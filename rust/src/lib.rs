//! # kashinflow
//!
//! A production-grade reproduction of *"Efficient Randomized Subspace
//! Embeddings for Distributed Optimization under a Communication Budget"*
//! (Saha, Pilanci, Goldsmith, 2021).
//!
//! The library implements the paper's full stack:
//!
//! * **Democratic / near-democratic (Kashin) embeddings** ([`embed`]) —
//!   the `l_inf`-minimizing subspace representations of §2, computed with the
//!   Lyubarskii–Vershynin iteration, an exact LP, or the closed-form
//!   near-democratic transform `x = Sᵀy`.
//! * **Source coding** ([`quant`]) — Democratic Source Coding (DSC) and
//!   Near-Democratic Source Coding (NDSC) of §3, plus every baseline
//!   compressor from Table 1 (QSGD, sign, ternary, top-k, random-k,
//!   vqSGD cross-polytope, RATQ-style adaptive ranges) and an exact-width
//!   bit-packed wire format that respects the budget of `R` bits/dimension
//!   for any `R ∈ (0, ∞)`.
//! * **Optimizers** ([`opt`]) — one composable round engine
//!   ([`opt::engine`]: pluggable oracles, step schedules, feedback
//!   memories and drivers) behind every algorithm: `DGD-DEF` (Alg. 1,
//!   error feedback, smooth strongly-convex), `DQ-PSGD` (Alg. 2/3,
//!   dithered gain–shape, general convex non-smooth), the multi-worker
//!   consensus loops, and the unquantized GD / projected SGD references,
//!   plus the objective/oracle zoo used in the evaluation.
//! * **Distributed runtime** ([`coordinator`]) — a parameter-server with
//!   `m` workers over a pluggable transport (in-process channels, a
//!   deterministic SimNet latency/jitter/drop/topology model, recorded
//!   traces with bit-exact replay), byte-accounted and budget-enforced
//!   per worker (`⌊n·R_i⌋`), with full / k-of-m / deadline participation —
//!   the multi-worker consensus loop of §4.3.
//! * **Mesh engine** ([`mesh`]) — the serverless counterpart: every
//!   node owns its iterate and gossips *compressed innovations* with
//!   its peer-graph neighbors (ring / torus / seeded random graphs)
//!   over Metropolis mixing weights, with the full codec registry and
//!   a DEF-style feedback memory on every directed link.
//! * **Serving layer** ([`serve`]) — N concurrent jobs (any engine
//!   composition) multiplexed over one **global** bits-per-round budget:
//!   job registry with lifecycle, deficit-round-robin arbitration with
//!   effective-`R_i` degradation, and versioned binary checkpoints that
//!   resume a suspended job bit-for-bit.
//! * **PJRT runtime** ([`runtime`]) — loads AOT-compiled JAX/Pallas HLO
//!   artifacts (built once by `python/compile/aot.py`) and executes them
//!   from the Rust hot path; Python is never on the request path.
//! * **Experiment harness** ([`exp`]) — regenerates every table and figure
//!   of the paper's evaluation (see `DESIGN.md` for the index).

pub mod coordinator;
pub mod data;
pub mod embed;
pub mod exp;
pub mod linalg;
pub mod mesh;
pub mod opt;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod testkit;
