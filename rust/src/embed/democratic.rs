//! Democratic (Kashin) embeddings via the Lyubarskii–Vershynin iteration.
//!
//! The paper ([10], used for the `Kashin` curves in Fig. 1a) computes a
//! Kashin representation of `y` w.r.t. a Parseval frame `S` satisfying the
//! Uncertainty Principle with parameters `(η, δ)` by repeating:
//!
//! ```text
//! b ← y,  x ← 0
//! repeat K times:
//!     a ← Sᵀb                       (project the residual)
//!     M ← ‖b‖₂ / √(δN)              (truncation level)
//!     x ← x + clip(a, ±M)           (accumulate the democratic part)
//!     b ← b − S·clip(a, ±M)         (new residual; ‖b‖ ≤ η‖b_prev‖)
//! ```
//!
//! after which `‖y − Sx‖₂ ≤ η^K‖y‖₂` and `‖x‖∞ ≤ M₀/(1−η)·(1/√N)`-scale,
//! i.e. `x` is a Kashin representation with constant `K_u = O(1)` (Lemma 1).
//! A final correction `x += Sᵀb` makes the representation **exact**
//! (`Sx = y` up to float error) at negligible `l∞` cost.
//!
//! The UP parameters are not readily available for concrete random draws
//! (the paper makes the same observation), so [`KashinParams::for_frame`]
//! provides the empirically-tuned values used in the experiments, and the
//! solver is also self-guarding: if an iteration fails to contract it
//! relaxes the truncation level.

use crate::linalg::frames::Frame;
use crate::linalg::rng::Rng;
use crate::linalg::vecops::{norm2, norm_inf};

/// Tuning of the LV iteration.
#[derive(Clone, Copy, Debug)]
pub struct KashinParams {
    /// UP sparsity fraction δ ∈ (0,1): truncation level is `‖b‖/√(δN)`.
    pub delta: f32,
    /// Expected contraction factor per iteration (only used to size the
    /// iteration count).
    pub eta: f32,
    /// Number of truncate-and-project rounds.
    pub iters: usize,
}

impl KashinParams {
    /// Empirical defaults by aspect ratio λ = N/n. Tighter frames (λ→1)
    /// leave less room to spread mass, so δ shrinks and more iterations are
    /// needed; for λ = 1 the democratic embedding *is* `Sᵀy` and the
    /// iteration converges in one step.
    pub fn for_lambda(lambda: f32) -> Self {
        // Heuristics consistent with [10] and with the Kashin-compression
        // literature: delta ~ (1 - 1/λ) scaled down for safety.
        let delta = (0.7 * (1.0 - 1.0 / lambda)).clamp(0.05, 0.6);
        let eta = (1.0 - 0.5 * (1.0 - 1.0 / lambda)).clamp(0.5, 0.98);
        let iters = if lambda <= 1.0 + 1e-6 {
            1
        } else {
            // enough rounds to push the residual below f32 noise
            ((-24.0f32) / eta.log2()).ceil().clamp(8.0, 60.0) as usize
        };
        KashinParams { delta, eta, iters }
    }

    pub fn for_frame(frame: &dyn Frame) -> Self {
        Self::for_lambda(frame.lambda())
    }
}

/// Result of a Kashin computation.
#[derive(Clone, Debug)]
pub struct KashinEmbedding {
    /// The representation `x ∈ R^N` with `Sx = y` (exact to float error).
    pub x: Vec<f32>,
    /// Residual `‖y − Sx‖₂` *before* the final exact correction.
    pub pre_correction_residual: f32,
    /// Rounds actually executed.
    pub iters: usize,
}

/// Lyubarskii–Vershynin solver. Reusable: holds scratch buffers so repeated
/// embeddings (every optimizer iteration) do not allocate.
pub struct KashinSolver {
    params: KashinParams,
    scratch_a: Vec<f32>,
    scratch_b: Vec<f32>,
    scratch_sy: Vec<f32>,
}

impl KashinSolver {
    pub fn new(params: KashinParams) -> Self {
        KashinSolver {
            params,
            scratch_a: Vec::new(),
            scratch_b: Vec::new(),
            scratch_sy: Vec::new(),
        }
    }

    pub fn for_frame(frame: &dyn Frame) -> Self {
        Self::new(KashinParams::for_frame(frame))
    }

    /// Compute a Kashin (democratic) embedding of `y` w.r.t. `frame`.
    pub fn embed(&mut self, frame: &dyn Frame, y: &[f32]) -> KashinEmbedding {
        let mut x = vec![0.0f32; frame.big_n()];
        let stats = self.embed_into(frame, y, &mut x);
        KashinEmbedding {
            x,
            pre_correction_residual: stats.pre_correction_residual,
            iters: stats.iters,
        }
    }

    /// Allocation-free form of [`KashinSolver::embed`]: writes the
    /// representation into the caller's `x` (`len == N`, fully
    /// overwritten) and scratches only in the solver's warm buffers.
    /// Same iteration, same floats as the allocating form.
    pub fn embed_into(&mut self, frame: &dyn Frame, y: &[f32], x: &mut [f32]) -> KashinStats {
        let (n, big_n) = (frame.n(), frame.big_n());
        assert_eq!(y.len(), n);
        assert_eq!(x.len(), big_n);
        let p = self.params;
        self.scratch_a.resize(big_n, 0.0);
        self.scratch_b.resize(n, 0.0);
        self.scratch_sy.resize(n, 0.0);

        x.fill(0.0);
        let b = &mut self.scratch_b;
        b.copy_from_slice(y);
        let mut level_scale = 1.0f32;
        let mut prev_res = norm2(b);
        let mut iters_done = 0;
        if prev_res > 0.0 {
            for _ in 0..p.iters {
                iters_done += 1;
                // a = S^T b
                frame.adjoint(b, &mut self.scratch_a);
                let m = level_scale * norm2(b) / (p.delta * big_n as f32).sqrt();
                // x += clip(a, m); then b -= S clip(a, m)
                for v in self.scratch_a.iter_mut() {
                    *v = v.clamp(-m, m);
                }
                for (xi, &ai) in x.iter_mut().zip(self.scratch_a.iter()) {
                    *xi += ai;
                }
                // scratch_a is dead until the next adjoint refills it, so
                // the transform may destroy it (no per-iteration allocs).
                frame.apply_inplace(&mut self.scratch_a, &mut self.scratch_sy);
                for (bi, &si) in b.iter_mut().zip(self.scratch_sy.iter()) {
                    *bi -= si;
                }
                let res = norm2(b);
                if res < 1e-7 * (1.0 + norm2(y)) {
                    break;
                }
                // Self-guard: if we failed to contract, the assumed (η, δ)
                // are too optimistic for this frame draw — raise the level.
                if res > 0.95 * prev_res {
                    level_scale *= 1.5;
                }
                prev_res = res;
            }
        }
        let pre_correction_residual = norm2(b);
        // Exact correction: x += S^T b  =>  S x = S x + S S^T b = (y - b) + b.
        frame.adjoint(b, &mut self.scratch_a);
        for (xi, &ai) in x.iter_mut().zip(self.scratch_a.iter()) {
            *xi += ai;
        }
        KashinStats { pre_correction_residual, iters: iters_done }
    }
}

/// Summary of one [`KashinSolver::embed_into`] run.
#[derive(Clone, Copy, Debug)]
pub struct KashinStats {
    /// Residual `‖y − Sx‖₂` *before* the final exact correction.
    pub pre_correction_residual: f32,
    /// Rounds actually executed.
    pub iters: usize,
}

/// Measure the *empirical* upper Kashin constant `K̂_u` of a frame:
/// `K̂_u = max over trials of ‖x_d‖∞·√N / ‖y‖₂` (Lemma 1 rearranged).
pub fn empirical_kashin_constant(
    frame: &dyn Frame,
    solver: &mut KashinSolver,
    trials: usize,
    rng: &mut Rng,
) -> f32 {
    let n = frame.n();
    let mut worst = 0.0f32;
    for _ in 0..trials {
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
        let ny = norm2(&y);
        if ny == 0.0 {
            continue;
        }
        let emb = solver.embed(frame, &y);
        let ku = norm_inf(&emb.x) * (frame.big_n() as f32).sqrt() / ny;
        worst = worst.max(ku);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::frames::{HadamardFrame, OrthonormalFrame};
    use crate::linalg::vecops::dist2;

    fn check_exact_and_flat(frame: &dyn Frame, rng: &mut Rng, ku_budget: f32) {
        let mut solver = KashinSolver::for_frame(frame);
        for _ in 0..5 {
            let y: Vec<f32> = (0..frame.n()).map(|_| rng.gaussian_cubed()).collect();
            let emb = solver.embed(frame, &y);
            // Exactness: S x = y.
            let mut back = vec![0.0; frame.n()];
            frame.apply(&emb.x, &mut back);
            assert!(
                dist2(&back, &y) < 1e-3 * (1.0 + norm2(&y)),
                "not exact: {}",
                dist2(&back, &y)
            );
            // Democracy: ||x||_inf * sqrt(N) / ||y|| bounded by a small constant.
            let ku = norm_inf(&emb.x) * (frame.big_n() as f32).sqrt() / norm2(&y);
            assert!(ku < ku_budget, "K_u estimate {ku} over budget {ku_budget}");
        }
    }

    #[test]
    fn hadamard_lambda2_embeds_exactly() {
        let mut rng = Rng::seed_from(1);
        // n=512 -> N=1024 gives lambda=2.
        let frame = HadamardFrame::with_big_n(512, 1024, &mut rng);
        check_exact_and_flat(&frame, &mut rng, 6.0);
    }

    #[test]
    fn orthonormal_lambda_1p5_embeds_exactly() {
        let mut rng = Rng::seed_from(2);
        let frame = OrthonormalFrame::with_lambda(100, 1.5, &mut rng);
        check_exact_and_flat(&frame, &mut rng, 8.0);
    }

    #[test]
    fn lambda1_reduces_to_adjoint() {
        // For a square orthonormal frame the solution space is a point:
        // x_d = S^T y exactly.
        let mut rng = Rng::seed_from(3);
        let frame = OrthonormalFrame::with_big_n(64, 64, &mut rng);
        let y: Vec<f32> = (0..64).map(|_| rng.gaussian_cubed()).collect();
        let mut solver = KashinSolver::for_frame(&frame);
        let emb = solver.embed(&frame, &y);
        let mut adj = vec![0.0; 64];
        frame.adjoint(&y, &mut adj);
        assert!(dist2(&emb.x, &adj) < 1e-3 * (1.0 + norm2(&adj)));
    }

    #[test]
    fn democratic_flatter_than_near_democratic() {
        // The whole point: on heavy-tailed y and a wide frame, the LV
        // embedding has (weakly) smaller l_inf norm than S^T y.
        let mut rng = Rng::seed_from(4);
        let frame = HadamardFrame::with_big_n(256, 512, &mut rng);
        let mut solver = KashinSolver::for_frame(&frame);
        let mut wins = 0;
        for _ in 0..10 {
            let y: Vec<f32> = (0..256).map(|_| rng.gaussian_cubed()).collect();
            let emb = solver.embed(&frame, &y);
            let mut nde = vec![0.0; 512];
            frame.adjoint(&y, &mut nde);
            if norm_inf(&emb.x) <= norm_inf(&nde) * 1.05 {
                wins += 1;
            }
        }
        assert!(wins >= 8, "democratic beat NDE only {wins}/10 times");
    }

    #[test]
    fn zero_vector_embeds_to_zero() {
        let mut rng = Rng::seed_from(5);
        let frame = HadamardFrame::new(100, &mut rng);
        let mut solver = KashinSolver::for_frame(&frame);
        let emb = solver.embed(&frame, &vec![0.0; 100]);
        assert!(emb.x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn empirical_ku_is_small_constant() {
        let mut rng = Rng::seed_from(6);
        let frame = HadamardFrame::with_big_n(256, 512, &mut rng);
        let mut solver = KashinSolver::for_frame(&frame);
        let ku = empirical_kashin_constant(&frame, &mut solver, 10, &mut rng);
        assert!(ku > 0.5 && ku < 8.0, "K_u = {ku}");
    }
}
