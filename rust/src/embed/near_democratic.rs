//! Near-democratic embeddings — the closed form of §2.1.
//!
//! `x_nd = Sᵀ(SSᵀ)⁻¹y`, which for Parseval frames collapses to `Sᵀy`
//! (Appendix G). Lemmas 2 and 3 bound `‖x_nd‖∞ ≤ 2√(log(2N)/N)·‖y‖₂`
//! w.p. ≥ 1 − 1/(2N) (Hadamard; an extra `√λ` for orthonormal frames).

use crate::linalg::frames::Frame;
use crate::linalg::rng::Rng;
use crate::linalg::vecops::{norm2, norm_inf};

/// Compute the near-democratic embedding into `out` (`len = N`).
/// Zero allocation — the runtime hot path of NDSC.
#[inline]
pub fn nde_into(frame: &dyn Frame, y: &[f32], out: &mut [f32]) {
    frame.pinv_embed(y, out);
}

/// Allocating convenience wrapper.
pub fn nde(frame: &dyn Frame, y: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; frame.big_n()];
    nde_into(frame, y, &mut out);
    out
}

/// The Lemma 2/3 bound `2√(λ̃·log(2N)/N)` with `λ̃ = λ` for orthonormal
/// frames and `λ̃ = 1` for Hadamard frames.
pub fn lemma_bound(big_n: usize, lambda_factor: f32) -> f32 {
    2.0 * (lambda_factor * (2.0 * big_n as f32).ln() / big_n as f32).sqrt()
}

/// Empirical check of Lemma 2/3: fraction of random draws where
/// `‖x_nd‖∞ > bound·‖y‖₂`. Should be ≤ ~1/(2N).
pub fn lemma_violation_rate(
    frame: &dyn Frame,
    lambda_factor: f32,
    trials: usize,
    rng: &mut Rng,
) -> f32 {
    let n = frame.n();
    let bound = lemma_bound(frame.big_n(), lambda_factor);
    let mut bad = 0usize;
    let mut x = vec![0.0f32; frame.big_n()];
    for _ in 0..trials {
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
        let ny = norm2(&y);
        if ny == 0.0 {
            continue;
        }
        nde_into(frame, &y, &mut x);
        if norm_inf(&x) > bound * ny {
            bad += 1;
        }
    }
    bad as f32 / trials as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::frames::{HadamardFrame, OrthonormalFrame, SubGaussianFrame};
    use crate::linalg::vecops::dist2;

    #[test]
    fn lemma3_bound_holds_hadamard() {
        let mut rng = Rng::seed_from(1);
        let frame = HadamardFrame::new(1000, &mut rng);
        let rate = lemma_violation_rate(&frame, 1.0, 200, &mut rng);
        // Lemma 3: violation probability <= 1/(2N) ~ 5e-4; allow slack.
        assert!(rate <= 0.02, "violation rate {rate}");
    }

    #[test]
    fn lemma2_bound_holds_orthonormal() {
        let mut rng = Rng::seed_from(2);
        let frame = OrthonormalFrame::with_lambda(100, 1.5, &mut rng);
        let rate = lemma_violation_rate(&frame, frame.lambda(), 100, &mut rng);
        assert!(rate <= 0.05, "violation rate {rate}");
    }

    #[test]
    fn nde_is_exact_preimage_for_parseval() {
        let mut rng = Rng::seed_from(3);
        let frame = HadamardFrame::new(116, &mut rng);
        let y: Vec<f32> = (0..116).map(|_| rng.gaussian_cubed()).collect();
        let x = nde(&frame, &y);
        let mut back = vec![0.0f32; 116];
        frame.apply(&x, &mut back);
        assert!(dist2(&back, &y) < 1e-3 * (1.0 + norm2(&y)));
    }

    #[test]
    fn nde_is_exact_preimage_for_subgaussian() {
        let mut rng = Rng::seed_from(4);
        let frame = SubGaussianFrame::with_lambda(40, 2.0, &mut rng);
        let y: Vec<f32> = (0..40).map(|_| rng.gaussian_cubed()).collect();
        let x = nde(&frame, &y);
        let mut back = vec![0.0f32; frame.big_n()];
        // note: apply consumes len-N input
        let mut out = vec![0.0f32; 40];
        frame.apply(&x, &mut out);
        back.truncate(0);
        assert!(dist2(&out, &y) < 1e-2 * (1.0 + norm2(&y)));
    }

    #[test]
    fn flattening_effect_on_heavy_tails() {
        // The embedding spreads a spiky vector: l_inf shrinks by ~sqrt(N/log N).
        let mut rng = Rng::seed_from(5);
        let n = 1024;
        let frame = HadamardFrame::new(n, &mut rng);
        let mut y = vec![0.0f32; n];
        y[17] = 100.0; // one-hot: worst case for naive quantization
        let x = nde(&frame, &y);
        assert!(norm_inf(&x) < norm_inf(&y) * 0.2, "no flattening: {}", norm_inf(&x));
    }
}
