//! Democratic and near-democratic (Kashin) embeddings — §2 of the paper.
//!
//! Given a frame `S ∈ R^{n×N}`, the **democratic embedding** of `y ∈ R^n`
//! is the minimum-`l∞` solution of the under-determined system `y = Sx`
//! (eq. 5); the **near-democratic embedding** is the minimum-`l₂` solution
//! `x = Sᵀ(SSᵀ)⁻¹y` (eq. 7/8), which for Parseval frames is just `Sᵀy`.
//!
//! Three solvers are provided:
//!
//! * [`democratic::KashinSolver`] — the Lyubarskii–Vershynin iterative
//!   truncate-and-project algorithm ([10] in the paper), `O(K · n log n)`
//!   for Hadamard frames. This is what DSC uses at runtime.
//! * [`lp::min_linf`] — a bisection + alternating-projection solver of the
//!   exact LP (5), the stand-in for the paper's CVX baseline (Fig. 1c) and
//!   the ground truth for tests.
//! * [`near_democratic::nde`] — the closed form `Sᵀy`.

pub mod democratic;
pub mod lp;
pub mod near_democratic;
