//! Exact minimum-`l∞` embedding — the LP (5) of the paper.
//!
//! The paper solves `min ‖x‖∞ s.t. y = Sx` with CVX (simplex / interior
//! point). This module is our CVX stand-in: it computes the same optimum to
//! a tolerance via **bisection on the level `t`** combined with
//! **alternating projections (POCS)** onto the two convex sets
//!
//! * the affine subspace `A = {x : Sx = y}` — projection
//!   `x ← x − Sᵀ(SSᵀ)⁻¹(Sx − y)` (for Parseval frames `x − Sᵀ(Sx − y)`),
//! * the box `B_t = {x : ‖x‖∞ ≤ t}` — coordinate clipping.
//!
//! `A ∩ B_t ≠ ∅` iff `t ≥ t* = min ‖x‖∞`, and POCS converges to a point of
//! the intersection whenever it is non-empty, so bisection on `t` brackets
//! `t*`. Cost per POCS sweep is one `Sᵀ`/`S` pair — `O(N log N)` for
//! Hadamard frames — with the overall solve `O(log(1/ε))` sweeps heavier
//! than the LV iteration; this is deliberately the *slow, exact* reference
//! used in tests and in the Fig. 1c wall-clock comparison.

use crate::linalg::frames::Frame;
use crate::linalg::vecops::{dist2, norm2, norm_inf};

/// Options for the exact solver.
#[derive(Clone, Copy, Debug)]
pub struct LinfOptions {
    /// Relative bisection tolerance on the level `t`.
    pub tol: f32,
    /// POCS sweeps per feasibility probe.
    pub pocs_iters: usize,
    /// Relative feasibility slack: the probe accepts if the affine residual
    /// after projection onto the box is below `feas_tol·‖y‖₂`.
    pub feas_tol: f32,
}

impl Default for LinfOptions {
    fn default() -> Self {
        LinfOptions { tol: 1e-3, pocs_iters: 400, feas_tol: 1e-3 }
    }
}

/// Result of the exact solve.
#[derive(Clone, Debug)]
pub struct LinfEmbedding {
    /// Feasible point with `Sx = y` (exact to float error) and
    /// `‖x‖∞ ≤ (1 + tol)·t*`.
    pub x: Vec<f32>,
    /// The certified level (upper bracket of the bisection).
    pub level: f32,
    /// Total POCS sweeps spent.
    pub sweeps: usize,
}

/// Project `x` onto the affine set `{x : Sx = y}` (Parseval frames):
/// `x ← x + Sᵀ(y − Sx)`.
fn project_affine(frame: &dyn Frame, y: &[f32], x: &mut [f32], sx: &mut [f32], corr: &mut [f32]) {
    frame.apply(x, sx);
    for (s, &yy) in sx.iter_mut().zip(y) {
        *s = yy - *s;
    }
    frame.adjoint(sx, corr);
    for (xi, &c) in x.iter_mut().zip(corr.iter()) {
        *xi += c;
    }
}

/// Probe whether the level `t` is feasible: run POCS from `x0`, return the
/// final iterate (in the box) and its affine residual.
fn probe(
    frame: &dyn Frame,
    y: &[f32],
    t: f32,
    x: &mut Vec<f32>,
    opts: &LinfOptions,
) -> (f32, usize) {
    let (n, big_n) = (frame.n(), frame.big_n());
    let mut sx = vec![0.0f32; n];
    let mut corr = vec![0.0f32; big_n];
    let mut sweeps = 0;
    let ny = norm2(y).max(1e-30);
    for _ in 0..opts.pocs_iters {
        sweeps += 1;
        // Project onto the box first, then the affine set, and measure the
        // box violation of the affine point: when the intersection is
        // non-empty both distances go to zero.
        for v in x.iter_mut() {
            *v = v.clamp(-t, t);
        }
        project_affine(frame, y, x, &mut sx, &mut corr);
        // Residual: how far outside the box is the affine-feasible point?
        let overflow =
            x.iter().map(|&v| (v.abs() - t).max(0.0) as f64).fold(0.0f64, |a, b| a.max(b)) as f32;
        if overflow <= opts.feas_tol * ny / (big_n as f32).sqrt() {
            return (overflow, sweeps);
        }
    }
    let overflow =
        x.iter().map(|&v| (v.abs() - t).max(0.0) as f64).fold(0.0f64, |a, b| a.max(b)) as f32;
    (overflow, sweeps)
}

/// Solve `min ‖x‖∞ s.t. Sx = y` to tolerance. Only valid for Parseval
/// frames (all frames the paper's experiments use).
pub fn min_linf(frame: &dyn Frame, y: &[f32], opts: &LinfOptions) -> LinfEmbedding {
    let (n, big_n) = (frame.n(), frame.big_n());
    assert_eq!(y.len(), n);
    assert!(frame.is_parseval(), "min_linf requires a Parseval frame");
    // Bracket: the NDE x = S^T y is feasible, so t_hi = ||S^T y||_inf works;
    // t_lo = ||y||_2 / sqrt(N) is the Parseval lower bound (Lemma 1, K_l=1).
    let mut nde = vec![0.0f32; big_n];
    frame.adjoint(y, &mut nde);
    let mut t_hi = norm_inf(&nde);
    let mut t_lo = norm2(y) / (big_n as f32).sqrt();
    if t_hi == 0.0 {
        return LinfEmbedding { x: vec![0.0; big_n], level: 0.0, sweeps: 0 };
    }
    let mut best = nde.clone();
    let mut total_sweeps = 0;
    // Warm-start each probe from the previous feasible point.
    let mut x = nde.clone();
    while t_hi - t_lo > opts.tol * t_hi {
        let t_mid = 0.5 * (t_lo + t_hi);
        let mut x_probe = x.clone();
        let (overflow, sweeps) = probe(frame, y, t_mid, &mut x_probe, opts);
        total_sweeps += sweeps;
        if overflow <= opts.feas_tol * norm2(y).max(1e-30) / (big_n as f32).sqrt() {
            // Feasible at t_mid: tighten the upper bracket, keep the point.
            t_hi = t_mid;
            best = x_probe.clone();
            x = x_probe;
        } else {
            t_lo = t_mid;
        }
    }
    // Final exactness polish on the incumbent.
    let mut sx = vec![0.0f32; n];
    let mut corr = vec![0.0f32; big_n];
    project_affine(frame, y, &mut best, &mut sx, &mut corr);
    LinfEmbedding { x: best, level: t_hi, sweeps: total_sweeps }
}

/// Convenience wrapper asserting the returned point is exactly feasible.
pub fn min_linf_checked(frame: &dyn Frame, y: &[f32], opts: &LinfOptions) -> LinfEmbedding {
    let emb = min_linf(frame, y, opts);
    let mut back = vec![0.0f32; frame.n()];
    frame.apply(&emb.x, &mut back);
    debug_assert!(
        dist2(&back, y) <= 1e-2 * (1.0 + norm2(y)),
        "LP solution infeasible: residual {}",
        dist2(&back, y)
    );
    emb
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::democratic::KashinSolver;
    use crate::linalg::frames::{HadamardFrame, OrthonormalFrame};
    use crate::linalg::rng::Rng;

    #[test]
    fn lp_feasible_and_no_worse_than_nde() {
        let mut rng = Rng::seed_from(1);
        let frame = HadamardFrame::with_big_n(48, 64, &mut rng);
        for _ in 0..3 {
            let y: Vec<f32> = (0..48).map(|_| rng.gaussian_cubed()).collect();
            let emb = min_linf_checked(&frame, &y, &LinfOptions::default());
            let mut nde = vec![0.0f32; 64];
            frame.adjoint(&y, &mut nde);
            assert!(norm_inf(&emb.x) <= norm_inf(&nde) * (1.0 + 1e-3));
            // Feasibility double-check.
            let mut back = vec![0.0f32; 48];
            frame.apply(&emb.x, &mut back);
            assert!(dist2(&back, &y) < 1e-2 * (1.0 + norm2(&y)));
        }
    }

    #[test]
    fn lp_matches_kashin_solver_level() {
        // The LV iteration is suboptimal but should land within a small
        // multiple of the true optimum; conversely the LP must not be worse.
        let mut rng = Rng::seed_from(2);
        let frame = OrthonormalFrame::with_lambda(32, 2.0, &mut rng);
        let y: Vec<f32> = (0..32).map(|_| rng.gaussian_cubed()).collect();
        let lp = min_linf_checked(&frame, &y, &LinfOptions::default());
        let mut solver = KashinSolver::for_frame(&frame);
        let lv = solver.embed(&frame, &y);
        assert!(
            norm_inf(&lp.x) <= norm_inf(&lv.x) * 1.05,
            "LP {} should be <= LV {}",
            norm_inf(&lp.x),
            norm_inf(&lv.x)
        );
    }

    #[test]
    fn lp_lower_bound_respected() {
        // Lemma 1 with K_l = 1: ||x||_inf >= ||y||_2 / sqrt(N).
        let mut rng = Rng::seed_from(3);
        let frame = HadamardFrame::with_big_n(30, 32, &mut rng);
        let y: Vec<f32> = (0..30).map(|_| rng.gaussian_f32()).collect();
        let emb = min_linf_checked(&frame, &y, &LinfOptions::default());
        let lower = norm2(&y) / (32f32).sqrt();
        assert!(norm_inf(&emb.x) >= lower * 0.99, "{} < {}", norm_inf(&emb.x), lower);
    }

    #[test]
    fn zero_input() {
        let mut rng = Rng::seed_from(4);
        let frame = HadamardFrame::new(16, &mut rng);
        let emb = min_linf(&frame, &vec![0.0; 16], &LinfOptions::default());
        assert_eq!(emb.level, 0.0);
        assert!(emb.x.iter().all(|&v| v == 0.0));
    }
}
