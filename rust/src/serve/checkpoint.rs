//! Versioned binary job snapshots: save a running job, restore it in a
//! fresh process, continue the uninterrupted trace **bit for bit**.
//!
//! A snapshot carries three sections:
//!
//! | Section | Contents |
//! |---|---|
//! | spec    | name, scheme (canonical registry string), `R`, `n`, workers, problem, rounds, schedule, feedback kind, batch, drop-prob, domain, output mode, seed |
//! | state   | round index `t`, iterate `x`, Polyak average, job RNG, per-worker RNG streams, feedback memory, accumulated trace + traffic totals |
//! | sched trailer (v2) | DRR deficit counter, adaptive-`R` rung, QoS class, FNV-1a checksum ([`SchedTrailer`]) |
//!
//! The trailer is what makes a snapshot **fleet-independent**: a job
//! checkpointed mid-deficit restores into another fleet with its banked
//! scheduler credit and last-granted rung intact, not reset to zero —
//! the migration path ([`crate::serve::cluster`]) depends on it.
//! Version-1 snapshots (no trailer) still load, with scheduler defaults.
//!
//! **Delta snapshots (version 3)** make periodic autosave O(changed)
//! instead of O(job): [`save_delta`] records only the dynamic state that
//! moves round to round — iterate, RNGs, feedback memory, the trace
//! records appended *since a pinned base snapshot* — plus the base's
//! length and FNV-1a-64 fingerprint, and covers the **entire record**
//! with a trailing FNV-1a-32 checksum (stronger than v2, whose body
//! relies on cross-checks: any single byte flip anywhere in a delta
//! surfaces as [`io::ErrorKind::InvalidData`]). [`restore_delta`]
//! verifies the checksum, verifies the provided base against the pinned
//! fingerprint, restores the base through the full v1/v2 validation
//! path, then overlays the delta. [`compact`] folds a base plus its
//! delta chain back into one plain v2 snapshot for retirement of long
//! chains. A v3 record is *not* loadable by [`restore`] (it has no spec
//! section); the version word guards the two families apart.
//!
//! Static artifacts (dataset, frames/codecs, workspace) are **not**
//! serialized: [`restore`] rebuilds them deterministically from the spec
//! seed via [`crate::serve::job::Job::build`], then overlays the dynamic
//! state. That keeps snapshots small (KBs, independent of dataset size)
//! and makes the format a statement of exactly which state matters.
//!
//! Hardening follows [`crate::coordinator::protocol`]: little-endian
//! length-prefixed fields, every length checked against a sanity cap
//! ([`protocol::checked_len_capped`]) before allocation, truncation
//! mapped to [`io::ErrorKind::InvalidData`] — a corrupt snapshot is an
//! error, never a panic or a giant allocation
//! (`rust/tests/test_serve.rs` fuzzes truncations and corruptions).

use std::io::{self, Read};

use crate::coordinator::protocol::{self, checked_len_capped};
use crate::linalg::rng::Rng;
use crate::opt::engine::schedule::Schedule;
use crate::opt::engine::OutputMode;
use crate::opt::projection::Domain;
use crate::opt::{IterRecord, Trace};
use crate::quant::registry::CompressorSpec;
use crate::serve::job::{FeedbackKind, Job, JobSpec, ProblemSpec};
use crate::serve::plancache::PlanCache;
use crate::serve::scheduler::QosClass;

/// Magic bytes opening every snapshot (version-tagged family).
pub const CHECKPOINT_MAGIC: &[u8; 8] = b"KFCKPT01";
/// Format version this build writes. Version 2 appends the mandatory
/// [`SchedTrailer`]; version-1 snapshots (engine state only) are still
/// accepted by [`restore`] and restore with scheduler defaults.
pub const CHECKPOINT_VERSION: u32 = 2;
/// Oldest format version [`restore`] still reads.
pub const CHECKPOINT_MIN_VERSION: u32 = 1;
/// Format version of delta records ([`save_delta`]/[`restore_delta`]).
/// Deliberately *outside* [`restore`]'s accepted range: a delta is not a
/// standalone snapshot and cannot restore without its base.
pub const CHECKPOINT_DELTA_VERSION: u32 = 3;

/// Sanity caps: generous for every real configuration (transformer-scale
/// `n`, thousands of workers, millions of rounds), low enough that a
/// flipped bit in any size field cannot turn the deterministic rebuild
/// into a giant allocation before the cross-checks run. **Enforced at
/// [`Job::build`] too**, so every job a fleet admits is guaranteed to
/// round-trip through its own snapshot — a spec the reader would reject
/// never starts running in the first place.
pub(crate) const MAX_STR: usize = 4096;
pub(crate) const MAX_DIM: usize = 1 << 20;
pub(crate) const MAX_WORKERS: usize = 1 << 12;
pub(crate) const MAX_ROWS: usize = 1 << 16;
pub(crate) const MAX_ROUNDS: usize = 1 << 22;
const MAX_VEC: u64 = 1 << 26;

// ---------------------------------------------------------------------------
// Writers.
// ---------------------------------------------------------------------------

fn w_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn w_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_str(out: &mut Vec<u8>, s: &str) {
    w_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn w_f32s(out: &mut Vec<u8>, v: &[f32]) {
    w_u64(out, v.len() as u64);
    for &x in v {
        w_f32(out, x);
    }
}

fn w_rng(out: &mut Vec<u8>, rng: &Rng) {
    let (s, spare) = rng.state();
    for w in s {
        w_u64(out, w);
    }
    match spare {
        Some(g) => {
            w_u8(out, 1);
            w_u64(out, g.to_bits());
        }
        None => {
            w_u8(out, 0);
            w_u64(out, 0);
        }
    }
}

// ---------------------------------------------------------------------------
// Readers (truncation ⇒ InvalidData).
// ---------------------------------------------------------------------------

fn invalid(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Map a short read to `InvalidData`: a truncated snapshot is corrupt
/// input, not an I/O condition the caller can retry.
fn ck<T>(r: io::Result<T>) -> io::Result<T> {
    r.map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            invalid("truncated checkpoint")
        } else {
            e
        }
    })
}

fn r_u8(r: &mut impl Read) -> io::Result<u8> {
    let mut b = [0u8; 1];
    ck(r.read_exact(&mut b))?;
    Ok(b[0])
}

fn r_u32(r: &mut impl Read) -> io::Result<u32> {
    ck(protocol::read_u32(r))
}

fn r_u64(r: &mut impl Read) -> io::Result<u64> {
    ck(protocol::read_u64(r))
}

fn r_f32(r: &mut impl Read) -> io::Result<f32> {
    ck(protocol::read_f32(r))
}

fn r_str(r: &mut impl Read, what: &str) -> io::Result<String> {
    let len = checked_len_capped(r_u64(r)?, what, MAX_STR as u64)?;
    let mut buf = vec![0u8; len];
    ck(r.read_exact(&mut buf))?;
    String::from_utf8(buf).map_err(|_| invalid(format!("{what} is not valid UTF-8")))
}

fn r_f32s(r: &mut impl Read, what: &str) -> io::Result<Vec<f32>> {
    let len = checked_len_capped(r_u64(r)?, what, MAX_VEC)?;
    // Bounded initial reserve: a corrupt length field under the cap must
    // hit the truncation error, not a cap-sized upfront allocation.
    let mut out = Vec::with_capacity(len.min(1 << 16));
    for _ in 0..len {
        out.push(r_f32(r)?);
    }
    Ok(out)
}

fn r_rng(r: &mut impl Read) -> io::Result<Rng> {
    let mut s = [0u64; 4];
    for w in &mut s {
        *w = r_u64(r)?;
    }
    let spare = match r_u8(r)? {
        0 => {
            r_u64(r)?; // reserved slot, ignored
            None
        }
        1 => Some(f64::from_bits(r_u64(r)?)),
        t => return Err(invalid(format!("bad RNG spare flag {t}"))),
    };
    Ok(Rng::from_state(s, spare))
}

// ---------------------------------------------------------------------------
// Enum tags.
// ---------------------------------------------------------------------------

fn schedule_tag(s: Schedule) -> (u8, f32, f32) {
    match s {
        Schedule::Constant(c) => (0, c, 0.0),
        Schedule::InvSqrt { c } => (1, c, 0.0),
        Schedule::Harmonic { c, t0 } => (2, c, t0),
    }
}

fn schedule_from_tag(tag: u8, a: f32, b: f32) -> io::Result<Schedule> {
    Ok(match tag {
        0 => Schedule::Constant(a),
        1 => Schedule::InvSqrt { c: a },
        2 => Schedule::Harmonic { c: a, t0: b },
        t => return Err(invalid(format!("bad schedule tag {t}"))),
    })
}

fn domain_tag(d: Domain) -> (u8, f32, f32) {
    match d {
        Domain::Unconstrained => (0, 0.0, 0.0),
        Domain::L2Ball { radius } => (1, radius, 0.0),
        Domain::Box { lo, hi } => (2, lo, hi),
    }
}

fn domain_from_tag(tag: u8, a: f32, b: f32) -> io::Result<Domain> {
    Ok(match tag {
        0 => Domain::Unconstrained,
        1 => Domain::L2Ball { radius: a },
        2 => Domain::Box { lo: a, hi: b },
        t => return Err(invalid(format!("bad domain tag {t}"))),
    })
}

fn output_tag(o: OutputMode) -> u8 {
    match o {
        OutputMode::LastIterate { trailing: false } => 0,
        OutputMode::LastIterate { trailing: true } => 1,
        OutputMode::PolyakAverage => 2,
    }
}

fn output_from_tag(tag: u8) -> io::Result<OutputMode> {
    Ok(match tag {
        0 => OutputMode::LastIterate { trailing: false },
        1 => OutputMode::LastIterate { trailing: true },
        2 => OutputMode::PolyakAverage,
        t => return Err(invalid(format!("bad output-mode tag {t}"))),
    })
}

// ---------------------------------------------------------------------------
// The scheduler trailer (format version 2).
// ---------------------------------------------------------------------------

/// The scheduler-side state of a snapshotted job: everything the fleet
/// (not the engine) owns about it. Travels as a fixed-length,
/// checksummed trailer after the engine state so a job migrated between
/// fleets keeps its banked DRR credit, its last adaptive-`R` rung, and
/// its QoS class.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SchedTrailer {
    /// Banked DRR credit in payload bits at snapshot time.
    pub deficit_bits: u64,
    /// Ladder level of the job's most recent grant (`None` before its
    /// first served round). Observability plus adaptive-policy
    /// continuity; never changes what a restored round computes.
    pub rung: Option<u8>,
    /// Weighted-QoS class ([`QosClass::Silver`] by default).
    pub qos: QosClass,
}

/// Trailer magic (distinct from the header magic so a truncated body
/// cannot alias as a trailer).
const TRAILER_MAGIC: &[u8; 4] = b"KFT1";
/// Serialized trailer length: magic (4) + deficit (8) + rung (1) +
/// qos (1) + FNV-1a checksum (4).
const TRAILER_LEN: usize = 18;
/// `rung = None` on the wire.
const RUNG_NONE: u8 = 0xFF;

/// 32-bit FNV-1a over the trailer's magic + payload. The engine body is
/// covered by its own cross-checks (shape, tag and cap validation); the
/// trailer's payload is free-form integers, so without a checksum a
/// flipped deficit byte would silently restore as different (valid)
/// credit — the corruption fuzz in `rust/tests/test_serve.rs` requires
/// every trailer byte-flip to surface as `InvalidData`.
fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h = 0x811c_9dc5u32;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

fn w_sched_trailer(out: &mut Vec<u8>, sched: &SchedTrailer) {
    let start = out.len();
    out.extend_from_slice(TRAILER_MAGIC);
    w_u64(out, sched.deficit_bits);
    w_u8(out, sched.rung.unwrap_or(RUNG_NONE));
    w_u8(out, sched.qos.tag());
    let sum = fnv1a(&out[start..]);
    w_u32(out, sum);
}

fn r_sched_trailer(r: &mut &[u8]) -> io::Result<SchedTrailer> {
    if r.len() < TRAILER_LEN {
        return Err(invalid(format!(
            "truncated scheduler trailer ({} of {TRAILER_LEN} bytes)",
            r.len()
        )));
    }
    let body = &r[..TRAILER_LEN - 4];
    if &body[..4] != TRAILER_MAGIC {
        return Err(invalid("bad scheduler-trailer magic"));
    }
    let mut rr: &[u8] = &body[4..];
    let deficit_bits = r_u64(&mut rr)?;
    let rung_byte = r_u8(&mut rr)?;
    let qos_tag = r_u8(&mut rr)?;
    let mut rr: &[u8] = &r[TRAILER_LEN - 4..TRAILER_LEN];
    let want = r_u32(&mut rr)?;
    if fnv1a(body) != want {
        return Err(invalid("scheduler-trailer checksum mismatch"));
    }
    let rung = if rung_byte == RUNG_NONE { None } else { Some(rung_byte) };
    let qos = QosClass::from_tag(qos_tag)
        .ok_or_else(|| invalid(format!("bad QoS tag {qos_tag} in scheduler trailer")))?;
    *r = &r[TRAILER_LEN..];
    Ok(SchedTrailer { deficit_bits, rung, qos })
}

// ---------------------------------------------------------------------------
// Save / restore.
// ---------------------------------------------------------------------------

/// [`save_with_sched`] with a zeroed scheduler trailer (the job's own
/// QoS class, no banked credit, no rung) — the standalone-job form.
pub fn save(job: &Job) -> io::Result<Vec<u8>> {
    save_with_sched(job, &SchedTrailer { qos: job.spec().qos, ..SchedTrailer::default() })
}

/// Serialize a resumable snapshot of `job` (see the module docs for the
/// layout), with the fleet's scheduler-side state in the trailer.
/// Refuses a finalized job: snapshots exist to resume
/// running/paused jobs, and a finalized trace (trailing record appended,
/// `final_x` set) would restore into a double-finalized, diverged trace.
pub fn save_with_sched(job: &Job, sched: &SchedTrailer) -> io::Result<Vec<u8>> {
    if job.run.is_finalized() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            "cannot checkpoint a finalized job; snapshots resume running/paused jobs",
        ));
    }
    let spec = job.spec();
    let mut out = Vec::with_capacity(4096);
    out.extend_from_slice(CHECKPOINT_MAGIC);
    w_u32(&mut out, CHECKPOINT_VERSION);
    // --- spec ---
    w_str(&mut out, &spec.name);
    w_str(&mut out, &spec.scheme.name());
    w_f32(&mut out, spec.r);
    w_u64(&mut out, spec.n as u64);
    w_u64(&mut out, spec.workers as u64);
    let ProblemSpec::PlantedRegression { rows_per_shard, student_t } = spec.problem;
    w_u64(&mut out, rows_per_shard as u64);
    w_u8(&mut out, student_t as u8);
    w_u64(&mut out, spec.rounds as u64);
    let (stag, sa, sb) = schedule_tag(spec.schedule);
    w_u8(&mut out, stag);
    w_f32(&mut out, sa);
    w_f32(&mut out, sb);
    w_u8(&mut out, matches!(spec.feedback, FeedbackKind::Def) as u8);
    w_u64(&mut out, spec.batch.map(|b| b as u64).unwrap_or(0));
    w_f32(&mut out, spec.drop_prob);
    let (dtag, da, db) = domain_tag(spec.domain);
    w_u8(&mut out, dtag);
    w_f32(&mut out, da);
    w_f32(&mut out, db);
    w_u8(&mut out, output_tag(spec.output));
    w_u64(&mut out, spec.seed);
    // --- dynamic state ---
    w_u64(&mut out, job.run.round() as u64);
    w_f32s(&mut out, &job.run.x);
    w_f32s(&mut out, &job.run.avg);
    w_rng(&mut out, &job.rng);
    w_u64(&mut out, job.run.worker_rngs.len() as u64);
    for wr in &job.run.worker_rngs {
        w_rng(&mut out, wr);
    }
    let mut fb = Vec::new();
    job.save_feedback(&mut fb);
    w_f32s(&mut out, &fb);
    let trace = job.trace();
    w_u64(&mut out, trace.records.len() as u64);
    for rec in &trace.records {
        w_f32(&mut out, rec.value);
        w_f32(&mut out, rec.dist_to_opt);
        w_u64(&mut out, rec.payload_bits as u64);
        w_u64(&mut out, rec.participants as u64);
    }
    w_u64(&mut out, trace.total_payload_bits as u64);
    w_u64(&mut out, trace.total_side_bits as u64);
    // --- scheduler trailer (version 2) ---
    w_sched_trailer(&mut out, sched);
    Ok(out)
}

/// [`restore_with_sched`] discarding the scheduler trailer — the
/// standalone-job form (the restored job still carries the trailer's QoS
/// class on its spec).
pub fn restore(bytes: &[u8]) -> io::Result<Job> {
    restore_with_sched(bytes).map(|(job, _)| job)
}

/// Rebuild a job (and its scheduler-side state) from a snapshot. The
/// static artifacts are regrown from the spec seed (identical by the
/// derivation discipline of [`crate::serve::job`]); the dynamic state is
/// overlaid and cross-checked against the spec — any inconsistency,
/// unknown tag, out-of-cap length, truncation, checksum mismatch or
/// trailing garbage is [`io::ErrorKind::InvalidData`]. A version-1
/// snapshot (pre-trailer) restores with [`SchedTrailer::default`].
pub fn restore_with_sched(bytes: &[u8]) -> io::Result<(Job, SchedTrailer)> {
    restore_with_sched_cached(bytes, None)
}

/// [`restore_with_sched`] with an optional codec-plan cache: the
/// rebuilt job's ladder comes from the cache when the scheme's plan is
/// shareable — the dominant cost of a restore (and therefore of a
/// migration) for frame-backed schemes — and the overlaid dynamic
/// state is untouched either way, so the restored trace is
/// bit-identical to the uncached path.
pub fn restore_with_sched_cached(
    bytes: &[u8],
    cache: Option<&PlanCache>,
) -> io::Result<(Job, SchedTrailer)> {
    let mut r: &[u8] = bytes;
    let mut magic = [0u8; 8];
    ck(r.read_exact(&mut magic))?;
    if &magic != CHECKPOINT_MAGIC {
        return Err(invalid("not a KFCKPT01 job checkpoint"));
    }
    let version = r_u32(&mut r)?;
    if version == CHECKPOINT_DELTA_VERSION {
        return Err(invalid(
            "this is a delta snapshot; it restores only against its base (restore_delta)",
        ));
    }
    if !(CHECKPOINT_MIN_VERSION..=CHECKPOINT_VERSION).contains(&version) {
        return Err(invalid(format!(
            "unsupported checkpoint version {version} \
             (this build reads {CHECKPOINT_MIN_VERSION}..={CHECKPOINT_VERSION})"
        )));
    }
    // --- spec ---
    let name = r_str(&mut r, "job name")?;
    let scheme_name = r_str(&mut r, "scheme name")?;
    let scheme = CompressorSpec::parse(&scheme_name)
        .ok_or_else(|| invalid(format!("unknown scheme '{scheme_name}' in checkpoint")))?;
    let r_budget = r_f32(&mut r)?;
    let n = checked_len_capped(r_u64(&mut r)?, "dimension", MAX_DIM as u64)?;
    let workers = checked_len_capped(r_u64(&mut r)?, "worker count", MAX_WORKERS as u64)?;
    let rows_per_shard = checked_len_capped(r_u64(&mut r)?, "rows per shard", MAX_ROWS as u64)?;
    let student_t = match r_u8(&mut r)? {
        0 => false,
        1 => true,
        t => return Err(invalid(format!("bad student-t flag {t}"))),
    };
    let rounds = checked_len_capped(r_u64(&mut r)?, "round count", MAX_ROUNDS as u64)?;
    let (stag, sa, sb) = (r_u8(&mut r)?, r_f32(&mut r)?, r_f32(&mut r)?);
    let schedule = schedule_from_tag(stag, sa, sb)?;
    let feedback = match r_u8(&mut r)? {
        0 => FeedbackKind::None,
        1 => FeedbackKind::Def,
        t => return Err(invalid(format!("bad feedback tag {t}"))),
    };
    let batch = match r_u64(&mut r)? {
        0 => None,
        b => Some(checked_len_capped(b, "batch size", MAX_VEC)?),
    };
    let drop_prob = r_f32(&mut r)?;
    let (dtag, da, db) = (r_u8(&mut r)?, r_f32(&mut r)?, r_f32(&mut r)?);
    let domain = domain_from_tag(dtag, da, db)?;
    let output = output_from_tag(r_u8(&mut r)?)?;
    let seed = r_u64(&mut r)?;
    let spec = JobSpec {
        name,
        scheme,
        r: r_budget,
        n,
        workers,
        problem: ProblemSpec::PlantedRegression { rows_per_shard, student_t },
        rounds,
        schedule,
        feedback,
        batch,
        drop_prob,
        domain,
        output,
        // Not in the spec section: the v2 scheduler trailer carries the
        // class, and the overlay below installs it post-build.
        qos: QosClass::default(),
        seed,
    };
    let mut job = Job::build_cached(spec, cache)
        .map_err(|e| invalid(format!("checkpoint spec rejected: {e}")))?;
    // --- dynamic state ---
    let t = checked_len_capped(r_u64(&mut r)?, "round index", MAX_ROUNDS as u64)?;
    if t > rounds {
        return Err(invalid(format!("round index {t} exceeds configured rounds {rounds}")));
    }
    let x = r_f32s(&mut r, "iterate")?;
    if x.len() != n {
        return Err(invalid(format!("iterate length {} != dimension {n}", x.len())));
    }
    let avg = r_f32s(&mut r, "Polyak average")?;
    let want_avg = if output == OutputMode::PolyakAverage { n } else { 0 };
    if avg.len() != want_avg {
        return Err(invalid(format!(
            "Polyak average length {} != expected {want_avg}",
            avg.len()
        )));
    }
    let rng = r_rng(&mut r)?;
    let n_wr = checked_len_capped(r_u64(&mut r)?, "worker RNG count", MAX_WORKERS as u64)?;
    if n_wr != workers {
        return Err(invalid(format!("worker RNG count {n_wr} != workers {workers}")));
    }
    let mut worker_rngs = Vec::with_capacity(n_wr);
    for _ in 0..n_wr {
        worker_rngs.push(r_rng(&mut r)?);
    }
    let fb = r_f32s(&mut r, "feedback state")?;
    if !job.restore_feedback(&fb) {
        return Err(invalid(format!("feedback state has wrong shape ({} floats)", fb.len())));
    }
    let n_rec = checked_len_capped(r_u64(&mut r)?, "trace record count", MAX_ROUNDS as u64 + 1)?;
    if n_rec > rounds + 1 {
        return Err(invalid(format!("{n_rec} trace records for a {rounds}-round job")));
    }
    let mut trace = Trace::default();
    trace.records.reserve(rounds + 1);
    for _ in 0..n_rec {
        trace.records.push(IterRecord {
            value: r_f32(&mut r)?,
            dist_to_opt: r_f32(&mut r)?,
            payload_bits: r_u64(&mut r)? as usize,
            participants: r_u64(&mut r)? as usize,
        });
    }
    trace.total_payload_bits = r_u64(&mut r)? as usize;
    trace.total_side_bits = r_u64(&mut r)? as usize;
    // --- scheduler trailer: mandatory in v2, absent in v1 ---
    let sched = if version >= 2 { r_sched_trailer(&mut r)? } else { SchedTrailer::default() };
    if !r.is_empty() {
        return Err(invalid(format!("{} trailing bytes after checkpoint", r.len())));
    }
    // Overlay onto the freshly built job.
    job.run.t = t;
    job.run.x.copy_from_slice(&x);
    job.run.avg.copy_from_slice(&avg);
    job.run.worker_rngs = worker_rngs;
    job.run.trace = trace;
    job.rng = rng;
    job.spec.qos = sched.qos;
    Ok((job, sched))
}

// ---------------------------------------------------------------------------
// Delta snapshots (version 3).
// ---------------------------------------------------------------------------

/// 64-bit FNV-1a — the base snapshot's fingerprint inside a delta
/// record (same constants as the cluster's placement hash); also the
/// plan cache's spec-fingerprint primitive.
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Advance the cursor `n` bytes without materializing them.
fn skip(r: &mut &[u8], n: usize, what: &str) -> io::Result<()> {
    if r.len() < n {
        return Err(invalid(format!("truncated base snapshot ({what})")));
    }
    *r = &r[n..];
    Ok(())
}

fn skip_str(r: &mut &[u8], what: &str) -> io::Result<()> {
    let len = checked_len_capped(r_u64(r)?, what, MAX_STR as u64)?;
    skip(r, len, what)
}

fn skip_f32s(r: &mut &[u8], what: &str) -> io::Result<()> {
    let len = checked_len_capped(r_u64(r)?, what, MAX_VEC)?;
    skip(r, len * 4, what)
}

/// Serialized [`w_rng`] length: 4 state words + spare flag + spare slot.
const RNG_LEN: usize = 4 * 8 + 1 + 8;

/// What [`save_delta_with_sched`] needs to know about a base snapshot:
/// enough to pin it and to tell where its trace ends. A length-checked
/// byte walk, not a restore — pinning a base must not cost a job
/// rebuild. The base is *fully* validated on the restore side.
struct BaseSummary {
    name: String,
    n: usize,
    workers: usize,
    rounds: usize,
    seed: u64,
    t: usize,
    records: usize,
}

fn base_summary(base: &[u8]) -> io::Result<BaseSummary> {
    let mut r: &[u8] = base;
    let mut magic = [0u8; 8];
    ck(r.read_exact(&mut magic))?;
    if &magic != CHECKPOINT_MAGIC {
        return Err(invalid("delta base is not a KFCKPT01 job checkpoint"));
    }
    let version = r_u32(&mut r)?;
    if !(CHECKPOINT_MIN_VERSION..=CHECKPOINT_VERSION).contains(&version) {
        return Err(invalid(format!(
            "delta base must be a plain v{CHECKPOINT_MIN_VERSION}..=v{CHECKPOINT_VERSION} \
             snapshot, got version {version} (deltas cannot chain on deltas)"
        )));
    }
    // --- spec ---
    let name = r_str(&mut r, "job name")?;
    skip_str(&mut r, "scheme name")?;
    skip(&mut r, 4, "rate")?;
    let n = checked_len_capped(r_u64(&mut r)?, "dimension", MAX_DIM as u64)?;
    let workers = checked_len_capped(r_u64(&mut r)?, "worker count", MAX_WORKERS as u64)?;
    skip(&mut r, 8 + 1, "problem")?;
    let rounds = checked_len_capped(r_u64(&mut r)?, "round count", MAX_ROUNDS as u64)?;
    skip(&mut r, 1 + 4 + 4, "schedule")?;
    skip(&mut r, 1 + 8 + 4, "feedback/batch/drop")?;
    skip(&mut r, 1 + 4 + 4, "domain")?;
    skip(&mut r, 1, "output mode")?;
    let seed = r_u64(&mut r)?;
    // --- dynamic state ---
    let t = checked_len_capped(r_u64(&mut r)?, "round index", MAX_ROUNDS as u64)?;
    skip_f32s(&mut r, "iterate")?;
    skip_f32s(&mut r, "Polyak average")?;
    skip(&mut r, RNG_LEN, "job RNG")?;
    let n_wr = checked_len_capped(r_u64(&mut r)?, "worker RNG count", MAX_WORKERS as u64)?;
    skip(&mut r, n_wr * RNG_LEN, "worker RNGs")?;
    skip_f32s(&mut r, "feedback state")?;
    let records = checked_len_capped(r_u64(&mut r)?, "trace record count", MAX_ROUNDS as u64 + 1)?;
    Ok(BaseSummary { name, n, workers, rounds, seed, t, records })
}

/// `true` if `bytes` opens like a delta record (v3); the full
/// magic/checksum validation happens in [`restore_delta`].
pub fn is_delta(bytes: &[u8]) -> bool {
    bytes.len() >= 12
        && &bytes[..8] == CHECKPOINT_MAGIC
        && u32::from_le_bytes(bytes[8..12].try_into().unwrap()) == CHECKPOINT_DELTA_VERSION
}

/// [`save_delta_with_sched`] with a zeroed scheduler trailer — the
/// standalone-job form, mirroring [`save`].
pub fn save_delta(job: &Job, base: &[u8]) -> io::Result<Vec<u8>> {
    save_delta_with_sched(
        job,
        &SchedTrailer { qos: job.spec().qos, ..SchedTrailer::default() },
        base,
    )
}

/// Serialize a **delta record** of `job` against a pinned `base`
/// snapshot (v1/v2 bytes previously produced by [`save_with_sched`] for
/// the *same* job at an earlier round). The record carries only the
/// state that moves round to round — no spec, no pre-base trace — so
/// periodic autosave costs O(changed): for a long-horizon job the trace
/// tail is the only part that grows.
///
/// Layout: magic, version 3, base length + FNV-1a-64 fingerprint, base
/// record count, then round index, iterate, Polyak average, job RNG,
/// worker RNGs, feedback memory, appended trace records, traffic
/// totals, the scheduler trailer — and a final FNV-1a-32 checksum over
/// **all preceding bytes** of the record.
pub fn save_delta_with_sched(job: &Job, sched: &SchedTrailer, base: &[u8]) -> io::Result<Vec<u8>> {
    if job.run.is_finalized() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            "cannot checkpoint a finalized job; snapshots resume running/paused jobs",
        ));
    }
    let summary = base_summary(base)?;
    let spec = job.spec();
    if summary.name != spec.name
        || summary.n != spec.n
        || summary.workers != spec.workers
        || summary.rounds != spec.rounds
        || summary.seed != spec.seed
    {
        return Err(invalid("delta base does not belong to this job"));
    }
    let trace = job.trace();
    if summary.t > job.run.round() || summary.records > trace.records.len() {
        return Err(invalid(format!(
            "delta base is ahead of the job (base round {} / job round {})",
            summary.t,
            job.run.round()
        )));
    }
    let mut out = Vec::with_capacity(1024);
    out.extend_from_slice(CHECKPOINT_MAGIC);
    w_u32(&mut out, CHECKPOINT_DELTA_VERSION);
    w_u64(&mut out, base.len() as u64);
    w_u64(&mut out, fnv1a64(base));
    w_u64(&mut out, summary.records as u64);
    // --- dynamic state (same field order as the full format) ---
    w_u64(&mut out, job.run.round() as u64);
    w_f32s(&mut out, &job.run.x);
    w_f32s(&mut out, &job.run.avg);
    w_rng(&mut out, &job.rng);
    w_u64(&mut out, job.run.worker_rngs.len() as u64);
    for wr in &job.run.worker_rngs {
        w_rng(&mut out, wr);
    }
    let mut fb = Vec::new();
    job.save_feedback(&mut fb);
    w_f32s(&mut out, &fb);
    let tail = &trace.records[summary.records..];
    w_u64(&mut out, tail.len() as u64);
    for rec in tail {
        w_f32(&mut out, rec.value);
        w_f32(&mut out, rec.dist_to_opt);
        w_u64(&mut out, rec.payload_bits as u64);
        w_u64(&mut out, rec.participants as u64);
    }
    w_u64(&mut out, trace.total_payload_bits as u64);
    w_u64(&mut out, trace.total_side_bits as u64);
    w_sched_trailer(&mut out, sched);
    let sum = fnv1a(&out);
    w_u32(&mut out, sum);
    Ok(out)
}

/// [`restore_delta_with_sched`] discarding the scheduler trailer.
pub fn restore_delta(delta: &[u8], base: &[u8]) -> io::Result<Job> {
    restore_delta_with_sched(delta, base).map(|(job, _)| job)
}

/// Rebuild a job from a pinned `base` snapshot plus one `delta` record.
/// The whole-record checksum is verified **first**, so any truncation or
/// byte flip anywhere in the delta is [`io::ErrorKind::InvalidData`]
/// before a single field is trusted; the base must match the delta's
/// pinned length + fingerprint byte for byte and then passes the full
/// v1/v2 validation path; the delta must not be behind its base (a
/// stale delta never silently rolls a job back).
pub fn restore_delta_with_sched(delta: &[u8], base: &[u8]) -> io::Result<(Job, SchedTrailer)> {
    restore_delta_with_sched_cached(delta, base, None)
}

/// [`restore_delta_with_sched`] with an optional codec-plan cache for
/// the base rebuild (see [`restore_with_sched_cached`]); validation and
/// the overlay are byte-for-byte the uncached path.
pub fn restore_delta_with_sched_cached(
    delta: &[u8],
    base: &[u8],
    cache: Option<&PlanCache>,
) -> io::Result<(Job, SchedTrailer)> {
    if delta.len() < 16 {
        return Err(invalid("truncated delta snapshot"));
    }
    let (body, sum_bytes) = delta.split_at(delta.len() - 4);
    let want = u32::from_le_bytes(sum_bytes.try_into().expect("4-byte split"));
    if fnv1a(body) != want {
        return Err(invalid("delta snapshot checksum mismatch"));
    }
    let mut r: &[u8] = body;
    let mut magic = [0u8; 8];
    ck(r.read_exact(&mut magic))?;
    if &magic != CHECKPOINT_MAGIC {
        return Err(invalid("not a KFCKPT01 delta snapshot"));
    }
    let version = r_u32(&mut r)?;
    if version != CHECKPOINT_DELTA_VERSION {
        return Err(invalid(format!(
            "not a delta snapshot (version {version}, expected {CHECKPOINT_DELTA_VERSION})"
        )));
    }
    let base_len = r_u64(&mut r)?;
    let base_hash = r_u64(&mut r)?;
    if base.len() as u64 != base_len || fnv1a64(base) != base_hash {
        return Err(invalid("delta's pinned base does not match the provided base snapshot"));
    }
    // The fingerprint matched: restore the base through the full v1/v2
    // validation path, then overlay the delta on top.
    let (mut job, _base_sched) = restore_with_sched_cached(base, cache)?;
    let base_records =
        checked_len_capped(r_u64(&mut r)?, "base record count", MAX_ROUNDS as u64 + 1)?;
    if job.trace().records.len() != base_records {
        return Err(invalid(format!(
            "delta pins {base_records} base trace records, base has {}",
            job.trace().records.len()
        )));
    }
    let (n, workers, rounds, output) =
        (job.spec().n, job.spec().workers, job.spec().rounds, job.spec().output);
    let t = checked_len_capped(r_u64(&mut r)?, "round index", MAX_ROUNDS as u64)?;
    if t > rounds {
        return Err(invalid(format!("round index {t} exceeds configured rounds {rounds}")));
    }
    if t < job.run.round() {
        return Err(invalid(format!(
            "stale delta: round {t} is behind its own base (round {})",
            job.run.round()
        )));
    }
    let x = r_f32s(&mut r, "iterate")?;
    if x.len() != n {
        return Err(invalid(format!("iterate length {} != dimension {n}", x.len())));
    }
    let avg = r_f32s(&mut r, "Polyak average")?;
    let want_avg = if output == OutputMode::PolyakAverage { n } else { 0 };
    if avg.len() != want_avg {
        return Err(invalid(format!(
            "Polyak average length {} != expected {want_avg}",
            avg.len()
        )));
    }
    let rng = r_rng(&mut r)?;
    let n_wr = checked_len_capped(r_u64(&mut r)?, "worker RNG count", MAX_WORKERS as u64)?;
    if n_wr != workers {
        return Err(invalid(format!("worker RNG count {n_wr} != workers {workers}")));
    }
    let mut worker_rngs = Vec::with_capacity(n_wr);
    for _ in 0..n_wr {
        worker_rngs.push(r_rng(&mut r)?);
    }
    let fb = r_f32s(&mut r, "feedback state")?;
    if !job.restore_feedback(&fb) {
        return Err(invalid(format!("feedback state has wrong shape ({} floats)", fb.len())));
    }
    let n_tail = checked_len_capped(r_u64(&mut r)?, "appended record count", MAX_ROUNDS as u64 + 1)?;
    if base_records + n_tail > rounds + 1 {
        return Err(invalid(format!(
            "{} trace records for a {rounds}-round job",
            base_records + n_tail
        )));
    }
    for _ in 0..n_tail {
        job.run.trace.records.push(IterRecord {
            value: r_f32(&mut r)?,
            dist_to_opt: r_f32(&mut r)?,
            payload_bits: r_u64(&mut r)? as usize,
            participants: r_u64(&mut r)? as usize,
        });
    }
    let total_payload = r_u64(&mut r)? as usize;
    let total_side = r_u64(&mut r)? as usize;
    if total_payload < job.run.trace.total_payload_bits
        || total_side < job.run.trace.total_side_bits
    {
        return Err(invalid("delta traffic totals regress below the base's"));
    }
    let sched = r_sched_trailer(&mut r)?;
    if !r.is_empty() {
        return Err(invalid(format!("{} trailing bytes after delta snapshot", r.len())));
    }
    // Overlay the moved state (same overlay discipline as the full path).
    job.run.t = t;
    job.run.x.copy_from_slice(&x);
    job.run.avg.copy_from_slice(&avg);
    job.run.worker_rngs = worker_rngs;
    job.run.trace.total_payload_bits = total_payload;
    job.run.trace.total_side_bits = total_side;
    job.rng = rng;
    job.spec.qos = sched.qos;
    Ok((job, sched))
}

/// Fold a base snapshot and its delta chain back into one plain v2
/// snapshot (the compaction pass: retire a long autosave chain into a
/// fresh base). Every delta must pin `base` (deltas reference the base,
/// not each other) and the chain must be round-monotonic; each link is
/// fully restored — compaction doubles as end-to-end validation of the
/// chain. With an empty chain the base itself is re-validated and
/// re-serialized as v2.
pub fn compact(base: &[u8], deltas: &[&[u8]]) -> io::Result<Vec<u8>> {
    if deltas.is_empty() {
        let (job, sched) = restore_with_sched(base)?;
        return save_with_sched(&job, &sched);
    }
    let mut newest: Option<(Job, SchedTrailer)> = None;
    for (i, d) in deltas.iter().enumerate() {
        let (job, sched) = restore_delta_with_sched(d, base)?;
        if let Some((prev, _)) = &newest {
            if job.run.round() < prev.run.round() {
                return Err(invalid(format!(
                    "delta chain is not round-monotonic at link {i} \
                     (round {} after round {})",
                    job.run.round(),
                    prev.run.round()
                )));
            }
        }
        newest = Some((job, sched));
    }
    let (job, sched) = newest.expect("non-empty chain");
    save_with_sched(&job, &sched)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job() -> Job {
        let spec = JobSpec::new(
            "ckpt-unit",
            CompressorSpec::parse("ndsc-dith").unwrap(),
            1.0,
            16,
            10,
            7,
        )
        .with_workers(2)
        .with_def_feedback();
        Job::build(spec).unwrap()
    }

    #[test]
    fn snapshot_roundtrips_mid_run() {
        let mut a = job();
        for _ in 0..4 {
            a.step_round(0);
        }
        let bytes = save(&a).unwrap();
        let b = restore(&bytes).unwrap();
        assert_eq!(b.rounds_done(), 4);
        assert_eq!(b.spec().name, "ckpt-unit");
        assert_eq!(b.trace().records.len(), a.trace().records.len());
        assert_eq!(b.trace().total_payload_bits, a.trace().total_payload_bits);
        // A second snapshot of the restored job is byte-identical.
        assert_eq!(save(&b).unwrap(), bytes);
    }

    #[test]
    fn finalized_jobs_are_not_checkpointable() {
        let mut a = job();
        while !a.is_complete() {
            a.step_round(0);
        }
        // Complete but not yet finalized: still snapshotable (restore +
        // fleet admission will finalize it exactly once).
        assert!(save(&a).is_ok());
        a.finalize();
        let err = save(&a).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
    }

    #[test]
    fn build_caps_match_the_reader_caps() {
        // Anything Job::build admits must survive its own snapshot; the
        // reader's caps are therefore admission rules (no spec can be
        // served-but-unrestorable).
        let mut s = JobSpec::new(
            "caps",
            CompressorSpec::parse("ndsc-dith").unwrap(),
            1.0,
            16,
            8,
            1,
        );
        s.rounds = super::MAX_ROUNDS + 1;
        assert!(Job::build(s.clone()).is_err(), "rounds beyond the reader cap");
        s.rounds = 8;
        s.name = "x".repeat(super::MAX_STR + 1);
        assert!(Job::build(s.clone()).is_err(), "name beyond the reader cap");
        s.name = "caps".into();
        s.problem =
            ProblemSpec::PlantedRegression { rows_per_shard: super::MAX_ROWS + 1, student_t: false };
        assert!(Job::build(s).is_err(), "rows beyond the reader cap");
    }

    #[test]
    fn sched_trailer_roundtrips_deficit_rung_and_qos() {
        let mut a = job();
        a.step_round(0);
        let sched =
            SchedTrailer { deficit_bits: 12_345, rung: Some(2), qos: QosClass::Gold };
        let bytes = save_with_sched(&a, &sched).unwrap();
        let (b, got) = restore_with_sched(&bytes).unwrap();
        assert_eq!(got, sched);
        assert_eq!(b.spec().qos, QosClass::Gold, "QoS travels on the restored spec");
        assert_eq!(b.rounds_done(), 1);
        // The plain save writes a zeroed trailer with the spec's class.
        let plain = save(&b).unwrap();
        let (_, zeroed) = restore_with_sched(&plain).unwrap();
        assert_eq!(zeroed, SchedTrailer { qos: QosClass::Gold, ..SchedTrailer::default() });
    }

    #[test]
    fn version_1_snapshots_without_trailer_still_load() {
        // A v1 snapshot is exactly the v2 bytes minus the trailer, with
        // the version word rolled back — what every pre-trailer build
        // wrote. It must restore with scheduler defaults.
        let mut a = job();
        for _ in 0..3 {
            a.step_round(0);
        }
        let v2 = save(&a).unwrap();
        let mut v1 = v2[..v2.len() - TRAILER_LEN].to_vec();
        v1[8..12].copy_from_slice(&1u32.to_le_bytes());
        let (b, sched) = restore_with_sched(&v1).unwrap();
        assert_eq!(sched, SchedTrailer::default());
        assert_eq!(b.rounds_done(), 3);
        assert_eq!(b.trace().total_payload_bits, a.trace().total_payload_bits);
        // ...but a v2 snapshot with the trailer cut off is truncated, not
        // a v1 snapshot: the version word says the trailer must be there.
        let cut = &v2[..v2.len() - TRAILER_LEN];
        assert_eq!(restore(cut).unwrap_err().kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn every_trailer_byte_flip_is_detected() {
        // The trailer payload is free-form integers (deficit, rung), so
        // only the checksum stands between a flipped bit and silently
        // restored wrong scheduler credit.
        let mut a = job();
        a.step_round(0);
        let good =
            save_with_sched(&a, &SchedTrailer { deficit_bits: 999, rung: Some(1), qos: QosClass::Bronze })
                .unwrap();
        for pos in good.len() - TRAILER_LEN..good.len() {
            let mut bad = good.clone();
            bad[pos] ^= 0xA5;
            let err = restore_with_sched(&bad)
                .expect_err(&format!("trailer flip at byte {pos} must be rejected"));
            assert_eq!(err.kind(), io::ErrorKind::InvalidData, "byte {pos}");
        }
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        let mut a = job();
        a.step_round(0);
        let good = save(&a).unwrap();
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert_eq!(restore(&bad).unwrap_err().kind(), io::ErrorKind::InvalidData);
        let mut bad = good.clone();
        bad[8] = 99; // version word
        assert_eq!(restore(&bad).unwrap_err().kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn corrupt_length_fields_error_not_allocate() {
        let a = job();
        let good = save(&a).unwrap();
        // The job-name length field sits right after magic + version.
        let mut bad = good.clone();
        bad[12..20].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = restore(&bad).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // Trailing garbage is rejected.
        let mut bad = good.clone();
        bad.extend_from_slice(&[0u8; 3]);
        assert_eq!(restore(&bad).unwrap_err().kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn delta_roundtrips_bit_for_bit_and_stays_small() {
        let mut a = job();
        for _ in 0..4 {
            a.step_round(0);
        }
        let base = save(&a).unwrap();
        for _ in 0..4 {
            a.step_round(0);
        }
        let full = save(&a).unwrap();
        let delta = save_delta(&a, &base).unwrap();
        assert!(is_delta(&delta));
        assert!(!is_delta(&base));
        assert!(
            delta.len() < full.len(),
            "delta ({}) must be smaller than the full snapshot ({})",
            delta.len(),
            full.len()
        );
        let b = restore_delta(&delta, &base).unwrap();
        assert_eq!(b.rounds_done(), 8);
        // The restored job re-serializes byte-identically to the
        // original — the delta lost nothing.
        assert_eq!(save(&b).unwrap(), full);
        // A delta is not a standalone snapshot.
        assert_eq!(restore(&delta).unwrap_err().kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn delta_carries_the_scheduler_trailer() {
        let mut a = job();
        a.step_round(0);
        let base = save(&a).unwrap();
        a.step_round(0);
        let sched = SchedTrailer { deficit_bits: 777, rung: Some(3), qos: QosClass::Gold };
        let delta = save_delta_with_sched(&a, &sched, &base).unwrap();
        let (b, got) = restore_delta_with_sched(&delta, &base).unwrap();
        assert_eq!(got, sched);
        assert_eq!(b.spec().qos, QosClass::Gold);
        assert_eq!(b.rounds_done(), 2);
    }

    #[test]
    fn compaction_folds_a_delta_chain_into_a_plain_snapshot() {
        let mut a = job();
        for _ in 0..2 {
            a.step_round(0);
        }
        let base = save(&a).unwrap();
        for _ in 0..2 {
            a.step_round(0);
        }
        let d4 = save_delta(&a, &base).unwrap();
        for _ in 0..2 {
            a.step_round(0);
        }
        let d6 = save_delta(&a, &base).unwrap();
        let compacted = compact(&base, &[d4.as_slice(), d6.as_slice()]).unwrap();
        assert!(!is_delta(&compacted), "compaction retires the chain into a plain v2 base");
        assert_eq!(compacted, save(&a).unwrap(), "compaction ≡ a fresh full snapshot");
        // A reversed (non-monotonic) chain is a caller bug, not a state
        // to silently roll back to.
        assert_eq!(
            compact(&base, &[d6.as_slice(), d4.as_slice()]).unwrap_err().kind(),
            io::ErrorKind::InvalidData
        );
        // The empty chain re-validates and re-serializes the base.
        assert_eq!(compact(&base, &[]).unwrap(), base);
    }

    #[test]
    fn every_delta_byte_flip_and_truncation_is_invalid_data() {
        let mut a = job();
        for _ in 0..3 {
            a.step_round(0);
        }
        let base = save(&a).unwrap();
        a.step_round(0);
        let delta =
            save_delta_with_sched(&a, &SchedTrailer { deficit_bits: 5, rung: Some(1), qos: QosClass::Silver }, &base)
                .unwrap();
        // The whole-record checksum leaves no byte uncovered: every
        // single flip surfaces as InvalidData, never a panic and never a
        // silently different restore.
        for pos in 0..delta.len() {
            let mut bad = delta.clone();
            bad[pos] ^= 0xA5;
            let err = restore_delta(&bad, &base)
                .expect_err(&format!("delta flip at byte {pos} must be rejected"));
            assert_eq!(err.kind(), io::ErrorKind::InvalidData, "byte {pos}");
        }
        for cut in 0..delta.len() {
            let err = restore_delta(&delta[..cut], &base)
                .expect_err(&format!("truncation to {cut} bytes must be rejected"));
            assert_eq!(err.kind(), io::ErrorKind::InvalidData, "cut {cut}");
        }
    }

    #[test]
    fn wrong_or_corrupt_base_is_rejected_at_both_ends() {
        let mut a = job();
        a.step_round(0);
        let base = save(&a).unwrap();
        a.step_round(0);
        let delta = save_delta(&a, &base).unwrap();
        // A flipped base byte breaks the pinned fingerprint.
        for pos in [0usize, 12, base.len() / 2, base.len() - 1] {
            let mut bad = base.clone();
            bad[pos] ^= 0xA5;
            assert_eq!(
                restore_delta(&delta, &bad).unwrap_err().kind(),
                io::ErrorKind::InvalidData,
                "base flip at byte {pos}"
            );
        }
        // A different job's snapshot is not this delta's base...
        let other = {
            let spec = JobSpec::new(
                "other-job",
                CompressorSpec::parse("ndsc-dith").unwrap(),
                1.0,
                16,
                10,
                99,
            )
            .with_workers(2)
            .with_def_feedback();
            let mut j = Job::build(spec).unwrap();
            j.step_round(0);
            save(&j).unwrap()
        };
        assert_eq!(restore_delta(&delta, &other).unwrap_err().kind(), io::ErrorKind::InvalidData);
        // ...and save_delta refuses to pin it in the first place.
        assert_eq!(save_delta(&a, &other).unwrap_err().kind(), io::ErrorKind::InvalidData);
        // A base *ahead* of the job (stale job state) is refused at save
        // time: a delta must never roll a job backwards.
        let mut behind = job();
        behind.step_round(0);
        let ahead = save(&a).unwrap(); // a is at round 2
        assert_eq!(save_delta(&behind, &ahead).unwrap_err().kind(), io::ErrorKind::InvalidData);
        // A delta never chains on a delta.
        assert_eq!(save_delta(&a, &delta).unwrap_err().kind(), io::ErrorKind::InvalidData);
    }
}
