//! Versioned binary job snapshots: save a running job, restore it in a
//! fresh process, continue the uninterrupted trace **bit for bit**.
//!
//! A snapshot carries two sections:
//!
//! | Section | Contents |
//! |---|---|
//! | spec    | name, scheme (canonical registry string), `R`, `n`, workers, problem, rounds, schedule, feedback kind, batch, drop-prob, domain, output mode, seed |
//! | state   | round index `t`, iterate `x`, Polyak average, job RNG, per-worker RNG streams, feedback memory, accumulated trace + traffic totals |
//!
//! Static artifacts (dataset, frames/codecs, workspace) are **not**
//! serialized: [`restore`] rebuilds them deterministically from the spec
//! seed via [`crate::serve::job::Job::build`], then overlays the dynamic
//! state. That keeps snapshots small (KBs, independent of dataset size)
//! and makes the format a statement of exactly which state matters.
//!
//! Hardening follows [`crate::coordinator::protocol`]: little-endian
//! length-prefixed fields, every length checked against a sanity cap
//! ([`protocol::checked_len_capped`]) before allocation, truncation
//! mapped to [`io::ErrorKind::InvalidData`] — a corrupt snapshot is an
//! error, never a panic or a giant allocation
//! (`rust/tests/test_serve.rs` fuzzes truncations and corruptions).

use std::io::{self, Read};

use crate::coordinator::protocol::{self, checked_len_capped};
use crate::linalg::rng::Rng;
use crate::opt::engine::schedule::Schedule;
use crate::opt::engine::OutputMode;
use crate::opt::projection::Domain;
use crate::opt::{IterRecord, Trace};
use crate::quant::registry::CompressorSpec;
use crate::serve::job::{FeedbackKind, Job, JobSpec, ProblemSpec};

/// Magic bytes opening every snapshot (version-tagged family).
pub const CHECKPOINT_MAGIC: &[u8; 8] = b"KFCKPT01";
/// Format version this build writes and accepts.
pub const CHECKPOINT_VERSION: u32 = 1;

/// Sanity caps: generous for every real configuration (transformer-scale
/// `n`, thousands of workers, millions of rounds), low enough that a
/// flipped bit in any size field cannot turn the deterministic rebuild
/// into a giant allocation before the cross-checks run. **Enforced at
/// [`Job::build`] too**, so every job a fleet admits is guaranteed to
/// round-trip through its own snapshot — a spec the reader would reject
/// never starts running in the first place.
pub(crate) const MAX_STR: usize = 4096;
pub(crate) const MAX_DIM: usize = 1 << 20;
pub(crate) const MAX_WORKERS: usize = 1 << 12;
pub(crate) const MAX_ROWS: usize = 1 << 16;
pub(crate) const MAX_ROUNDS: usize = 1 << 22;
const MAX_VEC: u64 = 1 << 26;

// ---------------------------------------------------------------------------
// Writers.
// ---------------------------------------------------------------------------

fn w_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn w_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_str(out: &mut Vec<u8>, s: &str) {
    w_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn w_f32s(out: &mut Vec<u8>, v: &[f32]) {
    w_u64(out, v.len() as u64);
    for &x in v {
        w_f32(out, x);
    }
}

fn w_rng(out: &mut Vec<u8>, rng: &Rng) {
    let (s, spare) = rng.state();
    for w in s {
        w_u64(out, w);
    }
    match spare {
        Some(g) => {
            w_u8(out, 1);
            w_u64(out, g.to_bits());
        }
        None => {
            w_u8(out, 0);
            w_u64(out, 0);
        }
    }
}

// ---------------------------------------------------------------------------
// Readers (truncation ⇒ InvalidData).
// ---------------------------------------------------------------------------

fn invalid(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Map a short read to `InvalidData`: a truncated snapshot is corrupt
/// input, not an I/O condition the caller can retry.
fn ck<T>(r: io::Result<T>) -> io::Result<T> {
    r.map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            invalid("truncated checkpoint")
        } else {
            e
        }
    })
}

fn r_u8(r: &mut impl Read) -> io::Result<u8> {
    let mut b = [0u8; 1];
    ck(r.read_exact(&mut b))?;
    Ok(b[0])
}

fn r_u32(r: &mut impl Read) -> io::Result<u32> {
    ck(protocol::read_u32(r))
}

fn r_u64(r: &mut impl Read) -> io::Result<u64> {
    ck(protocol::read_u64(r))
}

fn r_f32(r: &mut impl Read) -> io::Result<f32> {
    ck(protocol::read_f32(r))
}

fn r_str(r: &mut impl Read, what: &str) -> io::Result<String> {
    let len = checked_len_capped(r_u64(r)?, what, MAX_STR as u64)?;
    let mut buf = vec![0u8; len];
    ck(r.read_exact(&mut buf))?;
    String::from_utf8(buf).map_err(|_| invalid(format!("{what} is not valid UTF-8")))
}

fn r_f32s(r: &mut impl Read, what: &str) -> io::Result<Vec<f32>> {
    let len = checked_len_capped(r_u64(r)?, what, MAX_VEC)?;
    // Bounded initial reserve: a corrupt length field under the cap must
    // hit the truncation error, not a cap-sized upfront allocation.
    let mut out = Vec::with_capacity(len.min(1 << 16));
    for _ in 0..len {
        out.push(r_f32(r)?);
    }
    Ok(out)
}

fn r_rng(r: &mut impl Read) -> io::Result<Rng> {
    let mut s = [0u64; 4];
    for w in &mut s {
        *w = r_u64(r)?;
    }
    let spare = match r_u8(r)? {
        0 => {
            r_u64(r)?; // reserved slot, ignored
            None
        }
        1 => Some(f64::from_bits(r_u64(r)?)),
        t => return Err(invalid(format!("bad RNG spare flag {t}"))),
    };
    Ok(Rng::from_state(s, spare))
}

// ---------------------------------------------------------------------------
// Enum tags.
// ---------------------------------------------------------------------------

fn schedule_tag(s: Schedule) -> (u8, f32, f32) {
    match s {
        Schedule::Constant(c) => (0, c, 0.0),
        Schedule::InvSqrt { c } => (1, c, 0.0),
        Schedule::Harmonic { c, t0 } => (2, c, t0),
    }
}

fn schedule_from_tag(tag: u8, a: f32, b: f32) -> io::Result<Schedule> {
    Ok(match tag {
        0 => Schedule::Constant(a),
        1 => Schedule::InvSqrt { c: a },
        2 => Schedule::Harmonic { c: a, t0: b },
        t => return Err(invalid(format!("bad schedule tag {t}"))),
    })
}

fn domain_tag(d: Domain) -> (u8, f32, f32) {
    match d {
        Domain::Unconstrained => (0, 0.0, 0.0),
        Domain::L2Ball { radius } => (1, radius, 0.0),
        Domain::Box { lo, hi } => (2, lo, hi),
    }
}

fn domain_from_tag(tag: u8, a: f32, b: f32) -> io::Result<Domain> {
    Ok(match tag {
        0 => Domain::Unconstrained,
        1 => Domain::L2Ball { radius: a },
        2 => Domain::Box { lo: a, hi: b },
        t => return Err(invalid(format!("bad domain tag {t}"))),
    })
}

fn output_tag(o: OutputMode) -> u8 {
    match o {
        OutputMode::LastIterate { trailing: false } => 0,
        OutputMode::LastIterate { trailing: true } => 1,
        OutputMode::PolyakAverage => 2,
    }
}

fn output_from_tag(tag: u8) -> io::Result<OutputMode> {
    Ok(match tag {
        0 => OutputMode::LastIterate { trailing: false },
        1 => OutputMode::LastIterate { trailing: true },
        2 => OutputMode::PolyakAverage,
        t => return Err(invalid(format!("bad output-mode tag {t}"))),
    })
}

// ---------------------------------------------------------------------------
// Save / restore.
// ---------------------------------------------------------------------------

/// Serialize a resumable snapshot of `job` (see the module docs for the
/// layout). Refuses a finalized job: snapshots exist to resume
/// running/paused jobs, and a finalized trace (trailing record appended,
/// `final_x` set) would restore into a double-finalized, diverged trace.
pub fn save(job: &Job) -> io::Result<Vec<u8>> {
    if job.run.is_finalized() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            "cannot checkpoint a finalized job; snapshots resume running/paused jobs",
        ));
    }
    let spec = job.spec();
    let mut out = Vec::with_capacity(4096);
    out.extend_from_slice(CHECKPOINT_MAGIC);
    w_u32(&mut out, CHECKPOINT_VERSION);
    // --- spec ---
    w_str(&mut out, &spec.name);
    w_str(&mut out, &spec.scheme.name());
    w_f32(&mut out, spec.r);
    w_u64(&mut out, spec.n as u64);
    w_u64(&mut out, spec.workers as u64);
    let ProblemSpec::PlantedRegression { rows_per_shard, student_t } = spec.problem;
    w_u64(&mut out, rows_per_shard as u64);
    w_u8(&mut out, student_t as u8);
    w_u64(&mut out, spec.rounds as u64);
    let (stag, sa, sb) = schedule_tag(spec.schedule);
    w_u8(&mut out, stag);
    w_f32(&mut out, sa);
    w_f32(&mut out, sb);
    w_u8(&mut out, matches!(spec.feedback, FeedbackKind::Def) as u8);
    w_u64(&mut out, spec.batch.map(|b| b as u64).unwrap_or(0));
    w_f32(&mut out, spec.drop_prob);
    let (dtag, da, db) = domain_tag(spec.domain);
    w_u8(&mut out, dtag);
    w_f32(&mut out, da);
    w_f32(&mut out, db);
    w_u8(&mut out, output_tag(spec.output));
    w_u64(&mut out, spec.seed);
    // --- dynamic state ---
    w_u64(&mut out, job.run.round() as u64);
    w_f32s(&mut out, &job.run.x);
    w_f32s(&mut out, &job.run.avg);
    w_rng(&mut out, &job.rng);
    w_u64(&mut out, job.run.worker_rngs.len() as u64);
    for wr in &job.run.worker_rngs {
        w_rng(&mut out, wr);
    }
    let mut fb = Vec::new();
    job.save_feedback(&mut fb);
    w_f32s(&mut out, &fb);
    let trace = job.trace();
    w_u64(&mut out, trace.records.len() as u64);
    for rec in &trace.records {
        w_f32(&mut out, rec.value);
        w_f32(&mut out, rec.dist_to_opt);
        w_u64(&mut out, rec.payload_bits as u64);
        w_u64(&mut out, rec.participants as u64);
    }
    w_u64(&mut out, trace.total_payload_bits as u64);
    w_u64(&mut out, trace.total_side_bits as u64);
    Ok(out)
}

/// Rebuild a job from a snapshot. The static artifacts are regrown from
/// the spec seed (identical by the derivation discipline of
/// [`crate::serve::job`]); the dynamic state is overlaid and
/// cross-checked against the spec — any inconsistency, unknown tag,
/// out-of-cap length, truncation or trailing garbage is
/// [`io::ErrorKind::InvalidData`].
pub fn restore(bytes: &[u8]) -> io::Result<Job> {
    let mut r: &[u8] = bytes;
    let mut magic = [0u8; 8];
    ck(r.read_exact(&mut magic))?;
    if &magic != CHECKPOINT_MAGIC {
        return Err(invalid("not a KFCKPT01 job checkpoint"));
    }
    let version = r_u32(&mut r)?;
    if version != CHECKPOINT_VERSION {
        return Err(invalid(format!(
            "unsupported checkpoint version {version} (this build reads {CHECKPOINT_VERSION})"
        )));
    }
    // --- spec ---
    let name = r_str(&mut r, "job name")?;
    let scheme_name = r_str(&mut r, "scheme name")?;
    let scheme = CompressorSpec::parse(&scheme_name)
        .ok_or_else(|| invalid(format!("unknown scheme '{scheme_name}' in checkpoint")))?;
    let r_budget = r_f32(&mut r)?;
    let n = checked_len_capped(r_u64(&mut r)?, "dimension", MAX_DIM as u64)?;
    let workers = checked_len_capped(r_u64(&mut r)?, "worker count", MAX_WORKERS as u64)?;
    let rows_per_shard = checked_len_capped(r_u64(&mut r)?, "rows per shard", MAX_ROWS as u64)?;
    let student_t = match r_u8(&mut r)? {
        0 => false,
        1 => true,
        t => return Err(invalid(format!("bad student-t flag {t}"))),
    };
    let rounds = checked_len_capped(r_u64(&mut r)?, "round count", MAX_ROUNDS as u64)?;
    let (stag, sa, sb) = (r_u8(&mut r)?, r_f32(&mut r)?, r_f32(&mut r)?);
    let schedule = schedule_from_tag(stag, sa, sb)?;
    let feedback = match r_u8(&mut r)? {
        0 => FeedbackKind::None,
        1 => FeedbackKind::Def,
        t => return Err(invalid(format!("bad feedback tag {t}"))),
    };
    let batch = match r_u64(&mut r)? {
        0 => None,
        b => Some(checked_len_capped(b, "batch size", MAX_VEC)?),
    };
    let drop_prob = r_f32(&mut r)?;
    let (dtag, da, db) = (r_u8(&mut r)?, r_f32(&mut r)?, r_f32(&mut r)?);
    let domain = domain_from_tag(dtag, da, db)?;
    let output = output_from_tag(r_u8(&mut r)?)?;
    let seed = r_u64(&mut r)?;
    let spec = JobSpec {
        name,
        scheme,
        r: r_budget,
        n,
        workers,
        problem: ProblemSpec::PlantedRegression { rows_per_shard, student_t },
        rounds,
        schedule,
        feedback,
        batch,
        drop_prob,
        domain,
        output,
        seed,
    };
    let mut job =
        Job::build(spec).map_err(|e| invalid(format!("checkpoint spec rejected: {e}")))?;
    // --- dynamic state ---
    let t = checked_len_capped(r_u64(&mut r)?, "round index", MAX_ROUNDS as u64)?;
    if t > rounds {
        return Err(invalid(format!("round index {t} exceeds configured rounds {rounds}")));
    }
    let x = r_f32s(&mut r, "iterate")?;
    if x.len() != n {
        return Err(invalid(format!("iterate length {} != dimension {n}", x.len())));
    }
    let avg = r_f32s(&mut r, "Polyak average")?;
    let want_avg = if output == OutputMode::PolyakAverage { n } else { 0 };
    if avg.len() != want_avg {
        return Err(invalid(format!(
            "Polyak average length {} != expected {want_avg}",
            avg.len()
        )));
    }
    let rng = r_rng(&mut r)?;
    let n_wr = checked_len_capped(r_u64(&mut r)?, "worker RNG count", MAX_WORKERS as u64)?;
    if n_wr != workers {
        return Err(invalid(format!("worker RNG count {n_wr} != workers {workers}")));
    }
    let mut worker_rngs = Vec::with_capacity(n_wr);
    for _ in 0..n_wr {
        worker_rngs.push(r_rng(&mut r)?);
    }
    let fb = r_f32s(&mut r, "feedback state")?;
    if !job.restore_feedback(&fb) {
        return Err(invalid(format!("feedback state has wrong shape ({} floats)", fb.len())));
    }
    let n_rec = checked_len_capped(r_u64(&mut r)?, "trace record count", MAX_ROUNDS as u64 + 1)?;
    if n_rec > rounds + 1 {
        return Err(invalid(format!("{n_rec} trace records for a {rounds}-round job")));
    }
    let mut trace = Trace::default();
    trace.records.reserve(rounds + 1);
    for _ in 0..n_rec {
        trace.records.push(IterRecord {
            value: r_f32(&mut r)?,
            dist_to_opt: r_f32(&mut r)?,
            payload_bits: r_u64(&mut r)? as usize,
            participants: r_u64(&mut r)? as usize,
        });
    }
    trace.total_payload_bits = r_u64(&mut r)? as usize;
    trace.total_side_bits = r_u64(&mut r)? as usize;
    if !r.is_empty() {
        return Err(invalid(format!("{} trailing bytes after checkpoint", r.len())));
    }
    // Overlay onto the freshly built job.
    job.run.t = t;
    job.run.x.copy_from_slice(&x);
    job.run.avg.copy_from_slice(&avg);
    job.run.worker_rngs = worker_rngs;
    job.run.trace = trace;
    job.rng = rng;
    Ok(job)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job() -> Job {
        let spec = JobSpec::new(
            "ckpt-unit",
            CompressorSpec::parse("ndsc-dith").unwrap(),
            1.0,
            16,
            10,
            7,
        )
        .with_workers(2)
        .with_def_feedback();
        Job::build(spec).unwrap()
    }

    #[test]
    fn snapshot_roundtrips_mid_run() {
        let mut a = job();
        for _ in 0..4 {
            a.step_round(0);
        }
        let bytes = save(&a).unwrap();
        let b = restore(&bytes).unwrap();
        assert_eq!(b.rounds_done(), 4);
        assert_eq!(b.spec().name, "ckpt-unit");
        assert_eq!(b.trace().records.len(), a.trace().records.len());
        assert_eq!(b.trace().total_payload_bits, a.trace().total_payload_bits);
        // A second snapshot of the restored job is byte-identical.
        assert_eq!(save(&b).unwrap(), bytes);
    }

    #[test]
    fn finalized_jobs_are_not_checkpointable() {
        let mut a = job();
        while !a.is_complete() {
            a.step_round(0);
        }
        // Complete but not yet finalized: still snapshotable (restore +
        // fleet admission will finalize it exactly once).
        assert!(save(&a).is_ok());
        a.finalize();
        let err = save(&a).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
    }

    #[test]
    fn build_caps_match_the_reader_caps() {
        // Anything Job::build admits must survive its own snapshot; the
        // reader's caps are therefore admission rules (no spec can be
        // served-but-unrestorable).
        let mut s = JobSpec::new(
            "caps",
            CompressorSpec::parse("ndsc-dith").unwrap(),
            1.0,
            16,
            8,
            1,
        );
        s.rounds = super::MAX_ROUNDS + 1;
        assert!(Job::build(s.clone()).is_err(), "rounds beyond the reader cap");
        s.rounds = 8;
        s.name = "x".repeat(super::MAX_STR + 1);
        assert!(Job::build(s.clone()).is_err(), "name beyond the reader cap");
        s.name = "caps".into();
        s.problem =
            ProblemSpec::PlantedRegression { rows_per_shard: super::MAX_ROWS + 1, student_t: false };
        assert!(Job::build(s).is_err(), "rows beyond the reader cap");
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        let mut a = job();
        a.step_round(0);
        let good = save(&a).unwrap();
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert_eq!(restore(&bad).unwrap_err().kind(), io::ErrorKind::InvalidData);
        let mut bad = good.clone();
        bad[8] = 99; // version word
        assert_eq!(restore(&bad).unwrap_err().kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn corrupt_length_fields_error_not_allocate() {
        let a = job();
        let good = save(&a).unwrap();
        // The job-name length field sits right after magic + version.
        let mut bad = good.clone();
        bad[12..20].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = restore(&bad).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // Trailing garbage is rejected.
        let mut bad = good.clone();
        bad.extend_from_slice(&[0u8; 3]);
        assert_eq!(restore(&bad).unwrap_err().kind(), io::ErrorKind::InvalidData);
    }
}
