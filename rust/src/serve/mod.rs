//! Multi-job serving layer: many concurrent optimization jobs over one
//! shared fleet, arbitrated by a **global** per-round bit budget.
//!
//! The paper's algorithms assume one run owning the whole channel; in a
//! served deployment the bit budget `R` is exactly the resource many
//! tenants contend for. This layer multiplexes N engine runs — each an
//! arbitrary oracle × schedule × feedback × compressor composition —
//! over a single communication budget:
//!
//! ```text
//!  submit / pause / resume / cancel / migrate   (lifecycle, cluster.rs)
//!        │  FNV-1a(name,seed) % active placement + load-aware rebalance
//!        ▼
//!  ┌───────── FleetCluster (k fleets, epoch-based executor) ──────────┐
//!  │ ┌───────────┐  epoch grants (job, level R_i)·E    ┌────────────┐ │
//!  │ │ JobServer │ ──────────────────────────────────▶ │ per-fleet  │ │
//!  │ │ registry  │  weighted DRR + QoS reservations    │ deques +   │ │
//!  │ │ + DRR     │  arbitrated E rounds at a barrier   │ stealing   │ │
//!  │ │ + QoS     │  (scheduler.rs, nominal costs)      │ (pool of k │ │
//!  │ └───────────┘                                     │ workers)   │ │
//!  │      · autoscaler grows/shrinks active fleets ·   └────────────┘ │
//!  └──────────────────────────────────────────────────────────────────┘
//!        │ drain grant → snapshot → restore in target (migration)
//!        ▼
//!  checkpoint.rs — versioned binary snapshots         per-job Trace +
//!  (KFCKPT01 v2: + scheduler trailer with deficit /   FleetMetrics +
//!  rung / QoS; v3: delta records vs a pinned base;    ClusterMetrics
//!  corrupt input ⇒ InvalidData)
//! ```
//!
//! Design invariants:
//!
//! * **Isolation** — all cross-round state (iterate, feedback memory,
//!   RNG streams, accounting) lives inside the [`job::Job`]; the
//!   scheduler only decides *when* a job's next round runs, never *how*.
//!   A job's trace is therefore bit-identical whether it runs solo,
//!   interleaved with any mix of tenants, or suspended and resumed —
//!   `rust/tests/test_serve.rs` proves all three.
//! * **Budget arbitration** — each fleet round, deficit round robin
//!   ([`scheduler::Policy`]) picks which jobs transmit and at what
//!   effective `R_i` (a dyadic ladder of feasible budgets per
//!   [`crate::quant::registry::CompressorSpec::is_feasible`]), with
//!   bounded deficit counters guaranteeing starvation-freedom.
//! * **Resumability** — [`checkpoint::save`] serializes the complete
//!   resumable state; [`checkpoint::restore`] rebuilds the job in a
//!   fresh context and continues the uninterrupted trace bit-for-bit;
//!   [`checkpoint::save_delta`] records only what moved since a pinned
//!   base (O(changed) periodic autosave) and [`checkpoint::compact`]
//!   folds delta chains back into a base. Corrupt or truncated
//!   snapshots surface as [`std::io::ErrorKind::InvalidData`], never as
//!   a panic (the [`crate::coordinator::protocol`] hardening rules).
//! * **Epochs over barriers** — the cluster arbitrates E rounds of
//!   grants up front (bit-identical to E lockstep rounds, because
//!   arbitration consumes only nominal ladder costs), then executes
//!   them on a persistent work-stealing pool, so one big-`n` straggler
//!   no longer stalls every fleet at a per-round join
//!   ([`cluster::FleetCluster::run_epoch`]).
//! * **Zero-allocation steady state** — a fleet round performs no heap
//!   allocation per job once warm, and a work-stealing cluster epoch
//!   performs none per epoch (`rust/tests/test_alloc.rs`, phases 4–5).
//! * **Plan reuse** — built codec ladders are immutable and derived
//!   entirely from `(scheme, R, n, workers, seed)`, so the cluster
//!   shares them through a content-addressed, LRU-capped
//!   [`plancache::PlanCache`]: admission of a same-spec tenant,
//!   checkpoint restore and autoscaler migration reuse the existing
//!   plan (bit-identical by construction) instead of regrowing frames.
//! * **Fleet-independence** — a snapshot carries no fleet identity, so a
//!   job restores into *any* fleet (same process or not) and its trace,
//!   banked deficit and adaptive rung continue bit-for-bit; this is the
//!   whole mechanism behind [`cluster::FleetCluster::migrate`].
//!
//! The CLI load-driver is `repro serve` ([`crate::exp::serve`]); the
//! throughput benchmark is `rust/benches/bench_serve.rs`
//! (`BENCH_serve.json`).
//!
//! [`Trace`]: crate::opt::Trace
//! [`FleetMetrics`]: crate::coordinator::metrics::FleetMetrics

pub mod checkpoint;
pub mod cluster;
pub mod fleet;
pub mod job;
pub mod plancache;
pub mod scheduler;

pub use cluster::{FleetCluster, GlobalJobId};
pub use fleet::{JobId, JobServer, JobState, ServeError};
pub use job::{FeedbackKind, Job, JobSpec, ProblemSpec};
pub use plancache::PlanCache;
pub use scheduler::{Deficit, Policy, QosClass};
