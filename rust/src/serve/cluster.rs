//! Multi-fleet serving: partition the tenant population across several
//! coordinator fleets and run their rounds concurrently.
//!
//! A [`FleetCluster`] owns `k` independent [`JobServer`]s, each with its
//! own per-round bit budget, DRR scheduler and job registry. Placement
//! and migration are the only cluster-level decisions; everything about
//! *how* a job's rounds run stays inside its fleet, which is what makes
//! the whole construction trace-neutral:
//!
//! * **Placement** — a submission hashes `(name, seed)` (FNV-1a) onto a
//!   home fleet; a load-aware override reroutes it to the least-loaded
//!   fleet when the home fleet is more than one live job ahead of the
//!   lightest one, so adversarial name distributions cannot pile every
//!   tenant onto one fleet.
//! * **Concurrent rounds** — [`FleetCluster::run_round`] runs one fleet
//!   round on every member fleet, each on its own scoped thread. Fleets
//!   share no mutable state (the recycled buffer pool is lock-protected
//!   and content-independent), so per-job traces are bit-identical to a
//!   solo fleet's — `rust/tests/test_serve.rs` proves it.
//! * **Migration** — [`FleetCluster::migrate`] drains a job's grant,
//!   snapshots it (`KFCKPT01` v2, scheduler trailer included), restores
//!   it into the target fleet and evicts the source copy. Checkpoints
//!   are fleet-independent, so the migrated job's trace continues
//!   bit-for-bit mid-deficit and mid-rung.
//!
//! Worker-thread fan-out inside granted rounds is armed per fleet with
//! the cluster's fleet count, so the never-nest cap
//! ([`crate::coordinator::config::FLEET_MAX_WORKER_THREADS`]) holds
//! across the whole cluster, not per fleet.

use std::sync::Arc;

use crate::coordinator::channel::ChannelPools;
use crate::coordinator::metrics::ClusterMetrics;
use crate::serve::fleet::{JobId, JobServer, JobState, ServeError};
use crate::serve::job::{Job, JobSpec};
use crate::serve::scheduler::Policy;

/// Cluster-assigned job handle (stable across migrations, unlike the
/// per-fleet [`JobId`] which changes when a job changes fleets).
pub type GlobalJobId = u64;

/// Where a job currently lives.
#[derive(Clone, Copy, Debug)]
struct Placement {
    gid: GlobalJobId,
    fleet: usize,
    local: JobId,
}

/// The multi-fleet job cluster (see the [module docs](self)).
pub struct FleetCluster {
    fleets: Vec<JobServer>,
    placements: Vec<Placement>,
    pools: Arc<ChannelPools>,
    next_gid: GlobalJobId,
    rounds: u64,
    rejected: u64,
    migrated: u64,
}

/// FNV-1a over the placement key — stable across processes (no
/// `DefaultHasher` seed dependence), so a resubmitted spec lands on the
/// same home fleet.
fn place_hash(name: &str, seed: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in name.as_bytes().iter().chain(seed.to_le_bytes().iter()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl FleetCluster {
    /// A cluster of `fleets` member fleets, each offering
    /// `budget_bits_per_fleet_round` payload bits per round under
    /// `policy`. All fleets share one recycled buffer pool, and each is
    /// armed for worker-thread fan-out with the cluster's fleet count
    /// (the never-nest share).
    pub fn new(fleets: usize, budget_bits_per_fleet_round: usize, policy: Policy) -> Self {
        let k = fleets.max(1);
        let pools = Arc::new(ChannelPools::new(8));
        let fleets = (0..k)
            .map(|_| {
                let mut f =
                    JobServer::with_pools(budget_bits_per_fleet_round, policy, pools.clone());
                f.enable_fanout(k);
                f
            })
            .collect();
        FleetCluster {
            fleets,
            placements: Vec::new(),
            pools,
            next_gid: 0,
            rounds: 0,
            rejected: 0,
            migrated: 0,
        }
    }

    /// Member fleet count.
    pub fn fleet_count(&self) -> usize {
        self.fleets.len()
    }

    /// Read access to a member fleet (metrics, budget).
    pub fn fleet(&self, i: usize) -> &JobServer {
        &self.fleets[i]
    }

    /// The cluster-shared recycled buffer pool.
    pub fn pools(&self) -> &Arc<ChannelPools> {
        &self.pools
    }

    /// Which fleet a job currently lives on.
    pub fn fleet_of(&self, gid: GlobalJobId) -> Option<usize> {
        self.placement(gid).map(|p| p.fleet)
    }

    /// Hash-based placement with the load-aware override (exposed so
    /// tests can predict where a submission lands).
    pub fn placement_for(&self, spec: &JobSpec) -> usize {
        let home = (place_hash(&spec.name, spec.seed) % self.fleets.len() as u64) as usize;
        let lightest = (0..self.fleets.len())
            .min_by_key(|&i| self.fleets[i].live_jobs())
            .unwrap_or(home);
        if self.fleets[home].live_jobs() > self.fleets[lightest].live_jobs() + 1 {
            lightest
        } else {
            home
        }
    }

    /// Validate, place and admit a job on its (possibly rebalanced) home
    /// fleet. Admission failures count toward the cluster's `rejected`
    /// breakdown.
    pub fn submit(&mut self, spec: JobSpec) -> Result<GlobalJobId, ServeError> {
        let fleet = self.placement_for(&spec);
        match self.fleets[fleet].submit(spec) {
            Ok(local) => {
                let gid = self.next_gid;
                self.next_gid += 1;
                self.placements.push(Placement { gid, fleet, local });
                Ok(gid)
            }
            Err(e) => {
                self.rejected += 1;
                Err(e)
            }
        }
    }

    /// Run one cluster round: every member fleet runs one fleet round,
    /// each on its own scoped thread (fleets share no mutable state, so
    /// this is trace-neutral at any interleaving). Returns the total
    /// number of jobs granted an engine round.
    pub fn run_round(&mut self) -> usize {
        let granted = if self.fleets.len() == 1 {
            self.fleets[0].run_round()
        } else {
            std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .fleets
                    .iter_mut()
                    .map(|f| s.spawn(move || f.run_round()))
                    .collect();
                handles.into_iter().map(|h| h.join().expect("fleet thread panicked")).sum()
            })
        };
        self.rounds += 1;
        granted
    }

    /// Run cluster rounds until no job is live anywhere or
    /// `max_rounds` have executed; returns how many ran.
    pub fn run(&mut self, max_rounds: usize) -> usize {
        let mut ran = 0;
        while ran < max_rounds && self.fleets.iter().any(|f| f.live_jobs() > 0) {
            self.run_round();
            ran += 1;
        }
        ran
    }

    /// Move a live (`Running`/`Paused`) job to `to_fleet`: drain its
    /// grant (the move happens between fleet rounds), snapshot with the
    /// scheduler trailer, restore into the target and evict the source
    /// copy. The global id is stable across the move; the job's trace,
    /// banked deficit and adaptive rung continue exactly where they
    /// were.
    pub fn migrate(&mut self, gid: GlobalJobId, to_fleet: usize) -> Result<(), ServeError> {
        if to_fleet >= self.fleets.len() {
            return Err(ServeError::Snapshot(format!(
                "no fleet {to_fleet} in a {}-fleet cluster",
                self.fleets.len()
            )));
        }
        let p = *self.placement(gid).ok_or(ServeError::UnknownJob(gid))?;
        if p.fleet == to_fleet {
            return Ok(());
        }
        let was_paused = self.fleets[p.fleet].state(p.local) == Some(JobState::Paused);
        let snap = self.fleets[p.fleet].checkpoint(p.local)?;
        let new_local = self.fleets[to_fleet]
            .restore(&snap)
            .map_err(|e| ServeError::Snapshot(e.to_string()))?;
        if was_paused {
            // restore() admits as Running; re-park to preserve lifecycle.
            self.fleets[to_fleet].pause(new_local)?;
        }
        self.fleets[p.fleet].evict(p.local)?;
        let entry = self.placement_mut(gid).expect("placement vanished mid-migration");
        entry.fleet = to_fleet;
        entry.local = new_local;
        self.migrated += 1;
        Ok(())
    }

    /// A job's lifecycle state.
    pub fn state(&self, gid: GlobalJobId) -> Option<JobState> {
        let p = self.placement(gid)?;
        self.fleets[p.fleet].state(p.local)
    }

    /// Read access to a job (trace, spec, progress).
    pub fn job(&self, gid: GlobalJobId) -> Option<&Job> {
        let p = self.placement(gid)?;
        self.fleets[p.fleet].job(p.local)
    }

    /// A job's banked DRR deficit (invariant checks / debugging).
    pub fn deficit_bits(&self, gid: GlobalJobId) -> Option<u64> {
        let p = self.placement(gid)?;
        self.fleets[p.fleet].deficit_bits(p.local)
    }

    /// Park a running job.
    pub fn pause(&mut self, gid: GlobalJobId) -> Result<(), ServeError> {
        let p = *self.placement(gid).ok_or(ServeError::UnknownJob(gid))?;
        self.fleets[p.fleet].pause(p.local)
    }

    /// Unpark a paused job.
    pub fn resume(&mut self, gid: GlobalJobId) -> Result<(), ServeError> {
        let p = *self.placement(gid).ok_or(ServeError::UnknownJob(gid))?;
        self.fleets[p.fleet].resume(p.local)
    }

    /// Terminate a running or paused job (partial trace finalized).
    pub fn cancel(&mut self, gid: GlobalJobId) -> Result<(), ServeError> {
        let p = *self.placement(gid).ok_or(ServeError::UnknownJob(gid))?;
        self.fleets[p.fleet].cancel(p.local)
    }

    /// Cluster rounds executed so far.
    pub fn round(&self) -> u64 {
        self.rounds
    }

    /// Jobs currently live (running or paused) across all fleets.
    pub fn queued_jobs(&self) -> u64 {
        self.placements
            .iter()
            .filter(|p| {
                matches!(
                    self.fleets[p.fleet].state(p.local),
                    Some(JobState::Running) | Some(JobState::Paused)
                )
            })
            .count() as u64
    }

    /// The cluster's aggregate accounting: the
    /// served/queued/rejected/migrated tenant breakdown plus per-fleet
    /// snapshots.
    pub fn metrics(&self) -> ClusterMetrics {
        ClusterMetrics {
            cluster_rounds: self.rounds,
            served_jobs: self
                .placements
                .iter()
                .filter(|p| self.fleets[p.fleet].state(p.local) == Some(JobState::Finished))
                .count() as u64,
            queued_jobs: self.queued_jobs(),
            rejected_jobs: self.rejected,
            migrated_jobs: self.migrated,
            served_job_rounds: self.fleets.iter().map(|f| f.metrics().served_job_rounds()).sum(),
            spent_payload_bits: self.fleets.iter().map(|f| f.metrics().spent_payload_bits).sum(),
            fleets: self.fleets.iter().map(|f| f.metrics().clone()).collect(),
        }
    }

    fn placement(&self, gid: GlobalJobId) -> Option<&Placement> {
        self.placements.iter().find(|p| p.gid == gid)
    }

    fn placement_mut(&mut self, gid: GlobalJobId) -> Option<&mut Placement> {
        self.placements.iter_mut().find(|p| p.gid == gid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::registry::CompressorSpec;

    fn spec(name: &str, rounds: usize, seed: u64) -> JobSpec {
        JobSpec::new(name, CompressorSpec::parse("ndsc-dith").unwrap(), 1.0, 16, rounds, seed)
    }

    #[test]
    fn placement_is_stable_and_load_aware() {
        let mut c = FleetCluster::new(4, 1 << 20, Policy::Drr);
        // Same spec always hashes to the same home fleet.
        let s = spec("stable", 8, 7);
        assert_eq!(c.placement_for(&s), c.placement_for(&s));
        // Whatever the hash distribution does, the load-aware override
        // must keep the live counts within its rebalance threshold.
        for i in 0..12 {
            c.submit(spec(&format!("j{i}"), 64, i as u64)).unwrap();
        }
        let live: Vec<usize> = (0..4).map(|i| c.fleet(i).live_jobs()).collect();
        let spread = live.iter().max().unwrap() - live.iter().min().unwrap();
        assert!(spread <= 2, "load-aware placement must keep fleets balanced, got {live:?}");
        assert_eq!(c.queued_jobs(), 12);
    }

    #[test]
    fn rejected_submissions_count_in_the_breakdown() {
        let mut c = FleetCluster::new(2, 10, Policy::Drr);
        // qsgd at R=4, n=16 needs 64 bits/round > the 10-bit budget.
        let bad = JobSpec::new("greedy", CompressorSpec::parse("qsgd").unwrap(), 4.0, 16, 8, 1);
        assert!(matches!(c.submit(bad), Err(ServeError::Infeasible { .. })));
        let m = c.metrics();
        assert_eq!(m.rejected_jobs, 1);
        assert_eq!(m.queued_jobs, 0);
    }

    #[test]
    fn cluster_runs_jobs_to_completion_across_fleets() {
        let mut c = FleetCluster::new(3, 1 << 20, Policy::Drr);
        let gids: Vec<_> =
            (0..6).map(|i| c.submit(spec(&format!("j{i}"), 10, 100 + i as u64)).unwrap()).collect();
        c.run(64);
        for gid in gids {
            assert_eq!(c.state(gid), Some(JobState::Finished));
            let t = c.job(gid).unwrap().trace();
            assert_eq!(t.records.len(), 10);
            assert!(t.final_x.iter().all(|v| v.is_finite()));
        }
        let m = c.metrics();
        assert_eq!(m.served_jobs, 6);
        assert_eq!(m.queued_jobs, 0);
        assert_eq!(m.served_job_rounds, 60);
        assert_eq!(m.fleets.len(), 3);
    }

    #[test]
    fn migrate_is_rejected_for_bad_targets_and_is_idempotent_in_place() {
        let mut c = FleetCluster::new(2, 1 << 20, Policy::Drr);
        let gid = c.submit(spec("m", 20, 5)).unwrap();
        let home = c.fleet_of(gid).unwrap();
        assert!(matches!(c.migrate(gid, 9), Err(ServeError::Snapshot(_))));
        c.migrate(gid, home).unwrap();
        assert_eq!(c.fleet_of(gid), Some(home), "same-fleet migrate is a no-op");
        assert!(matches!(c.migrate(99, 0), Err(ServeError::UnknownJob(99))));
        assert_eq!(c.metrics().migrated_jobs, 0);
    }

    #[test]
    fn migration_preserves_lifecycle_and_counts() {
        let mut c = FleetCluster::new(2, 1 << 20, Policy::Drr);
        let gid = c.submit(spec("mover", 30, 5)).unwrap();
        for _ in 0..4 {
            c.run_round();
        }
        c.pause(gid).unwrap();
        let from = c.fleet_of(gid).unwrap();
        let to = 1 - from;
        c.migrate(gid, to).unwrap();
        assert_eq!(c.fleet_of(gid), Some(to));
        assert_eq!(c.state(gid), Some(JobState::Paused), "paused jobs migrate parked");
        c.resume(gid).unwrap();
        c.run(64);
        assert_eq!(c.state(gid), Some(JobState::Finished));
        assert_eq!(c.job(gid).unwrap().trace().records.len(), 30);
        assert_eq!(c.metrics().migrated_jobs, 1);
    }
}
