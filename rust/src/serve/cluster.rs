//! Multi-fleet serving: partition the tenant population across several
//! coordinator fleets and run their rounds concurrently.
//!
//! A [`FleetCluster`] owns `k` independent [`JobServer`]s, each with its
//! own per-round bit budget, DRR scheduler and job registry. Placement
//! and migration are the only cluster-level decisions; everything about
//! *how* a job's rounds run stays inside its fleet, which is what makes
//! the whole construction trace-neutral:
//!
//! * **Placement** — a submission hashes `(name, seed)` (FNV-1a) onto a
//!   home fleet; a load-aware override reroutes it to the least-loaded
//!   fleet when the home fleet is more than one live job ahead of the
//!   lightest one, so adversarial name distributions cannot pile every
//!   tenant onto one fleet.
//! * **Concurrent rounds, two executors** — [`FleetCluster::run_round`]
//!   is the lockstep barrier (one scoped thread per fleet, joined every
//!   round): one big-n straggler stalls every fleet.
//!   [`FleetCluster::run_epoch`] replaces it with an epoch: every fleet
//!   arbitrates `E` rounds of grants up front (nominal ladder costs
//!   only, so batching is bit-identical — see the fleet docs), then the
//!   granted work executes on a **persistent pool** of per-fleet worker
//!   threads with per-fleet deques and cross-fleet stealing. A worker
//!   that drains its own fleet's deque steals from its neighbours', so
//!   the straggler occupies one worker while the other workers absorb
//!   the rest of the cluster's grants. Jobs are independent and own
//!   their RNG/state, so per-job traces are bit-identical to lockstep
//!   (and to a solo fleet) at any interleaving —
//!   `rust/tests/test_serve.rs` proves both identities.
//! * **Autoscaling** — [`FleetCluster::autoscale`] grows/shrinks the
//!   *active* fleet count between epochs from the queued-jobs pressure
//!   (watermarks in [`crate::coordinator::config`]), rebalancing with
//!   the live-migration path. Inactive fleets idle (their arbitration
//!   is a no-op) and their pool workers steal for the active ones.
//! * **Migration** — [`FleetCluster::migrate`] drains a job's grant,
//!   snapshots it (`KFCKPT01` v2, scheduler trailer included), restores
//!   it into the target fleet and evicts the source copy. Checkpoints
//!   are fleet-independent, so the migrated job's trace continues
//!   bit-for-bit mid-deficit and mid-rung.
//!
//! Worker-thread fan-out inside granted rounds is armed per fleet with
//! the cluster's **maximum** fleet count (never the autoscaled active
//! count — with stealing, up to `max` pool workers can execute grants
//! concurrently), so the never-nest cap
//! ([`crate::coordinator::config::FLEET_MAX_WORKER_THREADS`]) holds
//! across the whole cluster, not per fleet.
//!
//! # The epoch pool's synchronization protocol
//!
//! Work items are **filled before the epoch starts and never pushed
//! mid-epoch**, which degenerates the classic Chase–Lev deque to a
//! fixed buffer with one claim `cursor` and one publish watermark
//! (`bottom`). Both pack a **generation** with their position
//! (`gen << 32 | idx` in one `AtomicU64`): owners and thieves claim by
//! CAS on the cursor, an item is claimable only while the two
//! generations match *and* `idx < len`, and the item is read from the
//! buffer only **after** the CAS is won — never before. The coordinator
//! refills between epochs while workers may still be lagging inside the
//! previous epoch's steal sweep, so refill order is load-bearing:
//!
//! 1. `cursor := (gen+1) << 32` — retire the old generation. The packed
//!    value is fresh (the generation only ever grows), so a stale CAS
//!    from the previous epoch can never succeed again — there is no ABA
//!    window even though every epoch's indices restart at 0. New claims
//!    cannot succeed either: `bottom` still carries the old generation,
//!    so the generations mismatch.
//! 2. rewrite the buffer (plain stores — safe because a worker reads
//!    the buffer only after winning a CAS at matching generations,
//!    impossible until step 4 publishes),
//! 3. `remaining := Σ items` (the completion counter, set **before**
//!    any item becomes claimable so an early steal cannot underflow it),
//! 4. `bottom := (gen+1) << 32 | len` — publish (the SeqCst store
//!    releases the buffer writes to any thief whose load observes it).
//!
//! Why the read-after-CAS is safe: winning a CAS at generation `g`
//! proves the *next* refill has not begun (its step 1 would have bumped
//! the cursor's generation past `g`, and the full 64-bit value never
//! repeats), and it cannot begin until this epoch completes — the
//! coordinator parks on a condvar until `remaining == 0`, and the
//! claimed item has not decremented `remaining` yet. So the buffer is
//! stable, holds generation `g`'s items, and `idx < len == buf.len()`
//! is in bounds. A thief that observes the new cursor and watermark
//! simply joins the new epoch early, which is benign (each item still
//! executes exactly once, and each execution decrements `remaining`
//! exactly once). Completion is signaled by the counter — never by
//! epoch number, which a lagging worker could report stale.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::SeqCst};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::channel::ChannelPools;
use crate::coordinator::config;
use crate::coordinator::metrics::ClusterMetrics;
use crate::serve::fleet::{self, JobId, JobServer, JobState, ServeError, WorkItem};
use crate::serve::plancache::PlanCache;
use crate::serve::job::{Job, JobSpec};
use crate::serve::scheduler::Policy;

/// Cluster-assigned job handle (stable across migrations, unlike the
/// per-fleet [`JobId`] which changes when a job changes fleets).
pub type GlobalJobId = u64;

/// Where a job currently lives.
#[derive(Clone, Copy, Debug)]
struct Placement {
    gid: GlobalJobId,
    fleet: usize,
    local: JobId,
}

/// The multi-fleet job cluster (see the [module docs](self)).
pub struct FleetCluster {
    /// Declared before `fleets` so the pool joins its workers before any
    /// fleet memory its stale work items point into is freed (the
    /// workers are parked by then — this is belt-and-braces).
    pool: Option<EpochPool>,
    fleets: Vec<JobServer>,
    placements: Vec<Placement>,
    pools: Arc<ChannelPools>,
    next_gid: GlobalJobId,
    rounds: u64,
    rejected: u64,
    migrated: u64,
    /// Fleets `0..active_fleets` take new placements; the rest idle
    /// until the autoscaler re-activates them.
    active_fleets: usize,
    autoscale_events: u64,
    /// The cluster-wide codec-plan cache, shared by every member fleet.
    /// Admission of a same-spec tenant, checkpoint restore, and — the
    /// heaviest caller — autoscaler migration all reuse built ladders
    /// through it instead of regrowing frames.
    plan_cache: Arc<PlanCache>,
}

/// FNV-1a over the placement key — stable across processes (no
/// `DefaultHasher` seed dependence), so a resubmitted spec lands on the
/// same home fleet.
fn place_hash(name: &str, seed: u64) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in name.as_bytes().iter().chain(seed.to_le_bytes().iter()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One fleet's work buffer for an epoch: a fill-before-start Chase–Lev
/// degenerate (see the [module docs](self) for the refill protocol that
/// makes coordinator refills safe against lagging thieves).
struct Deque {
    buf: UnsafeCell<Vec<WorkItem>>,
    /// Claim cursor: `generation << 32 | next unclaimed index`. Owners
    /// and thieves CAS it; the coordinator bumps the generation at each
    /// refill, so the packed value never repeats and a stale CAS from a
    /// previous epoch can never succeed (no ABA).
    cursor: AtomicU64,
    /// Publish watermark: `generation << 32 | len`. Items are claimable
    /// only while the cursor's generation matches. Written only by the
    /// coordinator between epochs.
    bottom: AtomicU64,
}

/// Low half of a packed cursor/watermark: the index (or length).
const DEQUE_IDX_MASK: u64 = 0xffff_ffff;

// SAFETY: `buf` is written only by the coordinator while the current
// generation is unpublished (`bottom` carries the previous one), and
// read by workers only at indices they won the claim CAS for at
// matching generations, after the publish store released the buffer
// writes — the module-docs protocol.
unsafe impl Sync for Deque {}

impl Deque {
    fn new() -> Self {
        Deque {
            buf: UnsafeCell::new(Vec::new()),
            cursor: AtomicU64::new(0),
            bottom: AtomicU64::new(0),
        }
    }

    /// Claim the next unexecuted item, or `None` if this deque is
    /// drained (or mid-refill: the generations mismatch until the
    /// coordinator publishes).
    fn claim(&self) -> Option<WorkItem> {
        loop {
            let c = self.cursor.load(SeqCst);
            let b = self.bottom.load(SeqCst);
            if (c >> 32) != (b >> 32) || (c & DEQUE_IDX_MASK) >= (b & DEQUE_IDX_MASK) {
                return None;
            }
            if self.cursor.compare_exchange(c, c + 1, SeqCst, SeqCst).is_ok() {
                // SAFETY: the won CAS proves the next refill has not
                // begun (it would have bumped the generation, and the
                // packed value never repeats) and it cannot begin until
                // this item decrements `remaining`, so the buffer is
                // stable and `idx < len == buf.len()` is in bounds.
                return Some(unsafe { (*self.buf.get())[(c & DEQUE_IDX_MASK) as usize] });
            }
        }
    }

    /// Refill steps 1–2 (module docs): retire the old generation — after
    /// this no stale or new claim can succeed until [`Deque::publish`] —
    /// and hand the coordinator the buffer to rewrite.
    ///
    /// # Safety
    /// Single writer only (the coordinator between epochs); must be
    /// followed by [`Deque::publish`] before items are expected to run.
    #[allow(clippy::mut_from_ref)]
    unsafe fn begin_refill(&self) -> &mut Vec<WorkItem> {
        let gen = (self.cursor.load(SeqCst) >> 32) + 1;
        self.cursor.store(gen << 32, SeqCst);
        unsafe { &mut *self.buf.get() }
    }

    /// Refill step 4: publish the rewritten buffer under the generation
    /// [`Deque::begin_refill`] installed, making its items claimable.
    fn publish(&self) {
        // No claim can have touched the cursor since `begin_refill`
        // (generation mismatch), so it still reads `gen << 32`.
        let gen = self.cursor.load(SeqCst) >> 32;
        // SAFETY: still single-writer; only the length is read.
        let n = unsafe { (*self.buf.get()).len() } as u64;
        debug_assert!(n <= DEQUE_IDX_MASK, "epoch item count must fit the 32-bit index half");
        self.bottom.store((gen << 32) | n, SeqCst);
    }
}

/// State the pool's condvars guard.
struct PoolState {
    /// Monotonic epoch counter; workers sweep once per increment.
    epoch: u64,
    shutdown: bool,
}

/// Everything the coordinator and the pool workers share.
struct PoolShared {
    deques: Vec<Deque>,
    /// Unexecuted items in the current epoch; the worker that takes it
    /// to zero signals `done`.
    remaining: AtomicUsize,
    /// Cumulative cross-fleet steals (surfaced in [`ClusterMetrics`]).
    steals: AtomicU64,
    state: Mutex<PoolState>,
    start: Condvar,
    done_lock: Mutex<()>,
    done: Condvar,
    pools: Arc<ChannelPools>,
}

/// The persistent work-stealing pool: one worker thread per member
/// fleet, spawned lazily at the first multi-fleet epoch and joined on
/// drop. Between epochs the workers park on `start`; the per-round
/// thread spawn/join the lockstep barrier pays is replaced by one
/// condvar wake per epoch.
struct EpochPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl EpochPool {
    fn spawn(workers: usize, pools: Arc<ChannelPools>) -> Self {
        let shared = Arc::new(PoolShared {
            deques: (0..workers).map(|_| Deque::new()).collect(),
            remaining: AtomicUsize::new(0),
            steals: AtomicU64::new(0),
            state: Mutex::new(PoolState { epoch: 0, shutdown: false }),
            start: Condvar::new(),
            done_lock: Mutex::new(()),
            done: Condvar::new(),
            pools,
        });
        let handles = (0..workers)
            .map(|me| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("kf-epoch-{me}"))
                    .spawn(move || worker_loop(me, shared))
                    .expect("spawn epoch pool worker")
            })
            .collect();
        EpochPool { shared, handles }
    }
}

impl Drop for EpochPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.start.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A pool worker: wake per epoch, drain the own fleet's deque first
/// (locality — the fleet's jobs stay on the fleet's worker when nobody
/// is behind), then sweep the other deques stealing whatever is left.
/// One sweep suffices because no items appear mid-epoch: a deque that
/// reads empty stays empty, and every claimed item is executed by its
/// claimant.
fn worker_loop(me: usize, shared: Arc<PoolShared>) {
    let mut seen = 0u64;
    loop {
        {
            let mut st = shared.state.lock().unwrap();
            while st.epoch <= seen && !st.shutdown {
                st = shared.start.wait(st).unwrap();
            }
            if st.shutdown {
                return;
            }
            seen = st.epoch;
        }
        let k = shared.deques.len();
        for offset in 0..k {
            let d = &shared.deques[(me + offset) % k];
            while let Some(item) = d.claim() {
                if offset != 0 {
                    shared.steals.fetch_add(1, SeqCst);
                }
                // SAFETY: the claim CAS gives this thread exclusive
                // ownership of the item's job and group; the coordinator
                // parks until `remaining == 0`, so the pointers stay live.
                unsafe { fleet::execute_item(item, &shared.pools) };
                if shared.remaining.fetch_sub(1, SeqCst) == 1 {
                    // Last item in the epoch: wake the coordinator. Taking
                    // the lock orders the notify after the coordinator's
                    // predicate check, so the wake cannot be lost.
                    let _guard = shared.done_lock.lock().unwrap();
                    shared.done.notify_all();
                }
            }
        }
    }
}

impl FleetCluster {
    /// A cluster of `fleets` member fleets, each offering
    /// `budget_bits_per_fleet_round` payload bits per round under
    /// `policy`. All fleets share one recycled buffer pool, and each is
    /// armed for worker-thread fan-out with the cluster's fleet count
    /// (the never-nest share).
    pub fn new(fleets: usize, budget_bits_per_fleet_round: usize, policy: Policy) -> Self {
        let k = fleets.max(1);
        let pools = Arc::new(ChannelPools::new(8));
        let plan_cache = Arc::new(PlanCache::with_default_cap());
        let fleets = (0..k)
            .map(|_| {
                let mut f =
                    JobServer::with_pools(budget_bits_per_fleet_round, policy, pools.clone());
                f.enable_fanout(k);
                f.set_plan_cache(Some(Arc::clone(&plan_cache)));
                f
            })
            .collect();
        FleetCluster {
            pool: None,
            fleets,
            placements: Vec::new(),
            pools,
            next_gid: 0,
            rounds: 0,
            rejected: 0,
            migrated: 0,
            active_fleets: k,
            autoscale_events: 0,
            plan_cache,
        }
    }

    /// The cluster-wide codec-plan cache (hit/miss/resident gauges).
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plan_cache
    }

    /// Enable or disable plan-cache consultation across every member
    /// fleet. Off clears each fleet's cache handle so admission,
    /// restore and migration build ladders fresh — the uncached
    /// baseline the bit-identity tests and `bench_serve` ratio rows
    /// compare against. The cluster's cache (and its counters) survive
    /// the toggle; re-enabling re-installs the same shared instance.
    pub fn set_plan_cache_enabled(&mut self, on: bool) {
        for f in &mut self.fleets {
            f.set_plan_cache(if on { Some(Arc::clone(&self.plan_cache)) } else { None });
        }
    }

    /// Toggle batched-panel emission on every member fleet (on by
    /// default; see [`JobServer::set_epoch_batching`]).
    pub fn set_epoch_batching(&mut self, on: bool) {
        for f in &mut self.fleets {
            f.set_epoch_batching(on);
        }
    }

    /// Fleets currently taking placements (the autoscaler moves this
    /// between 1 and [`FleetCluster::fleet_count`]).
    pub fn active_fleets(&self) -> usize {
        self.active_fleets
    }

    /// Times the autoscaler resized the active fleet set.
    pub fn autoscale_events(&self) -> u64 {
        self.autoscale_events
    }

    /// Cumulative grants executed by a pool worker for a fleet other
    /// than its own (0 until the first multi-fleet epoch).
    pub fn stolen_grants(&self) -> u64 {
        self.pool.as_ref().map(|p| p.shared.steals.load(SeqCst)).unwrap_or(0)
    }

    /// Member fleet count.
    pub fn fleet_count(&self) -> usize {
        self.fleets.len()
    }

    /// Read access to a member fleet (metrics, budget).
    pub fn fleet(&self, i: usize) -> &JobServer {
        &self.fleets[i]
    }

    /// The cluster-shared recycled buffer pool.
    pub fn pools(&self) -> &Arc<ChannelPools> {
        &self.pools
    }

    /// Which fleet a job currently lives on.
    pub fn fleet_of(&self, gid: GlobalJobId) -> Option<usize> {
        self.placement(gid).map(|p| p.fleet)
    }

    /// Hash-based placement with the load-aware override (exposed so
    /// tests can predict where a submission lands).
    pub fn placement_for(&self, spec: &JobSpec) -> usize {
        let home = (place_hash(&spec.name, spec.seed) % self.active_fleets as u64) as usize;
        let lightest = (0..self.active_fleets)
            .min_by_key(|&i| self.fleets[i].live_jobs())
            .unwrap_or(home);
        if self.fleets[home].live_jobs() > self.fleets[lightest].live_jobs() + 1 {
            lightest
        } else {
            home
        }
    }

    /// Validate, place and admit a job on its (possibly rebalanced) home
    /// fleet. Admission failures count toward the cluster's `rejected`
    /// breakdown.
    pub fn submit(&mut self, spec: JobSpec) -> Result<GlobalJobId, ServeError> {
        let fleet = self.placement_for(&spec);
        match self.fleets[fleet].submit(spec) {
            Ok(local) => {
                let gid = self.next_gid;
                self.next_gid += 1;
                self.placements.push(Placement { gid, fleet, local });
                Ok(gid)
            }
            Err(e) => {
                self.rejected += 1;
                Err(e)
            }
        }
    }

    /// Run one cluster round: every member fleet runs one fleet round,
    /// each on its own scoped thread (fleets share no mutable state, so
    /// this is trace-neutral at any interleaving). Returns the total
    /// number of jobs granted an engine round.
    pub fn run_round(&mut self) -> usize {
        let granted = if self.fleets.len() == 1 {
            self.fleets[0].run_round()
        } else {
            std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .fleets
                    .iter_mut()
                    .map(|f| s.spawn(move || f.run_round()))
                    .collect();
                handles.into_iter().map(|h| h.join().expect("fleet thread panicked")).sum()
            })
        };
        self.rounds += 1;
        granted
    }

    /// Run cluster rounds until no job is live anywhere or
    /// `max_rounds` have executed; returns how many ran.
    pub fn run(&mut self, max_rounds: usize) -> usize {
        let mut ran = 0;
        while ran < max_rounds && self.fleets.iter().any(|f| f.live_jobs() > 0) {
            self.run_round();
            ran += 1;
        }
        ran
    }

    /// Run `rounds` cluster rounds as one epoch on the work-stealing
    /// pool: every fleet arbitrates all `rounds` grants at the barrier
    /// (bit-identical to `rounds` lockstep rounds — the grant pass uses
    /// nominal ladder costs only), the granted work executes with
    /// cross-fleet stealing, and the accounting pass folds measured bits
    /// back in deterministic slot order. Returns total jobs granted an
    /// engine round. A single-fleet cluster skips the pool entirely.
    pub fn run_epoch(&mut self, rounds: usize) -> usize {
        if rounds == 0 {
            return 0;
        }
        let granted = if self.fleets.len() == 1 {
            self.fleets[0].run_epoch(rounds)
        } else {
            for f in &mut self.fleets {
                f.compute_epoch_grants(rounds);
            }
            let pool = self
                .pool
                .get_or_insert_with(|| EpochPool::spawn(self.fleets.len(), self.pools.clone()));
            let shared = &pool.shared;
            // Refill under a fresh generation first; items become
            // claimable only after `remaining` is set and every deque
            // publishes, per the module-docs protocol.
            let mut total_items = 0usize;
            for (i, f) in self.fleets.iter_mut().enumerate() {
                let d = &shared.deques[i];
                // SAFETY: the coordinator is the single refill writer,
                // and `publish` follows below before the epoch starts.
                let buf = unsafe { d.begin_refill() };
                buf.clear();
                f.collect_epoch_items(buf);
                total_items += buf.len();
            }
            if total_items > 0 {
                shared.remaining.store(total_items, SeqCst);
                for d in &shared.deques {
                    d.publish();
                }
                {
                    let mut st = shared.state.lock().unwrap();
                    st.epoch += 1;
                    shared.start.notify_all();
                }
                let mut guard = shared.done_lock.lock().unwrap();
                while shared.remaining.load(SeqCst) != 0 {
                    guard = shared.done.wait(guard).unwrap();
                }
            }
            self.fleets.iter_mut().map(|f| f.apply_epoch()).sum()
        };
        self.rounds += rounds as u64;
        granted
    }

    /// Run epochs of `epoch_len` cluster rounds until no job is live or
    /// `max_rounds` have executed; returns how many ran.
    pub fn run_async(&mut self, max_rounds: usize, epoch_len: usize) -> usize {
        let epoch = epoch_len.max(1);
        let mut ran = 0;
        while ran < max_rounds && self.fleets.iter().any(|f| f.live_jobs() > 0) {
            let chunk = epoch.min(max_rounds - ran);
            self.run_epoch(chunk);
            ran += chunk;
        }
        ran
    }

    /// [`FleetCluster::run_async`] with an [`FleetCluster::autoscale`]
    /// pass between epochs.
    pub fn run_autoscaled(
        &mut self,
        max_rounds: usize,
        epoch_len: usize,
    ) -> Result<usize, ServeError> {
        let epoch = epoch_len.max(1);
        let mut ran = 0;
        while ran < max_rounds && self.fleets.iter().any(|f| f.live_jobs() > 0) {
            self.autoscale()?;
            let chunk = epoch.min(max_rounds - ran);
            self.run_epoch(chunk);
            ran += chunk;
        }
        ran
    }

    /// One autoscaler step: compare queued-jobs pressure against the
    /// per-active-fleet watermarks and grow or shrink the active fleet
    /// set by one, rebalancing live jobs over the migration path (which
    /// preserves traces bit-for-bit). Returns whether a resize happened.
    ///
    /// * **Grow** (`queued ≥ HIGH × active`, room left): rebalance jobs
    ///   off the heaviest active fleets onto the next fleet until it is
    ///   within one job of them, then activate it. The resize commits
    ///   only after the rebalance succeeds, so an `Err` mid-migration
    ///   leaves the active set and the event counter untouched (any
    ///   already-completed migrations are trace-preserving no-ops to
    ///   retry from).
    /// * **Shrink** (`queued ≤ LOW × active`, more than one active):
    ///   drain the last active fleet onto the lightest survivors and
    ///   deactivate it.
    ///
    /// Both branches balance on [`JobServer::lodged_jobs`]
    /// (Running + Paused) — the same population the migration candidate
    /// filter and [`FleetCluster::queued_jobs`] count.
    pub fn autoscale(&mut self) -> Result<bool, ServeError> {
        let queued = self.queued_jobs() as usize;
        let active = self.active_fleets;
        if active < self.fleets.len() && queued >= config::AUTOSCALE_HIGH_QUEUED_PER_FLEET * active
        {
            let newcomer = active;
            loop {
                let heaviest = (0..newcomer)
                    .max_by_key(|&i| self.fleets[i].lodged_jobs())
                    .expect("grow always has an active fleet");
                if self.fleets[heaviest].lodged_jobs() <= self.fleets[newcomer].lodged_jobs() + 1 {
                    break;
                }
                let gid = self
                    .placements
                    .iter()
                    .find(|p| {
                        p.fleet == heaviest
                            && matches!(
                                self.fleets[p.fleet].state(p.local),
                                Some(JobState::Running) | Some(JobState::Paused)
                            )
                    })
                    .map(|p| p.gid)
                    .expect("heaviest fleet reported lodged jobs");
                self.migrate(gid, newcomer)?;
            }
            self.active_fleets = active + 1;
            self.autoscale_events += 1;
            return Ok(true);
        }
        if active > 1 && queued <= config::AUTOSCALE_LOW_QUEUED_PER_FLEET * active {
            let retiring = active - 1;
            while let Some(gid) = self
                .placements
                .iter()
                .find(|p| {
                    p.fleet == retiring
                        && matches!(
                            self.fleets[p.fleet].state(p.local),
                            Some(JobState::Running) | Some(JobState::Paused)
                        )
                })
                .map(|p| p.gid)
            {
                let lightest = (0..retiring)
                    .min_by_key(|&i| self.fleets[i].lodged_jobs())
                    .expect("shrink keeps at least one active fleet");
                self.migrate(gid, lightest)?;
            }
            self.active_fleets = retiring;
            self.autoscale_events += 1;
            return Ok(true);
        }
        Ok(false)
    }

    /// Move a live (`Running`/`Paused`) job to `to_fleet`: drain its
    /// grant (the move happens between fleet rounds), snapshot with the
    /// scheduler trailer, restore into the target and evict the source
    /// copy. The global id is stable across the move; the job's trace,
    /// banked deficit and adaptive rung continue exactly where they
    /// were.
    pub fn migrate(&mut self, gid: GlobalJobId, to_fleet: usize) -> Result<(), ServeError> {
        if to_fleet >= self.fleets.len() {
            return Err(ServeError::Snapshot(format!(
                "no fleet {to_fleet} in a {}-fleet cluster",
                self.fleets.len()
            )));
        }
        let p = *self.placement(gid).ok_or(ServeError::UnknownJob(gid))?;
        if p.fleet == to_fleet {
            return Ok(());
        }
        let was_paused = self.fleets[p.fleet].state(p.local) == Some(JobState::Paused);
        let snap = self.fleets[p.fleet].checkpoint(p.local)?;
        let new_local = self.fleets[to_fleet]
            .restore(&snap)
            .map_err(|e| ServeError::Snapshot(e.to_string()))?;
        if was_paused {
            // restore() admits as Running; re-park to preserve lifecycle.
            self.fleets[to_fleet].pause(new_local)?;
        }
        self.fleets[p.fleet].evict(p.local)?;
        let entry = self.placement_mut(gid).expect("placement vanished mid-migration");
        entry.fleet = to_fleet;
        entry.local = new_local;
        self.migrated += 1;
        Ok(())
    }

    /// A job's lifecycle state.
    pub fn state(&self, gid: GlobalJobId) -> Option<JobState> {
        let p = self.placement(gid)?;
        self.fleets[p.fleet].state(p.local)
    }

    /// Read access to a job (trace, spec, progress).
    pub fn job(&self, gid: GlobalJobId) -> Option<&Job> {
        let p = self.placement(gid)?;
        self.fleets[p.fleet].job(p.local)
    }

    /// A job's banked DRR deficit (invariant checks / debugging).
    pub fn deficit_bits(&self, gid: GlobalJobId) -> Option<u64> {
        let p = self.placement(gid)?;
        self.fleets[p.fleet].deficit_bits(p.local)
    }

    /// Park a running job.
    pub fn pause(&mut self, gid: GlobalJobId) -> Result<(), ServeError> {
        let p = *self.placement(gid).ok_or(ServeError::UnknownJob(gid))?;
        self.fleets[p.fleet].pause(p.local)
    }

    /// Unpark a paused job.
    pub fn resume(&mut self, gid: GlobalJobId) -> Result<(), ServeError> {
        let p = *self.placement(gid).ok_or(ServeError::UnknownJob(gid))?;
        self.fleets[p.fleet].resume(p.local)
    }

    /// Terminate a running or paused job (partial trace finalized).
    pub fn cancel(&mut self, gid: GlobalJobId) -> Result<(), ServeError> {
        let p = *self.placement(gid).ok_or(ServeError::UnknownJob(gid))?;
        self.fleets[p.fleet].cancel(p.local)
    }

    /// Cluster rounds executed so far.
    pub fn round(&self) -> u64 {
        self.rounds
    }

    /// Jobs currently live (running or paused) across all fleets.
    pub fn queued_jobs(&self) -> u64 {
        self.placements
            .iter()
            .filter(|p| {
                matches!(
                    self.fleets[p.fleet].state(p.local),
                    Some(JobState::Running) | Some(JobState::Paused)
                )
            })
            .count() as u64
    }

    /// The cluster's aggregate accounting: the
    /// served/queued/rejected/migrated tenant breakdown plus per-fleet
    /// snapshots.
    pub fn metrics(&self) -> ClusterMetrics {
        ClusterMetrics {
            cluster_rounds: self.rounds,
            served_jobs: self
                .placements
                .iter()
                .filter(|p| self.fleets[p.fleet].state(p.local) == Some(JobState::Finished))
                .count() as u64,
            queued_jobs: self.queued_jobs(),
            rejected_jobs: self.rejected,
            migrated_jobs: self.migrated,
            stolen_grants: self.stolen_grants(),
            active_fleets: self.active_fleets as u64,
            autoscale_events: self.autoscale_events,
            served_job_rounds: self.fleets.iter().map(|f| f.metrics().served_job_rounds()).sum(),
            spent_payload_bits: self.fleets.iter().map(|f| f.metrics().spent_payload_bits).sum(),
            plan_cache_hits: self.plan_cache.hits(),
            plan_cache_misses: self.plan_cache.misses(),
            plan_cache_resident_bytes: self.plan_cache.resident_bytes(),
            fleets: self.fleets.iter().map(|f| f.metrics().clone()).collect(),
        }
    }

    fn placement(&self, gid: GlobalJobId) -> Option<&Placement> {
        self.placements.iter().find(|p| p.gid == gid)
    }

    fn placement_mut(&mut self, gid: GlobalJobId) -> Option<&mut Placement> {
        self.placements.iter_mut().find(|p| p.gid == gid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::registry::CompressorSpec;

    fn spec(name: &str, rounds: usize, seed: u64) -> JobSpec {
        JobSpec::new(name, CompressorSpec::parse("ndsc-dith").unwrap(), 1.0, 16, rounds, seed)
    }

    #[test]
    fn placement_is_stable_and_load_aware() {
        let mut c = FleetCluster::new(4, 1 << 20, Policy::Drr);
        // Same spec always hashes to the same home fleet.
        let s = spec("stable", 8, 7);
        assert_eq!(c.placement_for(&s), c.placement_for(&s));
        // Whatever the hash distribution does, the load-aware override
        // must keep the live counts within its rebalance threshold.
        for i in 0..12 {
            c.submit(spec(&format!("j{i}"), 64, i as u64)).unwrap();
        }
        let live: Vec<usize> = (0..4).map(|i| c.fleet(i).live_jobs()).collect();
        let spread = live.iter().max().unwrap() - live.iter().min().unwrap();
        assert!(spread <= 2, "load-aware placement must keep fleets balanced, got {live:?}");
        assert_eq!(c.queued_jobs(), 12);
    }

    #[test]
    fn rejected_submissions_count_in_the_breakdown() {
        let mut c = FleetCluster::new(2, 10, Policy::Drr);
        // qsgd at R=4, n=16 needs 64 bits/round > the 10-bit budget.
        let bad = JobSpec::new("greedy", CompressorSpec::parse("qsgd").unwrap(), 4.0, 16, 8, 1);
        assert!(matches!(c.submit(bad), Err(ServeError::Infeasible { .. })));
        let m = c.metrics();
        assert_eq!(m.rejected_jobs, 1);
        assert_eq!(m.queued_jobs, 0);
    }

    #[test]
    fn cluster_runs_jobs_to_completion_across_fleets() {
        let mut c = FleetCluster::new(3, 1 << 20, Policy::Drr);
        let gids: Vec<_> =
            (0..6).map(|i| c.submit(spec(&format!("j{i}"), 10, 100 + i as u64)).unwrap()).collect();
        c.run(64);
        for gid in gids {
            assert_eq!(c.state(gid), Some(JobState::Finished));
            let t = c.job(gid).unwrap().trace();
            assert_eq!(t.records.len(), 10);
            assert!(t.final_x.iter().all(|v| v.is_finite()));
        }
        let m = c.metrics();
        assert_eq!(m.served_jobs, 6);
        assert_eq!(m.queued_jobs, 0);
        assert_eq!(m.served_job_rounds, 60);
        assert_eq!(m.fleets.len(), 3);
    }

    #[test]
    fn epoch_executor_matches_lockstep_cluster() {
        // Same tenants, same submission order: R lockstep cluster rounds
        // vs. the same R rounds in ragged work-stealing epochs must agree
        // on every lifecycle state, trace, and accounting row.
        let build = || {
            let mut c = FleetCluster::new(4, 256, Policy::DrrAdaptive);
            let gids: Vec<_> = (0..8)
                .map(|i| c.submit(spec(&format!("t{i}"), 12, 40 + i as u64)).unwrap())
                .collect();
            (c, gids)
        };
        let (mut lockstep, gids) = build();
        let (mut epoch, gids2) = build();
        assert_eq!(gids, gids2);
        for _ in 0..24 {
            lockstep.run_round();
        }
        for chunk in [1usize, 5, 10, 8] {
            epoch.run_epoch(chunk);
        }
        assert_eq!(lockstep.round(), epoch.round());
        for &gid in &gids {
            assert_eq!(lockstep.state(gid), epoch.state(gid), "state diverged for {gid}");
            assert_eq!(
                lockstep.deficit_bits(gid),
                epoch.deficit_bits(gid),
                "deficit diverged for {gid}"
            );
            let (a, b) = (lockstep.job(gid).unwrap(), epoch.job(gid).unwrap());
            assert_eq!(a.rounds_done(), b.rounds_done(), "rounds diverged for {gid}");
            assert_eq!(
                a.trace().total_payload_bits,
                b.trace().total_payload_bits,
                "payload diverged for {gid}"
            );
            assert_eq!(
                a.trace().final_x,
                b.trace().final_x,
                "final iterate diverged for {gid}"
            );
        }
        let (ma, mb) = (lockstep.metrics(), epoch.metrics());
        assert_eq!(ma.served_job_rounds, mb.served_job_rounds);
        assert_eq!(ma.spent_payload_bits, mb.spent_payload_bits);
    }

    #[test]
    fn autoscaler_tracks_queue_pressure_and_preserves_jobs() {
        let mut c = FleetCluster::new(4, 1 << 20, Policy::Drr);
        assert_eq!(c.active_fleets(), 4);
        // Two live jobs on four fleets is under the low watermark:
        // repeated passes shrink to the floor of one active fleet.
        let a = c.submit(spec("lo-a", 40, 1)).unwrap();
        let b = c.submit(spec("lo-b", 40, 2)).unwrap();
        while c.autoscale().unwrap() {}
        assert_eq!(c.active_fleets(), 1, "low pressure must shrink to the floor");
        assert_eq!(c.fleet_of(a), Some(0));
        assert_eq!(c.fleet_of(b), Some(0));
        // Pile on tenants until the high watermark trips: the autoscaler
        // re-activates fleets and rebalances onto them.
        let more: Vec<_> =
            (0..14).map(|i| c.submit(spec(&format!("hi{i}"), 40, 50 + i as u64)).unwrap()).collect();
        c.autoscale().unwrap();
        assert_eq!(c.active_fleets(), 2, "high pressure must grow");
        let m = c.metrics();
        assert!(m.autoscale_events >= 4, "3 shrinks + 1 grow, got {}", m.autoscale_events);
        assert!(m.migrated_jobs >= 1, "rebalance uses the migration path");
        assert_eq!(m.active_fleets, 2);
        // Everything still runs to completion through autoscaled epochs.
        c.run_autoscaled(4096, 8).unwrap();
        for gid in [a, b].into_iter().chain(more) {
            assert_eq!(c.state(gid), Some(JobState::Finished), "job {gid} lost in autoscaling");
            assert_eq!(c.job(gid).unwrap().trace().records.len(), 40);
        }
    }

    #[test]
    fn deque_generations_gate_claims_across_refills() {
        // Audit pin for the PR 8 refill protocol: a stale or mid-refill
        // deque must never surrender an item, and each published
        // generation's items are claimable exactly once, in order.
        let dummy = |k: usize| WorkItem {
            slots: std::ptr::null_mut(),
            groups: std::ptr::null_mut(),
            n_groups: k,
        };
        let d = Deque::new();
        // Nothing refilled yet: generation 0 at length 0.
        assert!(d.claim().is_none());
        // SAFETY: single-threaded test — one writer, publish follows.
        let buf = unsafe { d.begin_refill() };
        buf.clear();
        buf.extend([dummy(1), dummy(2), dummy(3)]);
        // Refilled but unpublished: the cursor generation is ahead of
        // the watermark's, so nothing is claimable. This is also the
        // exact state an all-idle epoch leaves behind (`run_epoch`
        // skips publish when no fleet emitted items).
        assert!(d.claim().is_none(), "unpublished refills must not leak items");
        d.publish();
        assert_eq!(d.claim().map(|w| w.n_groups), Some(1));
        // Refill mid-generation, as the coordinator does between
        // epochs: the unclaimed remainder dies with its generation.
        let buf = unsafe { d.begin_refill() };
        buf.clear();
        buf.extend([dummy(7), dummy(8)]);
        assert!(d.claim().is_none(), "retired generations must not serve claims");
        d.publish();
        assert_eq!(d.claim().map(|w| w.n_groups), Some(7));
        assert_eq!(d.claim().map(|w| w.n_groups), Some(8));
        assert!(d.claim().is_none(), "a drained deque must stay drained");
    }

    #[test]
    fn all_paused_epochs_interleave_without_perturbing_traces() {
        // An epoch where every tenant is paused grants nothing, so the
        // executor never publishes and each deque's cursor generation
        // stays ahead of its watermark. The next epoch must recover,
        // and the active rounds must stay bit-identical to lockstep.
        let build = || {
            let mut c = FleetCluster::new(4, 256, Policy::Drr);
            let gids: Vec<_> = (0..8)
                .map(|i| c.submit(spec(&format!("z{i}"), 12, 70 + i as u64)).unwrap())
                .collect();
            (c, gids)
        };
        let (mut lockstep, gids) = build();
        let (mut epoch, _) = build();
        for _ in 0..16 {
            lockstep.run_round();
        }
        epoch.run_epoch(4);
        for &g in &gids {
            epoch.pause(g).unwrap();
        }
        assert_eq!(epoch.run_epoch(3), 0, "an all-paused epoch grants nothing");
        for &g in &gids {
            epoch.resume(g).unwrap();
        }
        epoch.run_epoch(12);
        // 4 + 12 active epoch rounds ≡ 16 lockstep rounds; the paused
        // rounds freeze scheduler state rather than perturbing it.
        for &gid in &gids {
            assert_eq!(lockstep.state(gid), epoch.state(gid), "state diverged for {gid}");
            assert_eq!(
                lockstep.deficit_bits(gid),
                epoch.deficit_bits(gid),
                "deficit diverged for {gid}"
            );
            let (a, b) = (lockstep.job(gid).unwrap(), epoch.job(gid).unwrap());
            assert_eq!(a.rounds_done(), b.rounds_done(), "rounds diverged for {gid}");
            assert_eq!(a.trace().final_x, b.trace().final_x, "iterate diverged for {gid}");
        }
        let (ma, mb) = (lockstep.metrics(), epoch.metrics());
        assert_eq!(ma.served_job_rounds, mb.served_job_rounds);
        assert_eq!(ma.spent_payload_bits, mb.spent_payload_bits);
    }

    #[test]
    fn autoscale_grow_commits_state_before_rebalance_is_visible() {
        // Audit pin for the PR 8 commit ordering: by the time
        // `autoscale` returns, the resize is committed (active set,
        // event counter) and the rebalance it triggered has already
        // evened lodged jobs over the *new* active set.
        let mut c = FleetCluster::new(4, 1 << 20, Policy::Drr);
        c.submit(spec("seed-a", 64, 3)).unwrap();
        while c.autoscale().unwrap() {}
        assert_eq!(c.active_fleets(), 1, "one tenant shrinks to the floor");
        let resizes = c.autoscale_events();
        for i in 0..16 {
            c.submit(spec(&format!("g{i}"), 64, 90 + i as u64)).unwrap();
        }
        assert!(c.autoscale().unwrap(), "17 lodged on 1 fleet is above the high watermark");
        assert_eq!(c.active_fleets(), 2);
        assert_eq!(c.autoscale_events(), resizes + 1, "exactly one committed resize");
        let lodged: Vec<usize> =
            (0..c.active_fleets()).map(|i| c.fleet(i).lodged_jobs()).collect();
        let spread = lodged.iter().max().unwrap() - lodged.iter().min().unwrap();
        assert!(spread <= 1, "post-grow rebalance must even lodged jobs, got {lodged:?}");
        // Placement bookkeeping stayed consistent: every job sits on an
        // active fleet and still runs to completion from there.
        for gid in 0..17u64 {
            let f = c.fleet_of(gid).expect("every admitted job keeps a placement");
            assert!(f < c.active_fleets(), "job {gid} stranded on an idle fleet");
        }
        c.run_autoscaled(4096, 8).unwrap();
        for gid in 0..17u64 {
            assert_eq!(c.state(gid), Some(JobState::Finished), "job {gid} lost after grow");
        }
    }

    #[test]
    fn plan_cache_is_shared_across_fleets_and_surfaces_in_metrics() {
        let mut c = FleetCluster::new(4, 1 << 20, Policy::Drr);
        // Same generative inputs, different names: the names hash to
        // different home fleets, but the cluster-wide cache serves the
        // second admission from the first's plan.
        c.submit(spec("cache-a", 8, 77)).unwrap();
        c.submit(spec("cache-b", 8, 77)).unwrap();
        let m = c.metrics();
        assert_eq!(m.plan_cache_misses, 1, "first admission builds the plan");
        assert_eq!(m.plan_cache_hits, 1, "same-(spec, seed) admission reuses it");
        assert!(m.plan_cache_resident_bytes > 0);
        assert_eq!(m.plan_cache_resident_bytes, c.plan_cache().resident_bytes());
        // DQGD codecs carry mutable per-round state: uncacheable, and
        // the bypass touches neither counter.
        let dq = JobSpec::new("dq", CompressorSpec::parse("dqgd").unwrap(), 4.0, 16, 8, 5);
        c.submit(dq).unwrap();
        let m2 = c.metrics();
        assert_eq!((m2.plan_cache_hits, m2.plan_cache_misses), (1, 1), "dqgd must bypass");
        // Cache-off clears the fleet handles but keeps the shared
        // instance (and its counters) warm for re-enabling.
        c.set_plan_cache_enabled(false);
        c.submit(spec("cache-c", 8, 77)).unwrap();
        assert_eq!(c.plan_cache().hits(), 1, "a disabled cache must not be consulted");
        c.set_plan_cache_enabled(true);
        c.submit(spec("cache-d", 8, 77)).unwrap();
        assert_eq!(c.plan_cache().hits(), 2);
    }

    #[test]
    fn migrate_is_rejected_for_bad_targets_and_is_idempotent_in_place() {
        let mut c = FleetCluster::new(2, 1 << 20, Policy::Drr);
        let gid = c.submit(spec("m", 20, 5)).unwrap();
        let home = c.fleet_of(gid).unwrap();
        assert!(matches!(c.migrate(gid, 9), Err(ServeError::Snapshot(_))));
        c.migrate(gid, home).unwrap();
        assert_eq!(c.fleet_of(gid), Some(home), "same-fleet migrate is a no-op");
        assert!(matches!(c.migrate(99, 0), Err(ServeError::UnknownJob(99))));
        assert_eq!(c.metrics().migrated_jobs, 0);
    }

    #[test]
    fn migration_preserves_lifecycle_and_counts() {
        let mut c = FleetCluster::new(2, 1 << 20, Policy::Drr);
        let gid = c.submit(spec("mover", 30, 5)).unwrap();
        for _ in 0..4 {
            c.run_round();
        }
        c.pause(gid).unwrap();
        let from = c.fleet_of(gid).unwrap();
        let to = 1 - from;
        c.migrate(gid, to).unwrap();
        assert_eq!(c.fleet_of(gid), Some(to));
        assert_eq!(c.state(gid), Some(JobState::Paused), "paused jobs migrate parked");
        c.resume(gid).unwrap();
        c.run(64);
        assert_eq!(c.state(gid), Some(JobState::Finished));
        assert_eq!(c.job(gid).unwrap().trace().records.len(), 30);
        assert_eq!(c.metrics().migrated_jobs, 1);
    }
}
