//! Cluster-wide codec-plan cache: content-addressed, `Arc`-shared
//! storage of immutable built ladders.
//!
//! [`Job::build`](crate::serve::job::Job::build) regrows the full
//! frame/codec ladder — `levels × workers` calls to
//! `CompressorSpec::build`, each materializing sign vectors or dense
//! `O(n·N)` orthonormal matrices — on every admission, every
//! checkpoint restore, and every autoscaler migration. But the ladder
//! is a **pure function of its generative inputs**: the derivation
//! discipline in [`crate::serve::job`] fixes every frame bit as
//! `f(scheme, R, n, workers, seed)`. This cache keys ladders by
//! exactly those inputs — a 64-bit FNV-1a spec fingerprint plus the
//! raw seed — so a hit returns a plan **bit-identical by construction**
//! to the one a fresh build would grow, and a restore or migration
//! reuses the very `Arc` the evicted job held.
//!
//! What is *not* cached: problem data, run state, RNGs, feedback —
//! all per-job mutable state, always built fresh. Schemes whose codec
//! objects carry mutable round-to-round state (DQGD's range-refinement
//! counter) are excluded at the source via
//! [`CompressorSpec::plan_cacheable`](crate::quant::registry::CompressorSpec::plan_cacheable);
//! they silently take the uncached path.
//!
//! Memory is bounded by an LRU byte cap
//! ([`config::PLAN_CACHE_MAX_BYTES`]) accounted with the **true**
//! resident footprint (`Compressor::resident_bytes`, which frames
//! report exactly). Eviction drops only the cache's own `Arc` — live
//! jobs keep theirs — so the cap bounds the cache's extra pinned
//! memory, never correctness: an evicted key simply rebuilds on next
//! use, bit-identical again.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::config;
use crate::serve::checkpoint::fnv1a64;
use crate::serve::job::{build_ladder, JobSpec, LadderLevel};

/// Cache key: `(spec fingerprint, seed)` — the ladder's generative
/// inputs. The fingerprint hashes the scheme's **canonical name**
/// (admission rejects specs whose name does not round-trip through the
/// registry parser, so the name is a faithful content address), the
/// requested budget's raw bits, the dimension and the worker count.
/// The seed rides alongside unhashed: equal keys mean equal ladders,
/// bit for bit.
pub type PlanKey = (u64, u64);

struct CacheEntry {
    key: PlanKey,
    plan: Arc<Vec<LadderLevel>>,
    bytes: usize,
    last_used: u64,
}

struct CacheInner {
    /// Linear store: entry counts stay small (distinct `(spec, seed)`
    /// shapes, not tenants), and eviction wants an LRU scan anyway.
    entries: Vec<CacheEntry>,
    /// Monotone access clock backing the LRU order.
    tick: u64,
    /// Sum of `entries[i].bytes` — the gauge behind
    /// [`PlanCache::resident_bytes`].
    resident: usize,
}

/// The cache. One instance is shared `Arc`-wide across a
/// [`crate::serve::cluster::FleetCluster`]'s fleets; a standalone
/// [`crate::serve::fleet::JobServer`] may also be handed one. All
/// methods take `&self` (internal `Mutex`), so fleets on scoped threads
/// can consult it concurrently — the lock is only held for map
/// bookkeeping, never across a ladder build.
pub struct PlanCache {
    max_bytes: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    inner: Mutex<CacheInner>,
}

impl PlanCache {
    /// A cache holding at most `max_bytes` of resident plan state
    /// (0 disables retention: every lookup misses, every build runs).
    pub fn new(max_bytes: usize) -> Self {
        PlanCache {
            max_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inner: Mutex::new(CacheInner { entries: Vec::new(), tick: 0, resident: 0 }),
        }
    }

    /// A cache at the configured cluster cap
    /// ([`config::PLAN_CACHE_MAX_BYTES`]).
    pub fn with_default_cap() -> Self {
        Self::new(config::PLAN_CACHE_MAX_BYTES)
    }

    /// The `(fingerprint, seed)` key for a spec — see [`PlanKey`].
    pub fn key_for(spec: &JobSpec) -> PlanKey {
        let mut bytes = Vec::with_capacity(64);
        bytes.extend_from_slice(spec.scheme.name().as_bytes());
        bytes.extend_from_slice(&spec.r.to_bits().to_le_bytes());
        bytes.extend_from_slice(&(spec.n as u64).to_le_bytes());
        bytes.extend_from_slice(&(spec.workers as u64).to_le_bytes());
        (fnv1a64(&bytes), spec.seed)
    }

    /// Fetch the plan for `spec`, growing and (capacity permitting)
    /// retaining it on a miss. The build runs **outside** the lock, so
    /// a slow orthonormal-frame build never stalls other fleets'
    /// lookups; if two fleets race the same cold key, the first insert
    /// wins and both callers leave holding the same `Arc` (the ladders
    /// are bit-identical either way).
    ///
    /// The caller is responsible for the cacheability gate
    /// ([`crate::quant::registry::CompressorSpec::plan_cacheable`]):
    /// this method assumes the spec's plan is immutable and the spec
    /// already passed admission validation.
    pub fn get_or_build(&self, spec: &JobSpec) -> Arc<Vec<LadderLevel>> {
        let key = Self::key_for(spec);
        {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(e) = inner.entries.iter_mut().find(|e| e.key == key) {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Arc::clone(&e.plan);
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let plan = Arc::new(build_ladder(spec));
        let bytes = plan_resident_bytes(&plan);
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.entries.iter_mut().find(|e| e.key == key) {
            // A racing builder inserted first; adopt its (identical)
            // plan so every holder of this key shares one allocation.
            e.last_used = tick;
            return Arc::clone(&e.plan);
        }
        if bytes <= self.max_bytes {
            inner.resident += bytes;
            inner.entries.push(CacheEntry { key, plan: Arc::clone(&plan), bytes, last_used: tick });
            while inner.resident > self.max_bytes {
                let lru = inner
                    .entries
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(i, _)| i)
                    .expect("resident > cap implies a nonempty cache");
                let evicted = inner.entries.swap_remove(lru);
                inner.resident -= evicted.bytes;
            }
        }
        plan
    }

    /// Cache hits since construction.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses (= ladder builds routed through the cache).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Bytes of plan state the cache currently pins
    /// (`Compressor::resident_bytes` summed over retained ladders);
    /// at most the construction-time cap.
    pub fn resident_bytes(&self) -> u64 {
        self.inner.lock().unwrap().resident as u64
    }

    /// Number of retained plans.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// Whether no plan is retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// True resident footprint of a built ladder: per-level struct
/// overhead plus each codec's own accounting (frames report their
/// exact table sizes; scalar-configured codecs report 0 and cost only
/// their box).
pub(crate) fn plan_resident_bytes(plan: &[LadderLevel]) -> usize {
    plan.iter()
        .map(|lvl| {
            std::mem::size_of::<LadderLevel>()
                + lvl
                    .codecs
                    .iter()
                    .map(|c| std::mem::size_of_val(c) + c.resident_bytes())
                    .sum::<usize>()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::registry::CompressorSpec;

    fn spec(name: &str, scheme: &str, n: usize, seed: u64) -> JobSpec {
        JobSpec::new(name, CompressorSpec::parse(scheme).unwrap(), 1.0, n, 8, seed)
    }

    fn key_of(s: &JobSpec) -> PlanKey {
        PlanCache::key_for(s)
    }

    #[test]
    fn key_ignores_name_and_separates_generative_inputs() {
        // Two tenants, same generative inputs, different names: one plan.
        let a = key_of(&spec("alice", "ndsc-dith", 32, 7));
        let b = key_of(&spec("bob", "ndsc-dith", 32, 7));
        assert_eq!(a, b, "job names are not generative inputs");
        // Any generative input separates keys.
        assert_ne!(a, key_of(&spec("alice", "ndsc-dith", 32, 8)), "seed");
        assert_ne!(a, key_of(&spec("alice", "ndsc-dith", 64, 7)), "n");
        assert_ne!(a, key_of(&spec("alice", "ndsc", 32, 7)), "scheme");
        let mut wide = spec("alice", "ndsc-dith", 32, 7);
        wide.workers = 9;
        assert_ne!(a, key_of(&wide), "workers");
        let mut rate = spec("alice", "ndsc-dith", 32, 7);
        rate.r = 2.0;
        assert_ne!(a, key_of(&rate), "budget R");
    }

    #[test]
    fn hit_returns_the_same_arc_and_counts() {
        let cache = PlanCache::new(usize::MAX >> 1);
        let s = spec("t", "ndsc-dith", 16, 3);
        let first = cache.get_or_build(&s);
        let second = cache.get_or_build(&s);
        assert!(Arc::ptr_eq(&first, &second), "a hit must share the stored plan");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.resident_bytes(), plan_resident_bytes(&first) as u64);
    }

    #[test]
    fn lru_eviction_respects_the_byte_cap_and_recency() {
        let sa = spec("a", "ndsc-dith", 16, 1);
        let sb = spec("b", "ndsc-dith", 16, 2);
        let sc = spec("c", "ndsc-dith", 16, 3);
        // Cap sized for exactly two of these (equal-shape) plans.
        let one = plan_resident_bytes(&build_ladder(&sa));
        let cache = PlanCache::new(2 * one);
        let a1 = cache.get_or_build(&sa);
        let _b1 = cache.get_or_build(&sb);
        // Touch `a` so `b` is the LRU victim when `c` arrives.
        let _ = cache.get_or_build(&sa);
        let _c1 = cache.get_or_build(&sc);
        assert_eq!(cache.len(), 2);
        assert!(cache.resident_bytes() <= 2 * one as u64);
        // `a` survived (hit), `b` was evicted (miss → rebuild), and the
        // rebuild is a fresh allocation while `a`'s Arc is still shared.
        let a2 = cache.get_or_build(&sa);
        assert!(Arc::ptr_eq(&a1, &a2));
        let hits_before = cache.hits();
        let _b2 = cache.get_or_build(&sb);
        assert_eq!(cache.hits(), hits_before, "evicted key must rebuild, not hit");
    }

    #[test]
    fn zero_cap_disables_retention_but_still_builds() {
        let cache = PlanCache::new(0);
        let s = spec("t", "ndsc-dith", 16, 3);
        let p = cache.get_or_build(&s);
        assert_eq!(p.len(), 4, "full dyadic ladder at R=1");
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.resident_bytes(), 0);
    }

    /// Pin the documented cold-key race: builds run outside the lock,
    /// the first insert wins, and every losing builder *adopts* the
    /// stored plan instead of retaining a duplicate allocation.
    #[test]
    fn racing_cold_builders_converge_on_one_shared_plan() {
        let cache = PlanCache::new(usize::MAX >> 1);
        // An orthonormal-frame ladder: the build is slow enough to keep
        // the race window open for real.
        let s = spec("race", "ndsc-ortho", 32, 5);
        let plans: Vec<Arc<Vec<LadderLevel>>> = std::thread::scope(|sc| {
            let handles: Vec<_> = (0..4).map(|_| sc.spawn(|| cache.get_or_build(&s))).collect();
            handles.into_iter().map(|h| h.join().expect("racer panicked")).collect()
        });
        let stored = cache.get_or_build(&s);
        for p in &plans {
            assert!(Arc::ptr_eq(p, &stored), "every racer must share the one retained plan");
        }
        assert_eq!(cache.len(), 1, "a cold-key race must retain exactly one entry");
        assert!(cache.misses() >= 1, "somebody built the plan");
        assert_eq!(cache.hits() + cache.misses(), 5, "each lookup counts exactly once");
        assert_eq!(cache.resident_bytes(), plan_resident_bytes(&stored) as u64);
    }

    /// A plan bigger than the whole cap must be served to the caller
    /// but never pinned — and must never poison the resident tally.
    #[test]
    fn oversized_plan_is_served_but_never_retained() {
        let s = spec("big", "ndsc-dith", 16, 9);
        let cap = plan_resident_bytes(&build_ladder(&s)) - 1;
        let cache = PlanCache::new(cap);
        let p = cache.get_or_build(&s);
        assert_eq!(p.len(), 4, "the caller still gets the full dyadic ladder");
        assert_eq!(cache.len(), 0, "an over-cap plan must not be retained");
        assert_eq!(cache.resident_bytes(), 0, "nor counted as resident");
        let _ = cache.get_or_build(&s);
        assert_eq!(cache.misses(), 2, "every oversized lookup rebuilds");
        assert_eq!(cache.hits(), 0);
    }
}
