//! The job server: registry, lifecycle, and the per-round serve loop.
//!
//! A [`JobServer`] hosts any number of [`Job`]s over one global
//! bits-per-round budget. [`JobServer::run_round`] executes one fleet
//! round: deficit accrual, rotation, level selection and at most one
//! engine round per granted job — all allocation-free once warm
//! (`rust/tests/test_alloc.rs`, phase 4). Lifecycle transitions
//! (`submit`/`pause`/`resume`/`cancel`) take effect between fleet
//! rounds; a paused job's state is untouched until resume, so its trace
//! continues exactly where it stopped.

use std::io;

use crate::coordinator::metrics::{FleetMetrics, JobBits};
use crate::serve::checkpoint;
use crate::serve::job::{Job, JobSpec};
use crate::serve::scheduler::{self, Deficit, Policy};

/// Fleet-assigned job handle.
pub type JobId = u64;

/// Lifecycle state of a submitted job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Eligible for scheduling.
    Running,
    /// Parked: not scheduled, state frozen, resumable.
    Paused,
    /// All configured rounds executed; trace finalized.
    Finished,
    /// Terminated early by the operator; partial trace finalized.
    Cancelled,
}

impl std::fmt::Display for JobState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            JobState::Running => "running",
            JobState::Paused => "paused",
            JobState::Finished => "finished",
            JobState::Cancelled => "cancelled",
        })
    }
}

/// Errors of the serving API.
#[derive(Debug)]
pub enum ServeError {
    /// No job with that id was ever submitted.
    UnknownJob(JobId),
    /// The spec failed [`Job::build`] validation.
    InvalidSpec(String),
    /// Admission control: the job's cheapest grantable round exceeds the
    /// global per-round budget, so the scheduler could never serve it.
    Infeasible {
        /// Cheapest per-round cost the policy could grant.
        needed_bits: u64,
        /// The fleet's global budget.
        budget_bits: usize,
    },
    /// The operation is not valid in the job's current lifecycle state.
    BadState {
        /// The job.
        id: JobId,
        /// Its current state.
        state: JobState,
        /// The rejected operation.
        op: &'static str,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownJob(id) => write!(f, "unknown job {id}"),
            ServeError::InvalidSpec(e) => write!(f, "invalid job spec: {e}"),
            ServeError::Infeasible { needed_bits, budget_bits } => write!(
                f,
                "admission rejected: cheapest grantable round needs {needed_bits} bits but the \
                 global budget is {budget_bits} bits/round"
            ),
            ServeError::BadState { id, state, op } => {
                write!(f, "cannot {op} job {id} in state {state}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

struct JobSlot {
    id: JobId,
    state: JobState,
    deficit: Deficit,
    job: Job,
}

/// The multi-job server (see the [module docs](self)).
pub struct JobServer {
    policy: Policy,
    budget_bits: usize,
    slots: Vec<JobSlot>,
    metrics: FleetMetrics,
    cursor: usize,
    next_id: JobId,
}

impl JobServer {
    /// A fleet offering `budget_bits_per_round` payload bits per fleet
    /// round, arbitrated by `policy`.
    pub fn new(budget_bits_per_round: usize, policy: Policy) -> Self {
        JobServer {
            policy,
            budget_bits: budget_bits_per_round,
            slots: Vec::new(),
            metrics: FleetMetrics {
                budget_bits_per_round,
                ..Default::default()
            },
            cursor: 0,
            next_id: 0,
        }
    }

    /// The fleet's arbitration policy.
    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// The global per-round budget.
    pub fn budget_bits(&self) -> usize {
        self.budget_bits
    }

    /// Fleet rounds executed so far.
    pub fn round(&self) -> u64 {
        self.metrics.fleet_rounds
    }

    /// Jobs currently eligible for scheduling.
    pub fn live_jobs(&self) -> usize {
        self.slots.iter().filter(|s| s.state == JobState::Running).count()
    }

    /// All submitted job ids, in submission order.
    pub fn job_ids(&self) -> impl Iterator<Item = JobId> + '_ {
        self.slots.iter().map(|s| s.id)
    }

    /// Aggregate + per-job accounting.
    pub fn metrics(&self) -> &FleetMetrics {
        &self.metrics
    }

    /// Validate, build and admit a job. Admission requires the cheapest
    /// round the policy could ever grant to fit the global budget —
    /// otherwise the job could never transmit and would starve by
    /// construction.
    pub fn submit(&mut self, spec: JobSpec) -> Result<JobId, ServeError> {
        let job = Job::build(spec).map_err(ServeError::InvalidSpec)?;
        let needed = job.min_cost_bits(self.policy);
        if needed > self.budget_bits as u64 {
            return Err(ServeError::Infeasible { needed_bits: needed, budget_bits: self.budget_bits });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.metrics.jobs.push(JobBits { job: id, name: job.spec().name.clone(), ..Default::default() });
        self.slots.push(JobSlot { id, state: JobState::Running, deficit: Deficit::default(), job });
        Ok(id)
    }

    /// Restore a checkpointed job into this fleet (a fresh id is
    /// assigned; accounting rows are seeded from the snapshot's trace
    /// totals so per-job bits stay cumulative across restores). The
    /// restored job is admitted like any submission.
    pub fn restore(&mut self, bytes: &[u8]) -> io::Result<JobId> {
        let job = checkpoint::restore(bytes)?;
        let needed = job.min_cost_bits(self.policy);
        if needed > self.budget_bits as u64 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "restored job needs {needed} bits/round but the fleet budget is {} bits/round",
                    self.budget_bits
                ),
            ));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.metrics.jobs.push(JobBits {
            job: id,
            name: job.spec().name.clone(),
            rounds_served: job.rounds_done() as u64,
            payload_bits: job.trace().total_payload_bits as u64,
            side_bits: job.trace().total_side_bits as u64,
        });
        let state = if job.is_complete() { JobState::Finished } else { JobState::Running };
        let mut slot = JobSlot { id, state, deficit: Deficit::default(), job };
        if slot.state == JobState::Finished {
            slot.job.finalize();
        }
        self.slots.push(slot);
        Ok(id)
    }

    /// Serialize a resumable snapshot of a `Running`/`Paused` job.
    pub fn checkpoint(&self, id: JobId) -> Result<Vec<u8>, ServeError> {
        let slot = self.slot(id)?;
        match slot.state {
            // A Running/Paused job is never finalized (the fleet
            // finalizes and marks Finished in the same round), so the
            // writer's finalized-job refusal is unreachable here; map it
            // to BadState defensively rather than panicking.
            JobState::Running | JobState::Paused => checkpoint::save(&slot.job)
                .map_err(|_| ServeError::BadState { id, state: slot.state, op: "checkpoint" }),
            state => Err(ServeError::BadState { id, state, op: "checkpoint" }),
        }
    }

    /// Park a running job: it keeps its place in the registry but is
    /// skipped by the scheduler until [`JobServer::resume`].
    pub fn pause(&mut self, id: JobId) -> Result<(), ServeError> {
        let slot = self.slot_mut(id)?;
        match slot.state {
            JobState::Running => {
                slot.state = JobState::Paused;
                Ok(())
            }
            state => Err(ServeError::BadState { id, state, op: "pause" }),
        }
    }

    /// Unpark a paused job.
    pub fn resume(&mut self, id: JobId) -> Result<(), ServeError> {
        let slot = self.slot_mut(id)?;
        match slot.state {
            JobState::Paused => {
                slot.state = JobState::Running;
                Ok(())
            }
            state => Err(ServeError::BadState { id, state, op: "resume" }),
        }
    }

    /// Terminate a running or paused job. Its partial trace is finalized
    /// and remains readable via [`JobServer::job`].
    pub fn cancel(&mut self, id: JobId) -> Result<(), ServeError> {
        let slot = self.slot_mut(id)?;
        match slot.state {
            JobState::Running | JobState::Paused => {
                slot.job.finalize();
                slot.state = JobState::Cancelled;
                Ok(())
            }
            state => Err(ServeError::BadState { id, state, op: "cancel" }),
        }
    }

    /// A job's lifecycle state.
    pub fn state(&self, id: JobId) -> Option<JobState> {
        self.slots.iter().find(|s| s.id == id).map(|s| s.state)
    }

    /// Read access to a submitted job (trace, spec, progress).
    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.slots.iter().find(|s| s.id == id).map(|s| &s.job)
    }

    /// A job's current deficit counter (invariant checks / debugging).
    pub fn deficit_bits(&self, id: JobId) -> Option<u64> {
        self.slots.iter().find(|s| s.id == id).map(|s| s.deficit.bits)
    }

    /// Execute one fleet round (see the [scheduler docs]). Returns the
    /// number of jobs granted an engine round. A fleet with no live job
    /// is idle: nothing runs and the round counter does not advance.
    ///
    /// [scheduler docs]: crate::serve::scheduler
    pub fn run_round(&mut self) -> usize {
        let live = self.live_jobs();
        if live == 0 {
            return 0;
        }
        let quantum = scheduler::quantum(self.budget_bits, live);
        let mut remaining = self.budget_bits as u64;
        let mut served = 0usize;
        let nslots = self.slots.len();
        for k in 0..nslots {
            let j = (self.cursor + k) % nslots;
            let slot = &mut self.slots[j];
            if slot.state != JobState::Running {
                continue;
            }
            slot.deficit.accrue(quantum, slot.job.requested_cost_bits());
            let afford = slot.deficit.bits.min(remaining);
            if let Some(lvl) = slot.job.pick_level(self.policy, afford) {
                let cost = slot.job.level_cost(lvl);
                let (payload, side) = slot.job.step_round(lvl);
                slot.deficit.charge(cost);
                remaining -= cost;
                served += 1;
                if slot.job.is_complete() {
                    slot.job.finalize();
                    slot.state = JobState::Finished;
                }
                let row = &mut self.metrics.jobs[j];
                row.rounds_served += 1;
                row.payload_bits += payload;
                row.side_bits += side;
                self.metrics.spent_payload_bits += payload;
            }
        }
        self.cursor = (self.cursor + 1) % nslots;
        self.metrics.fleet_rounds += 1;
        served
    }

    /// Run fleet rounds until no job is live or `max_fleet_rounds` have
    /// executed; returns how many ran.
    pub fn run(&mut self, max_fleet_rounds: usize) -> usize {
        let mut ran = 0;
        while ran < max_fleet_rounds && self.live_jobs() > 0 {
            self.run_round();
            ran += 1;
        }
        ran
    }

    fn slot(&self, id: JobId) -> Result<&JobSlot, ServeError> {
        self.slots.iter().find(|s| s.id == id).ok_or(ServeError::UnknownJob(id))
    }

    fn slot_mut(&mut self, id: JobId) -> Result<&mut JobSlot, ServeError> {
        self.slots.iter_mut().find(|s| s.id == id).ok_or(ServeError::UnknownJob(id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::registry::CompressorSpec;

    fn spec(name: &str, scheme: &str, r: f32, rounds: usize, seed: u64) -> JobSpec {
        JobSpec::new(name, CompressorSpec::parse(scheme).unwrap(), r, 16, rounds, seed)
    }

    #[test]
    fn lifecycle_transitions_are_enforced() {
        let mut srv = JobServer::new(1 << 20, Policy::Drr);
        let id = srv.submit(spec("a", "ndsc-dith", 1.0, 8, 1)).unwrap();
        assert_eq!(srv.state(id), Some(JobState::Running));
        srv.pause(id).unwrap();
        assert_eq!(srv.state(id), Some(JobState::Paused));
        assert!(matches!(srv.pause(id), Err(ServeError::BadState { .. })));
        srv.resume(id).unwrap();
        assert!(matches!(srv.resume(id), Err(ServeError::BadState { .. })));
        srv.run(64);
        assert_eq!(srv.state(id), Some(JobState::Finished));
        assert!(matches!(srv.cancel(id), Err(ServeError::BadState { .. })));
        assert!(matches!(srv.pause(99), Err(ServeError::UnknownJob(99))));
        assert!(srv.job(id).unwrap().trace().final_x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn paused_jobs_are_skipped_cancelled_jobs_keep_their_trace() {
        let mut srv = JobServer::new(1 << 20, Policy::Drr);
        let a = srv.submit(spec("a", "ndsc-dith", 1.0, 50, 1)).unwrap();
        let b = srv.submit(spec("b", "sd", 0.5, 50, 2)).unwrap();
        srv.run_round();
        srv.pause(a).unwrap();
        let a_rounds = srv.job(a).unwrap().rounds_done();
        for _ in 0..5 {
            srv.run_round();
        }
        assert_eq!(srv.job(a).unwrap().rounds_done(), a_rounds, "paused job must not advance");
        assert_eq!(srv.job(b).unwrap().rounds_done(), 6);
        srv.cancel(b).unwrap();
        assert_eq!(srv.state(b), Some(JobState::Cancelled));
        let tb = srv.job(b).unwrap().trace();
        assert!(!tb.final_x.is_empty(), "cancelled job's partial trace is finalized");
        srv.resume(a).unwrap();
        srv.run(256);
        assert_eq!(srv.state(a), Some(JobState::Finished));
    }

    #[test]
    fn admission_rejects_what_the_budget_cannot_serve() {
        // qsgd at R=4, n=16 costs 64 bits/round; a 10-bit fleet can never
        // grant it under strict DRR.
        let mut srv = JobServer::new(10, Policy::Drr);
        match srv.submit(spec("greedy", "qsgd", 4.0, 8, 1)) {
            Err(ServeError::Infeasible { needed_bits, budget_bits }) => {
                assert_eq!(needed_bits, 64);
                assert_eq!(budget_bits, 10);
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
        // An idle fleet does not advance its round counter.
        assert_eq!(srv.run_round(), 0);
        assert_eq!(srv.round(), 0);
    }

    #[test]
    fn accounting_tracks_measured_bits_per_job() {
        let mut srv = JobServer::new(1 << 20, Policy::Drr);
        let a = srv.submit(spec("a", "ndsc-dith", 1.0, 10, 1)).unwrap();
        srv.run(64);
        let m = srv.metrics();
        assert_eq!(m.jobs.len(), 1);
        assert_eq!(m.jobs[0].rounds_served, 10);
        let tr = srv.job(a).unwrap().trace();
        assert_eq!(m.jobs[0].payload_bits, tr.total_payload_bits as u64);
        assert_eq!(m.jobs[0].side_bits, tr.total_side_bits as u64);
        assert_eq!(m.spent_payload_bits, tr.total_payload_bits as u64);
        assert!(m.utilization() > 0.0);
    }
}
