//! The job server: registry, lifecycle, and the per-round serve loop.
//!
//! A [`JobServer`] hosts any number of [`Job`]s over one global
//! bits-per-round budget. [`JobServer::run_round`] executes one fleet
//! round: deficit accrual, rotation, level selection and at most one
//! engine round per granted job — all allocation-free once warm
//! (`rust/tests/test_alloc.rs`, phase 4). Lifecycle transitions
//! (`submit`/`pause`/`resume`/`cancel`) take effect between fleet
//! rounds; a paused job's state is untouched until resume, so its trace
//! continues exactly where it stopped.
//!
//! **Epochs: arbitration split from execution.** A fleet round is two
//! passes that only *look* fused: the grant pass (census, accrual,
//! level pick, budget drain, deficit charge, cursor rotation) consumes
//! nothing but **nominal** ladder costs, and the execution pass
//! (engine rounds) feeds nothing back into grants — measured bits go to
//! metrics rows only. [`JobServer::run_epoch`] exploits that: it
//! arbitrates `E` rounds up front at a barrier (bit-identical to `E`
//! calls of [`JobServer::run_round`], including virtual completion —
//! a job granted its final round is excluded from later rounds' census
//! exactly as the fused loop's `Finished` transition would), then each
//! granted job executes its levels back-to-back. Because grants of one
//! epoch touch disjoint jobs and all cross-round state lives inside the
//! job, the execution pass may run in any order or on any thread — the
//! cluster's work-stealing pool ([`crate::serve::cluster`]) executes
//! the same [`EpochGroup`]s concurrently with cross-fleet stealing and
//! stays trace- and accounting-identical to lockstep.
//!
//! **QoS.** Each job carries a [`QosClass`]: its DRR quantum is the
//! weighted share `⌊B·w_j/Σ_live w⌋`, and every class with live members
//! holds a reserved slice of the round budget
//! ([`QosClass::reserve_num`]/[`scheduler::RESERVE_DENOM`]) that only
//! its own members may draw — a granted job spends its class reserve
//! first, then the common pool. Single-class fleets reduce exactly to
//! the unweighted scheduler, so pre-QoS traces are unchanged.
//!
//! **Threaded granted rounds.** [`JobServer::enable_fanout`] switches
//! granted rounds from the inline engine to the threaded executor
//! ([`Job::step_round_mt`]) whenever the never-nest gate
//! ([`crate::coordinator::config::fleet_fanout_threads`]) allows — the
//! per-worker scratch comes from a fleet-owned (or cluster-shared)
//! [`ChannelPools`]. Traces are bit-identical either way, so a fleet may
//! flip fan-out on or off mid-run.

use std::io;
use std::sync::Arc;

use crate::coordinator::channel::ChannelPools;
use crate::coordinator::config;
use crate::coordinator::metrics::{FleetMetrics, JobBits};
use crate::serve::checkpoint::{self, SchedTrailer};
use crate::serve::job::{Job, JobSpec};
use crate::serve::plancache::PlanCache;
use crate::serve::scheduler::{self, Deficit, Policy, QosClass};

/// Fleet-assigned job handle.
pub type JobId = u64;

/// Lifecycle state of a submitted job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Eligible for scheduling.
    Running,
    /// Parked: not scheduled, state frozen, resumable.
    Paused,
    /// All configured rounds executed; trace finalized.
    Finished,
    /// Terminated early by the operator; partial trace finalized.
    Cancelled,
}

impl std::fmt::Display for JobState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            JobState::Running => "running",
            JobState::Paused => "paused",
            JobState::Finished => "finished",
            JobState::Cancelled => "cancelled",
        })
    }
}

/// Errors of the serving API.
#[derive(Debug)]
pub enum ServeError {
    /// No job with that id was ever submitted.
    UnknownJob(JobId),
    /// The spec failed [`Job::build`] validation.
    InvalidSpec(String),
    /// Admission control: the job's cheapest grantable round exceeds the
    /// global per-round budget, so the scheduler could never serve it.
    Infeasible {
        /// Cheapest per-round cost the policy could grant.
        needed_bits: u64,
        /// The fleet's global budget.
        budget_bits: usize,
    },
    /// The operation is not valid in the job's current lifecycle state.
    BadState {
        /// The job.
        id: JobId,
        /// Its current state.
        state: JobState,
        /// The rejected operation.
        op: &'static str,
    },
    /// A checkpoint round-trip inside a compound operation (migration)
    /// failed; the message carries the underlying snapshot error.
    Snapshot(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownJob(id) => write!(f, "unknown job {id}"),
            ServeError::InvalidSpec(e) => write!(f, "invalid job spec: {e}"),
            ServeError::Infeasible { needed_bits, budget_bits } => write!(
                f,
                "admission rejected: cheapest grantable round needs {needed_bits} bits but the \
                 global budget is {budget_bits} bits/round"
            ),
            ServeError::BadState { id, state, op } => {
                write!(f, "cannot {op} job {id} in state {state}")
            }
            ServeError::Snapshot(e) => write!(f, "checkpoint round-trip failed: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

pub(crate) struct JobSlot {
    id: JobId,
    state: JobState,
    deficit: Deficit,
    /// Last granted ladder level (`None` until the first grant) — the
    /// adaptive-R rung that travels in the checkpoint trailer so a
    /// restored job's observability picks up where it left off.
    rung: Option<u8>,
    /// Ladder levels granted to this slot in the current epoch, in
    /// round order. Cleared at each arbitration barrier; the capacity
    /// persists, so steady-state epochs push within it (phase 5 of
    /// `rust/tests/test_alloc.rs`).
    granted: Vec<u8>,
    job: Job,
}

/// One slot's share of an epoch: which slot runs, how wide its worker
/// fan-out may go, and (after execution) the measured bits its granted
/// rounds put on the wire. The grant pass emits these in slot order;
/// the execution pass — inline or on the cluster's work-stealing pool —
/// fills `payload`/`side`; [`JobServer::apply_epoch`] folds them into
/// the metrics rows deterministically.
#[derive(Clone, Copy)]
pub(crate) struct EpochGroup {
    pub(crate) slot: usize,
    pub(crate) threads: Option<usize>,
    pub(crate) payload: u64,
    pub(crate) side: u64,
}

/// A contiguous **panel** of one fleet's [`EpochGroup`]s as raw
/// pointers, so the cluster's persistent pool workers can execute it
/// from any thread. A panel is the unit of claiming/stealing: heavy
/// groups (worker fan-out, big `n`) travel as singleton panels exactly
/// like the pre-batching executor, while runs of same-`(n, workers)`
/// lightweight grants are coalesced by
/// [`JobServer::collect_epoch_items`] so the 1000-small-tenant epoch
/// pays the per-item fixed costs (deque CAS, steal scan, dispatch)
/// once per panel instead of once per tenant.
///
/// Disjointness is structural: the grant pass emits at most one group
/// per slot per epoch, panels partition the fleet's group list, and
/// the coordinator parks until every item completes before touching
/// fleet state again — so no two items (nor two groups within one
/// item) ever alias a slot, and the `slots` base pointer is only ever
/// dereferenced at this panel's own group indices.
#[derive(Clone, Copy)]
pub(crate) struct WorkItem {
    /// Base of the owning fleet's slot array (indexed by
    /// `EpochGroup::slot`).
    pub(crate) slots: *mut JobSlot,
    /// First group of this panel (points into the fleet's pooled
    /// `groups` vec; execution writes measured bits back through it).
    pub(crate) groups: *mut EpochGroup,
    /// Panel length (≥ 1 for items emitted by the grant pass).
    pub(crate) n_groups: usize,
}

// SAFETY: a WorkItem is an owned capability to its panel's jobs for one
// epoch — the epoch executor hands each item to exactly one worker and
// joins the pool before the fleet's `&mut self` methods run again.
unsafe impl Send for WorkItem {}

/// Step every granted level of one epoch group, returning the summed
/// measured `(payload, side)` bits. Shared by the inline and the
/// work-stealing execution paths so they cannot drift.
pub(crate) fn execute_group(
    job: &mut Job,
    levels: &[u8],
    threads: Option<usize>,
    pools: &Arc<ChannelPools>,
) -> (u64, u64) {
    let (mut payload, mut side) = (0u64, 0u64);
    for &lvl in levels {
        let (p, s) = job.step_round_auto(lvl as usize, threads, pools);
        payload += p;
        side += s;
    }
    (payload, side)
}

/// Execute one [`WorkItem`] panel (pool workers call this; the inline
/// path goes through [`JobServer::execute_epoch_inline`]). Groups run
/// in panel order, which is slot order — each job still steps its own
/// granted levels in sequence through the shared [`execute_group`], so
/// a batched panel is bit-identical to the same groups executed as
/// singleton items.
///
/// # Safety
/// The item's pointers must be live and this thread must hold exclusive
/// logical ownership of every job and group in the panel for the
/// duration of the call — guaranteed by the epoch protocol above.
pub(crate) unsafe fn execute_item(item: WorkItem, pools: &Arc<ChannelPools>) {
    for gi in 0..item.n_groups {
        let g = unsafe { &mut *item.groups.add(gi) };
        let s = unsafe { &mut *item.slots.add(g.slot) };
        let (payload, side) = execute_group(&mut s.job, &s.granted, g.threads, pools);
        g.payload = payload;
        g.side = side;
    }
}

/// The multi-job server (see the [module docs](self)).
pub struct JobServer {
    policy: Policy,
    budget_bits: usize,
    slots: Vec<JobSlot>,
    metrics: FleetMetrics,
    cursor: usize,
    next_id: JobId,
    /// Recycled threaded-round scratch (shared across the cluster when
    /// this fleet was built by [`JobServer::with_pools`]).
    pools: Arc<ChannelPools>,
    /// `Some(active_fleets)` once [`JobServer::enable_fanout`] armed
    /// threaded granted rounds; `None` (the default) steps inline.
    fanout_fleets: Option<usize>,
    /// The current epoch's execution groups, in slot order. Pooled: the
    /// grant pass clears and refills it, so steady-state epochs allocate
    /// nothing.
    groups: Vec<EpochGroup>,
    /// Shared codec-plan cache consulted by [`JobServer::submit`] and
    /// [`JobServer::restore`]; `None` (the default) builds every ladder
    /// fresh. The cluster installs one cache across all member fleets.
    plan_cache: Option<Arc<PlanCache>>,
    /// Whether [`JobServer::collect_epoch_items`] coalesces runs of
    /// lightweight same-shape groups into batched panels (on by
    /// default; the off switch exists for the batched-vs-per-job
    /// bit-identity proofs and same-run benches).
    batching: bool,
}

impl JobServer {
    /// A fleet offering `budget_bits_per_round` payload bits per fleet
    /// round, arbitrated by `policy`.
    pub fn new(budget_bits_per_round: usize, policy: Policy) -> Self {
        Self::with_pools(budget_bits_per_round, policy, Arc::new(ChannelPools::new(8)))
    }

    /// Like [`JobServer::new`], with a caller-provided buffer pool — the
    /// cluster hands every member fleet one shared pool so migrated
    /// jobs' scratch is recycled fleet-to-fleet.
    pub fn with_pools(
        budget_bits_per_round: usize,
        policy: Policy,
        pools: Arc<ChannelPools>,
    ) -> Self {
        JobServer {
            policy,
            budget_bits: budget_bits_per_round,
            slots: Vec::new(),
            metrics: FleetMetrics {
                budget_bits_per_round,
                ..Default::default()
            },
            cursor: 0,
            next_id: 0,
            pools,
            fanout_fleets: None,
            groups: Vec::new(),
            plan_cache: None,
            batching: true,
        }
    }

    /// Install (or clear) the shared codec-plan cache consulted by
    /// [`JobServer::submit`] and [`JobServer::restore`].
    /// [`FleetCluster`] installs one cache across all member fleets so
    /// restore-after-migration reuses the evicted fleet's plan.
    ///
    /// [`FleetCluster`]: crate::serve::cluster::FleetCluster
    pub fn set_plan_cache(&mut self, cache: Option<Arc<PlanCache>>) {
        self.plan_cache = cache;
    }

    /// The installed plan cache, if any.
    pub fn plan_cache(&self) -> Option<&Arc<PlanCache>> {
        self.plan_cache.as_ref()
    }

    /// Toggle batched-panel emission in
    /// [`JobServer::collect_epoch_items`] (on by default). Off forces
    /// one panel per group — the per-job baseline the bit-identity
    /// tests and same-run benches compare against.
    pub fn set_epoch_batching(&mut self, on: bool) {
        self.batching = on;
    }

    /// Arm threaded granted rounds: with `active_fleets` fleets running
    /// concurrently, each granted job's worker phase fans out over at
    /// most `FLEET_MAX_WORKER_THREADS / active_fleets` scoped threads
    /// (never-nest cap; see
    /// [`crate::coordinator::config::fleet_fanout_threads`]). Jobs the
    /// gate declines (single-worker, kernel-parallel dims, exhausted
    /// allowance) keep stepping inline. Idempotent; pass the cluster's
    /// fleet count, or `1` for a solo fleet.
    pub fn enable_fanout(&mut self, active_fleets: usize) {
        self.fanout_fleets = Some(active_fleets.max(1));
    }

    /// The fleet's recycled threaded-round buffer pool.
    pub fn pools(&self) -> &Arc<ChannelPools> {
        &self.pools
    }

    /// The fleet's arbitration policy.
    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// The global per-round budget.
    pub fn budget_bits(&self) -> usize {
        self.budget_bits
    }

    /// Fleet rounds executed so far.
    pub fn round(&self) -> u64 {
        self.metrics.fleet_rounds
    }

    /// Jobs currently eligible for scheduling.
    pub fn live_jobs(&self) -> usize {
        self.slots.iter().filter(|s| s.state == JobState::Running).count()
    }

    /// Jobs currently occupying a slot, running **or** parked — the
    /// migration-eligible population the cluster autoscaler balances
    /// (matches [`FleetCluster::queued_jobs`]'s per-job filter, unlike
    /// [`JobServer::live_jobs`] which counts `Running` only).
    ///
    /// [`FleetCluster::queued_jobs`]: crate::serve::cluster::FleetCluster::queued_jobs
    pub fn lodged_jobs(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| matches!(s.state, JobState::Running | JobState::Paused))
            .count()
    }

    /// All submitted job ids, in submission order.
    pub fn job_ids(&self) -> impl Iterator<Item = JobId> + '_ {
        self.slots.iter().map(|s| s.id)
    }

    /// Aggregate + per-job accounting.
    pub fn metrics(&self) -> &FleetMetrics {
        &self.metrics
    }

    /// Validate, build and admit a job. Admission requires the cheapest
    /// round the policy could ever grant to fit the global budget —
    /// otherwise the job could never transmit and would starve by
    /// construction.
    pub fn submit(&mut self, spec: JobSpec) -> Result<JobId, ServeError> {
        let job = Job::build_cached(spec, self.plan_cache.as_deref()).map_err(ServeError::InvalidSpec)?;
        let needed = job.min_cost_bits(self.policy);
        if needed > self.budget_bits as u64 {
            return Err(ServeError::Infeasible { needed_bits: needed, budget_bits: self.budget_bits });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.metrics.jobs.push(JobBits { job: id, name: job.spec().name.clone(), ..Default::default() });
        self.slots.push(JobSlot {
            id,
            state: JobState::Running,
            deficit: Deficit::default(),
            rung: None,
            granted: Vec::new(),
            job,
        });
        Ok(id)
    }

    /// Restore a checkpointed job into this fleet (a fresh id is
    /// assigned; accounting rows are seeded from the snapshot's trace
    /// totals so per-job bits stay cumulative across restores). The
    /// restored job is admitted like any submission. Scheduler state in
    /// the trailer — banked DRR deficit (clamped to the classic DRR cap
    /// so a foreign snapshot cannot bank unbounded credit here) and the
    /// adaptive-R rung — resumes intact, which is what makes a
    /// mid-deficit fleet-to-fleet migration trace-neutral.
    pub fn restore(&mut self, bytes: &[u8]) -> io::Result<JobId> {
        let (job, sched) = checkpoint::restore_with_sched_cached(bytes, self.plan_cache.as_deref())?;
        let needed = job.min_cost_bits(self.policy);
        if needed > self.budget_bits as u64 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "restored job needs {needed} bits/round but the fleet budget is {} bits/round",
                    self.budget_bits
                ),
            ));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.metrics.jobs.push(JobBits {
            job: id,
            name: job.spec().name.clone(),
            rounds_served: job.rounds_done() as u64,
            payload_bits: job.trace().total_payload_bits as u64,
            side_bits: job.trace().total_side_bits as u64,
        });
        let cost = job.requested_cost_bits();
        let cap = Deficit::cap(scheduler::quantum(self.budget_bits, 1), cost);
        let state = if job.is_complete() { JobState::Finished } else { JobState::Running };
        let mut slot = JobSlot {
            id,
            state,
            deficit: Deficit { bits: sched.deficit_bits.min(cap) },
            rung: sched.rung,
            granted: Vec::new(),
            job,
        };
        if slot.state == JobState::Finished {
            slot.job.finalize();
        }
        self.slots.push(slot);
        Ok(id)
    }

    /// Serialize a resumable snapshot of a `Running`/`Paused` job,
    /// scheduler trailer (banked deficit, adaptive-R rung, QoS class)
    /// included — fleet-independent by construction, so any fleet (this
    /// one or a migration target) restores it bit-for-bit.
    pub fn checkpoint(&self, id: JobId) -> Result<Vec<u8>, ServeError> {
        let slot = self.slot(id)?;
        match slot.state {
            // A Running/Paused job is never finalized (the fleet
            // finalizes and marks Finished in the same round), so the
            // writer's finalized-job refusal is unreachable here; map it
            // to BadState defensively rather than panicking.
            JobState::Running | JobState::Paused => {
                let sched = SchedTrailer {
                    deficit_bits: slot.deficit.bits,
                    rung: slot.rung,
                    qos: slot.job.spec().qos,
                };
                checkpoint::save_with_sched(&slot.job, &sched)
                    .map_err(|_| ServeError::BadState { id, state: slot.state, op: "checkpoint" })
            }
            state => Err(ServeError::BadState { id, state, op: "checkpoint" }),
        }
    }

    /// [`JobServer::checkpoint`] as a **delta record** against a pinned
    /// `base` snapshot previously taken of the same job (periodic
    /// autosave: O(changed) bytes per save instead of O(job)). The
    /// current scheduler trailer rides along; restore with
    /// [`checkpoint::restore_delta_with_sched`] or fold chains back into
    /// a base with [`checkpoint::compact`].
    pub fn checkpoint_delta(&self, id: JobId, base: &[u8]) -> Result<Vec<u8>, ServeError> {
        let slot = self.slot(id)?;
        match slot.state {
            JobState::Running | JobState::Paused => {
                let sched = SchedTrailer {
                    deficit_bits: slot.deficit.bits,
                    rung: slot.rung,
                    qos: slot.job.spec().qos,
                };
                checkpoint::save_delta_with_sched(&slot.job, &sched, base)
                    .map_err(|e| ServeError::Snapshot(e.to_string()))
            }
            state => Err(ServeError::BadState { id, state, op: "checkpoint_delta" }),
        }
    }

    /// Remove a job from the registry entirely, returning it — the
    /// drain step of a fleet-to-fleet migration (snapshot first via
    /// [`JobServer::checkpoint`]; the trailer carries the scheduler
    /// state eviction discards here). The job's threaded-round scratch
    /// goes back to the fleet pool, and its metrics row leaves with it
    /// so slot/metrics stay in lockstep.
    pub fn evict(&mut self, id: JobId) -> Result<Job, ServeError> {
        let j = self
            .slots
            .iter()
            .position(|s| s.id == id)
            .ok_or(ServeError::UnknownJob(id))?;
        let mut slot = self.slots.remove(j);
        self.metrics.jobs.remove(j);
        // Keep the rotation anchored on the same successor slot.
        if j < self.cursor {
            self.cursor -= 1;
        }
        if self.slots.is_empty() {
            self.cursor = 0;
        } else {
            self.cursor %= self.slots.len();
        }
        slot.job.release_mt(&self.pools);
        Ok(slot.job)
    }

    /// Park a running job: it keeps its place in the registry but is
    /// skipped by the scheduler until [`JobServer::resume`].
    pub fn pause(&mut self, id: JobId) -> Result<(), ServeError> {
        let slot = self.slot_mut(id)?;
        match slot.state {
            JobState::Running => {
                slot.state = JobState::Paused;
                Ok(())
            }
            state => Err(ServeError::BadState { id, state, op: "pause" }),
        }
    }

    /// Unpark a paused job.
    pub fn resume(&mut self, id: JobId) -> Result<(), ServeError> {
        let slot = self.slot_mut(id)?;
        match slot.state {
            JobState::Paused => {
                slot.state = JobState::Running;
                Ok(())
            }
            state => Err(ServeError::BadState { id, state, op: "resume" }),
        }
    }

    /// Terminate a running or paused job. Its partial trace is finalized
    /// and remains readable via [`JobServer::job`].
    pub fn cancel(&mut self, id: JobId) -> Result<(), ServeError> {
        let slot = self.slot_mut(id)?;
        match slot.state {
            JobState::Running | JobState::Paused => {
                slot.job.finalize();
                slot.state = JobState::Cancelled;
                Ok(())
            }
            state => Err(ServeError::BadState { id, state, op: "cancel" }),
        }
    }

    /// A job's lifecycle state.
    pub fn state(&self, id: JobId) -> Option<JobState> {
        self.slots.iter().find(|s| s.id == id).map(|s| s.state)
    }

    /// Read access to a submitted job (trace, spec, progress).
    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.slots.iter().find(|s| s.id == id).map(|s| &s.job)
    }

    /// A job's current deficit counter (invariant checks / debugging).
    pub fn deficit_bits(&self, id: JobId) -> Option<u64> {
        self.slots.iter().find(|s| s.id == id).map(|s| s.deficit.bits)
    }

    /// A job's last granted ladder level (`None` until first grant) —
    /// the adaptive-R rung preserved across checkpoint/restore.
    pub fn last_rung(&self, id: JobId) -> Option<Option<u8>> {
        self.slots.iter().find(|s| s.id == id).map(|s| s.rung)
    }

    /// Execute one fleet round (see the [scheduler docs]). Returns the
    /// number of jobs granted an engine round. A fleet with no live job
    /// is idle: nothing runs and the round counter does not advance.
    ///
    /// Per round: every class with live members gets its reserved slice
    /// of the budget; each live job accrues its weighted quantum, and a
    /// granted job's cost is drawn from its class reserve first, then
    /// the common pool. With one live class this is arithmetic-identical
    /// to the unweighted scheduler (the reserve and common pool are one
    /// undifferentiated budget).
    ///
    /// [scheduler docs]: crate::serve::scheduler
    pub fn run_round(&mut self) -> usize {
        self.run_epoch(1)
    }

    /// Arbitrate and execute `rounds` fleet rounds as one epoch: every
    /// grant decision is made up front at a barrier (the grant pass
    /// consumes only nominal ladder costs, so batching it is
    /// **bit-identical** to `rounds` calls of [`JobServer::run_round`]),
    /// then each granted job steps its levels back-to-back on the
    /// current thread. Returns job-rounds granted. The cluster's
    /// work-stealing executor uses the same three passes but runs the
    /// middle one on its persistent pool.
    pub fn run_epoch(&mut self, rounds: usize) -> usize {
        self.compute_epoch_grants(rounds);
        self.execute_epoch_inline();
        self.apply_epoch()
    }

    /// The grant pass: arbitrate `rounds` fleet rounds, mutating all
    /// scheduler state (deficits, rungs, cursor, round counter) exactly
    /// as the fused loop did, and record each slot's granted levels for
    /// the execution pass. Returns job-rounds granted.
    pub(crate) fn compute_epoch_grants(&mut self, rounds: usize) -> usize {
        for s in &mut self.slots {
            s.granted.clear();
        }
        self.groups.clear();
        let mut total = 0;
        for _ in 0..rounds {
            total += self.arbitrate_round();
        }
        // Execution groups in slot order (deterministic apply order).
        let groups = &mut self.groups;
        let fanout = self.fanout_fleets;
        for (j, s) in self.slots.iter().enumerate() {
            if s.granted.is_empty() {
                continue;
            }
            let threads = fanout.and_then(|fleets| {
                config::fleet_fanout_threads(s.job.spec().workers, s.job.spec().n, fleets)
            });
            groups.push(EpochGroup { slot: j, threads, payload: 0, side: 0 });
        }
        total
    }

    /// Arbitrate one fleet round. A slot already granted its last
    /// configured round earlier in this epoch is *virtually complete*:
    /// the fused loop would have flipped it to `Finished` before the
    /// next round's census, so the batched pass must exclude it the
    /// same way. An idle round (no live, non-complete job) advances
    /// nothing — matching [`JobServer::run_round`] on an idle fleet.
    fn arbitrate_round(&mut self) -> usize {
        fn eligible(s: &JobSlot) -> bool {
            s.state == JobState::Running
                && s.job.rounds_done() + s.granted.len() < s.job.spec().rounds
        }
        // Class census → weighted quanta + per-class reservations.
        let mut live_weight = [0u64; QosClass::ALL.len()];
        for s in &self.slots {
            if eligible(s) {
                live_weight[s.job.spec().qos.index()] += s.job.spec().qos.weight();
            }
        }
        let total_weight: u64 = live_weight.iter().sum();
        if total_weight == 0 {
            return 0;
        }
        let budget = self.budget_bits as u64;
        let mut reserved = [0u64; QosClass::ALL.len()];
        for c in QosClass::ALL {
            if live_weight[c.index()] > 0 {
                reserved[c.index()] =
                    (budget as u128 * c.reserve_num() as u128 / scheduler::RESERVE_DENOM as u128)
                        as u64;
            }
        }
        // Idle classes' slices stay in the common pool.
        let mut common = budget - reserved.iter().sum::<u64>();
        // A class's steady-state ceiling: its own reserve plus the common
        // pool. An *admitted* job whose cheapest rung exceeds this ceiling
        // would be starved forever by the reservations alone, breaking the
        // admission contract — such jobs bypass the class cap and draw on
        // the whole remaining budget instead (reservations yield to the
        // admission guarantee, never the other way around).
        let mut class_ceiling = [0u64; QosClass::ALL.len()];
        for c in QosClass::ALL {
            class_ceiling[c.index()] = reserved[c.index()] + common;
        }
        let mut served = 0usize;
        let nslots = self.slots.len();
        for k in 0..nslots {
            let j = (self.cursor + k) % nslots;
            let slot = &mut self.slots[j];
            if !eligible(slot) {
                continue;
            }
            let class = slot.job.spec().qos;
            let quantum =
                scheduler::weighted_quantum(self.budget_bits, class.weight(), total_weight);
            slot.deficit.accrue(quantum, slot.job.requested_cost_bits());
            let oversized = slot.job.min_cost_bits(self.policy) > class_ceiling[class.index()];
            let pool = if oversized {
                reserved.iter().sum::<u64>() + common
            } else {
                reserved[class.index()] + common
            };
            let afford = slot.deficit.bits.min(pool);
            if let Some(lvl) = slot.job.pick_level(self.policy, afford) {
                let cost = slot.job.level_cost(lvl);
                // Draw the class reserve down first, then the common pool,
                // then (oversized bypass only) other classes' reserves.
                // `afford ≤ pool` guarantees the drain terminates at zero.
                let mut owed = cost;
                let take = owed.min(reserved[class.index()]);
                reserved[class.index()] -= take;
                owed -= take;
                let take = owed.min(common);
                common -= take;
                owed -= take;
                if owed > 0 {
                    for c in QosClass::ALL {
                        let take = owed.min(reserved[c.index()]);
                        reserved[c.index()] -= take;
                        owed -= take;
                    }
                }
                debug_assert_eq!(owed, 0, "grant exceeded the round budget");
                slot.deficit.charge(cost);
                slot.rung = Some(lvl as u8);
                slot.granted.push(lvl as u8);
                served += 1;
            }
        }
        self.cursor = (self.cursor + 1) % nslots;
        self.metrics.fleet_rounds += 1;
        served
    }

    /// The execution pass, inline flavor: step every epoch group on the
    /// current thread, in slot order.
    pub(crate) fn execute_epoch_inline(&mut self) {
        for gi in 0..self.groups.len() {
            let EpochGroup { slot, threads, .. } = self.groups[gi];
            let s = &mut self.slots[slot];
            let (payload, side) = execute_group(&mut s.job, &s.granted, threads, &self.pools);
            self.groups[gi].payload = payload;
            self.groups[gi].side = side;
        }
    }

    /// Emit the epoch's groups as [`WorkItem`] panels for the cluster's
    /// work-stealing pool. Heavy groups — threaded worker fan-out, or
    /// dims above [`config::EPOCH_BATCH_MAX_DIM`] — travel as singleton
    /// panels exactly as before; a run of **consecutive** lightweight
    /// same-`(n, workers)` groups coalesces into one panel of at most
    /// [`config::EPOCH_BATCH_MAX_GROUPS`] groups (capped so a uniform
    /// small-tenant mix still fragments into stealable units). Panels
    /// partition the group list in slot order and execute their groups
    /// in that order, so batched execution is bit-identical to one panel
    /// per group ([`JobServer::set_epoch_batching`] forces the latter).
    /// The scan allocates nothing (phase 5 of `rust/tests/test_alloc.rs`).
    ///
    /// Caller contract: the fleet must not be touched again until every
    /// item has executed, and [`JobServer::apply_epoch`] must run
    /// afterwards.
    pub(crate) fn collect_epoch_items(&mut self, out: &mut Vec<WorkItem>) {
        let slots = self.slots.as_mut_ptr();
        let groups = self.groups.as_mut_ptr();
        let n_groups = self.groups.len();
        let mut i = 0usize;
        while i < n_groups {
            let g = &self.groups[i];
            let mut len = 1usize;
            if self.batching && g.threads.is_none() {
                let spec = self.slots[g.slot].job.spec();
                let (n0, w0) = (spec.n, spec.workers);
                if n0 <= config::EPOCH_BATCH_MAX_DIM {
                    while i + len < n_groups && len < config::EPOCH_BATCH_MAX_GROUPS {
                        let h = &self.groups[i + len];
                        if h.threads.is_some() {
                            break;
                        }
                        let hs = self.slots[h.slot].job.spec();
                        if hs.n != n0 || hs.workers != w0 {
                            break;
                        }
                        len += 1;
                    }
                }
            }
            out.push(WorkItem { slots, groups: unsafe { groups.add(i) }, n_groups: len });
            i += len;
        }
    }

    /// The accounting pass: fold measured bits into the per-job metrics
    /// rows and apply completion transitions, in slot order. Returns
    /// job-rounds served (= granted — every granted level executed).
    pub(crate) fn apply_epoch(&mut self) -> usize {
        let mut served = 0usize;
        for gi in 0..self.groups.len() {
            let g = self.groups[gi];
            let slot = &mut self.slots[g.slot];
            let grants = slot.granted.len();
            served += grants;
            if slot.job.is_complete() {
                slot.job.finalize();
                slot.state = JobState::Finished;
            }
            let row = &mut self.metrics.jobs[g.slot];
            row.rounds_served += grants as u64;
            row.payload_bits += g.payload;
            row.side_bits += g.side;
            self.metrics.spent_payload_bits += g.payload;
        }
        served
    }

    /// Run fleet rounds until no job is live or `max_fleet_rounds` have
    /// executed; returns how many ran.
    pub fn run(&mut self, max_fleet_rounds: usize) -> usize {
        let mut ran = 0;
        while ran < max_fleet_rounds && self.live_jobs() > 0 {
            self.run_round();
            ran += 1;
        }
        ran
    }

    fn slot(&self, id: JobId) -> Result<&JobSlot, ServeError> {
        self.slots.iter().find(|s| s.id == id).ok_or(ServeError::UnknownJob(id))
    }

    fn slot_mut(&mut self, id: JobId) -> Result<&mut JobSlot, ServeError> {
        self.slots.iter_mut().find(|s| s.id == id).ok_or(ServeError::UnknownJob(id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::registry::CompressorSpec;

    fn spec(name: &str, scheme: &str, r: f32, rounds: usize, seed: u64) -> JobSpec {
        JobSpec::new(name, CompressorSpec::parse(scheme).unwrap(), r, 16, rounds, seed)
    }

    #[test]
    fn lifecycle_transitions_are_enforced() {
        let mut srv = JobServer::new(1 << 20, Policy::Drr);
        let id = srv.submit(spec("a", "ndsc-dith", 1.0, 8, 1)).unwrap();
        assert_eq!(srv.state(id), Some(JobState::Running));
        srv.pause(id).unwrap();
        assert_eq!(srv.state(id), Some(JobState::Paused));
        assert!(matches!(srv.pause(id), Err(ServeError::BadState { .. })));
        srv.resume(id).unwrap();
        assert!(matches!(srv.resume(id), Err(ServeError::BadState { .. })));
        srv.run(64);
        assert_eq!(srv.state(id), Some(JobState::Finished));
        assert!(matches!(srv.cancel(id), Err(ServeError::BadState { .. })));
        assert!(matches!(srv.pause(99), Err(ServeError::UnknownJob(99))));
        assert!(srv.job(id).unwrap().trace().final_x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn oversized_admitted_tenant_bypasses_class_ceiling_and_finishes() {
        // Budget 80, all three classes live: reservations are 30/20/10,
        // common 20, so gold's class ceiling is 30+20 = 50 — below the
        // gold qsgd tenant's only rung (64 bits). It is admitted
        // (64 ≤ 80), so the reservation cap must yield: without the
        // oversized bypass this job would be starved forever.
        let mut srv = JobServer::new(80, Policy::Drr);
        let g = srv
            .submit(spec("g-qsgd", "qsgd", 4.0, 3, 1).with_qos(QosClass::Gold))
            .unwrap();
        let s = srv.submit(spec("s-sd", "sd", 0.5, 5, 2)).unwrap();
        let b = srv
            .submit(spec("b-randk", "randk1b", 0.25, 5, 3).with_qos(QosClass::Bronze))
            .unwrap();
        srv.run(256);
        for id in [g, s, b] {
            assert_eq!(srv.state(id), Some(JobState::Finished), "job {id} starved");
        }
        assert_eq!(srv.job(g).unwrap().rounds_done(), 3);
    }

    #[test]
    fn paused_jobs_are_skipped_cancelled_jobs_keep_their_trace() {
        let mut srv = JobServer::new(1 << 20, Policy::Drr);
        let a = srv.submit(spec("a", "ndsc-dith", 1.0, 50, 1)).unwrap();
        let b = srv.submit(spec("b", "sd", 0.5, 50, 2)).unwrap();
        srv.run_round();
        srv.pause(a).unwrap();
        let a_rounds = srv.job(a).unwrap().rounds_done();
        for _ in 0..5 {
            srv.run_round();
        }
        assert_eq!(srv.job(a).unwrap().rounds_done(), a_rounds, "paused job must not advance");
        assert_eq!(srv.job(b).unwrap().rounds_done(), 6);
        srv.cancel(b).unwrap();
        assert_eq!(srv.state(b), Some(JobState::Cancelled));
        let tb = srv.job(b).unwrap().trace();
        assert!(!tb.final_x.is_empty(), "cancelled job's partial trace is finalized");
        srv.resume(a).unwrap();
        srv.run(256);
        assert_eq!(srv.state(a), Some(JobState::Finished));
    }

    #[test]
    fn evict_removes_slot_and_metrics_in_lockstep() {
        let mut srv = JobServer::new(1 << 20, Policy::Drr);
        let a = srv.submit(spec("a", "ndsc-dith", 1.0, 50, 1)).unwrap();
        let b = srv.submit(spec("b", "sd", 0.5, 50, 2)).unwrap();
        let c = srv.submit(spec("c", "ndsc-dith", 1.0, 50, 3)).unwrap();
        srv.run_round();
        let job = srv.evict(b).unwrap();
        assert_eq!(job.spec().name, "b");
        assert!(matches!(srv.evict(b), Err(ServeError::UnknownJob(_))));
        assert_eq!(srv.job_ids().collect::<Vec<_>>(), vec![a, c]);
        assert_eq!(srv.metrics().jobs.len(), 2);
        assert_eq!(srv.metrics().jobs[1].name, "c");
        // The survivors keep being scheduled to completion.
        srv.run(256);
        assert_eq!(srv.state(a), Some(JobState::Finished));
        assert_eq!(srv.state(c), Some(JobState::Finished));
        assert_eq!(srv.metrics().jobs[0].rounds_served, 50);
    }

    #[test]
    fn rung_tracks_last_granted_level_and_restores_with_deficit() {
        // Scarce adaptive fleet: jobs get downgraded rungs; checkpoint
        // then restore into a fresh fleet must carry both the banked
        // deficit and the rung.
        let mut srv = JobServer::new(40, Policy::DrrAdaptive);
        let a = srv.submit(spec("a", "ndsc-dith", 1.0, 400, 1)).unwrap();
        let _b = srv.submit(spec("b", "ndsc-dith", 1.0, 400, 2)).unwrap();
        assert_eq!(srv.last_rung(a), Some(None), "no grant yet, no rung");
        for _ in 0..12 {
            srv.run_round();
        }
        let rung = srv.last_rung(a).unwrap();
        assert!(rung.is_some(), "12 scarce rounds must have granted job a at least once");
        let deficit = srv.deficit_bits(a).unwrap();
        let snap = srv.checkpoint(a).unwrap();
        let mut dst = JobServer::new(40, Policy::DrrAdaptive);
        let a2 = dst.restore(&snap).unwrap();
        assert_eq!(dst.deficit_bits(a2), Some(deficit), "banked credit survives restore");
        assert_eq!(dst.last_rung(a2), Some(rung), "adaptive rung survives restore");
    }

    #[test]
    fn admission_rejects_what_the_budget_cannot_serve() {
        // qsgd at R=4, n=16 costs 64 bits/round; a 10-bit fleet can never
        // grant it under strict DRR.
        let mut srv = JobServer::new(10, Policy::Drr);
        match srv.submit(spec("greedy", "qsgd", 4.0, 8, 1)) {
            Err(ServeError::Infeasible { needed_bits, budget_bits }) => {
                assert_eq!(needed_bits, 64);
                assert_eq!(budget_bits, 10);
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
        // An idle fleet does not advance its round counter.
        assert_eq!(srv.run_round(), 0);
        assert_eq!(srv.round(), 0);
    }

    #[test]
    fn epoch_grants_match_sequential_rounds() {
        // The batched grant pass must be indistinguishable from the fused
        // per-round loop: same grants, same deficits, same rungs, same
        // metrics, same traces — under a scarce adaptive budget where the
        // DRR arithmetic actually bites, and across ragged epoch sizes
        // that straddle job completions.
        let build = || {
            let mut srv = JobServer::new(96, Policy::DrrAdaptive);
            srv.submit(spec("g", "ndsc-dith", 1.0, 7, 11).with_qos(QosClass::Gold)).unwrap();
            srv.submit(spec("s", "sd", 0.5, 23, 12)).unwrap();
            srv.submit(spec("b", "ndsc-dith", 1.0, 23, 13).with_qos(QosClass::Bronze)).unwrap();
            srv
        };
        let mut lockstep = build();
        let mut epoch = build();
        let mut served_lock = 0usize;
        let mut served_epoch = 0usize;
        for &chunk in &[1usize, 3, 8, 16, 5] {
            for _ in 0..chunk {
                served_lock += lockstep.run_round();
            }
            served_epoch += epoch.run_epoch(chunk);
            assert_eq!(served_lock, served_epoch, "served diverged at chunk {chunk}");
        }
        assert_eq!(lockstep.round(), epoch.round());
        for id in lockstep.job_ids().collect::<Vec<_>>() {
            assert_eq!(lockstep.state(id), epoch.state(id), "state diverged for job {id}");
            assert_eq!(
                lockstep.deficit_bits(id),
                epoch.deficit_bits(id),
                "deficit diverged for job {id}"
            );
            assert_eq!(lockstep.last_rung(id), epoch.last_rung(id), "rung diverged for job {id}");
            let (a, b) = (lockstep.job(id).unwrap(), epoch.job(id).unwrap());
            assert_eq!(a.rounds_done(), b.rounds_done(), "rounds diverged for job {id}");
            assert_eq!(
                a.trace().total_payload_bits,
                b.trace().total_payload_bits,
                "payload diverged for job {id}"
            );
        }
        let (ma, mb) = (lockstep.metrics(), epoch.metrics());
        assert_eq!(ma.spent_payload_bits, mb.spent_payload_bits);
        for (ra, rb) in ma.jobs.iter().zip(&mb.jobs) {
            assert_eq!(ra.rounds_served, rb.rounds_served, "row diverged for {}", ra.name);
            assert_eq!(ra.payload_bits, rb.payload_bits, "row diverged for {}", ra.name);
            assert_eq!(ra.side_bits, rb.side_bits, "row diverged for {}", ra.name);
        }
    }

    #[test]
    fn accounting_tracks_measured_bits_per_job() {
        let mut srv = JobServer::new(1 << 20, Policy::Drr);
        let a = srv.submit(spec("a", "ndsc-dith", 1.0, 10, 1)).unwrap();
        srv.run(64);
        let m = srv.metrics();
        assert_eq!(m.jobs.len(), 1);
        assert_eq!(m.jobs[0].rounds_served, 10);
        let tr = srv.job(a).unwrap().trace();
        assert_eq!(m.jobs[0].payload_bits, tr.total_payload_bits as u64);
        assert_eq!(m.jobs[0].side_bits, tr.total_side_bits as u64);
        assert_eq!(m.spent_payload_bits, tr.total_payload_bits as u64);
        assert!(m.utilization() > 0.0);
    }
}
