//! The job server: registry, lifecycle, and the per-round serve loop.
//!
//! A [`JobServer`] hosts any number of [`Job`]s over one global
//! bits-per-round budget. [`JobServer::run_round`] executes one fleet
//! round: deficit accrual, rotation, level selection and at most one
//! engine round per granted job — all allocation-free once warm
//! (`rust/tests/test_alloc.rs`, phase 4). Lifecycle transitions
//! (`submit`/`pause`/`resume`/`cancel`) take effect between fleet
//! rounds; a paused job's state is untouched until resume, so its trace
//! continues exactly where it stopped.
//!
//! **QoS.** Each job carries a [`QosClass`]: its DRR quantum is the
//! weighted share `⌊B·w_j/Σ_live w⌋`, and every class with live members
//! holds a reserved slice of the round budget
//! ([`QosClass::reserve_num`]/[`scheduler::RESERVE_DENOM`]) that only
//! its own members may draw — a granted job spends its class reserve
//! first, then the common pool. Single-class fleets reduce exactly to
//! the unweighted scheduler, so pre-QoS traces are unchanged.
//!
//! **Threaded granted rounds.** [`JobServer::enable_fanout`] switches
//! granted rounds from the inline engine to the threaded executor
//! ([`Job::step_round_mt`]) whenever the never-nest gate
//! ([`crate::coordinator::config::fleet_fanout_threads`]) allows — the
//! per-worker scratch comes from a fleet-owned (or cluster-shared)
//! [`ChannelPools`]. Traces are bit-identical either way, so a fleet may
//! flip fan-out on or off mid-run.

use std::io;
use std::sync::Arc;

use crate::coordinator::channel::ChannelPools;
use crate::coordinator::config;
use crate::coordinator::metrics::{FleetMetrics, JobBits};
use crate::serve::checkpoint::{self, SchedTrailer};
use crate::serve::job::{Job, JobSpec};
use crate::serve::scheduler::{self, Deficit, Policy, QosClass};

/// Fleet-assigned job handle.
pub type JobId = u64;

/// Lifecycle state of a submitted job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Eligible for scheduling.
    Running,
    /// Parked: not scheduled, state frozen, resumable.
    Paused,
    /// All configured rounds executed; trace finalized.
    Finished,
    /// Terminated early by the operator; partial trace finalized.
    Cancelled,
}

impl std::fmt::Display for JobState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            JobState::Running => "running",
            JobState::Paused => "paused",
            JobState::Finished => "finished",
            JobState::Cancelled => "cancelled",
        })
    }
}

/// Errors of the serving API.
#[derive(Debug)]
pub enum ServeError {
    /// No job with that id was ever submitted.
    UnknownJob(JobId),
    /// The spec failed [`Job::build`] validation.
    InvalidSpec(String),
    /// Admission control: the job's cheapest grantable round exceeds the
    /// global per-round budget, so the scheduler could never serve it.
    Infeasible {
        /// Cheapest per-round cost the policy could grant.
        needed_bits: u64,
        /// The fleet's global budget.
        budget_bits: usize,
    },
    /// The operation is not valid in the job's current lifecycle state.
    BadState {
        /// The job.
        id: JobId,
        /// Its current state.
        state: JobState,
        /// The rejected operation.
        op: &'static str,
    },
    /// A checkpoint round-trip inside a compound operation (migration)
    /// failed; the message carries the underlying snapshot error.
    Snapshot(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownJob(id) => write!(f, "unknown job {id}"),
            ServeError::InvalidSpec(e) => write!(f, "invalid job spec: {e}"),
            ServeError::Infeasible { needed_bits, budget_bits } => write!(
                f,
                "admission rejected: cheapest grantable round needs {needed_bits} bits but the \
                 global budget is {budget_bits} bits/round"
            ),
            ServeError::BadState { id, state, op } => {
                write!(f, "cannot {op} job {id} in state {state}")
            }
            ServeError::Snapshot(e) => write!(f, "checkpoint round-trip failed: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

struct JobSlot {
    id: JobId,
    state: JobState,
    deficit: Deficit,
    /// Last granted ladder level (`None` until the first grant) — the
    /// adaptive-R rung that travels in the checkpoint trailer so a
    /// restored job's observability picks up where it left off.
    rung: Option<u8>,
    job: Job,
}

/// The multi-job server (see the [module docs](self)).
pub struct JobServer {
    policy: Policy,
    budget_bits: usize,
    slots: Vec<JobSlot>,
    metrics: FleetMetrics,
    cursor: usize,
    next_id: JobId,
    /// Recycled threaded-round scratch (shared across the cluster when
    /// this fleet was built by [`JobServer::with_pools`]).
    pools: Arc<ChannelPools>,
    /// `Some(active_fleets)` once [`JobServer::enable_fanout`] armed
    /// threaded granted rounds; `None` (the default) steps inline.
    fanout_fleets: Option<usize>,
}

impl JobServer {
    /// A fleet offering `budget_bits_per_round` payload bits per fleet
    /// round, arbitrated by `policy`.
    pub fn new(budget_bits_per_round: usize, policy: Policy) -> Self {
        Self::with_pools(budget_bits_per_round, policy, Arc::new(ChannelPools::new(8)))
    }

    /// Like [`JobServer::new`], with a caller-provided buffer pool — the
    /// cluster hands every member fleet one shared pool so migrated
    /// jobs' scratch is recycled fleet-to-fleet.
    pub fn with_pools(
        budget_bits_per_round: usize,
        policy: Policy,
        pools: Arc<ChannelPools>,
    ) -> Self {
        JobServer {
            policy,
            budget_bits: budget_bits_per_round,
            slots: Vec::new(),
            metrics: FleetMetrics {
                budget_bits_per_round,
                ..Default::default()
            },
            cursor: 0,
            next_id: 0,
            pools,
            fanout_fleets: None,
        }
    }

    /// Arm threaded granted rounds: with `active_fleets` fleets running
    /// concurrently, each granted job's worker phase fans out over at
    /// most `FLEET_MAX_WORKER_THREADS / active_fleets` scoped threads
    /// (never-nest cap; see
    /// [`crate::coordinator::config::fleet_fanout_threads`]). Jobs the
    /// gate declines (single-worker, kernel-parallel dims, exhausted
    /// allowance) keep stepping inline. Idempotent; pass the cluster's
    /// fleet count, or `1` for a solo fleet.
    pub fn enable_fanout(&mut self, active_fleets: usize) {
        self.fanout_fleets = Some(active_fleets.max(1));
    }

    /// The fleet's recycled threaded-round buffer pool.
    pub fn pools(&self) -> &Arc<ChannelPools> {
        &self.pools
    }

    /// The fleet's arbitration policy.
    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// The global per-round budget.
    pub fn budget_bits(&self) -> usize {
        self.budget_bits
    }

    /// Fleet rounds executed so far.
    pub fn round(&self) -> u64 {
        self.metrics.fleet_rounds
    }

    /// Jobs currently eligible for scheduling.
    pub fn live_jobs(&self) -> usize {
        self.slots.iter().filter(|s| s.state == JobState::Running).count()
    }

    /// All submitted job ids, in submission order.
    pub fn job_ids(&self) -> impl Iterator<Item = JobId> + '_ {
        self.slots.iter().map(|s| s.id)
    }

    /// Aggregate + per-job accounting.
    pub fn metrics(&self) -> &FleetMetrics {
        &self.metrics
    }

    /// Validate, build and admit a job. Admission requires the cheapest
    /// round the policy could ever grant to fit the global budget —
    /// otherwise the job could never transmit and would starve by
    /// construction.
    pub fn submit(&mut self, spec: JobSpec) -> Result<JobId, ServeError> {
        let job = Job::build(spec).map_err(ServeError::InvalidSpec)?;
        let needed = job.min_cost_bits(self.policy);
        if needed > self.budget_bits as u64 {
            return Err(ServeError::Infeasible { needed_bits: needed, budget_bits: self.budget_bits });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.metrics.jobs.push(JobBits { job: id, name: job.spec().name.clone(), ..Default::default() });
        self.slots.push(JobSlot {
            id,
            state: JobState::Running,
            deficit: Deficit::default(),
            rung: None,
            job,
        });
        Ok(id)
    }

    /// Restore a checkpointed job into this fleet (a fresh id is
    /// assigned; accounting rows are seeded from the snapshot's trace
    /// totals so per-job bits stay cumulative across restores). The
    /// restored job is admitted like any submission. Scheduler state in
    /// the trailer — banked DRR deficit (clamped to the classic DRR cap
    /// so a foreign snapshot cannot bank unbounded credit here) and the
    /// adaptive-R rung — resumes intact, which is what makes a
    /// mid-deficit fleet-to-fleet migration trace-neutral.
    pub fn restore(&mut self, bytes: &[u8]) -> io::Result<JobId> {
        let (job, sched) = checkpoint::restore_with_sched(bytes)?;
        let needed = job.min_cost_bits(self.policy);
        if needed > self.budget_bits as u64 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "restored job needs {needed} bits/round but the fleet budget is {} bits/round",
                    self.budget_bits
                ),
            ));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.metrics.jobs.push(JobBits {
            job: id,
            name: job.spec().name.clone(),
            rounds_served: job.rounds_done() as u64,
            payload_bits: job.trace().total_payload_bits as u64,
            side_bits: job.trace().total_side_bits as u64,
        });
        let cost = job.requested_cost_bits();
        let cap = Deficit::cap(scheduler::quantum(self.budget_bits, 1), cost);
        let state = if job.is_complete() { JobState::Finished } else { JobState::Running };
        let mut slot = JobSlot {
            id,
            state,
            deficit: Deficit { bits: sched.deficit_bits.min(cap) },
            rung: sched.rung,
            job,
        };
        if slot.state == JobState::Finished {
            slot.job.finalize();
        }
        self.slots.push(slot);
        Ok(id)
    }

    /// Serialize a resumable snapshot of a `Running`/`Paused` job,
    /// scheduler trailer (banked deficit, adaptive-R rung, QoS class)
    /// included — fleet-independent by construction, so any fleet (this
    /// one or a migration target) restores it bit-for-bit.
    pub fn checkpoint(&self, id: JobId) -> Result<Vec<u8>, ServeError> {
        let slot = self.slot(id)?;
        match slot.state {
            // A Running/Paused job is never finalized (the fleet
            // finalizes and marks Finished in the same round), so the
            // writer's finalized-job refusal is unreachable here; map it
            // to BadState defensively rather than panicking.
            JobState::Running | JobState::Paused => {
                let sched = SchedTrailer {
                    deficit_bits: slot.deficit.bits,
                    rung: slot.rung,
                    qos: slot.job.spec().qos,
                };
                checkpoint::save_with_sched(&slot.job, &sched)
                    .map_err(|_| ServeError::BadState { id, state: slot.state, op: "checkpoint" })
            }
            state => Err(ServeError::BadState { id, state, op: "checkpoint" }),
        }
    }

    /// Remove a job from the registry entirely, returning it — the
    /// drain step of a fleet-to-fleet migration (snapshot first via
    /// [`JobServer::checkpoint`]; the trailer carries the scheduler
    /// state eviction discards here). The job's threaded-round scratch
    /// goes back to the fleet pool, and its metrics row leaves with it
    /// so slot/metrics stay in lockstep.
    pub fn evict(&mut self, id: JobId) -> Result<Job, ServeError> {
        let j = self
            .slots
            .iter()
            .position(|s| s.id == id)
            .ok_or(ServeError::UnknownJob(id))?;
        let mut slot = self.slots.remove(j);
        self.metrics.jobs.remove(j);
        // Keep the rotation anchored on the same successor slot.
        if j < self.cursor {
            self.cursor -= 1;
        }
        if self.slots.is_empty() {
            self.cursor = 0;
        } else {
            self.cursor %= self.slots.len();
        }
        slot.job.release_mt(&self.pools);
        Ok(slot.job)
    }

    /// Park a running job: it keeps its place in the registry but is
    /// skipped by the scheduler until [`JobServer::resume`].
    pub fn pause(&mut self, id: JobId) -> Result<(), ServeError> {
        let slot = self.slot_mut(id)?;
        match slot.state {
            JobState::Running => {
                slot.state = JobState::Paused;
                Ok(())
            }
            state => Err(ServeError::BadState { id, state, op: "pause" }),
        }
    }

    /// Unpark a paused job.
    pub fn resume(&mut self, id: JobId) -> Result<(), ServeError> {
        let slot = self.slot_mut(id)?;
        match slot.state {
            JobState::Paused => {
                slot.state = JobState::Running;
                Ok(())
            }
            state => Err(ServeError::BadState { id, state, op: "resume" }),
        }
    }

    /// Terminate a running or paused job. Its partial trace is finalized
    /// and remains readable via [`JobServer::job`].
    pub fn cancel(&mut self, id: JobId) -> Result<(), ServeError> {
        let slot = self.slot_mut(id)?;
        match slot.state {
            JobState::Running | JobState::Paused => {
                slot.job.finalize();
                slot.state = JobState::Cancelled;
                Ok(())
            }
            state => Err(ServeError::BadState { id, state, op: "cancel" }),
        }
    }

    /// A job's lifecycle state.
    pub fn state(&self, id: JobId) -> Option<JobState> {
        self.slots.iter().find(|s| s.id == id).map(|s| s.state)
    }

    /// Read access to a submitted job (trace, spec, progress).
    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.slots.iter().find(|s| s.id == id).map(|s| &s.job)
    }

    /// A job's current deficit counter (invariant checks / debugging).
    pub fn deficit_bits(&self, id: JobId) -> Option<u64> {
        self.slots.iter().find(|s| s.id == id).map(|s| s.deficit.bits)
    }

    /// A job's last granted ladder level (`None` until first grant) —
    /// the adaptive-R rung preserved across checkpoint/restore.
    pub fn last_rung(&self, id: JobId) -> Option<Option<u8>> {
        self.slots.iter().find(|s| s.id == id).map(|s| s.rung)
    }

    /// Execute one fleet round (see the [scheduler docs]). Returns the
    /// number of jobs granted an engine round. A fleet with no live job
    /// is idle: nothing runs and the round counter does not advance.
    ///
    /// Per round: every class with live members gets its reserved slice
    /// of the budget; each live job accrues its weighted quantum, and a
    /// granted job's cost is drawn from its class reserve first, then
    /// the common pool. With one live class this is arithmetic-identical
    /// to the unweighted scheduler (the reserve and common pool are one
    /// undifferentiated budget).
    ///
    /// [scheduler docs]: crate::serve::scheduler
    pub fn run_round(&mut self) -> usize {
        let live = self.live_jobs();
        if live == 0 {
            return 0;
        }
        // Class census → weighted quanta + per-class reservations.
        let mut live_weight = [0u64; QosClass::ALL.len()];
        for s in &self.slots {
            if s.state == JobState::Running {
                live_weight[s.job.spec().qos.index()] += s.job.spec().qos.weight();
            }
        }
        let total_weight: u64 = live_weight.iter().sum();
        let budget = self.budget_bits as u64;
        let mut reserved = [0u64; QosClass::ALL.len()];
        for c in QosClass::ALL {
            if live_weight[c.index()] > 0 {
                reserved[c.index()] = budget * c.reserve_num() / scheduler::RESERVE_DENOM;
            }
        }
        // Idle classes' slices stay in the common pool.
        let mut common = budget - reserved.iter().sum::<u64>();
        // A class's steady-state ceiling: its own reserve plus the common
        // pool. An *admitted* job whose cheapest rung exceeds this ceiling
        // would be starved forever by the reservations alone, breaking the
        // admission contract — such jobs bypass the class cap and draw on
        // the whole remaining budget instead (reservations yield to the
        // admission guarantee, never the other way around).
        let mut class_ceiling = [0u64; QosClass::ALL.len()];
        for c in QosClass::ALL {
            class_ceiling[c.index()] = reserved[c.index()] + common;
        }
        let mut served = 0usize;
        let nslots = self.slots.len();
        for k in 0..nslots {
            let j = (self.cursor + k) % nslots;
            let slot = &mut self.slots[j];
            if slot.state != JobState::Running {
                continue;
            }
            let class = slot.job.spec().qos;
            let quantum =
                scheduler::weighted_quantum(self.budget_bits, class.weight(), total_weight);
            slot.deficit.accrue(quantum, slot.job.requested_cost_bits());
            let oversized = slot.job.min_cost_bits(self.policy) > class_ceiling[class.index()];
            let pool = if oversized {
                reserved.iter().sum::<u64>() + common
            } else {
                reserved[class.index()] + common
            };
            let afford = slot.deficit.bits.min(pool);
            if let Some(lvl) = slot.job.pick_level(self.policy, afford) {
                let cost = slot.job.level_cost(lvl);
                let threads = self.fanout_fleets.and_then(|fleets| {
                    config::fleet_fanout_threads(
                        slot.job.spec().workers,
                        slot.job.spec().n,
                        fleets,
                    )
                });
                let (payload, side) = match threads {
                    Some(t) => slot.job.step_round_mt(lvl, t, &self.pools),
                    None => slot.job.step_round(lvl),
                };
                // Draw the class reserve down first, then the common pool,
                // then (oversized bypass only) other classes' reserves.
                // `afford ≤ pool` guarantees the drain terminates at zero.
                let mut owed = cost;
                let take = owed.min(reserved[class.index()]);
                reserved[class.index()] -= take;
                owed -= take;
                let take = owed.min(common);
                common -= take;
                owed -= take;
                if owed > 0 {
                    for c in QosClass::ALL {
                        let take = owed.min(reserved[c.index()]);
                        reserved[c.index()] -= take;
                        owed -= take;
                    }
                }
                debug_assert_eq!(owed, 0, "grant exceeded the round budget");
                slot.deficit.charge(cost);
                slot.rung = Some(lvl as u8);
                served += 1;
                if slot.job.is_complete() {
                    slot.job.finalize();
                    slot.state = JobState::Finished;
                }
                let row = &mut self.metrics.jobs[j];
                row.rounds_served += 1;
                row.payload_bits += payload;
                row.side_bits += side;
                self.metrics.spent_payload_bits += payload;
            }
        }
        self.cursor = (self.cursor + 1) % nslots;
        self.metrics.fleet_rounds += 1;
        served
    }

    /// Run fleet rounds until no job is live or `max_fleet_rounds` have
    /// executed; returns how many ran.
    pub fn run(&mut self, max_fleet_rounds: usize) -> usize {
        let mut ran = 0;
        while ran < max_fleet_rounds && self.live_jobs() > 0 {
            self.run_round();
            ran += 1;
        }
        ran
    }

    fn slot(&self, id: JobId) -> Result<&JobSlot, ServeError> {
        self.slots.iter().find(|s| s.id == id).ok_or(ServeError::UnknownJob(id))
    }

    fn slot_mut(&mut self, id: JobId) -> Result<&mut JobSlot, ServeError> {
        self.slots.iter_mut().find(|s| s.id == id).ok_or(ServeError::UnknownJob(id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::registry::CompressorSpec;

    fn spec(name: &str, scheme: &str, r: f32, rounds: usize, seed: u64) -> JobSpec {
        JobSpec::new(name, CompressorSpec::parse(scheme).unwrap(), r, 16, rounds, seed)
    }

    #[test]
    fn lifecycle_transitions_are_enforced() {
        let mut srv = JobServer::new(1 << 20, Policy::Drr);
        let id = srv.submit(spec("a", "ndsc-dith", 1.0, 8, 1)).unwrap();
        assert_eq!(srv.state(id), Some(JobState::Running));
        srv.pause(id).unwrap();
        assert_eq!(srv.state(id), Some(JobState::Paused));
        assert!(matches!(srv.pause(id), Err(ServeError::BadState { .. })));
        srv.resume(id).unwrap();
        assert!(matches!(srv.resume(id), Err(ServeError::BadState { .. })));
        srv.run(64);
        assert_eq!(srv.state(id), Some(JobState::Finished));
        assert!(matches!(srv.cancel(id), Err(ServeError::BadState { .. })));
        assert!(matches!(srv.pause(99), Err(ServeError::UnknownJob(99))));
        assert!(srv.job(id).unwrap().trace().final_x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn oversized_admitted_tenant_bypasses_class_ceiling_and_finishes() {
        // Budget 80, all three classes live: reservations are 30/20/10,
        // common 20, so gold's class ceiling is 30+20 = 50 — below the
        // gold qsgd tenant's only rung (64 bits). It is admitted
        // (64 ≤ 80), so the reservation cap must yield: without the
        // oversized bypass this job would be starved forever.
        let mut srv = JobServer::new(80, Policy::Drr);
        let g = srv
            .submit(spec("g-qsgd", "qsgd", 4.0, 3, 1).with_qos(QosClass::Gold))
            .unwrap();
        let s = srv.submit(spec("s-sd", "sd", 0.5, 5, 2)).unwrap();
        let b = srv
            .submit(spec("b-randk", "randk1b", 0.25, 5, 3).with_qos(QosClass::Bronze))
            .unwrap();
        srv.run(256);
        for id in [g, s, b] {
            assert_eq!(srv.state(id), Some(JobState::Finished), "job {id} starved");
        }
        assert_eq!(srv.job(g).unwrap().rounds_done(), 3);
    }

    #[test]
    fn paused_jobs_are_skipped_cancelled_jobs_keep_their_trace() {
        let mut srv = JobServer::new(1 << 20, Policy::Drr);
        let a = srv.submit(spec("a", "ndsc-dith", 1.0, 50, 1)).unwrap();
        let b = srv.submit(spec("b", "sd", 0.5, 50, 2)).unwrap();
        srv.run_round();
        srv.pause(a).unwrap();
        let a_rounds = srv.job(a).unwrap().rounds_done();
        for _ in 0..5 {
            srv.run_round();
        }
        assert_eq!(srv.job(a).unwrap().rounds_done(), a_rounds, "paused job must not advance");
        assert_eq!(srv.job(b).unwrap().rounds_done(), 6);
        srv.cancel(b).unwrap();
        assert_eq!(srv.state(b), Some(JobState::Cancelled));
        let tb = srv.job(b).unwrap().trace();
        assert!(!tb.final_x.is_empty(), "cancelled job's partial trace is finalized");
        srv.resume(a).unwrap();
        srv.run(256);
        assert_eq!(srv.state(a), Some(JobState::Finished));
    }

    #[test]
    fn evict_removes_slot_and_metrics_in_lockstep() {
        let mut srv = JobServer::new(1 << 20, Policy::Drr);
        let a = srv.submit(spec("a", "ndsc-dith", 1.0, 50, 1)).unwrap();
        let b = srv.submit(spec("b", "sd", 0.5, 50, 2)).unwrap();
        let c = srv.submit(spec("c", "ndsc-dith", 1.0, 50, 3)).unwrap();
        srv.run_round();
        let job = srv.evict(b).unwrap();
        assert_eq!(job.spec().name, "b");
        assert!(matches!(srv.evict(b), Err(ServeError::UnknownJob(_))));
        assert_eq!(srv.job_ids().collect::<Vec<_>>(), vec![a, c]);
        assert_eq!(srv.metrics().jobs.len(), 2);
        assert_eq!(srv.metrics().jobs[1].name, "c");
        // The survivors keep being scheduled to completion.
        srv.run(256);
        assert_eq!(srv.state(a), Some(JobState::Finished));
        assert_eq!(srv.state(c), Some(JobState::Finished));
        assert_eq!(srv.metrics().jobs[0].rounds_served, 50);
    }

    #[test]
    fn rung_tracks_last_granted_level_and_restores_with_deficit() {
        // Scarce adaptive fleet: jobs get downgraded rungs; checkpoint
        // then restore into a fresh fleet must carry both the banked
        // deficit and the rung.
        let mut srv = JobServer::new(40, Policy::DrrAdaptive);
        let a = srv.submit(spec("a", "ndsc-dith", 1.0, 400, 1)).unwrap();
        let _b = srv.submit(spec("b", "ndsc-dith", 1.0, 400, 2)).unwrap();
        assert_eq!(srv.last_rung(a), Some(None), "no grant yet, no rung");
        for _ in 0..12 {
            srv.run_round();
        }
        let rung = srv.last_rung(a).unwrap();
        assert!(rung.is_some(), "12 scarce rounds must have granted job a at least once");
        let deficit = srv.deficit_bits(a).unwrap();
        let snap = srv.checkpoint(a).unwrap();
        let mut dst = JobServer::new(40, Policy::DrrAdaptive);
        let a2 = dst.restore(&snap).unwrap();
        assert_eq!(dst.deficit_bits(a2), Some(deficit), "banked credit survives restore");
        assert_eq!(dst.last_rung(a2), Some(rung), "adaptive rung survives restore");
    }

    #[test]
    fn admission_rejects_what_the_budget_cannot_serve() {
        // qsgd at R=4, n=16 costs 64 bits/round; a 10-bit fleet can never
        // grant it under strict DRR.
        let mut srv = JobServer::new(10, Policy::Drr);
        match srv.submit(spec("greedy", "qsgd", 4.0, 8, 1)) {
            Err(ServeError::Infeasible { needed_bits, budget_bits }) => {
                assert_eq!(needed_bits, 64);
                assert_eq!(budget_bits, 10);
            }
            other => panic!("expected Infeasible, got {other:?}"),
        }
        // An idle fleet does not advance its round counter.
        assert_eq!(srv.run_round(), 0);
        assert_eq!(srv.round(), 0);
    }

    #[test]
    fn accounting_tracks_measured_bits_per_job() {
        let mut srv = JobServer::new(1 << 20, Policy::Drr);
        let a = srv.submit(spec("a", "ndsc-dith", 1.0, 10, 1)).unwrap();
        srv.run(64);
        let m = srv.metrics();
        assert_eq!(m.jobs.len(), 1);
        assert_eq!(m.jobs[0].rounds_served, 10);
        let tr = srv.job(a).unwrap().trace();
        assert_eq!(m.jobs[0].payload_bits, tr.total_payload_bits as u64);
        assert_eq!(m.jobs[0].side_bits, tr.total_side_bits as u64);
        assert_eq!(m.spent_payload_bits, tr.total_payload_bits as u64);
        assert!(m.utilization() > 0.0);
    }
}
