//! Deficit-round-robin arbitration of the global bit budget.
//!
//! The fleet offers `B` payload bits per fleet round. Each live job `j`
//! accrues a **quantum** `q = max(1, B / live_jobs)` of credit per round
//! into a deficit counter and may transmit when (a) its counter covers a
//! ladder level's nominal cost and (b) the round's remaining budget
//! does. Service order rotates one slot per round, so every live job is
//! periodically first in line with the full budget available.
//!
//! Guarantees (property-tested in `rust/tests/test_serve.rs`):
//!
//! * **Bounded deficit** — counters are capped at `cost + quantum`
//!   ([`Deficit::accrue`]); credit beyond "can afford the requested
//!   level, plus one round of slack" buys nothing and would let an
//!   unserviceable job bank unbounded credit.
//! * **Starvation-freedom** — admission requires every job's cheapest
//!   grantable level to fit inside `B` ([`crate::serve::fleet`]); with
//!   rotation and quantum accrual, job `j` transmits at least once every
//!   `jobs · (⌈cost_j/q⌉ + 1)` fleet rounds, adversarial mixes included.
//!
//! Bits are the **arbitrable resource** here exactly as in the
//! per-round-budget framing of Mayekar & Tyagi (2020) and Michelusi et
//! al. (2020): the scheduler splits a shared precision budget across
//! tenants round by round.

/// Which arbitration rule the fleet runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Strict deficit round robin: a job only ever transmits at its
    /// requested budget `R` (ladder level 0). Trace-preserving: a job's
    /// rounds are bit-identical to a solo run at any contention level.
    Drr,
    /// DRR with budget degradation: under contention a job may be
    /// granted a deeper (cheaper) ladder level `R_i < R`. Higher fleet
    /// utilization; per-round precision becomes contention-dependent.
    DrrAdaptive,
}

impl Policy {
    /// Canonical CLI name (`repro serve policy=<name>`).
    pub fn name(self) -> &'static str {
        match self {
            Policy::Drr => "drr",
            Policy::DrrAdaptive => "adaptive",
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "drr" => Some(Policy::Drr),
            "adaptive" | "drr-adaptive" => Some(Policy::DrrAdaptive),
            _ => None,
        }
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-job deficit counter (bits of banked transmission credit).
#[derive(Clone, Copy, Debug, Default)]
pub struct Deficit {
    /// Banked credit in payload bits.
    pub bits: u64,
}

impl Deficit {
    /// Accrue one round's quantum, capped at `cost + quantum` where
    /// `cost` is the job's requested-level cost — the classic DRR bound
    /// that keeps counters finite for jobs the budget cannot serve this
    /// round.
    pub fn accrue(&mut self, quantum: u64, cost: u64) {
        self.bits = (self.bits + quantum).min(cost.saturating_add(quantum));
    }

    /// Spend `cost` bits of credit after a granted transmission.
    pub fn charge(&mut self, cost: u64) {
        self.bits = self.bits.saturating_sub(cost);
    }

    /// The cap [`Deficit::accrue`] enforces (exposed for invariant
    /// checks).
    pub fn cap(quantum: u64, cost: u64) -> u64 {
        cost.saturating_add(quantum)
    }
}

/// The per-round credit quantum: an equal bits share of the budget
/// across live jobs, floored at 1 so starved counters always grow.
pub fn quantum(budget_bits: usize, live_jobs: usize) -> u64 {
    (budget_bits as u64 / live_jobs.max(1) as u64).max(1)
}

/// Weighted QoS class of a tenant: how large its DRR quantum share is
/// and how much of the fleet budget is held in reserve for its class.
///
/// Grammar (CLI / spec builders): `gold` (weight 4), `silver` (weight 2,
/// the default), `bronze` (weight 1). A job's per-round quantum is
/// `⌊B · w_j / Σ_live w⌋` ([`weighted_quantum`]) — when every live job
/// is in one class this is exactly the unweighted `⌊B/live⌋`, so
/// single-class fleets behave identically to the pre-QoS scheduler.
///
/// On top of the weighted quanta, [`QosClass::reserve_num`] carves
/// guaranteed budget reservations (over [`RESERVE_DENOM`]) per class
/// with members live: a granted job draws its class reservation first
/// and only then the common pool, so a heavy gold tenant burning the
/// common pool can never starve a light bronze tenant out of its
/// reserved slice (property-tested in `rust/tests/test_serve.rs`).
///
/// One carve-out: an *admitted* job whose cheapest rung exceeds its
/// class ceiling (own reserve + common pool) would be starved forever by
/// the reservations alone, so the fleet grants such oversized tenants
/// from the whole remaining round budget instead — the admission
/// guarantee outranks the per-round reservation, which in those rounds
/// becomes best-effort.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum QosClass {
    /// Weight 4, reservation 3/8 of the budget.
    Gold,
    /// Weight 2, reservation 2/8 — the default class.
    #[default]
    Silver,
    /// Weight 1, reservation 1/8.
    Bronze,
}

/// Denominator of the per-class budget reservations (numerators in
/// [`QosClass::reserve_num`]; 3+2+1 = 6 of 8, leaving 2/8 always in the
/// common pool).
pub const RESERVE_DENOM: u64 = 8;

impl QosClass {
    /// All classes, in tag order (iteration / reservation bookkeeping).
    pub const ALL: [QosClass; 3] = [QosClass::Gold, QosClass::Silver, QosClass::Bronze];

    /// DRR quantum weight.
    pub fn weight(self) -> u64 {
        match self {
            QosClass::Gold => 4,
            QosClass::Silver => 2,
            QosClass::Bronze => 1,
        }
    }

    /// Reservation numerator over [`RESERVE_DENOM`]: the slice of the
    /// fleet budget held for this class each round while it has live
    /// members (idle classes' slices return to the common pool).
    pub fn reserve_num(self) -> u64 {
        match self {
            QosClass::Gold => 3,
            QosClass::Silver => 2,
            QosClass::Bronze => 1,
        }
    }

    /// Canonical CLI / checkpoint name.
    pub fn name(self) -> &'static str {
        match self {
            QosClass::Gold => "gold",
            QosClass::Silver => "silver",
            QosClass::Bronze => "bronze",
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<QosClass> {
        match s {
            "gold" => Some(QosClass::Gold),
            "silver" => Some(QosClass::Silver),
            "bronze" => Some(QosClass::Bronze),
            _ => None,
        }
    }

    /// Stable one-byte wire tag (the checkpoint trailer's encoding).
    pub fn tag(self) -> u8 {
        match self {
            QosClass::Gold => 0,
            QosClass::Silver => 1,
            QosClass::Bronze => 2,
        }
    }

    /// Inverse of [`QosClass::tag`]; `None` on an unknown byte (corrupt
    /// snapshot).
    pub fn from_tag(tag: u8) -> Option<QosClass> {
        match tag {
            0 => Some(QosClass::Gold),
            1 => Some(QosClass::Silver),
            2 => Some(QosClass::Bronze),
            _ => None,
        }
    }

    /// Index into [`QosClass::ALL`]-shaped bookkeeping arrays.
    pub fn index(self) -> usize {
        self.tag() as usize
    }
}

impl std::fmt::Display for QosClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The weighted per-round credit quantum: job `j`'s share of the budget
/// is `⌊B · w_j / Σ_live w⌋`, floored at 1 so starved counters always
/// grow. Degenerates to the unweighted [`quantum`] when all live jobs
/// share one class: `⌊B·w/(live·w)⌋ = ⌊B/live⌋`.
pub fn weighted_quantum(budget_bits: usize, weight: u64, total_weight: u64) -> u64 {
    // Widen before multiplying: `budget · weight` overflows u64 for
    // budgets past 2^62 (weight 4), and a silently wrapped quantum would
    // starve the very tenants the weights privilege.
    let q = (budget_bits as u128 * weight as u128) / total_weight.max(1) as u128;
    q.clamp(1, u64::MAX as u128) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_roundtrip() {
        for p in [Policy::Drr, Policy::DrrAdaptive] {
            assert_eq!(Policy::parse(p.name()), Some(p));
            assert_eq!(format!("{p}"), p.name());
        }
        assert_eq!(Policy::parse("bogus"), None);
    }

    #[test]
    fn deficit_accrues_charges_and_stays_capped() {
        let mut d = Deficit::default();
        d.accrue(10, 25);
        d.accrue(10, 25);
        assert_eq!(d.bits, 20);
        d.accrue(10, 25);
        d.accrue(10, 25);
        // Capped at cost + quantum = 35, not 40.
        assert_eq!(d.bits, Deficit::cap(10, 25));
        d.charge(25);
        assert_eq!(d.bits, 10);
        // Saturating: a charge larger than the balance zeroes it.
        d.charge(1000);
        assert_eq!(d.bits, 0);
    }

    #[test]
    fn quantum_is_an_equal_share_floored_at_one() {
        assert_eq!(quantum(1000, 4), 250);
        assert_eq!(quantum(3, 8), 1);
        assert_eq!(quantum(0, 0), 1);
    }

    #[test]
    fn qos_names_tags_and_weights_roundtrip() {
        for c in QosClass::ALL {
            assert_eq!(QosClass::parse(c.name()), Some(c));
            assert_eq!(QosClass::from_tag(c.tag()), Some(c));
            assert_eq!(format!("{c}"), c.name());
            assert_eq!(QosClass::ALL[c.index()], c);
        }
        assert_eq!(QosClass::parse("platinum"), None);
        assert_eq!(QosClass::from_tag(7), None);
        assert_eq!(QosClass::default(), QosClass::Silver);
        // Gold outweighs silver outweighs bronze, in quanta and reserves.
        assert!(QosClass::Gold.weight() > QosClass::Silver.weight());
        assert!(QosClass::Silver.weight() > QosClass::Bronze.weight());
        let reserved: u64 = QosClass::ALL.iter().map(|c| c.reserve_num()).sum();
        assert!(reserved < RESERVE_DENOM, "a common pool must always remain");
    }

    #[test]
    fn weighted_quantum_degenerates_to_equal_share_for_one_class() {
        // All-silver fleet of 4: exactly the unweighted quantum — the
        // pre-QoS scheduler's arithmetic, so single-class fleets (and
        // every existing deficit/starvation bound) are unchanged.
        let w = QosClass::Silver.weight();
        assert_eq!(weighted_quantum(1000, w, 4 * w), quantum(1000, 4));
        assert_eq!(weighted_quantum(3, w, 8 * w), quantum(3, 8));
        // Mixed fleet: gold gets 4x bronze's share of the same budget.
        let total = QosClass::Gold.weight() + QosClass::Bronze.weight();
        let g = weighted_quantum(1000, QosClass::Gold.weight(), total);
        let b = weighted_quantum(1000, QosClass::Bronze.weight(), total);
        assert_eq!(g, 4 * b);
        assert_eq!(weighted_quantum(0, 1, 0), 1, "floored at 1");
    }

    #[test]
    fn weighted_quantum_survives_huge_budgets_without_wrapping() {
        // budget · weight would wrap u64 here; the widened arithmetic
        // must return the true share, not a wrapped remnant.
        let b = usize::MAX;
        let w = QosClass::Gold.weight();
        assert_eq!(weighted_quantum(b, w, w), b as u64, "solo gold gets the whole budget");
        assert_eq!(weighted_quantum(b, w, 2 * w), b as u64 / 2);
        // Degenerate caller (weight beyond the live total) saturates
        // instead of truncating through a narrowing cast.
        assert_eq!(weighted_quantum(b, 8, 1), u64::MAX);
    }
}
