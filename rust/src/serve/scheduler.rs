//! Deficit-round-robin arbitration of the global bit budget.
//!
//! The fleet offers `B` payload bits per fleet round. Each live job `j`
//! accrues a **quantum** `q = max(1, B / live_jobs)` of credit per round
//! into a deficit counter and may transmit when (a) its counter covers a
//! ladder level's nominal cost and (b) the round's remaining budget
//! does. Service order rotates one slot per round, so every live job is
//! periodically first in line with the full budget available.
//!
//! Guarantees (property-tested in `rust/tests/test_serve.rs`):
//!
//! * **Bounded deficit** — counters are capped at `cost + quantum`
//!   ([`Deficit::accrue`]); credit beyond "can afford the requested
//!   level, plus one round of slack" buys nothing and would let an
//!   unserviceable job bank unbounded credit.
//! * **Starvation-freedom** — admission requires every job's cheapest
//!   grantable level to fit inside `B` ([`crate::serve::fleet`]); with
//!   rotation and quantum accrual, job `j` transmits at least once every
//!   `jobs · (⌈cost_j/q⌉ + 1)` fleet rounds, adversarial mixes included.
//!
//! Bits are the **arbitrable resource** here exactly as in the
//! per-round-budget framing of Mayekar & Tyagi (2020) and Michelusi et
//! al. (2020): the scheduler splits a shared precision budget across
//! tenants round by round.

/// Which arbitration rule the fleet runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Strict deficit round robin: a job only ever transmits at its
    /// requested budget `R` (ladder level 0). Trace-preserving: a job's
    /// rounds are bit-identical to a solo run at any contention level.
    Drr,
    /// DRR with budget degradation: under contention a job may be
    /// granted a deeper (cheaper) ladder level `R_i < R`. Higher fleet
    /// utilization; per-round precision becomes contention-dependent.
    DrrAdaptive,
}

impl Policy {
    /// Canonical CLI name (`repro serve policy=<name>`).
    pub fn name(self) -> &'static str {
        match self {
            Policy::Drr => "drr",
            Policy::DrrAdaptive => "adaptive",
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "drr" => Some(Policy::Drr),
            "adaptive" | "drr-adaptive" => Some(Policy::DrrAdaptive),
            _ => None,
        }
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-job deficit counter (bits of banked transmission credit).
#[derive(Clone, Copy, Debug, Default)]
pub struct Deficit {
    /// Banked credit in payload bits.
    pub bits: u64,
}

impl Deficit {
    /// Accrue one round's quantum, capped at `cost + quantum` where
    /// `cost` is the job's requested-level cost — the classic DRR bound
    /// that keeps counters finite for jobs the budget cannot serve this
    /// round.
    pub fn accrue(&mut self, quantum: u64, cost: u64) {
        self.bits = (self.bits + quantum).min(cost.saturating_add(quantum));
    }

    /// Spend `cost` bits of credit after a granted transmission.
    pub fn charge(&mut self, cost: u64) {
        self.bits = self.bits.saturating_sub(cost);
    }

    /// The cap [`Deficit::accrue`] enforces (exposed for invariant
    /// checks).
    pub fn cap(quantum: u64, cost: u64) -> u64 {
        cost.saturating_add(quantum)
    }
}

/// The per-round credit quantum: an equal bits share of the budget
/// across live jobs, floored at 1 so starved counters always grow.
pub fn quantum(budget_bits: usize, live_jobs: usize) -> u64 {
    (budget_bits as u64 / live_jobs.max(1) as u64).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_roundtrip() {
        for p in [Policy::Drr, Policy::DrrAdaptive] {
            assert_eq!(Policy::parse(p.name()), Some(p));
            assert_eq!(format!("{p}"), p.name());
        }
        assert_eq!(Policy::parse("bogus"), None);
    }

    #[test]
    fn deficit_accrues_charges_and_stays_capped() {
        let mut d = Deficit::default();
        d.accrue(10, 25);
        d.accrue(10, 25);
        assert_eq!(d.bits, 20);
        d.accrue(10, 25);
        d.accrue(10, 25);
        // Capped at cost + quantum = 35, not 40.
        assert_eq!(d.bits, Deficit::cap(10, 25));
        d.charge(25);
        assert_eq!(d.bits, 10);
        // Saturating: a charge larger than the balance zeroes it.
        d.charge(1000);
        assert_eq!(d.bits, 0);
    }

    #[test]
    fn quantum_is_an_equal_share_floored_at_one() {
        assert_eq!(quantum(1000, 4), 250);
        assert_eq!(quantum(3, 8), 1);
        assert_eq!(quantum(0, 0), 1);
    }
}
