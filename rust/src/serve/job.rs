//! A served job: one engine run's components, owned, steppable one round
//! at a time, and checkpointable.
//!
//! A [`Job`] owns everything a run needs across rounds — the (seeded)
//! problem data, the codec ladder, the feedback memory, the
//! [`RunState`], and the job RNG. When the scheduler grants it a round,
//! [`Job::step_round`] assembles a [`RoundCtx`] on the stack over those
//! owned components and advances the engine by exactly one round. No
//! state leaks outside the job, so its trace is independent of how its
//! rounds interleave with other tenants'.
//!
//! **Derivation discipline:** every random artifact is derived from
//! `spec.seed` through a fixed salt ([`DATA_SALT`], [`FRAME_SALT`],
//! [`RUN_SALT`]), so a job rebuilt from its spec — at submit, or during
//! [`crate::serve::checkpoint::restore`] in a fresh process — regrows
//! identical data and frames; only the dynamic state (iterate, RNGs,
//! feedback, trace) needs to travel in a snapshot.

use std::sync::Arc;

use crate::coordinator::channel::ChannelPools;
use crate::coordinator::transport::Participation;
use crate::data::synthetic::planted_regression_shards;
use crate::linalg::rng::Rng;
use crate::opt::engine::feedback::{DefFeedback, FeedbackMemory, NoFeedback};
use crate::opt::engine::schedule::Schedule;
use crate::opt::engine::{
    Codecs, MtRoundCtx, OracleBank, OutputMode, Problem, RngPolicy, RoundCtx, RunState,
    SharedOracleBank,
};
use crate::opt::multi::ShardedProblem;
use crate::opt::objectives::{DatasetObjective, Loss};
use crate::opt::projection::Domain;
use crate::opt::Trace;
use crate::quant::registry::CompressorSpec;
use crate::quant::{budget_bits, Compressor};
use crate::serve::plancache::PlanCache;
use crate::serve::scheduler::{Policy, QosClass};

/// Salt for the problem-data RNG stream (`seed ^ DATA_SALT`).
pub const DATA_SALT: u64 = 0xDA7A_5EED;
/// Salt for the frame/common-randomness RNG stream (`seed ^ FRAME_SALT`);
/// ladder level `l`'s codecs are built from `fork(l)` of that stream.
pub const FRAME_SALT: u64 = 0xF4A3_5EED;
/// Salt for the run RNG stream (`seed ^ RUN_SALT`) that the engine
/// consumes (worker forks, participation, dither, drop verdicts).
pub const RUN_SALT: u64 = 0x4B1D_5EED;

/// Dyadic effective-budget ladder: level 0 is the requested `R`, deeper
/// levels are fallbacks the adaptive scheduler may grant under
/// contention. Infeasible levels (per `CompressorSpec::is_feasible`) are
/// skipped at build.
const LADDER_FRACTIONS: [f32; 4] = [1.0, 0.5, 0.25, 0.125];

/// The data a job optimizes over. Self-contained by construction —
/// regenerated from the job seed — so a checkpoint never has to carry
/// the dataset.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ProblemSpec {
    /// Worker-sharded planted least-squares regression
    /// ([`planted_regression_shards`]): `rows_per_shard` rows per worker,
    /// heavy-tailed (`student_t`) or Gaussian³ data.
    PlantedRegression {
        /// Rows in each worker's private shard.
        rows_per_shard: usize,
        /// Student-t(1) planted model (Fig. 3a) instead of Gaussian³.
        student_t: bool,
    },
}

/// The worker-side feedback memory a job runs with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeedbackKind {
    /// No memory (dithered/unbiased schemes).
    None,
    /// DGD-DEF error feedback ([`DefFeedback`], one error vector per
    /// worker).
    Def,
}

/// Plain-data description of a job: what to optimize, with which
/// compressor at which requested budget, for how many rounds, under
/// which seed. Everything a checkpoint needs to rebuild the job.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Human-readable job name (reported in fleet metrics).
    pub name: String,
    /// Compression scheme (must round-trip through
    /// [`CompressorSpec::parse`] so snapshots can name it).
    pub scheme: CompressorSpec,
    /// Requested uplink budget in bits/dimension.
    pub r: f32,
    /// Problem dimension.
    pub n: usize,
    /// Worker count (one shard and one codec per worker).
    pub workers: usize,
    /// Problem data description.
    pub problem: ProblemSpec,
    /// Engine rounds this job runs for.
    pub rounds: usize,
    /// Step-size rule. `Schedule::Constant(f32::NAN)` (see
    /// [`JobSpec::auto_step`]) derives the shard-stable step at build.
    pub schedule: Schedule,
    /// Worker feedback memory.
    pub feedback: FeedbackKind,
    /// Minibatch size per oracle query (`None` = full local gradient).
    pub batch: Option<usize>,
    /// Lossy-uplink probability in `[0, 1]`.
    pub drop_prob: f32,
    /// Projection domain.
    pub domain: Domain,
    /// Trace shape.
    pub output: OutputMode,
    /// Weighted QoS class: scales the job's DRR quantum and backs the
    /// per-class budget reservations
    /// ([`crate::serve::scheduler::QosClass`]). Travels in the
    /// checkpoint's scheduler trailer, not the spec section, so v1
    /// snapshots restore as the default class.
    pub qos: QosClass,
    /// Master seed; every stream is salted off it.
    pub seed: u64,
}

impl JobSpec {
    /// A single-worker spec with defaults: 10-row planted regression,
    /// auto-derived stable constant step, no feedback, full batch,
    /// reliable uplink, unconstrained domain, Polyak-average output.
    pub fn new(name: impl Into<String>, scheme: CompressorSpec, r: f32, n: usize, rounds: usize, seed: u64) -> Self {
        JobSpec {
            name: name.into(),
            scheme,
            r,
            n,
            workers: 1,
            problem: ProblemSpec::PlantedRegression { rows_per_shard: 10, student_t: false },
            rounds,
            schedule: Schedule::Constant(f32::NAN),
            feedback: FeedbackKind::None,
            batch: None,
            drop_prob: 0.0,
            domain: Domain::Unconstrained,
            output: OutputMode::PolyakAverage,
            qos: QosClass::default(),
            seed,
        }
    }

    /// Set the worker count (shards, codecs and feedback slots follow).
    pub fn with_workers(mut self, m: usize) -> Self {
        self.workers = m;
        self
    }

    /// Set the problem data description.
    pub fn with_problem(mut self, p: ProblemSpec) -> Self {
        self.problem = p;
        self
    }

    /// Set an explicit step schedule.
    pub fn with_schedule(mut self, s: Schedule) -> Self {
        self.schedule = s;
        self
    }

    /// Derive the shard-stable constant step at build time (the default):
    /// encoded as `Schedule::Constant(NaN)` so the derivation — which
    /// depends only on the seeded data — re-runs identically on restore.
    pub fn auto_step(mut self) -> Self {
        self.schedule = Schedule::Constant(f32::NAN);
        self
    }

    /// Run with DGD-DEF error feedback and last-iterate output (the
    /// smooth strongly-convex composition).
    pub fn with_def_feedback(mut self) -> Self {
        self.feedback = FeedbackKind::Def;
        self.output = OutputMode::LastIterate { trailing: true };
        self
    }

    /// Set the per-query minibatch size (`None` = full local gradient).
    pub fn with_batch(mut self, b: Option<usize>) -> Self {
        self.batch = b;
        self
    }

    /// Set the lossy-uplink probability.
    pub fn with_drop_prob(mut self, p: f32) -> Self {
        self.drop_prob = p;
        self
    }

    /// Set the trace shape.
    pub fn with_output(mut self, o: OutputMode) -> Self {
        self.output = o;
        self
    }

    /// Set the weighted QoS class (default: [`QosClass::Silver`]).
    pub fn with_qos(mut self, q: QosClass) -> Self {
        self.qos = q;
        self
    }
}

/// One rung of a job's effective-budget ladder.
pub struct LadderLevel {
    /// Effective budget (bits/dimension) at this level.
    pub r: f32,
    /// Nominal per-round cost the scheduler charges: `workers · ⌊n·r⌋`
    /// payload bits — the wire-contract **upper bound** on what the level
    /// can emit, so admission can never under-charge.
    pub cost_bits: u64,
    /// One codec per worker, built at this level's budget.
    pub codecs: Vec<Box<dyn Compressor>>,
}

/// Grow a spec's full effective-budget ladder from `seed ^ FRAME_SALT`:
/// level `l`'s codecs come from `fork(l)` of that stream, forked
/// unconditionally so each level's frame randomness is fixed regardless
/// of which levels turn out feasible. Pure in `(scheme, r, n, workers,
/// seed)` — exactly the plan-cache key — so two calls with equal inputs
/// return bit-identical ladders; the caller must have validated the
/// spec (level 0 feasible, `r > 0`, caps respected).
pub(crate) fn build_ladder(spec: &JobSpec) -> Vec<LadderLevel> {
    let mut frame_rng = Rng::seed_from(spec.seed ^ FRAME_SALT);
    let mut ladder = Vec::new();
    for (lvl, &frac) in LADDER_FRACTIONS.iter().enumerate() {
        let mut level_rng = frame_rng.fork(lvl as u64);
        let r_l = spec.r * frac;
        if lvl > 0 && !spec.scheme.is_feasible(spec.n, r_l) {
            continue;
        }
        let codecs: Vec<Box<dyn Compressor>> =
            (0..spec.workers).map(|_| spec.scheme.build(spec.n, r_l, &mut level_rng)).collect();
        ladder.push(LadderLevel {
            r: r_l,
            cost_bits: (spec.workers * budget_bits(spec.n, r_l)) as u64,
            codecs,
        });
    }
    ladder
}

/// A live job: spec + owned components + resumable run state. Built by
/// [`Job::build`]; stepped by the fleet via [`Job::step_round`].
pub struct Job {
    pub(crate) spec: JobSpec,
    problem: ShardedProblem,
    x_star: Vec<f32>,
    /// The schedule actually queried each round (auto-step resolved).
    sched_eff: Schedule,
    /// The immutable codec-ladder plan. `Arc`-held so same-spec jobs
    /// can share one build through the cluster plan cache
    /// ([`crate::serve::plancache::PlanCache`]); a cache-less build
    /// simply holds the sole reference. Codecs are `&self`-only on the
    /// hot path, so sharing is invisible to execution.
    ladder: Arc<Vec<LadderLevel>>,
    feedback: FeedbackSlot,
    pub(crate) run: RunState,
    pub(crate) rng: Rng,
    /// Minibatch index scratch, reused across rounds (zero-alloc).
    idx: Vec<usize>,
}

impl Job {
    /// Validate the spec and build the job: problem data from
    /// `seed ^ DATA_SALT`, codec ladder from `seed ^ FRAME_SALT`
    /// (level `l` forks stream `l`), run state + worker RNG forks from
    /// `seed ^ RUN_SALT`. Deterministic: two builds of the same spec are
    /// identical, which is what makes snapshots spec + dynamic-state only
    /// — and what makes the ladder safe to share via
    /// [`Job::build_cached`].
    pub fn build(spec: JobSpec) -> Result<Job, String> {
        Self::build_cached(spec, None)
    }

    /// [`Job::build`] with an optional plan cache: when the scheme's
    /// plan is shareable ([`CompressorSpec::plan_cacheable`]) the codec
    /// ladder is fetched from (or inserted into) the cache instead of
    /// regrown — bit-identical by the derivation discipline, since the
    /// cache key is exactly the ladder's generative inputs. Everything
    /// else (data, run state, RNGs) is always built fresh: it is
    /// per-job mutable state.
    pub fn build_cached(spec: JobSpec, cache: Option<&PlanCache>) -> Result<Job, String> {
        use crate::serve::checkpoint::{MAX_DIM, MAX_ROUNDS, MAX_ROWS, MAX_STR, MAX_WORKERS};
        // The checkpoint reader's sanity caps are admission rules too:
        // a job the snapshot format could not restore must never be
        // accepted — otherwise a running job's own checkpoint would be
        // rejected exactly when the operator needs it.
        if spec.n == 0 || spec.n > MAX_DIM {
            return Err(format!("job dimension n must be in 1..={MAX_DIM}, got {}", spec.n));
        }
        if spec.workers == 0 || spec.workers > MAX_WORKERS {
            return Err(format!(
                "worker count must be in 1..={MAX_WORKERS}, got {}",
                spec.workers
            ));
        }
        if spec.rounds == 0 || spec.rounds > MAX_ROUNDS {
            return Err(format!("rounds must be in 1..={MAX_ROUNDS}, got {}", spec.rounds));
        }
        if spec.name.len() > MAX_STR {
            return Err(format!(
                "job name is {} bytes; the checkpoint format caps names at {MAX_STR}",
                spec.name.len()
            ));
        }
        if let Some(b) = spec.batch {
            if b > MAX_ROWS {
                return Err(format!("batch size must be at most {MAX_ROWS}, got {b}"));
            }
        }
        // The upper bound keeps `workers · ⌊nR⌋` cost arithmetic far from
        // overflow even for corrupt checkpoint specs (fp32 is R = 32; no
        // scheme in the zoo asks for more than 64 bits/dimension).
        if !(spec.r > 0.0) || !(spec.r <= 64.0) {
            return Err(format!("bit budget R must be in (0, 64], got {}", spec.r));
        }
        if !(0.0..=1.0).contains(&spec.drop_prob) {
            return Err(format!("drop probability must be in [0, 1], got {}", spec.drop_prob));
        }
        if let Some(0) = spec.batch {
            return Err("batch size must be at least 1 (use None for full gradients)".into());
        }
        if !spec.scheme.is_feasible(spec.n, spec.r) {
            return Err(format!(
                "scheme {} cannot honor the ⌊nR⌋ wire contract at n={}, R={}",
                spec.scheme.name(),
                spec.n,
                spec.r
            ));
        }
        // Snapshots name the scheme by its canonical string; a spec that
        // does not round-trip would silently rehydrate as something else.
        if CompressorSpec::parse(&spec.scheme.name()) != Some(spec.scheme) {
            return Err(format!(
                "scheme name '{}' does not round-trip through the registry parser; \
                 such specs are not checkpointable and cannot be served",
                spec.scheme.name()
            ));
        }
        let ProblemSpec::PlantedRegression { rows_per_shard, student_t } = spec.problem;
        if rows_per_shard == 0 || rows_per_shard > MAX_ROWS {
            return Err(format!(
                "rows per shard must be in 1..={MAX_ROWS}, got {rows_per_shard}"
            ));
        }
        let mut data_rng = Rng::seed_from(spec.seed ^ DATA_SALT);
        let (shards, x_star) = planted_regression_shards(
            spec.workers,
            rows_per_shard,
            spec.n,
            Loss::Square,
            &mut data_rng,
            student_t,
        );
        let problem = ShardedProblem::new(shards);
        let sched_eff = match spec.schedule {
            Schedule::Constant(c) if c.is_nan() => Schedule::Constant(problem.stable_step()),
            s => s,
        };
        let ladder: Arc<Vec<LadderLevel>> = match cache {
            Some(c) if spec.scheme.plan_cacheable() => c.get_or_build(&spec),
            _ => Arc::new(build_ladder(&spec)),
        };
        let feedback = match spec.feedback {
            FeedbackKind::None => FeedbackSlot::None(NoFeedback),
            FeedbackKind::Def => FeedbackSlot::Def(DefFeedback::new(spec.workers, spec.n)),
        };
        let mut rng = Rng::seed_from(spec.seed ^ RUN_SALT);
        let x0 = vec![0.0f32; spec.n];
        let run = RunState::new(
            &x0,
            spec.workers,
            spec.rounds,
            spec.domain,
            RngPolicy::ForkPerWorker,
            spec.output,
            ladder[0].codecs.first().map(|c| c.as_ref()),
            &mut rng,
        );
        Ok(Job { spec, problem, x_star, sched_eff, ladder, feedback, run, rng, idx: Vec::new() })
    }

    /// The job's spec.
    pub fn spec(&self) -> &JobSpec {
        &self.spec
    }

    /// The job's effective-budget ladder (level 0 = requested `R`).
    pub fn ladder(&self) -> &[LadderLevel] {
        &self.ladder
    }

    /// The schedule the job actually runs (auto-step resolved).
    pub fn effective_schedule(&self) -> Schedule {
        self.sched_eff
    }

    /// The planted minimizer (distance-to-optimum reference).
    pub fn x_star(&self) -> &[f32] {
        &self.x_star
    }

    /// Engine rounds executed so far.
    pub fn rounds_done(&self) -> usize {
        self.run.round()
    }

    /// Whether every configured engine round has executed.
    pub fn is_complete(&self) -> bool {
        self.run.round() >= self.spec.rounds
    }

    /// The trace so far (`final_x` populated once finalized).
    pub fn trace(&self) -> &Trace {
        self.run.trace()
    }

    /// Nominal per-round cost at the requested budget (ladder level 0).
    pub fn requested_cost_bits(&self) -> u64 {
        self.ladder[0].cost_bits
    }

    /// Nominal cost of ladder level `lvl`.
    pub fn level_cost(&self, lvl: usize) -> u64 {
        self.ladder[lvl].cost_bits
    }

    /// Cheapest level the policy may ever grant — the admission bound:
    /// a fleet whose budget cannot cover this can never serve the job.
    pub fn min_cost_bits(&self, policy: Policy) -> u64 {
        match policy {
            Policy::Drr => self.ladder[0].cost_bits,
            Policy::DrrAdaptive => self.ladder.last().map(|l| l.cost_bits).unwrap_or(0),
        }
    }

    /// Highest (most precise) ladder level affordable within
    /// `afford_bits`, per policy: strict DRR only ever grants the
    /// requested budget; adaptive DRR may downgrade to a deeper rung.
    pub fn pick_level(&self, policy: Policy, afford_bits: u64) -> Option<usize> {
        match policy {
            Policy::Drr => (self.ladder[0].cost_bits <= afford_bits).then_some(0),
            Policy::DrrAdaptive => self.ladder.iter().position(|l| l.cost_bits <= afford_bits),
        }
    }

    /// Execute one engine round at ladder level `lvl`. Returns the
    /// measured `(payload_bits, side_bits)` the round put on the wire.
    /// Allocation-free once warm.
    pub fn step_round(&mut self, lvl: usize) -> (u64, u64) {
        let before_payload = self.run.trace().total_payload_bits;
        let before_side = self.run.trace().total_side_bits;
        let mut bank =
            ShardBank { shards: &self.problem.shards, batch: self.spec.batch, idx: &mut self.idx };
        let mut ctx = RoundCtx {
            problem: Problem::Sharded(&self.problem),
            oracles: &mut bank,
            codecs: Codecs::PerWorker(&self.ladder[lvl].codecs),
            schedule: &self.sched_eff,
            feedback: self.feedback.as_dyn_mut(),
            domain: self.spec.domain,
            participation: Participation::Full,
            drop_prob: self.spec.drop_prob,
            rng_policy: RngPolicy::ForkPerWorker,
            rounds: self.spec.rounds,
            x_star: Some(&self.x_star),
        };
        let stepped = self.run.step(&mut ctx, &mut self.rng);
        debug_assert!(stepped, "step_round called on a completed job");
        (
            (self.run.trace().total_payload_bits - before_payload) as u64,
            (self.run.trace().total_side_bits - before_side) as u64,
        )
    }

    /// [`Job::step_round`]'s threaded twin: execute one engine round at
    /// ladder level `lvl` with the worker phase fanned out over `threads`
    /// scoped threads ([`RunState::step_mt`]), per-worker scratch drawn
    /// from the fleet's recycled `pools`. Bit-identical to the inline
    /// path at any thread count — the serve conformance tests compare
    /// whole traces — so a fleet may freely mix inline and threaded
    /// rounds on the same job.
    pub fn step_round_mt(
        &mut self,
        lvl: usize,
        threads: usize,
        pools: &Arc<ChannelPools>,
    ) -> (u64, u64) {
        let before_payload = self.run.trace().total_payload_bits;
        let before_side = self.run.trace().total_side_bits;
        let bank =
            ShardBank { shards: &self.problem.shards, batch: self.spec.batch, idx: &mut self.idx };
        let mut ctx = MtRoundCtx {
            problem: Problem::Sharded(&self.problem),
            oracles: &bank,
            codecs: Codecs::PerWorker(&self.ladder[lvl].codecs),
            schedule: &self.sched_eff,
            feedback: self.feedback.as_dyn_mut(),
            domain: self.spec.domain,
            drop_prob: self.spec.drop_prob,
            rounds: self.spec.rounds,
            x_star: Some(&self.x_star),
        };
        let stepped = self.run.step_mt(&mut ctx, threads, pools);
        debug_assert!(stepped, "step_round_mt called on a completed job");
        (
            (self.run.trace().total_payload_bits - before_payload) as u64,
            (self.run.trace().total_side_bits - before_side) as u64,
        )
    }

    /// Execute one granted engine round, threaded when the fleet's
    /// never-nest gate allowed it (`threads = Some(t ≥ 2)`), inline
    /// otherwise — the one execution entry point both the lockstep round
    /// and the work-stealing epoch executor call, so the two paths
    /// cannot drift. Bit-identical either way.
    pub(crate) fn step_round_auto(
        &mut self,
        lvl: usize,
        threads: Option<usize>,
        pools: &Arc<ChannelPools>,
    ) -> (u64, u64) {
        match threads {
            Some(t) => self.step_round_mt(lvl, t, pools),
            None => self.step_round(lvl),
        }
    }

    /// Return the run's threaded-round scratch buffers to `pools` (called
    /// when a job leaves a fleet — completion, eviction, or migration —
    /// so its successors reuse the allocations). No-op if the job never
    /// ran a threaded round.
    pub fn release_mt(&mut self, pools: &Arc<ChannelPools>) {
        self.run.release_mt_slots(pools);
    }

    /// Close the trace (trailing record + `final_x`). Idempotent.
    pub fn finalize(&mut self) {
        self.run.finalize(Problem::Sharded(&self.problem), self.spec.output, Some(&self.x_star));
    }

    /// Append the feedback memory's checkpoint state to `out`.
    pub(crate) fn save_feedback(&self, out: &mut Vec<f32>) {
        self.feedback.save(out);
    }

    /// Restore the feedback memory; `false` on shape mismatch.
    pub(crate) fn restore_feedback(&mut self, data: &[f32]) -> bool {
        self.feedback.restore(data)
    }
}

/// Owned feedback memory, concrete enough to checkpoint.
enum FeedbackSlot {
    None(NoFeedback),
    Def(DefFeedback),
}

impl FeedbackSlot {
    fn as_dyn_mut(&mut self) -> &mut dyn FeedbackMemory {
        match self {
            FeedbackSlot::None(f) => f,
            FeedbackSlot::Def(f) => f,
        }
    }

    fn save(&self, out: &mut Vec<f32>) {
        match self {
            FeedbackSlot::None(f) => f.save_state(out),
            FeedbackSlot::Def(f) => f.save_state(out),
        }
    }

    fn restore(&mut self, data: &[f32]) -> bool {
        match self {
            FeedbackSlot::None(f) => f.restore_state(data),
            FeedbackSlot::Def(f) => f.restore_state(data),
        }
    }
}

/// Stack-assembled oracle bank over the job's owned shards: worker `i`
/// queries its shard's full or minibatch gradient, drawing batch indices
/// from the worker's round RNG into the job's reusable index buffer —
/// exactly the draws [`crate::opt::engine::oracle::ShardOracle`] makes,
/// so serve traces match inline-engine traces bit for bit.
struct ShardBank<'a> {
    shards: &'a [DatasetObjective],
    batch: Option<usize>,
    idx: &'a mut Vec<usize>,
}

impl OracleBank for ShardBank<'_> {
    fn workers(&self) -> usize {
        self.shards.len()
    }

    fn query(&mut self, i: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]) {
        let obj = &self.shards[i];
        match self.batch {
            Some(b) => {
                rng.sample_indices_into(obj.m, b.min(obj.m), self.idx);
                obj.minibatch_gradient(x, Some(self.idx), out);
            }
            None => obj.gradient(x, out),
        }
    }
}

impl SharedOracleBank for ShardBank<'_> {
    fn query_shared(&self, i: usize, x: &[f32], rng: &mut Rng, idx: &mut Vec<usize>, out: &mut [f32]) {
        // Same draws as `query` — `sample_indices_into` clears its scratch
        // first, so the caller-owned `idx` (one per worker slot in the
        // threaded executor) yields bit-identical batches to the shared
        // buffer the inline path reuses.
        let obj = &self.shards[i];
        match self.batch {
            Some(b) => {
                rng.sample_indices_into(obj.m, b.min(obj.m), idx);
                obj.minibatch_gradient(x, Some(idx), out);
            }
            None => obj.gradient(x, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> JobSpec {
        JobSpec::new("t", CompressorSpec::parse("ndsc-dith").unwrap(), 1.0, 16, 12, 3)
    }

    #[test]
    fn build_validates_spec() {
        assert!(Job::build(small_spec()).is_ok());
        let mut s = small_spec();
        s.r = 0.0;
        assert!(Job::build(s).is_err());
        let mut s = small_spec();
        s.workers = 0;
        assert!(Job::build(s).is_err());
        let mut s = small_spec();
        s.batch = Some(0);
        assert!(Job::build(s).is_err());
        // Fixed-rate scheme below its wire rate: infeasible.
        let mut s = small_spec();
        s.scheme = CompressorSpec::parse("qsgd").unwrap();
        s.r = 1.0;
        assert!(Job::build(s).is_err());
    }

    #[test]
    fn ladder_is_dyadic_and_costed() {
        let job = Job::build(small_spec().with_workers(2)).unwrap();
        let ladder = job.ladder();
        assert!(!ladder.is_empty());
        assert_eq!(ladder[0].r, 1.0);
        assert_eq!(ladder[0].codecs.len(), 2);
        assert_eq!(ladder[0].cost_bits, 2 * 16);
        for w in ladder.windows(2) {
            assert!(w[1].r < w[0].r, "ladder must be strictly decreasing");
            assert!(w[1].cost_bits <= w[0].cost_bits);
        }
        assert_eq!(job.min_cost_bits(Policy::Drr), ladder[0].cost_bits);
        assert_eq!(job.min_cost_bits(Policy::DrrAdaptive), ladder.last().unwrap().cost_bits);
        // Level picking honors affordability.
        assert_eq!(job.pick_level(Policy::Drr, ladder[0].cost_bits), Some(0));
        assert_eq!(job.pick_level(Policy::Drr, ladder[0].cost_bits - 1), None);
        assert_eq!(job.pick_level(Policy::DrrAdaptive, ladder[0].cost_bits - 1), Some(1));
    }

    #[test]
    fn step_round_advances_and_charges_measured_bits() {
        let mut job = Job::build(small_spec()).unwrap();
        assert_eq!(job.rounds_done(), 0);
        let (pay, _side) = job.step_round(0);
        assert_eq!(job.rounds_done(), 1);
        assert!(pay > 0);
        assert!(pay <= job.level_cost(0), "wire contract: measured ≤ nominal");
        while !job.is_complete() {
            job.step_round(0);
        }
        job.finalize();
        assert_eq!(job.trace().records.len(), 12);
        assert!(job.trace().final_x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn auto_step_resolves_to_stable_step() {
        let job = Job::build(small_spec()).unwrap();
        match job.effective_schedule() {
            Schedule::Constant(c) => assert!(c.is_finite() && c > 0.0),
            s => panic!("expected constant schedule, got {s:?}"),
        }
    }
}
