//! Stochastic (dithered) uniform quantization — eq. (20) and App. I.
//!
//! For `v ∈ [lo, hi]` with `M = 2^b` levels `u_0 < … < u_{M−1}` uniformly
//! spaced over `[lo, hi]`, the dithered quantizer outputs the bracketing
//! level `u_{r+1}` w.p. `(v − u_r)/(u_{r+1} − u_r)` and `u_r` otherwise, so
//! `E[Q(v)] = v` for in-range inputs. Unbiasedness is what lets DQ-PSGD
//! (Alg. 2) reach the minimax rate *without* error feedback (§4.2).
//!
//! [`DitheredUniform`] is a `Copy` value with scalar `encode`/`decode` —
//! constructing one per coordinate (as the `compress_into` hot paths do)
//! costs nothing and touches no heap.

use crate::linalg::rng::Rng;

/// Dithered quantizer over a fixed symmetric-or-not range.
#[derive(Clone, Copy, Debug)]
pub struct DitheredUniform {
    pub lo: f32,
    pub hi: f32,
    /// Bits per sample (levels = 2^bits). `bits = 0` decodes to the
    /// midpoint deterministically.
    pub bits: usize,
}

impl DitheredUniform {
    pub fn symmetric(range: f32, bits: usize) -> Self {
        DitheredUniform { lo: -range, hi: range, bits }
    }

    #[inline]
    fn levels(&self) -> u64 {
        1u64 << self.bits
    }

    #[inline]
    fn step(&self) -> f32 {
        (self.hi - self.lo) / (self.levels() - 1).max(1) as f32
    }

    /// Stochastically round `v` to a level index. In-range values are
    /// unbiased; out-of-range values clamp (biased — callers choose the
    /// range so this happens with vanishing probability, cf. App. E.1).
    #[inline]
    pub fn encode(&self, v: f32, rng: &mut Rng) -> u64 {
        if self.bits == 0 {
            return 0;
        }
        let m = self.levels();
        if m == 1 {
            return 0;
        }
        let step = self.step();
        let t = ((v - self.lo) / step).clamp(0.0, (m - 1) as f32);
        let r = t.floor();
        let frac = t - r;
        let idx = r as u64 + u64::from(rng.bernoulli(frac as f64));
        idx.min(m - 1)
    }

    /// Level value for an index.
    #[inline]
    pub fn decode(&self, idx: u64) -> f32 {
        if self.bits == 0 {
            return 0.5 * (self.lo + self.hi);
        }
        self.lo + idx as f32 * self.step()
    }

    /// One-shot stochastic rounding.
    #[inline]
    pub fn quantize(&self, v: f32, rng: &mut Rng) -> f32 {
        self.decode(self.encode(v, rng))
    }

    /// Per-sample variance bound `step²/4` for in-range inputs
    /// (`(u_{r+1}−v)(v−u_r) ≤ step²/4`, App. I).
    pub fn variance_bound(&self) -> f32 {
        let s = self.step();
        s * s / 4.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::{forall, Cases};

    #[test]
    fn unbiased_in_range() {
        forall(Cases::new("dither unbiased", 20), |rng, _| {
            let q = DitheredUniform::symmetric(1.0, 1 + rng.below(4));
            let v = (rng.uniform_f32() - 0.5) * 1.9;
            let trials = 20_000;
            let mean: f64 =
                (0..trials).map(|_| q.quantize(v, rng) as f64).sum::<f64>() / trials as f64;
            let tol = 4.0 * (q.variance_bound() as f64 / trials as f64).sqrt() + 1e-3;
            assert!((mean - v as f64).abs() < tol, "v={v} mean={mean} tol={tol}");
        });
    }

    #[test]
    fn outputs_are_levels() {
        let mut rng = Rng::seed_from(1);
        let q = DitheredUniform::symmetric(2.0, 3);
        for _ in 0..100 {
            let v = (rng.uniform_f32() - 0.5) * 4.0;
            let out = q.quantize(v, &mut rng);
            let idx = ((out - q.lo) / q.step()).round() as i64;
            assert!((0..8).contains(&idx));
            assert!((q.decode(idx as u64) - out).abs() < 1e-6);
        }
    }

    #[test]
    fn clamps_out_of_range() {
        let mut rng = Rng::seed_from(2);
        let q = DitheredUniform::symmetric(1.0, 2);
        assert_eq!(q.quantize(10.0, &mut rng), 1.0);
        assert_eq!(q.quantize(-10.0, &mut rng), -1.0);
    }

    #[test]
    fn variance_within_bound() {
        let mut rng = Rng::seed_from(3);
        let q = DitheredUniform::symmetric(1.0, 2);
        let v = 0.37;
        let trials = 50_000;
        let var: f64 = (0..trials)
            .map(|_| {
                let d = (q.quantize(v, &mut rng) - v) as f64;
                d * d
            })
            .sum::<f64>()
            / trials as f64;
        assert!(var <= q.variance_bound() as f64 * 1.05, "var={var}");
    }

    #[test]
    fn zero_bits_is_midpoint() {
        let mut rng = Rng::seed_from(4);
        let q = DitheredUniform { lo: 0.0, hi: 4.0, bits: 0 };
        assert_eq!(q.quantize(3.3, &mut rng), 2.0);
    }
}
