//! Democratic Source Coding — the paper's central contribution (§3.1).
//!
//! [`SubspaceCodec`] implements the encode/decode pair (eq. 12):
//!
//! ```text
//! E(y) = Q( x / ‖x‖∞ ),   D(x') = ‖x‖∞ · S·x'
//! ```
//!
//! where `x` is either the **democratic** embedding (LV iteration, → DSC)
//! or the **near-democratic** embedding (`Sᵀy`, → NDSC), and `Q` is either
//! the deterministic nearest-neighbour uniform quantizer of eq. (11)
//! (used by DGD-DEF, which needs a *uniform* error bound) or the dithered
//! unbiased quantizer of App. E (used by DQ-PSGD, which needs
//! `E[Q(y)] = y`).
//!
//! Budget handling follows the paper exactly:
//! * the total payload is `⌊nR⌋` bits regardless of the embedding dimension
//!   `N ≥ n` (each coordinate gets `≈ nR/N` bits — Thm. 1's `R/λ`);
//! * in the **sub-linear regime** (`⌊nR⌋ < N`) the dithered encoder
//!   subsamples `⌊nR⌋` random coordinates, allots 1 bit each, and rescales
//!   by `N/k` for unbiasedness (App. E.2);
//! * scalar side information (gain, `‖x‖∞`, the subsampling seed) is
//!   counted separately as the `O(1)` of App. F.

use std::sync::Mutex;

use crate::embed::democratic::{KashinParams, KashinSolver};
use crate::linalg::frames::Frame;
use crate::linalg::rng::Rng;
use crate::linalg::vecops::{norm2, norm_inf};
use crate::quant::bitpack::{allocate_bits, BitReader, BitWriter};
use crate::quant::dither::DitheredUniform;
use crate::quant::uniform::{dequantize_index, quantize_index};
use crate::quant::{budget_bits, Compressed, Compressor, Workspace};

/// Which embedding feeds the quantizer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EmbedKind {
    /// Lyubarskii–Vershynin democratic embedding → **DSC**.
    Democratic,
    /// Closed-form `Sᵀy` → **NDSC**.
    NearDemocratic,
}

/// Quantizer flavour.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecMode {
    /// Nearest-neighbour (eq. 11): uniform worst-case error, biased.
    /// What DGD-DEF uses.
    Deterministic,
    /// Dithered gain–shape (App. E): unbiased. What DQ-PSGD uses.
    Dithered,
}

/// The (N)DSC encoder/decoder over an arbitrary frame.
///
/// The codec itself holds **no per-call scratch** (the old
/// `Mutex<Vec<f32>>` serialized the coordinator's scoped-thread fan-out);
/// all hot-path buffers live in the caller's [`Workspace`], so `m` workers
/// and `m` server-side decodes can run the same codec concurrently,
/// allocation-free. The only interior state is the LV solver (Democratic
/// embedding), which keeps its own warm buffers behind a mutex.
pub struct SubspaceCodec {
    frame: Box<dyn Frame>,
    embed: EmbedKind,
    mode: CodecMode,
    r: f32,
    /// LV solver state (scratch buffers) — only touched when
    /// `embed == Democratic`.
    solver: Mutex<KashinSolver>,
    label: String,
}

impl SubspaceCodec {
    pub fn new(frame: Box<dyn Frame>, embed: EmbedKind, mode: CodecMode, r: f32) -> Self {
        assert!(r > 0.0, "bit budget must be positive");
        let params = KashinParams::for_lambda(frame.lambda());
        let label = match (embed, mode) {
            (EmbedKind::Democratic, CodecMode::Deterministic) => "DSC",
            (EmbedKind::Democratic, CodecMode::Dithered) => "DSC-dith",
            (EmbedKind::NearDemocratic, CodecMode::Deterministic) => "NDSC",
            (EmbedKind::NearDemocratic, CodecMode::Dithered) => "NDSC-dith",
        }
        .to_string();
        SubspaceCodec {
            frame,
            embed,
            mode,
            r,
            solver: Mutex::new(KashinSolver::new(params)),
            label,
        }
    }

    /// Access the frame (used by tests and the experiment harness).
    pub fn frame(&self) -> &dyn Frame {
        self.frame.as_ref()
    }

    /// Compute the configured embedding of `y` into `out` (`len → N`),
    /// scratching in `tmp` (pseudo-inverse solves of non-Parseval frames).
    ///
    /// Returns the **deferred scale** `c`: the true embedding is
    /// `out[i] * c` per element (see [`Frame::pinv_embed_deferred`]), and
    /// the quantize pass must apply that multiply itself. On every path
    /// that applies the scale eagerly (reference, dense-frame fallback,
    /// Democratic/LV) `c == 1.0` — and `v * 1.0` is an IEEE identity — so
    /// one downstream code path serves fused and unfused alike,
    /// bit-identically.
    fn embed_into_buf(
        &self,
        y: &[f32],
        out: &mut Vec<f32>,
        tmp: &mut Vec<f32>,
        fused: bool,
    ) -> f32 {
        out.resize(self.frame.big_n(), 0.0);
        match self.embed {
            EmbedKind::NearDemocratic => {
                if fused {
                    if let Some(c) = self.frame.pinv_embed_deferred(y, out) {
                        return c;
                    }
                    self.frame.pinv_embed_into(y, out, tmp);
                } else {
                    self.frame.pinv_embed_reference_into(y, out, tmp);
                }
            }
            EmbedKind::Democratic => {
                let mut solver = self.solver.lock().unwrap();
                solver.embed_into(self.frame.as_ref(), y, out);
            }
        }
        1.0
    }

    /// Theorem-1 error factor `β` for this codec: `2^{1−R/λ}·K̂` (DSC) or
    /// `2^{2−R/λ}·√log(2N)` (NDSC) — used by DGD-DEF's step-size theory.
    pub fn beta(&self) -> f32 {
        let lambda = self.frame.lambda();
        let big_n = self.frame.big_n() as f32;
        match self.embed {
            EmbedKind::Democratic => (2.0f32).powf(1.0 - self.r / lambda) * 3.0, // K_u ≈ 3
            EmbedKind::NearDemocratic => {
                (2.0f32).powf(2.0 - self.r / lambda) * (2.0 * big_n).ln().sqrt()
            }
        }
    }

    /// Deterministic encode. `fused = true` is the hot path: deferred-scale
    /// embed (one unnormalized FWHT, no scaling sweep) with the scale
    /// folded into the quantize loop — **one** pass over the `N` floats
    /// after the transform instead of three (scale sweep, `‖·‖∞` sweep,
    /// quantize/bitpack sweep); only the irreducible `‖·‖∞` reduction
    /// remains separate, since `s` must be known before the first
    /// quantization. `fused = false` is the pre-fusion reference path.
    /// Both produce bit-identical wire bytes: `s = max|aᵢ|·c` equals
    /// `max|aᵢ·c|` exactly (`|a·c| = |a|·c` for `c > 0`, and a positive
    /// scale is monotone so it commutes with the max), and the quantizer
    /// input `(aᵢ·c)·s⁻¹` performs the same two multiplies in the same
    /// order as scale-sweep-then-quantize.
    fn compress_deterministic_impl(
        &self,
        y: &[f32],
        ws: &mut Workspace,
        out: &mut Compressed,
        fused: bool,
    ) {
        let n = self.frame.n();
        let big_n = self.frame.big_n();
        let c = {
            let Workspace { a, c: tmp, .. } = ws;
            self.embed_into_buf(y, a, tmp, fused)
        };
        let s = norm_inf(&ws.a) * c;
        let budget = budget_bits(n, self.r);
        let alloc = allocate_bits(budget, big_n);
        let mut w = BitWriter::reuse(std::mem::take(&mut out.bytes));
        w.reserve_bits(budget + 32);
        w.write_f32(s);
        if s > 0.0 {
            let inv = 1.0 / s;
            for (i, &xi) in ws.a.iter().enumerate() {
                let bits = alloc.bits(i);
                if bits > 0 {
                    // (xi·c)·inv, never xi·(c·inv): preserve the unfused
                    // two-multiply order so the quantizer sees identical bits.
                    w.write_bits(quantize_index((xi * c) * inv, bits), bits);
                }
            }
        } else {
            // all-zero input: budget bits of zeros keep the format fixed-length
            let mut left = budget;
            while left > 0 {
                let take = left.min(64);
                w.write_bits(0, take);
                left -= take;
            }
        }
        out.n = n;
        out.payload_bits = w.len_bits() - 32;
        out.side_bits = 32;
        out.bytes = w.into_bytes();
    }

    fn decompress_deterministic_impl(
        &self,
        msg: &Compressed,
        ws: &mut Workspace,
        out: &mut [f32],
        fused: bool,
    ) {
        let n = self.frame.n();
        let big_n = self.frame.big_n();
        let mut r = BitReader::new(&msg.bytes);
        let s = r.read_f32();
        let alloc = allocate_bits(budget_bits(n, self.r), big_n);
        ws.a.resize(big_n, 0.0);
        if s > 0.0 {
            for (i, xi) in ws.a.iter_mut().enumerate() {
                let bits = alloc.bits(i);
                *xi = if bits > 0 { s * dequantize_index(r.read_bits(bits), bits) } else { 0.0 };
            }
        } else {
            ws.a.fill(0.0);
        }
        if fused {
            self.frame.apply_inplace(&mut ws.a, out);
        } else {
            self.frame.apply_inplace_reference(&mut ws.a, out);
        }
    }

    /// Dithered encode; same fusion contract as
    /// [`SubspaceCodec::compress_deterministic_impl`]. The dither RNG
    /// consumption is also bit-identical across paths: encode inputs match
    /// bitwise, so every Bernoulli draw takes the same branch.
    fn compress_dithered_impl(
        &self,
        y: &[f32],
        rng: &mut Rng,
        ws: &mut Workspace,
        out: &mut Compressed,
        fused: bool,
    ) {
        let n = self.frame.n();
        let big_n = self.frame.big_n();
        let gain = norm2(y);
        let budget = budget_bits(n, self.r);
        let mut w = BitWriter::reuse(std::mem::take(&mut out.bytes));
        // Worst case: gain + s headers (2×32) + subsample seed (64) + payload.
        w.reserve_bits(budget + 128);
        w.write_f32(gain);
        if gain == 0.0 || budget == 0 {
            out.n = n;
            out.payload_bits = 0;
            out.side_bits = 32;
            out.bytes = w.into_bytes();
            return;
        }
        // shape = y / ‖y‖₂ in the secondary scratch, embedded into `a`.
        ws.b.resize(n, 0.0);
        for (bi, &yi) in ws.b.iter_mut().zip(y) {
            *bi = yi / gain;
        }
        let c = {
            let Workspace { a, b, c: tmp, .. } = ws;
            self.embed_into_buf(b, a, tmp, fused)
        };
        let s = norm_inf(&ws.a) * c;
        w.write_f32(s);
        let mut side_bits = 64;
        let payload_bits;
        if budget >= big_n {
            // High-budget: every coordinate gets >= 1 bit.
            let alloc = allocate_bits(budget, big_n);
            for (i, &xi) in ws.a.iter().enumerate() {
                let bits = alloc.bits(i);
                let q = DitheredUniform::symmetric(s, bits);
                w.write_bits(q.encode(xi * c, rng), bits);
            }
            payload_bits = alloc.total();
        } else {
            // Sub-linear: random k = budget coords, 1 bit each, rescale by
            // N/k at the decoder (App. E.2). The index set is shared
            // randomness: the seed rides along as side information.
            let seed = rng.next_u64();
            w.write_u64(seed);
            side_bits += 64;
            let mut sel_rng = Rng::seed_from(seed);
            sel_rng.sample_indices_into(big_n, budget, &mut ws.idx);
            let q = DitheredUniform::symmetric(s, 1);
            for &i in &ws.idx {
                w.write_bits(q.encode(ws.a[i] * c, rng), 1);
            }
            payload_bits = budget;
        }
        out.n = n;
        out.payload_bits = payload_bits;
        out.side_bits = side_bits;
        out.bytes = w.into_bytes();
    }

    fn decompress_dithered_impl(
        &self,
        msg: &Compressed,
        ws: &mut Workspace,
        out: &mut [f32],
        fused: bool,
    ) {
        let n = self.frame.n();
        let big_n = self.frame.big_n();
        let budget = budget_bits(n, self.r);
        let mut r = BitReader::new(&msg.bytes);
        let gain = r.read_f32();
        if gain == 0.0 || budget == 0 {
            out.fill(0.0);
            return;
        }
        let s = r.read_f32();
        ws.a.resize(big_n, 0.0);
        if budget >= big_n {
            let alloc = allocate_bits(budget, big_n);
            for (i, xi) in ws.a.iter_mut().enumerate() {
                let bits = alloc.bits(i);
                let q = DitheredUniform::symmetric(s, bits);
                *xi = q.decode(r.read_bits(bits));
            }
        } else {
            ws.a.fill(0.0);
            let seed = r.read_u64();
            let mut sel_rng = Rng::seed_from(seed);
            sel_rng.sample_indices_into(big_n, budget, &mut ws.idx);
            let q = DitheredUniform::symmetric(s, 1);
            let rescale = big_n as f32 / budget as f32;
            for &i in &ws.idx {
                ws.a[i] = rescale * q.decode(r.read_bits(1));
            }
        }
        if fused {
            self.frame.apply_inplace(&mut ws.a, out);
        } else {
            self.frame.apply_inplace_reference(&mut ws.a, out);
        }
        for v in out.iter_mut() {
            *v *= gain;
        }
    }

    /// Unfused scalar-reference compress: full-sweep embed over the
    /// textbook scalar FWHT kernel, then the quantize/bitpack loop — the
    /// pre-fusion code path, kept as the bit-exactness oracle for
    /// [`Compressor::compress_into`] and as the same-run baseline the
    /// hot-path bench records. Wire bytes, bit accounting and RNG
    /// consumption are bit-identical to the fused path (the equivalence
    /// tier in `tests/test_kernels.rs` enforces it on dirty shared
    /// workspaces).
    pub fn compress_reference_into(
        &self,
        y: &[f32],
        rng: &mut Rng,
        ws: &mut Workspace,
        out: &mut Compressed,
    ) {
        assert_eq!(y.len(), self.frame.n());
        match self.mode {
            CodecMode::Deterministic => self.compress_deterministic_impl(y, ws, out, false),
            CodecMode::Dithered => self.compress_dithered_impl(y, rng, ws, out, false),
        }
    }

    /// Unfused scalar-reference decompress — see
    /// [`SubspaceCodec::compress_reference_into`].
    pub fn decompress_reference_into(&self, msg: &Compressed, ws: &mut Workspace, out: &mut [f32]) {
        assert_eq!(out.len(), self.frame.n());
        match self.mode {
            CodecMode::Deterministic => self.decompress_deterministic_impl(msg, ws, out, false),
            CodecMode::Dithered => self.decompress_dithered_impl(msg, ws, out, false),
        }
    }
}

impl Compressor for SubspaceCodec {
    fn name(&self) -> String {
        format!("{}[{}λ={:.2}]", self.label, self.frame.big_n(), self.frame.lambda())
    }

    fn n(&self) -> usize {
        self.frame.n()
    }

    fn bits_per_dim(&self) -> f32 {
        self.r
    }

    fn compress_into(&self, y: &[f32], rng: &mut Rng, ws: &mut Workspace, out: &mut Compressed) {
        assert_eq!(y.len(), self.frame.n());
        match self.mode {
            CodecMode::Deterministic => self.compress_deterministic_impl(y, ws, out, true),
            CodecMode::Dithered => self.compress_dithered_impl(y, rng, ws, out, true),
        }
    }

    fn decompress_into(&self, msg: &Compressed, ws: &mut Workspace, out: &mut [f32]) {
        assert_eq!(out.len(), self.frame.n());
        match self.mode {
            CodecMode::Deterministic => self.decompress_deterministic_impl(msg, ws, out, true),
            CodecMode::Dithered => self.decompress_dithered_impl(msg, ws, out, true),
        }
    }

    fn workspace_floats(&self) -> usize {
        self.frame.big_n()
    }

    fn is_unbiased(&self) -> bool {
        self.mode == CodecMode::Dithered
    }

    /// The frame's tables plus the cached label; solver scratch is warm
    /// state, not plan, and is excluded by contract.
    fn resident_bytes(&self) -> usize {
        self.frame.resident_bytes() + self.label.len()
    }
}

/// DSC constructor (democratic embedding, deterministic quantizer).
pub fn dsc(frame: Box<dyn Frame>, r: f32) -> SubspaceCodec {
    SubspaceCodec::new(frame, EmbedKind::Democratic, CodecMode::Deterministic, r)
}

/// Dithered DSC — the `(E_Dith, D_Dith)` of Alg. 2.
pub fn dsc_dithered(frame: Box<dyn Frame>, r: f32) -> SubspaceCodec {
    SubspaceCodec::new(frame, EmbedKind::Democratic, CodecMode::Dithered, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::frames::{HadamardFrame, OrthonormalFrame};
    use crate::linalg::vecops::dist2;
    use crate::testkit::prop::{forall, gen, Cases};

    fn hadamard_codec(n: usize, embed: EmbedKind, mode: CodecMode, r: f32, seed: u64) -> SubspaceCodec {
        let mut rng = Rng::seed_from(seed);
        SubspaceCodec::new(Box::new(HadamardFrame::new(n, &mut rng)), embed, mode, r)
    }

    #[test]
    fn theorem1_error_bound_dsc() {
        // ||y - Q_d(y)|| <= 2^{1-R/λ} K_u ||y|| — check with measured slack.
        let mut rng = Rng::seed_from(1);
        let n = 512; // N = 512, λ = 1 exactly
        let c = hadamard_codec(n, EmbedKind::Democratic, CodecMode::Deterministic, 4.0, 2);
        for _ in 0..5 {
            let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let msg = c.compress(&y, &mut rng);
            let yhat = c.decompress(&msg);
            let rel = dist2(&yhat, &y) / norm2(&y);
            // β = 2^{1-4}·K_u ≈ 0.125·K_u; with K_u ≲ 3 allow 0.5.
            assert!(rel < 0.5, "rel err {rel}");
        }
    }

    #[test]
    fn theorem1_error_bound_ndsc() {
        let mut rng = Rng::seed_from(3);
        let n = 1000; // N = 1024
        let c = hadamard_codec(n, EmbedKind::NearDemocratic, CodecMode::Deterministic, 4.0, 4);
        for _ in 0..5 {
            let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
            let msg = c.compress(&y, &mut rng);
            let yhat = c.decompress(&msg);
            let rel = dist2(&yhat, &y) / norm2(&y);
            let bound = c.beta();
            assert!(rel < bound, "rel err {rel} vs β {bound}");
        }
    }

    #[test]
    fn budget_respected_exactly() {
        forall(Cases::new("(N)DSC budget", 40), |rng, _| {
            let n = gen::dim(rng);
            let r = gen::bit_budget(rng);
            let mode =
                if rng.bernoulli(0.5) { CodecMode::Deterministic } else { CodecMode::Dithered };
            let embed =
                if rng.bernoulli(0.3) { EmbedKind::Democratic } else { EmbedKind::NearDemocratic };
            let frame = HadamardFrame::new(n, rng);
            let c = SubspaceCodec::new(Box::new(frame), embed, mode, r);
            let y = gen::nonzero_vector(rng, n);
            let msg = c.compress(&y, rng);
            assert!(
                msg.payload_bits <= budget_bits(n, r),
                "{}: payload {} > budget {}",
                c.name(),
                msg.payload_bits,
                budget_bits(n, r)
            );
            assert!(msg.side_bits <= 128 + 64);
            let yhat = c.decompress(&msg);
            assert_eq!(yhat.len(), n);
            assert!(yhat.iter().all(|v| v.is_finite()));
        });
    }

    #[test]
    fn dithered_is_unbiased() {
        // Average many independent compressions: mean → y.
        let mut rng = Rng::seed_from(5);
        let n = 64;
        let c = hadamard_codec(n, EmbedKind::NearDemocratic, CodecMode::Dithered, 2.0, 6);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let trials = 3000;
        let mut mean = vec![0.0f64; n];
        for _ in 0..trials {
            let msg = c.compress(&y, &mut rng);
            let yhat = c.decompress(&msg);
            for (m, &v) in mean.iter_mut().zip(&yhat) {
                *m += v as f64 / trials as f64;
            }
        }
        let mean_f: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
        let err = dist2(&mean_f, &y) / norm2(&y);
        assert!(err < 0.06, "bias {err}");
    }

    #[test]
    fn sublinear_dithered_unbiased() {
        // R = 0.5: subsampling + rescale must stay unbiased.
        let mut rng = Rng::seed_from(7);
        let n = 32;
        let c = hadamard_codec(n, EmbedKind::NearDemocratic, CodecMode::Dithered, 0.5, 8);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let trials = 8000;
        let mut mean = vec![0.0f64; n];
        for _ in 0..trials {
            let msg = c.compress(&y, &mut rng);
            assert_eq!(msg.payload_bits, 16);
            let yhat = c.decompress(&msg);
            for (m, &v) in mean.iter_mut().zip(&yhat) {
                *m += v as f64 / trials as f64;
            }
        }
        let mean_f: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
        let err = dist2(&mean_f, &y) / norm2(&y);
        assert!(err < 0.12, "bias {err}");
    }

    #[test]
    fn error_dimension_free_across_n() {
        // The headline property: at fixed R the relative error of NDSC
        // grows at most ~ sqrt(log N), nothing like sqrt(n).
        let mut rng = Rng::seed_from(9);
        let mut errs = Vec::new();
        for &n in &[64usize, 256, 1024, 4096] {
            let c = hadamard_codec(n, EmbedKind::NearDemocratic, CodecMode::Deterministic, 3.0, 10);
            let e = crate::quant::normalized_error(&c, 10, &mut rng, |rng| {
                (0..n).map(|_| rng.gaussian_cubed()).collect()
            });
            errs.push(e);
        }
        let growth = errs.last().unwrap() / errs.first().unwrap();
        // sqrt(n) growth would be 8x; sqrt(log) growth is ~1.2x.
        assert!(growth < 2.0, "errors {errs:?} grew {growth}x");
    }

    #[test]
    fn deterministic_roundtrip_is_deterministic() {
        let mut rng = Rng::seed_from(11);
        let n = 100;
        let c = hadamard_codec(n, EmbedKind::NearDemocratic, CodecMode::Deterministic, 2.0, 12);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
        let m1 = c.compress(&y, &mut rng);
        let m2 = c.compress(&y, &mut rng);
        assert_eq!(m1.bytes, m2.bytes);
    }

    #[test]
    fn zero_vector_roundtrip() {
        let mut rng = Rng::seed_from(13);
        for mode in [CodecMode::Deterministic, CodecMode::Dithered] {
            let c = hadamard_codec(16, EmbedKind::NearDemocratic, mode, 1.0, 14);
            let msg = c.compress(&vec![0.0; 16], &mut rng);
            let yhat = c.decompress(&msg);
            assert!(yhat.iter().all(|&v| v == 0.0), "{mode:?}");
        }
    }

    #[test]
    fn orthonormal_frame_codec_works() {
        let mut rng = Rng::seed_from(15);
        let n = 30;
        let frame = OrthonormalFrame::with_big_n(n, n, &mut rng);
        let c = SubspaceCodec::new(
            Box::new(frame),
            EmbedKind::NearDemocratic,
            CodecMode::Deterministic,
            4.0,
        );
        let y: Vec<f32> = (0..n).map(|_| rng.student_t(1)).collect();
        let msg = c.compress(&y, &mut rng);
        let yhat = c.decompress(&msg);
        assert!(dist2(&yhat, &y) / norm2(&y) < 0.6);
    }
}
