//! Random-k sparsification [19] — Table 1 and the "Rand-K" curves.
//!
//! Retains `k` coordinates chosen uniformly at random; indices are **shared
//! randomness** (the seed rides as `O(1)` side information — no per-index
//! cost, which is exactly how the paper budgets Fig. 2's "randomly
//! sparsified, 1 bit each" runs). Retained values get `value_bits` dithered
//! bits in `±‖y‖∞`. Optional `1/p` rescaling makes the sparsifier unbiased
//! (`p = k/n`), as required when used inside DQ-PSGD.

use crate::linalg::rng::Rng;
use crate::linalg::vecops::norm_inf;
use crate::quant::bitpack::{BitReader, BitWriter};
use crate::quant::dither::DitheredUniform;
use crate::quant::{Compressed, Compressor, Workspace};

pub struct RandK {
    n: usize,
    pub k: usize,
    pub value_bits: usize,
    /// Rescale by `n/k` for unbiasedness.
    pub rescale: bool,
    /// Nearest-neighbour (eq. 11 midpoints) instead of dithered values —
    /// the low-worst-case-error variant for error-feedback GD (Fig. 1d).
    pub deterministic: bool,
}

impl RandK {
    pub fn new(n: usize, k: usize, value_bits: usize) -> Self {
        assert!(k <= n && k > 0);
        assert!(value_bits >= 1);
        RandK { n, k, value_bits, rescale: false, deterministic: false }
    }

    pub fn unbiased(mut self) -> Self {
        self.rescale = true;
        self
    }

    pub fn deterministic(mut self) -> Self {
        self.deterministic = true;
        self
    }
}

impl Compressor for RandK {
    fn name(&self) -> String {
        format!("rand{}x{}b", self.k, self.value_bits)
    }

    fn n(&self) -> usize {
        self.n
    }

    fn bits_per_dim(&self) -> f32 {
        (self.k * self.value_bits) as f32 / self.n as f32
    }

    fn compress_into(&self, y: &[f32], rng: &mut Rng, ws: &mut Workspace, out: &mut Compressed) {
        assert_eq!(y.len(), self.n);
        let s = norm_inf(y);
        let seed = rng.next_u64();
        let mut w = BitWriter::reuse(std::mem::take(&mut out.bytes));
        w.reserve_bits(self.k * self.value_bits + 96);
        w.write_f32(s);
        w.write_u64(seed);
        let mut sel = Rng::seed_from(seed);
        sel.sample_indices_into(self.n, self.k, &mut ws.idx);
        let q = DitheredUniform::symmetric(s.max(1e-30), self.value_bits);
        let inv = 1.0 / s.max(1e-30);
        for &i in &ws.idx {
            let code = if self.deterministic {
                crate::quant::uniform::quantize_index(y[i] * inv, self.value_bits)
            } else {
                q.encode(y[i], rng)
            };
            w.write_bits(code, self.value_bits);
        }
        out.n = self.n;
        out.payload_bits = self.k * self.value_bits;
        out.side_bits = 32 + 64;
        out.bytes = w.into_bytes();
    }

    fn decompress_into(&self, msg: &Compressed, ws: &mut Workspace, out: &mut [f32]) {
        let mut r = BitReader::new(&msg.bytes);
        let s = r.read_f32();
        let seed = r.read_u64();
        let mut sel = Rng::seed_from(seed);
        sel.sample_indices_into(self.n, self.k, &mut ws.idx);
        let q = DitheredUniform::symmetric(s.max(1e-30), self.value_bits);
        let gain = if self.rescale { self.n as f32 / self.k as f32 } else { 1.0 };
        out.fill(0.0);
        for &i in &ws.idx {
            let code = r.read_bits(self.value_bits);
            out[i] = gain
                * if self.deterministic {
                    s * crate::quant::uniform::dequantize_index(code, self.value_bits)
                } else {
                    q.decode(code)
                };
        }
    }

    fn is_unbiased(&self) -> bool {
        self.rescale && !self.deterministic
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::{dist2, norm0, norm2};

    #[test]
    fn support_size_is_at_most_k() {
        let mut rng = Rng::seed_from(1);
        let c = RandK::new(100, 17, 4);
        let y: Vec<f32> = (0..100).map(|_| 1.0 + rng.uniform_f32()).collect();
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        assert!(norm0(&yhat) <= 17);
    }

    #[test]
    fn unbiased_with_rescale() {
        let mut rng = Rng::seed_from(2);
        let n = 30;
        let c = RandK::new(n, 15, 1).unbiased();
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let trials = 10_000;
        let mut mean = vec![0.0f64; n];
        for _ in 0..trials {
            let yhat = c.decompress(&c.compress(&y, &mut rng));
            for (m, &v) in mean.iter_mut().zip(&yhat) {
                *m += v as f64 / trials as f64;
            }
        }
        let mean_f: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
        assert!(dist2(&mean_f, &y) / norm2(&y) < 0.1);
    }

    #[test]
    fn decoder_recovers_same_support() {
        let mut rng = Rng::seed_from(3);
        let c = RandK::new(50, 10, 3);
        let y: Vec<f32> = (0..50).map(|_| rng.gaussian_f32()).collect();
        let msg = c.compress(&y, &mut rng);
        let y1 = c.decompress(&msg);
        let y2 = c.decompress(&msg);
        assert_eq!(y1, y2); // decode is deterministic given the message
    }
}
