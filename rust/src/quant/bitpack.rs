//! Exact-width bit packing — the wire format substrate.
//!
//! The paper's budget is `R` bits **per dimension**, with `R` any positive
//! real (sub-linear budgets `R < 1` included), plus `O(1)` bits for scalar
//! side information (App. F). To make that budget *auditable* rather than
//! notional, every compressor serializes through [`BitWriter`] /
//! [`BitReader`]: the coordinator's channel layer counts the exact payload
//! bits of each message and rejects over-budget sends.
//!
//! Both halves operate fully in place: [`BitReader`] borrows the wire bytes
//! and [`BitWriter::reuse`] rebuilds a writer on top of a spent byte buffer
//! (cleared, capacity kept), which is how the hot path recycles wire
//! buffers round-over-round without allocating.

/// Append-only bit-level writer (LSB-first within each byte).
#[derive(Default, Clone, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Number of valid bits in `buf`.
    len_bits: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity_bits(bits: usize) -> Self {
        BitWriter { buf: Vec::with_capacity(bits.div_ceil(8)), len_bits: 0 }
    }

    /// Rebuild a writer on top of a spent byte buffer: the buffer is
    /// cleared but its capacity is kept, so writing a message of the same
    /// size as the previous occupant allocates nothing.
    pub fn reuse(mut buf: Vec<u8>) -> Self {
        buf.clear();
        BitWriter { buf, len_bits: 0 }
    }

    /// Ensure capacity for `bits` more bits without reallocating later.
    pub fn reserve_bits(&mut self, bits: usize) {
        let need = (self.len_bits + bits).div_ceil(8);
        if need > self.buf.capacity() {
            self.buf.reserve(need - self.buf.len());
        }
    }

    /// Write the low `width` bits of `value` (`width ≤ 64`, enforced in
    /// every build profile).
    ///
    /// Bits of `value` above `width` are **masked off up front**: the wire
    /// stream is always exactly `width` bits of `value & ((1 << width) - 1)`
    /// regardless of build profile. A quantizer that hands over an
    /// over-wide value therefore produces the same (truncated) bytes in
    /// debug and release — it cannot corrupt stream *accounting*, only its
    /// own payload, and the adversarial tests below pin that contract.
    pub fn write_bits(&mut self, value: u64, width: usize) {
        assert!(width <= 64, "write_bits width {width} > 64");
        let mut remaining = width;
        let mut v = if width == 64 { value } else { value & ((1u64 << width) - 1) };
        while remaining > 0 {
            let bit_in_byte = self.len_bits % 8;
            if bit_in_byte == 0 {
                self.buf.push(0);
            }
            let byte = self.buf.last_mut().unwrap();
            let take = remaining.min(8 - bit_in_byte);
            let mask = if take == 64 { u64::MAX } else { (1u64 << take) - 1 };
            *byte |= ((v & mask) as u8) << bit_in_byte;
            v >>= take;
            remaining -= take;
            self.len_bits += take;
        }
    }

    /// Write a full `f32` (32 bits of side information).
    pub fn write_f32(&mut self, x: f32) {
        self.write_bits(x.to_bits() as u64, 32);
    }

    /// Write a `u64` (e.g. a shared-randomness seed).
    pub fn write_u64(&mut self, x: u64) {
        self.write_bits(x & 0xFFFF_FFFF, 32);
        self.write_bits(x >> 32, 32);
    }

    /// Total bits written so far.
    pub fn len_bits(&self) -> usize {
        self.len_bits
    }

    /// Finish, returning the byte buffer (last byte zero-padded).
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Bit-level reader matching [`BitWriter`]'s layout.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos_bits: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos_bits: 0 }
    }

    /// Read `width` bits (`≤ 64`, enforced in every build profile).
    /// Panics past end of buffer.
    pub fn read_bits(&mut self, width: usize) -> u64 {
        assert!(width <= 64, "read_bits width {width} > 64");
        let mut out = 0u64;
        let mut got = 0usize;
        while got < width {
            let byte_idx = self.pos_bits / 8;
            let bit_in_byte = self.pos_bits % 8;
            assert!(byte_idx < self.buf.len(), "BitReader past end");
            let take = (width - got).min(8 - bit_in_byte);
            let chunk = (self.buf[byte_idx] >> bit_in_byte) as u64 & ((1u64 << take) - 1);
            out |= chunk << got;
            got += take;
            self.pos_bits += take;
        }
        out
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_bits(32) as u32)
    }

    pub fn read_u64(&mut self) -> u64 {
        let lo = self.read_bits(32);
        let hi = self.read_bits(32);
        lo | (hi << 32)
    }

    pub fn pos_bits(&self) -> usize {
        self.pos_bits
    }
}

/// Per-coordinate bit allocation for a total budget of `total_bits` over
/// `big_n` coordinates: each coordinate gets `⌊total/N⌋` bits and the first
/// `total mod N` coordinates get one extra. Exactly `total_bits` are used.
///
/// This is how a *fixed-length* scheme realizes fractional `R` (and the
/// `nR/N` bits/dimension of Theorem 1's proof): with `R < λ` some
/// coordinates receive zero bits and decode to the interval midpoint `0`.
pub fn allocate_bits(total_bits: usize, big_n: usize) -> BitAllocation {
    let base = total_bits / big_n;
    let extra = total_bits % big_n;
    BitAllocation { base, extra, big_n }
}

/// Compact representation of the allocation (no per-coordinate Vec).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitAllocation {
    pub base: usize,
    pub extra: usize,
    pub big_n: usize,
}

impl BitAllocation {
    /// Bits assigned to coordinate `i`.
    #[inline]
    pub fn bits(&self, i: usize) -> usize {
        self.base + usize::from(i < self.extra)
    }

    /// Total bits across all coordinates.
    pub fn total(&self) -> usize {
        self.base * self.big_n + self.extra
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::testkit::prop::{forall, Cases};

    #[test]
    fn roundtrip_mixed_widths() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_f32(3.25);
        w.write_bits(0xDEAD, 16);
        w.write_bits(1, 1);
        w.write_u64(0x0123_4567_89AB_CDEF);
        let total = w.len_bits();
        assert_eq!(total, 3 + 32 + 16 + 1 + 64);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3), 0b101);
        assert_eq!(r.read_f32(), 3.25);
        assert_eq!(r.read_bits(16), 0xDEAD);
        assert_eq!(r.read_bits(1), 1);
        assert_eq!(r.read_u64(), 0x0123_4567_89AB_CDEF);
        assert_eq!(r.pos_bits(), total);
    }

    #[test]
    fn prop_roundtrip_random_streams() {
        forall(Cases::new("bitpack roundtrip", 200), |rng: &mut Rng, _| {
            let n_fields = 1 + rng.below(40);
            let mut fields: Vec<(u64, usize)> = Vec::new();
            let mut w = BitWriter::new();
            for _ in 0..n_fields {
                let width = 1 + rng.below(64);
                let value = if width == 64 {
                    rng.next_u64()
                } else {
                    rng.next_u64() & ((1u64 << width) - 1)
                };
                w.write_bits(value, width);
                fields.push((value, width));
            }
            let expected_bits: usize = fields.iter().map(|f| f.1).sum();
            assert_eq!(w.len_bits(), expected_bits);
            let bytes = w.into_bytes();
            assert_eq!(bytes.len(), expected_bits.div_ceil(8));
            let mut r = BitReader::new(&bytes);
            for (value, width) in fields {
                assert_eq!(r.read_bits(width), value, "width {width}");
            }
        });
    }

    /// Property: packing through a *fractional* budget allocation —
    /// `⌊nR⌋` bits split into mixed widths by [`allocate_bits`], exactly
    /// how every fixed-length scheme realizes non-integer `R` — round-trips
    /// bit-exactly, spends exactly the budget, and pads only the final
    /// byte.
    #[test]
    fn prop_fractional_budget_roundtrip_bit_exact() {
        forall(Cases::new("fractional-width packing", 150), |rng: &mut Rng, _| {
            let n = 1 + rng.below(300);
            let r = [0.1f32, 0.25, 0.5, 1.0, 1.7, 2.5, 3.0, 6.3][rng.below(8)];
            let total = crate::quant::budget_bits(n, r);
            let alloc = allocate_bits(total, n);
            let vals: Vec<u64> = (0..n)
                .map(|i| {
                    let b = alloc.bits(i);
                    if b == 0 {
                        0
                    } else {
                        rng.next_u64() & ((1u64 << b) - 1)
                    }
                })
                .collect();
            let mut w = BitWriter::with_capacity_bits(total);
            for (i, &v) in vals.iter().enumerate() {
                let b = alloc.bits(i);
                if b > 0 {
                    w.write_bits(v, b);
                }
            }
            assert_eq!(w.len_bits(), total, "n={n} R={r}: budget not exactly spent");
            let bytes = w.into_bytes();
            assert_eq!(bytes.len(), total.div_ceil(8), "n={n} R={r}: slack bytes");
            let mut rd = BitReader::new(&bytes);
            for (i, &v) in vals.iter().enumerate() {
                let b = alloc.bits(i);
                if b > 0 {
                    assert_eq!(rd.read_bits(b), v, "n={n} R={r} coord {i} width {b}");
                }
            }
            assert_eq!(rd.pos_bits(), total);
        });
    }

    #[test]
    fn allocation_exactly_spends_budget() {
        forall(Cases::new("bit allocation", 300), |rng: &mut Rng, _| {
            let big_n = 1 + rng.below(2000);
            let total = rng.below(8 * big_n);
            let alloc = allocate_bits(total, big_n);
            let sum: usize = (0..big_n).map(|i| alloc.bits(i)).sum();
            assert_eq!(sum, total);
            assert_eq!(alloc.total(), total);
            // Allocation is balanced: widths differ by at most one.
            let min = alloc.bits(big_n - 1);
            let max = alloc.bits(0);
            assert!(max - min <= 1);
        });
    }

    #[test]
    fn reuse_keeps_capacity_and_clears_content() {
        let mut w = BitWriter::with_capacity_bits(256);
        w.write_u64(0xDEAD_BEEF_0BAD_F00D);
        w.write_bits(0b1011, 4);
        let bytes = w.into_bytes();
        let cap = bytes.capacity();
        let want = bytes.clone();
        // Recycle: identical writes must produce identical bytes with no
        // buffer growth.
        let mut w2 = BitWriter::reuse(bytes);
        w2.reserve_bits(68);
        w2.write_u64(0xDEAD_BEEF_0BAD_F00D);
        w2.write_bits(0b1011, 4);
        assert_eq!(w2.len_bits(), 68);
        let bytes2 = w2.into_bytes();
        assert_eq!(bytes2, want);
        assert_eq!(bytes2.capacity(), cap, "reuse must not shrink capacity");
    }

    /// Edge widths {0, 1, 63, 64} round-trip exactly, in release builds
    /// too (none of these rely on `debug_assert!`).
    #[test]
    fn edge_widths_roundtrip_release_mode() {
        let cases: &[(u64, usize)] = &[
            (0, 0), // width-0 write is a no-op
            (1, 1),
            (0, 1),
            ((1u64 << 63) - 1, 63),
            (1u64 << 62, 63),
            (u64::MAX, 64),
            (0, 64),
            (0x8000_0000_0000_0001, 64),
        ];
        let mut w = BitWriter::new();
        for &(v, width) in cases {
            w.write_bits(v, width);
        }
        let total: usize = cases.iter().map(|c| c.1).sum();
        assert_eq!(w.len_bits(), total);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &(v, width) in cases {
            assert_eq!(r.read_bits(width), v, "width {width}");
        }
        assert_eq!(r.pos_bits(), total);
    }

    /// Over-wide values are masked up front: the wire bytes and the bit
    /// accounting are identical to writing the pre-masked value, in every
    /// build profile.
    #[test]
    fn overwide_values_truncate_to_masked_wire_bytes() {
        for &(value, width) in
            &[(u64::MAX, 3usize), (0xABCD, 7), (1u64 << 40, 13), (u64::MAX, 1), (0b100, 2)]
        {
            let masked = value & ((1u64 << width) - 1);
            let mut dirty = BitWriter::new();
            dirty.write_bits(0b1, 5); // unaligned start so masking must not smear
            dirty.write_bits(value, width);
            dirty.write_bits(0x55, 8);
            let mut clean = BitWriter::new();
            clean.write_bits(0b1, 5);
            clean.write_bits(masked, width);
            clean.write_bits(0x55, 8);
            assert_eq!(dirty.len_bits(), clean.len_bits(), "width {width}");
            let (db, cb) = (dirty.into_bytes(), clean.into_bytes());
            assert_eq!(db, cb, "value {value:#x} width {width}");
            let mut r = BitReader::new(&db);
            assert_eq!(r.read_bits(5), 0b1);
            assert_eq!(r.read_bits(width), masked);
            assert_eq!(r.read_bits(8), 0x55);
        }
    }

    #[test]
    #[should_panic(expected = "width 65 > 64")]
    fn writer_rejects_width_over_64_in_release() {
        let mut w = BitWriter::new();
        w.write_bits(0, 65);
    }

    #[test]
    #[should_panic(expected = "width 65 > 64")]
    fn reader_rejects_width_over_64_in_release() {
        let bytes = vec![0u8; 16];
        let mut r = BitReader::new(&bytes);
        r.read_bits(65);
    }

    /// Width-0 reads/writes are no-ops even at a dirty, unaligned cursor.
    #[test]
    fn width_zero_is_noop_mid_stream() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(u64::MAX, 0); // value ignored entirely at width 0
        w.write_bits(0b11, 2);
        assert_eq!(w.len_bits(), 5);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3), 0b101);
        assert_eq!(r.read_bits(0), 0);
        assert_eq!(r.read_bits(2), 0b11);
    }

    #[test]
    fn sublinear_budget_gives_zero_bits_to_tail() {
        let alloc = allocate_bits(15, 30); // R = 0.5 over N = 30
        assert_eq!(alloc.bits(0), 1);
        assert_eq!(alloc.bits(14), 1);
        assert_eq!(alloc.bits(15), 0);
        assert_eq!(alloc.bits(29), 0);
    }
}
