//! DQGD's quantizer — the baseline of Lin, Kostina & Hassibi [6] that the
//! paper's Fig. 1b compares against.
//!
//! Unlike our adaptive `NaiveUniform` (which spends 32 side bits on the
//! per-message `‖u‖∞` scale), DQGD uses a **predefined decaying dynamic
//! range** `r_t = r₀·γᵗ` agreed offline between worker and server — zero
//! side information, but fragile: once the quantizer input outgrows the
//! shrunken range, clipping error compounds through the error-feedback
//! loop and the descent diverges. This is exactly the sharp rate-1 plateau
//! of the paper's Fig. 1b at low budgets, which the ‖·‖∞-normalized
//! variants avoid.
//!
//! The schedule state is a per-compressor atomic round counter; the round
//! index rides in the message header (counted as side bits) so decode is
//! self-contained and order-robust.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::linalg::rng::Rng;
use crate::quant::bitpack::{allocate_bits, BitReader, BitWriter};
use crate::quant::uniform::{dequantize_index, quantize_index};
use crate::quant::{budget_bits, Compressed, Compressor, Workspace};

pub struct DqgdRange {
    n: usize,
    r: f32,
    /// Initial dynamic range `r₀` (≈ an upper bound on `‖∇f(x₀)‖∞`).
    pub r0: f32,
    /// Per-round decay `γ` (the paper's ν, the target linear rate).
    pub gamma: f32,
    round: AtomicU64,
}

impl DqgdRange {
    pub fn new(n: usize, r: f32, r0: f32, gamma: f32) -> Self {
        assert!(r > 0.0 && r0 > 0.0 && (0.0..=1.0).contains(&gamma));
        DqgdRange { n, r, r0, gamma, round: AtomicU64::new(0) }
    }

    fn range_at(&self, t: u64) -> f32 {
        self.r0 * self.gamma.powi(t.min(1_000_000) as i32)
    }
}

impl Compressor for DqgdRange {
    fn name(&self) -> String {
        format!("dqgd(r0={},γ={})", self.r0, self.gamma)
    }

    fn n(&self) -> usize {
        self.n
    }

    fn bits_per_dim(&self) -> f32 {
        self.r
    }

    fn compress_into(&self, y: &[f32], _rng: &mut Rng, _ws: &mut Workspace, out: &mut Compressed) {
        assert_eq!(y.len(), self.n);
        let t = self.round.fetch_add(1, Ordering::Relaxed);
        let range = self.range_at(t).max(1e-30);
        let budget = budget_bits(self.n, self.r);
        let alloc = allocate_bits(budget, self.n);
        let mut w = BitWriter::reuse(std::mem::take(&mut out.bytes));
        w.reserve_bits(budget + 32);
        w.write_bits(t & 0xFFFF_FFFF, 32); // round header
        let inv = 1.0 / range;
        for (i, &yi) in y.iter().enumerate() {
            let bits = alloc.bits(i);
            if bits > 0 {
                // values outside the schedule's range CLIP — the failure mode
                w.write_bits(quantize_index(yi * inv, bits), bits);
            }
        }
        out.n = self.n;
        out.payload_bits = budget;
        out.side_bits = 32;
        out.bytes = w.into_bytes();
    }

    fn decompress_into(&self, msg: &Compressed, _ws: &mut Workspace, out: &mut [f32]) {
        let mut rd = BitReader::new(&msg.bytes);
        let t = rd.read_bits(32);
        let range = self.range_at(t).max(1e-30);
        let alloc = allocate_bits(budget_bits(self.n, self.r), self.n);
        for (i, yi) in out.iter_mut().enumerate() {
            let bits = alloc.bits(i);
            *yi = if bits > 0 { range * dequantize_index(rd.read_bits(bits), bits) } else { 0.0 };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::{dist2, norm2};

    #[test]
    fn roundtrip_within_range_is_accurate() {
        let mut rng = Rng::seed_from(1);
        let c = DqgdRange::new(64, 6.0, 10.0, 1.0); // no decay
        let y: Vec<f32> = (0..64).map(|_| rng.gaussian_f32()).collect(); // well within ±10
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        assert!(dist2(&yhat, &y) / norm2(&y) < 0.2);
    }

    #[test]
    fn out_of_range_inputs_clip() {
        let mut rng = Rng::seed_from(2);
        let c = DqgdRange::new(8, 8.0, 1.0, 1.0);
        let y = vec![100.0f32; 8]; // far outside ±1
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        // everything clips to the top cell near +1
        assert!(yhat.iter().all(|&v| v < 1.1));
        assert!(dist2(&yhat, &y) / norm2(&y) > 0.9, "clipping must destroy the vector");
    }

    #[test]
    fn schedule_decays_across_rounds() {
        let mut rng = Rng::seed_from(3);
        let c = DqgdRange::new(4, 8.0, 8.0, 0.5);
        let y = vec![1.0f32; 4];
        // round 0: range 8, resolution coarse; round 3: range 1, exact-ish
        let e0 = {
            let yhat = c.decompress(&c.compress(&y, &mut rng));
            dist2(&yhat, &y)
        };
        c.compress(&y, &mut rng); // round 1
        c.compress(&y, &mut rng); // round 2
        let e3 = {
            let yhat = c.decompress(&c.compress(&y, &mut rng));
            dist2(&yhat, &y)
        };
        assert!(e3 < e0, "finer range should quantize better: {e0} -> {e3}");
    }
}
