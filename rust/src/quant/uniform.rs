//! R-bit uniform scalar quantization (eq. 11 of the paper).
//!
//! With `b` bits a coordinate in `[-1, 1]` maps to one of `M = 2^b` points
//! `v_i = −1 + (2i−1)Δ/2`, `Δ = 2/M`; the worst-case per-coordinate error
//! is `Δ/2 = 2^{−b}`. Coordinates allotted 0 bits decode to the midpoint 0.
//!
//! Every helper here is a pure scalar function — no state, no heap — so
//! the schemes built on top ((N)DSC, the naive baseline, DQGD) quantize
//! entire vectors inside the allocation-free `compress_into` hot path.

/// Nearest-neighbour index of `x ∈ [−1,1]` among the `M = 2^bits` points.
#[inline]
pub fn quantize_index(x: f32, bits: usize) -> u64 {
    debug_assert!(bits >= 1 && bits <= 32);
    let m = 1u64 << bits;
    // Cells are [-1 + iΔ, -1 + (i+1)Δ); clamp handles x = ±1 and overshoot.
    let delta = 2.0 / m as f32;
    let i = ((x.clamp(-1.0, 1.0) + 1.0) / delta) as i64;
    i.clamp(0, m as i64 - 1) as u64
}

/// Reconstruction point for an index.
#[inline]
pub fn dequantize_index(i: u64, bits: usize) -> f32 {
    let m = 1u64 << bits;
    let delta = 2.0 / m as f32;
    -1.0 + (2.0 * i as f32 + 1.0) * delta / 2.0
}

/// Quantize a value with `bits` bits (0 bits → 0.0).
#[inline]
pub fn quantize_value(x: f32, bits: usize) -> f32 {
    if bits == 0 {
        0.0
    } else {
        dequantize_index(quantize_index(x, bits), bits)
    }
}

/// Worst-case error of the `b`-bit scalar quantizer on `[−1,1]`: `2^{−b}`
/// (`= 1` for `b = 0`, the midpoint decoder).
#[inline]
pub fn worst_case_err(bits: usize) -> f32 {
    if bits == 0 {
        1.0
    } else {
        (2.0f32).powi(-(bits as i32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::{forall, Cases};

    #[test]
    fn one_bit_maps_to_pm_half() {
        // M = 2: points at -0.5 and +0.5.
        assert_eq!(quantize_value(-0.9, 1), -0.5);
        assert_eq!(quantize_value(0.3, 1), 0.5);
        assert_eq!(quantize_value(-0.001, 1), -0.5);
    }

    #[test]
    fn error_bounded_by_half_delta() {
        forall(Cases::new("uniform error bound", 500), |rng, _| {
            let bits = 1 + rng.below(12);
            let x = (rng.uniform_f32() - 0.5) * 2.0;
            let q = quantize_value(x, bits);
            let delta = 2.0 / (1u64 << bits) as f32;
            assert!((x - q).abs() <= delta / 2.0 + 1e-6, "bits={bits} x={x} q={q}");
        });
    }

    #[test]
    fn roundtrip_index_value() {
        for bits in 1..=10 {
            let m = 1u64 << bits;
            for i in 0..m.min(64) {
                let v = dequantize_index(i, bits);
                assert_eq!(quantize_index(v, bits), i, "bits={bits} i={i}");
            }
        }
    }

    #[test]
    fn clamps_out_of_range() {
        assert_eq!(quantize_index(5.0, 3), (1 << 3) - 1);
        assert_eq!(quantize_index(-5.0, 3), 0);
    }

    #[test]
    fn zero_bits_decodes_to_midpoint() {
        assert_eq!(quantize_value(0.73, 0), 0.0);
        assert_eq!(worst_case_err(0), 1.0);
    }
}
