//! Source coding under a strict bit budget — §3 of the paper plus every
//! baseline from Table 1.
//!
//! All compressors implement [`Compressor`]: a fixed-length mapping
//! `R^n → {0,1}^{⌊nR⌋ + O(1)}` with bit-exact serialization. The `O(1)`
//! side-information bits (norm scalars, shared-randomness seeds) are
//! reported separately per App. F so the coordinator can account them.
//!
//! | Module | Scheme | Paper ref |
//! |---|---|---|
//! | [`dsc`] | Democratic Source Coding (deterministic & dithered) | §3.1, App. E |
//! | [`ndsc`] | Near-Democratic Source Coding (Hadamard/orthonormal) | §3.1 |
//! | [`uniform`] | R-bit uniform scalar quantizer (eq. 11) | §3 |
//! | [`dither`] | stochastic uniform / dithered quantizer (eq. 20) | App. E |
//! | [`gain_shape`] | gain–shape composition | App. E |
//! | [`qsgd`] | QSGD [8] | Table 1 |
//! | [`sign`] | 1-bit sign quantization [14, 15] | Table 1 |
//! | [`ternary`] | TernGrad [16] | Table 1 |
//! | [`topk`] | Top-k sparsification [18] | Table 1 |
//! | [`randk`] | random-k sparsification [19] | Table 1 |
//! | [`vqsgd`] | vqSGD cross-polytope [17] | Table 1 |
//! | [`ratq`] | RATQ-style rotated adaptive quantizer [7] | Table 1 |
//! | [`compose`] | sparsify/compress *in the embedding domain* | App. H |
//! | [`registry`] | unified spec → compressor registry over the whole zoo | §3, App. F |
//!
//! [`registry`] is the single place that enumerates the zoo: a
//! [`registry::CompressorSpec`] names a scheme, `build(spec, n, R)`
//! instantiates it with every budget-dependent knob derived from `⌊nR⌋`,
//! and `registry::all_specs()` is the row set of the cross-scheme
//! conformance matrix (`rust/tests/test_conformance.rs`).

pub mod bitpack;
pub mod compose;
pub mod dither;
pub mod dqgd;
pub mod dsc;
pub mod gain_shape;
pub mod ndsc;
pub mod qsgd;
pub mod randk;
pub mod ratq;
pub mod registry;
pub mod sign;
pub mod ternary;
pub mod topk;
pub mod uniform;
pub mod vqsgd;

use crate::linalg::rng::Rng;

/// A compressed message: exact wire bytes plus the bit accounting the
/// coordinator's budget enforcement uses.
#[derive(Clone, Debug)]
pub struct Compressed {
    /// Original dimension `n` of the compressed vector.
    pub n: usize,
    /// Bit-packed wire payload.
    pub bytes: Vec<u8>,
    /// Bits charged against the `⌊nR⌋` budget.
    pub payload_bits: usize,
    /// `O(1)` side-information bits (norm scalars, seeds) per App. F.
    pub side_bits: usize,
}

impl Compressed {
    /// An empty message shell for [`Compressor::compress_into`] to fill.
    /// Reusing one shell round-over-round reuses its byte buffer: after the
    /// first fill the encode path performs no heap allocation.
    pub fn empty(n: usize) -> Self {
        Compressed { n, bytes: Vec::new(), payload_bits: 0, side_bits: 0 }
    }

    /// Total wire bits.
    pub fn total_bits(&self) -> usize {
        self.payload_bits + self.side_bits
    }

    /// Effective rate in bits/dimension, *excluding* the `O(1)` part —
    /// the quantity constrained to `≤ R` in the paper.
    pub fn rate(&self) -> f32 {
        self.payload_bits as f32 / self.n as f32
    }
}

/// Reusable scratch buffers for the allocation-free compression hot path.
///
/// A `Workspace` is a plain bag of growable buffers that
/// [`Compressor::compress_into`] / [`Compressor::decompress_into`] resize
/// and use freely; buffer *contents* carry no state between calls (every
/// scheme fully overwrites what it reads), so one workspace can be shared
/// across different codecs, dimensions and budgets — capacities only ever
/// grow. Size one upfront with [`Workspace::for_compressor`] (or the
/// [`Compressor::workspace_floats`] hint) and steady-state rounds perform
/// zero heap allocations; `rust/tests/test_alloc.rs` enforces this.
///
/// Composed codecs ([`compose::EmbeddedCompressor`]) hold their embedding
/// in the dedicated `emb` buffer (via `mem::take`), so the inner scheme is
/// free to use `a`/`b`/`c`/`idx` without collision. (Nesting a composition
/// inside a composition would contend for `emb` and fall back to
/// per-call allocation; the registry never builds that shape.)
#[derive(Debug, Default)]
pub struct Workspace {
    /// Primary f32 scratch — embedding-domain vectors (length `N`).
    pub a: Vec<f32>,
    /// Secondary f32 scratch — shape vectors, normalized copies.
    pub b: Vec<f32>,
    /// Tertiary f32 scratch — pseudo-inverse solves and other temporaries.
    pub c: Vec<f32>,
    /// Index scratch — sparsifier supports, subsampling draws.
    pub idx: Vec<usize>,
    /// Composition scratch — the outer embedding of an
    /// [`compose::EmbeddedCompressor`]; reserved for it alone.
    pub emb: Vec<f32>,
}

impl Workspace {
    /// An empty workspace; buffers grow on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// A workspace pre-sized for `c`: the dominant f32 buffer (`a`, the
    /// embedding-domain scratch every subspace path touches) is reserved
    /// at the codec's [`Compressor::workspace_floats`] report. The other
    /// buffers are touched by fewer schemes (or only one side of the
    /// encode/decode pair) and grow once on their first use — eagerly
    /// reserving all of them would waste O(N) per slot on codecs that
    /// never look at them (e.g. every server decode slot would carry a
    /// dead `b`).
    pub fn for_compressor(c: &dyn Compressor) -> Self {
        let floats = c.workspace_floats();
        Workspace { a: Vec::with_capacity(floats), ..Default::default() }
    }
}

/// A fixed-length vector compressor with budget `R` bits/dimension.
///
/// The encode/decode API comes in two equivalent forms:
///
/// * the **allocating** form ([`Compressor::compress`] /
///   [`Compressor::decompress`]) returns fresh buffers — convenient for
///   tests and one-shot calls;
/// * the **workspace** form ([`Compressor::compress_into`] /
///   [`Compressor::decompress_into`]) writes into caller-owned buffers and
///   is allocation-free once those buffers are warm — what the coordinator
///   and the optimizer loops use every round.
///
/// The two forms are **bit-identical**: given the same input and the same
/// RNG state they produce exactly the same wire bytes and the same decoded
/// vector (`rust/tests/test_conformance.rs` asserts this over the whole
/// registry × budget × dimension matrix). Each pair has a default
/// implementation in terms of the other, so an implementor must override
/// **at least one form of each direction** (overriding neither recurses);
/// every in-tree scheme overrides the workspace form and inherits the
/// allocating wrappers.
pub trait Compressor: Send + Sync {
    /// Human-readable name used in reports (e.g. `"NDSC-Hadamard"`).
    fn name(&self) -> String;
    /// Input dimension.
    fn n(&self) -> usize;
    /// Configured budget `R` (bits per dimension); the compressor must emit
    /// `payload_bits ≤ ⌊n·R⌋` for every input.
    fn bits_per_dim(&self) -> f32;

    /// Encode. Stochastic schemes draw dithers / samples from `rng`;
    /// deterministic schemes ignore it.
    fn compress(&self, y: &[f32], rng: &mut Rng) -> Compressed {
        let mut ws = Workspace::new();
        let mut out = Compressed::empty(self.n());
        self.compress_into(y, rng, &mut ws, &mut out);
        out
    }

    /// Decode (the parameter-server side).
    fn decompress(&self, msg: &Compressed) -> Vec<f32> {
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; self.n()];
        self.decompress_into(msg, &mut ws, &mut out);
        out
    }

    /// Encode into a reused message shell, scratching in `ws`. Overwrites
    /// every field of `out` (recycling its byte buffer); draws from `rng`
    /// exactly as [`Compressor::compress`] does, so the wire bytes are
    /// bit-identical to the allocating path under the same RNG state.
    /// Allocation-free once `ws` and `out.bytes` have warm capacity.
    fn compress_into(&self, y: &[f32], rng: &mut Rng, ws: &mut Workspace, out: &mut Compressed) {
        let _ = ws;
        *out = self.compress(y, rng);
    }

    /// Decode into `out` (`out.len() == n`), scratching in `ws`. Fully
    /// overwrites `out` — untouched coordinates are written as `0.0`, never
    /// left stale. Bit-identical to [`Compressor::decompress`].
    fn decompress_into(&self, msg: &Compressed, ws: &mut Workspace, out: &mut [f32]) {
        let _ = ws;
        let y = self.decompress(msg);
        out.copy_from_slice(&y);
    }

    /// Workspace sizing hint: the largest f32 scratch length this codec
    /// touches (the embedding dimension `N` for subspace codecs, `n`
    /// otherwise). `Workspace::for_compressor` uses it to preallocate.
    fn workspace_floats(&self) -> usize {
        self.n()
    }

    /// Whether `E[decompress(compress(y))] = y` (needed by DQ-PSGD's
    /// analysis; deterministic nearest-neighbour schemes are biased).
    fn is_unbiased(&self) -> bool {
        false
    }

    /// Heap bytes of **immutable plan state** this codec holds resident
    /// for its lifetime — materialized frames, sign vectors, nested
    /// codecs — as accounted by the serve-layer plan cache
    /// ([`crate::serve::plancache::PlanCache`]) against its byte cap.
    /// Scalar-configured schemes (sign, QSGD, top-k, …) own no such
    /// state and inherit this `0` default; schemes wrapping a
    /// [`crate::linalg::frames::Frame`] or a sign table override it
    /// with the true figure. Warm scratch (solver buffers, workspaces)
    /// is deliberately excluded: it is rebuilt on demand and not part
    /// of the shared plan.
    fn resident_bytes(&self) -> usize {
        0
    }
}

/// Budget ceiling in payload bits for dimension `n` at rate `r`.
pub fn budget_bits(n: usize, r: f32) -> usize {
    (n as f64 * r as f64).floor() as usize
}

/// Measured normalized error `‖Q(y) − y‖₂ / ‖y‖₂` averaged over `trials`
/// draws of `gen` — the quantity plotted in Fig. 1a.
pub fn normalized_error(
    c: &dyn Compressor,
    trials: usize,
    rng: &mut Rng,
    mut gen: impl FnMut(&mut Rng) -> Vec<f32>,
) -> f32 {
    let mut acc = 0.0f64;
    let mut used = 0usize;
    for _ in 0..trials {
        let y = gen(rng);
        let ny = crate::linalg::vecops::norm2(&y);
        if ny == 0.0 {
            continue;
        }
        let msg = c.compress(&y, rng);
        let yhat = c.decompress(&msg);
        acc += (crate::linalg::vecops::dist2(&yhat, &y) / ny) as f64;
        used += 1;
    }
    (acc / used.max(1) as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_bits_floor() {
        assert_eq!(budget_bits(1000, 0.5), 500);
        assert_eq!(budget_bits(784, 0.1), 78);
        assert_eq!(budget_bits(30, 0.5), 15);
        assert_eq!(budget_bits(116, 3.0), 348);
    }

    /// A legacy-style implementor (only `compress`/`decompress` overridden)
    /// must get working `_into` wrappers from the trait defaults.
    #[test]
    fn default_into_wrappers_serve_legacy_impls() {
        struct Legacy;
        impl Compressor for Legacy {
            fn name(&self) -> String {
                "legacy".into()
            }
            fn n(&self) -> usize {
                4
            }
            fn bits_per_dim(&self) -> f32 {
                32.0
            }
            fn compress(&self, y: &[f32], _rng: &mut Rng) -> Compressed {
                let mut w = crate::quant::bitpack::BitWriter::new();
                for &v in y {
                    w.write_f32(v);
                }
                Compressed { n: 4, bytes: w.into_bytes(), payload_bits: 128, side_bits: 0 }
            }
            fn decompress(&self, msg: &Compressed) -> Vec<f32> {
                let mut r = crate::quant::bitpack::BitReader::new(&msg.bytes);
                (0..4).map(|_| r.read_f32()).collect()
            }
        }
        let c = Legacy;
        let mut rng = Rng::seed_from(1);
        let y = [1.0f32, -2.0, 3.5, 0.25];
        let mut ws = Workspace::new();
        let mut msg = Compressed::empty(4);
        c.compress_into(&y, &mut rng, &mut ws, &mut msg);
        assert_eq!(msg.bytes, c.compress(&y, &mut rng).bytes);
        let mut out = [0.0f32; 4];
        c.decompress_into(&msg, &mut ws, &mut out);
        assert_eq!(out.to_vec(), y.to_vec());
    }
}
