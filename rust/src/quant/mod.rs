//! Source coding under a strict bit budget — §3 of the paper plus every
//! baseline from Table 1.
//!
//! All compressors implement [`Compressor`]: a fixed-length mapping
//! `R^n → {0,1}^{⌊nR⌋ + O(1)}` with bit-exact serialization. The `O(1)`
//! side-information bits (norm scalars, shared-randomness seeds) are
//! reported separately per App. F so the coordinator can account them.
//!
//! | Module | Scheme | Paper ref |
//! |---|---|---|
//! | [`dsc`] | Democratic Source Coding (deterministic & dithered) | §3.1, App. E |
//! | [`ndsc`] | Near-Democratic Source Coding (Hadamard/orthonormal) | §3.1 |
//! | [`uniform`] | R-bit uniform scalar quantizer (eq. 11) | §3 |
//! | [`dither`] | stochastic uniform / dithered quantizer (eq. 20) | App. E |
//! | [`gain_shape`] | gain–shape composition | App. E |
//! | [`qsgd`] | QSGD [8] | Table 1 |
//! | [`sign`] | 1-bit sign quantization [14, 15] | Table 1 |
//! | [`ternary`] | TernGrad [16] | Table 1 |
//! | [`topk`] | Top-k sparsification [18] | Table 1 |
//! | [`randk`] | random-k sparsification [19] | Table 1 |
//! | [`vqsgd`] | vqSGD cross-polytope [17] | Table 1 |
//! | [`ratq`] | RATQ-style rotated adaptive quantizer [7] | Table 1 |
//! | [`compose`] | sparsify/compress *in the embedding domain* | App. H |
//! | [`registry`] | unified spec → compressor registry over the whole zoo | §3, App. F |
//!
//! [`registry`] is the single place that enumerates the zoo: a
//! [`registry::CompressorSpec`] names a scheme, `build(spec, n, R)`
//! instantiates it with every budget-dependent knob derived from `⌊nR⌋`,
//! and `registry::all_specs()` is the row set of the cross-scheme
//! conformance matrix (`rust/tests/test_conformance.rs`).

pub mod bitpack;
pub mod compose;
pub mod dither;
pub mod dqgd;
pub mod dsc;
pub mod gain_shape;
pub mod ndsc;
pub mod qsgd;
pub mod randk;
pub mod ratq;
pub mod registry;
pub mod sign;
pub mod ternary;
pub mod topk;
pub mod uniform;
pub mod vqsgd;

use crate::linalg::rng::Rng;

/// A compressed message: exact wire bytes plus the bit accounting the
/// coordinator's budget enforcement uses.
#[derive(Clone, Debug)]
pub struct Compressed {
    /// Original dimension `n` of the compressed vector.
    pub n: usize,
    /// Bit-packed wire payload.
    pub bytes: Vec<u8>,
    /// Bits charged against the `⌊nR⌋` budget.
    pub payload_bits: usize,
    /// `O(1)` side-information bits (norm scalars, seeds) per App. F.
    pub side_bits: usize,
}

impl Compressed {
    /// Total wire bits.
    pub fn total_bits(&self) -> usize {
        self.payload_bits + self.side_bits
    }

    /// Effective rate in bits/dimension, *excluding* the `O(1)` part —
    /// the quantity constrained to `≤ R` in the paper.
    pub fn rate(&self) -> f32 {
        self.payload_bits as f32 / self.n as f32
    }
}

/// A fixed-length vector compressor with budget `R` bits/dimension.
pub trait Compressor: Send + Sync {
    /// Human-readable name used in reports (e.g. `"NDSC-Hadamard"`).
    fn name(&self) -> String;
    /// Input dimension.
    fn n(&self) -> usize;
    /// Configured budget `R` (bits per dimension); the compressor must emit
    /// `payload_bits ≤ ⌊n·R⌋` for every input.
    fn bits_per_dim(&self) -> f32;
    /// Encode. Stochastic schemes draw dithers / samples from `rng`;
    /// deterministic schemes ignore it.
    fn compress(&self, y: &[f32], rng: &mut Rng) -> Compressed;
    /// Decode (the parameter-server side).
    fn decompress(&self, msg: &Compressed) -> Vec<f32>;
    /// Whether `E[decompress(compress(y))] = y` (needed by DQ-PSGD's
    /// analysis; deterministic nearest-neighbour schemes are biased).
    fn is_unbiased(&self) -> bool {
        false
    }
}

/// Budget ceiling in payload bits for dimension `n` at rate `r`.
pub fn budget_bits(n: usize, r: f32) -> usize {
    (n as f64 * r as f64).floor() as usize
}

/// Measured normalized error `‖Q(y) − y‖₂ / ‖y‖₂` averaged over `trials`
/// draws of `gen` — the quantity plotted in Fig. 1a.
pub fn normalized_error(
    c: &dyn Compressor,
    trials: usize,
    rng: &mut Rng,
    mut gen: impl FnMut(&mut Rng) -> Vec<f32>,
) -> f32 {
    let mut acc = 0.0f64;
    let mut used = 0usize;
    for _ in 0..trials {
        let y = gen(rng);
        let ny = crate::linalg::vecops::norm2(&y);
        if ny == 0.0 {
            continue;
        }
        let msg = c.compress(&y, rng);
        let yhat = c.decompress(&msg);
        acc += (crate::linalg::vecops::dist2(&yhat, &y) / ny) as f64;
        used += 1;
    }
    (acc / used.max(1) as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_bits_floor() {
        assert_eq!(budget_bits(1000, 0.5), 500);
        assert_eq!(budget_bits(784, 0.1), 78);
        assert_eq!(budget_bits(30, 0.5), 15);
        assert_eq!(budget_bits(116, 3.0), 348);
    }
}
