//! RATQ-style rotated adaptive quantizer (Mayekar & Tyagi [7]) — Table 1.
//!
//! RATQ = randomized Hadamard **rotation** (which Gaussianizes the
//! coordinates) followed by per-group *adaptive* dynamic ranges drawn from
//! a tetra-iterated ladder, then dithered uniform quantization. This
//! implementation keeps the structure that matters for the comparison:
//!
//! 1. rotate with `H·D` (the same `O(n log n)` transform NDSC uses),
//! 2. split into groups of `g ≈ log n` coordinates,
//! 3. per group, transmit `h` bits selecting the smallest ladder level
//!    `M_j ≥ max |x_i|` (the ladder is `M₀·√(e^{…iterated…})`, here a
//!    geometric ladder calibrated to the rotated coordinates' sub-Gaussian
//!    scale — the iterated-exponential refinement only affects constants),
//! 4. dithered-quantize each coordinate within its group range with the
//!    per-coordinate budget.
//!
//! Bits: `n·R + (n/g)·h + O(1)`, i.e. `R + h/g` per dimension — the
//! `O(log log n)` overhead the paper's comparison cites shows up in `h`.

use crate::linalg::fwht::{fwht_normalized_inplace, next_pow2};
use crate::linalg::rng::Rng;
use crate::linalg::vecops::norm2;
use crate::quant::bitpack::{BitReader, BitWriter};
use crate::quant::dither::DitheredUniform;
use crate::quant::{Compressed, Compressor, Workspace};

pub struct Ratq {
    n: usize,
    big_n: usize,
    /// ±1 diagonal of the rotation.
    signs: Vec<f32>,
    /// Bits per coordinate.
    bits: usize,
    /// Group size (≈ log n in the paper).
    group: usize,
    /// Bits used to index the range ladder.
    ladder_bits: usize,
}

impl Ratq {
    pub fn new(n: usize, bits: usize, rng: &mut Rng) -> Self {
        assert!(bits >= 1);
        let big_n = next_pow2(n);
        let signs: Vec<f32> = (0..big_n).map(|_| rng.sign()).collect();
        let group = ((n as f32).ln().ceil() as usize).max(2);
        Ratq { n, big_n, signs, bits, group, ladder_bits: 3 }
    }

    /// Geometric range ladder: level `j` covers `base · 2^j`. The base is
    /// the sub-Gaussian scale of rotated coordinates, `‖y‖₂/√N`.
    fn ladder(&self, base: f32, j: u64) -> f32 {
        base * (2.0f32).powi(j as i32 + 1)
    }

    /// `x ← H·D·[y; 0]` into the caller's buffer (resized to `N`).
    fn rotate_into(&self, y: &[f32], x: &mut Vec<f32>) {
        x.resize(self.big_n, 0.0);
        x.fill(0.0);
        x[..self.n].copy_from_slice(y);
        for (xi, s) in x.iter_mut().zip(&self.signs) {
            *xi *= s;
        }
        fwht_normalized_inplace(x);
    }

    /// Inverse rotation, destroying `x`; the first `n` coordinates land in
    /// `out`.
    fn unrotate_into(&self, x: &mut [f32], out: &mut [f32]) {
        fwht_normalized_inplace(x);
        for (xi, s) in x.iter_mut().zip(&self.signs) {
            *xi *= s;
        }
        out.copy_from_slice(&x[..self.n]);
    }
}

impl Compressor for Ratq {
    fn name(&self) -> String {
        format!("ratq-{}b", self.bits)
    }

    fn n(&self) -> usize {
        self.n
    }

    fn bits_per_dim(&self) -> f32 {
        // payload per original dimension, incl. ladder overhead
        (self.big_n * self.bits + self.big_n.div_ceil(self.group) * self.ladder_bits) as f32
            / self.n as f32
    }

    fn compress_into(&self, y: &[f32], rng: &mut Rng, ws: &mut Workspace, out: &mut Compressed) {
        assert_eq!(y.len(), self.n);
        let g2 = norm2(y);
        let mut w = BitWriter::reuse(std::mem::take(&mut out.bytes));
        w.reserve_bits(self.big_n * self.bits + 64);
        w.write_f32(g2);
        let mut payload_bits = 0;
        if g2 > 0.0 {
            self.rotate_into(y, &mut ws.a);
            let base = g2 / (self.big_n as f32).sqrt();
            let max_level = (1u64 << self.ladder_bits) - 1;
            for chunk in ws.a.chunks(self.group) {
                let m = chunk.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                // smallest ladder level covering m
                let mut j = 0u64;
                while j < max_level && self.ladder(base, j) < m {
                    j += 1;
                }
                w.write_bits(j, self.ladder_bits);
                payload_bits += self.ladder_bits;
                let q = DitheredUniform::symmetric(self.ladder(base, j), self.bits);
                for &v in chunk {
                    w.write_bits(q.encode(v, rng), self.bits);
                    payload_bits += self.bits;
                }
            }
        }
        out.n = self.n;
        out.payload_bits = payload_bits;
        out.side_bits = 32;
        out.bytes = w.into_bytes();
    }

    fn decompress_into(&self, msg: &Compressed, ws: &mut Workspace, out: &mut [f32]) {
        let mut r = BitReader::new(&msg.bytes);
        let g2 = r.read_f32();
        if g2 == 0.0 {
            out.fill(0.0);
            return;
        }
        let base = g2 / (self.big_n as f32).sqrt();
        ws.a.resize(self.big_n, 0.0);
        for chunk in ws.a.chunks_mut(self.group) {
            let j = r.read_bits(self.ladder_bits);
            let q = DitheredUniform::symmetric(self.ladder(base, j), self.bits);
            for v in chunk.iter_mut() {
                *v = q.decode(r.read_bits(self.bits));
            }
        }
        self.unrotate_into(&mut ws.a, out);
    }

    fn workspace_floats(&self) -> usize {
        self.big_n
    }

    fn is_unbiased(&self) -> bool {
        // Unbiased except for the (exponentially rare) ladder-saturation
        // clamp — same caveat as the original.
        true
    }

    /// The `N`-entry rotation sign table.
    fn resident_bytes(&self) -> usize {
        self.signs.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::dist2;

    #[test]
    fn roundtrip_error_reasonable() {
        let mut rng = Rng::seed_from(1);
        let n = 512;
        let c = Ratq::new(n, 4, &mut rng);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        let rel = dist2(&yhat, &y) / norm2(&y);
        assert!(rel < 0.3, "rel={rel}");
    }

    #[test]
    fn near_unbiased() {
        let mut rng = Rng::seed_from(2);
        let n = 32;
        let c = Ratq::new(n, 3, &mut rng);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let trials = 4000;
        let mut mean = vec![0.0f64; n];
        for _ in 0..trials {
            let yhat = c.decompress(&c.compress(&y, &mut rng));
            for (m, &v) in mean.iter_mut().zip(&yhat) {
                *m += v as f64 / trials as f64;
            }
        }
        let mean_f: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
        assert!(dist2(&mean_f, &y) / norm2(&y) < 0.08);
    }

    #[test]
    fn adaptive_range_beats_fixed_worst_case() {
        // A spiky vector saturates a fixed range; RATQ's ladder adapts.
        let mut rng = Rng::seed_from(3);
        let n = 256;
        let c = Ratq::new(n, 4, &mut rng);
        let mut y = vec![0.01f32; n];
        y[3] = 50.0;
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        assert!(dist2(&yhat, &y) / norm2(&y) < 0.5);
    }
}
