//! TernGrad-style ternary quantization [16] — Table 1.
//!
//! `Q(y)_i = s·sign(y_i)·b_i` with `s = ‖y‖∞` and
//! `b_i ~ Bernoulli(|y_i|/s)` — unbiased by construction. Trits are packed
//! five to a byte (3⁵ = 243 ≤ 256), i.e. 1.6 bits per dimension on the
//! wire (the paper's `n·log₂3 ≈ 1.585n` row, within 1%).

use crate::linalg::rng::Rng;
use crate::linalg::vecops::norm_inf;
use crate::quant::bitpack::{BitReader, BitWriter};
use crate::quant::{Compressed, Compressor, Workspace};

pub struct Ternary {
    n: usize,
}

impl Ternary {
    pub fn new(n: usize) -> Self {
        Ternary { n }
    }
}

/// Bits per group of 5 trits.
const GROUP_BITS: usize = 8;

impl Compressor for Ternary {
    fn name(&self) -> String {
        "ternary".into()
    }

    fn n(&self) -> usize {
        self.n
    }

    fn bits_per_dim(&self) -> f32 {
        GROUP_BITS as f32 / 5.0
    }

    fn compress_into(&self, y: &[f32], rng: &mut Rng, _ws: &mut Workspace, out: &mut Compressed) {
        assert_eq!(y.len(), self.n);
        let s = norm_inf(y);
        let mut w = BitWriter::reuse(std::mem::take(&mut out.bytes));
        w.reserve_bits(self.n * 2 + 32);
        w.write_f32(s);
        let mut payload_bits = 0;
        if s > 0.0 {
            let mut group = 0u64;
            let mut count = 0;
            for &v in y {
                let p = (v.abs() / s) as f64;
                let trit: u64 = if rng.bernoulli(p) {
                    if v >= 0.0 {
                        2
                    } else {
                        0
                    }
                } else {
                    1
                };
                group = group * 3 + trit;
                count += 1;
                if count == 5 {
                    w.write_bits(group, GROUP_BITS);
                    payload_bits += GROUP_BITS;
                    group = 0;
                    count = 0;
                }
            }
            if count > 0 {
                for _ in count..5 {
                    group *= 3; // pad with zeros (decoded then discarded)
                }
                w.write_bits(group, GROUP_BITS);
                payload_bits += GROUP_BITS;
            }
        }
        out.n = self.n;
        out.payload_bits = payload_bits;
        out.side_bits = 32;
        out.bytes = w.into_bytes();
    }

    fn decompress_into(&self, msg: &Compressed, _ws: &mut Workspace, out: &mut [f32]) {
        let mut r = BitReader::new(&msg.bytes);
        let s = r.read_f32();
        if s == 0.0 {
            out.fill(0.0);
            return;
        }
        let mut i = 0;
        while i < self.n {
            let group = r.read_bits(GROUP_BITS);
            let mut trits = [0u64; 5];
            let mut g = group;
            for t in (0..5).rev() {
                trits[t] = g % 3;
                g /= 3;
            }
            for &t in trits.iter().take((self.n - i).min(5)) {
                out[i] = match t {
                    0 => -s,
                    1 => 0.0,
                    _ => s,
                };
                i += 1;
            }
        }
    }

    fn is_unbiased(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::{dist2, norm2};

    #[test]
    fn values_are_ternary() {
        let mut rng = Rng::seed_from(1);
        let n = 103; // not a multiple of 5: exercises the tail group
        let c = Ternary::new(n);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let s = norm_inf(&y);
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        for &v in &yhat {
            assert!(v == 0.0 || (v.abs() - s).abs() < 1e-6);
        }
    }

    #[test]
    fn unbiased() {
        let mut rng = Rng::seed_from(2);
        let n = 24;
        let c = Ternary::new(n);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let trials = 6000;
        let mut mean = vec![0.0f64; n];
        for _ in 0..trials {
            let yhat = c.decompress(&c.compress(&y, &mut rng));
            for (m, &v) in mean.iter_mut().zip(&yhat) {
                *m += v as f64 / trials as f64;
            }
        }
        let mean_f: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
        assert!(dist2(&mean_f, &y) / norm2(&y) < 0.08);
    }

    #[test]
    fn wire_rate_close_to_log2_3() {
        let mut rng = Rng::seed_from(3);
        let n = 1000;
        let c = Ternary::new(n);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let msg = c.compress(&y, &mut rng);
        let rate = msg.payload_bits as f32 / n as f32;
        assert!(rate <= 1.61, "rate={rate}");
        assert!(rate >= 1.55);
    }
}
