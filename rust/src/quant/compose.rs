//! Compression **in the embedding domain** — Appendix H / Theorem 4.
//!
//! For any compressor `C : R^N → R^N`, instead of compressing `y` directly,
//! compress its (near-)democratic embedding: `E(y) = C(x)`, `D(x') = S·x'`.
//! Theorem 4 shows the composed error is `γ²‖y‖²` with `γ = K_u` (DE) or
//! `2√log(2N)` (NDE) — dimension-free — because every coordinate of `x`
//! carries `Θ(1/√N)` of the mass, the best case for sparsifiers and
//! scalar quantizers alike. This is the "with NDE" family of curves in
//! Figs. 1a, 1d, 2a–2d.

use std::sync::Mutex;

use crate::embed::democratic::{KashinParams, KashinSolver};
use crate::linalg::frames::Frame;
use crate::linalg::rng::Rng;
use crate::quant::dsc::EmbedKind;
use crate::quant::{Compressed, Compressor, Workspace};

/// `inner` compressor (of dimension `N`) applied to the embedding of `y`
/// (dimension `n`).
pub struct EmbeddedCompressor {
    frame: Box<dyn Frame>,
    embed: EmbedKind,
    inner: Box<dyn Compressor>,
    solver: Mutex<KashinSolver>,
}

impl EmbeddedCompressor {
    pub fn new(frame: Box<dyn Frame>, embed: EmbedKind, inner: Box<dyn Compressor>) -> Self {
        assert_eq!(
            inner.n(),
            frame.big_n(),
            "inner compressor must act on R^N = R^{}",
            frame.big_n()
        );
        let params = KashinParams::for_lambda(frame.lambda());
        EmbeddedCompressor { frame, embed, inner, solver: Mutex::new(KashinSolver::new(params)) }
    }

    /// Near-democratic composition (the common case: "X + NDE").
    pub fn nde(frame: Box<dyn Frame>, inner: Box<dyn Compressor>) -> Self {
        Self::new(frame, EmbedKind::NearDemocratic, inner)
    }
}

impl Compressor for EmbeddedCompressor {
    fn name(&self) -> String {
        let tag = match self.embed {
            EmbedKind::Democratic => "DE",
            EmbedKind::NearDemocratic => "NDE",
        };
        format!("{}+{}", self.inner.name(), tag)
    }

    fn n(&self) -> usize {
        self.frame.n()
    }

    fn bits_per_dim(&self) -> f32 {
        // inner budget is per embedding dimension; express per original dim.
        self.inner.bits_per_dim() * self.frame.big_n() as f32 / self.frame.n() as f32
    }

    /// Embed into the workspace's dedicated composition buffer (`emb`,
    /// taken out for the duration), compress in the embedding domain. The
    /// inner scheme keeps full use of `a`/`b`/`c`/`idx`, so any codec can
    /// be nested without buffer collisions or per-call allocation.
    fn compress_into(&self, y: &[f32], rng: &mut Rng, ws: &mut Workspace, out: &mut Compressed) {
        assert_eq!(y.len(), self.frame.n());
        let big_n = self.frame.big_n();
        let mut x = std::mem::take(&mut ws.emb);
        x.resize(big_n, 0.0);
        match self.embed {
            EmbedKind::NearDemocratic => self.frame.pinv_embed_into(y, &mut x, &mut ws.c),
            EmbedKind::Democratic => {
                let mut solver = self.solver.lock().unwrap();
                solver.embed_into(self.frame.as_ref(), y, &mut x);
            }
        }
        self.inner.compress_into(&x, rng, ws, out);
        out.n = self.frame.n(); // budget accounting is per original dim
        ws.emb = x;
    }

    /// Inner-decode into the embedding buffer, then `S·x` in place. The
    /// inner decoder reads its dimension from its own config, so the outer
    /// `msg.n` (original-dim accounting) needs no fix-up copy.
    fn decompress_into(&self, msg: &Compressed, ws: &mut Workspace, out: &mut [f32]) {
        let big_n = self.frame.big_n();
        let mut x = std::mem::take(&mut ws.emb);
        x.resize(big_n, 0.0);
        self.inner.decompress_into(msg, ws, &mut x);
        self.frame.apply_inplace(&mut x, out);
        ws.emb = x;
    }

    fn workspace_floats(&self) -> usize {
        self.frame.big_n().max(self.inner.workspace_floats())
    }

    fn is_unbiased(&self) -> bool {
        // S is linear, so unbiasedness of the inner compressor transfers
        // (Theorem 4's first step).
        self.inner.is_unbiased()
    }

    /// The frame's tables plus whatever the nested codec holds.
    fn resident_bytes(&self) -> usize {
        self.frame.resident_bytes() + self.inner.resident_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::frames::HadamardFrame;
    use crate::linalg::vecops::{dist2, norm2};
    use crate::quant::gain_shape::StandardDither;
    use crate::quant::randk::RandK;
    use crate::quant::sign::SignQuantizer;

    fn hadamard(n: usize, seed: u64) -> (Box<dyn Frame>, usize) {
        let mut rng = Rng::seed_from(seed);
        let f = HadamardFrame::new(n, &mut rng);
        let big_n = f.big_n();
        (Box::new(f), big_n)
    }

    #[test]
    fn theorem4_randk_with_nde_beats_plain_randk() {
        // Fig. 1d / 2a in miniature: random sparsification + 1-bit quantize,
        // with vs without NDE, on heavy-tailed inputs.
        let mut rng = Rng::seed_from(1);
        let n = 1024;
        let (frame, big_n) = hadamard(n, 2);
        let k = n / 2;
        let with_nde =
            EmbeddedCompressor::nde(frame, Box::new(RandK::new(big_n, k, 1).unbiased()));
        let without = RandK::new(n, k, 1).unbiased();
        let gen = |rng: &mut Rng| -> Vec<f32> { (0..n).map(|_| rng.gaussian_cubed()).collect() };
        let e_with = crate::quant::normalized_error(&with_nde, 15, &mut rng, gen);
        let e_without = crate::quant::normalized_error(&without, 15, &mut rng, gen);
        assert!(
            e_with < e_without,
            "rand-k+NDE {e_with} should beat plain rand-k {e_without}"
        );
    }

    #[test]
    fn sign_with_nde_nearly_lossless_shapewise() {
        // After embedding, coordinates are near-equal magnitude: the best
        // case for sign quantization (Theorem 4's intuition).
        let mut rng = Rng::seed_from(3);
        let n = 512;
        let (frame, big_n) = hadamard(n, 4);
        let c = EmbeddedCompressor::nde(frame, Box::new(SignQuantizer::new(big_n)));
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        let plain = SignQuantizer::new(n);
        let yplain = plain.decompress(&plain.compress(&y, &mut rng));
        assert!(dist2(&yhat, &y) < dist2(&yplain, &y));
    }

    #[test]
    fn unbiasedness_transfers_through_s() {
        let mut rng = Rng::seed_from(5);
        let n = 32;
        let (frame, big_n) = hadamard(n, 6);
        let c = EmbeddedCompressor::nde(frame, Box::new(StandardDither::new(big_n, 2.0)));
        assert!(c.is_unbiased());
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let trials = 4000;
        let mut mean = vec![0.0f64; n];
        for _ in 0..trials {
            let yhat = c.decompress(&c.compress(&y, &mut rng));
            for (m, &v) in mean.iter_mut().zip(&yhat) {
                *m += v as f64 / trials as f64;
            }
        }
        let mean_f: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
        assert!(dist2(&mean_f, &y) / norm2(&y) < 0.08);
    }
}
