//! Gain–shape quantizers (App. E) and the **naive** uniform scalar
//! baseline the paper compares against everywhere.
//!
//! A gain–shape quantizer factors `Q(y) = Q_G(‖y‖)·Q_S(y/‖y‖)`: the scalar
//! gain is side information (`O(1)` bits, App. F) and the shape is the
//! budget-constrained part. [`NaiveUniform`] is exactly the paper's "naive
//! scalar quantization": normalize by `‖y‖∞`, spend `⌊nR⌋` bits on
//! coordinate-wise nearest-neighbour uniform quantization of `y` itself —
//! no subspace embedding. Its error carries the `√n` covering-efficiency
//! penalty (§3.2) that DSC/NDSC remove.

use crate::linalg::rng::Rng;
use crate::linalg::vecops::norm_inf;
use crate::quant::bitpack::{allocate_bits, BitReader, BitWriter};
use crate::quant::dither::DitheredUniform;
use crate::quant::uniform::{dequantize_index, quantize_index};
use crate::quant::{budget_bits, Compressed, Compressor, Workspace};

/// Naive uniform scalar quantizer: `Q(y) = ‖y‖∞ · Q_unif(y/‖y‖∞)`.
pub struct NaiveUniform {
    n: usize,
    r: f32,
}

impl NaiveUniform {
    pub fn new(n: usize, r: f32) -> Self {
        assert!(r > 0.0);
        NaiveUniform { n, r }
    }
}

impl Compressor for NaiveUniform {
    fn name(&self) -> String {
        "naive-uniform".into()
    }

    fn n(&self) -> usize {
        self.n
    }

    fn bits_per_dim(&self) -> f32 {
        self.r
    }

    fn compress_into(&self, y: &[f32], _rng: &mut Rng, _ws: &mut Workspace, out: &mut Compressed) {
        assert_eq!(y.len(), self.n);
        let s = norm_inf(y);
        let budget = budget_bits(self.n, self.r);
        let alloc = allocate_bits(budget, self.n);
        let mut w = BitWriter::reuse(std::mem::take(&mut out.bytes));
        w.reserve_bits(budget + 32);
        w.write_f32(s);
        if s > 0.0 {
            let inv = 1.0 / s;
            for (i, &yi) in y.iter().enumerate() {
                let bits = alloc.bits(i);
                if bits > 0 {
                    w.write_bits(quantize_index(yi * inv, bits), bits);
                }
            }
        }
        out.n = self.n;
        out.payload_bits = w.len_bits().saturating_sub(32);
        out.side_bits = 32;
        out.bytes = w.into_bytes();
    }

    fn decompress_into(&self, msg: &Compressed, _ws: &mut Workspace, out: &mut [f32]) {
        let mut r = BitReader::new(&msg.bytes);
        let s = r.read_f32();
        let alloc = allocate_bits(budget_bits(self.n, self.r), self.n);
        if s > 0.0 {
            for (i, yi) in out.iter_mut().enumerate() {
                let bits = alloc.bits(i);
                *yi = if bits > 0 { s * dequantize_index(r.read_bits(bits), bits) } else { 0.0 };
            }
        } else {
            out.fill(0.0);
        }
    }
}

/// Standard Dithering (the "SD" curve of Fig. 1a): gain–shape with
/// `Q_G = ‖y‖₂` sent as a float and an unbiased dithered shape quantizer
/// over `[−‖y‖∞, ‖y‖∞]` — the stochastic uniform quantizer of App. I
/// applied directly to `y` (no embedding).
pub struct StandardDither {
    n: usize,
    r: f32,
}

impl StandardDither {
    pub fn new(n: usize, r: f32) -> Self {
        assert!(r > 0.0);
        StandardDither { n, r }
    }
}

impl Compressor for StandardDither {
    fn name(&self) -> String {
        "standard-dither".into()
    }

    fn n(&self) -> usize {
        self.n
    }

    fn bits_per_dim(&self) -> f32 {
        self.r
    }

    fn compress_into(&self, y: &[f32], rng: &mut Rng, ws: &mut Workspace, out: &mut Compressed) {
        assert_eq!(y.len(), self.n);
        let s = norm_inf(y);
        let budget = budget_bits(self.n, self.r);
        let mut w = BitWriter::reuse(std::mem::take(&mut out.bytes));
        w.reserve_bits(budget + 96);
        w.write_f32(s);
        let mut side_bits = 32;
        let payload_bits;
        if s == 0.0 || budget == 0 {
            payload_bits = 0;
        } else if budget >= self.n {
            let alloc = allocate_bits(budget, self.n);
            for (i, &yi) in y.iter().enumerate() {
                let bits = alloc.bits(i);
                let q = DitheredUniform::symmetric(s, bits);
                w.write_bits(q.encode(yi, rng), bits);
            }
            payload_bits = alloc.total();
        } else {
            // Sub-linear: random subsample + 1 bit, rescaled (unbiased).
            let seed = rng.next_u64();
            w.write_u64(seed);
            side_bits += 64;
            let mut sel = Rng::seed_from(seed);
            sel.sample_indices_into(self.n, budget, &mut ws.idx);
            let q = DitheredUniform::symmetric(s, 1);
            for &i in &ws.idx {
                w.write_bits(q.encode(y[i], rng), 1);
            }
            payload_bits = budget;
        }
        out.n = self.n;
        out.payload_bits = payload_bits;
        out.side_bits = side_bits;
        out.bytes = w.into_bytes();
    }

    fn decompress_into(&self, msg: &Compressed, ws: &mut Workspace, out: &mut [f32]) {
        let budget = budget_bits(self.n, self.r);
        let mut r = BitReader::new(&msg.bytes);
        let s = r.read_f32();
        if s == 0.0 || budget == 0 {
            out.fill(0.0);
            return;
        }
        if budget >= self.n {
            let alloc = allocate_bits(budget, self.n);
            for (i, yi) in out.iter_mut().enumerate() {
                let bits = alloc.bits(i);
                let q = DitheredUniform::symmetric(s, bits);
                *yi = q.decode(r.read_bits(bits));
            }
        } else {
            out.fill(0.0);
            let seed = r.read_u64();
            let mut sel = Rng::seed_from(seed);
            sel.sample_indices_into(self.n, budget, &mut ws.idx);
            let q = DitheredUniform::symmetric(s, 1);
            let rescale = self.n as f32 / budget as f32;
            for &i in &ws.idx {
                out[i] = rescale * q.decode(r.read_bits(1));
            }
        }
    }

    fn is_unbiased(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::norm2;
    use crate::linalg::vecops::dist2;
    use crate::testkit::prop::{forall, gen, Cases};

    #[test]
    fn naive_error_bound() {
        // ||y - Q(y)||_2 <= ||y||_inf 2^{-R} sqrt(n): the sqrt(n) penalty.
        let mut rng = Rng::seed_from(1);
        let n = 256;
        let c = NaiveUniform::new(n, 3.0);
        for _ in 0..5 {
            let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let msg = c.compress(&y, &mut rng);
            let yhat = c.decompress(&msg);
            let bound = norm_inf(&y) * (2.0f32).powi(-3) * (n as f32).sqrt();
            assert!(dist2(&yhat, &y) <= bound * 1.01);
        }
    }

    #[test]
    fn budgets_respected() {
        forall(Cases::new("naive/SD budget", 50), |rng, _| {
            let n = gen::dim(rng);
            let r = gen::bit_budget(rng);
            let y = gen::nonzero_vector(rng, n);
            for c in [&NaiveUniform::new(n, r) as &dyn Compressor, &StandardDither::new(n, r)] {
                let msg = c.compress(&y, rng);
                assert!(msg.payload_bits <= budget_bits(n, r), "{}", c.name());
                let yhat = c.decompress(&msg);
                assert_eq!(yhat.len(), n);
            }
        });
    }

    #[test]
    fn standard_dither_unbiased() {
        let mut rng = Rng::seed_from(2);
        let n = 32;
        let c = StandardDither::new(n, 2.0);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let trials = 4000;
        let mut mean = vec![0.0f64; n];
        for _ in 0..trials {
            let yhat = c.decompress(&c.compress(&y, &mut rng));
            for (m, &v) in mean.iter_mut().zip(&yhat) {
                *m += v as f64 / trials as f64;
            }
        }
        let mean_f: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
        assert!(dist2(&mean_f, &y) / norm2(&y) < 0.06);
    }

    #[test]
    fn naive_struggles_on_one_hot() {
        // The motivating failure: a one-hot vector under R=1 naive
        // quantization loses almost everything relative to NDSC (see
        // ndsc.rs::one_hot_worst_case).
        let mut rng = Rng::seed_from(3);
        let n = 1024;
        let c = NaiveUniform::new(n, 1.0);
        let mut y = vec![0.0f32; n];
        y[7] = 42.0;
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        // With 1 bit/coord every zero coordinate decodes to ±s/2 => huge error.
        assert!(dist2(&yhat, &y) / norm2(&y) > 5.0);
    }
}
