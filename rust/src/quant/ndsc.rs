//! Near-Democratic Source Coding — NDSC (§3.1, §2.1).
//!
//! NDSC is the [`SubspaceCodec`] instantiated with the closed-form
//! near-democratic embedding `x = Sᵀy`. This module provides the
//! paper-named constructors:
//!
//! * **NDH** — NDSC with a randomized Hadamard frame (`O(n log n)`
//!   additions, 1-bit-per-entry frame storage), the paper's recommended
//!   default;
//! * **NDO** — NDSC with a random (Haar) orthonormal frame at λ = 1
//!   (a random rotation; the paper notes NDSC generalizes random
//!   rotations).
//!
//! The returned [`SubspaceCodec`] implements both the allocating and the
//! workspace (`compress_into`/`decompress_into`) API; long-running loops
//! should pair the codec with a
//! [`Workspace::for_compressor`](crate::quant::Workspace::for_compressor)
//! and reuse it — steady-state rounds then allocate nothing.
//!
//! With a Hadamard frame the workspace API runs the **fused** hot path:
//! one unnormalized FWHT with the `1/√N` scale folded into the quantize
//! (encode) or gather (decode) pass, multi-threaded above
//! [`MT_FWHT_MIN_DIM`](crate::coordinator::config::MT_FWHT_MIN_DIM) — and
//! bit-identical to the scalar reference pipeline
//! ([`SubspaceCodec::compress_reference_into`]), which
//! `rust/tests/test_kernels.rs` enforces.

use crate::linalg::frames::{Frame, HadamardFrame, OrthonormalFrame};
use crate::linalg::rng::Rng;
use crate::quant::dsc::{CodecMode, EmbedKind, SubspaceCodec};

/// NDSC over an arbitrary frame, deterministic (nearest-neighbour) mode.
pub struct Ndsc;

impl Ndsc {
    /// NDSC with the given frame and budget, deterministic quantizer.
    pub fn new(frame: impl Frame + 'static, r: f32) -> SubspaceCodec {
        SubspaceCodec::new(Box::new(frame), EmbedKind::NearDemocratic, CodecMode::Deterministic, r)
    }

    /// NDSC, dithered/unbiased quantizer (for DQ-PSGD).
    pub fn dithered(frame: impl Frame + 'static, r: f32) -> SubspaceCodec {
        SubspaceCodec::new(Box::new(frame), EmbedKind::NearDemocratic, CodecMode::Dithered, r)
    }

    /// NDH: randomized Hadamard frame with `N = 2^⌈log₂n⌉`.
    pub fn hadamard(n: usize, r: f32, rng: &mut Rng) -> SubspaceCodec {
        Self::new(HadamardFrame::new(n, rng), r)
    }

    /// Dithered NDH.
    pub fn hadamard_dithered(n: usize, r: f32, rng: &mut Rng) -> SubspaceCodec {
        Self::dithered(HadamardFrame::new(n, rng), r)
    }

    /// NDO: random orthonormal (λ = 1 — "no resolution is lost due to the
    /// fixed bit-budget", §5).
    pub fn orthonormal(n: usize, r: f32, rng: &mut Rng) -> SubspaceCodec {
        Self::new(OrthonormalFrame::with_big_n(n, n, rng), r)
    }

    /// Dithered NDO.
    pub fn orthonormal_dithered(n: usize, r: f32, rng: &mut Rng) -> SubspaceCodec {
        Self::dithered(OrthonormalFrame::with_big_n(n, n, rng), r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::{dist2, norm2};
    use crate::quant::Compressor;

    #[test]
    fn ndh_beats_naive_on_heavy_tails() {
        // The Fig. 1a claim in miniature: at R = 2, NDH error on Gaussian³
        // inputs is well below the naive uniform scalar quantizer's.
        let mut rng = Rng::seed_from(1);
        let n = 1000;
        let ndh = Ndsc::hadamard(n, 2.0, &mut rng);
        let naive = crate::quant::gain_shape::NaiveUniform::new(n, 2.0);
        let gen = |rng: &mut Rng| -> Vec<f32> { (0..n).map(|_| rng.gaussian_cubed()).collect() };
        let e_ndh = crate::quant::normalized_error(&ndh, 20, &mut rng, gen);
        let e_naive = crate::quant::normalized_error(&naive, 20, &mut rng, gen);
        assert!(
            e_ndh < 0.7 * e_naive,
            "NDH {e_ndh} should beat naive {e_naive} on heavy tails"
        );
    }

    #[test]
    fn ndo_matches_ndh_order_of_magnitude() {
        let mut rng = Rng::seed_from(2);
        let n = 128;
        let ndh = Ndsc::hadamard(n, 3.0, &mut rng);
        let ndo = Ndsc::orthonormal(n, 3.0, &mut rng);
        let gen = |rng: &mut Rng| -> Vec<f32> { (0..n).map(|_| rng.gaussian_cubed()).collect() };
        let e_h = crate::quant::normalized_error(&ndh, 15, &mut rng, gen);
        let e_o = crate::quant::normalized_error(&ndo, 15, &mut rng, gen);
        assert!(e_h < 3.0 * e_o && e_o < 3.0 * e_h, "NDH {e_h} vs NDO {e_o}");
    }

    #[test]
    fn into_path_matches_allocating_path_bitwise() {
        use crate::quant::{Compressed, Workspace};
        // Twin codecs from identical seeds (same frame draw), one driven
        // through the allocating API and one through the workspace API:
        // wire bytes and decodes must agree bit-for-bit.
        let mut rng_a = Rng::seed_from(9);
        let mut rng_b = Rng::seed_from(9);
        let ca = Ndsc::hadamard_dithered(100, 2.0, &mut rng_a);
        let cb = Ndsc::hadamard_dithered(100, 2.0, &mut rng_b);
        let mut ws = Workspace::for_compressor(&cb);
        let mut msg_b = Compressed::empty(100);
        let mut dec_b = vec![0.0f32; 100];
        let mut gen = Rng::seed_from(1);
        for _ in 0..4 {
            let y: Vec<f32> = (0..100).map(|_| gen.gaussian_cubed()).collect();
            let msg_a = ca.compress(&y, &mut rng_a);
            cb.compress_into(&y, &mut rng_b, &mut ws, &mut msg_b);
            assert_eq!(msg_a.bytes, msg_b.bytes);
            assert_eq!(msg_a.payload_bits, msg_b.payload_bits);
            let dec_a = ca.decompress(&msg_a);
            cb.decompress_into(&msg_b, &mut ws, &mut dec_b);
            assert_eq!(dec_a, dec_b);
        }
    }

    #[test]
    fn one_hot_worst_case() {
        // One-hot vectors are the naive quantizer's nightmare and the
        // embedding's showcase.
        let mut rng = Rng::seed_from(3);
        let n = 1024;
        let ndh = Ndsc::hadamard(n, 2.0, &mut rng);
        let mut y = vec![0.0f32; n];
        y[123] = 42.0;
        let msg = ndh.compress(&y, &mut rng);
        let yhat = ndh.decompress(&msg);
        assert!(dist2(&yhat, &y) / norm2(&y) < 0.3);
    }
}
