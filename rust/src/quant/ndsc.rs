//! Near-Democratic Source Coding — NDSC (§3.1, §2.1).
//!
//! NDSC is the [`SubspaceCodec`] instantiated with the closed-form
//! near-democratic embedding `x = Sᵀy`. This module provides the
//! paper-named constructors:
//!
//! * **NDH** — NDSC with a randomized Hadamard frame (`O(n log n)`
//!   additions, 1-bit-per-entry frame storage), the paper's recommended
//!   default;
//! * **NDO** — NDSC with a random (Haar) orthonormal frame at λ = 1
//!   (a random rotation; the paper notes NDSC generalizes random
//!   rotations).

use crate::linalg::frames::{Frame, HadamardFrame, OrthonormalFrame};
use crate::linalg::rng::Rng;
use crate::quant::dsc::{CodecMode, EmbedKind, SubspaceCodec};

/// NDSC over an arbitrary frame, deterministic (nearest-neighbour) mode.
pub struct Ndsc;

impl Ndsc {
    /// NDSC with the given frame and budget, deterministic quantizer.
    pub fn new(frame: impl Frame + 'static, r: f32) -> SubspaceCodec {
        SubspaceCodec::new(Box::new(frame), EmbedKind::NearDemocratic, CodecMode::Deterministic, r)
    }

    /// NDSC, dithered/unbiased quantizer (for DQ-PSGD).
    pub fn dithered(frame: impl Frame + 'static, r: f32) -> SubspaceCodec {
        SubspaceCodec::new(Box::new(frame), EmbedKind::NearDemocratic, CodecMode::Dithered, r)
    }

    /// NDH: randomized Hadamard frame with `N = 2^⌈log₂n⌉`.
    pub fn hadamard(n: usize, r: f32, rng: &mut Rng) -> SubspaceCodec {
        Self::new(HadamardFrame::new(n, rng), r)
    }

    /// Dithered NDH.
    pub fn hadamard_dithered(n: usize, r: f32, rng: &mut Rng) -> SubspaceCodec {
        Self::dithered(HadamardFrame::new(n, rng), r)
    }

    /// NDO: random orthonormal (λ = 1 — "no resolution is lost due to the
    /// fixed bit-budget", §5).
    pub fn orthonormal(n: usize, r: f32, rng: &mut Rng) -> SubspaceCodec {
        Self::new(OrthonormalFrame::with_big_n(n, n, rng), r)
    }

    /// Dithered NDO.
    pub fn orthonormal_dithered(n: usize, r: f32, rng: &mut Rng) -> SubspaceCodec {
        Self::dithered(OrthonormalFrame::with_big_n(n, n, rng), r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::{dist2, norm2};
    use crate::quant::Compressor;

    #[test]
    fn ndh_beats_naive_on_heavy_tails() {
        // The Fig. 1a claim in miniature: at R = 2, NDH error on Gaussian³
        // inputs is well below the naive uniform scalar quantizer's.
        let mut rng = Rng::seed_from(1);
        let n = 1000;
        let ndh = Ndsc::hadamard(n, 2.0, &mut rng);
        let naive = crate::quant::gain_shape::NaiveUniform::new(n, 2.0);
        let gen = |rng: &mut Rng| -> Vec<f32> { (0..n).map(|_| rng.gaussian_cubed()).collect() };
        let e_ndh = crate::quant::normalized_error(&ndh, 20, &mut rng, gen);
        let e_naive = crate::quant::normalized_error(&naive, 20, &mut rng, gen);
        assert!(
            e_ndh < 0.7 * e_naive,
            "NDH {e_ndh} should beat naive {e_naive} on heavy tails"
        );
    }

    #[test]
    fn ndo_matches_ndh_order_of_magnitude() {
        let mut rng = Rng::seed_from(2);
        let n = 128;
        let ndh = Ndsc::hadamard(n, 3.0, &mut rng);
        let ndo = Ndsc::orthonormal(n, 3.0, &mut rng);
        let gen = |rng: &mut Rng| -> Vec<f32> { (0..n).map(|_| rng.gaussian_cubed()).collect() };
        let e_h = crate::quant::normalized_error(&ndh, 15, &mut rng, gen);
        let e_o = crate::quant::normalized_error(&ndo, 15, &mut rng, gen);
        assert!(e_h < 3.0 * e_o && e_o < 3.0 * e_h, "NDH {e_h} vs NDO {e_o}");
    }

    #[test]
    fn one_hot_worst_case() {
        // One-hot vectors are the naive quantizer's nightmare and the
        // embedding's showcase.
        let mut rng = Rng::seed_from(3);
        let n = 1024;
        let ndh = Ndsc::hadamard(n, 2.0, &mut rng);
        let mut y = vec![0.0f32; n];
        y[123] = 42.0;
        let msg = ndh.compress(&y, &mut rng);
        let yhat = ndh.decompress(&msg);
        assert!(dist2(&yhat, &y) / norm2(&y) < 0.3);
    }
}
