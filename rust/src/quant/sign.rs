//! 1-bit sign quantization (signSGD [14], EF-signSGD [15]) — Table 1 row 1.
//!
//! `Q(y) = s · sign(y)` with the scale `s = ‖y‖₁/n` (the magnitude that
//! minimizes `‖y − s·sign(y)‖₂`). Exactly 1 payload bit per dimension plus
//! one `f32` of side information.

use crate::linalg::rng::Rng;
use crate::quant::bitpack::{BitReader, BitWriter};
use crate::quant::{Compressed, Compressor, Workspace};

pub struct SignQuantizer {
    n: usize,
}

impl SignQuantizer {
    pub fn new(n: usize) -> Self {
        SignQuantizer { n }
    }
}

impl Compressor for SignQuantizer {
    fn name(&self) -> String {
        "sign".into()
    }

    fn n(&self) -> usize {
        self.n
    }

    fn bits_per_dim(&self) -> f32 {
        1.0
    }

    fn compress_into(&self, y: &[f32], _rng: &mut Rng, _ws: &mut Workspace, out: &mut Compressed) {
        assert_eq!(y.len(), self.n);
        let scale = y.iter().map(|v| v.abs() as f64).sum::<f64>() as f32 / self.n as f32;
        let mut w = BitWriter::reuse(std::mem::take(&mut out.bytes));
        w.reserve_bits(self.n + 32);
        w.write_f32(scale);
        for &v in y {
            w.write_bits(u64::from(v >= 0.0), 1);
        }
        out.n = self.n;
        out.payload_bits = self.n;
        out.side_bits = 32;
        out.bytes = w.into_bytes();
    }

    fn decompress_into(&self, msg: &Compressed, _ws: &mut Workspace, out: &mut [f32]) {
        let mut r = BitReader::new(&msg.bytes);
        let scale = r.read_f32();
        for v in out.iter_mut() {
            *v = if r.read_bits(1) == 1 { scale } else { -scale };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::{dist2, norm2};
    use crate::testkit::prop::{forall, gen, Cases};

    #[test]
    fn signs_preserved() {
        forall(Cases::new("sign preserves signs", 50), |rng, _| {
            let n = gen::dim(rng);
            let c = SignQuantizer::new(n);
            let y = gen::nonzero_vector(rng, n);
            let msg = c.compress(&y, rng);
            assert_eq!(msg.payload_bits, n);
            let yhat = c.decompress(&msg);
            for (a, b) in y.iter().zip(&yhat) {
                if *a != 0.0 {
                    assert!(a.signum() == b.signum() || *b == 0.0);
                }
            }
        });
    }

    #[test]
    fn exact_on_constant_magnitude() {
        // If |y_i| = c for all i, sign quantization is lossless.
        let y = vec![0.7, -0.7, 0.7, 0.7, -0.7];
        let c = SignQuantizer::new(5);
        let mut rng = Rng::seed_from(1);
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        assert!(dist2(&yhat, &y) < 1e-6);
    }

    #[test]
    fn error_order_n_on_heavy_tails() {
        // Table 1: sign quantization's normalized error is O(1)·||y|| on
        // heavy-tailed inputs (it cannot represent magnitude variation).
        let mut rng = Rng::seed_from(2);
        let n = 1000;
        let c = SignQuantizer::new(n);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        assert!(dist2(&yhat, &y) / norm2(&y) > 0.5);
    }
}
