//! vqSGD cross-polytope quantizer [17] — Table 1.
//!
//! The unit `l₂` ball sits inside the scaled cross-polytope
//! `conv{±√n·e_i}` (since `‖v‖₁ ≤ √n‖v‖₂`). vqSGD writes
//! `v = Σ λ_j c_j` as a convex combination of the `2n` vertices plus a
//! slack split evenly over antipodal pairs, samples **one** vertex from the
//! λ distribution, and transmits its index — `⌈log₂ 2n⌉ + O(1)` bits total,
//! unbiased, with `O(n)` variance (the Table 1 error row). Repetitions
//! (`reps`) average independent samples to trade bits for variance.

use crate::linalg::rng::Rng;
use crate::linalg::vecops::{norm1, norm2};
use crate::quant::bitpack::{BitReader, BitWriter};
use crate::quant::{Compressed, Compressor, Workspace};

pub struct VqSgd {
    n: usize,
    /// Number of independent vertex samples averaged at the decoder.
    pub reps: usize,
}

impl VqSgd {
    pub fn new(n: usize, reps: usize) -> Self {
        assert!(reps >= 1);
        VqSgd { n, reps }
    }

    fn index_bits(&self) -> usize {
        (usize::BITS - (2 * self.n - 1).leading_zeros()) as usize
    }
}

impl Compressor for VqSgd {
    fn name(&self) -> String {
        format!("vqsgd-x{}", self.reps)
    }

    fn n(&self) -> usize {
        self.n
    }

    fn bits_per_dim(&self) -> f32 {
        (self.reps * self.index_bits()) as f32 / self.n as f32
    }

    fn compress_into(&self, y: &[f32], rng: &mut Rng, ws: &mut Workspace, out: &mut Compressed) {
        assert_eq!(y.len(), self.n);
        let g = norm2(y);
        let ib = self.index_bits();
        let mut w = BitWriter::reuse(std::mem::take(&mut out.bytes));
        w.reserve_bits(self.reps * ib + 32);
        w.write_f32(g);
        if g > 0.0 {
            let sqrt_n = (self.n as f32).sqrt();
            // λ_i = |v_i| / √n for the vertex sign(v_i)·√n·e_i; the slack
            // 1 − ‖v‖₁/√n is split evenly across all 2n vertices (their
            // contributions cancel in expectation).
            ws.b.resize(self.n, 0.0);
            for (vi, &yi) in ws.b.iter_mut().zip(y) {
                *vi = yi / g;
            }
            let v = &ws.b;
            let slack = (1.0 - norm1(v) / sqrt_n).max(0.0);
            let slack_each = slack / (2 * self.n) as f32;
            for _ in 0..self.reps {
                // Sample from the categorical distribution over 2n vertices.
                let mut u = rng.uniform_f32();
                let mut chosen = 2 * self.n - 1;
                for (i, &vi) in v.iter().enumerate() {
                    let (p_pos, p_neg) = if vi >= 0.0 {
                        (vi / sqrt_n + slack_each, slack_each)
                    } else {
                        (slack_each, -vi / sqrt_n + slack_each)
                    };
                    if u < p_pos {
                        chosen = 2 * i;
                        break;
                    }
                    u -= p_pos;
                    if u < p_neg {
                        chosen = 2 * i + 1;
                        break;
                    }
                    u -= p_neg;
                }
                w.write_bits(chosen as u64, ib);
            }
        }
        out.n = self.n;
        out.payload_bits = if g > 0.0 { self.reps * ib } else { 0 };
        out.side_bits = 32;
        out.bytes = w.into_bytes();
    }

    fn decompress_into(&self, msg: &Compressed, _ws: &mut Workspace, out: &mut [f32]) {
        let mut r = BitReader::new(&msg.bytes);
        let g = r.read_f32();
        out.fill(0.0);
        if g == 0.0 {
            return;
        }
        let ib = self.index_bits();
        let sqrt_n = (self.n as f32).sqrt();
        let scale = g * sqrt_n / self.reps as f32;
        for _ in 0..self.reps {
            let idx = r.read_bits(ib) as usize;
            let coord = idx / 2;
            let sign = if idx % 2 == 0 { 1.0 } else { -1.0 };
            if coord < self.n {
                out[coord] += sign * scale;
            }
        }
    }

    fn is_unbiased(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::dist2;

    #[test]
    fn output_is_scaled_vertex_average() {
        let mut rng = Rng::seed_from(1);
        let n = 16;
        let c = VqSgd::new(n, 1);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        // Exactly one coordinate, magnitude g·√n.
        let nz: Vec<f32> = yhat.iter().copied().filter(|&v| v != 0.0).collect();
        assert_eq!(nz.len(), 1);
        assert!((nz[0].abs() - norm2(&y) * (n as f32).sqrt()).abs() < 1e-3);
    }

    #[test]
    fn unbiased() {
        let mut rng = Rng::seed_from(2);
        let n = 8;
        let c = VqSgd::new(n, 4);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let trials = 40_000;
        let mut mean = vec![0.0f64; n];
        for _ in 0..trials {
            let yhat = c.decompress(&c.compress(&y, &mut rng));
            for (m, &v) in mean.iter_mut().zip(&yhat) {
                *m += v as f64 / trials as f64;
            }
        }
        let mean_f: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
        assert!(dist2(&mean_f, &y) / norm2(&y) < 0.1, "bias {}", dist2(&mean_f, &y) / norm2(&y));
    }

    #[test]
    fn bit_cost_logarithmic() {
        let c = VqSgd::new(1024, 1);
        assert_eq!(c.index_bits(), 11); // log2(2048)
        let mut rng = Rng::seed_from(3);
        let y: Vec<f32> = (0..1024).map(|_| rng.gaussian_f32()).collect();
        let msg = c.compress(&y, &mut rng);
        assert_eq!(msg.payload_bits, 11);
    }
}
