//! Unified compressor registry — every scheme in the zoo behind one
//! constructor, so any `(scheme, n, R)` triple can be built from config,
//! CLI or a test matrix without touching call sites.
//!
//! A [`CompressorSpec`] is a plain-data description of a scheme (plus its
//! per-scheme parameters); [`CompressorSpec::build`] turns it into a live
//! [`Compressor`] for a dimension `n` and budget `R`, deriving every
//! budget-dependent knob (sparsifier `k`, QSGD levels, vqSGD repetitions,
//! RATQ per-coordinate widths) from the paper's `⌊nR⌋` wire contract
//! (§3, App. F). Schemes with a *fixed* wire rate (sign is 1 bit/dim,
//! TernGrad ≈ log₂3, QSGD ≥ 2 bits/dim) cannot honor arbitrarily small
//! budgets — [`CompressorSpec::is_feasible`] encodes exactly when the
//! contract can hold, and `rust/tests/test_conformance.rs` checks both
//! directions over the whole `all_specs() × R × n` matrix.
//!
//! For the allocation-free hot path, [`build_with_workspace`] returns the
//! codec together with a pre-sized [`Workspace`] (from the codec's
//! [`Compressor::workspace_floats`] report), so callers preallocate once.
//!
//! The spec grammar accepted by [`CompressorSpec::parse`] (and printed by
//! [`CompressorSpec::name`]):
//!
//! ```text
//! ndsc | ndsc-dith | ndsc-ortho | ndsc-ortho-dith | dsc | dsc-dith
//! naive | sd | qsgd | sign | ternary | vqsgd | ratq | dqgd | fp32
//! topk[<V>b[-idx]]           e.g. topk1b, topk4b-idx   (k = ⌊nR⌋/bits-per-entry)
//! randk[<V>b[-det|-plain]]   e.g. randk1b, randk1b-det (k = ⌊nR⌋/V)
//! <inner>+<frame>            e.g. sd+ndh, randk1b+ndh, topk1b+ndo
//!                            (App. H: compress in the embedding domain)
//! ```

use crate::linalg::frames::{Frame, FrameKind, HadamardFrame, OrthonormalFrame, SubGaussianFrame};
use crate::linalg::fwht::next_pow2;
use crate::linalg::rng::Rng;
use crate::quant::compose::EmbeddedCompressor;
use crate::quant::dqgd::DqgdRange;
use crate::quant::dsc::{CodecMode, EmbedKind, SubspaceCodec};
use crate::quant::gain_shape::{NaiveUniform, StandardDither};
use crate::quant::qsgd::Qsgd;
use crate::quant::randk::RandK;
use crate::quant::ratq::Ratq;
use crate::quant::sign::SignQuantizer;
use crate::quant::ternary::Ternary;
use crate::quant::topk::TopK;
use crate::quant::vqsgd::VqSgd;
use crate::quant::{budget_bits, Compressed, Compressor, Workspace};

// ---------------------------------------------------------------------------
// Frame specs
// ---------------------------------------------------------------------------

/// Plain-data description of the frame an embedding-based scheme uses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FrameSpec {
    /// Randomized Hadamard `S = PDH`, `N = 2^⌈log₂n⌉` (λ → 1, the default).
    Hadamard,
    /// Randomized Hadamard with `N = 2^⌈log₂n⌉·λ` (App. N sweeps; λ is
    /// rounded up to a power of two).
    HadamardLambda(u8),
    /// Haar orthonormal with λ = 1 (a random rotation).
    Orthonormal,
    /// Haar orthonormal with an explicit aspect ratio λ ≥ 1.
    OrthonormalLambda(f32),
    /// Sub-Gaussian i.i.d. frame at λ = 2 (App. J.1).
    SubGaussian,
}

impl FrameSpec {
    pub fn from_kind(kind: FrameKind) -> FrameSpec {
        match kind {
            FrameKind::Hadamard => FrameSpec::Hadamard,
            FrameKind::Orthonormal => FrameSpec::Orthonormal,
            FrameKind::SubGaussian => FrameSpec::SubGaussian,
        }
    }

    /// Embedding dimension `N` this frame will have at original dim `n`.
    pub fn big_n(self, n: usize) -> usize {
        match self {
            FrameSpec::Hadamard => next_pow2(n),
            FrameSpec::HadamardLambda(m) => {
                next_pow2(n) * (m as usize).max(1).next_power_of_two()
            }
            FrameSpec::Orthonormal => n,
            FrameSpec::OrthonormalLambda(l) => ((n as f32 * l).ceil() as usize).max(n),
            FrameSpec::SubGaussian => (2 * n).max(n),
        }
    }

    pub fn build(self, n: usize, rng: &mut Rng) -> Box<dyn Frame> {
        match self {
            FrameSpec::Hadamard => Box::new(HadamardFrame::new(n, rng)),
            FrameSpec::HadamardLambda(_) => {
                Box::new(HadamardFrame::with_big_n(n, self.big_n(n), rng))
            }
            FrameSpec::Orthonormal => Box::new(OrthonormalFrame::with_big_n(n, n, rng)),
            FrameSpec::OrthonormalLambda(l) => Box::new(OrthonormalFrame::with_lambda(n, l, rng)),
            FrameSpec::SubGaussian => Box::new(SubGaussianFrame::with_lambda(n, 2.0, rng)),
        }
    }
}

// ---------------------------------------------------------------------------
// Compressor specs
// ---------------------------------------------------------------------------

/// Sparsifier flavour (random-k): plain, `n/k`-rescaled (unbiased), or
/// nearest-neighbour values (the error-feedback variant of Fig. 1d).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparsifyKind {
    Plain,
    Unbiased,
    Deterministic,
}

/// Inner compressor of an App.-H composition (`<inner>+NDE`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InnerSpec {
    StandardDither,
    RandK { value_bits: u8, kind: SparsifyKind },
    TopK { value_bits: u8 },
}

/// Plain-data description of a compression scheme. `Copy` on purpose:
/// specs are cheap values that flow through configs and test matrices.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CompressorSpec {
    /// (N)DSC — the paper's subspace codecs: embedding × quantizer × frame.
    Subspace { embed: EmbedKind, mode: CodecMode, frame: FrameSpec },
    /// Naive `‖·‖∞`-normalized uniform scalar quantizer (eq. 11).
    Naive,
    /// Standard dithering, no embedding (App. E / Fig. 1a "SD").
    StandardDither,
    /// QSGD with `2^⌊R−1⌋` levels (fixed-length variant, Table 1).
    Qsgd,
    /// 1-bit sign quantization.
    Sign,
    /// TernGrad ternary (≈1.6 bits/dim packed).
    Ternary,
    /// Top-k, `k = ⌊nR⌋ / bits-per-entry`; optionally charging index bits.
    TopK { value_bits: u8, count_index_bits: bool },
    /// Random-k over shared randomness, `k = ⌊nR⌋ / value_bits`.
    RandK { value_bits: u8, kind: SparsifyKind },
    /// vqSGD cross-polytope, repetitions filled from the budget.
    VqSgd,
    /// RATQ-style rotated adaptive quantizer, widths from the budget.
    Ratq,
    /// DQGD's predefined decaying dynamic range [6].
    Dqgd { r0: f32, gamma: f32 },
    /// Appendix-H composition: `inner` applied in the embedding domain.
    Embedded { inner: InnerSpec, frame: FrameSpec },
    /// Uncompressed fp32 reference (32 bits/dim).
    Fp32,
}

/// `⌈log₂ n⌉` bits to address one of `n` items (matches `TopK`'s coding).
fn index_bits(n: usize) -> usize {
    if n <= 1 {
        0
    } else {
        (usize::BITS - (n - 1).leading_zeros()) as usize
    }
}

/// Bits per vqSGD vertex index: `⌈log₂ 2n⌉`.
fn vq_index_bits(n: usize) -> usize {
    (usize::BITS - (2 * n - 1).leading_zeros()) as usize
}

/// QSGD level bits for a budget `R`: `1 + bits ≤ R` ⇒ `bits = ⌊R⌋ − 1`,
/// clamped to the implementable range.
pub fn qsgd_level_bits(r: f32) -> usize {
    ((r.floor() as i64) - 1).clamp(1, 24) as usize
}

/// Largest per-coordinate width RATQ can afford under `⌊nR⌋` once the
/// per-group ladder bits are paid; `None` when even 1 bit does not fit.
pub fn ratq_value_bits(n: usize, r: f32) -> Option<usize> {
    let big_n = next_pow2(n);
    let group = ((n as f32).ln().ceil() as usize).max(2);
    let overhead = big_n.div_ceil(group) * 3; // ladder_bits = 3, as Ratq::new
    let b = budget_bits(n, r);
    if b <= overhead {
        return None;
    }
    let bits = (b - overhead) / big_n;
    if bits == 0 {
        None
    } else {
        Some(bits.min(24))
    }
}

impl CompressorSpec {
    /// Whether this scheme can honor `payload_bits ≤ ⌊nR⌋` at `(n, R)`.
    /// Budget-adaptive schemes are feasible whenever the budget admits one
    /// atom (one retained value, one vertex index, …); fixed-rate schemes
    /// (sign, ternary, QSGD, fp32) need `R` at or above their wire rate.
    pub fn is_feasible(&self, n: usize, r: f32) -> bool {
        if n == 0 || !(r > 0.0) {
            return false;
        }
        let b = budget_bits(n, r);
        match *self {
            CompressorSpec::Subspace { .. }
            | CompressorSpec::Naive
            | CompressorSpec::StandardDither
            | CompressorSpec::Dqgd { .. } => true,
            CompressorSpec::Qsgd => n * (qsgd_level_bits(r) + 1) <= b,
            CompressorSpec::Sign => n <= b,
            CompressorSpec::Ternary => n.div_ceil(5) * 8 <= b,
            CompressorSpec::TopK { value_bits, count_index_bits } => {
                // Same `max(1)` floor as `build` so feasibility and the
                // built compressor can never disagree on the wire cost.
                let per = (value_bits as usize).max(1)
                    + if count_index_bits { index_bits(n) } else { 0 };
                b >= per
            }
            CompressorSpec::RandK { value_bits, .. } => b >= (value_bits as usize).max(1),
            CompressorSpec::VqSgd => b >= vq_index_bits(n),
            CompressorSpec::Ratq => ratq_value_bits(n, r).is_some(),
            CompressorSpec::Embedded { inner, .. } => match inner {
                InnerSpec::StandardDither => b >= 1,
                InnerSpec::RandK { value_bits, .. } | InnerSpec::TopK { value_bits } => {
                    b >= (value_bits as usize).max(1)
                }
            },
            CompressorSpec::Fp32 => 32 * n <= b,
        }
    }

    /// Build a live compressor for dimension `n` at budget `R`. Frame and
    /// shared randomness are drawn from `rng` (common randomness with the
    /// decoder, established at setup, as in the paper).
    pub fn build(&self, n: usize, r: f32, rng: &mut Rng) -> Box<dyn Compressor> {
        assert!(n > 0, "dimension must be positive");
        assert!(r > 0.0, "bit budget must be positive");
        let b = budget_bits(n, r);
        match *self {
            CompressorSpec::Subspace { embed, mode, frame } => {
                Box::new(SubspaceCodec::new(frame.build(n, rng), embed, mode, r))
            }
            CompressorSpec::Naive => Box::new(NaiveUniform::new(n, r)),
            CompressorSpec::StandardDither => Box::new(StandardDither::new(n, r)),
            CompressorSpec::Qsgd => Box::new(Qsgd::new(n, qsgd_level_bits(r))),
            CompressorSpec::Sign => Box::new(SignQuantizer::new(n)),
            CompressorSpec::Ternary => Box::new(Ternary::new(n)),
            CompressorSpec::TopK { value_bits, count_index_bits } => {
                let vb = (value_bits as usize).max(1);
                let per = vb + if count_index_bits { index_bits(n) } else { 0 };
                let k = (b / per.max(1)).clamp(1, n);
                let t = TopK::new(n, k, vb);
                Box::new(if count_index_bits { t.counting_index_bits() } else { t })
            }
            CompressorSpec::RandK { value_bits, kind } => {
                let vb = (value_bits as usize).max(1);
                let k = (b / vb).clamp(1, n);
                let c = RandK::new(n, k, vb);
                Box::new(match kind {
                    SparsifyKind::Plain => c,
                    SparsifyKind::Unbiased => c.unbiased(),
                    SparsifyKind::Deterministic => c.deterministic(),
                })
            }
            CompressorSpec::VqSgd => {
                let reps = (b / vq_index_bits(n).max(1)).max(1);
                Box::new(VqSgd::new(n, reps))
            }
            CompressorSpec::Ratq => {
                Box::new(Ratq::new(n, ratq_value_bits(n, r).unwrap_or(1), rng))
            }
            CompressorSpec::Dqgd { r0, gamma } => Box::new(DqgdRange::new(n, r, r0, gamma)),
            CompressorSpec::Embedded { inner, frame } => {
                let f = frame.build(n, rng);
                let big_n = f.big_n();
                // Spread the original-space budget ⌊nR⌋ over the N
                // embedding coordinates (Theorem 1's R/λ).
                let inner_box: Box<dyn Compressor> = match inner {
                    InnerSpec::StandardDither => {
                        Box::new(StandardDither::new(big_n, b.max(1) as f32 / big_n as f32))
                    }
                    InnerSpec::RandK { value_bits, kind } => {
                        let vb = (value_bits as usize).max(1);
                        let k = (b / vb).clamp(1, big_n);
                        let c = RandK::new(big_n, k, vb);
                        Box::new(match kind {
                            SparsifyKind::Plain => c,
                            SparsifyKind::Unbiased => c.unbiased(),
                            SparsifyKind::Deterministic => c.deterministic(),
                        })
                    }
                    InnerSpec::TopK { value_bits } => {
                        let vb = (value_bits as usize).max(1);
                        let k = (b / vb).clamp(1, big_n);
                        Box::new(TopK::new(big_n, k, vb))
                    }
                };
                Box::new(EmbeddedCompressor::new(f, EmbedKind::NearDemocratic, inner_box))
            }
            CompressorSpec::Fp32 => Box::new(Fp32Passthrough { n }),
        }
    }

    /// Whether a built plan (the `levels × workers` codec ladder a
    /// served job regrows from its seed) is **immutable after
    /// construction** and therefore safe to share across jobs via the
    /// serve-layer plan cache ([`crate::serve::plancache::PlanCache`]).
    ///
    /// Every scheme here is a pure function of `(spec, n, R, rng
    /// stream)` at *build* time; what disqualifies a scheme is mutable
    /// *runtime* state inside the codec object. The only offender is
    /// DQGD: [`crate::quant::dqgd::DqgdRange`] carries a per-codec
    /// round counter (its range-refinement schedule) that advances on
    /// every `compress`, so two jobs sharing one instance would
    /// interleave each other's schedules and diverge from the solo
    /// trace. DQGD jobs therefore always take a fresh deterministic
    /// build — bit-identical anyway, just not shared. Solver scratch
    /// behind a `Mutex` (subspace/embedded codecs) does **not**
    /// disqualify: it is deterministic warm scratch with no
    /// round-to-round memory.
    pub fn plan_cacheable(&self) -> bool {
        !matches!(*self, CompressorSpec::Dqgd { .. })
    }

    /// Canonical spec name (round-trips through [`CompressorSpec::parse`]).
    pub fn name(&self) -> String {
        match *self {
            CompressorSpec::Subspace { embed, mode, frame } => {
                let base = match (embed, frame) {
                    (EmbedKind::NearDemocratic, FrameSpec::Hadamard) => "ndsc".to_string(),
                    (EmbedKind::NearDemocratic, FrameSpec::Orthonormal) => {
                        "ndsc-ortho".to_string()
                    }
                    (EmbedKind::NearDemocratic, f) => format!("ndsc[{f:?}]"),
                    (EmbedKind::Democratic, FrameSpec::Hadamard) => "dsc".to_string(),
                    (EmbedKind::Democratic, f) => format!("dsc[{f:?}]"),
                };
                if mode == CodecMode::Dithered {
                    format!("{base}-dith")
                } else {
                    base
                }
            }
            CompressorSpec::Naive => "naive".into(),
            CompressorSpec::StandardDither => "sd".into(),
            CompressorSpec::Qsgd => "qsgd".into(),
            CompressorSpec::Sign => "sign".into(),
            CompressorSpec::Ternary => "ternary".into(),
            CompressorSpec::TopK { value_bits, count_index_bits } => {
                if count_index_bits {
                    format!("topk{value_bits}b-idx")
                } else {
                    format!("topk{value_bits}b")
                }
            }
            CompressorSpec::RandK { value_bits, kind } => match kind {
                SparsifyKind::Unbiased => format!("randk{value_bits}b"),
                SparsifyKind::Deterministic => format!("randk{value_bits}b-det"),
                SparsifyKind::Plain => format!("randk{value_bits}b-plain"),
            },
            CompressorSpec::VqSgd => "vqsgd".into(),
            CompressorSpec::Ratq => "ratq".into(),
            CompressorSpec::Dqgd { .. } => "dqgd".into(),
            CompressorSpec::Embedded { inner, frame } => {
                // Only the canonical frames get parseable tags; exotic
                // frames are named loudly un-parseable rather than
                // silently rehydrating as a different frame.
                let tag = match frame {
                    FrameSpec::Hadamard => "ndh".to_string(),
                    FrameSpec::Orthonormal => "ndo".to_string(),
                    f => format!("nde[{f:?}]"),
                };
                let i = match inner {
                    InnerSpec::StandardDither => "sd".to_string(),
                    InnerSpec::RandK { value_bits, kind } => match kind {
                        SparsifyKind::Unbiased => format!("randk{value_bits}b"),
                        SparsifyKind::Deterministic => format!("randk{value_bits}b-det"),
                        SparsifyKind::Plain => format!("randk{value_bits}b-plain"),
                    },
                    InnerSpec::TopK { value_bits } => format!("topk{value_bits}b"),
                };
                format!("{i}+{tag}")
            }
            CompressorSpec::Fp32 => "fp32".into(),
        }
    }

    /// Parse the spec grammar (module docs). Accepts the legacy
    /// `SchemeKind` aliases so existing CLI invocations keep working.
    pub fn parse(s: &str) -> Option<CompressorSpec> {
        use CompressorSpec as S;
        let t = s.to_ascii_lowercase();
        // App.-H compositions: "<inner>+<frame>".
        if let Some((inner_s, frame_s)) = t.split_once('+') {
            let frame = match frame_s {
                "ndh" | "hadamard" => FrameSpec::Hadamard,
                "ndo" | "ortho" | "orthonormal" => FrameSpec::Orthonormal,
                _ => return None, // incl. "nde[..]" names of exotic frames
            };
            let inner = if inner_s == "sd" || inner_s == "dither" {
                InnerSpec::StandardDither
            } else if let Some(rest) = inner_s.strip_prefix("randk") {
                let (vb, kind) = parse_sparsify_suffix(rest)?;
                InnerSpec::RandK { value_bits: vb, kind }
            } else if let Some(rest) = inner_s.strip_prefix("topk") {
                let vb: u8 =
                    if rest.is_empty() { 1 } else { rest.strip_suffix('b')?.parse().ok()? };
                if vb == 0 {
                    return None;
                }
                InnerSpec::TopK { value_bits: vb }
            } else {
                return None;
            };
            return Some(S::Embedded { inner, frame });
        }
        let det = |frame| S::Subspace {
            embed: EmbedKind::NearDemocratic,
            mode: CodecMode::Deterministic,
            frame,
        };
        Some(match t.as_str() {
            "ndsc" => det(FrameSpec::Hadamard),
            "ndsc-dith" | "ndsc_dithered" | "ndscd" => S::Subspace {
                embed: EmbedKind::NearDemocratic,
                mode: CodecMode::Dithered,
                frame: FrameSpec::Hadamard,
            },
            "ndsc-ortho" | "ndo" => det(FrameSpec::Orthonormal),
            "ndsc-ortho-dith" => S::Subspace {
                embed: EmbedKind::NearDemocratic,
                mode: CodecMode::Dithered,
                frame: FrameSpec::Orthonormal,
            },
            "dsc" => S::Subspace {
                embed: EmbedKind::Democratic,
                mode: CodecMode::Deterministic,
                frame: FrameSpec::Hadamard,
            },
            "dsc-dith" | "dsc_dithered" | "dscd" => S::Subspace {
                embed: EmbedKind::Democratic,
                mode: CodecMode::Dithered,
                frame: FrameSpec::Hadamard,
            },
            "naive" | "uniform" => S::Naive,
            "sd" | "dither" | "standard-dither" => S::StandardDither,
            "qsgd" => S::Qsgd,
            "sign" => S::Sign,
            "ternary" | "terngrad" => S::Ternary,
            "vqsgd" => S::VqSgd,
            "ratq" => S::Ratq,
            "dqgd" => S::Dqgd { r0: 1.0, gamma: 1.0 },
            "none" | "float" | "fp32" => S::Fp32,
            // "topk", "topk<V>b", "topk<V>b-idx"; legacy "topk"/"top-k"
            // defaults to 8-bit values (k = ⌊nR⌋/8, the old SchemeKind).
            "topk" | "top-k" => S::TopK { value_bits: 8, count_index_bits: false },
            "randk" | "rand-k" | "random" => {
                S::RandK { value_bits: 1, kind: SparsifyKind::Unbiased }
            }
            _ => {
                if let Some(rest) = t.strip_prefix("topk") {
                    let (core, idx) = match rest.strip_suffix("-idx") {
                        Some(c) => (c, true),
                        None => (rest, false),
                    };
                    let vb: u8 = core.strip_suffix('b')?.parse().ok()?;
                    if vb == 0 {
                        return None;
                    }
                    S::TopK { value_bits: vb, count_index_bits: idx }
                } else if let Some(rest) = t.strip_prefix("randk") {
                    let (vb, kind) = parse_sparsify_suffix(rest)?;
                    S::RandK { value_bits: vb, kind }
                } else {
                    return None;
                }
            }
        })
    }
}

fn parse_sparsify_suffix(rest: &str) -> Option<(u8, SparsifyKind)> {
    if rest.is_empty() {
        return Some((1, SparsifyKind::Unbiased));
    }
    let (core, kind) = if let Some(c) = rest.strip_suffix("-det") {
        (c, SparsifyKind::Deterministic)
    } else if let Some(c) = rest.strip_suffix("-plain") {
        (c, SparsifyKind::Plain)
    } else {
        (rest, SparsifyKind::Unbiased)
    };
    let vb: u8 = core.strip_suffix('b')?.parse().ok()?;
    if vb == 0 {
        return None;
    }
    Some((vb, kind))
}

/// Free-function form of [`CompressorSpec::build`].
pub fn build(spec: &CompressorSpec, n: usize, r: f32, rng: &mut Rng) -> Box<dyn Compressor> {
    spec.build(n, r, rng)
}

/// Build a compressor together with a [`Workspace`] pre-sized for it (via
/// the codec's [`Compressor::workspace_floats`] report), so long-running
/// callers — the coordinator, the optimizer loops — preallocate once and
/// run every subsequent `compress_into`/`decompress_into` allocation-free.
pub fn build_with_workspace(
    spec: &CompressorSpec,
    n: usize,
    r: f32,
    rng: &mut Rng,
) -> (Box<dyn Compressor>, Workspace) {
    let c = spec.build(n, r, rng);
    let ws = Workspace::for_compressor(c.as_ref());
    (c, ws)
}

/// The full enumerable zoo: every scheme the paper's Table 1 and figures
/// exercise, in canonical parameterizations. This is the conformance
/// matrix's row set (`rust/tests/test_conformance.rs`) and what
/// `repro schemes` prints. The fp32 passthrough is excluded — it is a
/// reference, not a compression scheme (it needs `R ≥ 32`).
pub fn all_specs() -> Vec<CompressorSpec> {
    use CompressorSpec as S;
    let ndh = FrameSpec::Hadamard;
    vec![
        S::Subspace { embed: EmbedKind::NearDemocratic, mode: CodecMode::Deterministic, frame: ndh },
        S::Subspace { embed: EmbedKind::NearDemocratic, mode: CodecMode::Dithered, frame: ndh },
        S::Subspace {
            embed: EmbedKind::NearDemocratic,
            mode: CodecMode::Deterministic,
            frame: FrameSpec::Orthonormal,
        },
        S::Subspace { embed: EmbedKind::Democratic, mode: CodecMode::Deterministic, frame: ndh },
        S::Subspace { embed: EmbedKind::Democratic, mode: CodecMode::Dithered, frame: ndh },
        S::Naive,
        S::StandardDither,
        S::Qsgd,
        S::Sign,
        S::Ternary,
        S::TopK { value_bits: 1, count_index_bits: false },
        S::TopK { value_bits: 4, count_index_bits: true },
        S::RandK { value_bits: 1, kind: SparsifyKind::Unbiased },
        S::RandK { value_bits: 1, kind: SparsifyKind::Deterministic },
        S::VqSgd,
        S::Ratq,
        S::Dqgd { r0: 1.0, gamma: 1.0 },
        S::Embedded { inner: InnerSpec::StandardDither, frame: ndh },
        S::Embedded {
            inner: InnerSpec::RandK { value_bits: 1, kind: SparsifyKind::Unbiased },
            frame: ndh,
        },
        S::Embedded { inner: InnerSpec::TopK { value_bits: 1 }, frame: ndh },
    ]
}

/// Working dimension for a spec at a nominal `n`, capping dense-frame
/// schemes: a Haar-orthonormal (or sub-Gaussian) frame is an `O(n·N)`
/// dense matrix with `O(n²N)` construction, so enumerating the zoo at
/// transformer-scale `n` must not instantiate one. Harnesses that sweep
/// the full zoo (`table1`, `repro schemes`) build such specs at
/// `min(n, 512)` and report that dimension instead.
pub fn dense_frame_dim_cap(spec: &CompressorSpec, n: usize) -> usize {
    let dense = |f: &FrameSpec| {
        matches!(
            f,
            FrameSpec::Orthonormal | FrameSpec::OrthonormalLambda(_) | FrameSpec::SubGaussian
        )
    };
    match spec {
        CompressorSpec::Subspace { frame, .. } | CompressorSpec::Embedded { frame, .. }
            if dense(frame) =>
        {
            n.min(512)
        }
        _ => n,
    }
}

/// Identity "compressor" for unquantized reference runs: 32 bits/dim of
/// payload so the traffic accounting stays meaningful. (Formerly lived in
/// `coordinator::config`; re-exported there for backward compatibility.)
pub struct Fp32Passthrough {
    pub n: usize,
}

impl Compressor for Fp32Passthrough {
    fn name(&self) -> String {
        "fp32".into()
    }

    fn n(&self) -> usize {
        self.n
    }

    fn bits_per_dim(&self) -> f32 {
        32.0
    }

    fn compress_into(
        &self,
        y: &[f32],
        _rng: &mut Rng,
        _ws: &mut Workspace,
        out: &mut Compressed,
    ) {
        let mut w = crate::quant::bitpack::BitWriter::reuse(std::mem::take(&mut out.bytes));
        w.reserve_bits(32 * y.len());
        for &v in y {
            w.write_f32(v);
        }
        out.n = self.n;
        out.payload_bits = 32 * self.n;
        out.side_bits = 0;
        out.bytes = w.into_bytes();
    }

    fn decompress_into(&self, msg: &Compressed, _ws: &mut Workspace, out: &mut [f32]) {
        let mut r = crate::quant::bitpack::BitReader::new(&msg.bytes);
        for v in out.iter_mut() {
            *v = r.read_f32();
        }
    }

    fn is_unbiased(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip_through_parse() {
        for spec in all_specs() {
            let name = spec.name();
            let parsed = CompressorSpec::parse(&name)
                .unwrap_or_else(|| panic!("'{name}' does not parse"));
            assert_eq!(parsed, spec, "name '{name}' round-trip");
        }
        // Legacy aliases still work.
        assert_eq!(
            CompressorSpec::parse("topk"),
            Some(CompressorSpec::TopK { value_bits: 8, count_index_bits: false })
        );
        assert_eq!(CompressorSpec::parse("fp32"), Some(CompressorSpec::Fp32));
        assert!(CompressorSpec::parse("sd+ndh").is_some());
        assert!(CompressorSpec::parse("bogus").is_none());
    }

    #[test]
    fn zoo_has_at_least_12_distinct_schemes() {
        let specs = all_specs();
        assert!(specs.len() >= 12, "only {} specs", specs.len());
        let mut names: Vec<String> = specs.iter().map(|s| s.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), specs.len(), "duplicate spec names");
    }

    #[test]
    fn budget_derived_knobs_match_hand_wiring() {
        // The registry must reproduce the figures' hand-derived settings.
        let mut rng = Rng::seed_from(1);
        // Fig. 2c: n = 784, R = 0.1 → 78 coords at 1 bit.
        let c = CompressorSpec::RandK { value_bits: 1, kind: SparsifyKind::Unbiased }
            .build(784, 0.1, &mut rng);
        let y: Vec<f32> = (0..784).map(|_| rng.gaussian_f32()).collect();
        assert_eq!(c.compress(&y, &mut rng).payload_bits, 78);
        // Fig. 2a: n = 30, R = 0.5, 5-bit top-k → k = 3.
        let c = CompressorSpec::TopK { value_bits: 5, count_index_bits: false }
            .build(30, 0.5, &mut rng);
        let y: Vec<f32> = (0..30).map(|_| rng.gaussian_f32()).collect();
        assert_eq!(c.compress(&y, &mut rng).payload_bits, 15);
    }

    #[test]
    fn infeasible_fixed_rate_schemes_are_flagged() {
        assert!(!CompressorSpec::Sign.is_feasible(64, 0.5));
        assert!(CompressorSpec::Sign.is_feasible(64, 1.0));
        assert!(!CompressorSpec::Ternary.is_feasible(64, 1.0));
        assert!(CompressorSpec::Ternary.is_feasible(64, 3.0));
        assert!(!CompressorSpec::Qsgd.is_feasible(64, 1.0));
        assert!(CompressorSpec::Qsgd.is_feasible(64, 3.0));
        assert!(!CompressorSpec::Fp32.is_feasible(64, 3.0));
        assert!(CompressorSpec::Fp32.is_feasible(64, 32.0));
    }

    #[test]
    fn fp32_passthrough_is_lossless() {
        let mut rng = Rng::seed_from(2);
        let c = Fp32Passthrough { n: 10 };
        let y: Vec<f32> = (0..10).map(|_| rng.gaussian_cubed()).collect();
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        assert_eq!(y, yhat);
    }
}
