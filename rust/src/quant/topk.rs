//! Top-k sparsification [18] — Table 1 and the "Top-K" curves of Figs. 1, 2.
//!
//! Keeps the `k` largest-magnitude coordinates and quantizes each retained
//! value with `value_bits` bits (dithered, range `±‖y‖∞`). Index cost:
//! `⌈log₂ n⌉` bits per index, charged against the payload when
//! `count_index_bits` is set (the paper's Table 1 charges the
//! information-theoretic `log₂ C(n,k)`; our explicit coding is within
//! `k·log₂(n/k)·O(1)` of that and is what actually crosses the wire).
//! The paper's Fig. 2 experiments charge only value bits — matching their
//! "78 coordinates × 1 bit = 78 bits" accounting — so the flag defaults
//! to `false` there.

use crate::linalg::rng::Rng;
use crate::linalg::vecops::{norm_inf, top_k_indices_into};
use crate::quant::bitpack::{BitReader, BitWriter};
use crate::quant::dither::DitheredUniform;
use crate::quant::{Compressed, Compressor, Workspace};

pub struct TopK {
    n: usize,
    pub k: usize,
    pub value_bits: usize,
    pub count_index_bits: bool,
}

impl TopK {
    pub fn new(n: usize, k: usize, value_bits: usize) -> Self {
        assert!(k <= n && k > 0);
        assert!(value_bits >= 1);
        TopK { n, k, value_bits, count_index_bits: false }
    }

    pub fn counting_index_bits(mut self) -> Self {
        self.count_index_bits = true;
        self
    }

    fn index_bits(&self) -> usize {
        (usize::BITS - (self.n - 1).leading_zeros()) as usize
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("top{}x{}b", self.k, self.value_bits)
    }

    fn n(&self) -> usize {
        self.n
    }

    fn bits_per_dim(&self) -> f32 {
        let idx = if self.count_index_bits { self.index_bits() } else { 0 };
        (self.k * (self.value_bits + idx)) as f32 / self.n as f32
    }

    fn compress_into(&self, y: &[f32], rng: &mut Rng, ws: &mut Workspace, out: &mut Compressed) {
        assert_eq!(y.len(), self.n);
        let s = norm_inf(y);
        let ib = self.index_bits();
        let mut w = BitWriter::reuse(std::mem::take(&mut out.bytes));
        w.reserve_bits(self.k * (ib + self.value_bits) + 32);
        w.write_f32(s);
        top_k_indices_into(y, self.k, &mut ws.idx);
        ws.idx.sort_unstable();
        let q = DitheredUniform::symmetric(s.max(1e-30), self.value_bits);
        for &i in &ws.idx {
            w.write_bits(i as u64, ib);
            w.write_bits(q.encode(y[i], rng), self.value_bits);
        }
        let value_payload = self.k * self.value_bits;
        let index_payload = self.k * ib;
        let (payload_bits, side_bits) = if self.count_index_bits {
            (value_payload + index_payload, 32)
        } else {
            (value_payload, 32 + index_payload)
        };
        out.n = self.n;
        out.payload_bits = payload_bits;
        out.side_bits = side_bits;
        out.bytes = w.into_bytes();
    }

    fn decompress_into(&self, msg: &Compressed, _ws: &mut Workspace, out: &mut [f32]) {
        let mut r = BitReader::new(&msg.bytes);
        let s = r.read_f32();
        let ib = self.index_bits();
        let q = DitheredUniform::symmetric(s.max(1e-30), self.value_bits);
        out.fill(0.0);
        for _ in 0..self.k {
            let i = r.read_bits(ib) as usize;
            out[i] = q.decode(r.read_bits(self.value_bits));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::{dist2, norm2};

    #[test]
    fn keeps_largest_coordinates() {
        let mut rng = Rng::seed_from(1);
        let n = 100;
        let c = TopK::new(n, 10, 8);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        // The support of yhat must be among the top-10 magnitudes of y.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| y[b].abs().partial_cmp(&y[a].abs()).unwrap());
        let top: std::collections::HashSet<usize> = order[..10].iter().copied().collect();
        for (i, &v) in yhat.iter().enumerate() {
            if v != 0.0 {
                assert!(top.contains(&i), "index {i} not in top-10");
            }
        }
    }

    #[test]
    fn sparsification_error_fraction() {
        // Table 1: error ~ mass of the dropped (n-k) coordinates.
        let mut rng = Rng::seed_from(2);
        let n = 1000;
        let c = TopK::new(n, 100, 12);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        let rel = dist2(&yhat, &y) / norm2(&y);
        // Gaussian: dropping 90% of coords keeps ~ the top decile of mass.
        assert!(rel > 0.5 && rel < 1.0, "rel={rel}");
    }

    #[test]
    fn heavy_tail_friendly() {
        // On Gaussian³, top-k captures most of the l2 mass.
        let mut rng = Rng::seed_from(3);
        let n = 1000;
        let c = TopK::new(n, 100, 12);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_cubed()).collect();
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        assert!(dist2(&yhat, &y) / norm2(&y) < 0.45);
    }

    #[test]
    fn bit_accounting_modes() {
        let mut rng = Rng::seed_from(4);
        let y: Vec<f32> = (0..784).map(|_| rng.gaussian_f32()).collect();
        let free = TopK::new(784, 78, 1);
        let m = free.compress(&y, &mut rng);
        assert_eq!(m.payload_bits, 78); // the paper's Fig 2c accounting
        let charged = TopK::new(784, 78, 1).counting_index_bits();
        let m2 = charged.compress(&y, &mut rng);
        assert_eq!(m2.payload_bits, 78 * (1 + 10)); // ceil(log2 784) = 10
    }
}
