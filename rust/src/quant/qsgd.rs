//! QSGD [8] — stochastic level quantization, Table 1.
//!
//! With `s = 2^R` levels, `Q(y)_i = ‖y‖₂ · sign(y_i) · ξ_i(y)` where
//! `ξ_i ∈ {0, 1/s, …, 1}` stochastically rounds `|y_i|/‖y‖₂` — unbiased.
//! QSGD's headline efficiency comes from *variable-length* Elias coding of
//! the levels; this implementation is the **fixed-length** variant
//! (`1 + R` bits/coordinate), since the paper studies fixed-length budgets
//! — the error behaviour (`min{√n·2^{−R}·…}` scaling, Table 1) is the
//! level structure's, not the entropy coder's.

use crate::linalg::rng::Rng;
use crate::linalg::vecops::norm2;
use crate::quant::bitpack::{BitReader, BitWriter};
use crate::quant::{Compressed, Compressor, Workspace};

pub struct Qsgd {
    n: usize,
    /// Bits for the level index (levels `s = 2^bits`).
    bits: usize,
}

impl Qsgd {
    pub fn new(n: usize, bits: usize) -> Self {
        assert!(bits >= 1 && bits <= 24);
        Qsgd { n, bits }
    }

    fn levels(&self) -> u64 {
        1u64 << self.bits
    }
}

impl Compressor for Qsgd {
    fn name(&self) -> String {
        format!("qsgd-{}lvl", self.levels())
    }

    fn n(&self) -> usize {
        self.n
    }

    fn bits_per_dim(&self) -> f32 {
        (self.bits + 1) as f32
    }

    fn compress_into(&self, y: &[f32], rng: &mut Rng, _ws: &mut Workspace, out: &mut Compressed) {
        assert_eq!(y.len(), self.n);
        let g = norm2(y);
        let s = self.levels() - 1; // s intervals
        let mut w = BitWriter::reuse(std::mem::take(&mut out.bytes));
        w.reserve_bits(self.n * (self.bits + 1) + 32);
        w.write_f32(g);
        if g > 0.0 {
            for &v in y {
                let t = (v.abs() / g) * s as f32;
                let l = t.floor().min((s - 1) as f32);
                let idx = l as u64 + u64::from(rng.bernoulli((t - l) as f64));
                w.write_bits(u64::from(v >= 0.0), 1);
                w.write_bits(idx.min(s), self.bits);
            }
        }
        out.n = self.n;
        out.payload_bits = if g > 0.0 { self.n * (self.bits + 1) } else { 0 };
        out.side_bits = 32;
        out.bytes = w.into_bytes();
    }

    fn decompress_into(&self, msg: &Compressed, _ws: &mut Workspace, out: &mut [f32]) {
        let mut r = BitReader::new(&msg.bytes);
        let g = r.read_f32();
        let s = self.levels() - 1;
        if g == 0.0 {
            out.fill(0.0);
            return;
        }
        for v in out.iter_mut() {
            let sign = if r.read_bits(1) == 1 { 1.0 } else { -1.0 };
            let idx = r.read_bits(self.bits);
            *v = sign * g * idx as f32 / s as f32;
        }
    }

    fn is_unbiased(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::dist2;

    #[test]
    fn unbiased() {
        let mut rng = Rng::seed_from(1);
        let n = 20;
        let c = Qsgd::new(n, 2);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let trials = 8000;
        let mut mean = vec![0.0f64; n];
        for _ in 0..trials {
            let yhat = c.decompress(&c.compress(&y, &mut rng));
            for (m, &v) in mean.iter_mut().zip(&yhat) {
                *m += v as f64 / trials as f64;
            }
        }
        let mean_f: Vec<f32> = mean.iter().map(|&v| v as f32).collect();
        assert!(dist2(&mean_f, &y) / norm2(&y) < 0.05);
    }

    #[test]
    fn error_shrinks_with_levels() {
        let mut rng = Rng::seed_from(2);
        let n = 512;
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let mut last = f32::INFINITY;
        for bits in [1usize, 3, 6] {
            let c = Qsgd::new(n, bits);
            let mut err = 0.0;
            for _ in 0..10 {
                let yhat = c.decompress(&c.compress(&y, &mut rng));
                err += dist2(&yhat, &y) / 10.0;
            }
            assert!(err < last, "bits={bits} err={err} last={last}");
            last = err;
        }
    }

    #[test]
    fn payload_is_fixed_length() {
        let mut rng = Rng::seed_from(3);
        let c = Qsgd::new(100, 3);
        let y: Vec<f32> = (0..100).map(|_| rng.gaussian_cubed()).collect();
        let msg = c.compress(&y, &mut rng);
        assert_eq!(msg.payload_bits, 100 * 4);
    }
}
