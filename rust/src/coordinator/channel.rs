//! Byte-accounted, budget-enforced channels and buffer recycling.
//!
//! [`AccountedSender`] wraps an `mpsc::SyncSender` and (a) tallies payload
//! and overhead bits of everything sent, (b) **rejects** any message whose
//! payload exceeds the per-message budget — making the paper's "strict
//! budget of R bits per dimension" an enforced runtime invariant rather
//! than a convention. The *bounded* (`sync_channel`) flavour matters for
//! the allocation-free hot path: its ring buffer is allocated once at
//! channel creation, so steady-state sends touch no heap (the unbounded
//! flavour allocates a fresh block every few dozen messages).
//!
//! [`ChannelPools`] closes the loop on message *payloads*: broadcast
//! iterate buffers and uplink wire-byte buffers ping-pong between server
//! and workers instead of being reallocated every round, which is what
//! makes a steady-state coordinator round fully allocation-free
//! (`rust/tests/test_alloc.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{SendError, SyncSender};
use std::sync::{Arc, Mutex};

use crate::coordinator::protocol::WireSize;

/// Shared traffic counters for one logical link (or a set of links).
#[derive(Default, Debug)]
pub struct TrafficCounter {
    pub payload_bits: AtomicUsize,
    pub overhead_bits: AtomicUsize,
    pub messages: AtomicUsize,
    pub rejected: AtomicUsize,
}

impl TrafficCounter {
    pub fn total_bits(&self) -> usize {
        self.payload_bits.load(Ordering::Relaxed) + self.overhead_bits.load(Ordering::Relaxed)
    }
}

/// Error returned by a budget-violating send.
#[derive(Debug)]
pub enum ChannelError<T> {
    /// Message payload exceeded the per-message bit budget.
    OverBudget { payload_bits: usize, budget_bits: usize },
    /// Receiver hung up.
    Disconnected(SendError<T>),
}

/// Budget-enforcing, accounting sender. Cloneable; clones share counters.
pub struct AccountedSender<T: WireSize> {
    tx: SyncSender<T>,
    counter: Arc<TrafficCounter>,
    /// Max payload bits per message (None = unconstrained, e.g. downlink).
    budget_bits: Option<usize>,
}

impl<T: WireSize> Clone for AccountedSender<T> {
    fn clone(&self) -> Self {
        AccountedSender {
            tx: self.tx.clone(),
            counter: self.counter.clone(),
            budget_bits: self.budget_bits,
        }
    }
}

impl<T: WireSize> AccountedSender<T> {
    pub fn new(tx: SyncSender<T>, budget_bits: Option<usize>) -> Self {
        AccountedSender { tx, counter: Arc::new(TrafficCounter::default()), budget_bits }
    }

    /// A sender sharing an existing counter — how the transport layer
    /// gives every worker its *own* per-message budget (heterogeneous
    /// `⌊n·R_i⌋`) while tallying all uplink traffic in one place.
    pub fn with_counter(
        tx: SyncSender<T>,
        counter: Arc<TrafficCounter>,
        budget_bits: Option<usize>,
    ) -> Self {
        AccountedSender { tx, counter, budget_bits }
    }

    /// Send with budget enforcement and accounting.
    pub fn send(&self, msg: T) -> Result<(), ChannelError<T>> {
        let payload = msg.payload_bits();
        if let Some(budget) = self.budget_bits {
            if payload > budget {
                self.counter.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(ChannelError::OverBudget { payload_bits: payload, budget_bits: budget });
            }
        }
        let overhead = msg.overhead_bits();
        // Count BEFORE the send: the mpsc channel's happens-before edge then
        // guarantees the receiver observes the updated counters for every
        // message it has received (counting after the send races with a
        // server that reads totals right after the final recv).
        self.counter.payload_bits.fetch_add(payload, Ordering::Relaxed);
        self.counter.overhead_bits.fetch_add(overhead, Ordering::Relaxed);
        self.counter.messages.fetch_add(1, Ordering::Relaxed);
        self.tx.send(msg).map_err(|e| {
            self.counter.payload_bits.fetch_sub(payload, Ordering::Relaxed);
            self.counter.overhead_bits.fetch_sub(overhead, Ordering::Relaxed);
            self.counter.messages.fetch_sub(1, Ordering::Relaxed);
            ChannelError::Disconnected(e)
        })?;
        Ok(())
    }

    pub fn counter(&self) -> Arc<TrafficCounter> {
        self.counter.clone()
    }
}

/// A lock-protected free list of reusable buffers. `put` returns a spent
/// buffer, `get_or` pops one (falling back to `make` only while the pool
/// is still warming up). The backing stack is preallocated, so steady-state
/// `get_or`/`put` pairs perform zero heap allocations.
pub struct BufferPool<T> {
    stack: Mutex<Vec<T>>,
}

impl<T> BufferPool<T> {
    pub fn with_capacity(cap: usize) -> Self {
        BufferPool { stack: Mutex::new(Vec::with_capacity(cap)) }
    }

    /// Pop a recycled buffer, or build a fresh one with `make`.
    pub fn get_or(&self, make: impl FnOnce() -> T) -> T {
        self.stack.lock().unwrap().pop().unwrap_or_else(make)
    }

    /// Return a spent buffer for reuse.
    pub fn put(&self, buf: T) {
        self.stack.lock().unwrap().push(buf);
    }

    /// Pop a recycled buffer if one is parked; `None` when the pool is
    /// empty (unlike [`BufferPool::get_or`], never builds a fresh one).
    pub fn try_get(&self) -> Option<T> {
        self.stack.lock().unwrap().pop()
    }

    /// Buffers currently parked in the pool.
    pub fn len(&self) -> usize {
        self.stack.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The coordinator's buffer-recycling loops (one instance per run, shared
/// via `Arc` between the server and all workers):
///
/// * `iterates` — broadcast iterate buffers: the server fills one per
///   worker per round; the worker returns it right after evaluating its
///   gradient (and *before* uploading, so by the time the server has
///   collected a round's uploads the pool holds `m` buffers again).
/// * `bytes` — uplink wire-byte buffers: the worker pops a spent buffer to
///   encode into; the server returns it after decoding.
///
/// Round 0 populates both pools (`m` allocations each); every later round
/// recycles. All buffers in a run share one `(n, R)` shape, so any worker
/// can reuse any returned buffer.
pub struct ChannelPools {
    pub iterates: BufferPool<Vec<f32>>,
    pub bytes: BufferPool<Vec<u8>>,
}

impl ChannelPools {
    pub fn new(workers: usize) -> Self {
        ChannelPools {
            iterates: BufferPool::with_capacity(2 * workers.max(1)),
            bytes: BufferPool::with_capacity(2 * workers.max(1)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::Upload;
    use crate::quant::Compressed;
    use std::sync::mpsc;

    fn upload(payload_bits: usize) -> Upload {
        Upload {
            round: 0,
            worker: 0,
            msg: Compressed {
                n: 10,
                bytes: vec![0; payload_bits.div_ceil(8)],
                payload_bits,
                side_bits: 32,
            },
            local_value: 0.0,
        }
    }

    #[test]
    fn buffer_pool_recycles_capacity() {
        let pool: BufferPool<Vec<u8>> = BufferPool::with_capacity(4);
        assert!(pool.is_empty());
        let mut b = pool.get_or(|| Vec::with_capacity(64));
        let ptr_cap = b.capacity();
        b.extend_from_slice(&[1, 2, 3]);
        pool.put(b);
        assert_eq!(pool.len(), 1);
        let b2 = pool.get_or(Vec::new);
        // same buffer comes back, capacity intact
        assert_eq!(b2.capacity(), ptr_cap);
        assert_eq!(b2, vec![1, 2, 3]);
    }

    #[test]
    fn within_budget_passes_and_counts() {
        let (tx, rx) = mpsc::sync_channel(8);
        let s = AccountedSender::new(tx, Some(100));
        s.send(upload(80)).unwrap();
        s.send(upload(100)).unwrap();
        assert_eq!(rx.try_iter().count(), 2);
        let c = s.counter();
        assert_eq!(c.payload_bits.load(Ordering::Relaxed), 180);
        assert_eq!(c.messages.load(Ordering::Relaxed), 2);
        assert_eq!(c.rejected.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn over_budget_rejected() {
        let (tx, rx) = mpsc::sync_channel(8);
        let s = AccountedSender::new(tx, Some(100));
        match s.send(upload(101)) {
            Err(ChannelError::OverBudget { payload_bits, budget_bits }) => {
                assert_eq!(payload_bits, 101);
                assert_eq!(budget_bits, 100);
            }
            other => panic!("expected OverBudget, got {other:?}"),
        }
        assert_eq!(rx.try_iter().count(), 0);
        assert_eq!(s.counter().rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn clones_share_counters() {
        let (tx, _rx) = mpsc::sync_channel(8);
        let s = AccountedSender::new(tx, None);
        let s2 = s.clone();
        s.send(upload(50)).unwrap();
        s2.send(upload(70)).unwrap();
        assert_eq!(s.counter().payload_bits.load(Ordering::Relaxed), 120);
    }

    #[test]
    fn disconnected_receiver_reported() {
        let (tx, rx) = mpsc::sync_channel(8);
        drop(rx);
        let s = AccountedSender::new(tx, None);
        assert!(matches!(s.send(upload(1)), Err(ChannelError::Disconnected(_))));
    }
}
