//! Pluggable wire transport for the coordinator — the seam between the
//! paper's *algorithm* (what crosses the wire: `⌊n·R_i⌋`-bit quantized
//! descent directions) and the *network* that carries it.
//!
//! A transport owns message delivery, byte accounting and buffer
//! recycling on both sides of the star:
//!
//! ```text
//!            ┌────────────────────── server thread ─────────────────────┐
//!            │ server_loop ── broadcast(w, ·) ─┐   ┌─ recv() → Arrival  │
//!            └─────────────────────────────────┼───┼────────────────────┘
//!                                    [`ServerTransport`]
//!                                              │   │
//!                     InProc │ SimNet │ Recorded │ Replay
//!                                              │   │
//!            ┌─────────────────────────────────┼───┼────────────────────┐
//!            │ worker_loop ←─ recv_broadcast() ─┘   └── upload(Upload)  │
//!            └────────────────────── worker threads ────────────────────┘
//! ```
//!
//! Three live implementations plus a replay source:
//!
//! * [`inproc`] — today's pooled, bounded `sync_channel`s; bit-identical
//!   to the pre-transport coordinator and allocation-free in steady state.
//! * [`simnet`] — a deterministic, seeded network model: per-link base
//!   latency, jitter, drop probability and bandwidth, composed over a
//!   [`Topology`] (star / chain / tree) that multiplies hops. Arrival
//!   times are *simulated* (virtual µs) and computed from
//!   `(seed, round, worker)` alone, so every straggler/lossy-link
//!   schedule is exactly reproducible regardless of thread scheduling.
//! * [`recorded`] — wraps the channel transport and serializes every wire
//!   frame (broadcasts and uploads) to a trace file; [`recorded::replay`]
//!   re-feeds a trace into a server loop with no workers at all and
//!   reproduces the original server iterates bit-for-bit.
//!
//! **Lockstep with logical stragglers.** Every worker answers every
//! broadcast exactly once, so the server always collects `m` frames per
//! round and the buffer-recycling protocol of
//! [`ChannelPools`](crate::coordinator::channel::ChannelPools) is
//! preserved. Straggling and loss are *logical*: each frame carries a
//! simulated arrival tag ([`Arrival::at`]; `None` = lost by the link),
//! and the [`Participation`] policy decides which delivered frames the
//! server actually aggregates. This keeps rounds deadlock-free and
//! deterministic while still modeling k-of-m and deadline aggregation.

pub mod inproc;
pub mod recorded;
pub mod simnet;

use std::sync::mpsc::SendError;
use std::sync::Arc;

use crate::coordinator::channel::{ChannelError, ChannelPools, TrafficCounter};
use crate::coordinator::protocol::{Broadcast, Upload, WireSize};

pub use recorded::replay;
pub use simnet::{LinkModel, SimNetConfig, Topology};

/// Simulated network time, in microseconds. Virtual — no wall clock is
/// ever consulted, which is what makes SimNet schedules reproducible.
pub type SimTime = u64;

/// One uplink frame as the server receives it: the payload plus the
/// transport's delivery verdict.
#[derive(Debug)]
pub struct Arrival {
    pub up: Upload,
    /// Simulated arrival time at the server; `None` = the link lost the
    /// frame (the bits were still spent — they are counted at send).
    pub at: Option<SimTime>,
}

impl WireSize for Arrival {
    fn payload_bits(&self) -> usize {
        self.up.payload_bits()
    }

    fn overhead_bits(&self) -> usize {
        // The arrival tag is simulation metadata, not wire data.
        self.up.overhead_bits()
    }
}

/// Transport-level failure.
#[derive(Debug)]
pub enum TransportError {
    /// The peer(s) hung up.
    Disconnected,
    /// Trace-file I/O failed (Recorded/Replay only).
    Io(String),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Disconnected => write!(f, "transport peer disconnected"),
            TransportError::Io(e) => write!(f, "transport I/O error: {e}"),
        }
    }
}

/// Server-side endpoint: broadcast delivery, upload collection, and the
/// run's shared buffer pools / traffic counters.
pub trait ServerTransport: Send {
    /// Number of workers this transport was built for.
    fn workers(&self) -> usize;

    /// Deliver the round's broadcast to worker `w`. The iterate buffer
    /// inside `b` comes from [`ServerTransport::pools`] and is returned
    /// there by the worker.
    fn broadcast(&mut self, worker: usize, b: Broadcast) -> Result<(), TransportError>;

    /// Block for the next uplink frame (delivered or dropped — the server
    /// receives exactly one frame per worker per round).
    fn recv(&mut self) -> Result<Arrival, TransportError>;

    /// The run's buffer-recycling pools, shared with every worker.
    fn pools(&self) -> &Arc<ChannelPools>;

    /// Shared uplink traffic counters (payload/overhead/messages/rejects).
    fn traffic(&self) -> Arc<TrafficCounter>;

    /// End the run: close downlinks so workers exit, flush trace files.
    fn finish(&mut self) {}
}

/// Worker-side endpoint.
pub trait WorkerTransport: Send {
    /// Block for the next broadcast; `None` = server closed the downlink.
    fn recv_broadcast(&mut self) -> Option<Broadcast>;

    /// Send one uplink frame. Budget enforcement (this worker's
    /// `⌊n·R_i⌋`) happens here; an over-budget payload is rejected.
    fn upload(&mut self, up: Upload) -> Result<(), ChannelError<Upload>>;
}

/// Which of a round's delivered uploads the server aggregates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Participation {
    /// Every delivered upload (classic full participation).
    Full,
    /// The `k` earliest-arriving delivered uploads (count-triggered
    /// k-of-m; ties broken by a seeded per-round ranking, so on a
    /// zero-latency transport this is a uniformly random k-subset).
    KofM { k: usize },
    /// Delivered uploads arriving within `us` simulated microseconds
    /// (deadline-triggered). On a zero-latency transport everything
    /// arrives at t = 0, so any deadline degrades to full participation.
    Deadline { us: SimTime },
}

impl Participation {
    /// Parse `full`, `k:<count>` or `deadline:<µs>`.
    pub fn parse(s: &str) -> Option<Participation> {
        let t = s.to_ascii_lowercase();
        if t == "full" {
            return Some(Participation::Full);
        }
        if let Some(v) = t.strip_prefix("k:") {
            return v.parse().ok().map(|k| Participation::KofM { k });
        }
        if let Some(v) = t.strip_prefix("deadline:").or_else(|| t.strip_prefix("dl:")) {
            return v.parse().ok().map(|us| Participation::Deadline { us });
        }
        None
    }
}

impl std::fmt::Display for Participation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Participation::Full => write!(f, "full"),
            Participation::KofM { k } => write!(f, "k:{k}"),
            Participation::Deadline { us } => write!(f, "deadline:{us}"),
        }
    }
}

/// Which transport a run uses (the config surface of this module).
#[derive(Clone, Debug)]
pub enum TransportKind {
    /// In-process bounded channels (bit-identical to the legacy path).
    InProc,
    /// Deterministic seeded latency/jitter/drop/bandwidth model.
    SimNet(SimNetConfig),
    /// Record every wire frame to `path` while running over in-process
    /// channels (`net: None`) or the given network model.
    Recorded { path: String, net: Option<SimNetConfig> },
}

impl TransportKind {
    /// Short human-readable tag for run summaries.
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::SimNet(_) => "simnet",
            TransportKind::Recorded { .. } => "recorded",
        }
    }
}

/// SplitMix64-style mix of `(seed, round, worker)` — an allocation-free
/// stand-in for a per-round random permutation: distinct workers get
/// distinct pseudo-random ranks, so sorting by rank yields a uniformly
/// random order among equal arrival times.
pub(crate) fn round_rank(seed: u64, round: u64, worker: usize) -> u64 {
    let mut z = seed
        ^ round.wrapping_mul(0x9E3779B97F4A7C15)
        ^ (worker as u64).wrapping_mul(0xA24BAED4963EE407);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Apply the participation policy to one round's `m` arrivals.
///
/// Reorders `arrivals` in place (no allocation) so that the selected
/// participants occupy the prefix, **sorted by worker id** — the
/// deterministic accumulation order the decode step requires — and
/// returns the participant count. Dropped frames always sort to the
/// back; ties in arrival time are broken by [`round_rank`], making
/// `KofM` on a zero-latency transport a uniformly random k-subset.
pub fn select_participants(
    arrivals: &mut [Arrival],
    policy: Participation,
    round: u64,
    seed: u64,
) -> usize {
    arrivals.sort_unstable_by_key(|a| match a.at {
        Some(at) => (0u8, at, round_rank(seed, round, a.up.worker)),
        None => (1u8, 0, 0),
    });
    let delivered = arrivals.iter().take_while(|a| a.at.is_some()).count();
    let p = match policy {
        Participation::Full => delivered,
        Participation::KofM { k } => delivered.min(k),
        Participation::Deadline { us } => arrivals[..delivered]
            .iter()
            .take_while(|a| a.at.unwrap_or(SimTime::MAX) <= us)
            .count(),
    };
    arrivals[..p].sort_unstable_by_key(|a| a.up.worker);
    p
}

/// Build the server endpoint plus one worker endpoint per budget entry.
///
/// `budgets[i]` is worker `i`'s per-message payload cap in bits
/// (`⌊n·R_i⌋`; `None` = unconstrained, the fp32 reference). All workers
/// share one traffic counter and one set of buffer pools.
pub fn build(
    kind: &TransportKind,
    budgets: &[Option<usize>],
) -> (Box<dyn ServerTransport>, Vec<Box<dyn WorkerTransport>>) {
    match kind {
        TransportKind::InProc => inproc::build(budgets),
        TransportKind::SimNet(net) => simnet::build(net, budgets),
        TransportKind::Recorded { path, net } => recorded::build(path, net.as_ref(), budgets),
    }
}

/// Map a channel-layer error on an [`Arrival`] back to the [`Upload`] the
/// worker handed in (the worker loop matches on `ChannelError<Upload>`).
pub(crate) fn demote_err(e: ChannelError<Arrival>) -> ChannelError<Upload> {
    match e {
        ChannelError::OverBudget { payload_bits, budget_bits } => {
            ChannelError::OverBudget { payload_bits, budget_bits }
        }
        ChannelError::Disconnected(SendError(arr)) => {
            ChannelError::Disconnected(SendError(arr.up))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Compressed;

    fn arrival(worker: usize, at: Option<SimTime>) -> Arrival {
        Arrival {
            up: Upload {
                round: 0,
                worker,
                msg: Compressed { n: 4, bytes: vec![0; 2], payload_bits: 10, side_bits: 0 },
                local_value: 0.0,
            },
            at,
        }
    }

    #[test]
    fn full_selects_all_delivered_in_worker_order() {
        let mut arr =
            vec![arrival(3, Some(5)), arrival(0, Some(1)), arrival(2, None), arrival(1, Some(9))];
        let p = select_participants(&mut arr, Participation::Full, 0, 42);
        assert_eq!(p, 3);
        let ids: Vec<usize> = arr[..p].iter().map(|a| a.up.worker).collect();
        assert_eq!(ids, vec![0, 1, 3]);
        assert_eq!(arr[3].up.worker, 2); // dropped frame parked at the back
    }

    #[test]
    fn kofm_takes_earliest_arrivals() {
        let mut arr = vec![
            arrival(0, Some(100)),
            arrival(1, Some(1)),
            arrival(2, Some(50)),
            arrival(3, Some(2)),
        ];
        let p = select_participants(&mut arr, Participation::KofM { k: 2 }, 0, 7);
        assert_eq!(p, 2);
        let ids: Vec<usize> = arr[..p].iter().map(|a| a.up.worker).collect();
        assert_eq!(ids, vec![1, 3]); // earliest two, re-sorted by worker id
    }

    #[test]
    fn kofm_tie_break_is_seeded_and_round_dependent() {
        // All arrivals at t = 0: the k-subset must be a deterministic
        // function of (seed, round) and actually vary with the round.
        let select = |round: u64, seed: u64| -> Vec<usize> {
            let mut arr: Vec<Arrival> = (0..8).map(|w| arrival(w, Some(0))).collect();
            let p = select_participants(&mut arr, Participation::KofM { k: 3 }, round, seed);
            arr[..p].iter().map(|a| a.up.worker).collect()
        };
        assert_eq!(select(0, 1), select(0, 1), "same (round, seed) must repeat");
        let distinct: std::collections::BTreeSet<Vec<usize>> =
            (0..16).map(|r| select(r, 1)).collect();
        assert!(distinct.len() > 1, "selection never varied across rounds");
    }

    #[test]
    fn deadline_filters_by_sim_time() {
        let mut arr = vec![
            arrival(0, Some(100)),
            arrival(1, Some(10)),
            arrival(2, None),
            arrival(3, Some(11)),
        ];
        let p = select_participants(&mut arr, Participation::Deadline { us: 50 }, 3, 9);
        assert_eq!(p, 2);
        let ids: Vec<usize> = arr[..p].iter().map(|a| a.up.worker).collect();
        assert_eq!(ids, vec![1, 3]);
    }

    #[test]
    fn participation_parse_roundtrip() {
        assert_eq!(Participation::parse("full"), Some(Participation::Full));
        assert_eq!(Participation::parse("k:3"), Some(Participation::KofM { k: 3 }));
        assert_eq!(
            Participation::parse("deadline:500"),
            Some(Participation::Deadline { us: 500 })
        );
        assert_eq!(Participation::parse("dl:500"), Some(Participation::Deadline { us: 500 }));
        assert_eq!(Participation::parse("bogus"), None);
        let all =
            [Participation::Full, Participation::KofM { k: 4 }, Participation::Deadline { us: 9 }];
        for p in all {
            assert_eq!(Participation::parse(&p.to_string()), Some(p));
        }
    }
}
