//! In-process transport: bounded, budget-enforced `sync_channel`s with
//! pooled buffer recycling — exactly the pre-transport coordinator path,
//! now behind the [`ServerTransport`]/[`WorkerTransport`] seam.
//!
//! Channels are *bounded* (ring buffers allocated once at setup): workers
//! send at most one upload per round, so `2m` uplink slots and 2 downlink
//! slots per worker never fill, and steady-state sends touch no heap.
//! Every frame is delivered instantly (`at = Some(0)`), so under
//! [`Participation::Full`](crate::coordinator::transport::Participation)
//! the behavior — and the bits — are identical to the legacy coordinator;
//! `rust/tests/test_alloc.rs` holds this transport to zero steady-state
//! allocations per round.

use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::Arc;

use crate::coordinator::channel::{AccountedSender, ChannelError, ChannelPools, TrafficCounter};
use crate::coordinator::protocol::{Broadcast, Upload};

use super::{demote_err, Arrival, ServerTransport, TransportError, WorkerTransport};

/// Server half of the channel transport (shared by InProc, SimNet and
/// Recorded — they differ only in what the *worker* side stamps on each
/// frame and in what gets written to disk).
pub(crate) struct ChannelServer {
    down_txs: Vec<SyncSender<Broadcast>>,
    up_rx: Receiver<Arrival>,
    pools: Arc<ChannelPools>,
    traffic: Arc<TrafficCounter>,
}

impl ServerTransport for ChannelServer {
    fn workers(&self) -> usize {
        self.down_txs.len()
    }

    fn broadcast(&mut self, worker: usize, b: Broadcast) -> Result<(), TransportError> {
        self.down_txs[worker].send(b).map_err(|_| TransportError::Disconnected)
    }

    fn recv(&mut self) -> Result<Arrival, TransportError> {
        self.up_rx.recv().map_err(|_| TransportError::Disconnected)
    }

    fn pools(&self) -> &Arc<ChannelPools> {
        &self.pools
    }

    fn traffic(&self) -> Arc<TrafficCounter> {
        self.traffic.clone()
    }

    fn finish(&mut self) {
        // Dropping the downlink senders closes every worker's receive
        // loop; the scoped-thread join in `run_distributed` does the rest.
        self.down_txs.clear();
    }
}

/// Worker half: instant, reliable delivery (`at = 0`).
pub struct InProcWorker {
    pub(crate) down_rx: Receiver<Broadcast>,
    pub(crate) up_tx: AccountedSender<Arrival>,
}

impl WorkerTransport for InProcWorker {
    fn recv_broadcast(&mut self) -> Option<Broadcast> {
        self.down_rx.recv().ok()
    }

    fn upload(&mut self, up: Upload) -> Result<(), ChannelError<Upload>> {
        self.up_tx.send(Arrival { up, at: Some(0) }).map_err(demote_err)
    }
}

/// Wire up the shared channel fabric: one bounded downlink per worker,
/// one shared bounded uplink, per-worker budget enforcement, one traffic
/// counter and one set of buffer pools for the whole run.
pub(crate) fn channel_fabric(
    budgets: &[Option<usize>],
) -> (ChannelServer, Vec<InProcWorker>) {
    let m = budgets.len();
    // Workers send at most one upload per round: 2m slots never fill.
    let (up_tx, up_rx) = mpsc::sync_channel::<Arrival>(2 * m.max(1));
    let traffic = Arc::new(TrafficCounter::default());
    let pools = Arc::new(ChannelPools::new(m));
    let mut down_txs = Vec::with_capacity(m);
    let mut workers = Vec::with_capacity(m);
    for &budget in budgets {
        // At most one broadcast is in flight per worker: 2 slots suffice.
        let (down_tx, down_rx) = mpsc::sync_channel::<Broadcast>(2);
        down_txs.push(down_tx);
        workers.push(InProcWorker {
            down_rx,
            up_tx: AccountedSender::with_counter(up_tx.clone(), traffic.clone(), budget),
        });
    }
    // The prototype sender drops here: only worker-held clones remain, so
    // a dead worker set is observable as a closed channel, not a deadlock.
    drop(up_tx);
    (ChannelServer { down_txs, up_rx, pools, traffic }, workers)
}

/// Build the in-process transport for `budgets.len()` workers.
pub fn build(
    budgets: &[Option<usize>],
) -> (Box<dyn ServerTransport>, Vec<Box<dyn WorkerTransport>>) {
    let (server, workers) = channel_fabric(budgets);
    (
        Box::new(server),
        workers.into_iter().map(|w| Box::new(w) as Box<dyn WorkerTransport>).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Compressed;

    fn upload(worker: usize, payload_bits: usize) -> Upload {
        Upload {
            round: 0,
            worker,
            msg: Compressed {
                n: 8,
                bytes: vec![0; payload_bits.div_ceil(8)],
                payload_bits,
                side_bits: 0,
            },
            local_value: 0.0,
        }
    }

    #[test]
    fn per_worker_budgets_are_enforced_independently() {
        let (mut server, mut workers) = channel_fabric(&[Some(8), Some(64)]);
        // Worker 0 (8-bit cap) rejects a 16-bit payload; worker 1 accepts.
        match workers[0].upload(upload(0, 16)) {
            Err(ChannelError::OverBudget { payload_bits, budget_bits }) => {
                assert_eq!((payload_bits, budget_bits), (16, 8));
            }
            other => panic!("expected OverBudget, got {other:?}"),
        }
        workers[1].upload(upload(1, 16)).unwrap();
        let a = server.recv().unwrap();
        assert_eq!(a.up.worker, 1);
        assert_eq!(a.at, Some(0));
        let t = server.traffic();
        assert_eq!(t.payload_bits.load(std::sync::atomic::Ordering::Relaxed), 16);
        assert_eq!(t.rejected.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn finish_closes_worker_downlinks() {
        let (mut server, mut workers) = channel_fabric(&[None]);
        server.broadcast(0, Broadcast { round: 0, iterate: vec![0.0; 4] }).unwrap();
        assert!(workers[0].recv_broadcast().is_some());
        server.finish();
        assert!(workers[0].recv_broadcast().is_none());
    }
}
