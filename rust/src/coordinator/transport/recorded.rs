//! Recorded transport + trace replay.
//!
//! [`build`] wraps the channel fabric (ideal, or a
//! [`SimNetConfig`](super::SimNetConfig) model on the worker side) and
//! serializes the run's wire frames — one broadcast per round with its
//! full fp32 iterate (the downlink content is identical for all `m`
//! workers, so one copy is the complete record), and **every** upload
//! with its exact wire bytes, bit accounting, and simulated arrival
//! tag — to a trace file in the
//! [`protocol`](crate::coordinator::protocol) trace format.
//!
//! [`replay`] is the other half: it loads a trace and acts as a
//! [`ServerTransport`] with *no workers at all* — `recv` hands back the
//! recorded uploads in their recorded order, `broadcast` is a sink — so
//! running the ordinary server loop over it reproduces the original
//! server iterates bit-for-bit (`rust/tests/test_transport.rs`). That
//! makes a trace file a complete, inspectable witness of a distributed
//! run: what crossed the wire is sufficient to re-derive every iterate.
//!
//! Recording buffers through a `BufWriter` and is explicitly *not*
//! allocation-free; the zero-allocation contract applies to the InProc
//! hot path only.

use std::fs::File;
use std::io::{BufReader, BufWriter, Write};
use std::sync::Arc;

use crate::coordinator::channel::{ChannelPools, TrafficCounter};
use crate::coordinator::protocol::{
    read_trace_frame, read_trace_header, write_broadcast_frame, write_trace_header,
    write_upload_frame, Broadcast, TraceFrame, WireSize,
};

use super::inproc::{channel_fabric, ChannelServer};
use super::simnet::SimNetConfig;
use super::{Arrival, ServerTransport, TransportError, WorkerTransport};

/// Server endpoint that forwards to the channel fabric while writing
/// every frame it touches to the trace file.
struct RecordedServer {
    inner: ChannelServer,
    writer: BufWriter<File>,
    path: String,
}

impl ServerTransport for RecordedServer {
    fn workers(&self) -> usize {
        self.inner.workers()
    }

    fn broadcast(&mut self, worker: usize, b: Broadcast) -> Result<(), TransportError> {
        // The round's iterate is identical for every worker, so one
        // broadcast frame per round carries the full downlink content —
        // recording all m copies would multiply the trace by m (~1 GB at
        // transformer scale) for bytes the replay discards anyway.
        if worker == 0 {
            write_broadcast_frame(&mut self.writer, worker, &b)
                .map_err(|e| TransportError::Io(format!("{}: {e}", self.path)))?;
        }
        self.inner.broadcast(worker, b)
    }

    fn recv(&mut self) -> Result<Arrival, TransportError> {
        let a = self.inner.recv()?;
        write_upload_frame(&mut self.writer, &a.up, a.at)
            .map_err(|e| TransportError::Io(format!("{}: {e}", self.path)))?;
        Ok(a)
    }

    fn pools(&self) -> &Arc<ChannelPools> {
        self.inner.pools()
    }

    fn traffic(&self) -> Arc<TrafficCounter> {
        self.inner.traffic()
    }

    fn finish(&mut self) {
        if let Err(e) = self.writer.flush() {
            eprintln!("recorded transport: could not flush {}: {e}", self.path);
        }
        self.inner.finish();
    }
}

/// Build a recording transport writing to `path`. Worker endpoints come
/// from `net` when given (record a straggler/lossy scenario) and are
/// plain in-process endpoints otherwise. Panics if the trace file cannot
/// be created — a run that silently records nothing would be worse.
pub fn build(
    path: &str,
    net: Option<&SimNetConfig>,
    budgets: &[Option<usize>],
) -> (Box<dyn ServerTransport>, Vec<Box<dyn WorkerTransport>>) {
    let file = File::create(path)
        .unwrap_or_else(|e| panic!("recorded transport: cannot create '{path}': {e}"));
    let mut writer = BufWriter::new(file);
    write_trace_header(&mut writer, budgets.len())
        .unwrap_or_else(|e| panic!("recorded transport: cannot write '{path}': {e}"));

    let (inner, inproc_workers) = channel_fabric(budgets);
    let workers: Vec<Box<dyn WorkerTransport>> = match net {
        None => inproc_workers
            .into_iter()
            .map(|w| Box::new(w) as Box<dyn WorkerTransport>)
            .collect(),
        Some(cfg) => inproc_workers
            .into_iter()
            .enumerate()
            .map(|(i, inner)| super::simnet::wrap_worker(inner, i, cfg))
            .collect(),
    };
    (Box::new(RecordedServer { inner, writer, path: path.to_string() }), workers)
}

/// Replay server: a [`ServerTransport`] whose "network" is a recorded
/// trace. No workers exist; broadcasts return their buffer to the pool,
/// and `recv` streams the recorded uploads in order straight off the
/// reader — O(1) residency even for transformer-scale traces (the
/// uploads are consumed strictly in recorded order, so nothing needs to
/// be buffered).
pub struct ReplayServer {
    workers: usize,
    reader: BufReader<File>,
    path: String,
    pools: Arc<ChannelPools>,
    traffic: Arc<TrafficCounter>,
}

impl ServerTransport for ReplayServer {
    fn workers(&self) -> usize {
        self.workers
    }

    fn broadcast(&mut self, _worker: usize, b: Broadcast) -> Result<(), TransportError> {
        // Return the iterate buffer straight to the pool: the recycling
        // protocol expects the "worker" to hand it back each round.
        self.pools.iterates.put(b.iterate);
        Ok(())
    }

    fn recv(&mut self) -> Result<Arrival, TransportError> {
        loop {
            match read_trace_frame(&mut self.reader) {
                Ok(Some(TraceFrame::Broadcast { .. })) => continue, // re-derived by the server
                Ok(Some(TraceFrame::Upload { up, at })) => {
                    // No workers exist to drain the bytes pool the server
                    // refills after each decode; discard one parked
                    // buffer per streamed frame so replay residency stays
                    // O(m) instead of growing by rounds × m buffers.
                    drop(self.pools.bytes.try_get());
                    let a = Arrival { up, at };
                    // Mirror the live accounting (counted at worker
                    // send): replayed totals must match the recorded
                    // run's.
                    self.traffic
                        .payload_bits
                        .fetch_add(a.payload_bits(), std::sync::atomic::Ordering::Relaxed);
                    self.traffic
                        .overhead_bits
                        .fetch_add(a.overhead_bits(), std::sync::atomic::Ordering::Relaxed);
                    self.traffic.messages.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    return Ok(a);
                }
                // Clean EOF gets its own diagnosis: "all workers
                // disconnected" would be nonsense for a run with no
                // workers — the trace simply has fewer rounds than the
                // replaying config asked for.
                Ok(None) => {
                    return Err(TransportError::Io(format!(
                        "{}: trace exhausted (recorded run had fewer rounds than cfg.rounds)",
                        self.path
                    )))
                }
                Err(e) => return Err(TransportError::Io(format!("{}: {e}", self.path))),
            }
        }
    }

    fn pools(&self) -> &Arc<ChannelPools> {
        &self.pools
    }

    fn traffic(&self) -> Arc<TrafficCounter> {
        self.traffic.clone()
    }
}

/// Open a trace for (streaming) replay. Broadcast records are skipped on
/// the fly (the replaying server re-derives every iterate itself —
/// matching them bit-for-bit is exactly what the replay test asserts).
pub fn replay(path: &str) -> std::io::Result<ReplayServer> {
    let mut reader = BufReader::new(File::open(path)?);
    let workers = read_trace_header(&mut reader)?;
    Ok(ReplayServer {
        workers,
        reader,
        path: path.to_string(),
        pools: Arc::new(ChannelPools::new(workers)),
        traffic: Arc::new(TrafficCounter::default()),
    })
}
