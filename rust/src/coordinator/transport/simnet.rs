//! SimNet: a deterministic, seeded network model over the in-process
//! channel fabric.
//!
//! Physically every frame still crosses a bounded `sync_channel`
//! (workers stay in lockstep; no real packet is ever lost), but each
//! uplink frame is stamped with a *simulated* delivery verdict computed
//! purely from `(seed, round, worker)` and the link/topology parameters:
//!
//! * **latency** — per-hop base latency plus seeded uniform jitter plus a
//!   serialization delay of `wire_bits / bandwidth`;
//! * **loss** — an independent per-hop Bernoulli drop;
//! * **topology** — [`Topology`] maps a worker to its hop count to the
//!   server (star = 1, chain = `i + 1`, tree = depth), so latency adds up
//!   and loss compounds exactly as a multi-hop route would.
//!
//! Because no wall clock and no cross-round RNG state are involved, a
//! SimNet schedule is bitwise reproducible from its seed regardless of
//! thread scheduling — `rust/tests/test_transport.rs` asserts this — and
//! the **ideal** configuration (zero latency, zero jitter, zero drops,
//! infinite bandwidth) consumes no randomness at all, making it
//! bit-identical to [`super::inproc`] (`rust/tests/test_determinism.rs`).
//!
//! Only the uplink — the budget-constrained direction in the paper — is
//! modeled; broadcasts stay instant and reliable (a lost broadcast would
//! stall the lockstep round structure, which is a liveness concern, not a
//! quantization one).

use crate::coordinator::channel::ChannelError;
use crate::coordinator::protocol::{Broadcast, Upload, WireSize};
use crate::linalg::rng::Rng;

use super::inproc::{channel_fabric, InProcWorker};
use super::{demote_err, round_rank, Arrival, ServerTransport, SimTime, WorkerTransport};

/// One (directed) link's delay/loss model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkModel {
    /// Fixed propagation delay per hop, in simulated µs.
    pub base_latency_us: u64,
    /// Per-hop jitter: uniform in `[0, jitter_us]` simulated µs.
    pub jitter_us: u64,
    /// Per-hop frame loss probability in `[0, 1)`.
    pub drop_prob: f32,
    /// Link bandwidth in bits per simulated µs (`0` = infinite, no
    /// serialization delay).
    pub bandwidth_bits_per_us: f32,
}

impl LinkModel {
    /// Instant, reliable, infinite-bandwidth link (the InProc-equivalent).
    pub const IDEAL: LinkModel = LinkModel {
        base_latency_us: 0,
        jitter_us: 0,
        drop_prob: 0.0,
        bandwidth_bits_per_us: 0.0,
    };
}

impl Default for LinkModel {
    fn default() -> Self {
        LinkModel::IDEAL
    }
}

/// Network shape: how many hops worker `i`'s uplink traffic traverses.
///
/// The first three shapes are server-rooted (the coordinator path);
/// `Ring`, `Torus` and `Random` are peer shapes consumed by the mesh
/// engine ([`crate::mesh`]) through [`Topology::mesh_edges`]. Every
/// shape also answers [`Topology::mesh_edges`] as a peer graph (node 0
/// takes the root seat), so the mesh engine accepts the whole grammar.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Server star: every worker one hop from the server.
    Star,
    /// Daisy chain: worker `i` relays through all earlier workers
    /// (`i + 1` hops) — the worst-case straggler shape.
    Chain,
    /// Complete `fanout`-ary tree rooted at the server; hops = the
    /// worker's depth (`fanout` is clamped to ≥ 2).
    Tree { fanout: usize },
    /// Peer ring: node `i` links to `i ± 1 (mod m)`. Needs `m ≥ 3` so
    /// the two neighbors are distinct ([`Topology::validate`]).
    Ring,
    /// Peer `rows × cols` torus, wrapping in both axes. Each axis
    /// needs ≥ 3 nodes (distinct wrap edges) and `rows·cols` must
    /// equal the node count exactly ([`Topology::validate`]).
    Torus { rows: usize, cols: usize },
    /// Seeded Erdős–Rényi overlay on a ring backbone: every non-ring
    /// pair is linked with probability `p`, drawn from a pure
    /// `(seed, i, j)` hash. The backbone keeps the graph connected at
    /// any `m ≥ 3`. The probability is stored as raw `f32` bits so the
    /// enum stays `Copy + Eq`.
    Random { p_bits: u32 },
}

impl Topology {
    /// A `Random` shape with edge probability `p` (see [`Topology::Random`]).
    pub fn random(p: f32) -> Topology {
        Topology::Random { p_bits: p.to_bits() }
    }

    /// Hop count from worker `worker` to the server.
    pub fn hops(self, worker: usize) -> u32 {
        match self {
            Topology::Star => 1,
            Topology::Chain => worker as u32 + 1,
            Topology::Tree { fanout } => {
                let f = fanout.max(2) as u64;
                let mut depth = 1u32;
                let mut level_start = 0u64;
                let mut level_size = f;
                let w = worker as u64;
                while w >= level_start + level_size {
                    level_start += level_size;
                    level_size = level_size.saturating_mul(f);
                    depth += 1;
                }
                depth
            }
            // Peer shapes have no server root; if one is used on the
            // coordinator uplink path anyway, every worker is one peer
            // hop from the collector.
            Topology::Ring | Topology::Torus { .. } | Topology::Random { .. } => 1,
        }
    }

    /// Whether this shape is well-formed over `workers` nodes — a
    /// config error, never a panic, at degenerate sizes. Server-rooted
    /// shapes accept any count; peer shapes need their wrap-around
    /// edges distinct, and a torus must tile the node count exactly.
    pub fn validate(self, workers: usize) -> Result<(), String> {
        match self {
            Topology::Star | Topology::Chain | Topology::Tree { .. } => Ok(()),
            Topology::Ring => {
                if workers < 3 {
                    Err(format!("ring topology needs at least 3 nodes, got {workers}"))
                } else {
                    Ok(())
                }
            }
            Topology::Torus { rows, cols } => {
                if rows < 3 || cols < 3 {
                    Err(format!("torus axes need at least 3 nodes each, got {rows}x{cols}"))
                } else if rows * cols != workers {
                    Err(format!("torus {rows}x{cols} tiles {} nodes, got {workers}", rows * cols))
                } else {
                    Ok(())
                }
            }
            Topology::Random { p_bits } => {
                let p = f32::from_bits(p_bits);
                if !(0.0..=1.0).contains(&p) {
                    Err(format!("random-graph probability must lie in [0, 1], got {p}"))
                } else if workers < 3 {
                    Err(format!("random-graph topology needs at least 3 nodes, got {workers}"))
                } else {
                    Ok(())
                }
            }
        }
    }

    /// Undirected peer edges `(i, j)` with `i < j`, sorted, over `m`
    /// nodes. Server-rooted shapes become peer graphs with node 0 in
    /// the root seat (star hub, chain head, heap-order tree root).
    /// `Random` draws each non-backbone pair from a pure `(seed, i, j)`
    /// hash on top of the connecting ring backbone, so equal seeds
    /// always yield the same overlay. Call [`Topology::validate`]
    /// first; the edge set of a degenerate shape is unspecified.
    pub fn mesh_edges(self, m: usize, seed: u64) -> Vec<(usize, usize)> {
        let mut edges: Vec<(usize, usize)> = Vec::new();
        match self {
            Topology::Star => edges.extend((1..m).map(|i| (0, i))),
            Topology::Chain => edges.extend((1..m).map(|i| (i - 1, i))),
            Topology::Tree { fanout } => {
                let f = fanout.max(2);
                edges.extend((1..m).map(|i| ((i - 1) / f, i)));
            }
            Topology::Ring => {
                for i in 0..m {
                    let j = (i + 1) % m;
                    edges.push((i.min(j), i.max(j)));
                }
            }
            Topology::Torus { rows, cols } => {
                let at = |r: usize, c: usize| r * cols + c;
                for r in 0..rows {
                    for c in 0..cols {
                        let i = at(r, c);
                        let right = at(r, (c + 1) % cols);
                        let down = at((r + 1) % rows, c);
                        edges.push((i.min(right), i.max(right)));
                        edges.push((i.min(down), i.max(down)));
                    }
                }
            }
            Topology::Random { p_bits } => {
                let p = f32::from_bits(p_bits);
                for i in 0..m {
                    let j = (i + 1) % m;
                    edges.push((i.min(j), i.max(j)));
                }
                for i in 0..m {
                    for j in (i + 2)..m {
                        if i == 0 && j == m - 1 {
                            continue; // backbone wrap edge, already present
                        }
                        let mut erng = Rng::seed_from(round_rank(seed, i as u64, j));
                        if erng.uniform_f32() < p {
                            edges.push((i, j));
                        }
                    }
                }
            }
        }
        edges.sort_unstable();
        edges.dedup();
        edges
    }

    /// Parse `star`, `chain`, `tree` (fanout 2), `tree:<fanout>`,
    /// `ring`, `torus:<rows>x<cols>` or `random:<p>` (alias
    /// `random-graph:<p>`, `p ∈ [0, 1]`).
    pub fn parse(s: &str) -> Option<Topology> {
        let t = s.to_ascii_lowercase();
        match t.as_str() {
            "star" => Some(Topology::Star),
            "chain" => Some(Topology::Chain),
            "tree" => Some(Topology::Tree { fanout: 2 }),
            "ring" => Some(Topology::Ring),
            _ => {
                if let Some(dims) = t.strip_prefix("torus:") {
                    let (r, c) = dims.split_once('x')?;
                    let rows: usize = r.parse().ok()?;
                    let cols: usize = c.parse().ok()?;
                    return Some(Topology::Torus { rows, cols });
                }
                if let Some(p) =
                    t.strip_prefix("random:").or_else(|| t.strip_prefix("random-graph:"))
                {
                    let p: f32 = p.parse().ok()?;
                    if !(0.0..=1.0).contains(&p) {
                        return None;
                    }
                    return Some(Topology::random(p));
                }
                let f: usize = t.strip_prefix("tree:")?.parse().ok()?;
                Some(Topology::Tree { fanout: f.max(2) })
            }
        }
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Topology::Star => write!(f, "star"),
            Topology::Chain => write!(f, "chain"),
            Topology::Tree { fanout } => write!(f, "tree:{fanout}"),
            Topology::Ring => write!(f, "ring"),
            Topology::Torus { rows, cols } => write!(f, "torus:{rows}x{cols}"),
            Topology::Random { p_bits } => write!(f, "random:{}", f32::from_bits(*p_bits)),
        }
    }
}

/// Full SimNet description: seed, shape, and per-worker uplink models.
#[derive(Clone, Debug)]
pub struct SimNetConfig {
    /// Schedule seed — two runs with equal seeds see identical latency,
    /// jitter and drop schedules.
    pub seed: u64,
    pub topology: Topology,
    /// Per-worker uplink models, cycled by worker index (`links[i % len]`)
    /// so a single entry means a uniform network and a short list encodes
    /// a repeating heterogeneity pattern. Empty = all-ideal.
    pub links: Vec<LinkModel>,
}

impl SimNetConfig {
    /// Zero-latency, zero-drop star — the InProc-equivalent baseline.
    pub fn ideal() -> Self {
        SimNetConfig { seed: 0, topology: Topology::Star, links: vec![LinkModel::IDEAL] }
    }

    /// Worker `w`'s uplink model.
    pub fn link(&self, w: usize) -> LinkModel {
        if self.links.is_empty() {
            LinkModel::IDEAL
        } else {
            self.links[w % self.links.len()]
        }
    }
}

/// Compute worker `worker`'s delivery verdict for one frame of `wire_bits`
/// bits in `round`: `None` if any hop drops it, else the summed simulated
/// arrival time. Pure in `(seed, round, worker, hops, link, wire_bits)`.
pub fn delivery(
    seed: u64,
    round: u64,
    worker: usize,
    hops: u32,
    link: &LinkModel,
    wire_bits: usize,
) -> Option<SimTime> {
    let transmit = if link.bandwidth_bits_per_us > 0.0 {
        (wire_bits as f64 / link.bandwidth_bits_per_us as f64).ceil() as u64
    } else {
        0
    };
    // A fresh per-(round, worker) stream: no cross-round RNG state, so
    // schedules cannot depend on thread interleaving. The ideal link
    // consumes no randomness at all.
    let mut lrng = Rng::seed_from(round_rank(seed, round, worker));
    let mut at: SimTime = 0;
    let mut lost = false;
    for _ in 0..hops {
        if link.drop_prob > 0.0 && lrng.uniform_f32() < link.drop_prob {
            lost = true;
        }
        // saturating_add: jitter_us = u64::MAX must not overflow into a
        // remainder-by-zero (the knob is CLI-exposed and unclamped).
        let jitter = if link.jitter_us > 0 {
            lrng.next_u64() % link.jitter_us.saturating_add(1)
        } else {
            0
        };
        at = at
            .saturating_add(link.base_latency_us)
            .saturating_add(jitter)
            .saturating_add(transmit);
    }
    if lost {
        None
    } else {
        Some(at)
    }
}

/// Worker endpoint: the in-process channel pair plus this worker's link
/// parameters; every upload gets its simulated delivery verdict stamped
/// before it enters the (budget-enforcing) channel.
pub struct SimNetWorker {
    inner: InProcWorker,
    worker: usize,
    seed: u64,
    hops: u32,
    link: LinkModel,
}

impl WorkerTransport for SimNetWorker {
    fn recv_broadcast(&mut self) -> Option<Broadcast> {
        self.inner.recv_broadcast()
    }

    fn upload(&mut self, up: Upload) -> Result<(), ChannelError<Upload>> {
        let wire_bits = up.payload_bits() + up.overhead_bits();
        let at = delivery(self.seed, up.round, self.worker, self.hops, &self.link, wire_bits);
        self.inner.up_tx.send(Arrival { up, at }).map_err(demote_err)
    }
}

/// Attach SimNet link semantics to an in-process worker endpoint (used
/// here and by the `Recorded` transport when it records a simulated net).
pub(crate) fn wrap_worker(
    inner: InProcWorker,
    worker: usize,
    net: &SimNetConfig,
) -> Box<dyn WorkerTransport> {
    Box::new(SimNetWorker {
        inner,
        worker,
        seed: net.seed,
        hops: net.topology.hops(worker),
        link: net.link(worker),
    })
}

/// Build the SimNet transport for `budgets.len()` workers.
pub fn build(
    net: &SimNetConfig,
    budgets: &[Option<usize>],
) -> (Box<dyn ServerTransport>, Vec<Box<dyn WorkerTransport>>) {
    let (server, workers) = channel_fabric(budgets);
    let workers = workers
        .into_iter()
        .enumerate()
        .map(|(i, inner)| wrap_worker(inner, i, net))
        .collect();
    (Box::new(server), workers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_hop_counts() {
        assert_eq!(Topology::Star.hops(0), 1);
        assert_eq!(Topology::Star.hops(9), 1);
        assert_eq!(Topology::Chain.hops(0), 1);
        assert_eq!(Topology::Chain.hops(3), 4);
        let t = Topology::Tree { fanout: 2 };
        // Workers 0-1 are children of the server (depth 1), 2-5 depth 2,
        // 6-13 depth 3.
        assert_eq!(t.hops(0), 1);
        assert_eq!(t.hops(1), 1);
        assert_eq!(t.hops(2), 2);
        assert_eq!(t.hops(5), 2);
        assert_eq!(t.hops(6), 3);
        assert_eq!(t.hops(13), 3);
    }

    #[test]
    fn topology_parse_roundtrip() {
        for t in [
            Topology::Star,
            Topology::Chain,
            Topology::Tree { fanout: 4 },
            Topology::Ring,
            Topology::Torus { rows: 3, cols: 4 },
            Topology::random(0.25),
        ] {
            assert_eq!(Topology::parse(&t.to_string()), Some(t));
        }
        assert_eq!(Topology::parse("tree"), Some(Topology::Tree { fanout: 2 }));
        assert_eq!(Topology::parse("random-graph:0.5"), Some(Topology::random(0.5)));
        assert_eq!(Topology::parse("mesh"), None);
        assert_eq!(Topology::parse("torus:3"), None, "torus needs <rows>x<cols>");
        assert_eq!(Topology::parse("random:1.5"), None, "p must lie in [0, 1]");
    }

    #[test]
    fn degenerate_peer_shapes_are_config_errors_not_panics() {
        // Ring below the minimum size.
        assert!(Topology::Ring.validate(2).is_err());
        assert!(Topology::Ring.validate(0).is_err());
        assert!(Topology::Ring.validate(3).is_ok());
        // Torus axes too short, or tiling the wrong worker count.
        assert!(Topology::Torus { rows: 2, cols: 3 }.validate(6).is_err());
        assert!(Topology::Torus { rows: 3, cols: 3 }.validate(8).is_err());
        assert!(Topology::Torus { rows: 3, cols: 3 }.validate(9).is_ok());
        // Random graph: too few nodes, or a probability outside [0, 1].
        assert!(Topology::random(0.3).validate(2).is_err());
        assert!(Topology::random(1.5).validate(9).is_err());
        assert!(Topology::random(0.3).validate(3).is_ok());
        // Server-rooted shapes accept any worker count.
        for m in [0, 1, 5] {
            assert!(Topology::Star.validate(m).is_ok());
            assert!(Topology::Chain.validate(m).is_ok());
            assert!(Topology::Tree { fanout: 2 }.validate(m).is_ok());
        }
    }

    #[test]
    fn mesh_edges_match_the_shape() {
        // Ring over m nodes: exactly m edges, all degrees 2.
        let ring = Topology::Ring.mesh_edges(5, 0);
        assert_eq!(ring, vec![(0, 1), (0, 4), (1, 2), (2, 3), (3, 4)]);
        // Torus 3×3: 2·9 = 18 distinct edges, all degrees 4.
        let torus = Topology::Torus { rows: 3, cols: 3 }.mesh_edges(9, 0);
        assert_eq!(torus.len(), 18);
        let mut deg = [0usize; 9];
        for &(a, b) in &torus {
            assert!(a < b);
            deg[a] += 1;
            deg[b] += 1;
        }
        assert!(deg.iter().all(|&d| d == 4));
        // Random overlay: p = 0 is exactly the ring backbone; p = 1 is
        // the complete graph; the draw is pure in the seed.
        assert_eq!(Topology::random(0.0).mesh_edges(6, 7), Topology::Ring.mesh_edges(6, 7));
        assert_eq!(Topology::random(1.0).mesh_edges(6, 7).len(), 6 * 5 / 2);
        assert_eq!(
            Topology::random(0.4).mesh_edges(8, 11),
            Topology::random(0.4).mesh_edges(8, 11)
        );
        // Server-rooted shapes as peer graphs: node 0 takes the root seat.
        assert_eq!(Topology::Star.mesh_edges(4, 0), vec![(0, 1), (0, 2), (0, 3)]);
        assert_eq!(Topology::Chain.mesh_edges(4, 0), vec![(0, 1), (1, 2), (2, 3)]);
        assert_eq!(
            Topology::Tree { fanout: 2 }.mesh_edges(5, 0),
            vec![(0, 1), (0, 2), (1, 3), (1, 4)]
        );
    }

    #[test]
    fn ideal_link_is_instant_and_reliable() {
        for round in 0..50 {
            for w in 0..8 {
                assert_eq!(delivery(123, round, w, 3, &LinkModel::IDEAL, 10_000), Some(0));
            }
        }
    }

    #[test]
    fn delivery_is_deterministic_and_seed_sensitive() {
        let link = LinkModel {
            base_latency_us: 100,
            jitter_us: 50,
            drop_prob: 0.3,
            bandwidth_bits_per_us: 8.0,
        };
        let schedule = |seed: u64| -> Vec<Option<SimTime>> {
            (0..200).map(|r| delivery(seed, r, 2, 2, &link, 1000)).collect()
        };
        assert_eq!(schedule(1), schedule(1), "same seed must reproduce the schedule");
        assert_ne!(schedule(1), schedule(2), "different seeds must differ");
        let drops = schedule(1).iter().filter(|a| a.is_none()).count();
        // Two hops at p = 0.3: loss rate 1-(0.7)^2 = 51%, so ~102/200.
        assert!((80..=125).contains(&drops), "implausible drop count {drops}/200");
    }

    #[test]
    fn latency_grows_with_hops_and_payload() {
        let link = LinkModel {
            base_latency_us: 10,
            jitter_us: 0,
            drop_prob: 0.0,
            bandwidth_bits_per_us: 1.0,
        };
        let one_hop = delivery(0, 0, 0, 1, &link, 100).unwrap();
        let two_hops = delivery(0, 0, 0, 2, &link, 100).unwrap();
        assert_eq!(one_hop, 10 + 100);
        assert_eq!(two_hops, 2 * (10 + 100));
        let fat = delivery(0, 0, 0, 1, &link, 1000).unwrap();
        assert_eq!(fat, 10 + 1000);
    }
}
