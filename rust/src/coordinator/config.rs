//! Run configuration: every knob of a distributed job, parseable from
//! `key=value` CLI arguments or a config file of the same lines — the
//! "real config system" a deployment needs without any external crates.

use crate::linalg::frames::FrameKind;

/// Compression scheme selector (the CLI surface of [`crate::quant`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SchemeKind {
    /// NDSC (near-democratic, deterministic) — default.
    Ndsc,
    /// NDSC dithered (for DQ-PSGD).
    NdscDithered,
    /// DSC (democratic via LV iteration).
    Dsc,
    /// DSC dithered.
    DscDithered,
    /// Naive uniform scalar quantizer.
    Naive,
    /// Standard dithering (no embedding).
    StandardDither,
    /// QSGD with `2^⌈R⌉−1`-ish levels.
    Qsgd,
    /// 1-bit sign quantization.
    Sign,
    /// TernGrad.
    Ternary,
    /// Top-k (k from the budget).
    TopK,
    /// Random-k (k from the budget).
    RandK,
    /// No compression (float32 gradients; reference).
    None,
}

impl SchemeKind {
    pub fn parse(s: &str) -> Option<SchemeKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "ndsc" => SchemeKind::Ndsc,
            "ndsc-dith" | "ndsc_dithered" | "ndscd" => SchemeKind::NdscDithered,
            "dsc" => SchemeKind::Dsc,
            "dsc-dith" | "dsc_dithered" | "dscd" => SchemeKind::DscDithered,
            "naive" | "uniform" => SchemeKind::Naive,
            "sd" | "dither" | "standard-dither" => SchemeKind::StandardDither,
            "qsgd" => SchemeKind::Qsgd,
            "sign" => SchemeKind::Sign,
            "ternary" | "terngrad" => SchemeKind::Ternary,
            "topk" | "top-k" => SchemeKind::TopK,
            "randk" | "rand-k" | "random" => SchemeKind::RandK,
            "none" | "float" | "fp32" => SchemeKind::None,
            _ => return None,
        })
    }
}

impl std::fmt::Display for SchemeKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

/// Full distributed-run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Problem dimension.
    pub n: usize,
    /// Number of workers `m`.
    pub workers: usize,
    /// Bit budget `R` (bits per dimension per worker per round).
    pub r: f32,
    pub scheme: SchemeKind,
    pub frame: FrameKind,
    /// Rounds `T`.
    pub rounds: usize,
    /// Step size `α`.
    pub step: f32,
    /// Worker minibatch size (0 = full local gradient).
    pub batch: usize,
    /// Projection-ball radius (`inf` = unconstrained).
    pub radius: f32,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            n: 30,
            workers: 10,
            r: 1.0,
            scheme: SchemeKind::Ndsc,
            frame: FrameKind::Hadamard,
            rounds: 200,
            step: 0.05,
            batch: 5,
            radius: f32::INFINITY,
            seed: 0,
        }
    }
}

impl RunConfig {
    /// Parse `key=value` tokens, e.g.
    /// `n=116 workers=4 r=0.5 scheme=ndsc frame=hadamard rounds=300`.
    pub fn parse_args(args: &[String]) -> Result<RunConfig, String> {
        let mut cfg = RunConfig::default();
        for a in args {
            let (k, v) = a
                .split_once('=')
                .ok_or_else(|| format!("expected key=value, got '{a}'"))?;
            match k {
                "n" => cfg.n = v.parse().map_err(|e| format!("n: {e}"))?,
                "workers" | "m" => cfg.workers = v.parse().map_err(|e| format!("workers: {e}"))?,
                "r" | "bits" => cfg.r = v.parse().map_err(|e| format!("r: {e}"))?,
                "scheme" => {
                    cfg.scheme =
                        SchemeKind::parse(v).ok_or_else(|| format!("unknown scheme '{v}'"))?
                }
                "frame" => {
                    cfg.frame = FrameKind::parse(v).ok_or_else(|| format!("unknown frame '{v}'"))?
                }
                "rounds" | "iters" | "t" => {
                    cfg.rounds = v.parse().map_err(|e| format!("rounds: {e}"))?
                }
                "step" | "alpha" | "lr" => cfg.step = v.parse().map_err(|e| format!("step: {e}"))?,
                "batch" => cfg.batch = v.parse().map_err(|e| format!("batch: {e}"))?,
                "radius" => cfg.radius = v.parse().map_err(|e| format!("radius: {e}"))?,
                "seed" => cfg.seed = v.parse().map_err(|e| format!("seed: {e}"))?,
                _ => return Err(format!("unknown config key '{k}'")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 {
            return Err("n must be positive".into());
        }
        if self.workers == 0 {
            return Err("workers must be positive".into());
        }
        if !(self.r > 0.0) && self.scheme != SchemeKind::None {
            return Err("r must be positive".into());
        }
        if self.rounds == 0 {
            return Err("rounds must be positive".into());
        }
        Ok(())
    }

    /// Build one compressor per worker from the scheme/frame config.
    /// Each worker draws independent frame randomness from `rng` (common
    /// randomness with the server, established at setup).
    pub fn build_compressors(
        &self,
        rng: &mut crate::linalg::rng::Rng,
    ) -> Vec<std::sync::Arc<dyn crate::quant::Compressor>> {
        use crate::quant::dsc::{CodecMode, EmbedKind, SubspaceCodec};
        use crate::quant::gain_shape::{NaiveUniform, StandardDither};
        use crate::quant::qsgd::Qsgd;
        use crate::quant::randk::RandK;
        use crate::quant::sign::SignQuantizer;
        use crate::quant::ternary::Ternary;
        use crate::quant::topk::TopK;
        use std::sync::Arc;

        let n = self.n;
        let r = self.r;
        (0..self.workers)
            .map(|_| -> std::sync::Arc<dyn crate::quant::Compressor> {
                match self.scheme {
                    SchemeKind::Ndsc => Arc::new(SubspaceCodec::new(
                        self.frame.build(n, rng),
                        EmbedKind::NearDemocratic,
                        CodecMode::Deterministic,
                        r,
                    )),
                    SchemeKind::NdscDithered => Arc::new(SubspaceCodec::new(
                        self.frame.build(n, rng),
                        EmbedKind::NearDemocratic,
                        CodecMode::Dithered,
                        r,
                    )),
                    SchemeKind::Dsc => Arc::new(SubspaceCodec::new(
                        self.frame.build(n, rng),
                        EmbedKind::Democratic,
                        CodecMode::Deterministic,
                        r,
                    )),
                    SchemeKind::DscDithered => Arc::new(SubspaceCodec::new(
                        self.frame.build(n, rng),
                        EmbedKind::Democratic,
                        CodecMode::Dithered,
                        r,
                    )),
                    SchemeKind::Naive => Arc::new(NaiveUniform::new(n, r)),
                    SchemeKind::StandardDither => Arc::new(StandardDither::new(n, r)),
                    SchemeKind::Qsgd => {
                        Arc::new(Qsgd::new(n, (r.ceil() as usize).saturating_sub(1).max(1)))
                    }
                    SchemeKind::Sign => Arc::new(SignQuantizer::new(n)),
                    SchemeKind::Ternary => Arc::new(Ternary::new(n)),
                    SchemeKind::TopK => {
                        let k = (crate::quant::budget_bits(n, r) / 8).clamp(1, n);
                        Arc::new(TopK::new(n, k, 8))
                    }
                    SchemeKind::RandK => {
                        let k = crate::quant::budget_bits(n, r).clamp(1, n);
                        Arc::new(RandK::new(n, k, 1).unbiased())
                    }
                    SchemeKind::None => Arc::new(Fp32Passthrough { n }),
                }
            })
            .collect()
    }
}

/// Identity "compressor" for the unquantized reference runs: 32 bits per
/// dimension of payload (so the traffic accounting stays meaningful).
pub struct Fp32Passthrough {
    pub n: usize,
}

impl crate::quant::Compressor for Fp32Passthrough {
    fn name(&self) -> String {
        "fp32".into()
    }

    fn n(&self) -> usize {
        self.n
    }

    fn bits_per_dim(&self) -> f32 {
        32.0
    }

    fn compress(
        &self,
        y: &[f32],
        _rng: &mut crate::linalg::rng::Rng,
    ) -> crate::quant::Compressed {
        let mut w = crate::quant::bitpack::BitWriter::with_capacity_bits(32 * y.len());
        for &v in y {
            w.write_f32(v);
        }
        crate::quant::Compressed {
            n: self.n,
            bytes: w.into_bytes(),
            payload_bits: 32 * self.n,
            side_bits: 0,
        }
    }

    fn decompress(&self, msg: &crate::quant::Compressed) -> Vec<f32> {
        let mut r = crate::quant::bitpack::BitReader::new(&msg.bytes);
        (0..self.n).map(|_| r.read_f32()).collect()
    }

    fn is_unbiased(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::quant::Compressor;

    #[test]
    fn parse_roundtrip() {
        let args: Vec<String> =
            ["n=116", "workers=4", "r=0.5", "scheme=ndsc-dith", "frame=haar", "rounds=300", "seed=7"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let cfg = RunConfig::parse_args(&args).unwrap();
        assert_eq!(cfg.n, 116);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.r, 0.5);
        assert_eq!(cfg.scheme, SchemeKind::NdscDithered);
        assert_eq!(cfg.frame, FrameKind::Orthonormal);
        assert_eq!(cfg.rounds, 300);
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(RunConfig::parse_args(&["nope".into()]).is_err());
        assert!(RunConfig::parse_args(&["scheme=bogus".into()]).is_err());
        assert!(RunConfig::parse_args(&["n=0".into()]).is_err());
    }

    #[test]
    fn builds_all_schemes() {
        let mut rng = Rng::seed_from(1);
        for scheme in [
            SchemeKind::Ndsc,
            SchemeKind::NdscDithered,
            SchemeKind::Dsc,
            SchemeKind::DscDithered,
            SchemeKind::Naive,
            SchemeKind::StandardDither,
            SchemeKind::Qsgd,
            SchemeKind::Sign,
            SchemeKind::Ternary,
            SchemeKind::TopK,
            SchemeKind::RandK,
            SchemeKind::None,
        ] {
            let cfg = RunConfig { scheme, n: 32, workers: 2, r: 2.0, ..Default::default() };
            let comps = cfg.build_compressors(&mut rng);
            assert_eq!(comps.len(), 2);
            // smoke: roundtrip a vector
            let y: Vec<f32> = (0..32).map(|i| (i as f32) - 16.0).collect();
            let msg = comps[0].compress(&y, &mut rng);
            let yhat = comps[0].decompress(&msg);
            assert_eq!(yhat.len(), 32, "{scheme:?}");
        }
    }

    #[test]
    fn fp32_passthrough_is_lossless() {
        let mut rng = Rng::seed_from(2);
        let c = Fp32Passthrough { n: 10 };
        let y: Vec<f32> = (0..10).map(|_| rng.gaussian_cubed()).collect();
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        assert_eq!(y, yhat);
    }
}
