//! Run configuration: every knob of a distributed job, parseable from
//! `key=value` CLI arguments or a config file of the same lines — the
//! "real config system" a deployment needs without any external crates.

use crate::coordinator::transport::{LinkModel, SimNetConfig, Topology};
use crate::linalg::frames::FrameKind;
use crate::quant::registry::{CompressorSpec, FrameSpec, SparsifyKind};

pub use crate::coordinator::transport::{Participation, TransportKind};
pub use crate::quant::registry::Fp32Passthrough;

/// Default dimension at which the server fans the per-round decode out
/// across scoped threads. Below this, a decode is a few microseconds of
/// work and a thread spawn would cost more than it saves; above it (the
/// (N)DSC decode is an `O(N log N)` FWHT plus an `O(N)` inverse transform,
/// and the transformer workload has `n ~ 10^5`) the `m`-way fan-out is a
/// near-linear speedup of the consensus step. This constant is the single
/// source of truth — [`RunConfig::parallel_decode_min_dim`] defaults to it
/// and is the per-run override (tests force both paths with it).
pub const PARALLEL_DECODE_MIN_DIM: usize = 8192;

/// Dimension at which [`crate::linalg::fwht::fwht_inplace_auto`] switches
/// from the single-threaded cache-blocked kernel to the rayon-free
/// `std::thread::scope` multi-threaded transform. Below this a transform
/// is well under a millisecond and thread spawns would dominate; above it
/// the butterfly stages are memory-bandwidth-bound and the column-panel
/// fan-out is a near-linear speedup. Deliberately set well above
/// [`PARALLEL_DECODE_MIN_DIM`]: the server's per-participant decode
/// fan-out and the in-transform fan-out would otherwise nest and
/// oversubscribe cores at moderate `n`. This constant is the single
/// source of truth for every caller (kernel, server decode, benches,
/// threshold-boundary tests).
pub const MT_FWHT_MIN_DIM: usize = 1 << 18;

/// Fleet-wide cap on concurrently live worker threads across **all**
/// coordinator fleets of one process — the multi-fleet extension of the
/// "never nest" invariant above. A [`crate::serve::cluster::FleetCluster`]
/// runs its fleets' rounds on one scoped thread each, and every fleet may
/// fan a granted job's worker phase out over that job's workers; with `k`
/// fleets the process would otherwise run up to `k · m` worker threads at
/// once. [`fleet_fanout_threads`] divides this cap by the number of
/// active fleets, so total fan-out stays bounded no matter how many
/// fleets the cluster hosts. Single-sourced here (with the two
/// thresholds above) because the hazard spans layers: serve, coordinator
/// decode, and the in-transform FWHT fan-out.
pub const FLEET_MAX_WORKER_THREADS: usize = 64;

/// How many worker threads one fleet may spend on a granted job's round,
/// or `None` to run the round inline (single-threaded). This is the
/// single gate every serve-layer fan-out goes through, and it encodes
/// the "never nest" invariant end to end:
///
/// * `workers < 2` — nothing to fan out;
/// * `n >= MT_FWHT_MIN_DIM` — the FWHT inside each encode/decode will
///   itself go multi-threaded ([`crate::linalg::fwht::fwht_inplace_auto`]),
///   and nesting a per-worker fan-out around a per-transform fan-out
///   oversubscribes cores: the job runs inline and lets the transform
///   own the parallelism;
/// * per-fleet allowance `FLEET_MAX_WORKER_THREADS / active_fleets < 2`
///   — with many fleets live, each fleet's share of the thread budget
///   rounds down to "inline".
///
/// The thread count only ever affects wall-clock, never results: the
/// threaded executor ([`crate::opt::engine::RunState::step_mt`]) is
/// bit-identical to the inline path for any thread count, so this gate
/// is free to be dynamic.
pub fn fleet_fanout_threads(workers: usize, n: usize, active_fleets: usize) -> Option<usize> {
    if workers < 2 || n >= MT_FWHT_MIN_DIM {
        return None;
    }
    let allowance = FLEET_MAX_WORKER_THREADS / active_fleets.max(1);
    if allowance < 2 {
        return None;
    }
    Some(workers.min(allowance))
}

/// Autoscaler high watermark: when the cluster's queued (running +
/// paused) job count reaches this many jobs **per active fleet**, the
/// autoscaler activates another fleet (up to the cluster's member
/// count) and rebalances onto it over the live-migration path. Chosen
/// well above the DRR round-robin's comfortable per-fleet multiplexing
/// level so transient submission bursts don't thrash the fleet set.
pub const AUTOSCALE_HIGH_QUEUED_PER_FLEET: usize = 8;

/// Autoscaler low watermark: when queued jobs drop to this many **per
/// active fleet** (or fewer), the autoscaler drains the last active
/// fleet onto the survivors and deactivates it (floor: one active
/// fleet). Strictly below [`AUTOSCALE_HIGH_QUEUED_PER_FLEET`] with
/// hysteresis room: after a shrink, queued-per-fleet rises by roughly
/// `active/(active-1)`, which must not immediately re-trip the high
/// watermark.
pub const AUTOSCALE_LOW_QUEUED_PER_FLEET: usize = 2;

/// Byte cap on the cluster-wide codec-plan cache
/// ([`crate::serve::plancache::PlanCache`]): the sum of
/// `Compressor::resident_bytes` across cached ladders is kept at or
/// below this figure by LRU eviction. Eviction only drops the cache's
/// own `Arc` — live jobs keep theirs — so the cap bounds *extra*
/// memory the cache pins, not job memory. 64 MiB holds hundreds of
/// orthonormal-frame ladders at the bench shapes while staying
/// irrelevant next to a single `n = 2^20` tenant's iterate state.
pub const PLAN_CACHE_MAX_BYTES: usize = 64 << 20;

/// Largest tenant dimension eligible for the batched small-tenant
/// epoch executor: grant groups with `n` at or below this (and no
/// worker fan-out threads) are coalesced into one contiguous panel per
/// work item, amortizing per-grant deque/claim/steal fixed costs that
/// dominate tiny jobs. Kept below [`PARALLEL_DECODE_MIN_DIM`] so a
/// batched panel never straddles the inline/parallel decode boundary.
pub const EPOCH_BATCH_MAX_DIM: usize = 4096;

/// Cap on how many same-`(n, workers)` grant groups one batched panel
/// may hold. A panel is the unit of work stealing, so an unbounded
/// panel would re-create the straggler problem the epoch executor
/// exists to kill; 64 amortizes the fixed costs to noise while leaving
/// a 1024-lightweight epoch split across enough items to steal.
pub const EPOCH_BATCH_MAX_GROUPS: usize = 64;

/// Compression scheme selector (the CLI surface of [`crate::quant`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SchemeKind {
    /// NDSC (near-democratic, deterministic) — default.
    Ndsc,
    /// NDSC dithered (for DQ-PSGD).
    NdscDithered,
    /// DSC (democratic via LV iteration).
    Dsc,
    /// DSC dithered.
    DscDithered,
    /// Naive uniform scalar quantizer.
    Naive,
    /// Standard dithering (no embedding).
    StandardDither,
    /// QSGD with `2^⌈R⌉−1`-ish levels.
    Qsgd,
    /// 1-bit sign quantization.
    Sign,
    /// TernGrad.
    Ternary,
    /// Top-k (k from the budget).
    TopK,
    /// Random-k (k from the budget).
    RandK,
    /// No compression (float32 gradients; reference).
    None,
}

impl SchemeKind {
    pub fn parse(s: &str) -> Option<SchemeKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "ndsc" => SchemeKind::Ndsc,
            "ndsc-dith" | "ndsc_dithered" | "ndscd" => SchemeKind::NdscDithered,
            "dsc" => SchemeKind::Dsc,
            "dsc-dith" | "dsc_dithered" | "dscd" => SchemeKind::DscDithered,
            "naive" | "uniform" => SchemeKind::Naive,
            "sd" | "dither" | "standard-dither" => SchemeKind::StandardDither,
            "qsgd" => SchemeKind::Qsgd,
            "sign" => SchemeKind::Sign,
            "ternary" | "terngrad" => SchemeKind::Ternary,
            "topk" | "top-k" => SchemeKind::TopK,
            "randk" | "rand-k" | "random" => SchemeKind::RandK,
            "none" | "float" | "fp32" => SchemeKind::None,
            _ => return None,
        })
    }
}

impl std::fmt::Display for SchemeKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl SchemeKind {
    /// The [`CompressorSpec`] this CLI selector denotes, at a given frame.
    /// `SchemeKind` is the stable CLI surface; the registry is the single
    /// constructor behind it.
    pub fn spec(self, frame: FrameKind) -> CompressorSpec {
        use crate::quant::dsc::{CodecMode, EmbedKind};
        let fs = FrameSpec::from_kind(frame);
        match self {
            SchemeKind::Ndsc => CompressorSpec::Subspace {
                embed: EmbedKind::NearDemocratic,
                mode: CodecMode::Deterministic,
                frame: fs,
            },
            SchemeKind::NdscDithered => CompressorSpec::Subspace {
                embed: EmbedKind::NearDemocratic,
                mode: CodecMode::Dithered,
                frame: fs,
            },
            SchemeKind::Dsc => CompressorSpec::Subspace {
                embed: EmbedKind::Democratic,
                mode: CodecMode::Deterministic,
                frame: fs,
            },
            SchemeKind::DscDithered => CompressorSpec::Subspace {
                embed: EmbedKind::Democratic,
                mode: CodecMode::Dithered,
                frame: fs,
            },
            SchemeKind::Naive => CompressorSpec::Naive,
            SchemeKind::StandardDither => CompressorSpec::StandardDither,
            SchemeKind::Qsgd => CompressorSpec::Qsgd,
            SchemeKind::Sign => CompressorSpec::Sign,
            SchemeKind::Ternary => CompressorSpec::Ternary,
            SchemeKind::TopK => CompressorSpec::TopK { value_bits: 8, count_index_bits: false },
            SchemeKind::RandK => {
                CompressorSpec::RandK { value_bits: 1, kind: SparsifyKind::Unbiased }
            }
            SchemeKind::None => CompressorSpec::Fp32,
        }
    }
}

/// Full distributed-run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Problem dimension.
    pub n: usize,
    /// Number of workers `m`.
    pub workers: usize,
    /// Bit budget `R` (bits per dimension per worker per round). When
    /// `budgets` is set this is the mean budget, kept for summaries; the
    /// per-worker truth is [`RunConfig::budget_for`].
    pub r: f32,
    /// Heterogeneous per-worker budgets `R_i` (`None` = uniform `r`).
    /// CLI grammar: `r=0.5,1,2,4` — a comma-separated list, one entry per
    /// worker. Every `R_i` must be feasible for the scheme on its own.
    pub budgets: Option<Vec<f32>>,
    /// Which uploads the server aggregates each round: all delivered
    /// (`full`), the `k` earliest (`k:<count>`), or those within a
    /// simulated deadline (`deadline:<µs>`).
    pub participation: Participation,
    /// Wire transport: in-process channels, the deterministic SimNet
    /// model, or a recording wrapper (`transport=inproc|sim|recorded:<path>`;
    /// SimNet knobs: `topo=`, `lat=`, `jitter=`, `drop=`, `bw=`, `net-seed=`).
    pub transport: TransportKind,
    pub scheme: SchemeKind,
    /// Registry spec taking precedence over `scheme` when set — this is
    /// how `scheme=<any registry name>` (e.g. `ratq`, `vqsgd`,
    /// `topk4b-idx`, `sd+ndh`) reaches the CLI beyond the legacy
    /// [`SchemeKind`] selectors.
    pub spec_override: Option<CompressorSpec>,
    pub frame: FrameKind,
    /// Rounds `T`.
    pub rounds: usize,
    /// Step size `α`.
    pub step: f32,
    /// Worker minibatch size (0 = full local gradient).
    pub batch: usize,
    /// Projection-ball radius (`inf` = unconstrained).
    pub radius: f32,
    pub seed: u64,
    /// Dimension threshold above which the server decodes uploads on
    /// scoped threads (default [`PARALLEL_DECODE_MIN_DIM`]). The decode
    /// result is bit-identical either way (accumulation is in worker-id
    /// order); tests override this to force both paths.
    pub parallel_decode_min_dim: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            n: 30,
            workers: 10,
            r: 1.0,
            budgets: None,
            participation: Participation::Full,
            transport: TransportKind::InProc,
            scheme: SchemeKind::Ndsc,
            spec_override: None,
            frame: FrameKind::Hadamard,
            rounds: 200,
            step: 0.05,
            batch: 5,
            radius: f32::INFINITY,
            seed: 0,
            parallel_decode_min_dim: PARALLEL_DECODE_MIN_DIM,
        }
    }
}

impl RunConfig {
    /// Parse `key=value` tokens, e.g.
    /// `n=116 workers=4 r=0.5 scheme=ndsc frame=hadamard rounds=300`,
    /// `r=0.5,1,2,4` (per-worker budgets), `part=k:3`,
    /// `transport=sim topo=chain lat=200 jitter=50 drop=0.1 bw=8`.
    pub fn parse_args(args: &[String]) -> Result<RunConfig, String> {
        let mut cfg = RunConfig::default();
        // SimNet knobs accumulate here; `transport=sim` (or touching any
        // knob without naming a transport) assembles them at the end.
        let mut link = LinkModel::IDEAL;
        let mut topology = Topology::Star;
        let mut net_seed = 0u64;
        let mut net_touched = false;
        let mut transport_arg: Option<String> = None;
        for a in args {
            let (k, v) = a
                .split_once('=')
                .ok_or_else(|| format!("expected key=value, got '{a}'"))?;
            match k {
                "n" => cfg.n = v.parse().map_err(|e| format!("n: {e}"))?,
                "workers" | "m" => cfg.workers = v.parse().map_err(|e| format!("workers: {e}"))?,
                "r" | "bits" => {
                    if v.contains(',') {
                        let list = v
                            .split(',')
                            .map(|t| t.parse::<f32>().map_err(|e| format!("r: '{t}': {e}")))
                            .collect::<Result<Vec<f32>, String>>()?;
                        cfg.r = list.iter().sum::<f32>() / list.len() as f32;
                        cfg.budgets = Some(list);
                    } else {
                        cfg.r = v.parse().map_err(|e| format!("r: {e}"))?;
                        cfg.budgets = None;
                    }
                }
                "part" | "participation" => {
                    cfg.participation = Participation::parse(v).ok_or_else(|| {
                        format!("unknown participation '{v}' (full|k:<n>|deadline:<µs>)")
                    })?
                }
                "transport" => transport_arg = Some(v.to_string()),
                "topo" | "topology" => {
                    topology = Topology::parse(v)
                        .ok_or_else(|| format!("unknown topology '{v}' (star|chain|tree:<f>)"))?;
                    net_touched = true;
                }
                "lat" | "latency" => {
                    link.base_latency_us = v.parse().map_err(|e| format!("lat: {e}"))?;
                    net_touched = true;
                }
                "jitter" => {
                    link.jitter_us = v.parse().map_err(|e| format!("jitter: {e}"))?;
                    net_touched = true;
                }
                "drop" => {
                    link.drop_prob = v.parse().map_err(|e| format!("drop: {e}"))?;
                    net_touched = true;
                }
                "bw" | "bandwidth" => {
                    link.bandwidth_bits_per_us = v.parse().map_err(|e| format!("bw: {e}"))?;
                    net_touched = true;
                }
                "net-seed" | "netseed" => {
                    net_seed = v.parse().map_err(|e| format!("net-seed: {e}"))?;
                    net_touched = true;
                }
                "scheme" => match SchemeKind::parse(v) {
                    Some(s) => {
                        cfg.scheme = s;
                        cfg.spec_override = None;
                    }
                    None => {
                        // Any registry spec name works here too.
                        cfg.spec_override = Some(
                            CompressorSpec::parse(v)
                                .ok_or_else(|| format!("unknown scheme '{v}'"))?,
                        );
                    }
                },
                "frame" => {
                    cfg.frame = FrameKind::parse(v).ok_or_else(|| format!("unknown frame '{v}'"))?
                }
                "rounds" | "iters" | "t" => {
                    cfg.rounds = v.parse().map_err(|e| format!("rounds: {e}"))?
                }
                "step" | "alpha" | "lr" => cfg.step = v.parse().map_err(|e| format!("step: {e}"))?,
                "batch" => cfg.batch = v.parse().map_err(|e| format!("batch: {e}"))?,
                "radius" => cfg.radius = v.parse().map_err(|e| format!("radius: {e}"))?,
                "seed" => cfg.seed = v.parse().map_err(|e| format!("seed: {e}"))?,
                _ => return Err(format!("unknown config key '{k}'")),
            }
        }
        let net = SimNetConfig { seed: net_seed, topology, links: vec![link] };
        match transport_arg.as_deref() {
            None => {
                if net_touched {
                    cfg.transport = TransportKind::SimNet(net);
                }
            }
            Some("inproc") => {
                // Silently ignoring latency/drop knobs would let a user
                // believe they simulated a network they didn't.
                if net_touched {
                    return Err(
                        "transport=inproc conflicts with SimNet knobs \
                         (topo/lat/jitter/drop/bw/net-seed); drop them or use transport=sim"
                            .into(),
                    );
                }
                cfg.transport = TransportKind::InProc;
            }
            Some("sim") | Some("simnet") => cfg.transport = TransportKind::SimNet(net),
            Some(t) => match t.strip_prefix("recorded:") {
                Some(path) if !path.is_empty() => {
                    cfg.transport = TransportKind::Recorded {
                        path: path.to_string(),
                        net: if net_touched { Some(net) } else { None },
                    }
                }
                _ => {
                    return Err(format!(
                        "unknown transport '{t}' (inproc|sim|recorded:<path>)"
                    ))
                }
            },
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 {
            return Err("n must be positive".into());
        }
        if self.workers == 0 {
            return Err("workers must be positive".into());
        }
        if !(self.r > 0.0) && self.scheme != SchemeKind::None {
            return Err("r must be positive".into());
        }
        if self.rounds == 0 {
            return Err("rounds must be positive".into());
        }
        if let Some(budgets) = &self.budgets {
            if budgets.len() != self.workers {
                return Err(format!(
                    "r lists one budget per worker: got {} entries for {} workers",
                    budgets.len(),
                    self.workers
                ));
            }
            if budgets.iter().any(|&b| !(b > 0.0)) {
                return Err("every per-worker budget R_i must be positive".into());
            }
        }
        match self.participation {
            Participation::KofM { k } if k == 0 || k > self.workers => {
                return Err(format!(
                    "participation k:{k} out of range (1..={} workers)",
                    self.workers
                ));
            }
            _ => {}
        }
        let links: &[LinkModel] = match &self.transport {
            TransportKind::SimNet(net) | TransportKind::Recorded { net: Some(net), .. } => {
                &net.links
            }
            _ => &[],
        };
        for l in links {
            if !(0.0..1.0).contains(&l.drop_prob) {
                return Err(format!("drop probability {} not in [0, 1)", l.drop_prob));
            }
            if !l.bandwidth_bits_per_us.is_finite() || l.bandwidth_bits_per_us < 0.0 {
                return Err("bandwidth must be a finite non-negative bits/µs".into());
            }
        }
        // Reject infeasible (scheme, n, R_i) upfront — for every worker's
        // own budget: without this the budget-enforcing uplink would
        // reject the first over-budget message and panic a worker thread
        // mid-run. scheme=none (fp32) is the unconstrained reference and
        // is exempt.
        let spec = self.compressor_spec();
        for i in 0..self.workers {
            let r_i = self.budget_for(i);
            if spec != CompressorSpec::Fp32 && r_i > 0.0 && !spec.is_feasible(self.n, r_i) {
                return Err(format!(
                    "scheme '{}' cannot fit worker {i}'s budget ⌊n·R_i⌋ = {} bits at n={}, R_i={} \
                     (its wire rate is fixed above R; raise r or pick a budget-adaptive scheme)",
                    spec.name(),
                    crate::quant::budget_bits(self.n, r_i),
                    self.n,
                    r_i
                ));
            }
        }
        Ok(())
    }

    /// Worker `i`'s bit budget `R_i` (the uniform `r` unless a per-worker
    /// list is set; short lists cycle defensively, though
    /// [`RunConfig::validate`] requires one entry per worker).
    pub fn budget_for(&self, worker: usize) -> f32 {
        match &self.budgets {
            Some(b) if !b.is_empty() => b[worker % b.len()],
            _ => self.r,
        }
    }

    /// Per-worker uplink payload caps in bits (`⌊n·R_i⌋`; `None` = the
    /// unconstrained fp32 reference) — what the transport layer enforces.
    pub fn uplink_budgets(&self) -> Vec<Option<usize>> {
        let spec = self.compressor_spec();
        (0..self.workers)
            .map(|i| {
                if spec == CompressorSpec::Fp32 {
                    None
                } else {
                    Some(crate::quant::budget_bits(self.n, self.budget_for(i)))
                }
            })
            .collect()
    }

    /// Human-readable scheme name for run summaries (the registry name
    /// when a spec override is active, else the legacy selector).
    pub fn scheme_name(&self) -> String {
        match self.spec_override {
            Some(spec) => spec.name(),
            None => self.scheme.to_string(),
        }
    }

    /// The registry spec this config selects: the explicit override when
    /// one was parsed, else the legacy `scheme`/`frame` mapping.
    pub fn compressor_spec(&self) -> CompressorSpec {
        self.spec_override.unwrap_or_else(|| self.scheme.spec(self.frame))
    }

    /// Build one compressor per worker through the registry, each at its
    /// own budget `R_i`. Each worker draws independent frame randomness
    /// from `rng` (common randomness with the server, established at
    /// setup).
    pub fn build_compressors(
        &self,
        rng: &mut crate::linalg::rng::Rng,
    ) -> Vec<std::sync::Arc<dyn crate::quant::Compressor>> {
        let spec = self.compressor_spec();
        (0..self.workers)
            .map(|i| std::sync::Arc::from(spec.build(self.n, self.budget_for(i), rng)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::quant::Compressor;

    #[test]
    fn parse_roundtrip() {
        let args: Vec<String> =
            ["n=116", "workers=4", "r=0.5", "scheme=ndsc-dith", "frame=haar", "rounds=300", "seed=7"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let cfg = RunConfig::parse_args(&args).unwrap();
        assert_eq!(cfg.n, 116);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.r, 0.5);
        assert_eq!(cfg.scheme, SchemeKind::NdscDithered);
        assert_eq!(cfg.frame, FrameKind::Orthonormal);
        assert_eq!(cfg.rounds, 300);
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(RunConfig::parse_args(&["nope".into()]).is_err());
        assert!(RunConfig::parse_args(&["scheme=bogus".into()]).is_err());
        assert!(RunConfig::parse_args(&["n=0".into()]).is_err());
    }

    #[test]
    fn registry_spec_names_reach_the_cli() {
        // Any registry spec name is a valid `scheme=` value; the override
        // drives both the summary name and the built compressors.
        let cfg =
            RunConfig::parse_args(&["scheme=ratq".into(), "n=64".into(), "r=3".into()]).unwrap();
        assert_eq!(cfg.spec_override, Some(CompressorSpec::Ratq));
        assert_eq!(cfg.scheme_name(), "ratq");
        let mut rng = Rng::seed_from(1);
        let comps = cfg.build_compressors(&mut rng);
        assert_eq!(comps[0].name(), "ratq-2b");
        // Legacy names still go through SchemeKind (no override).
        let cfg = RunConfig::parse_args(&["scheme=ndsc".into()]).unwrap();
        assert_eq!(cfg.spec_override, None);
        assert_eq!(cfg.scheme, SchemeKind::Ndsc);
    }

    #[test]
    fn validate_rejects_infeasible_budget_upfront() {
        // sign needs R >= 1: at R = 0.5 the config must fail loudly
        // instead of letting a worker panic on the first upload.
        let err = RunConfig::parse_args(&["scheme=sign".into(), "n=64".into(), "r=0.5".into()])
            .unwrap_err();
        assert!(err.contains("cannot fit"), "{err}");
        assert!(RunConfig::parse_args(&["scheme=sign".into(), "n=64".into(), "r=1".into()])
            .is_ok());
        // fp32 is the unconstrained reference: exempt from the check.
        assert!(RunConfig::parse_args(&["scheme=none".into()]).is_ok());
    }

    #[test]
    fn per_worker_budget_list_parses_and_validates() {
        let cfg = RunConfig::parse_args(&[
            "n=64".into(),
            "workers=4".into(),
            "r=0.5,1,2,4".into(),
            "scheme=ndsc".into(),
        ])
        .unwrap();
        assert_eq!(cfg.budgets, Some(vec![0.5, 1.0, 2.0, 4.0]));
        assert!((cfg.r - 1.875).abs() < 1e-6, "r is the mean budget, got {}", cfg.r);
        assert_eq!(cfg.budget_for(0), 0.5);
        assert_eq!(cfg.budget_for(3), 4.0);
        let caps = cfg.uplink_budgets();
        assert_eq!(caps, vec![Some(32), Some(64), Some(128), Some(256)]);
        // Compressors honor their own R_i: worker 0 at 0.5 b/dim spends
        // at most 32 payload bits, worker 3 at 4 b/dim up to 256.
        let mut rng = Rng::seed_from(3);
        let comps = cfg.build_compressors(&mut rng);
        let y: Vec<f32> = (0..64).map(|i| i as f32 - 32.0).collect();
        let m0 = comps[0].compress(&y, &mut rng);
        let m3 = comps[3].compress(&y, &mut rng);
        assert!(m0.payload_bits <= 32, "{}", m0.payload_bits);
        assert!(m3.payload_bits > 32 && m3.payload_bits <= 256, "{}", m3.payload_bits);
        // List length must match the worker count.
        assert!(RunConfig::parse_args(&["workers=3".into(), "r=1,2".into()]).is_err());
        // Every entry is feasibility-checked on its own: sign needs R ≥ 1.
        let err = RunConfig::parse_args(&[
            "n=64".into(),
            "workers=2".into(),
            "r=0.5,2".into(),
            "scheme=sign".into(),
        ])
        .unwrap_err();
        assert!(err.contains("cannot fit"), "{err}");
    }

    #[test]
    fn participation_and_transport_parse() {
        let cfg = RunConfig::parse_args(&[
            "workers=4".into(),
            "part=k:3".into(),
            "transport=sim".into(),
            "topo=chain".into(),
            "lat=200".into(),
            "drop=0.1".into(),
        ])
        .unwrap();
        assert_eq!(cfg.participation, Participation::KofM { k: 3 });
        match &cfg.transport {
            TransportKind::SimNet(net) => {
                assert_eq!(net.topology, Topology::Chain);
                assert_eq!(net.links[0].base_latency_us, 200);
                assert!((net.links[0].drop_prob - 0.1).abs() < 1e-6);
            }
            other => panic!("expected SimNet, got {other:?}"),
        }
        // Touching a net knob without transport= selects SimNet.
        let cfg = RunConfig::parse_args(&["jitter=5".into()]).unwrap();
        assert!(matches!(cfg.transport, TransportKind::SimNet(_)));
        // ...but combining net knobs with an explicit inproc is a
        // contradiction, not something to silently ignore.
        let err =
            RunConfig::parse_args(&["transport=inproc".into(), "drop=0.1".into()]).unwrap_err();
        assert!(err.contains("conflicts"), "{err}");
        // Recorded wants a path.
        let cfg = RunConfig::parse_args(&["transport=recorded:/tmp/t.kft".into()]).unwrap();
        assert!(matches!(cfg.transport, TransportKind::Recorded { .. }));
        assert!(RunConfig::parse_args(&["transport=recorded:".into()]).is_err());
        assert!(RunConfig::parse_args(&["transport=carrier-pigeon".into()]).is_err());
        // Participation bounds are validated.
        assert!(RunConfig::parse_args(&["workers=2".into(), "part=k:3".into()]).is_err());
        assert!(RunConfig::parse_args(&["drop=1.5".into()]).is_err());
    }

    #[test]
    fn builds_all_schemes() {
        let mut rng = Rng::seed_from(1);
        for scheme in [
            SchemeKind::Ndsc,
            SchemeKind::NdscDithered,
            SchemeKind::Dsc,
            SchemeKind::DscDithered,
            SchemeKind::Naive,
            SchemeKind::StandardDither,
            SchemeKind::Qsgd,
            SchemeKind::Sign,
            SchemeKind::Ternary,
            SchemeKind::TopK,
            SchemeKind::RandK,
            SchemeKind::None,
        ] {
            let cfg = RunConfig { scheme, n: 32, workers: 2, r: 2.0, ..Default::default() };
            let comps = cfg.build_compressors(&mut rng);
            assert_eq!(comps.len(), 2);
            // smoke: roundtrip a vector
            let y: Vec<f32> = (0..32).map(|i| (i as f32) - 16.0).collect();
            let msg = comps[0].compress(&y, &mut rng);
            let yhat = comps[0].decompress(&msg);
            assert_eq!(yhat.len(), 32, "{scheme:?}");
        }
    }

    #[test]
    fn fleet_fanout_never_nests_at_boundary_dims() {
        // Below the MT-FWHT threshold a 2+-worker job fans out...
        assert_eq!(fleet_fanout_threads(4, MT_FWHT_MIN_DIM - 1, 1), Some(4));
        assert_eq!(fleet_fanout_threads(4, PARALLEL_DECODE_MIN_DIM, 1), Some(4));
        // ...and exactly at (or past) it the transform owns the threads:
        // the fan-out gate must refuse, or the two levels would nest.
        assert_eq!(fleet_fanout_threads(4, MT_FWHT_MIN_DIM, 1), None);
        assert_eq!(fleet_fanout_threads(4, MT_FWHT_MIN_DIM + 1, 4), None);
        // Single-worker jobs have nothing to fan out.
        assert_eq!(fleet_fanout_threads(1, 1024, 1), None);
        assert_eq!(fleet_fanout_threads(0, 1024, 1), None);
        // The fleet-wide cap splits across active fleets: the per-fleet
        // allowance clamps wide jobs, and at 33+ fleets the share rounds
        // below 2 so every fleet degrades to inline.
        assert_eq!(fleet_fanout_threads(100, 1024, 1), Some(FLEET_MAX_WORKER_THREADS));
        assert_eq!(fleet_fanout_threads(100, 1024, 4), Some(FLEET_MAX_WORKER_THREADS / 4));
        assert_eq!(fleet_fanout_threads(8, 1024, 8), Some(8));
        assert_eq!(fleet_fanout_threads(8, 1024, FLEET_MAX_WORKER_THREADS / 2), Some(2));
        assert_eq!(fleet_fanout_threads(8, 1024, FLEET_MAX_WORKER_THREADS / 2 + 1), None);
        // active_fleets = 0 is treated as 1 defensively, not a panic.
        assert_eq!(fleet_fanout_threads(4, 1024, 0), Some(4));
    }

    #[test]
    fn autoscale_watermarks_leave_hysteresis_room() {
        assert!(AUTOSCALE_LOW_QUEUED_PER_FLEET < AUTOSCALE_HIGH_QUEUED_PER_FLEET);
        assert!(AUTOSCALE_LOW_QUEUED_PER_FLEET >= 1);
        // A shrink at exactly the low watermark concentrates
        // `LOW · active` queued jobs onto `active − 1` fleets; that new
        // per-fleet load must stay strictly under the high watermark or
        // the very next autoscale pass would grow right back (thrash).
        // Worst case is the smallest shrinkable cluster, active = 2.
        for active in 2..=64usize {
            let queued = AUTOSCALE_LOW_QUEUED_PER_FLEET * active;
            assert!(
                queued < AUTOSCALE_HIGH_QUEUED_PER_FLEET * (active - 1),
                "shrink at active={active} would immediately re-grow"
            );
        }
    }

    #[test]
    fn fp32_passthrough_is_lossless() {
        let mut rng = Rng::seed_from(2);
        let c = Fp32Passthrough { n: 10 };
        let y: Vec<f32> = (0..10).map(|_| rng.gaussian_cubed()).collect();
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        assert_eq!(y, yhat);
    }
}
