//! Run configuration: every knob of a distributed job, parseable from
//! `key=value` CLI arguments or a config file of the same lines — the
//! "real config system" a deployment needs without any external crates.

use crate::linalg::frames::FrameKind;
use crate::quant::registry::{CompressorSpec, FrameSpec, SparsifyKind};

pub use crate::quant::registry::Fp32Passthrough;

/// Compression scheme selector (the CLI surface of [`crate::quant`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SchemeKind {
    /// NDSC (near-democratic, deterministic) — default.
    Ndsc,
    /// NDSC dithered (for DQ-PSGD).
    NdscDithered,
    /// DSC (democratic via LV iteration).
    Dsc,
    /// DSC dithered.
    DscDithered,
    /// Naive uniform scalar quantizer.
    Naive,
    /// Standard dithering (no embedding).
    StandardDither,
    /// QSGD with `2^⌈R⌉−1`-ish levels.
    Qsgd,
    /// 1-bit sign quantization.
    Sign,
    /// TernGrad.
    Ternary,
    /// Top-k (k from the budget).
    TopK,
    /// Random-k (k from the budget).
    RandK,
    /// No compression (float32 gradients; reference).
    None,
}

impl SchemeKind {
    pub fn parse(s: &str) -> Option<SchemeKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "ndsc" => SchemeKind::Ndsc,
            "ndsc-dith" | "ndsc_dithered" | "ndscd" => SchemeKind::NdscDithered,
            "dsc" => SchemeKind::Dsc,
            "dsc-dith" | "dsc_dithered" | "dscd" => SchemeKind::DscDithered,
            "naive" | "uniform" => SchemeKind::Naive,
            "sd" | "dither" | "standard-dither" => SchemeKind::StandardDither,
            "qsgd" => SchemeKind::Qsgd,
            "sign" => SchemeKind::Sign,
            "ternary" | "terngrad" => SchemeKind::Ternary,
            "topk" | "top-k" => SchemeKind::TopK,
            "randk" | "rand-k" | "random" => SchemeKind::RandK,
            "none" | "float" | "fp32" => SchemeKind::None,
            _ => return None,
        })
    }
}

impl std::fmt::Display for SchemeKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl SchemeKind {
    /// The [`CompressorSpec`] this CLI selector denotes, at a given frame.
    /// `SchemeKind` is the stable CLI surface; the registry is the single
    /// constructor behind it.
    pub fn spec(self, frame: FrameKind) -> CompressorSpec {
        use crate::quant::dsc::{CodecMode, EmbedKind};
        let fs = FrameSpec::from_kind(frame);
        match self {
            SchemeKind::Ndsc => CompressorSpec::Subspace {
                embed: EmbedKind::NearDemocratic,
                mode: CodecMode::Deterministic,
                frame: fs,
            },
            SchemeKind::NdscDithered => CompressorSpec::Subspace {
                embed: EmbedKind::NearDemocratic,
                mode: CodecMode::Dithered,
                frame: fs,
            },
            SchemeKind::Dsc => CompressorSpec::Subspace {
                embed: EmbedKind::Democratic,
                mode: CodecMode::Deterministic,
                frame: fs,
            },
            SchemeKind::DscDithered => CompressorSpec::Subspace {
                embed: EmbedKind::Democratic,
                mode: CodecMode::Dithered,
                frame: fs,
            },
            SchemeKind::Naive => CompressorSpec::Naive,
            SchemeKind::StandardDither => CompressorSpec::StandardDither,
            SchemeKind::Qsgd => CompressorSpec::Qsgd,
            SchemeKind::Sign => CompressorSpec::Sign,
            SchemeKind::Ternary => CompressorSpec::Ternary,
            SchemeKind::TopK => CompressorSpec::TopK { value_bits: 8, count_index_bits: false },
            SchemeKind::RandK => {
                CompressorSpec::RandK { value_bits: 1, kind: SparsifyKind::Unbiased }
            }
            SchemeKind::None => CompressorSpec::Fp32,
        }
    }
}

/// Full distributed-run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Problem dimension.
    pub n: usize,
    /// Number of workers `m`.
    pub workers: usize,
    /// Bit budget `R` (bits per dimension per worker per round).
    pub r: f32,
    pub scheme: SchemeKind,
    /// Registry spec taking precedence over `scheme` when set — this is
    /// how `scheme=<any registry name>` (e.g. `ratq`, `vqsgd`,
    /// `topk4b-idx`, `sd+ndh`) reaches the CLI beyond the legacy
    /// [`SchemeKind`] selectors.
    pub spec_override: Option<CompressorSpec>,
    pub frame: FrameKind,
    /// Rounds `T`.
    pub rounds: usize,
    /// Step size `α`.
    pub step: f32,
    /// Worker minibatch size (0 = full local gradient).
    pub batch: usize,
    /// Projection-ball radius (`inf` = unconstrained).
    pub radius: f32,
    pub seed: u64,
    /// Dimension threshold above which the server decodes uploads on
    /// scoped threads (default
    /// [`crate::coordinator::server::PARALLEL_DECODE_MIN_DIM`]). The
    /// decode result is bit-identical either way (accumulation is in
    /// worker-id order); tests override this to force both paths.
    pub parallel_decode_min_dim: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            n: 30,
            workers: 10,
            r: 1.0,
            scheme: SchemeKind::Ndsc,
            spec_override: None,
            frame: FrameKind::Hadamard,
            rounds: 200,
            step: 0.05,
            batch: 5,
            radius: f32::INFINITY,
            seed: 0,
            parallel_decode_min_dim: crate::coordinator::server::PARALLEL_DECODE_MIN_DIM,
        }
    }
}

impl RunConfig {
    /// Parse `key=value` tokens, e.g.
    /// `n=116 workers=4 r=0.5 scheme=ndsc frame=hadamard rounds=300`.
    pub fn parse_args(args: &[String]) -> Result<RunConfig, String> {
        let mut cfg = RunConfig::default();
        for a in args {
            let (k, v) = a
                .split_once('=')
                .ok_or_else(|| format!("expected key=value, got '{a}'"))?;
            match k {
                "n" => cfg.n = v.parse().map_err(|e| format!("n: {e}"))?,
                "workers" | "m" => cfg.workers = v.parse().map_err(|e| format!("workers: {e}"))?,
                "r" | "bits" => cfg.r = v.parse().map_err(|e| format!("r: {e}"))?,
                "scheme" => match SchemeKind::parse(v) {
                    Some(s) => {
                        cfg.scheme = s;
                        cfg.spec_override = None;
                    }
                    None => {
                        // Any registry spec name works here too.
                        cfg.spec_override = Some(
                            CompressorSpec::parse(v)
                                .ok_or_else(|| format!("unknown scheme '{v}'"))?,
                        );
                    }
                },
                "frame" => {
                    cfg.frame = FrameKind::parse(v).ok_or_else(|| format!("unknown frame '{v}'"))?
                }
                "rounds" | "iters" | "t" => {
                    cfg.rounds = v.parse().map_err(|e| format!("rounds: {e}"))?
                }
                "step" | "alpha" | "lr" => cfg.step = v.parse().map_err(|e| format!("step: {e}"))?,
                "batch" => cfg.batch = v.parse().map_err(|e| format!("batch: {e}"))?,
                "radius" => cfg.radius = v.parse().map_err(|e| format!("radius: {e}"))?,
                "seed" => cfg.seed = v.parse().map_err(|e| format!("seed: {e}"))?,
                _ => return Err(format!("unknown config key '{k}'")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 {
            return Err("n must be positive".into());
        }
        if self.workers == 0 {
            return Err("workers must be positive".into());
        }
        if !(self.r > 0.0) && self.scheme != SchemeKind::None {
            return Err("r must be positive".into());
        }
        if self.rounds == 0 {
            return Err("rounds must be positive".into());
        }
        // Reject infeasible (scheme, n, R) upfront: without this the
        // budget-enforcing uplink would reject the first over-budget
        // message and panic a worker thread mid-run. scheme=none (fp32)
        // is the unconstrained reference and is exempt.
        let spec = self.compressor_spec();
        if spec != CompressorSpec::Fp32 && self.r > 0.0 && !spec.is_feasible(self.n, self.r) {
            return Err(format!(
                "scheme '{}' cannot fit the budget ⌊n·R⌋ = {} bits at n={}, R={} \
                 (its wire rate is fixed above R; raise r or pick a budget-adaptive scheme)",
                spec.name(),
                crate::quant::budget_bits(self.n, self.r),
                self.n,
                self.r
            ));
        }
        Ok(())
    }

    /// Human-readable scheme name for run summaries (the registry name
    /// when a spec override is active, else the legacy selector).
    pub fn scheme_name(&self) -> String {
        match self.spec_override {
            Some(spec) => spec.name(),
            None => self.scheme.to_string(),
        }
    }

    /// The registry spec this config selects: the explicit override when
    /// one was parsed, else the legacy `scheme`/`frame` mapping.
    pub fn compressor_spec(&self) -> CompressorSpec {
        self.spec_override.unwrap_or_else(|| self.scheme.spec(self.frame))
    }

    /// Build one compressor per worker through the registry. Each worker
    /// draws independent frame randomness from `rng` (common randomness
    /// with the server, established at setup).
    pub fn build_compressors(
        &self,
        rng: &mut crate::linalg::rng::Rng,
    ) -> Vec<std::sync::Arc<dyn crate::quant::Compressor>> {
        let spec = self.compressor_spec();
        (0..self.workers)
            .map(|_| std::sync::Arc::from(spec.build(self.n, self.r, rng)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;
    use crate::quant::Compressor;

    #[test]
    fn parse_roundtrip() {
        let args: Vec<String> =
            ["n=116", "workers=4", "r=0.5", "scheme=ndsc-dith", "frame=haar", "rounds=300", "seed=7"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let cfg = RunConfig::parse_args(&args).unwrap();
        assert_eq!(cfg.n, 116);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.r, 0.5);
        assert_eq!(cfg.scheme, SchemeKind::NdscDithered);
        assert_eq!(cfg.frame, FrameKind::Orthonormal);
        assert_eq!(cfg.rounds, 300);
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(RunConfig::parse_args(&["nope".into()]).is_err());
        assert!(RunConfig::parse_args(&["scheme=bogus".into()]).is_err());
        assert!(RunConfig::parse_args(&["n=0".into()]).is_err());
    }

    #[test]
    fn registry_spec_names_reach_the_cli() {
        // Any registry spec name is a valid `scheme=` value; the override
        // drives both the summary name and the built compressors.
        let cfg =
            RunConfig::parse_args(&["scheme=ratq".into(), "n=64".into(), "r=3".into()]).unwrap();
        assert_eq!(cfg.spec_override, Some(CompressorSpec::Ratq));
        assert_eq!(cfg.scheme_name(), "ratq");
        let mut rng = Rng::seed_from(1);
        let comps = cfg.build_compressors(&mut rng);
        assert_eq!(comps[0].name(), "ratq-2b");
        // Legacy names still go through SchemeKind (no override).
        let cfg = RunConfig::parse_args(&["scheme=ndsc".into()]).unwrap();
        assert_eq!(cfg.spec_override, None);
        assert_eq!(cfg.scheme, SchemeKind::Ndsc);
    }

    #[test]
    fn validate_rejects_infeasible_budget_upfront() {
        // sign needs R >= 1: at R = 0.5 the config must fail loudly
        // instead of letting a worker panic on the first upload.
        let err = RunConfig::parse_args(&["scheme=sign".into(), "n=64".into(), "r=0.5".into()])
            .unwrap_err();
        assert!(err.contains("cannot fit"), "{err}");
        assert!(RunConfig::parse_args(&["scheme=sign".into(), "n=64".into(), "r=1".into()])
            .is_ok());
        // fp32 is the unconstrained reference: exempt from the check.
        assert!(RunConfig::parse_args(&["scheme=none".into()]).is_ok());
    }

    #[test]
    fn builds_all_schemes() {
        let mut rng = Rng::seed_from(1);
        for scheme in [
            SchemeKind::Ndsc,
            SchemeKind::NdscDithered,
            SchemeKind::Dsc,
            SchemeKind::DscDithered,
            SchemeKind::Naive,
            SchemeKind::StandardDither,
            SchemeKind::Qsgd,
            SchemeKind::Sign,
            SchemeKind::Ternary,
            SchemeKind::TopK,
            SchemeKind::RandK,
            SchemeKind::None,
        ] {
            let cfg = RunConfig { scheme, n: 32, workers: 2, r: 2.0, ..Default::default() };
            let comps = cfg.build_compressors(&mut rng);
            assert_eq!(comps.len(), 2);
            // smoke: roundtrip a vector
            let y: Vec<f32> = (0..32).map(|i| (i as f32) - 16.0).collect();
            let msg = comps[0].compress(&y, &mut rng);
            let yhat = comps[0].decompress(&msg);
            assert_eq!(yhat.len(), 32, "{scheme:?}");
        }
    }

    #[test]
    fn fp32_passthrough_is_lossless() {
        let mut rng = Rng::seed_from(2);
        let c = Fp32Passthrough { n: 10 };
        let y: Vec<f32> = (0..10).map(|_| rng.gaussian_cubed()).collect();
        let yhat = c.decompress(&c.compress(&y, &mut rng));
        assert_eq!(y, yhat);
    }
}
