//! Wire messages between the parameter server and workers, their
//! serialized sizes, and the trace-frame format the `Recorded` transport
//! writes.
//!
//! The in-process transport passes these structs directly, but byte
//! accounting uses the *serialized* sizes ([`WireSize`]) so the metrics
//! reflect what a network deployment would move. The uplink payload is a
//! [`crate::quant::Compressed`] — already bit-exact — plus a small header.
//!
//! Message buffers (the broadcast's `iterate`, the upload's `msg.bytes`)
//! are owned `Vec`s so they can ping-pong through
//! [`crate::coordinator::channel::ChannelPools`] instead of being
//! reallocated per round; recycling is a transport-level concern and does
//! not change the wire sizes reported here.
//!
//! The **trace format** (`write_*_frame` / [`read_trace_frame`]) is what
//! [`crate::coordinator::transport::recorded`] serializes: a fixed magic
//! header, then length-prefixed little-endian records — broadcasts with
//! their full fp32 iterate, uploads with their exact wire bytes, bit
//! accounting, and simulated arrival tag. A recorded run replays to
//! bit-identical server iterates (`rust/tests/test_transport.rs`).

use std::io::{self, Read, Write};

use crate::quant::Compressed;

/// Downlink: server → worker. The broadcast iterate is sent at full
/// precision, as in the paper ("the worker receives the current iterate") —
//  only the uplink is budget-constrained.
#[derive(Clone, Debug)]
pub struct Broadcast {
    pub round: u64,
    pub iterate: Vec<f32>,
}

/// Uplink: worker → server, carrying the quantized gradient.
#[derive(Debug)]
pub struct Upload {
    pub round: u64,
    pub worker: usize,
    pub msg: Compressed,
    /// Local objective value at the broadcast iterate (f32 side channel,
    /// used for metrics only).
    pub local_value: f32,
}

/// Header bits of one upload frame: round (u64) + worker id (u32) +
/// local value (f32). Side-information bits are accounted separately.
pub const UPLOAD_HEADER_BITS: usize = 64 + 32 + 32;

/// Exact uplink wire bytes a [`Compressed`] message occupies once framed:
/// `⌈(payload + side + header) / 8⌉`. This is what `repro schemes` prints
/// next to each registry entry.
pub fn upload_wire_bytes(msg: &Compressed) -> usize {
    (msg.payload_bits + msg.side_bits + UPLOAD_HEADER_BITS).div_ceil(8)
}

/// Serialized size of a message, in bits, as it would cross a network.
pub trait WireSize {
    /// Bits subject to the per-round budget (quantized payload).
    fn payload_bits(&self) -> usize;
    /// Bits of headers/side info not counted against the budget.
    fn overhead_bits(&self) -> usize;
}

impl WireSize for Broadcast {
    fn payload_bits(&self) -> usize {
        0 // downlink is unconstrained in the paper's model
    }

    fn overhead_bits(&self) -> usize {
        64 + 32 * self.iterate.len()
    }
}

impl WireSize for Upload {
    fn payload_bits(&self) -> usize {
        self.msg.payload_bits
    }

    fn overhead_bits(&self) -> usize {
        UPLOAD_HEADER_BITS + self.msg.side_bits
    }
}

// ---------------------------------------------------------------------------
// Trace-frame (de)serialization — the `Recorded` transport's disk format.
// ---------------------------------------------------------------------------

/// Magic bytes opening every trace file (version-tagged).
pub const TRACE_MAGIC: &[u8; 8] = b"KFTRACE1";

const TAG_BROADCAST: u8 = 0;
const TAG_UPLOAD: u8 = 1;
/// Sentinel arrival meaning "the link dropped this frame".
const DROPPED: u64 = u64::MAX;
/// Sanity cap on any single frame's payload (1 GiB of bytes / 256M f32):
/// trace files are offline artifacts where corruption is an expected
/// failure mode, so a flipped bit in a length field must surface as
/// `InvalidData`, not as a 2^60-byte allocation aborting the process.
const MAX_FRAME_LEN: u64 = 1 << 30;

/// Guard a deserialized length field against a caller-chosen cap,
/// surfacing overruns as `InvalidData`. Shared hardening for every
/// length-prefixed on-disk format in the crate — the trace frames here
/// and the job snapshots of [`crate::serve::checkpoint`]: a flipped bit
/// in a length field must become an error, never a giant allocation.
pub fn checked_len_capped(raw: u64, what: &str, cap: u64) -> io::Result<usize> {
    if raw > cap {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("corrupt input: {what} length {raw} exceeds the {cap} cap"),
        ));
    }
    Ok(raw as usize)
}

fn checked_len(raw: u64, what: &str) -> io::Result<usize> {
    checked_len_capped(raw, what, MAX_FRAME_LEN)
}

/// One parsed trace record.
#[derive(Debug)]
pub enum TraceFrame {
    Broadcast { round: u64, worker: usize, iterate: Vec<f32> },
    Upload { up: Upload, at: Option<u64> },
}

/// Write the trace header (magic + worker count).
pub fn write_trace_header(w: &mut impl Write, workers: usize) -> io::Result<()> {
    w.write_all(TRACE_MAGIC)?;
    w.write_all(&(workers as u64).to_le_bytes())
}

/// Read and validate the trace header; returns the worker count.
pub fn read_trace_header(r: &mut impl Read) -> io::Result<usize> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != TRACE_MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "not a KFTRACE1 trace file"));
    }
    Ok(read_u64(r)? as usize)
}

/// Serialize one broadcast frame (full fp32 iterate).
pub fn write_broadcast_frame(w: &mut impl Write, worker: usize, b: &Broadcast) -> io::Result<()> {
    w.write_all(&[TAG_BROADCAST])?;
    w.write_all(&b.round.to_le_bytes())?;
    w.write_all(&(worker as u32).to_le_bytes())?;
    w.write_all(&(b.iterate.len() as u64).to_le_bytes())?;
    for &v in &b.iterate {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Serialize one upload frame (exact wire bytes + accounting + arrival).
pub fn write_upload_frame(w: &mut impl Write, up: &Upload, at: Option<u64>) -> io::Result<()> {
    w.write_all(&[TAG_UPLOAD])?;
    w.write_all(&up.round.to_le_bytes())?;
    w.write_all(&(up.worker as u32).to_le_bytes())?;
    w.write_all(&at.unwrap_or(DROPPED).to_le_bytes())?;
    w.write_all(&up.local_value.to_le_bytes())?;
    w.write_all(&(up.msg.n as u64).to_le_bytes())?;
    w.write_all(&(up.msg.payload_bits as u64).to_le_bytes())?;
    w.write_all(&(up.msg.side_bits as u64).to_le_bytes())?;
    w.write_all(&(up.msg.bytes.len() as u64).to_le_bytes())?;
    w.write_all(&up.msg.bytes)
}

/// Read the next record; `Ok(None)` at clean end-of-trace.
pub fn read_trace_frame(r: &mut impl Read) -> io::Result<Option<TraceFrame>> {
    let mut tag = [0u8; 1];
    match r.read_exact(&mut tag) {
        Ok(()) => {}
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    match tag[0] {
        TAG_BROADCAST => {
            let round = read_u64(r)?;
            let worker = read_u32(r)? as usize;
            let len = checked_len(read_u64(r)?, "broadcast iterate")?;
            let mut iterate = Vec::with_capacity(len);
            for _ in 0..len {
                iterate.push(read_f32(r)?);
            }
            Ok(Some(TraceFrame::Broadcast { round, worker, iterate }))
        }
        TAG_UPLOAD => {
            let round = read_u64(r)?;
            let worker = read_u32(r)? as usize;
            let at_raw = read_u64(r)?;
            let local_value = read_f32(r)?;
            let n = checked_len(read_u64(r)?, "upload dimension")?;
            let payload_bits = read_u64(r)? as usize;
            let side_bits = read_u64(r)? as usize;
            let nbytes = checked_len(read_u64(r)?, "upload bytes")?;
            let mut bytes = vec![0u8; nbytes];
            r.read_exact(&mut bytes)?;
            Ok(Some(TraceFrame::Upload {
                up: Upload {
                    round,
                    worker,
                    msg: Compressed { n, bytes, payload_bits, side_bits },
                    local_value,
                },
                at: if at_raw == DROPPED { None } else { Some(at_raw) },
            }))
        }
        t => Err(io::Error::new(io::ErrorKind::InvalidData, format!("unknown trace tag {t}"))),
    }
}

/// Read one little-endian `u64` (shared by the trace and checkpoint
/// readers).
pub fn read_u64(r: &mut impl Read) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Read one little-endian `u32`.
pub fn read_u32(r: &mut impl Read) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Read one little-endian `f32`.
pub fn read_f32(r: &mut impl Read) -> io::Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upload_sizes_reflect_compressed() {
        let up = Upload {
            round: 3,
            worker: 1,
            msg: Compressed { n: 100, bytes: vec![0; 25], payload_bits: 200, side_bits: 32 },
            local_value: 1.0,
        };
        assert_eq!(up.payload_bits(), 200);
        assert_eq!(up.overhead_bits(), 64 + 32 + 32 + 32);
        assert_eq!(upload_wire_bytes(&up.msg), (200 + 32 + UPLOAD_HEADER_BITS).div_ceil(8));
    }

    #[test]
    fn broadcast_payload_free() {
        let b = Broadcast { round: 0, iterate: vec![0.0; 10] };
        assert_eq!(b.payload_bits(), 0);
        assert_eq!(b.overhead_bits(), 64 + 320);
    }

    #[test]
    fn trace_frames_roundtrip() {
        let mut buf: Vec<u8> = Vec::new();
        write_trace_header(&mut buf, 3).unwrap();
        let b = Broadcast { round: 7, iterate: vec![1.0, -2.5, 0.0] };
        write_broadcast_frame(&mut buf, 2, &b).unwrap();
        let up = Upload {
            round: 7,
            worker: 2,
            msg: Compressed { n: 16, bytes: vec![0xAB, 0xCD], payload_bits: 12, side_bits: 32 },
            local_value: 3.25,
        };
        write_upload_frame(&mut buf, &up, Some(450)).unwrap();
        write_upload_frame(&mut buf, &up, None).unwrap();

        let mut r: &[u8] = &buf;
        assert_eq!(read_trace_header(&mut r).unwrap(), 3);
        match read_trace_frame(&mut r).unwrap().unwrap() {
            TraceFrame::Broadcast { round, worker, iterate } => {
                assert_eq!((round, worker), (7, 2));
                assert_eq!(iterate, vec![1.0, -2.5, 0.0]);
            }
            other => panic!("expected broadcast, got {other:?}"),
        }
        match read_trace_frame(&mut r).unwrap().unwrap() {
            TraceFrame::Upload { up, at } => {
                assert_eq!(at, Some(450));
                assert_eq!(up.round, 7);
                assert_eq!(up.worker, 2);
                assert_eq!(up.msg.bytes, vec![0xAB, 0xCD]);
                assert_eq!(up.msg.payload_bits, 12);
                assert_eq!(up.local_value, 3.25);
            }
            other => panic!("expected upload, got {other:?}"),
        }
        match read_trace_frame(&mut r).unwrap().unwrap() {
            TraceFrame::Upload { at, .. } => assert_eq!(at, None),
            other => panic!("expected upload, got {other:?}"),
        }
        assert!(read_trace_frame(&mut r).unwrap().is_none(), "clean EOF expected");
    }

    #[test]
    fn bad_magic_is_rejected() {
        let mut r: &[u8] = b"NOTATRACE.......";
        assert!(read_trace_header(&mut r).is_err());
    }

    #[test]
    fn corrupt_length_fields_are_rejected_not_allocated() {
        // An upload frame whose nbytes field is garbage must come back
        // as InvalidData, not as a giant allocation.
        let mut buf: Vec<u8> = Vec::new();
        buf.push(1u8); // upload tag
        buf.extend_from_slice(&0u64.to_le_bytes()); // round
        buf.extend_from_slice(&0u32.to_le_bytes()); // worker
        buf.extend_from_slice(&0u64.to_le_bytes()); // arrival
        buf.extend_from_slice(&0f32.to_le_bytes()); // local value
        buf.extend_from_slice(&8u64.to_le_bytes()); // n
        buf.extend_from_slice(&8u64.to_le_bytes()); // payload bits
        buf.extend_from_slice(&0u64.to_le_bytes()); // side bits
        buf.extend_from_slice(&(u64::MAX / 2).to_le_bytes()); // corrupt nbytes
        let mut r: &[u8] = &buf;
        let err = read_trace_frame(&mut r).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // Same for a broadcast frame's iterate length.
        let mut buf: Vec<u8> = Vec::new();
        buf.push(0u8); // broadcast tag
        buf.extend_from_slice(&0u64.to_le_bytes()); // round
        buf.extend_from_slice(&0u32.to_le_bytes()); // worker
        buf.extend_from_slice(&u64::MAX.to_le_bytes()); // corrupt len
        let mut r: &[u8] = &buf;
        let err = read_trace_frame(&mut r).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }
}
