//! Wire messages between the parameter server and workers.
//!
//! The in-process transport passes these structs directly, but byte
//! accounting uses the *serialized* sizes ([`WireSize`]) so the metrics
//! reflect what a network deployment would move. The uplink payload is a
//! [`crate::quant::Compressed`] — already bit-exact — plus a small header.
//!
//! Message buffers (the broadcast's `iterate`, the upload's `msg.bytes`)
//! are owned `Vec`s so they can ping-pong through
//! [`crate::coordinator::channel::ChannelPools`] instead of being
//! reallocated per round; recycling is a transport-level concern and does
//! not change the wire sizes reported here.

use crate::quant::Compressed;

/// Downlink: server → worker. The broadcast iterate is sent at full
/// precision, as in the paper ("the worker receives the current iterate") —
//  only the uplink is budget-constrained.
#[derive(Clone, Debug)]
pub struct Broadcast {
    pub round: u64,
    pub iterate: Vec<f32>,
}

/// Uplink: worker → server, carrying the quantized gradient.
#[derive(Debug)]
pub struct Upload {
    pub round: u64,
    pub worker: usize,
    pub msg: Compressed,
    /// Local objective value at the broadcast iterate (f32 side channel,
    /// used for metrics only).
    pub local_value: f32,
}

/// Serialized size of a message, in bits, as it would cross a network.
pub trait WireSize {
    /// Bits subject to the per-round budget (quantized payload).
    fn payload_bits(&self) -> usize;
    /// Bits of headers/side info not counted against the budget.
    fn overhead_bits(&self) -> usize;
}

impl WireSize for Broadcast {
    fn payload_bits(&self) -> usize {
        0 // downlink is unconstrained in the paper's model
    }

    fn overhead_bits(&self) -> usize {
        64 + 32 * self.iterate.len()
    }
}

impl WireSize for Upload {
    fn payload_bits(&self) -> usize {
        self.msg.payload_bits
    }

    fn overhead_bits(&self) -> usize {
        // round + worker id + side info + local value
        64 + 32 + self.msg.side_bits + 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upload_sizes_reflect_compressed() {
        let up = Upload {
            round: 3,
            worker: 1,
            msg: Compressed { n: 100, bytes: vec![0; 25], payload_bits: 200, side_bits: 32 },
            local_value: 1.0,
        };
        assert_eq!(up.payload_bits(), 200);
        assert_eq!(up.overhead_bits(), 64 + 32 + 32 + 32);
    }

    #[test]
    fn broadcast_payload_free() {
        let b = Broadcast { round: 0, iterate: vec![0.0; 10] };
        assert_eq!(b.payload_bits(), 0);
        assert_eq!(b.overhead_bits(), 64 + 320);
    }
}
