//! Parameter-server loop: broadcast → collect → decode → consensus →
//! step → project (Algorithm 3's server side).

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::channel::TrafficCounter;
use crate::coordinator::config::RunConfig;
use crate::coordinator::metrics::{RoundMetrics, RunMetrics};
use crate::coordinator::protocol::{Broadcast, Upload};
use crate::opt::projection::Domain;
use crate::quant::Compressor;

/// Dimension at which the server fans the per-round decode out across
/// scoped threads. Below this, a decode is a few microseconds of work and
/// a thread spawn would cost more than it saves; above it (the (N)DSC
/// decode is an `O(N log N)` FWHT plus an `O(N)` inverse transform, and
/// the transformer workload has `n ~ 10^5`) the `m`-way fan-out is a
/// near-linear speedup of the consensus step.
pub const PARALLEL_DECODE_MIN_DIM: usize = 8192;

/// Decode the round's uploads into the consensus average. One scoped
/// thread per upload when `n` is large enough to amortize the spawns;
/// worker order of accumulation is fixed either way, so the result is
/// bit-identical to the sequential path.
fn decode_round(
    consensus: &mut [f32],
    ups: &[Upload],
    compressors: &[std::sync::Arc<dyn Compressor>],
    n: usize,
) {
    let m = ups.len();
    if m > 1 && n >= PARALLEL_DECODE_MIN_DIM {
        std::thread::scope(|s| {
            let handles: Vec<_> = ups
                .iter()
                .map(|up| {
                    let comp = &compressors[up.worker];
                    s.spawn(move || comp.decompress(&up.msg))
                })
                .collect();
            for h in handles {
                let q = h.join().expect("decode thread panicked");
                for (c, &qi) in consensus.iter_mut().zip(&q) {
                    *c += qi / m as f32;
                }
            }
        });
    } else {
        for up in ups {
            let q = compressors[up.worker].decompress(&up.msg);
            for (c, &qi) in consensus.iter_mut().zip(&q) {
                *c += qi / m as f32;
            }
        }
    }
}

/// Server loop. `eval` computes the global objective value of an iterate
/// (for metrics; pass a cheap proxy for expensive models).
pub fn server_loop(
    cfg: &RunConfig,
    x0: Vec<f32>,
    downlinks: &[Sender<Broadcast>],
    uplink: &Receiver<Upload>,
    compressors: &[Arc<dyn Compressor>],
    traffic: Arc<TrafficCounter>,
    mut eval: impl FnMut(&[f32]) -> f32,
) -> RunMetrics {
    let m = downlinks.len();
    let n = cfg.n;
    assert_eq!(x0.len(), n, "x0 dimension mismatch");
    let domain = if cfg.radius.is_finite() {
        Domain::L2Ball { radius: cfg.radius }
    } else {
        Domain::Unconstrained
    };
    let mut x = x0;
    domain.project(&mut x);
    let mut consensus = vec![0.0f32; n];
    let mut metrics = RunMetrics::default();

    for round in 0..cfg.rounds as u64 {
        let t0 = Instant::now();
        // Broadcast the iterate.
        for tx in downlinks {
            // A dead worker is fatal: the consensus average would silently
            // change semantics, so surface it.
            tx.send(Broadcast { round, iterate: x.clone() }).expect("worker hung up");
        }
        // Collect exactly m uploads for this round (workers answer every
        // broadcast exactly once; rounds cannot interleave), then decode
        // them — in parallel when the dimension warrants it.
        consensus.fill(0.0);
        let mut round_bits = 0usize;
        let mut local_sum = 0.0f64;
        let mut ups: Vec<Upload> = Vec::with_capacity(m);
        for _ in 0..m {
            let up = uplink.recv().expect("all workers disconnected");
            assert_eq!(up.round, round, "round skew: got {} want {round}", up.round);
            round_bits += up.msg.payload_bits;
            local_sum += up.local_value as f64;
            ups.push(up);
        }
        decode_round(&mut consensus, &ups, compressors, n);
        // Step + project.
        for (xi, &ci) in x.iter_mut().zip(&consensus) {
            *xi -= cfg.step * ci;
        }
        domain.project(&mut x);
        metrics.rounds.push(RoundMetrics {
            round,
            value: eval(&x),
            mean_local_value: (local_sum / m as f64) as f32,
            payload_bits: round_bits,
            wall: t0.elapsed(),
        });
    }
    metrics.total_payload_bits = traffic.payload_bits.load(std::sync::atomic::Ordering::Relaxed);
    metrics.total_overhead_bits = traffic.overhead_bits.load(std::sync::atomic::Ordering::Relaxed);
    metrics.rejected_messages = traffic.rejected.load(std::sync::atomic::Ordering::Relaxed);
    metrics.final_iterate = x;
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::SchemeKind;
    use crate::coordinator::run_distributed;
    use crate::coordinator::worker::DatasetGradSource;
    use crate::data::synthetic::planted_regression_shards;
    use crate::linalg::rng::Rng;
    use crate::opt::objectives::Loss;

    /// End-to-end: 4 workers, NDSC at R=2, planted regression — global
    /// loss must drop by >10x and the budget must hold exactly.
    #[test]
    fn distributed_regression_converges() {
        let mut rng = Rng::seed_from(1);
        let (shards, _xs) =
            planted_regression_shards(4, 12, 16, Loss::Square, &mut rng, false);
        let global: Vec<_> = shards.clone();
        let cfg = RunConfig {
            n: 16,
            workers: 4,
            r: 2.0,
            scheme: SchemeKind::Ndsc,
            rounds: 150,
            step: 0.02,
            batch: 0,
            ..Default::default()
        };
        let comps = cfg.build_compressors(&mut rng);
        let sources: Vec<Box<dyn crate::coordinator::worker::GradSource>> = shards
            .into_iter()
            .enumerate()
            .map(|(i, obj)| {
                Box::new(DatasetGradSource { obj, batch: 0, rng: Rng::seed_from(100 + i as u64) })
                    as Box<dyn crate::coordinator::worker::GradSource>
            })
            .collect();
        let metrics = run_distributed(&cfg, vec![0.0; 16], sources, comps, |x| {
            global.iter().map(|s| s.value(x)).sum::<f32>() / 4.0
        });
        assert_eq!(metrics.rounds.len(), 150);
        assert_eq!(metrics.rejected_messages, 0);
        let first = metrics.rounds[0].value;
        let last = metrics.final_value();
        assert!(last < 0.1 * first, "loss {first} -> {last}");
        // Exact budget: every round, every worker sends floor(16*2)=32 bits.
        assert_eq!(metrics.total_payload_bits, 150 * 4 * 32);
        assert!((metrics.mean_rate(16, 4) - 2.0).abs() < 1e-6);
    }
}
